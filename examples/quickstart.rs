//! Quickstart: describe the problem, build an [`H2Solver`] session, solve,
//! and read the report — no permutation bookkeeping, no free-function
//! factorize, no panics on bad input.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use h2ulv::prelude::*;
use h2ulv::util::Rng;

fn main() {
    let n = 4096;
    // 1. Problem description: 3-D Laplace on a sphere surface (paper eq 35).
    let geometry = Geometry::sphere_surface(n, 42);
    let kernel = KernelFn::laplace();
    let config = H2Config { leaf_size: 64, max_rank: 32, eta: 1.0, ..Default::default() };

    // 2. One build() runs H² construction (Algorithm 1) and the inherently
    //    parallel ULV factorization (Algorithms 2/4) on the chosen backend.
    let solver = H2SolverBuilder::new(geometry, kernel)
        .config(config)
        .backend(BackendSpec::Native)
        .subst_mode(SubstMode::Parallel)
        .build()
        .expect("quickstart problem is well-formed");
    let stats = solver.stats();
    println!(
        "H² built: N={n}, depth={}, storage {:.1} MB vs dense {:.1} MB, \
         construct {:.3}s, factorize {:.3}s",
        stats.depth,
        stats.h2_entries as f64 * 8.0 / 1e6,
        (n * n) as f64 * 8.0 / 1e6,
        stats.construct_time,
        stats.factor_time
    );

    // 3. Solve in the caller's point ordering; the report carries a sampled
    //    exact-kernel residual.
    let mut rng = Rng::new(7);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let report = solver.solve(&b).expect("rhs length matches N");
    let resid = report.residual.expect("residual sampling enabled by default");
    println!(
        "solved[{}/{:?}] in {:.4}s, sampled residual |Ax-b|/|b| = {resid:.3e}",
        report.backend, report.subst_mode, report.subst_time
    );
    assert!(resid < 1e-2, "quickstart residual too large");

    // 4. Malformed input is a typed error, not a panic.
    let wrong = vec![0.0; n - 1];
    match solver.solve(&wrong) {
        Err(H2Error::DimensionMismatch { expected, got }) => {
            println!("wrong-length RHS rejected: expected {expected}, got {got}");
        }
        other => panic!("expected DimensionMismatch, got {other:?}"),
    }

    // 5. The same factorization serves many right-hand sides.
    let rhs: Vec<Vec<f64>> = (0..3)
        .map(|s| {
            let mut r = Rng::new(100 + s);
            (0..n).map(|_| r.normal()).collect()
        })
        .collect();
    let reports = solver.solve_many(&rhs).expect("all rhs lengths match");
    println!("solve_many: {} right-hand sides reused one factorization", reports.len());

    println!("quickstart OK");
}
