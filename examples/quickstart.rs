//! Quickstart: build an H²-matrix over a sphere, factorize with the
//! inherently parallel ULV scheme, solve, and verify the residual.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use h2ulv::batch::native::NativeBackend;
use h2ulv::construct::H2Config;
use h2ulv::geometry::Geometry;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::ulv::{factorize, SubstMode};
use h2ulv::util::Rng;

fn main() {
    let n = 4096;
    // 1. Geometry + kernel: 3-D Laplace on a sphere surface (paper eq 35).
    let geometry = Geometry::sphere_surface(n, 42);
    let kernel = KernelFn::laplace();

    // 2. H² construction with the factorization basis (Algorithm 1).
    let cfg = H2Config { leaf_size: 64, max_rank: 32, eta: 1.0, ..Default::default() };
    let h2 = H2Matrix::construct(&geometry, &kernel, &cfg);
    println!(
        "H² built: N={n}, depth={}, storage {:.1} MB vs dense {:.1} MB",
        h2.tree.depth,
        h2.storage_entries() as f64 * 8.0 / 1e6,
        (n * n) as f64 * 8.0 / 1e6
    );

    // 3. ULV factorization (Algorithm 2/4) — every level is batched,
    //    dependency-free work.
    let backend = NativeBackend::new();
    let factor = factorize(&h2, &backend);

    // 4. Inherently parallel forward/backward substitution (paper §3.7).
    let mut rng = Rng::new(7);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let x = factor.solve(&b, &backend, SubstMode::Parallel);

    // 5. Verify with a sampled exact-kernel residual.
    let bt = h2.tree.permute_vec(&b);
    let xt = h2.tree.permute_vec(&x);
    let resid = h2.residual_sampled(&xt, &bt, 256, 3);
    println!("sampled residual |Ax-b|/|b| = {resid:.3e}");
    assert!(resid < 1e-2, "quickstart residual too large");
    println!("quickstart OK");
}
