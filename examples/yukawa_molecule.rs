//! The paper's second workload (§6.4): Yukawa potential on (synthetic)
//! hemoglobin-like molecule surfaces, solved with the distributed runtime
//! — strong + weak scaling in one run, with communication accounting.
//!
//! ```bash
//! cargo run --release --example yukawa_molecule
//! ```

use h2ulv::construct::H2Config;
use h2ulv::dist::{dist_solve_driver, NCCL_LIKE};
use h2ulv::geometry::molecule::hemoglobin_like;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::ulv::SubstMode;
use h2ulv::util::Rng;

fn main() {
    let kernel = KernelFn::yukawa();
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 128, ..Default::default() };

    // Strong scaling: one molecule lattice, increasing rank counts.
    let base = hemoglobin_like(0.2, 11); // ~3000 surface points
    let n = 8192;
    let copies = n / base.len() + 1;
    let g = base.duplicate_lattice(copies, 6.0).truncated(n);
    println!("geometry: {} ({} points)", g.name, g.len());
    let h2 = H2Matrix::construct(&g, &kernel, &cfg);
    let mut rng = Rng::new(3);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let bt = h2.tree.permute_vec(&b);

    println!("\nstrong scaling (N={n}):");
    println!("P, factor_s, subst_s, factor_comm_KB, subst_comm_KB, residual");
    let mut x1: Option<Vec<f64>> = None;
    for p in [1usize, 2, 4, 8] {
        let report = dist_solve_driver(&h2, p, &bt, SubstMode::Parallel);
        let resid = h2.residual_sampled(&report.x, &bt, 128, 7);
        println!(
            "{p}, {:.4}, {:.4}, {:.1}, {:.1}, {resid:.2e}",
            report.factor_time(&NCCL_LIKE),
            report.subst_time(&NCCL_LIKE),
            report.factor_bytes as f64 / 1e3,
            report.subst_bytes as f64 / 1e3
        );
        // All rank counts must produce the same solution.
        match &x1 {
            None => x1 = Some(report.x),
            Some(ref_x) => {
                let err = h2ulv::linalg::norms::rel_err_vec(&report.x, ref_x);
                assert!(err < 1e-10, "P={p} diverged: {err}");
            }
        }
        assert!(resid < 2e-2);
    }
    println!("\nyukawa_molecule OK (all rank counts agree)");
}
