//! The paper's second workload (§6.4): Yukawa potential on (synthetic)
//! hemoglobin-like molecule surfaces, solved through the facade's
//! simulated distributed runtime — strong scaling with communication
//! accounting, all permutation handled inside [`H2Solver`].
//!
//! ```bash
//! cargo run --release --example yukawa_molecule
//! ```

use h2ulv::geometry::molecule::hemoglobin_like;
use h2ulv::prelude::*;
use h2ulv::util::Rng;

fn main() {
    let kernel = KernelFn::yukawa();
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 128, ..Default::default() };

    // Strong scaling: one molecule lattice, increasing rank counts.
    let base = hemoglobin_like(0.2, 11); // ~3000 surface points
    let n = 8192;
    let copies = n / base.len() + 1;
    let g = base.duplicate_lattice(copies, 6.0).truncated(n);
    println!("geometry: {} ({} points)", g.name, g.len());
    let solver = H2SolverBuilder::new(g, kernel)
        .config(cfg)
        .residual_samples(128)
        .build()
        .expect("well-formed problem");
    let mut rng = Rng::new(3);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    println!("\nstrong scaling (N={n}):");
    println!("P, factor_s, subst_s, factor_comm_KB, subst_comm_KB, residual");
    let mut x1: Option<Vec<f64>> = None;
    for p in [1usize, 2, 4, 8] {
        let rep = solver.solve_dist(&b, p).expect("rhs matches");
        let resid = rep.residual.unwrap_or(f64::NAN);
        println!(
            "{p}, {:.4}, {:.4}, {:.1}, {:.1}, {resid:.2e}",
            rep.factor_time,
            rep.subst_time,
            rep.factor_bytes as f64 / 1e3,
            rep.subst_bytes as f64 / 1e3
        );
        // All rank counts must produce the same solution.
        match &x1 {
            None => x1 = Some(rep.x),
            Some(ref_x) => {
                let err = h2ulv::linalg::norms::rel_err_vec(&rep.x, ref_x);
                assert!(err < 1e-10, "P={p} diverged: {err}");
            }
        }
        assert!(resid < 2e-2);
    }
    println!("\nyukawa_molecule OK (all rank counts agree)");
}
