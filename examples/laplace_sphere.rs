//! End-to-end driver (DESIGN.md §"End-to-end validation"): the full stack
//! on the paper's first workload — 3-D Laplace on a sphere surface — now
//! through the [`H2Solver`] facade: native and PJRT backends, both
//! substitution modes, and an O(N) complexity check across problem sizes.
//! The PJRT column reuses the native session via `rebind_backend` (one H²
//! construction, one recorded plan, two executions). Results land in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example laplace_sphere
//! ```

use h2ulv::prelude::*;
use h2ulv::util::Rng;

fn main() {
    let kernel = KernelFn::laplace();
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 128, ..Default::default() };
    let mut pjrt_warned = false;
    println!("N, construct_s, factor_native_s, factor_pjrt_s, gflops_native, subst_par_s, subst_naive_s, launches, residual");
    let mut prev_time = None;
    for n in [2048usize, 4096, 8192, 16384] {
        let g = Geometry::sphere_surface(n, 1);
        let mut solver = H2SolverBuilder::new(g, kernel.clone())
            .config(cfg.clone())
            .build()
            .expect("well-formed problem");
        let t_c = solver.stats().construct_time;
        let t_f = solver.stats().factor_time;
        let fl = solver.stats().factor_flops;
        let launches = solver.stats().schedule.factor_launches();
        let mut rng = Rng::new(5);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let rep_par = solver.solve(&b).expect("rhs matches");
        let rep_naive = solver.solve_with(&b, SubstMode::Naive).expect("rhs matches");
        let resid = rep_par.residual.unwrap_or(f64::NAN);
        // PJRT column: rebind the backend over the existing H² matrix and
        // replay the cached plan; NaN when artifacts are missing.
        let t_fp = match solver.rebind_backend(BackendSpec::pjrt()) {
            Ok(stats) => stats.factor_time,
            Err(e) => {
                if !pjrt_warned {
                    eprintln!("NOTE: pjrt backend unavailable ({e}); run `make artifacts`.");
                    pjrt_warned = true;
                }
                f64::NAN
            }
        };
        assert_eq!(
            solver.plan_recordings(),
            1,
            "backend rebinding must not re-derive the schedule"
        );
        println!(
            "{n}, {t_c:.3}, {t_f:.3}, {t_fp:.3}, {:.2}, {:.4}, {:.4}, {launches}, {resid:.2e}",
            fl as f64 / t_f / 1e9,
            rep_par.subst_time,
            rep_naive.subst_time
        );
        // O(N) check: doubling N should scale time by ~2, not 4+.
        if let Some(prev) = prev_time {
            let ratio: f64 = t_f / prev;
            assert!(
                ratio < 3.5,
                "factorization must scale near-linearly (got {ratio:.2}x per 2x N)"
            );
        }
        prev_time = Some(t_f);
        // Fixed rank 32 at every level => accuracy drifts slowly upward
        // with depth (the paper uses adaptive ranks to pin accuracy; our
        // artifact families fix leaf=2*rank). Require sane accuracy only.
        assert!(resid < 1e-1, "residual {resid} too large at N={n}");
    }
    println!("laplace_sphere end-to-end OK");
}
