//! End-to-end driver (DESIGN.md §"End-to-end validation"): the full
//! three-layer stack on the paper's first workload — 3-D Laplace on a
//! sphere surface — exercising construction, the **PJRT backend running
//! the AOT JAX/Pallas artifacts**, both substitution modes, and an O(N)
//! complexity check across problem sizes. Results land in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example laplace_sphere
//! ```

use h2ulv::batch::native::NativeBackend;
use h2ulv::construct::H2Config;
use h2ulv::geometry::Geometry;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::metrics::{flops, timer::timed};
use h2ulv::runtime::PjrtBackend;
use h2ulv::ulv::{factorize, SubstMode};
use h2ulv::util::Rng;

fn main() {
    let kernel = KernelFn::laplace();
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 128, ..Default::default() };
    let pjrt = PjrtBackend::new(std::path::Path::new("artifacts")).ok();
    if pjrt.is_none() {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts` for the PJRT path.");
    }
    println!("N, construct_s, factor_native_s, factor_pjrt_s, gflops_native, subst_par_s, subst_naive_s, residual");
    let mut prev_time = None;
    for n in [2048usize, 4096, 8192, 16384] {
        let g = Geometry::sphere_surface(n, 1);
        let (h2, t_c) = timed(|| H2Matrix::construct(&g, &kernel, &cfg));
        let native = NativeBackend::new();
        let before = flops::snapshot();
        let (fac, t_f) = timed(|| factorize(&h2, &native));
        let fl = flops::delta(before, flops::snapshot()).factor;
        let t_fp = match &pjrt {
            Some(be) => timed(|| factorize(&h2, be)).1,
            None => f64::NAN,
        };
        let mut rng = Rng::new(5);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let bt = h2.tree.permute_vec(&b);
        let (x, t_sp) = timed(|| fac.solve_tree_order(&bt, &native, SubstMode::Parallel));
        let (_, t_sn) = timed(|| fac.solve_tree_order(&bt, &native, SubstMode::Naive));
        let resid = h2.residual_sampled(&x, &bt, 128, 9);
        println!(
            "{n}, {t_c:.3}, {t_f:.3}, {t_fp:.3}, {:.2}, {t_sp:.4}, {t_sn:.4}, {resid:.2e}",
            fl as f64 / t_f / 1e9
        );
        // O(N) check: doubling N should scale time by ~2, not 4+.
        if let Some(prev) = prev_time {
            let ratio: f64 = t_f / prev;
            assert!(
                ratio < 3.5,
                "factorization must scale near-linearly (got {ratio:.2}x per 2x N)"
            );
        }
        prev_time = Some(t_f);
        // Fixed rank 32 at every level => accuracy drifts slowly upward
        // with depth (the paper uses adaptive ranks to pin accuracy; our
        // artifact families fix leaf=2*rank). Require sane accuracy only.
        assert!(resid < 1e-1, "residual {resid} too large at N={n}");
    }
    if let Some(be) = &pjrt {
        println!(
            "\npjrt launches: {}, fallbacks: {}",
            be.stats.launches.load(std::sync::atomic::Ordering::Relaxed),
            be.stats.fallbacks.load(std::sync::atomic::Ordering::Relaxed)
        );
    }
    println!("laplace_sphere end-to-end OK");
}
