//! Solver shoot-out on one problem: dense Cholesky (oracle), BLR tile
//! Cholesky (LORAPO analog, O(N²)), HSS (η=0) and H²-ULV — accuracy,
//! FLOPs, and time side by side (the paper's Figures 18-20 in miniature).
//!
//! ```bash
//! cargo run --release --example solver_comparison
//! ```

use h2ulv::baselines::blr::{BlrConfig, BlrMatrix};
use h2ulv::baselines::dense::DenseSolver;
use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::metrics::{flops, timer::timed};
use h2ulv::prelude::*;
use h2ulv::tree::ClusterTree;
use h2ulv::util::Rng;

fn main() {
    let n = 2048;
    let g = Geometry::sphere_surface(n, 99);
    let kernel = KernelFn::laplace();
    let mut rng = Rng::new(1);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    println!("solver, factor_s, solve_s, factor_gflop, solution_err");

    // Dense oracle.
    let dense_scope = flops::FlopScope::new();
    let (dense, t_df) = timed(|| {
        flops::scoped(&dense_scope, flops::Phase::Factor, || {
            DenseSolver::factorize(&g.points, &kernel).unwrap()
        })
    });
    let dfl = dense_scope.snapshot().total;
    let (x_dense, t_ds) = timed(|| dense.solve(&b));
    println!("dense,  {t_df:.3}, {t_ds:.4}, {:.2}, (oracle)", dfl as f64 / 1e9);

    // BLR.
    let tree = ClusterTree::build(&g, 128);
    let bt = tree.permute_vec(&b);
    let mut blr = BlrMatrix::build(&tree.points, &kernel, &BlrConfig { rtol: 1e-9, ..Default::default() });
    let blr_scope = flops::FlopScope::new();
    let ((), t_bf) = timed(|| {
        flops::scoped(&blr_scope, flops::Phase::Factor, || blr.factorize())
    });
    let bfl = blr_scope.snapshot().factor;
    let (xt, t_bs) = timed(|| blr.solve(&bt));
    let x_blr = tree.unpermute_vec(&xt);
    println!(
        "blr,    {t_bf:.3}, {t_bs:.4}, {:.2}, {:.2e}",
        bfl as f64 / 1e9,
        rel_err_vec(&x_blr, &x_dense)
    );

    // HSS (eta = 0) and H² (eta = 1) through the same facade.
    for (name, eta) in [("hss", 0.0), ("h2ulv", 1.0)] {
        let cfg = H2Config {
            leaf_size: 256,
            max_rank: 48,
            far_samples: 0,
            near_samples: 0,
            eta,
            ..Default::default()
        };
        let solver = H2SolverBuilder::new(g.clone(), kernel.clone())
            .config(cfg)
            .residual_samples(0)
            .build()
            .expect("well-formed problem");
        let rep = solver.solve(&b).expect("rhs matches");
        println!(
            "{name}, {:.3}, {:.4}, {:.2}, {:.2e}",
            solver.stats().factor_time,
            rep.subst_time,
            solver.stats().factor_flops as f64 / 1e9,
            rel_err_vec(&rep.x, &x_dense)
        );
    }
    println!("\nsolver_comparison OK");
}
