// Load every artifact family once; execute potrf/trsm/sparsify on real data.
fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    for name in ["potrf_b2_d32_k16", "trsm_b2_d32_k16", "sparsify_b2_d32_k16", "trsv_fwd_b2_d32_k16", "gemv_nt_b2_d32_k16", "basis_t_b2_d32_k16", "schur_b2_d32_k16"] {
        let path = format!("artifacts/{name}.hlo.txt");
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        match client.compile(&comp) {
            Ok(exe) => {
                // build dummy inputs per op shapes
                let mk = |b: usize, r: usize, c: usize, spd: bool| -> xla::Literal {
                    let mut v = vec![0.0f64; b*r*c];
                    for t in 0..b { for i in 0..r { for j in 0..c.min(r) {
                        v[t*r*c + i*c + j] = if i==j { (r + 2) as f64 } else if i>j && spd { 0.3/(1.0+(i-j) as f64) } else if spd {0.3/(1.0+(j-i) as f64)} else { 0.1 };
                    }}}
                    xla::Literal::vec1(&v).reshape(&[b as i64, r as i64, c as i64]).unwrap()
                };
                let args: Vec<xla::Literal> = match name.split('_').next().unwrap() {
                    "potrf" => vec![mk(2,16,16,true)],
                    "trsm" => vec![mk(2,16,16,true), mk(2,16,16,false)],
                    "sparsify" => vec![mk(2,32,32,false), mk(2,32,32,false), mk(2,32,32,false)],
                    "trsv" => vec![mk(2,16,16,true), mk(2,16,1,false)],
                    "gemv" => vec![mk(2,16,16,false), mk(2,16,1,false), mk(2,16,1,false)],
                    "basis" => vec![mk(2,32,32,false), mk(2,32,1,false)],
                    "schur" => vec![mk(2,16,16,true), mk(2,16,16,false)],
                    _ => unreachable!(),
                };
                match exe.execute::<xla::Literal>(&args) {
                    Ok(res) => {
                        let lit = res[0][0].to_literal_sync()?;
                        let out = lit.to_tuple1()?;
                        let v = out.to_vec::<f64>()?;
                        println!("{name}: OK, out[0..3]={:?}", &v[..3]);
                    }
                    Err(e) => println!("{name}: EXEC FAIL: {e}"),
                }
            }
            Err(e) => println!("{name}: COMPILE FAIL: {e}"),
        }
    }
    Ok(())
}
