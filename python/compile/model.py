"""Layer-2 JAX graphs: the batched ULV level-step operations.

Each function is a fixed-shape batched computation the rust coordinator
launches through PJRT (one AOT executable per (op, batch, D, K) bucket —
constant-size batches with zero padding, exactly the paper's §4.1 policy).

The FLOP hot spots call the Layer-1 Pallas kernels in
``kernels/batched_ops.py``; factorization-specific ops (Cholesky,
triangular solve) use ``jax.lax.linalg`` which XLA lowers to its native
batched routines — the analog of cuSOLVER's batched POTRF/TRSM.
"""

import jax
import jax.numpy as jnp

from .kernels import batched_ops as k1
from .kernels import factor_ops

jax.config.update("jax_enable_x64", True)


def sparsify(u, a, v):
    """F[t] = U[t]^T A[t] V[t] (matrix sparsification; Pallas two_sided)."""
    return (k1.two_sided(u, a, v),)


def potrf(a):
    """Batched lower Cholesky.

    Padded inputs carry unit diagonals in the padded region (the paper's
    AXPY-diagonal trick) so the factorization never hits a zero pivot.
    Custom-call-free (see kernels/factor_ops.py): plain-HLO while loop, so
    the artifact loads on the rust PJRT CPU client.
    """
    return (factor_ops.cholesky(a),)


def trsm_right_lt(l, b):
    """X[t] = B[t] @ L[t]^-T  (panel solve L_ji = A_ji L_ii^-T)."""
    return (factor_ops.trsm_right_lt(l, b),)


def schur_self(c, a):
    """C[t] - A[t] A[t]^T (the single allowed trailing update; Pallas)."""
    return (k1.schur_update(c, a),)


def trsv_fwd(l, x):
    """y[t] = L[t]^-1 x[t] for vector RHS shaped [B, n, 1]."""
    return (factor_ops.trsv_fwd(l, x),)


def trsv_bwd(l, x):
    """y[t] = L[t]^-T x[t] for vector RHS shaped [B, n, 1]."""
    return (factor_ops.trsv_bwd(l, x),)


def gemv_acc_nt(a, x, y):
    """y[t] -= A[t] x[t]  (substitution update, A not transposed)."""
    return (y - k1.batched_matmul(a, x),)


def gemv_acc_tt(a, x, y):
    """y[t] -= A[t]^T x[t] (backward-pass update)."""
    return (y - k1.batched_matmul(a, x, ta=True),)


def basis_t(u, x):
    """c[t] = U[t]^T x[t] (apply basis transpose to a vector)."""
    return (k1.batched_matmul(u, x, ta=True),)


def basis_n(u, x):
    """b[t] = U[t] x[t] (apply basis to a vector)."""
    return (k1.batched_matmul(u, x),)


#: op name -> (function, example-shape builder given (batch, d, k)).
#: d = padded block dim (ndof), k = padded rank (= nred = d/2 in the
#: self-similar configuration leaf = 2*rank).
OPS = {
    "sparsify": (sparsify, lambda b, d, k: [(b, d, d), (b, d, d), (b, d, d)]),
    "potrf": (potrf, lambda b, d, k: [(b, k, k)]),
    "trsm": (trsm_right_lt, lambda b, d, k: [(b, k, k), (b, k, k)]),
    "schur": (schur_self, lambda b, d, k: [(b, k, k), (b, k, k)]),
    "trsv_fwd": (trsv_fwd, lambda b, d, k: [(b, k, k), (b, k, 1)]),
    "trsv_bwd": (trsv_bwd, lambda b, d, k: [(b, k, k), (b, k, 1)]),
    "gemv_nt": (gemv_acc_nt, lambda b, d, k: [(b, k, k), (b, k, 1), (b, k, 1)]),
    "gemv_tt": (gemv_acc_tt, lambda b, d, k: [(b, k, k), (b, k, 1), (b, k, 1)]),
    "basis_t": (basis_t, lambda b, d, k: [(b, d, d), (b, d, 1)]),
    "basis_n": (basis_n, lambda b, d, k: [(b, d, d), (b, d, 1)]),
}
