"""Custom-call-free batched Cholesky and triangular solves.

``jax.lax.linalg.{cholesky,triangular_solve}`` lower to LAPACK typed-FFI
custom-calls (``lapack_dpotrf_ffi`` etc.) that the xla crate's
xla_extension 0.5.1 runtime cannot load (``Unknown custom-call API version
enum value: 4``). These replacements lower to plain HLO (while-loops +
dynamic slices), so the AOT artifacts run on any PJRT backend. Block sizes
in this system are small (<= 64), so the O(n) sequential loop around an
O(n²) vectorized body is the right shape — it is also exactly how a TPU
would schedule a small Cholesky panel.
"""

import jax
import jax.numpy as jnp
from jax import lax

jax.config.update("jax_enable_x64", True)


def _chol_one(a):
    """Lower Cholesky of one SPD matrix via n rank-1 downdates."""
    n = a.shape[-1]
    idx = jnp.arange(n)

    def body(j, carry):
        a, l = carry
        d = jnp.sqrt(a[j, j])
        col = jnp.where(idx >= j, a[:, j] / d, 0.0)
        l = lax.dynamic_update_slice(l, col[:, None], (0, j))
        a = a - jnp.outer(col, col)
        return (a, l)

    _, l = lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    return l


def cholesky(a):
    """Batched lower Cholesky, [B, n, n] -> [B, n, n]."""
    return jax.vmap(_chol_one)(a)


def _trsm_right_lt_one(l, b):
    """Solve X Lᵀ = B (one matrix): column-by-column forward substitution."""
    n = l.shape[-1]
    idx = jnp.arange(n)

    def body(j, x):
        # Row j of L, masked to the already-solved columns (< j).
        lj = jnp.where(idx < j, l[j, :], 0.0)
        rhs = lax.dynamic_slice(b, (0, j), (b.shape[0], 1))[:, 0]
        col = (rhs - x @ lj) / l[j, j]
        return lax.dynamic_update_slice(x, col[:, None], (0, j))

    return lax.fori_loop(0, n, body, b)


def trsm_right_lt(l, b):
    """Batched X[t] = B[t] · L[t]ᵀ⁻¹."""
    return jax.vmap(_trsm_right_lt_one)(l, b)


def _trsv_fwd_one(l, x):
    """Solve L y = x (vector shaped [n, 1])."""
    n = l.shape[-1]
    idx = jnp.arange(n)
    v = x[:, 0]

    def body(j, y):
        lj = jnp.where(idx < j, l[j, :], 0.0)
        yj = (v[j] - jnp.dot(lj, y)) / l[j, j]
        return lax.dynamic_update_slice(y, yj[None], (j,))

    y = lax.fori_loop(0, n, body, jnp.zeros_like(v))
    return y[:, None]


def trsv_fwd(l, x):
    return jax.vmap(_trsv_fwd_one)(l, x)


def _trsv_bwd_one(l, x):
    """Solve Lᵀ y = x (vector shaped [n, 1])."""
    n = l.shape[-1]
    idx = jnp.arange(n)
    v = x[:, 0]

    def body(t, y):
        j = n - 1 - t
        # Column j of L below the diagonal = row of Lᵀ right of diagonal.
        cj = jnp.where(idx > j, l[:, j], 0.0)
        yj = (v[j] - jnp.dot(cj, y)) / l[j, j]
        return lax.dynamic_update_slice(y, yj[None], (j,))

    y = lax.fori_loop(0, n, body, jnp.zeros_like(v))
    return y[:, None]


def trsv_bwd(l, x):
    return jax.vmap(_trsv_bwd_one)(l, x)
