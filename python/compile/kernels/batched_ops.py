"""Layer-1 Pallas kernels: the FLOP hot spots of the ULV level step.

Three kernels, all batched over the leading dimension (the paper's batched
cuBLAS/cuSOLVER launches):

* ``batched_matmul`` — tiled ``C[b] = op(A[b]) @ op(B[b])``;
* ``schur_update``   — ``C[b] -= A[b] @ A[b].T`` (the single trailing
  update of Algorithm 2 line 16);
* ``two_sided``      — ``F[b] = U[b].T @ A[b] @ V[b]`` (matrix
  sparsification, paper Figure 2), fused so the intermediate stays in VMEM.

TPU adaptation notes (DESIGN.md §2): the grid iterates over the batch — on a
real TPU each grid step owns one block resident in VMEM, which plays the
role the paper assigns to a threadblock owning a tile in shared memory. The
MXU consumes the inner ``jnp.dot``/``@``. ``interpret=True`` is mandatory on
CPU PJRT (Mosaic custom-calls cannot run there — /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _mm_kernel(a_ref, b_ref, c_ref, *, ta: bool, tb: bool):
    # Block shapes carry a leading batch dim of 1 (one grid step = one
    # batch element resident in VMEM); index it away.
    a = a_ref[0]
    b = b_ref[0]
    if ta:
        a = a.T
    if tb:
        b = b.T
    c_ref[0] = jnp.dot(a, b, preferred_element_type=c_ref.dtype)


def batched_matmul(a, b, ta: bool = False, tb: bool = False):
    """``C[t] = op(A[t]) @ op(B[t])`` as a Pallas kernel, grid over batch."""
    bsz, am, ak = a.shape
    _, bk, bn = b.shape
    m = ak if ta else am
    k = am if ta else ak
    n = bk if tb else bn
    k2 = bn if tb else bk
    assert k == k2, f"inner dim mismatch {k} vs {k2}"
    return pl.pallas_call(
        functools.partial(_mm_kernel, ta=ta, tb=tb),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), a.dtype),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, am, ak), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, bk, bn), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda t: (t, 0, 0)),
        interpret=True,
    )(a, b)


def _schur_kernel(c_ref, a_ref, o_ref):
    a = a_ref[0]
    o_ref[0] = c_ref[0] - jnp.dot(a, a.T, preferred_element_type=o_ref.dtype)


def schur_update(c, a):
    """``C[t] - A[t] @ A[t].T`` — the diagonal SS Schur update (eq 21)."""
    bsz, n, _ = c.shape
    _, n2, k = a.shape
    assert n == n2
    return pl.pallas_call(
        _schur_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, n, n), c.dtype),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, n, k), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, n), lambda t: (t, 0, 0)),
        interpret=True,
    )(c, a)


def _two_sided_kernel(u_ref, a_ref, v_ref, o_ref):
    # U^T A V fused: the U^T A intermediate lives in registers/VMEM only.
    u = u_ref[0]
    a = a_ref[0]
    v = v_ref[0]
    ua = jnp.dot(u.T, a, preferred_element_type=o_ref.dtype)
    o_ref[0] = jnp.dot(ua, v, preferred_element_type=o_ref.dtype)


def two_sided(u, a, v):
    """``F[t] = U[t].T @ A[t] @ V[t]`` — matrix sparsification."""
    bsz, m, mu = u.shape
    _, m2, n = a.shape
    _, n2, nv = v.shape
    assert m == m2 and n == n2
    return pl.pallas_call(
        _two_sided_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, mu, nv), a.dtype),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, m, mu), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, m, n), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, n, nv), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, mu, nv), lambda t: (t, 0, 0)),
        interpret=True,
    )(u, a, v)
