"""Pure-jnp oracles for the Pallas kernels (build-time correctness only)."""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def batched_matmul_ref(a, b, ta: bool = False, tb: bool = False):
    if ta:
        a = jnp.swapaxes(a, -1, -2)
    if tb:
        b = jnp.swapaxes(b, -1, -2)
    return jnp.einsum("bij,bjk->bik", a, b)


def schur_update_ref(c, a):
    return c - jnp.einsum("bij,bkj->bik", a, a)


def two_sided_ref(u, a, v):
    return jnp.einsum("bji,bjk,bkl->bil", u, a, v)
