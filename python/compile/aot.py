"""AOT lowering: JAX level-step graphs -> HLO text artifacts + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
xla crate's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts [--families 64x32,32x16]
                              [--buckets 1,2,4,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

DEFAULT_FAMILIES = [(64, 32), (32, 16)]
DEFAULT_BUCKETS = [1, 2, 4, 8, 16, 32, 64, 128, 256]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_op(name: str, batch: int, d: int, k: int) -> str:
    fn, shapes = model.OPS[name]
    specs = [jax.ShapeDtypeStruct(s, jnp.float64) for s in shapes(batch, d, k)]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--families",
        default=",".join(f"{d}x{k}" for d, k in DEFAULT_FAMILIES),
        help="comma-separated DxK padded-shape families",
    )
    ap.add_argument(
        "--buckets",
        default=",".join(str(b) for b in DEFAULT_BUCKETS),
        help="comma-separated batch-size buckets",
    )
    ap.add_argument("--ops", default=",".join(model.OPS.keys()))
    args = ap.parse_args()

    families = []
    for fam in args.families.split(","):
        d, k = fam.split("x")
        families.append((int(d), int(k)))
    buckets = [int(b) for b in args.buckets.split(",")]
    ops = args.ops.split(",")

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"artifacts": []}
    count = 0
    for d, k in families:
        for op in ops:
            for b in buckets:
                fname = f"{op}_b{b}_d{d}_k{k}.hlo.txt"
                path = os.path.join(args.out_dir, fname)
                text = lower_op(op, b, d, k)
                with open(path, "w") as f:
                    f.write(text)
                manifest["artifacts"].append(
                    {"op": op, "batch": b, "d": d, "k": k, "file": fname}
                )
                count += 1
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {count} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
