"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import batched_ops as k1
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

dims = st.integers(min_value=1, max_value=12)
batches = st.integers(min_value=1, max_value=5)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape))


@settings(max_examples=25, deadline=None)
@given(b=batches, m=dims, k=dims, n=dims, ta=st.booleans(), tb=st.booleans())
def test_batched_matmul_matches_ref(b, m, k, n, ta, tb):
    rng = np.random.default_rng(b * 1000 + m * 100 + k * 10 + n)
    a_shape = (b, k, m) if ta else (b, m, k)
    b_shape = (b, n, k) if tb else (b, k, n)
    a = rand(rng, *a_shape)
    bb = rand(rng, *b_shape)
    got = k1.batched_matmul(a, bb, ta=ta, tb=tb)
    want = ref.batched_matmul_ref(a, bb, ta=ta, tb=tb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(b=batches, n=dims, k=dims)
def test_schur_update_matches_ref(b, n, k):
    rng = np.random.default_rng(b * 100 + n * 10 + k)
    c = rand(rng, b, n, n)
    a = rand(rng, b, n, k)
    got = k1.schur_update(c, a)
    want = ref.schur_update_ref(c, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(b=batches, m=dims, n=dims, ku=dims, kv=dims)
def test_two_sided_matches_ref(b, m, n, ku, kv):
    rng = np.random.default_rng(b + m * 7 + n * 13 + ku * 17 + kv * 19)
    u = rand(rng, b, m, ku)
    a = rand(rng, b, m, n)
    v = rand(rng, b, n, kv)
    got = k1.two_sided(u, a, v)
    want = ref.two_sided_ref(u, a, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11, atol=1e-11)


def test_f32_dtype_supported():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((2, 4, 4)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 4, 4)), dtype=jnp.float32)
    got = k1.batched_matmul(a, b)
    assert got.dtype == jnp.float32
    want = ref.batched_matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((1, 2, 3))
    b = jnp.zeros((1, 4, 2))
    with pytest.raises(AssertionError):
        k1.batched_matmul(a, b)
