"""AOT path: lowering to HLO text must produce loadable modules."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_lower_one_op_produces_hlo_text():
    text = aot.lower_op("potrf", 2, 16, 8)
    assert "HloModule" in text
    assert "f64" in text


def test_lower_all_ops_smallest_bucket():
    for op in model.OPS:
        text = aot.lower_op(op, 1, 8, 4)
        assert "HloModule" in text, op
        # return_tuple=True: the root must be a tuple.
        assert "ROOT" in text, op


def test_pallas_interpret_lowers_without_custom_call():
    # interpret=True must lower to plain HLO ops: a Mosaic/TPU custom-call
    # would be unloadable by the CPU PJRT client (README gotcha).
    text = aot.lower_op("sparsify", 1, 8, 4)
    assert "custom-call" not in text or "Sharding" in text


def test_no_typed_ffi_custom_calls_anywhere():
    # xla_extension 0.5.1 rejects API_VERSION_TYPED_FFI custom-calls
    # (lapack_*_ffi); every artifact must lower without them — that is why
    # factor_ops.py reimplements Cholesky/TRSM as plain-HLO loops.
    for op in model.OPS:
        text = aot.lower_op(op, 1, 8, 4)
        assert "API_VERSION_TYPED_FFI" not in text, op
        assert "lapack_" not in text, op


def test_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    argv = [
        "aot",
        "--out-dir",
        str(out),
        "--families",
        "8x4",
        "--buckets",
        "1,2",
        "--ops",
        "potrf,trsm",
    ]
    old = sys.argv
    sys.argv = argv
    try:
        aot.main()
    finally:
        sys.argv = old
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest["artifacts"]) == 4
    for art in manifest["artifacts"]:
        assert (out / art["file"]).exists()
