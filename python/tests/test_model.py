"""L2 correctness: the batched ULV level-step graphs vs numpy references."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model

jax.config.update("jax_enable_x64", True)


def spd_batch(rng, b, n):
    g = rng.standard_normal((b, n, n))
    a = np.einsum("bij,bkj->bik", g, g) + n * np.eye(n)
    return jnp.asarray(a)


def test_potrf_reconstructs():
    rng = np.random.default_rng(1)
    a = spd_batch(rng, 4, 8)
    (l,) = model.potrf(a)
    l = np.asarray(l)
    rec = np.einsum("bij,bkj->bik", l, l)
    np.testing.assert_allclose(rec, np.asarray(a), rtol=1e-10, atol=1e-10)
    # Lower triangular.
    for t in range(4):
        assert np.allclose(np.triu(l[t], 1), 0.0)


def test_potrf_with_identity_padding():
    # The padded region carries unit diagonal -> factorization succeeds and
    # the true corner is unchanged (paper's AXPY-diagonal trick).
    rng = np.random.default_rng(2)
    a_small = np.asarray(spd_batch(rng, 2, 4))
    padded = np.zeros((2, 8, 8))
    padded[:, :4, :4] = a_small
    for d in range(4, 8):
        padded[:, d, d] = 1.0
    (l,) = model.potrf(jnp.asarray(padded))
    l = np.asarray(l)
    want = np.linalg.cholesky(a_small)
    np.testing.assert_allclose(l[:, :4, :4], want, rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(l[:, 4:, 4:], np.broadcast_to(np.eye(4), (2, 4, 4)), atol=1e-12)


def test_trsm_right_lt():
    rng = np.random.default_rng(3)
    a = spd_batch(rng, 3, 6)
    l = np.linalg.cholesky(np.asarray(a))
    x_true = rng.standard_normal((3, 5, 6))
    b = np.einsum("bij,bkj->bik", x_true, l)  # B = X L^T
    (x,) = model.trsm_right_lt(jnp.asarray(l), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(x), x_true, rtol=1e-10, atol=1e-10)


def test_schur_self():
    rng = np.random.default_rng(4)
    c = rng.standard_normal((2, 5, 5))
    a = rng.standard_normal((2, 5, 3))
    (got,) = model.schur_self(jnp.asarray(c), jnp.asarray(a))
    want = c - np.einsum("bij,bkj->bik", a, a)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-12, atol=1e-12)


def test_trsv_roundtrip():
    rng = np.random.default_rng(5)
    a = spd_batch(rng, 3, 7)
    l = np.linalg.cholesky(np.asarray(a))
    x_true = rng.standard_normal((3, 7, 1))
    b_fwd = np.einsum("bij,bjk->bik", l, x_true)
    (y,) = model.trsv_fwd(jnp.asarray(l), jnp.asarray(b_fwd))
    np.testing.assert_allclose(np.asarray(y), x_true, rtol=1e-10, atol=1e-10)
    b_bwd = np.einsum("bji,bjk->bik", l, x_true)
    (y,) = model.trsv_bwd(jnp.asarray(l), jnp.asarray(b_bwd))
    np.testing.assert_allclose(np.asarray(y), x_true, rtol=1e-10, atol=1e-10)


def test_gemv_acc_both():
    rng = np.random.default_rng(6)
    a = rng.standard_normal((2, 4, 4))
    x = rng.standard_normal((2, 4, 1))
    y = rng.standard_normal((2, 4, 1))
    (got,) = model.gemv_acc_nt(jnp.asarray(a), jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), y - a @ x, rtol=1e-12)
    (got,) = model.gemv_acc_tt(jnp.asarray(a), jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), y - np.swapaxes(a, 1, 2) @ x, rtol=1e-12)


def test_basis_apply():
    rng = np.random.default_rng(7)
    u = rng.standard_normal((3, 6, 6))
    x = rng.standard_normal((3, 6, 1))
    (got,) = model.basis_t(jnp.asarray(u), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.swapaxes(u, 1, 2) @ x, rtol=1e-12)
    (got,) = model.basis_n(jnp.asarray(u), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), u @ x, rtol=1e-12)


def test_ops_table_shapes_consistent():
    # Every OPS entry must lower without error at a tiny bucket.
    for name, (fn, shapes) in model.OPS.items():
        specs = [jax.ShapeDtypeStruct(s, jnp.float64) for s in shapes(2, 8, 4)]
        lowered = jax.jit(fn).lower(*specs)
        assert lowered is not None, name


def test_sparsify_orthogonal_roundtrip():
    # For orthogonal U, V: U F V^T must reconstruct A.
    rng = np.random.default_rng(8)
    q, _ = np.linalg.qr(rng.standard_normal((6, 6)))
    u = np.broadcast_to(q, (2, 6, 6)).copy()
    a = rng.standard_normal((2, 6, 6))
    (f,) = model.sparsify(jnp.asarray(u), jnp.asarray(a), jnp.asarray(u))
    rec = np.einsum("bij,bjk,blk->bil", u, np.asarray(f), u)
    np.testing.assert_allclose(rec, a, rtol=1e-10, atol=1e-10)
