//! `cargo bench` harness (criterion is unavailable offline — DESIGN.md
//! §10): regenerates every paper table/figure at Quick scale and prints
//! the series. One section per figure, matching DESIGN.md §7's index.

use h2ulv::figures::{self, Scale};

fn main() {
    let t0 = std::time::Instant::now();
    println!("h2ulv paper-figure bench (Quick scale; `h2ulv figures --full` for the larger runs)");
    let all = figures::run_all(Scale::Quick, Some(std::path::Path::new("figures_out")));
    println!("{all}");
    println!("\ntotal bench time: {:.1}s", t0.elapsed().as_secs_f64());
}
