//! Micro-benchmarks for the batched kernel hot paths: native GEMM/POTRF/
//! TRSM throughput (the L3 roofline used in Figure 14's % claims) and the
//! PJRT batched-launch overhead (the GPU-analog path). Used by the perf
//! pass in EXPERIMENTS.md §Perf.

use h2ulv::batch::native::NativeBackend;
use h2ulv::linalg::blas::{self};
use h2ulv::linalg::matrix::{Matrix, Trans};
use h2ulv::linalg::chol;
use h2ulv::util::Rng;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, flops_per_iter: f64, mut f: F) {
    // Warmup + timed reps.
    f();
    let reps = 5;
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "{name:<40} {:>9.3} ms   {:>8.2} GFLOP/s",
        dt * 1e3,
        flops_per_iter / dt / 1e9
    );
}

fn main() {
    let mut rng = Rng::new(1);
    println!("== native kernel roofline ==");
    for &n in &[64usize, 128, 256, 512] {
        let a = Matrix::randn(n, n, &mut rng);
        let b = Matrix::randn(n, n, &mut rng);
        let mut c = Matrix::zeros(n, n);
        bench(&format!("gemm {n}x{n}x{n}"), 2.0 * (n * n * n) as f64, || {
            blas::gemm(1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c);
        });
    }
    for &n in &[64usize, 128, 256] {
        let spd = Matrix::rand_spd(n, &mut rng);
        bench(&format!("potrf {n}"), (n * n * n) as f64 / 3.0, || {
            let mut m = spd.clone();
            chol::potrf(&mut m).unwrap();
        });
    }

    println!("\n== batched backends (32x32 blocks, batch 64) ==");
    let batch: Vec<Matrix> = (0..64).map(|_| Matrix::rand_spd(32, &mut rng)).collect();
    let native = NativeBackend::new();
    bench("potrf batch=64 native", 64.0 * 32f64.powi(3) / 3.0, || {
        let mut blocks = batch.clone();
        native.potrf(0, &mut blocks);
    });
    if let Ok(pjrt) = h2ulv::runtime::PjrtBackend::new(std::path::Path::new("artifacts")) {
        bench("potrf batch=64 pjrt", 64.0 * 32f64.powi(3) / 3.0, || {
            let mut blocks = batch.clone();
            pjrt.potrf(0, &mut blocks);
        });
        let us: Vec<Matrix> = (0..64).map(|_| Matrix::randn(64, 64, &mut rng)).collect();
        let aa: Vec<Matrix> = (0..64).map(|_| Matrix::randn(64, 64, &mut rng)).collect();
        let urefs: Vec<&Matrix> = us.iter().collect();
        bench("sparsify batch=64 pjrt", 64.0 * 2.0 * 2.0 * 64f64.powi(3), || {
            let _ = pjrt.sparsify(0, &urefs, &aa, &urefs);
        });
        bench("sparsify batch=64 native", 64.0 * 2.0 * 2.0 * 64f64.powi(3), || {
            let _ = native.sparsify(0, &urefs, &aa, &urefs);
        });
    } else {
        println!("(pjrt artifacts missing — run `make artifacts`)");
    }
}
