//! The `AsyncDevice` stream/fence contract, validated by a seeded
//! structure-fuzz + hazard-audit harness (ISSUE 5 acceptance):
//!
//! * bit-parity of the factorization and **every** solve entry point vs
//!   the wrapped device, across ≥8 generator seeds (`H2_TEST_SEEDS`
//!   widens the sweep in CI);
//! * a delay-injecting mock inner device proving `fence()` drains
//!   in-flight launches and cross-stream hazards are held back — the
//!   ordering asserts read `OverlapTrace` intervals (margin-free), and
//!   the few scheduling-liveness asserts get half-second injected delays
//!   so a loaded CI runner cannot flake them;
//! * the `OverlapTrace` of `AsyncDevice<NativeBackend>` showing at least
//!   one level whose uploads genuinely ran while another level's compute
//!   was in flight — the paper's "level k+1 uploads overlap level k
//!   TRSM/Schur" observed on real worker threads;
//! * concurrent-solve bit-parity on an `async:native` facade session
//!   (the PR 4 workspace-pool properties survive the wrapper).

mod common;

use common::{seeds, Case};
use h2ulv::batch::device::r#async::AsyncDevice;
use h2ulv::batch::device::{Device, DeviceArena, HostArena, Launch};
use h2ulv::batch::native::NativeBackend;
use h2ulv::linalg::{chol, Matrix};
use h2ulv::plan::{BufferId, Executor, ExtractItem};
use h2ulv::prelude::*;
use h2ulv::solver::backend::SerialBackend;
use h2ulv::ulv::{factorize, factorize_with_plan, SubstMode};
use h2ulv::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// (a) Seeded structure fuzz: bit-parity with the wrapped device.
// ---------------------------------------------------------------------

#[test]
fn async_factor_and_solves_bit_match_inner_across_seeds() {
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let h2 = case.h2();
        let native = NativeBackend::new();
        let adev = AsyncDevice::new(NativeBackend::new());
        let fac_n = factorize(&h2, &native);
        let fac_a = factorize_with_plan(&h2, &adev, fac_n.plan.clone());
        assert_eq!(
            fac_n.root_l.as_slice(),
            fac_a.root_l.as_slice(),
            "root factor diverged for {case}"
        );
        for (ln, la) in fac_n.levels.iter().zip(&fac_a.levels) {
            for (a, b) in ln.chol_rr.iter().zip(&la.chol_rr) {
                assert_eq!(
                    a.as_slice(),
                    b.as_slice(),
                    "chol_rr diverged at level {} for {case}",
                    ln.level
                );
            }
            for (k, m) in &ln.lr {
                assert_eq!(m.as_slice(), la.lr[k].as_slice(), "L(r){k:?} diverged for {case}");
            }
            for (k, m) in &ln.ls {
                assert_eq!(m.as_slice(), la.ls[k].as_slice(), "L(s){k:?} diverged for {case}");
            }
        }
        for k in 0..case.rhs_count as u64 {
            let bt = h2.tree.permute_vec(&case.rhs(k));
            for mode in [SubstMode::Parallel, SubstMode::Naive] {
                let xn = fac_n.solve_tree_order(&bt, &native, mode);
                let xa = fac_a.solve_tree_order(&bt, &adev, mode);
                assert_eq!(xn, xa, "{mode:?} solve diverged for {case} (rhs {k})");
            }
        }
    }
}

#[test]
fn async_facade_entry_points_bit_match_native_session() {
    // Every facade solve entry point — solve, solve_many, solve_refined,
    // solve_dist — on an async:native session reproduces the native
    // session bit-for-bit (same plan, same kernels, overlapped schedule).
    let case = Case::fixed(512, 601);
    let native = case.solver(BackendSpec::Native);
    let asynced = case.solver(BackendSpec::async_native());
    assert_eq!(asynced.backend_name(), "async:native");
    let b = case.rhs(0);

    let x_n = native.solve(&b).expect("rhs matches").x;
    let x_a = asynced.solve(&b).expect("rhs matches").x;
    assert_eq!(x_n, x_a, "solve diverged");

    let many: Vec<Vec<f64>> = (1..5u64).map(|k| case.rhs(k)).collect();
    let rep_n = native.solve_many(&many).expect("rhs match");
    let rep_a = asynced.solve_many(&many).expect("rhs match");
    for (rn, ra) in rep_n.iter().zip(&rep_a) {
        assert_eq!(rn.x, ra.x, "solve_many diverged");
    }

    let ref_n = native.solve_refined(&b, 1e-8, 50).expect("refinement converges");
    let ref_a = asynced.solve_refined(&b, 1e-8, 50).expect("refinement converges");
    assert_eq!(ref_n.x, ref_a.x, "solve_refined diverged");
    assert_eq!(ref_n.iterations, ref_a.iterations);

    let dist_n = native.solve_dist(&b, 4).expect("rhs matches");
    let dist_a = asynced.solve_dist(&b, 4).expect("rhs matches");
    assert_eq!(dist_n.x, dist_a.x, "solve_dist diverged");

    // Pool/arena balance invariants survive the wrapper.
    let (created, idle) = asynced.workspace_stats();
    assert_eq!(created, idle, "async session leaked a workspace region");
    assert_eq!(asynced.plan_recordings(), 1);
}

#[test]
fn async_refactorize_and_naive_replay_match_native() {
    // The &mut session phases (refactorize) and the lazily recorded naive
    // program both replay correctly on the overlapping executor.
    let case = Case::fixed(384, 603);
    let mut native = case.solver(BackendSpec::Native);
    let mut asynced = case.solver(BackendSpec::async_native());
    let b = case.rhs(0);
    let naive_n = native.solve_with(&b, SubstMode::Naive).expect("rhs matches").x;
    let naive_a = asynced.solve_with(&b, SubstMode::Naive).expect("rhs matches").x;
    assert_eq!(naive_n, naive_a, "lazy naive program diverged");
    native.refactorize(case.config()).expect("refactorize");
    asynced.refactorize(case.config()).expect("refactorize");
    assert_eq!(asynced.plan_recordings(), 1, "same-structure refactorize must not re-plan");
    let x_n = native.solve(&b).expect("rhs matches").x;
    let x_a = asynced.solve(&b).expect("rhs matches").x;
    assert_eq!(x_n, x_a, "post-refactorize solve diverged");
}

// ---------------------------------------------------------------------
// (b) Delay-injecting mock inner device: fence drains, hazards hold.
// ---------------------------------------------------------------------

/// Serial-reference device that sleeps before every factorization launch
/// (and, with [`SlowDevice::with_solve_delay`], before every substitution
/// launch), stretching compute so scheduling claims become deterministic
/// facts.
struct SlowDevice {
    inner: SerialBackend,
    delay: Duration,
    solve_delay: Duration,
    launches: AtomicUsize,
}

impl SlowDevice {
    fn new(delay: Duration) -> SlowDevice {
        SlowDevice {
            inner: SerialBackend,
            delay,
            solve_delay: Duration::ZERO,
            launches: AtomicUsize::new(0),
        }
    }

    fn with_solve_delay(delay: Duration) -> SlowDevice {
        SlowDevice { solve_delay: delay, ..SlowDevice::new(Duration::ZERO) }
    }
}

impl Device for SlowDevice {
    fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena> {
        Box::new(HostArena::with_capacity(capacity))
    }

    fn launch(&self, arena: &mut dyn DeviceArena, launch: &Launch<'_>) {
        std::thread::sleep(self.delay);
        self.inner.launch(arena, launch);
        self.launches.fetch_add(1, Ordering::SeqCst);
    }

    fn launch_solve(
        &self,
        factor: &dyn DeviceArena,
        ws: &mut dyn DeviceArena,
        launch: &Launch<'_>,
    ) {
        std::thread::sleep(self.solve_delay);
        self.inner.launch_solve(factor, ws, launch);
        self.launches.fetch_add(1, Ordering::SeqCst);
    }

    fn name(&self) -> &'static str {
        "slow"
    }
}

#[test]
fn fence_drains_inflight_launches_and_holds_back_cross_stream_hazards() {
    const DELAY_MS: u64 = 500;
    let adev = Arc::new(AsyncDevice::new(SlowDevice::new(Duration::from_millis(DELAY_MS))));
    let mut arena = adev.new_arena(4);
    let mut rng = Rng::new(99);
    let spd = Matrix::rand_spd(12, &mut rng);

    let issue_start = Instant::now();
    adev.stream(1);
    arena.upload(BufferId(0), &spd);
    let bufs = [BufferId(0)];
    adev.launch(arena.as_mut(), &Launch::Potrf { level: 1, bufs: &bufs });
    // Stream 0: an independent upload (no hazards — may run during the
    // POTRF) and an extract that reads the POTRF output (cross-stream RAW
    // hazard — must be held back until the POTRF completes).
    adev.stream(0);
    arena.upload(BufferId(1), &Matrix::eye(4));
    let ex = [ExtractItem { src: BufferId(0), r0: 0, c0: 0, rows: 4, cols: 4, dst: BufferId(2) }];
    adev.launch(arena.as_mut(), &Launch::Extract { items: &ex });
    let issue_time = issue_start.elapsed();

    // Issuing 4 ops returned long before even one injected delay elapsed
    // (issuing is microseconds of enqueueing; the 500 ms delay leaves a
    // huge margin): the launches really were in flight, not inline.
    assert!(
        issue_time < Duration::from_millis(DELAY_MS / 2),
        "issuing took {issue_time:?}; launches must not execute on the issuing thread"
    );
    assert!(
        adev.inner().launches.load(Ordering::SeqCst) < 2,
        "both launches finished before fence was even called"
    );

    adev.fence();
    let drained = issue_start.elapsed();
    // fence returned only after both delayed launches ran (they serialize
    // on the B0 hazard, so ≥ 2 delays of wall time have passed).
    assert_eq!(adev.inner().launches.load(Ordering::SeqCst), 2, "fence must drain all launches");
    assert!(
        drained >= Duration::from_millis(2 * DELAY_MS - 20),
        "fence returned after {drained:?}, before the hazard-serialized launches could finish"
    );

    // Numerics: the extract observed the *post-POTRF* content of B0.
    let want = chol::cholesky(&spd).unwrap().submatrix(0, 0, 4, 4);
    assert_eq!(arena.download(BufferId(2)).as_slice(), want.as_slice());

    // Interval-level ordering from the trace (no timing margins needed):
    let trace = adev.take_overlap_trace().expect("async devices trace");
    let potrf = trace.events.iter().find(|e| e.opcode == "POTRF").expect("POTRF traced");
    let extract = trace.events.iter().find(|e| e.opcode == "EXTRACT").expect("EXTRACT traced");
    let free_upload = trace
        .events
        .iter()
        .find(|e| e.opcode == "UPLOAD" && e.stream == 0)
        .expect("stream-0 upload traced");
    assert_eq!(potrf.stream, 1, "stream(1) work must run on queue 1");
    assert_eq!(extract.stream, 0, "stream(0) work must run on queue 0");
    assert!(
        extract.start >= potrf.end,
        "cross-stream RAW hazard violated: EXTRACT [{:.4}, {:.4}] began before POTRF [{:.4}, \
         {:.4}] finished",
        extract.start,
        extract.end,
        potrf.start,
        potrf.end
    );
    // The stream-0 worker only needs to execute a microsecond pointer
    // move at some point during the POTRF's 500 ms sleep window — a
    // failure here means it was descheduled for over half a second.
    assert!(
        free_upload.end < potrf.end,
        "the hazard-free upload should have completed while the delayed POTRF was in flight"
    );
}

#[test]
fn hazard_free_streams_overlap_on_the_mock_device() {
    // Two independent POTRFs on different streams: each sleeps 400 ms, so
    // their trace intervals intersect unless one worker was descheduled
    // for the other's entire sleep window.
    const DELAY_MS: u64 = 400;
    let adev = AsyncDevice::new(SlowDevice::new(Duration::from_millis(DELAY_MS)));
    let mut arena = adev.new_arena(2);
    let mut rng = Rng::new(101);
    let a = Matrix::rand_spd(8, &mut rng);
    let b = Matrix::rand_spd(8, &mut rng);
    adev.stream(0);
    arena.upload(BufferId(0), &a);
    let bufs0 = [BufferId(0)];
    adev.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &bufs0 });
    adev.stream(1);
    arena.upload(BufferId(1), &b);
    let bufs1 = [BufferId(1)];
    adev.launch(arena.as_mut(), &Launch::Potrf { level: 1, bufs: &bufs1 });
    adev.fence();
    assert_eq!(arena.download(BufferId(0)).as_slice(), chol::cholesky(&a).unwrap().as_slice());
    assert_eq!(arena.download(BufferId(1)).as_slice(), chol::cholesky(&b).unwrap().as_slice());
    let trace = adev.take_overlap_trace().expect("async devices trace");
    let potrfs: Vec<_> = trace.events.iter().filter(|e| e.opcode == "POTRF").collect();
    assert_eq!(potrfs.len(), 2);
    let overlap = potrfs[0].overlap_with(potrfs[1]);
    assert!(
        overlap > 0.0,
        "independent launches on distinct streams must overlap; trace:\n{}",
        trace.render()
    );
}

#[test]
fn independent_solve_workspaces_overlap_on_the_mock_device() {
    // ISSUE 10: two journaled TRSV launches against one shared factor, in
    // distinct workspaces on distinct streams. Both *read* factor B0 — the
    // shared-reader operand rule means neither orders against the other —
    // so with each launch sleeping 400 ms their trace intervals must
    // intersect unless the engine wrongly serialized the readers.
    const DELAY_MS: u64 = 400;
    let adev = AsyncDevice::new(SlowDevice::with_solve_delay(Duration::from_millis(DELAY_MS)));
    let mut rng = Rng::new(103);
    let spd = Matrix::rand_spd(8, &mut rng);
    let l = chol::cholesky(&spd).unwrap();
    let mut factor = adev.new_arena(1);
    factor.upload(BufferId(0), &l);
    adev.fence();
    let mut ws_a = adev.new_arena(1);
    let mut ws_b = adev.new_arena(1);
    let b: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
    let items = [(BufferId(0), BufferId(1))];
    adev.stream(0);
    ws_a.upload_vec(BufferId(1), &b);
    adev.launch_solve(factor.as_ref(), ws_a.as_mut(), &Launch::TrsvFwd { level: 0, items: &items });
    adev.stream(1);
    ws_b.upload_vec(BufferId(1), &b);
    adev.launch_solve(factor.as_ref(), ws_b.as_mut(), &Launch::TrsvFwd { level: 1, items: &items });
    adev.fence();

    // Both workspaces hold the synchronous forward-substitution result.
    let want = {
        let sync = SerialBackend;
        let mut f = sync.new_arena(1);
        f.upload(BufferId(0), &l);
        let mut w = sync.new_arena(1);
        w.upload_vec(BufferId(1), &b);
        sync.launch_solve(f.as_ref(), w.as_mut(), &Launch::TrsvFwd { level: 0, items: &items });
        w.download_vec(BufferId(1))
    };
    assert_eq!(ws_a.download_vec(BufferId(1)), want, "workspace A diverged");
    assert_eq!(ws_b.download_vec(BufferId(1)), want, "workspace B diverged");

    let trace = adev.take_overlap_trace().expect("async devices trace");
    let trsvs: Vec<_> = trace.events.iter().filter(|e| e.opcode == "TRSV").collect();
    assert_eq!(trsvs.len(), 2, "both solve launches must be traced");
    assert_ne!(trsvs[0].stream, trsvs[1].stream, "stream hints must route to distinct queues");
    assert!(
        trsvs[0].overlap_with(trsvs[1]) > 0.0,
        "concurrent readers of one factor must not serialize; trace:\n{}",
        trace.render()
    );
}

// ---------------------------------------------------------------------
// (c) Real overlap on AsyncDevice<NativeBackend>.
// ---------------------------------------------------------------------

#[test]
fn overlap_trace_shows_uploads_overlapping_prior_level_compute() {
    // Acceptance: on a real (undelayed) native device, at least one
    // level's uploads run concurrently with another level's compute. A
    // deep problem gives the scheduler many level pairs; the replay is
    // retried a few times to keep the assert robust on loaded CI runners.
    let case =
        Case { leaf_size: 32, max_rank: 24, eta: 1.0, rhs_count: 1, ..Case::fixed(1024, 0) };
    let h2 = case.h2();
    let plan = Arc::new(h2ulv::plan::record(&h2));
    let native = NativeBackend::new();
    let fac_ref = h2ulv::ulv::factorize_with_plan(&h2, &native, plan.clone());
    let adev = AsyncDevice::new(NativeBackend::new());
    let mut last_render = String::new();
    for attempt in 0..5 {
        let arena = Executor::new(&adev).factorize_device_only(&plan, &h2);
        let trace = adev.take_overlap_trace().expect("async devices trace");
        assert!(trace.streams() >= 2, "the factorization must exercise both stream queues");
        // Parity holds on every attempt, overlap or not.
        let got_root = arena.download(plan.factor.root_src);
        assert_eq!(
            got_root.as_slice(),
            fac_ref.root_l.as_slice(),
            "async root factor diverged on attempt {attempt}"
        );
        let pairs = trace.overlapped_transfer_pairs();
        if !pairs.is_empty() {
            // The paper's schedule: uploads of one level ran during
            // compute of a *different* (prior) level, or during the same
            // replay window on the other queue.
            assert!(trace.concurrent_busy() > 0.0);
            return;
        }
        last_render = trace.render();
    }
    panic!("no upload/compute overlap observed in 5 replays; last trace:\n{last_render}");
}

#[test]
fn facade_build_stats_carry_the_overlap_trace() {
    let case = Case::fixed(512, 605);
    let asynced = case.solver(BackendSpec::async_native());
    let trace =
        asynced.stats().overlap.clone().expect("async backends record an overlap trace");
    assert!(!trace.events.is_empty(), "the factorization replay must be traced");
    assert!(trace.streams() >= 1);
    // Synchronous backends stay trace-free.
    assert!(case.solver(BackendSpec::Native).stats().overlap.is_none());
    // The async session keeps serving solves after the trace was taken.
    let b = case.rhs(0);
    assert_eq!(asynced.solve(&b).expect("rhs matches").x.len(), case.n);
}

#[test]
fn solve_path_is_traced_and_surfaces_in_the_run_report() {
    // PR 7 acceptance: `Device::launch_solve` records per-stream busy
    // intervals too, so the overlap trace — and the RunReport built from
    // it — covers substitution, not just the factorization replay.
    let case = Case::fixed(512, 609);
    let asynced = case.solver(BackendSpec::async_native());
    let b = case.rhs(0);
    asynced.solve(&b).expect("rhs matches");
    let report = asynced.run_report();
    assert!(
        report.solve_trace_events > 0,
        "solve launches on an async device must appear in the overlap trace"
    );
    assert_eq!(report.rhs, 1);
    assert!(report.solve_time > 0.0);
    assert_eq!(report.backend, "async:native");
    assert!(report.factor_launches > 0);
    // Events accumulate across solves; the RHS counter follows.
    asynced.solve(&b).expect("rhs matches");
    let again = asynced.run_report();
    assert!(again.solve_trace_events >= report.solve_trace_events);
    assert_eq!(again.rhs, 2);
    // Host-synchronous sessions stay trace-free but still report times.
    let native = case.solver(BackendSpec::Native);
    native.solve(&b).expect("rhs matches");
    let nr = native.run_report();
    assert_eq!(nr.solve_trace_events, 0);
    assert_eq!(nr.overlapped_transfer_pairs, 0);
    assert_eq!(nr.solve_overlapped_transfer_pairs, 0);
    assert_eq!(nr.solve_overlap_ratio, 0.0);
    assert!(nr.solve_time > 0.0);
}

#[test]
fn run_report_snapshots_and_take_solve_overlap_drains() {
    // ISSUE 8 satellite: `run_report` has snapshot semantics — repeated
    // calls on a live session see the same monotonically growing event
    // history (nothing is drained behind the caller's back, so `bench`
    // trajectory files stay byte-stable) — while `take_solve_overlap` is
    // the explicit drain for callers that window overlap per interval.
    let case = Case::fixed(512, 609);
    let asynced = case.solver(BackendSpec::async_native());
    let b = case.rhs(0);
    asynced.solve(&b).expect("rhs matches");
    let first = asynced.run_report();
    assert!(first.solve_trace_events > 0);
    // Snapshot: a second report without intervening solves carries the
    // identical cumulative counters — no hidden drain.
    let second = asynced.run_report();
    assert_eq!(second.solve_trace_events, first.solve_trace_events, "run_report must not drain");
    assert_eq!(second.rhs, first.rhs);
    // More solves only grow the history.
    asynced.solve(&b).expect("rhs matches");
    let third = asynced.run_report();
    assert!(third.solve_trace_events >= first.solve_trace_events);
    assert_eq!(third.rhs, first.rhs + 1);
    // Explicit drain: everything accumulated comes back once, and the next
    // report starts from an empty solve-path window.
    let drained = asynced.take_solve_overlap();
    assert_eq!(drained.events.len(), third.solve_trace_events);
    let after = asynced.run_report();
    assert_eq!(after.solve_trace_events, 0, "post-drain report starts an empty window");
    assert_eq!(after.rhs, third.rhs, "draining overlap must not reset the RHS counter");
    // A second drain with no solves in between is empty.
    assert!(asynced.take_solve_overlap().events.is_empty());
}

// ---------------------------------------------------------------------
// (d) Concurrent solves on an async session.
// ---------------------------------------------------------------------

#[test]
fn concurrent_solves_on_async_session_bit_match_native() {
    const THREADS: usize = 4;
    let case = Case::fixed(384, 607);
    let native = case.solver(BackendSpec::Native);
    let asynced = case.solver(BackendSpec::async_native());
    let resident = asynced.resident_buffers();
    let bs: Vec<Vec<f64>> = (0..THREADS as u64).map(|t| case.rhs(700 + t)).collect();
    let want: Vec<Vec<f64>> =
        bs.iter().map(|b| native.solve(b).expect("rhs matches").x).collect();

    let started = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for (b, want) in bs.iter().zip(&want) {
            let asynced = &asynced;
            let started = &started;
            s.spawn(move || {
                started.fetch_add(1, Ordering::SeqCst);
                while started.load(Ordering::SeqCst) < THREADS {
                    std::hint::spin_loop();
                }
                for _ in 0..3 {
                    let x = asynced.solve(b).expect("rhs matches").x;
                    assert_eq!(x, *want, "concurrent async solve diverged from native");
                }
            });
        }
    });

    assert_eq!(asynced.resident_buffers(), resident, "factor region live count changed");
    let (created, idle) = asynced.workspace_stats();
    assert_eq!(created, idle, "a workspace region leaked");
    assert_eq!(asynced.plan_recordings(), 1, "re-planning occurred under contention");
}
