//! The multi-tenant solve service (ISSUE 8 acceptance):
//!
//! * scripted protocol round-trip over an in-memory stream — the same
//!   `serve_stream` loop that backs stdin/stdout and TCP transports;
//! * two tenants issuing identical `build`s share one cached session and
//!   the plan is recorded exactly once (`plan_recordings() == 1`);
//! * LRU eviction under a tiny resident-byte budget, with the evicted
//!   session producing a typed `unknown_session` error — not a dead loop;
//! * malformed requests and deterministic timeouts degrade to typed
//!   `{"ok":false,...}` responses on a connection that keeps serving;
//! * concurrent single-RHS requests coalesce into one `solve_many`
//!   dispatch, bit-identical to an unbatched solve;
//! * concurrent TCP clients bit-match a direct (in-process) solve.

mod common;

use h2ulv::serve::protocol::vec_json;
use h2ulv::serve::service::Client;
use h2ulv::serve::{BuildParams, ServeConfig, Service};
use h2ulv::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

const N: usize = 192;
const BUILD: &str = r#"{"op":"build","n":192,"leaf_size":32,"max_rank":16,"far_samples":32,"near_samples":32,"residual_samples":0}"#;

/// The `BuildParams` equivalent of the [`BUILD`] request line (unspecified
/// wire fields take the same defaults `from_json` fills in).
fn build_params() -> BuildParams {
    BuildParams {
        n: N,
        leaf_size: 32,
        max_rank: 16,
        far_samples: 32,
        near_samples: 32,
        residual_samples: 0,
        ..Default::default()
    }
}

fn rhs_literal(seed: u64) -> String {
    vec_json(&common::rhs(N, seed)).to_string_compact()
}

/// What an in-process solver (no service, no wire) returns for the same
/// problem and RHS, serialized the same way.
fn direct_x(seed: u64) -> String {
    let solver = build_params().build_solver().expect("direct build succeeds");
    let rep = solver.solve(&common::rhs(N, seed)).expect("rhs matches");
    vec_json(&rep.x).to_string_compact()
}

fn no_batching() -> ServeConfig {
    ServeConfig { batch_window_ms: 0, ..Default::default() }
}

#[test]
fn scripted_round_trip_over_an_in_memory_stream() {
    let svc = Service::new(no_batching());
    // A fresh service numbers sessions from 1, so the script can refer to
    // the session it is about to create.
    let script = format!(
        "{BUILD}\n\
         {BUILD}\n\
         {{\"op\":\"solve\",\"session\":1,\"b\":{rhs}}}\n\
         {{\"op\":\"stats\"}}\n\
         {{\"op\":\"evict\",\"session\":1}}\n\
         {{\"op\":\"solve\",\"session\":1,\"b\":{rhs}}}\n\
         {{\"op\":\"shutdown\"}}\n\
         {{\"op\":\"stats\"}}\n",
        rhs = rhs_literal(7)
    );
    let mut out = Vec::new();
    svc.serve_stream(script.as_bytes(), &mut out).expect("in-memory stream never errors");
    let text = String::from_utf8(out).expect("responses are UTF-8");
    let resps: Vec<Json> =
        text.lines().map(|l| Json::parse(l).expect("every response parses")).collect();
    // The loop stops after the shutdown response: the trailing stats line
    // is never processed.
    assert_eq!(resps.len(), 7, "one response per request, until shutdown:\n{text}");

    assert_eq!(resps[0].get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resps[0].get("cache_hit").and_then(Json::as_bool), Some(false));
    assert_eq!(resps[0].get("session").and_then(Json::as_u64), Some(1));
    assert_eq!(resps[1].get("cache_hit").and_then(Json::as_bool), Some(true));
    assert_eq!(resps[1].get("session").and_then(Json::as_u64), Some(1));
    assert_eq!(resps[1].get("plan_recordings").and_then(Json::as_u64), Some(1));

    let x = resps[2].get("x").and_then(Json::as_arr).expect("solve returns a solution");
    assert_eq!(x.len(), N);
    assert_eq!(
        resps[2].get("x").unwrap().to_string_compact(),
        direct_x(7),
        "served solution must bit-match a direct in-process solve"
    );

    let cache = resps[3].get("cache").expect("stats carries a cache section");
    assert_eq!(cache.get("sessions").and_then(Json::as_u64), Some(1));
    // The global hit counter tracks `build` resolution only (hit_rate is
    // the build-sharing metric); per-session counters absorb solve lookups.
    assert_eq!(cache.get("hits").and_then(Json::as_u64), Some(1), "the second build hit");

    assert_eq!(resps[4].get("evicted").and_then(Json::as_bool), Some(true));
    // The solve after eviction fails typed — the loop kept serving.
    assert_eq!(resps[5].get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(
        resps[5].get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("unknown_session")
    );
    assert_eq!(resps[6].get("op").and_then(Json::as_str), Some("shutdown"));
    assert!(svc.is_shutdown());
}

#[test]
fn two_tenants_share_one_plan_recording_at_the_service_level() {
    let svc = Service::new(no_batching());
    let a = Json::parse(&svc.handle_line(BUILD)).unwrap();
    let b = Json::parse(&svc.handle_line(BUILD)).unwrap();
    assert_eq!(a.get("session").and_then(Json::as_u64), b.get("session").and_then(Json::as_u64));
    assert_eq!(b.get("cache_hit").and_then(Json::as_bool), Some(true));
    // The acceptance counter, read off the cache itself rather than the
    // wire: one entry, planned exactly once.
    let entries = svc.cache().entries();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].solver.plan_recordings(), 1, "second tenant must not re-plan");
    let stats = svc.cache().stats();
    assert_eq!((stats.hits, stats.misses), (1, 1));
}

#[test]
fn lru_eviction_under_a_tiny_byte_budget_keeps_serving() {
    // A 1-byte budget forces every insertion after the first to evict the
    // least-recently-used session.
    let svc = Service::new(ServeConfig { budget_bytes: 1, ..no_batching() });
    let a = Json::parse(&svc.handle_line(BUILD)).unwrap();
    let sid_a = a.get("session").and_then(Json::as_u64).unwrap();
    let build_b = r#"{"op":"build","n":224,"leaf_size":32,"max_rank":16,"far_samples":32,"near_samples":32,"residual_samples":0}"#;
    let b = Json::parse(&svc.handle_line(build_b)).unwrap();
    assert_eq!(b.get("ok").and_then(Json::as_bool), Some(true));
    let stats = svc.cache().stats();
    assert_eq!(stats.sessions, 1, "over-budget cache keeps only the newest session");
    assert_eq!(stats.evictions, 1);
    // The evicted tenant gets a typed error; the surviving one solves.
    let gone = Json::parse(&svc.handle_line(&format!(
        r#"{{"op":"solve","session":{sid_a},"b":{}}}"#,
        rhs_literal(1)
    )))
    .unwrap();
    assert_eq!(
        gone.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("unknown_session")
    );
    let sid_b = b.get("session").and_then(Json::as_u64).unwrap();
    let ok = Json::parse(&svc.handle_line(&format!(
        r#"{{"op":"solve","session":{sid_b},"b":{}}}"#,
        vec_json(&common::rhs(224, 2)).to_string_compact()
    )))
    .unwrap();
    assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
}

#[test]
fn malformed_requests_produce_typed_errors_and_keep_the_loop_alive() {
    let svc = Service::new(no_batching());
    let kind = |line: &str| {
        let resp = Json::parse(&svc.handle_line(line)).expect("error responses are JSON too");
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "for {line}");
        resp.get("error")
            .and_then(|e| e.get("kind"))
            .and_then(Json::as_str)
            .expect("typed error kind")
            .to_string()
    };
    assert_eq!(kind("this is not json"), "parse_error");
    assert_eq!(kind(r#"{"n":64}"#), "bad_request", "missing op");
    assert_eq!(kind(r#"{"op":"dance"}"#), "unknown_op");
    assert_eq!(kind(r#"{"op":"solve","b":[1.0]}"#), "bad_request", "missing session");
    assert_eq!(kind(r#"{"op":"solve","session":1,"b":"nope"}"#), "bad_request");
    assert_eq!(kind(r#"{"op":"build","n":"many"}"#), "bad_request", "mistyped field");
    assert_eq!(kind(r#"{"op":"build","n":192,"geometry":"dodecahedron"}"#), "bad_request");
    // Dimension mismatch on a real session maps through the H2Error taxonomy.
    let a = Json::parse(&svc.handle_line(BUILD)).unwrap();
    let sid = a.get("session").and_then(Json::as_u64).unwrap();
    assert_eq!(kind(&format!(r#"{{"op":"solve","session":{sid},"b":[1.0,2.0]}}"#)), "dimension_mismatch");
    // After all that abuse the service still does real work.
    let ok = Json::parse(&svc.handle_line(&format!(
        r#"{{"op":"solve","session":{sid},"b":{}}}"#,
        rhs_literal(3)
    )))
    .unwrap();
    assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
    let stats = Json::parse(&svc.handle_line(r#"{"op":"stats"}"#)).unwrap();
    assert!(stats.get("errors").and_then(Json::as_u64).unwrap() >= 8);
}

#[test]
fn explicit_zero_timeout_deterministically_times_out() {
    // A 0 ms deadline on a batched solve can never be met: the batcher
    // holds the request for the full window, so `recv_timeout(0)` expires
    // first — a deterministic timeout-path probe, no sleeps to tune.
    let svc = Service::new(ServeConfig { batch_window_ms: 50, ..Default::default() });
    let a = Json::parse(&svc.handle_line(BUILD)).unwrap();
    let sid = a.get("session").and_then(Json::as_u64).unwrap();
    let timed_out = Json::parse(&svc.handle_line(&format!(
        r#"{{"op":"solve","session":{sid},"b":{},"timeout_ms":0}}"#,
        rhs_literal(4)
    )))
    .unwrap();
    assert_eq!(timed_out.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(
        timed_out.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("timeout")
    );
    // The same request without the deadline succeeds on the same session
    // (the abandoned solve finished in the background and was discarded).
    let ok = Json::parse(&svc.handle_line(&format!(
        r#"{{"op":"solve","session":{sid},"b":{},"batch":false}}"#,
        rhs_literal(4)
    )))
    .unwrap();
    assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
}

#[test]
fn concurrent_single_rhs_requests_coalesce_into_one_batch() {
    // A long window makes coalescing deterministic in practice: the second
    // request only has to arrive within 250 ms of the first. Retry rounds
    // guard against a pathologically descheduled spawner.
    let svc = Service::new(ServeConfig { batch_window_ms: 250, ..Default::default() });
    let a = Json::parse(&svc.handle_line(BUILD)).unwrap();
    let sid = a.get("session").and_then(Json::as_u64).unwrap();
    let unbatched = Json::parse(&svc.handle_line(&format!(
        r#"{{"op":"solve","session":{sid},"b":{},"batch":false}}"#,
        rhs_literal(5)
    )))
    .unwrap();
    let want_x = unbatched.get("x").unwrap().to_string_compact();

    let mut coalesced = false;
    for _round in 0..5 {
        let started = AtomicUsize::new(0);
        let sizes: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2u64)
                .map(|_k| {
                    let svc = &svc;
                    let started = &started;
                    let want_x = &want_x;
                    s.spawn(move || {
                        started.fetch_add(1, Ordering::SeqCst);
                        while started.load(Ordering::SeqCst) < 2 {
                            std::hint::spin_loop();
                        }
                        // Both threads reuse RHS seed 5: every batched
                        // solution must bit-match the unbatched reference.
                        let resp = Json::parse(&svc.handle_line(&format!(
                            r#"{{"op":"solve","session":{sid},"b":{}}}"#,
                            rhs_literal(5)
                        )))
                        .unwrap();
                        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
                        assert_eq!(
                            resp.get("x").unwrap().to_string_compact(),
                            *want_x,
                            "batched solution diverged from the unbatched reference"
                        );
                        resp.get("batch_size").and_then(Json::as_u64).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
        });
        if sizes.iter().any(|&s| s >= 2) {
            coalesced = true;
            break;
        }
    }
    assert!(coalesced, "two simultaneous requests never shared a 250 ms window");
    assert!(svc.counters().coalesced_batches.load(Ordering::Relaxed) >= 1);
    assert!(svc.counters().coalesced_requests.load(Ordering::Relaxed) >= 2);
}

#[test]
fn concurrent_tcp_clients_bit_match_a_direct_solve() {
    const CLIENTS: usize = 3;
    let svc = Service::new(ServeConfig { batch_window_ms: 5, ..Default::default() });
    let listener = svc.bind_tcp("127.0.0.1:0").expect("ephemeral port binds");
    let addr = svc.bound_addr().expect("bind recorded the address").to_string();
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || svc.serve_tcp(listener))
    };

    let want: Vec<String> = (0..CLIENTS as u64).map(|k| direct_x(30 + k)).collect();
    std::thread::scope(|s| {
        for (k, want_x) in want.iter().enumerate() {
            let addr = addr.clone();
            s.spawn(move || {
                let mut c = Client::connect(&addr).expect("client connects");
                // All clients race the same build: the cache's in-lock
                // re-check guarantees they converge on one session.
                let built = c.call_ok(BUILD).expect("build succeeds");
                let sid = built.get("session").and_then(Json::as_u64).unwrap();
                let resp = c
                    .call_ok(&format!(
                        r#"{{"op":"solve","session":{sid},"b":{}}}"#,
                        rhs_literal(30 + k as u64)
                    ))
                    .expect("solve succeeds");
                assert_eq!(
                    resp.get("x").unwrap().to_string_compact(),
                    *want_x,
                    "TCP-served solution diverged from the direct solve"
                );
            });
        }
    });

    // All clients shared one session and one plan recording.
    let entries = svc.cache().entries();
    assert_eq!(entries.len(), 1, "racing identical builds must converge on one session");
    assert_eq!(entries[0].solver.plan_recordings(), 1);

    let mut c = Client::connect(&addr).expect("shutdown client connects");
    c.call_ok(r#"{"op":"shutdown"}"#).expect("shutdown is acknowledged");
    server
        .join()
        .expect("server thread panicked")
        .expect("accept loop exits cleanly after shutdown");
}
