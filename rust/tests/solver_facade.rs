//! Integration tests for the `H2Solver` facade: round-trip accuracy across
//! kernels and substitution modes, typed errors for malformed inputs,
//! batched right-hand sides, refactorization, backend plumbing, and the
//! facade-level distributed solve.

use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::prelude::*;
use h2ulv::util::Rng;

const N: usize = 192;

/// Full-rank configuration: `max_rank >= ndof` at every level, so the H²
/// representation (and therefore the ULV solve) is exact up to roundoff —
/// this is what makes the 1e-6 residual assertions robust.
fn exact_cfg() -> H2Config {
    H2Config { leaf_size: 48, max_rank: 512, far_samples: 0, near_samples: 0, ..Default::default() }
}

/// Compressed configuration exercising the real low-rank path.
fn compressed_cfg() -> H2Config {
    H2Config { leaf_size: 48, max_rank: 24, far_samples: 0, ..Default::default() }
}

fn rhs(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn build(kernel: KernelFn, cfg: H2Config, mode: SubstMode) -> H2Solver {
    H2SolverBuilder::new(Geometry::sphere_surface(N, 811), kernel)
        .config(cfg)
        .subst_mode(mode)
        .residual_samples(128)
        .build()
        .expect("well-formed facade problem")
}

#[test]
fn roundtrip_laplace_yukawa_both_modes() {
    let g = Geometry::sphere_surface(N, 811);
    for kernel in [KernelFn::laplace(), KernelFn::yukawa()] {
        let dense = kernel.dense(&g.points);
        let b = rhs(N, 3);
        let want = h2ulv::linalg::lu::solve(&dense, &b).unwrap();
        for mode in [SubstMode::Parallel, SubstMode::Naive] {
            let solver = build(kernel.clone(), exact_cfg(), mode);
            let rep = solver.solve(&b).unwrap();
            let resid = rep.residual.expect("sampling enabled");
            assert!(resid < 1e-6, "{} {mode:?}: residual {resid}", kernel.name);
            let err = rel_err_vec(&rep.x, &want);
            assert!(err < 1e-6, "{} {mode:?}: error vs dense {err}", kernel.name);
            assert_eq!(rep.subst_mode, mode);
            assert_eq!(rep.iterations, 1);
        }
    }
}

#[test]
fn compressed_roundtrip_still_accurate() {
    for mode in [SubstMode::Parallel, SubstMode::Naive] {
        let solver = build(KernelFn::laplace(), compressed_cfg(), mode);
        let b = rhs(N, 5);
        let rep = solver.solve(&b).unwrap();
        let resid = rep.residual.unwrap();
        assert!(resid < 5e-3, "{mode:?}: compressed residual {resid}");
    }
}

#[test]
fn wrong_rhs_length_is_dimension_mismatch() {
    let solver = build(KernelFn::laplace(), compressed_cfg(), SubstMode::Parallel);
    match solver.solve(&[1.0; 100]) {
        Err(H2Error::DimensionMismatch { expected, got }) => {
            assert_eq!(expected, N);
            assert_eq!(got, 100);
        }
        other => panic!("expected DimensionMismatch, got {other:?}"),
    }
    // solve_many validates every RHS before solving any.
    let mixed = vec![rhs(N, 1), rhs(N - 1, 2)];
    assert!(matches!(
        solver.solve_many(&mixed),
        Err(H2Error::DimensionMismatch { got, .. }) if got == N - 1
    ));
}

#[test]
fn problem_smaller_than_leaf_is_typed_error() {
    let g = Geometry::uniform_cube(16, 5);
    let res = H2SolverBuilder::new(g, KernelFn::laplace())
        .config(H2Config { leaf_size: 64, ..Default::default() })
        .build();
    match res {
        Err(H2Error::ProblemTooSmall { n, leaf_size }) => {
            assert_eq!(n, 16);
            assert_eq!(leaf_size, 64);
        }
        Err(e) => panic!("expected ProblemTooSmall, got {e:?}"),
        Ok(_) => panic!("expected ProblemTooSmall, got a solver"),
    }
}

#[test]
fn malformed_configs_and_geometry_are_typed_errors() {
    let empty = Geometry { points: Vec::new(), name: "empty".to_string() };
    assert!(matches!(
        H2SolverBuilder::new(empty, KernelFn::laplace()).build(),
        Err(H2Error::EmptyGeometry)
    ));
    let g = Geometry::sphere_surface(N, 7);
    for bad in [
        H2Config { leaf_size: 0, ..Default::default() },
        H2Config { max_rank: 0, ..Default::default() },
        H2Config { eta: -1.0, ..Default::default() },
        H2Config { eta: f64::NAN, ..Default::default() },
        H2Config { rtol: -0.5, ..Default::default() },
    ] {
        let res = H2SolverBuilder::new(g.clone(), KernelFn::laplace()).config(bad).build();
        assert!(matches!(&res, Err(H2Error::InvalidConfig(_))), "got {:?}", res.err());
    }
}

#[test]
fn solve_many_matches_individual_solves() {
    let solver = build(KernelFn::laplace(), compressed_cfg(), SubstMode::Parallel);
    let many: Vec<Vec<f64>> = (0..3).map(|s| rhs(N, 20 + s)).collect();
    let reports = solver.solve_many(&many).unwrap();
    assert_eq!(reports.len(), 3);
    for (b, rep) in many.iter().zip(&reports) {
        let single = solver.solve(b).unwrap();
        assert_eq!(rep.x, single.x, "solve_many must match per-rhs solve exactly");
    }
}

#[test]
fn refactorize_improves_accuracy() {
    let mut solver = build(
        KernelFn::laplace(),
        H2Config { leaf_size: 48, max_rank: 8, far_samples: 0, ..Default::default() },
        SubstMode::Parallel,
    );
    let b = rhs(N, 31);
    let coarse = solver.solve(&b).unwrap().residual.unwrap();
    let stats = solver.refactorize(exact_cfg()).unwrap().clone();
    assert_eq!(stats.n, N);
    let fine = solver.solve(&b).unwrap().residual.unwrap();
    assert!(fine < 1e-6, "refactorized solve must be exact: {fine}");
    assert!(fine < coarse, "rank 8 ({coarse}) must be worse than full rank ({fine})");
}

#[test]
fn serial_reference_matches_native_exactly() {
    let b = rhs(N, 41);
    let mut solutions = Vec::new();
    for spec in [BackendSpec::Native, BackendSpec::SerialReference] {
        let solver = H2SolverBuilder::new(Geometry::sphere_surface(N, 811), KernelFn::laplace())
            .config(compressed_cfg())
            .backend(spec.clone())
            .build()
            .unwrap();
        assert_eq!(solver.backend_spec(), &spec);
        solutions.push(solver.solve(&b).unwrap().x);
    }
    let err = rel_err_vec(&solutions[0], &solutions[1]);
    assert!(err < 1e-12, "serial reference diverged from native: {err}");
}

#[test]
fn missing_pjrt_artifacts_is_backend_unavailable() {
    let res = H2SolverBuilder::new(Geometry::sphere_surface(N, 811), KernelFn::laplace())
        .config(compressed_cfg())
        .backend(BackendSpec::Pjrt { artifacts_dir: "definitely_missing_dir".into() })
        .build();
    match res {
        Err(H2Error::BackendUnavailable { backend, .. }) => assert_eq!(backend, "pjrt"),
        Err(e) => panic!("expected BackendUnavailable, got {e:?}"),
        Ok(_) => panic!("expected BackendUnavailable, got a solver"),
    }
}

#[test]
fn solve_refined_reaches_tight_tolerance() {
    // Aggressive compression: the direct solve is only approximate, but the
    // ULV-preconditioned refinement recovers a tight H²-operator residual.
    let solver = build(
        KernelFn::laplace(),
        H2Config { leaf_size: 48, max_rank: 12, far_samples: 64, ..Default::default() },
        SubstMode::Parallel,
    );
    let b = rhs(N, 51);
    let rep = solver.solve_refined(&b, 1e-10, 50).unwrap();
    assert!(rep.iterations >= 1);
    // Verify the refined residual against the H² operator directly.
    let bt = solver.matrix().tree.permute_vec(&b);
    let xt = solver.matrix().tree.permute_vec(&rep.x);
    let resid = solver.matrix().residual(&xt, &bt);
    assert!(resid < 1e-9, "refined H2-operator residual {resid}");
    // Nonsense tolerance is a typed error.
    assert!(matches!(solver.solve_refined(&b, -1.0, 10), Err(H2Error::InvalidConfig(_))));
}

#[test]
fn facade_dist_solve_matches_serial_and_reports_comm() {
    let solver = build(KernelFn::laplace(), compressed_cfg(), SubstMode::Parallel);
    let b = rhs(N, 61);
    let serial = solver.solve(&b).unwrap();
    let dist = solver.solve_dist(&b, 4).unwrap();
    assert_eq!(dist.ranks, 4); // N=192, leaf 48 -> 4 leaves
    let err = rel_err_vec(&dist.x, &serial.x);
    assert!(err < 1e-12, "distributed diverged from serial: {err}");
    assert!(dist.factor_bytes > 0 && dist.subst_bytes > 0);
    assert!(dist.factor_time > 0.0 && dist.subst_time > 0.0);
    // Single rank: no communication.
    let single = solver.solve_dist(&b, 1).unwrap();
    assert_eq!(single.factor_bytes, 0);
    assert_eq!(single.subst_bytes, 0);
}
