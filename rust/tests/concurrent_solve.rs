//! Concurrent solve sessions (ISSUE 4 acceptance):
//!
//! * ≥4 threads solving distinct right-hand sides on **one** `H2Solver`
//!   produce bit-identical results to sequential solves — the resident
//!   factor region is shared read-only and every call leases a private
//!   workspace, so no arena-wide mutex is held across launches;
//! * no `BufferId` leaks: the factor region's live count is unchanged and
//!   every pooled workspace returns empty;
//! * no re-planning under contention (`plan_recordings()` stays 1), and
//!   the lazily recorded naive program materializes exactly once even when
//!   many threads race to first-use it;
//! * `solve_many` fans out across the pool and still matches per-RHS
//!   sequential solves exactly.
//!
//! CI runs this file under `RUST_TEST_THREADS=4` so the scheduler actually
//! interleaves the in-flight solves.

mod common;

use common::{seeds, Case};
use h2ulv::prelude::*;
use std::sync::atomic::{AtomicUsize, Ordering};

const N: usize = 512;
const THREADS: usize = 6;

fn build_solver() -> H2Solver {
    // The pre-migration fixture used the *default* sampled far field
    // (far_samples = 128), unlike the exact-far-field `Case::fixed`
    // shared with device_api/plan_replay — keep exercising the
    // sampled-basis construction path under concurrency.
    let case = Case { far_samples: H2Config::default().far_samples, ..Case::fixed(N, 501) };
    case.solver(BackendSpec::Native)
}

fn rhs(seed: u64) -> Vec<f64> {
    common::rhs(N, seed)
}

#[test]
fn concurrent_solves_are_bit_identical_to_sequential() {
    let solver = build_solver();
    let resident = solver.resident_buffers();
    let bs: Vec<Vec<f64>> = (0..THREADS as u64).map(|t| rhs(100 + t)).collect();
    // Sequential ground truth.
    let sequential: Vec<Vec<f64>> =
        bs.iter().map(|b| solver.solve(b).expect("rhs matches").x).collect();

    // ≥4 threads solving distinct RHS simultaneously on one session.
    let started = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let handles: Vec<_> = bs
            .iter()
            .zip(&sequential)
            .map(|(b, want)| {
                let started = &started;
                let solver = &solver;
                s.spawn(move || {
                    started.fetch_add(1, Ordering::SeqCst);
                    // Crude rendezvous so the solves genuinely overlap.
                    while started.load(Ordering::SeqCst) < THREADS {
                        std::hint::spin_loop();
                    }
                    for _ in 0..3 {
                        let rep = solver.solve(b).expect("rhs matches");
                        assert_eq!(
                            rep.x, *want,
                            "concurrent solve diverged from sequential"
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("solver thread panicked");
        }
    });

    // No leaked BufferIds anywhere: the factor region is untouched and
    // every leased workspace came back to the pool.
    assert_eq!(solver.resident_buffers(), resident, "factor region live count changed");
    let (created, idle) = solver.workspace_stats();
    assert_eq!(created, idle, "a workspace region leaked");
    assert!(created <= THREADS, "pool grew past the number of in-flight solves");
    // The cached plan served every thread — recording never ran again.
    assert_eq!(solver.plan_recordings(), 1, "re-planning occurred under contention");
}

#[test]
fn concurrent_naive_solves_record_program_once() {
    // The naive program is recorded lazily; racing first-users must agree
    // bit-for-bit and leave plan_recordings untouched.
    let solver = build_solver();
    assert!(!solver.plan().naive_recorded());
    let bs: Vec<Vec<f64>> = (0..4u64).map(|t| rhs(200 + t)).collect();
    let xs: Vec<Vec<f64>> = std::thread::scope(|s| {
        let handles: Vec<_> = bs
            .iter()
            .map(|b| {
                let solver = &solver;
                s.spawn(move || solver.solve_with(b, SubstMode::Naive).expect("rhs matches").x)
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("thread panicked")).collect()
    });
    assert!(solver.plan().naive_recorded());
    assert_eq!(solver.plan_recordings(), 1);
    for (b, x) in bs.iter().zip(&xs) {
        let again = solver.solve_with(b, SubstMode::Naive).expect("rhs matches").x;
        assert_eq!(*x, again, "racing naive solves diverged from replay");
    }
}

#[test]
fn solve_many_fans_out_and_matches_sequential() {
    let solver = build_solver();
    let many: Vec<Vec<f64>> = (0..8u64).map(|t| rhs(300 + t)).collect();
    let reports = solver.solve_many(&many).expect("all rhs lengths match");
    assert_eq!(reports.len(), many.len());
    for (b, rep) in many.iter().zip(&reports) {
        let single = solver.solve(b).expect("rhs matches");
        assert_eq!(rep.x, single.x, "solve_many must match per-rhs solve exactly");
    }
    let (created, idle) = solver.workspace_stats();
    assert_eq!(created, idle, "solve_many leaked a workspace region");
    assert_eq!(solver.plan_recordings(), 1, "solve_many must not re-plan");
}

#[test]
fn concurrent_solves_bit_match_sequential_across_fuzzed_structures() {
    // The concurrency invariants hold across randomized H² structures
    // (depth, leaf size, ranks, admissibility), not just the fixed
    // fixture; `H2_TEST_SEEDS` (CI stress: 16) widens the sweep.
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let solver = case.solver(BackendSpec::Native);
        let bs: Vec<Vec<f64>> = (0..3u64).map(|t| case.rhs(500 + t)).collect();
        let want: Vec<Vec<f64>> =
            bs.iter().map(|b| solver.solve(b).expect("rhs matches").x).collect();
        std::thread::scope(|s| {
            for (b, want) in bs.iter().zip(&want) {
                let solver = &solver;
                let case = &case;
                s.spawn(move || {
                    let x = solver.solve(b).expect("rhs matches").x;
                    assert_eq!(x, *want, "concurrent solve diverged for {case}");
                });
            }
        });
        let (created, idle) = solver.workspace_stats();
        assert_eq!(created, idle, "workspace region leaked for {case}");
        assert_eq!(solver.plan_recordings(), 1, "re-planning occurred for {case}");
    }
}

#[test]
fn concurrent_pipelined_solves_bit_match_native_across_fuzzed_structures() {
    // ISSUE 10: the journaled solve path preserves the PR 4 concurrency
    // invariants — threads solving simultaneously on one `async:native`
    // session reproduce the synchronous native session bit-for-bit in
    // *both* substitution modes, while their launches pipeline through
    // one shared engine (`H2_TEST_SEEDS` widens the sweep in CI).
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let native = case.solver(BackendSpec::Native);
        let asynced = case.solver(BackendSpec::async_native());
        let bs: Vec<Vec<f64>> = (0..3u64).map(|t| case.rhs(900 + t)).collect();
        let want: Vec<(Vec<f64>, Vec<f64>)> = bs
            .iter()
            .map(|b| {
                (
                    native.solve(b).expect("rhs matches").x,
                    native.solve_with(b, SubstMode::Naive).expect("rhs matches").x,
                )
            })
            .collect();
        std::thread::scope(|s| {
            for (b, (parallel, naive)) in bs.iter().zip(&want) {
                let asynced = &asynced;
                let case = &case;
                s.spawn(move || {
                    let x = asynced.solve(b).expect("rhs matches").x;
                    assert_eq!(x, *parallel, "concurrent pipelined solve diverged for {case}");
                    let x = asynced.solve_with(b, SubstMode::Naive).expect("rhs matches").x;
                    assert_eq!(x, *naive, "concurrent pipelined naive solve diverged for {case}");
                });
            }
        });
        let (created, idle) = asynced.workspace_stats();
        assert_eq!(created, idle, "pipelined session leaked a workspace region for {case}");
        assert_eq!(asynced.plan_recordings(), 1, "re-planning occurred for {case}");
    }
}

#[test]
fn concurrent_mixed_entry_points_share_one_factor() {
    // solve / solve_refined / solve_dist all lease from one pool and read
    // one factor region; running them simultaneously must not perturb any
    // result.
    let solver = build_solver();
    let b = rhs(400);
    let want_direct = solver.solve(&b).expect("rhs matches").x;
    let want_dist = solver.solve_dist(&b, 4).expect("rhs matches").x;
    std::thread::scope(|s| {
        let solver = &solver;
        let b = &b;
        let want_direct = &want_direct;
        let want_dist = &want_dist;
        for _ in 0..2 {
            s.spawn(move || {
                let x = solver.solve(b).expect("rhs matches").x;
                assert_eq!(x, *want_direct);
            });
            s.spawn(move || {
                let x = solver.solve_dist(b, 4).expect("rhs matches").x;
                assert_eq!(x, *want_dist);
            });
            s.spawn(move || {
                let rep = solver.solve_refined(b, 1e-8, 50).expect("refinement converges");
                assert!(rep.iterations >= 1);
            });
        }
    });
    let (created, idle) = solver.workspace_stats();
    assert_eq!(created, idle, "mixed entry points leaked a workspace region");
}

#[test]
fn workspace_pool_shrinks_after_a_solve_burst() {
    // ISSUE 8 satellite: `trim_workspaces` (the serve layer's idle/evict
    // hook) must observably release pool memory — `workspace_bytes`
    // counts slot-table capacity, so idle regions pin real bytes even
    // after their payloads reset to empty.
    let solver = build_solver();
    let b = rhs(700);
    let want = solver.solve(&b).expect("rhs matches").x;
    let many: Vec<Vec<f64>> = (0..6u64).map(|t| rhs(600 + t)).collect();
    solver.solve_many(&many).expect("all rhs lengths match");
    let (created, idle) = solver.workspace_stats();
    assert_eq!(created, idle, "burst leaked a workspace region");
    assert!(created >= 1);
    let before = solver.workspace_bytes();
    assert!(before > 0, "idle regions pin slot-table bytes even when their payload is empty");
    let dropped = solver.trim_workspaces(0);
    assert_eq!(dropped, created, "trim_workspaces(0) drops every idle region");
    assert_eq!(solver.workspace_bytes(), 0, "a fully trimmed pool pins no bytes");
    assert_eq!(solver.workspace_stats(), (0, 0));
    // The pool re-grows on demand and the session still solves bit-identically.
    let again = solver.solve(&b).expect("rhs matches").x;
    assert_eq!(want, again, "solve after trim diverged");
    assert_eq!(solver.plan_recordings(), 1, "trimming must not force a re-plan");
}

#[test]
fn solve_many_thread_cap_bounds_fanout_and_preserves_bits() {
    // ISSUE 8 satellite: the builder-level `max_solve_threads` cap and the
    // per-call `SolveOptions::max_threads` override both bound the
    // `solve_many` fan-out without perturbing a single bit of the result
    // (each RHS runs the identical per-solve path regardless of workers).
    let case = Case { far_samples: H2Config::default().far_samples, ..Case::fixed(N, 501) };
    let reference = case.solver(BackendSpec::Native);
    let many: Vec<Vec<f64>> = (0..6u64).map(|t| rhs(800 + t)).collect();
    let want = reference.solve_many(&many).expect("all rhs lengths match");

    // Builder-level cap: the session never fans out past 2 workers, so
    // the pool never creates more than 2 regions.
    let capped = H2SolverBuilder::new(case.geometry(), case.kernel_fn())
        .config(case.config())
        .backend(BackendSpec::Native)
        .residual_samples(0)
        .max_solve_threads(2)
        .build()
        .expect("capped build succeeds");
    assert_eq!(capped.max_solve_threads(), 2);
    let got = capped.solve_many(&many).expect("all rhs lengths match");
    let (created, _) = capped.workspace_stats();
    assert!(created <= 2, "builder cap exceeded: pool grew to {created} regions");
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.x, g.x, "capped solve_many diverged from uncapped");
    }

    // Per-call override wins over the builder default: force 1 worker.
    let one = SolveOptions { max_threads: Some(1), ..Default::default() };
    let got1 = reference.solve_many_opts(&many, &one).expect("all rhs lengths match");
    for (w, g) in want.iter().zip(&got1) {
        assert_eq!(w.x, g.x, "single-threaded solve_many diverged");
    }
}
