//! Native vs PJRT backend parity: the full ULV pipeline must produce the
//! same factorization and solution through both execution paths (the
//! paper's CPU vs GPU implementations of one algorithm).

use h2ulv::batch::native::NativeBackend;
use h2ulv::construct::H2Config;
use h2ulv::geometry::Geometry;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::runtime::PjrtBackend;
use h2ulv::ulv::{factorize, SubstMode};
use h2ulv::util::Rng;

fn pjrt() -> Option<PjrtBackend> {
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(PjrtBackend::new(dir).unwrap())
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

/// Self-similar configuration: leaf = 2 * rank keeps every level's block
/// shapes inside one artifact family (DESIGN.md §5).
fn cfg() -> H2Config {
    H2Config { leaf_size: 64, max_rank: 32, far_samples: 128, near_samples: 96, ..Default::default() }
}

#[test]
fn factor_and_solve_parity_laplace_sphere() {
    let Some(be) = pjrt() else { return };
    let native = NativeBackend::new();
    let g = Geometry::sphere_surface(1024, 301);
    let k = KernelFn::laplace();
    let h2 = H2Matrix::construct(&g, &k, &cfg());
    let fac_n = factorize(&h2, &native);
    let fac_p = factorize(&h2, &be);
    // Factor data must agree (same math, different execution path).
    for (lf_n, lf_p) in fac_n.levels.iter().zip(&fac_p.levels) {
        for (a, b) in lf_n.chol_rr.iter().zip(&lf_p.chol_rr) {
            let mut d = a.clone();
            d.axpy(-1.0, b);
            assert!(
                h2ulv::linalg::norms::frob(&d) < 1e-8 * (1.0 + h2ulv::linalg::norms::frob(a)),
                "chol_rr diverged at level {}",
                lf_n.level
            );
        }
    }
    // Solutions must agree tightly.
    let mut rng = Rng::new(7);
    let b: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
    let x_n = fac_n.solve(&b, &native, SubstMode::Parallel);
    let x_p = fac_p.solve(&b, &be, SubstMode::Parallel);
    let err = rel_err_vec(&x_p, &x_n);
    assert!(err < 1e-9, "backend solutions diverged: {err}");
    assert!(
        be.stats.launches.load(std::sync::atomic::Ordering::Relaxed) > 10,
        "PJRT path must actually be exercised"
    );
}

#[test]
fn pjrt_solve_accuracy_vs_dense() {
    let Some(be) = pjrt() else { return };
    let g = Geometry::sphere_surface(512, 303);
    let kern = KernelFn::yukawa();
    let mut c = cfg();
    c.far_samples = 0; // best-accuracy construction
    let h2 = H2Matrix::construct(&g, &kern, &c);
    let fac = factorize(&h2, &be);
    let mut rng = Rng::new(9);
    let b: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
    let x = fac.solve(&b, &be, SubstMode::Parallel);
    let a = kern.dense(&g.points);
    let want = h2ulv::linalg::lu::solve(&a, &b).unwrap();
    let err = rel_err_vec(&x, &want);
    assert!(err < 1e-3, "pjrt end-to-end accuracy: {err}");
}

#[test]
fn pjrt_trace_records_batched_launches() {
    let Some(be) = pjrt() else { return };
    let be = be.with_tracer();
    let g = Geometry::sphere_surface(512, 305);
    let k = KernelFn::laplace();
    let h2 = H2Matrix::construct(&g, &k, &cfg());
    let _fac = factorize(&h2, &be);
    let tracer = be.tracer.as_ref().unwrap();
    let events = tracer.events();
    assert!(!events.is_empty());
    // The fig-12 property: launches are *batched* (mean batch > 1).
    assert!(
        tracer.mean_batch() > 1.5,
        "expected batched launches, got mean batch {}",
        tracer.mean_batch()
    );
    let kernels: std::collections::HashSet<_> = events.iter().map(|e| e.kernel).collect();
    assert!(kernels.contains("POTRF(pjrt)"));
    assert!(kernels.contains("GEMM2(pjrt)"));
}
