//! Shared test support: a seeded random H² problem generator (structure
//! fuzz) plus the fixed fixtures the pre-existing integration tests used,
//! so `device_api.rs`, `concurrent_solve.rs`, `plan_replay.rs`, and
//! `async_device.rs` build their problems from one place.
//!
//! A [`Case`] is a compact problem descriptor; its `Display` form is meant
//! to be embedded in assertion messages so a failing seed reproduces from
//! the test output alone:
//!
//! ```text
//! Case { seed: 5, n: 384, leaf: 48, rank: 24, eta: 1.5, far: 0, rhs: 2 }
//! ```
//!
//! [`seeds`] honours `H2_TEST_SEEDS` (default 8) so CI stress jobs can
//! widen interleaving/structure coverage without slowing the default
//! suite.

// Each test binary compiles its own copy of this module and uses a
// different subset of it.
#![allow(dead_code)]

use h2ulv::construct::H2Config;
use h2ulv::geometry::Geometry;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::solver::{BackendSpec, H2Solver, H2SolverBuilder};
use h2ulv::util::Rng;
use std::fmt;

/// One randomized (or fixed) H² test problem: everything needed to build
/// the matrix, its right-hand sides, and a facade session.
#[derive(Clone, Debug)]
pub struct Case {
    pub seed: u64,
    pub n: usize,
    pub leaf_size: usize,
    pub max_rank: usize,
    pub eta: f64,
    pub far_samples: usize,
    pub rhs_count: usize,
}

impl fmt::Display for Case {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Case {{ seed: {}, n: {}, leaf: {}, rank: {}, eta: {}, far: {}, rhs: {} }}",
            self.seed, self.n, self.leaf_size, self.max_rank, self.eta, self.far_samples,
            self.rhs_count
        )
    }
}

impl Case {
    /// Structure fuzz: derive a varied problem from one seed — tree depth
    /// (via `n / leaf`), leaf size, rank budget, admissibility `eta`, and
    /// RHS count all vary. Parameter ranges stay inside the envelope the
    /// fixed-fixture tests have proven SPD-safe (rank ≥ leaf/2, exact far
    /// field), so every generated case factorizes.
    pub fn from_seed(seed: u64) -> Case {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xC0FFEE));
        let leaf_size = [32, 48, 64][rng.below(3)];
        // 4..=12 leaves' worth of points: depth 2–4 once the tree splits.
        let leaves = 4 + rng.below(9);
        let n = leaf_size * leaves;
        let max_rank = [leaf_size / 2, (3 * leaf_size) / 4][rng.below(2)];
        let eta = [1.0, 1.5, 2.0][rng.below(3)];
        let rhs_count = 1 + rng.below(3);
        Case { seed, n, leaf_size, max_rank, eta, far_samples: 0, rhs_count }
    }

    /// The fixed fixture `device_api.rs` and `plan_replay.rs` shared
    /// (leaf 64, rank 32, exact far field, default admissibility).
    /// Override fields with struct-update syntax for variants — e.g.
    /// `concurrent_solve.rs` restores the default sampled far field.
    pub fn fixed(n: usize, seed: u64) -> Case {
        Case {
            seed,
            n,
            leaf_size: 64,
            max_rank: 32,
            eta: H2Config::default().eta,
            far_samples: 0,
            rhs_count: 1,
        }
    }

    pub fn config(&self) -> H2Config {
        H2Config {
            leaf_size: self.leaf_size,
            max_rank: self.max_rank,
            eta: self.eta,
            far_samples: self.far_samples,
            ..Default::default()
        }
    }

    pub fn geometry(&self) -> Geometry {
        Geometry::sphere_surface(self.n, self.seed)
    }

    /// Construct the H² matrix for this case (Laplace kernel).
    pub fn h2(&self) -> H2Matrix {
        H2Matrix::construct(&self.geometry(), &KernelFn::laplace(), &self.config())
    }

    /// The `k`-th deterministic right-hand side of this case.
    pub fn rhs(&self, k: u64) -> Vec<f64> {
        rhs(self.n, self.seed.wrapping_mul(1000).wrapping_add(k))
    }

    /// All `rhs_count` right-hand sides.
    pub fn rhs_set(&self) -> Vec<Vec<f64>> {
        (0..self.rhs_count as u64).map(|k| self.rhs(k)).collect()
    }

    /// Build a facade session on `spec` (residual sampling off — these
    /// are determinism/parity tests, not accuracy tests).
    pub fn solver(&self, spec: BackendSpec) -> H2Solver {
        H2SolverBuilder::new(self.geometry(), KernelFn::laplace())
            .config(self.config())
            .backend(spec)
            .residual_samples(0)
            .build()
            .unwrap_or_else(|e| panic!("failed to build solver for {self}: {e}"))
    }
}

/// A deterministic normal right-hand side.
pub fn rhs(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

/// Seed sweep for the randomized harnesses: `0..H2_TEST_SEEDS` (default
/// 8). CI's stress job sets `H2_TEST_SEEDS=16` to widen coverage.
pub fn seeds() -> Vec<u64> {
    let count = std::env::var("H2_TEST_SEEDS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(8);
    (0..count as u64).collect()
}
