//! Shared test support — a thin re-export of the library's canonical
//! seeded problem generator ([`h2ulv::bench::cases`]), so the integration
//! tests, the CLI `plan-lint` fuzzer, and the benchmark sweep all draw
//! their problems from one place. Since PR 7, [`Case::from_seed`] also
//! varies the point distribution (sphere vs clustered blobs) and the
//! kernel (laplace / yukawa / gaussian / matérn-3/2); a `Case`'s
//! `Display` form still reproduces a failing seed from test output alone.
//!
//! [`seeds`] honours `H2_TEST_SEEDS` (default 8) so CI stress jobs can
//! widen interleaving/structure coverage without slowing the default
//! suite.

// Each test binary compiles its own copy of this module and uses a
// different subset of it.
#![allow(dead_code)]
#![allow(unused_imports)]

pub use h2ulv::bench::cases::{rhs, sweep_seeds as seeds, Case, Distribution};
