//! `FactorStorage` policy (ISSUE 4 acceptance): a
//! `FactorStorage::DeviceOnly` session runs the full
//! factorize → solve → solve_dist → figure-style path with the `UlvFactor`
//! host mirror never materialized, matching the default `Mirrored` session
//! to 1e-12 (bit-identical on one backend, in fact), while the reported
//! factor footprint shrinks by exactly the mirror's size.

use h2ulv::prelude::*;
use h2ulv::util::Rng;

const N: usize = 512;

fn builder(storage: FactorStorage) -> H2SolverBuilder {
    let g = Geometry::sphere_surface(N, 601);
    H2SolverBuilder::new(g, KernelFn::laplace())
        .config(H2Config { leaf_size: 64, max_rank: 32, ..Default::default() })
        .factor_storage(storage)
        .residual_samples(0)
}

fn rhs(seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..N).map(|_| rng.normal()).collect()
}

#[test]
fn device_only_full_path_matches_mirrored() {
    let mirrored = builder(FactorStorage::Mirrored).build().expect("well-formed");
    let device_only = builder(FactorStorage::DeviceOnly).build().expect("well-formed");
    assert_eq!(mirrored.factor_storage(), FactorStorage::Mirrored);
    assert_eq!(device_only.factor_storage(), FactorStorage::DeviceOnly);
    assert!(mirrored.factor().is_some(), "mirrored session exposes the host factor");
    assert!(device_only.factor().is_none(), "device-only must never materialize the mirror");

    let b = rhs(1);
    // Direct solve: same backend, same plan — bit-identical.
    let xm = mirrored.solve(&b).expect("rhs matches").x;
    let xd = device_only.solve(&b).expect("rhs matches").x;
    assert_eq!(xm, xd, "device-only solve diverged from mirrored");

    // Refined solve.
    let rm = mirrored.solve_refined(&b, 1e-8, 50).expect("converges");
    let rd = device_only.solve_refined(&b, 1e-8, 50).expect("converges");
    assert_eq!(rm.x, rd.x, "device-only refinement diverged");

    // Distributed path: the model reads FactorMeta, not the mirror —
    // solutions and modeled times must agree exactly.
    for p in [1, 4] {
        let dm = mirrored.solve_dist(&b, p).expect("rhs matches");
        let dd = device_only.solve_dist(&b, p).expect("rhs matches");
        assert_eq!(dm.x, dd.x, "P={p}: device-only dist solve diverged");
        assert_eq!(dm.ranks, dd.ranks);
        assert_eq!(dm.factor_bytes, dd.factor_bytes, "P={p}: modeled comm diverged");
        assert_eq!(dm.subst_bytes, dd.subst_bytes);
        assert!((dm.factor_time - dd.factor_time).abs() < 1e-12);
    }

    // Figure-style introspection works without the mirror: schedule dump
    // and meta-derived footprints.
    let dump = device_only.plan().render_schedule();
    assert!(dump.contains("factor launches"));
    let err = rel_err(&xm, &xd);
    assert!(err <= 1e-12, "parity budget exceeded: {err}");
}

fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    let den: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
    num / den
}

#[test]
fn device_only_shrinks_factor_footprint() {
    let mirrored = builder(FactorStorage::Mirrored).build().expect("well-formed");
    let device_only = builder(FactorStorage::DeviceOnly).build().expect("well-formed");
    let sm = mirrored.stats();
    let sd = device_only.stats();

    // Same factor, same device residency, same schedule.
    assert_eq!(sm.factor_entries, sd.factor_entries);
    assert_eq!(sm.arena_bytes, sd.arena_bytes, "device residency must not depend on policy");
    assert_eq!(sm.arena_peak_bytes, sd.arena_peak_bytes);
    assert!(sd.arena_bytes > 0);
    assert!(sd.arena_peak_bytes >= sd.arena_bytes);

    // The mirror is the entire difference — and it is gone.
    assert_eq!(sm.mirror_entries, sm.factor_entries);
    assert_eq!(sd.mirror_entries, 0);
    assert_eq!(
        sm.factor_footprint_bytes() - sd.factor_footprint_bytes(),
        8 * sm.mirror_entries,
        "device-only must save exactly the mirror bytes"
    );

    // Meta agrees with the actual mirrored factor, shape for shape.
    let fac = mirrored.factor().expect("mirrored");
    assert_eq!(fac.storage_entries(), mirrored.factor_meta().storage_entries());
    assert_eq!(fac.meta().storage_entries(), device_only.factor_meta().storage_entries());
    assert_eq!(fac.root_l.rows(), device_only.factor_meta().root_n);
}

#[test]
fn download_block_matches_mirror_values() {
    let mirrored = builder(FactorStorage::Mirrored).build().expect("well-formed");
    let device_only = builder(FactorStorage::DeviceOnly).build().expect("well-formed");
    let fac = mirrored.factor().expect("mirrored");

    // Root factor, a diagonal Cholesky block, and a basis, fetched from
    // the device-only session's resident arena, must equal the mirror
    // bit-for-bit (same backend, same replay).
    let root = device_only.download_block(FactorBlock::Root).expect("root exists");
    assert_eq!(root.as_slice(), fac.root_l.as_slice());

    let chol = device_only
        .download_block(FactorBlock::CholRr { level: 0, box_index: 0 })
        .expect("leaf chol exists");
    assert_eq!(chol.as_slice(), fac.levels[0].chol_rr[0].as_slice());

    let basis = device_only
        .download_block(FactorBlock::Basis { level: 0, box_index: 0 })
        .expect("leaf basis exists");
    assert_eq!(basis.as_slice(), fac.levels[0].bases[0].u.as_slice());

    // A panel, through its meta-declared key.
    let meta = device_only.factor_meta();
    if let Some(&pair) = meta.levels[0].ls.first() {
        let panel = device_only
            .download_block(FactorBlock::Ls { level: 0, pair })
            .expect("declared panel exists");
        assert_eq!(panel.as_slice(), fac.levels[0].ls[&pair].as_slice());
    }

    // Unknown blocks are typed errors, not panics.
    let err = device_only
        .download_block(FactorBlock::CholRr { level: 99, box_index: 0 })
        .expect_err("bogus level");
    assert!(matches!(err, H2Error::InvalidConfig(_)));
}

#[test]
fn storage_mode_parses_like_backend_spec() {
    assert_eq!(FactorStorage::by_name("mirrored"), Some(FactorStorage::Mirrored));
    assert_eq!(FactorStorage::by_name("device-only"), Some(FactorStorage::DeviceOnly));
    assert_eq!(FactorStorage::by_name("device_only"), Some(FactorStorage::DeviceOnly));
    assert_eq!(FactorStorage::by_name("gpu"), None);
    assert_eq!(FactorStorage::default().name(), "mirrored");
    assert_eq!(FactorStorage::DeviceOnly.name(), "device-only");
}
