//! Static plan verifier integration tests (crate::plan::verify).
//!
//! Three angles, mirroring the verifier's three analyses:
//!
//! 1. **Positive sweep** — every fuzzed structure's recorded plan (the
//!    factorization and *both* substitution programs) verifies clean.
//! 2. **Peak exactness** — the liveness simulation's predicted arena peak
//!    equals the byte-tracking arena's measured peak on host-synchronous
//!    backends, for every fuzzed structure.
//! 3. **Negative corruption** — hand-corrupting a recorded program makes
//!    the verifier name the offending instruction index and violation
//!    class (no false negatives on the defect classes it claims to catch).
//!
//! Plus the differential hazard audit: the async engine's runtime hazard
//! tracker must order exactly the edges the static graph predicts.

mod common;

use common::{seeds, Case};
use h2ulv::batch::device::AsyncDevice;
use h2ulv::plan::verify::{self, ProgramKind, ViolationKind};
use h2ulv::plan::{self, Instr, Plan, SolveInstr};
use h2ulv::solver::backend::SerialBackend;
use h2ulv::solver::BackendSpec;
use h2ulv::ulv::SubstMode;
use std::sync::Arc;

// ---------------------------------------------------------------------
// 1. Positive sweep.
// ---------------------------------------------------------------------

#[test]
fn fuzzed_structures_verify_clean() {
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let h2 = case.h2();
        let plan = plan::record(&h2);
        // Materialize the lazy naive program so both substitution modes
        // are in scope for the verifier.
        let _ = plan.solve_program(SubstMode::Naive);
        let report = verify::verify(&plan)
            .unwrap_or_else(|v| panic!("{case}: recorded plan flagged by the verifier: {v}"));
        assert_eq!(report.n, case.n, "{case}");
        assert!(
            report.solve_naive.is_some(),
            "{case}: materialized naive program must be verified too"
        );
        assert!(report.predicted_peak_bytes > 0, "{case}: peak prediction is empty");
        assert!(report.hazard.critical_path > 0, "{case}: hazard graph is empty");
        assert!(
            report.hazard.ops.len() >= report.factor_instrs,
            "{case}: per-item uploads/frees must not shrink the op count"
        );
    }
}

// ---------------------------------------------------------------------
// 2. Predicted peak == measured arena peak (host-synchronous backends).
// ---------------------------------------------------------------------

#[test]
fn predicted_peak_matches_arena_peak() {
    for seed in seeds() {
        let case = Case::from_seed(seed);
        for (name, spec) in
            [("native", BackendSpec::Native), ("serial", BackendSpec::SerialReference)]
        {
            let solver = case.solver(spec);
            let stats = solver.stats();
            assert!(stats.predicted_peak_bytes > 0, "{case} on {name}: no prediction");
            assert_eq!(
                stats.predicted_peak_bytes, stats.arena_peak_bytes,
                "{case} on {name}: static liveness peak must equal the arena's measured peak"
            );
        }
    }
}

// ---------------------------------------------------------------------
// 3. Negative corruption tests.
// ---------------------------------------------------------------------

/// A fixed plan to corrupt. The recorder's per-level layout is pinned by
/// the index assertions below: steps[0] = basis Upload, steps[1] =
/// Sparsify, steps[2] = Free of the consumed dense blocks, steps[3] = the
/// RR Extract; the prologue is one Upload instruction, so
/// `levels[0].steps[k]` sits at flattened index `1 + k`.
fn fixed_plan(seed: u64) -> Plan {
    plan::record(&Case::fixed(256, seed).h2())
}

#[test]
fn verifier_flags_use_before_def_with_instruction_index() {
    let mut plan = fixed_plan(3);
    // Swap the basis upload behind the Sparsify that reads it.
    assert!(matches!(plan.factor.levels[0].steps[0], Instr::Upload { .. }));
    assert!(matches!(plan.factor.levels[0].steps[1], Instr::Sparsify { .. }));
    plan.factor.levels[0].steps.swap(0, 1);
    let v = verify::verify(&plan).expect_err("reordered basis upload must be flagged");
    assert!(matches!(v.kind, ViolationKind::UseBeforeDef), "{v}");
    assert_eq!(v.index, 1, "{v}");
    assert_eq!(v.opcode, "SPARSIFY", "{v}");
    assert!(matches!(v.program, ProgramKind::Factor), "{v}");
}

#[test]
fn verifier_flags_use_after_free_with_instruction_index() {
    let mut plan = fixed_plan(4);
    // Hoist the consumed-blocks Free above the Sparsify that reads them.
    assert!(matches!(plan.factor.levels[0].steps[2], Instr::Free { .. }));
    plan.factor.levels[0].steps.swap(1, 2);
    let v = verify::verify(&plan).expect_err("freed-then-read blocks must be flagged");
    assert!(matches!(v.kind, ViolationKind::UseAfterFree), "{v}");
    assert_eq!(v.index, 3, "{v}");
    assert_eq!(v.opcode, "SPARSIFY", "{v}");
}

#[test]
fn verifier_flags_duplicate_intra_launch_writes() {
    let mut plan = fixed_plan(5);
    let Instr::Extract { items } = &mut plan.factor.levels[0].steps[3] else {
        panic!("recorder layout changed: steps[3] is not the RR Extract");
    };
    assert!(items.len() >= 2, "need two leaf boxes to alias");
    items[1].dst = items[0].dst;
    let dup = items[0].dst;
    let v = verify::verify(&plan).expect_err("two items writing one buffer must be flagged");
    assert!(matches!(v.kind, ViolationKind::DuplicateWrite), "{v}");
    assert_eq!(v.index, 4, "{v}");
    assert_eq!(v.opcode, "EXTRACT", "{v}");
    assert_eq!(v.buffer, Some(dup), "{v}");
}

#[test]
fn verifier_flags_double_free_with_instruction_index() {
    let mut plan = fixed_plan(6);
    let Instr::Free { bufs } = &mut plan.factor.levels[0].steps[2] else {
        panic!("recorder layout changed: steps[2] is not the consumed-blocks Free");
    };
    let b = bufs[0];
    bufs.push(b);
    let v = verify::verify(&plan).expect_err("freeing a buffer twice must be flagged");
    assert!(matches!(v.kind, ViolationKind::DoubleFree), "{v}");
    assert_eq!(v.index, 3, "{v}");
    assert_eq!(v.opcode, "FREE", "{v}");
    assert_eq!(v.buffer, Some(b), "{v}");
}

#[test]
fn verifier_flags_leak_at_program_end() {
    let mut plan = fixed_plan(7);
    let removed = plan.factor.levels[0].steps.remove(2);
    assert!(matches!(removed, Instr::Free { .. }), "recorder layout changed");
    // Index arithmetic on the corrupted program: the end-of-program
    // residency audit reports one past the virtual root Cholesky.
    let flat = 1 + plan.factor.levels.iter().map(|l| l.steps.len()).sum::<usize>();
    let v = verify::verify(&plan).expect_err("undead buffers at program end must be flagged");
    assert!(matches!(v.kind, ViolationKind::Leak), "{v}");
    assert_eq!(v.index, flat + 1, "{v}");
    assert_eq!(v.opcode, "END", "{v}");
}

#[test]
fn verifier_flags_factor_region_writes_in_solve_programs() {
    let mut plan = fixed_plan(8);
    let idx = plan
        .solve_parallel
        .steps
        .iter()
        .position(|s| matches!(s, SolveInstr::TrsvFwd { .. }))
        .expect("parallel substitution always forward-substitutes");
    let SolveInstr::TrsvFwd { items, .. } = &mut plan.solve_parallel.steps[idx] else {
        unreachable!()
    };
    // Point the in-place vector operand at the factor-region matrix: a
    // substitution step may never write below the workspace base.
    items[0].1 = items[0].0;
    let v = verify::verify(&plan).expect_err("factor-region write must be flagged");
    assert!(matches!(v.kind, ViolationKind::FactorRegionWrite), "{v}");
    assert_eq!(v.index, idx, "{v}");
    assert!(matches!(v.program, ProgramKind::SolveParallel), "{v}");
}

// ---------------------------------------------------------------------
// Differential hazard audit: static graph vs the async runtime tracker.
// ---------------------------------------------------------------------

#[test]
fn async_hazard_tracker_matches_static_graph() {
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let h2 = case.h2();
        let plan = Arc::new(plan::record(&h2));
        let dev = AsyncDevice::new(SerialBackend);
        dev.enable_hazard_log();
        let _arena = plan::Executor::new(&dev).factorize_device_only(&plan, &h2);
        let log = dev.take_hazard_log();
        let graph = verify::hazard_graph(&plan, dev.streams());
        assert_eq!(
            log.len(),
            graph.ops.len(),
            "{case}: runtime issued a different op count than the static graph predicts"
        );
        for (r, s) in log.iter().zip(graph.ops.iter()) {
            assert_eq!(r.seq as usize, s.seq, "{case}: sequence drift");
            assert_eq!(r.opcode, s.opcode, "{case}: opcode at seq {}", s.seq);
            assert_eq!(r.stream, s.stream, "{case}: stream at seq {} ({})", s.seq, s.opcode);
            assert_eq!(r.level, s.level, "{case}: level at seq {} ({})", s.seq, s.opcode);
            assert_eq!(
                r.operands, s.operands,
                "{case}: operand set at seq {} ({})",
                s.seq, s.opcode
            );
            let deps: Vec<usize> = r.deps.iter().map(|&d| d as usize).collect();
            assert_eq!(
                deps, s.deps,
                "{case}: dependency edges at seq {} ({})",
                s.seq, s.opcode
            );
        }
    }
}
