//! Distributed runtime integration: P-rank SPMD factorize+solve must match
//! the single-process pipeline, and the communication profile must show
//! the paper's structural properties (§5).

use h2ulv::batch::native::NativeBackend;
use h2ulv::construct::H2Config;
use h2ulv::dist::{dist_solve_driver, NCCL_LIKE};
use h2ulv::geometry::Geometry;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::ulv::{factorize, SubstMode};
use h2ulv::util::Rng;

fn build(n: usize, seed: u64) -> H2Matrix {
    let g = Geometry::sphere_surface(n, seed);
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 128, ..Default::default() };
    H2Matrix::construct(&g, &KernelFn::laplace(), &cfg)
}

#[test]
fn dist_matches_serial_for_all_rank_counts() {
    let h2 = build(1024, 701);
    let fac = factorize(&h2, &NativeBackend::new());
    let mut rng = Rng::new(1);
    let b: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
    let want = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Parallel);
    for p in [1usize, 2, 4, 8] {
        let report = dist_solve_driver(&h2, p, &b, SubstMode::Parallel);
        let err = rel_err_vec(&report.x, &want);
        assert!(err < 1e-11, "p={p}: distributed diverged from serial: {err}");
    }
}

#[test]
fn single_rank_has_zero_comm() {
    let h2 = build(512, 703);
    let mut rng = Rng::new(3);
    let b: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
    let report = dist_solve_driver(&h2, 1, &b, SubstMode::Parallel);
    assert_eq!(report.factor_bytes, 0);
    assert_eq!(report.subst_bytes, 0);
}

#[test]
fn factorization_comm_independent_of_n() {
    // Paper §5.1: "both the number of collective communication function
    // calls and the message sizes are independent of the problem size N"
    // (for fixed P, fixed leaf size, fixed rank).
    let mut rng = Rng::new(5);
    let mut bytes = Vec::new();
    for n in [1024usize, 4096] {
        let h2 = build(n, 705);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let report = dist_solve_driver(&h2, 4, &b, SubstMode::Parallel);
        bytes.push(report.factor_bytes as f64);
    }
    // 4x problem size; factorization traffic should stay within ~2x
    // (the merged-level block count at the top of the tree is fixed).
    assert!(
        bytes[1] < 2.5 * bytes[0],
        "factor comm grew with N: {} -> {}",
        bytes[0],
        bytes[1]
    );
}

#[test]
fn flops_balance_across_ranks() {
    let h2 = build(2048, 707);
    let mut rng = Rng::new(7);
    let b: Vec<f64> = (0..2048).map(|_| rng.normal()).collect();
    let report = dist_solve_driver(&h2, 4, &b, SubstMode::Parallel);
    let f: Vec<f64> = report.rank_flops.iter().map(|&(x, _)| x as f64).collect();
    let max = f.iter().cloned().fold(0.0, f64::max);
    let min = f.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        max / min < 2.0,
        "factorization load imbalance: {min}..{max} ({:?})",
        report.rank_flops
    );
}

#[test]
fn modeled_times_positive_and_ordered() {
    let h2 = build(1024, 709);
    let mut rng = Rng::new(9);
    let b: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
    let report = dist_solve_driver(&h2, 4, &b, SubstMode::Parallel);
    let tf = report.factor_time(&NCCL_LIKE);
    let ts = report.subst_time(&NCCL_LIKE);
    assert!(tf > 0.0 && ts > 0.0);
    // Factorization does far more FLOPs than substitution.
    let ff: u64 = report.rank_flops.iter().map(|&(x, _)| x).sum();
    let fs: u64 = report.rank_flops.iter().map(|&(_, x)| x).sum();
    assert!(ff > 5 * fs, "factor flops {ff} vs subst {fs}");
}
