//! End-to-end integration: geometry -> construction -> ULV factorization ->
//! substitution -> residual, across kernels, geometries, admissibilities.

use h2ulv::batch::native::NativeBackend;
use h2ulv::construct::H2Config;
use h2ulv::geometry::molecule::hemoglobin_like;
use h2ulv::geometry::Geometry;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::ulv::{factorize, SubstMode};
use h2ulv::util::Rng;

fn solve_and_check(g: &Geometry, kern: &KernelFn, cfg: &H2Config, tol: f64, seed: u64) {
    let n = g.len();
    let h2 = H2Matrix::construct(g, kern, cfg);
    let fac = factorize(&h2, &NativeBackend::new());
    let mut rng = Rng::new(seed);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let x = fac.solve(&b, &NativeBackend::new(), SubstMode::Parallel);
    let a = kern.dense(&g.points);
    let want = h2ulv::linalg::lu::solve(&a, &b).unwrap();
    let err = rel_err_vec(&x, &want);
    assert!(
        err < tol,
        "{} on {}: solution error {err} > {tol}",
        kern.name,
        g.name
    );
}

#[test]
fn laplace_sphere_full_pipeline() {
    let g = Geometry::sphere_surface(1024, 401);
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 0, ..Default::default() };
    solve_and_check(&g, &KernelFn::laplace(), &cfg, 2e-3, 1);
}

#[test]
fn yukawa_molecule_full_pipeline() {
    // The paper's second workload: Yukawa potential on a molecule surface.
    let g = hemoglobin_like(0.06, 403); // ~900 points
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 0, ..Default::default() };
    solve_and_check(&g, &KernelFn::yukawa(), &cfg, 2e-3, 3);
}

#[test]
fn gaussian_cube_full_pipeline() {
    let g = Geometry::uniform_cube(768, 405);
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 0, ..Default::default() };
    solve_and_check(&g, &KernelFn::gaussian(), &cfg, 2e-3, 5);
}

#[test]
fn admissibility_sweep_all_solve() {
    let g = Geometry::sphere_surface(512, 407);
    for eta in [0.0, 0.7, 1.5, 2.5] {
        let cfg = H2Config {
            leaf_size: 64,
            max_rank: 32,
            far_samples: 0,
            eta,
            ..Default::default()
        };
        // Accuracy degrades as eta shrinks (HSS limit compresses touching
        // boxes); just require a sane solve everywhere.
        let tol = if eta < 0.5 { 0.2 } else { 5e-3 };
        solve_and_check(&g, &KernelFn::laplace(), &cfg, tol, 7);
    }
}

#[test]
fn sampled_construction_still_solves() {
    let g = Geometry::sphere_surface(2048, 409);
    let cfg = H2Config {
        leaf_size: 64,
        max_rank: 32,
        far_samples: 128,
        near_samples: 96,
        ..Default::default()
    };
    solve_and_check(&g, &KernelFn::laplace(), &cfg, 2e-2, 9);
}

#[test]
fn residual_sampled_agrees_with_direct() {
    // The sampled residual estimator (used at large N) must agree with the
    // dense residual at small N.
    let g = Geometry::sphere_surface(600, 411);
    let kern = KernelFn::laplace();
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 0, ..Default::default() };
    let h2 = H2Matrix::construct(&g, &kern, &cfg);
    let fac = factorize(&h2, &NativeBackend::new());
    let mut rng = Rng::new(11);
    let bt: Vec<f64> = (0..600).map(|_| rng.normal()).collect();
    let xt = fac.solve_tree_order(&bt, &NativeBackend::new(), SubstMode::Parallel);
    let sampled = h2.residual_sampled(&xt, &bt, 128, 13);
    // Direct dense residual.
    let a = kern.dense(&h2.tree.points);
    let mut ax = vec![0.0; 600];
    h2ulv::linalg::blas::gemv(1.0, &a, h2ulv::linalg::matrix::Trans::No, &xt, 0.0, &mut ax);
    let direct = rel_err_vec(&ax, &bt);
    assert!(
        sampled < 10.0 * direct + 1e-12 && direct < 10.0 * sampled + 1e-12,
        "sampled {sampled} vs direct {direct}"
    );
}

#[test]
fn gauss_seidel_prefactorization_matches_exact() {
    // Paper §3.5: 1-2 Gauss-Seidel sweeps suffice for the pre-factorization.
    let g = Geometry::sphere_surface(512, 413);
    let kern = KernelFn::laplace();
    let mut errs = Vec::new();
    for gs in [0usize, 2] {
        let cfg = H2Config {
            leaf_size: 64,
            max_rank: 32,
            far_samples: 0,
            gauss_seidel_iters: gs,
            ..Default::default()
        };
        let h2 = H2Matrix::construct(&g, &kern, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let mut rng = Rng::new(15);
        let b: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
        let x = fac.solve(&b, &NativeBackend::new(), SubstMode::Parallel);
        let a = kern.dense(&g.points);
        let want = h2ulv::linalg::lu::solve(&a, &b).unwrap();
        errs.push(rel_err_vec(&x, &want));
    }
    // GS-based construction must be in the same accuracy class as exact.
    assert!(errs[1] < 10.0 * errs[0] + 1e-6, "exact {} vs GS {}", errs[0], errs[1]);
}

#[test]
fn factorization_basis_ablation_suppresses_skipped_updates() {
    // The paper's central design point (eq 21): with the factorization
    // basis folded into the shared basis, the trailing updates the ULV
    // factorization *skips* are negligible. We measure that directly as
    // the residual of the ULV solve against the H² reconstruction Â
    // (naive substitution inverts the computed factor exactly, so this
    // residual *is* the skipped-update error). Note the trade-off: at a
    // fixed rank budget the near-field content costs some far-field
    // accuracy, so plain solution error can favor either variant — the
    // paper's claim is specifically about the skip term.
    let g = Geometry::sphere_surface(512, 415);
    let kern = KernelFn::laplace();
    let mut rng = Rng::new(17);
    let b: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
    let mut skip = Vec::new();
    for fb in [true, false] {
        let cfg = H2Config {
            leaf_size: 64,
            max_rank: 48,
            far_samples: 0,
            near_samples: 0,
            factorization_basis: fb,
            ..Default::default()
        };
        let h2 = H2Matrix::construct(&g, &kern, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let x = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Naive);
        let rec = h2.reconstruct_dense();
        let mut ax = vec![0.0; 512];
        h2ulv::linalg::blas::gemv(1.0, &rec, h2ulv::linalg::matrix::Trans::No, &x, 0.0, &mut ax);
        skip.push(rel_err_vec(&ax, &b));
    }
    assert!(
        skip[0] < 0.25 * skip[1],
        "factorization basis must suppress skipped updates: with={} without={}",
        skip[0],
        skip[1]
    );
}
