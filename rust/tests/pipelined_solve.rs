//! The pipelined substitution path (ISSUE 10 acceptance):
//! `Device::launch_solve` journals through `AsyncDevice`'s per-level
//! stream queues with shared-reader factor operands, so the solve side of
//! the ULV gets the same overlap machinery PR 5 built for factorization.
//!
//! * seed-swept (`H2_TEST_SEEDS`) bit-parity of the pipelined solve vs
//!   the synchronous native path, across both substitution modes and the
//!   `solve_many` fan-out;
//! * the differential solve hazard audit: the runtime journal of one
//!   substitution replay matches [`h2ulv::plan::verify::solve_hazard_graph`]
//!   op-for-op (opcode, stream, level, operand set, dependency edges) —
//!   including the *coalesced* naive program;
//! * the recorder's coalescing pass demonstrably widens the naive serial
//!   chain (fewer TRSV launches than chain runs) and the widened program
//!   still passes the full static verifier;
//! * solve-path overlap is observable at the facade: `run_report()` shows
//!   nonzero `solve_overlapped_transfer_pairs` on an `async:native`
//!   session driving a `solve_many` fan-out.

mod common;

use common::{seeds, Case};
use h2ulv::batch::device::{AsyncDevice, Device, VecRegion};
use h2ulv::plan::{self, verify, Executor, SolveInstr};
use h2ulv::prelude::*;
use h2ulv::solver::backend::SerialBackend;
use std::sync::Arc;

// ---------------------------------------------------------------------
// (a) Seed-swept bit-parity: pipelined vs synchronous.
// ---------------------------------------------------------------------

#[test]
fn pipelined_solves_bit_match_the_synchronous_path_across_seeds() {
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let native = case.solver(BackendSpec::Native);
        let asynced = case.solver(BackendSpec::async_native());
        assert_eq!(asynced.backend_name(), "async:native");
        for k in 0..case.rhs_count as u64 {
            let b = case.rhs(k);
            for mode in [SubstMode::Parallel, SubstMode::Naive] {
                let xn = native.solve_with(&b, mode).expect("rhs matches").x;
                let xa = asynced.solve_with(&b, mode).expect("rhs matches").x;
                assert_eq!(xn, xa, "{case}: pipelined {mode:?} solve diverged (rhs {k})");
            }
        }
        let many = case.rhs_set();
        let rep_n = native.solve_many(&many).expect("rhs lengths match");
        let rep_a = asynced.solve_many(&many).expect("rhs lengths match");
        for (i, (rn, ra)) in rep_n.iter().zip(&rep_a).enumerate() {
            assert_eq!(rn.x, ra.x, "{case}: pipelined solve_many diverged (rhs {i})");
        }
        // The pool/plan invariants survive the journaled path.
        let (created, idle) = asynced.workspace_stats();
        assert_eq!(created, idle, "{case}: pipelined session leaked a workspace region");
        assert_eq!(asynced.plan_recordings(), 1, "{case}: re-planning occurred");
    }
}

// ---------------------------------------------------------------------
// (b) Differential solve hazard audit: runtime journal vs static graph.
// ---------------------------------------------------------------------

#[test]
fn solve_journal_matches_the_static_solve_hazard_graph() {
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let h2 = case.h2();
        let plan = Arc::new(plan::record(&h2));
        let dev = AsyncDevice::new(SerialBackend);
        let ex = Executor::new(&dev);
        let arena = ex.factorize_device_only(&plan, &h2);
        let bt = h2.tree.permute_vec(&case.rhs(0));
        for mode in [SubstMode::Parallel, SubstMode::Naive] {
            // Restore the post-factorization steady state the static graph
            // models (the root Cholesky's hint parks the engine on stream
            // 0 / level 0), then quiesce so the hazard table starts empty.
            dev.stream(0);
            dev.fence();
            dev.enable_hazard_log();
            let mut ws = VecRegion::new(&dev, 0);
            let x = ex.solve_in(&plan, arena.as_ref(), &mut ws, &bt, mode);
            assert_eq!(x.len(), case.n, "{case}");
            dev.fence();
            let log = dev.take_hazard_log();
            let graph = verify::solve_hazard_graph(plan.solve_program(mode), dev.streams());
            assert_eq!(
                log.len(),
                graph.ops.len(),
                "{case} {mode:?}: runtime journaled a different op count than the static \
                 solve graph predicts"
            );
            // The journal's sequence numbers continue from the
            // factorization epoch; normalize to the program-local numbering
            // the static graph uses.
            let base = log.first().map(|r| r.seq).unwrap_or(0);
            for (r, s) in log.iter().zip(graph.ops.iter()) {
                assert_eq!((r.seq - base) as usize, s.seq, "{case} {mode:?}: sequence drift");
                assert_eq!(r.opcode, s.opcode, "{case} {mode:?}: opcode at seq {}", s.seq);
                assert_eq!(
                    r.stream, s.stream,
                    "{case} {mode:?}: stream at seq {} ({})",
                    s.seq, s.opcode
                );
                assert_eq!(
                    r.level, s.level,
                    "{case} {mode:?}: level at seq {} ({})",
                    s.seq, s.opcode
                );
                assert_eq!(
                    r.operands, s.operands,
                    "{case} {mode:?}: operand set at seq {} ({})",
                    s.seq, s.opcode
                );
                let deps: Vec<usize> = r.deps.iter().map(|&d| (d - base) as usize).collect();
                assert_eq!(
                    deps, s.deps,
                    "{case} {mode:?}: dependency edges at seq {} ({})",
                    s.seq, s.opcode
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// (c) The recorder's coalescing pass widens the naive chain.
// ---------------------------------------------------------------------

#[test]
fn recorder_coalesces_independent_runs_of_the_naive_chain() {
    let case = Case::fixed(512, 11);
    let plan = plan::record(&case.h2());
    let prog = plan.solve_program(SubstMode::Naive);
    let (mut launches, mut runs, mut widest) = (0usize, 0usize, 0usize);
    for step in &prog.steps {
        if let SolveInstr::TrsvFwd { items, .. } | SolveInstr::TrsvBwd { items, .. } = step {
            launches += 1;
            runs += items.len();
            widest = widest.max(items.len());
        }
    }
    assert!(
        widest > 1,
        "independent runs of the serial chain must merge into wider launches (widest = {widest})"
    );
    assert!(
        launches < runs,
        "coalescing must issue fewer TRSV launches ({launches}) than chain runs ({runs})"
    );
    // The widened program still passes every static analysis (dataflow,
    // shapes, factor-region write audit) — coalescing reorders nothing it
    // may not.
    let report = verify::verify(&plan)
        .unwrap_or_else(|v| panic!("coalesced naive program flagged by the verifier: {v}"));
    assert!(report.solve_naive.is_some(), "the naive program must be part of the report");
}

#[test]
fn coalescing_preserves_bits_across_fuzzed_structures() {
    // The coalesced naive program and the parallel program agree with the
    // serial reference backend bit-for-bit on every fuzzed structure (the
    // reference backend replays the same coalesced plan IR, so this pins
    // the pass's output against an independently computed solve).
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let native = case.solver(BackendSpec::Native);
        let serial = case.solver(BackendSpec::SerialReference);
        let b = case.rhs(3);
        let xn = native.solve_with(&b, SubstMode::Naive).expect("rhs matches").x;
        let xs = serial.solve_with(&b, SubstMode::Naive).expect("rhs matches").x;
        assert_eq!(xn, xs, "{case}: coalesced naive replay diverged across backends");
    }
}

// ---------------------------------------------------------------------
// (d) Observable solve-path overlap at the facade.
// ---------------------------------------------------------------------

#[test]
fn run_report_shows_nonzero_solve_path_overlap() {
    // Deep tree + solve_many fan-out: many independent workspaces journal
    // through one engine, so one solve's RHS transfers run while another's
    // substitution compute is in flight. Retried a few times so a loaded
    // CI runner cannot flake the assert; parity holds on every attempt.
    let case =
        Case { leaf_size: 32, max_rank: 24, eta: 1.0, rhs_count: 1, ..Case::fixed(1024, 0) };
    let asynced = case.solver(BackendSpec::async_native());
    let many: Vec<Vec<f64>> = (0..8u64).map(|k| case.rhs(k)).collect();
    for _attempt in 0..5 {
        asynced.solve_many(&many).expect("rhs lengths match");
        let report = asynced.run_report();
        assert!(report.solve_trace_events > 0, "the journaled solve path must be traced");
        if report.solve_overlapped_transfer_pairs > 0 {
            assert!(
                report.solve_overlap_ratio > 0.0,
                "paired transfer/compute intervals imply concurrent busy time"
            );
            return;
        }
        // Drain the window so the next attempt is judged on its own.
        let _ = asynced.take_solve_overlap();
    }
    panic!("no solve-path transfer/compute overlap observed in 5 solve_many fan-outs");
}
