//! The benchmark trajectory harness end to end (PR 7 acceptance):
//!
//! * [`RunReport`] from a *live* facade session round-trips through JSON
//!   byte-stably and carries real phase times / schedule counters;
//! * plan-derived counters are bit-deterministic across sessions and
//!   across full sweep re-runs — the property the trajectory comparator's
//!   strict gate rests on;
//! * scenario enumeration is a pure function of `(n, fuzz_seeds)`;
//! * the comparator flags counter regressions on real reports and stays
//!   quiet on self-comparison.

mod common;

use common::Case;
use h2ulv::bench::{self, compare::compare, BenchReport};
use h2ulv::metrics::RunReport;
use h2ulv::prelude::*;

#[test]
fn run_report_from_a_live_session_round_trips_byte_stable() {
    let case = Case::fixed(256, 11);
    let solver = case.solver(BackendSpec::Native);
    solver.solve(&case.rhs(0)).expect("rhs matches");
    let report = solver.run_report();
    assert_eq!(report.backend, "native");
    assert_eq!(report.n, 256);
    assert_eq!(report.rhs, 1);
    assert!(report.factor_launches > 0, "{}", report.render());
    assert!(report.factor_flops > 0);
    assert!(report.factor_padded_flops >= report.factor_flops);
    assert!(!report.factor_levels.is_empty());
    assert!(!report.solve_levels.is_empty());
    assert!(report.construct_time > 0.0);
    assert!(report.factor_time > 0.0);
    assert!(report.solve_time > 0.0);
    assert!(report.arena_peak_bytes >= report.arena_bytes);
    assert_eq!(report.arena_peak_bytes, report.predicted_peak_bytes);

    let text = report.to_json_string();
    let parsed = RunReport::from_json_str(&text).expect("valid schema");
    assert_eq!(parsed, report);
    assert_eq!(parsed.to_json_string(), text, "parse → re-serialize must be byte-stable");
}

#[test]
fn run_trace_spans_cover_every_phase() {
    let case = Case::fixed(256, 13);
    let solver = case.solver(BackendSpec::Native);
    solver.solve(&case.rhs(0)).expect("rhs matches");
    let trace = solver.run_trace();
    let names: Vec<&str> = trace.spans().iter().map(|s| s.name).collect();
    for phase in ["construct", "factorize", "factor-level", "factor-root", "substitution"] {
        assert!(names.contains(&phase), "missing {phase} span; got {names:?}");
    }
    assert!(trace.phase_time("substitution") > 0.0);
    // Per-level spans carry real level tags (the facade phases do not).
    assert!(trace
        .spans()
        .iter()
        .any(|s| s.name == "factor-level" && s.level != h2ulv::metrics::run_trace::NO_LEVEL));
}

#[test]
fn plan_derived_counters_are_deterministic_across_sessions() {
    let case = Case::fixed(256, 11);
    let a = case.solver(BackendSpec::Native).run_report();
    let b = case.solver(BackendSpec::Native).run_report();
    assert_eq!(a.factor_launches, b.factor_launches);
    assert_eq!(a.factor_flops, b.factor_flops);
    assert_eq!(a.factor_padded_flops, b.factor_padded_flops);
    assert_eq!(a.factor_levels, b.factor_levels);
    assert_eq!(a.solve_levels, b.solve_levels);
    assert_eq!(a.arena_bytes, b.arena_bytes);
    assert_eq!(a.arena_peak_bytes, b.arena_peak_bytes);
    assert_eq!(a.predicted_peak_bytes, b.predicted_peak_bytes);
}

#[test]
fn scenario_enumeration_is_deterministic_for_fixed_seeds() {
    let fuzz: Vec<u64> = vec![0, 1, 2, 3];
    let a = bench::scenario_matrix(256, &fuzz);
    let b = bench::scenario_matrix(256, &fuzz);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.backend, y.backend);
        assert_eq!(x.case.to_string(), y.case.to_string());
    }
    let names: std::collections::HashSet<_> = a.iter().map(|s| s.name.clone()).collect();
    assert_eq!(names.len(), a.len(), "scenario names are the comparator's join key");
}

#[test]
fn small_sweep_round_trips_and_re_runs_counter_identical() {
    // One (distribution, kernel, width) cell across all three backends:
    // small enough for the default suite, wide enough to exercise the
    // sweep → serialize → parse → compare pipeline end to end.
    let scenarios =
        bench::filter_scenarios(bench::scenario_matrix(128, &[]), "sphere-laplace/rhs1");
    assert_eq!(scenarios.len(), 3, "one scenario per backend");
    let report = BenchReport::collect(128, &scenarios).expect("sweep runs");
    assert_eq!(report.scenarios.len(), 3);
    for s in &report.scenarios {
        assert!(s.run.factor_launches > 0, "{}", s.name);
        assert_eq!(s.run.rhs, 1, "{}", s.name);
    }

    let text = report.to_json_string();
    let parsed = BenchReport::from_json_str(&text).expect("valid schema");
    assert_eq!(parsed, report);
    assert_eq!(parsed.to_json_string(), text);

    // Self-comparison is silent; a re-run differs only in wall times.
    let cmp = compare(&parsed, &report, 0.0);
    assert!(cmp.deltas.is_empty() && !cmp.has_regressions());
    let rerun = BenchReport::collect(128, &scenarios).expect("sweep runs");
    let cmp = compare(&report, &rerun, 0.0);
    assert!(!cmp.has_regressions(), "counters drifted across re-runs:\n{}", cmp.render());
    assert!(
        cmp.deltas.iter().all(|d| d.class == bench::compare::MetricClass::Time),
        "non-time delta across identical re-runs:\n{}",
        cmp.render()
    );
}

#[test]
fn comparator_gates_counter_regressions_on_real_reports() {
    let scenarios =
        bench::filter_scenarios(bench::scenario_matrix(128, &[]), "serial/sphere-laplace/rhs1");
    assert_eq!(scenarios.len(), 1);
    let baseline = BenchReport::collect(128, &scenarios).expect("sweep runs");
    let mut worse = baseline.clone();
    worse.scenarios[0].run.arena_peak_bytes += 1;
    let cmp = compare(&baseline, &worse, 0.0);
    assert!(cmp.has_regressions());
    assert_eq!(cmp.regressions()[0].metric, "arena_peak_bytes");
    // The reverse direction (shrinking peak) reports but does not gate.
    let cmp = compare(&worse, &baseline, 0.0);
    assert!(!cmp.has_regressions());
    assert_eq!(cmp.deltas.len(), 1);
}

#[test]
fn wide_rhs_scenarios_report_the_full_width() {
    let scenarios =
        bench::filter_scenarios(bench::scenario_matrix(128, &[]), "serial/sphere-laplace/rhs8");
    assert_eq!(scenarios.len(), 1);
    let rep = bench::run_scenario(&scenarios[0]).expect("scenario runs");
    assert_eq!(rep.run.rhs, 8);
    assert!(rep.run.solve_time > 0.0);
}

#[test]
fn clustered_bench_cases_build_and_solve() {
    // The non-uniform regime of the matrix actually factorizes: bounded
    // kernel (gaussian) + clustered blobs stay inside the SPD envelope.
    let case = Case {
        kernel: "gaussian",
        distribution: common::Distribution::Clustered { clusters: 4 },
        ..Case::fixed(192, 17)
    };
    let solver = case.solver(BackendSpec::Native);
    let rep = solver.solve(&case.rhs(0)).expect("clustered gaussian solves");
    assert_eq!(rep.x.len(), 192);
    let run = solver.run_report();
    assert!(run.factor_launches > 0);
}
