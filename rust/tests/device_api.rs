//! Device-trait API invariants (ISSUE 3 acceptance):
//!
//! * native/serial (and, when the XLA runtime is linked, PJRT-fallback)
//!   parity through the arena-native `Device` trait;
//! * arena alloc/free balance: after a factorization replay exactly the
//!   factor's resident buffers are live, and every solve replay returns
//!   the arena to that state (no leaked `BufferId`s);
//! * replays stay bit-identical (the PR 2 `plan_replay.rs` baselines) and
//!   `rebind_backend` round-trips the arena across backends to 1e-12;
//! * the naive substitution program records lazily on first use;
//! * `BackendSpec::by_name` accepts `pjrt:<artifacts_dir>`.

mod common;

use common::{rhs, seeds, Case};
use h2ulv::batch::device::{ValidatingDevice, WorkspacePool};
use h2ulv::batch::native::NativeBackend;
use h2ulv::construct::H2Config;
use h2ulv::geometry::Geometry;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::plan::Executor;
use h2ulv::prelude::*;
use h2ulv::solver::backend::SerialBackend;
use h2ulv::ulv::{factorize, SubstMode};
use std::sync::Arc;

fn cfg() -> H2Config {
    Case::fixed(0, 0).config()
}

fn build_h2(n: usize, seed: u64) -> H2Matrix {
    Case::fixed(n, seed).h2()
}

#[test]
fn device_native_serial_parity_through_trait() {
    let h2 = build_h2(512, 401);
    let native = NativeBackend::new();
    let serial = SerialBackend;
    let fac_n = factorize(&h2, &native);
    let fac_s = h2ulv::ulv::factorize_with_plan(&h2, &serial, fac_n.plan.clone());
    // The serial reference runs the same scalar kernels sequentially, so
    // the factor data must agree bit-for-bit with the thread-pool path.
    assert_eq!(fac_n.root_l.as_slice(), fac_s.root_l.as_slice());
    for (ln, ls) in fac_n.levels.iter().zip(&fac_s.levels) {
        for (a, b) in ln.chol_rr.iter().zip(&ls.chol_rr) {
            assert_eq!(a.as_slice(), b.as_slice(), "chol_rr diverged at level {}", ln.level);
        }
        for (k, m) in &ln.lr {
            assert_eq!(m.as_slice(), ls.lr[k].as_slice());
        }
        for (k, m) in &ln.ls {
            assert_eq!(m.as_slice(), ls.ls[k].as_slice());
        }
    }
    let b = rhs(512, 1);
    let bt = h2.tree.permute_vec(&b);
    for mode in [SubstMode::Parallel, SubstMode::Naive] {
        let xn = fac_n.solve_tree_order(&bt, &native, mode);
        let xs = fac_s.solve_tree_order(&bt, &serial, mode);
        let err = rel_err_vec(&xs, &xn);
        assert!(err < 1e-12, "{mode:?}: serial diverged from native: {err}");
    }
}

#[test]
fn device_arena_alloc_free_balance() {
    let h2 = build_h2(384, 403);
    let plan = Arc::new(h2ulv::plan::record(&h2));
    let be = NativeBackend::new();
    let (fac, arena) = Executor::new(&be).factorize_resident(&plan, &h2);
    // After the factorization replay exactly the factor's resident
    // buffers (outputs + bases + root) are live — no leaked BufferIds.
    let expected = plan.factor.resident_bufs().len();
    assert_eq!(
        arena.live(),
        expected,
        "factorization must free every temporary buffer"
    );
    // Every solve replay allocates its vector region in a pooled
    // workspace and empties it again; the factor region is never touched.
    let b = rhs(384, 3);
    let bt = h2.tree.permute_vec(&b);
    let exec = Executor::new(&be);
    let pool = WorkspacePool::new();
    for mode in [SubstMode::Parallel, SubstMode::Naive, SubstMode::Parallel] {
        let mut ws = pool.acquire(&be);
        let x = exec.solve_in(&plan, arena.as_ref(), ws.region(), &bt, mode);
        assert_eq!(x.len(), 384);
        assert_eq!(arena.live(), expected, "{mode:?}: solve touched the factor region");
        assert_eq!(ws.region().live(), 0, "{mode:?}: solve leaked vector buffers");
    }
    assert_eq!(pool.created(), 1, "sequential solves must reuse one region");
    assert_eq!(pool.idle(), 1, "the region must be back in the pool");
    // Resident-region solves bit-match the transient-upload path.
    let mut ws = pool.acquire(&be);
    let x_resident = exec.solve_in(&plan, arena.as_ref(), ws.region(), &bt, SubstMode::Parallel);
    let x_transient = fac.solve_tree_order(&bt, &be, SubstMode::Parallel);
    assert_eq!(x_resident, x_transient, "residency must not change the numerics");
}

#[test]
fn device_panicking_solve_returns_region_to_pool() {
    // The unwind guard contract (workspace-pooled edition): a panicking
    // launch empties the workspace via a region *reset* — not a drop — so
    // the region returns to its pool and the pool never shrinks, and the
    // shared factor region keeps its exact live-buffer balance.
    let h2 = build_h2(256, 431);
    let plan = Arc::new(h2ulv::plan::record(&h2));
    let be = NativeBackend::new();
    let (_fac, mut arena) = Executor::new(&be).factorize_resident(&plan, &h2);
    let expected = plan.factor.resident_bufs().len();
    assert_eq!(arena.live(), expected);
    // Sabotage: free one resident basis buffer so the substitution's
    // ApplyBasis launch panics ("read before upload") mid-program.
    let victim = plan.factor.outputs[0].basis[0];
    arena.free(victim);
    let bt = h2.tree.permute_vec(&rhs(256, 19));
    let pool = WorkspacePool::new();
    let exec = Executor::new(&be);
    {
        let mut ws = pool.acquire(&be);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.solve_in(&plan, arena.as_ref(), ws.region(), &bt, SubstMode::Parallel)
        }));
        assert!(result.is_err(), "solve against a freed basis buffer must panic");
        // The guard reset the region before re-raising: live balance is 0.
        assert_eq!(ws.region().live(), 0, "panicking solve leaked vector buffers");
    }
    // RAII returned the (reset) region: full capacity, nothing leaked.
    assert_eq!(pool.created(), 1);
    assert_eq!(pool.idle(), 1, "panicking solve must return its region to the pool");
    assert_eq!(arena.live(), expected - 1, "factor region balance must be untouched");
    // The pool still serves solves after repair.
    arena.upload(victim, &h2.bases[plan.factor.outputs[0].level][0].u);
    let mut ws = pool.acquire(&be);
    let x = exec.solve_in(&plan, arena.as_ref(), ws.region(), &bt, SubstMode::Parallel);
    assert_eq!(x.len(), 256);
    assert_eq!(pool.created(), 1, "recovery must reuse the recycled region");
}

#[test]
fn device_replay_bit_identical_baseline() {
    // The PR 2 plan_replay baselines, through the Device interface: two
    // replays of the same plan on the same backend are bit-identical.
    let h2 = build_h2(512, 405);
    let be = NativeBackend::new();
    let fac1 = factorize(&h2, &be);
    let fac2 = h2ulv::ulv::factorize_with_plan(&h2, &be, fac1.plan.clone());
    assert_eq!(fac1.root_l.as_slice(), fac2.root_l.as_slice());
    let bt = h2.tree.permute_vec(&rhs(512, 5));
    for mode in [SubstMode::Parallel, SubstMode::Naive] {
        let x1 = fac1.solve_tree_order(&bt, &be, mode);
        let x2 = fac2.solve_tree_order(&bt, &be, mode);
        assert_eq!(x1, x2, "{mode:?}: replay must be bit-deterministic");
    }
}

#[test]
fn device_lazy_naive_program_records_on_demand() {
    let h2 = build_h2(256, 407);
    let be = NativeBackend::new();
    let fac = factorize(&h2, &be);
    assert!(
        !fac.plan.naive_recorded(),
        "naive program must not be recorded at factorization time"
    );
    let bt = h2.tree.permute_vec(&rhs(256, 7));
    let _ = fac.solve_tree_order(&bt, &be, SubstMode::Parallel);
    assert!(
        !fac.plan.naive_recorded(),
        "a Parallel solve must not trigger the naive recording"
    );
    let x_naive = fac.solve_tree_order(&bt, &be, SubstMode::Naive);
    assert!(fac.plan.naive_recorded(), "first Naive solve records the program");
    let x_par = fac.solve_tree_order(&bt, &be, SubstMode::Parallel);
    let err = rel_err_vec(&x_naive, &x_par);
    assert!(err < 1e-3, "lazily recorded naive program diverged: {err}");
}

#[test]
fn device_rebind_backend_roundtrips_arena() {
    let case = Case::fixed(512, 409);
    let mut solver = H2SolverBuilder::new(case.geometry(), KernelFn::laplace())
        .config(case.config())
        .residual_samples(0)
        .build()
        .expect("well-formed problem");
    let b = rhs(512, 11);
    let x_native = solver.solve(&b).expect("rhs matches").x;
    // Rebind to serial: the plan replay re-materializes the arena on the
    // new device; results must round-trip to 1e-12.
    solver.rebind_backend(BackendSpec::SerialReference).expect("serial always available");
    assert_eq!(solver.backend_name(), "serial");
    let x_serial = solver.solve(&b).expect("rhs matches").x;
    let err = rel_err_vec(&x_serial, &x_native);
    assert!(err < 1e-12, "serial rebind diverged: {err}");
    // And back to native: bit-identical to the first pass (same plan,
    // same kernels, fresh arena).
    solver.rebind_backend(BackendSpec::Native).expect("native always available");
    let x_back = solver.solve(&b).expect("rhs matches").x;
    assert_eq!(x_back, x_native, "native→serial→native must round-trip exactly");
}

#[test]
fn device_backend_spec_pjrt_artifact_dir() {
    // `pjrt:<dir>` parses into a Pjrt spec pointing at the directory.
    let spec = BackendSpec::by_name("pjrt:some/dir").expect("valid spec");
    assert_eq!(
        spec,
        BackendSpec::Pjrt { artifacts_dir: std::path::PathBuf::from("some/dir") }
    );
    assert_eq!(BackendSpec::by_name("pjrt:"), None);
    // Rebinding a live session to an unavailable PJRT directory is a typed
    // error and leaves the session fully usable on its original backend.
    let g = Geometry::sphere_surface(256, 411);
    let mut solver = H2SolverBuilder::new(g, KernelFn::laplace())
        .config(H2Config { leaf_size: 32, max_rank: 24, ..Default::default() })
        .residual_samples(0)
        .build()
        .expect("well-formed problem");
    let b = rhs(256, 13);
    let x_before = solver.solve(&b).expect("rhs matches").x;
    let err = solver
        .rebind_backend(BackendSpec::by_name("pjrt:definitely/not/a/dir").unwrap())
        .expect_err("missing artifacts dir must fail");
    assert!(matches!(err, H2Error::BackendUnavailable { .. }), "{err:?}");
    assert_eq!(solver.backend_name(), "native", "failed rebind must not switch backends");
    let x_after = solver.solve(&b).expect("session must stay usable").x;
    assert_eq!(x_before, x_after);
}

#[test]
fn device_pjrt_fallback_parity() {
    // With an empty manifest every shape-family lookup misses, so a PJRT
    // device would route every launch through its native fallback kernels
    // — results must match the native device exactly. In the offline
    // container the XLA stub reports the runtime unavailable, which is the
    // documented BackendUnavailable path; the parity body runs wherever
    // the real bindings are linked.
    let dir = std::env::temp_dir().join("h2ulv_device_api_empty_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": []}"#).unwrap();
    let be = match h2ulv::runtime::PjrtBackend::new(&dir) {
        Err(e) => {
            let msg = e.to_string();
            assert!(
                msg.contains("not available") || msg.contains("manifest"),
                "unexpected PJRT failure: {msg}"
            );
            return;
        }
        Ok(be) => be,
    };
    let h2 = build_h2(256, 413);
    let native = NativeBackend::new();
    let fac_n = factorize(&h2, &native);
    let fac_p = h2ulv::ulv::factorize_with_plan(&h2, &be, fac_n.plan.clone());
    assert_eq!(fac_n.root_l.as_slice(), fac_p.root_l.as_slice());
    let bt = h2.tree.permute_vec(&rhs(256, 17));
    let xn = fac_n.solve_tree_order(&bt, &native, SubstMode::Parallel);
    let xp = fac_p.solve_tree_order(&bt, &be, SubstMode::Parallel);
    assert_eq!(xn, xp, "all-fallback PJRT must be bit-identical to native");
    assert!(be.stats.fallbacks.load(std::sync::atomic::Ordering::Relaxed) > 0);
}

#[test]
fn device_validating_wrapper_passes_full_plan_suite() {
    // Every launch of the recorded factorization and of both substitution
    // programs satisfies the hazard-audit invariants (operands live, no
    // out-of-range ids, no intra-launch write aliasing) — and the audited
    // execution is bit-identical to the bare backend.
    let h2 = build_h2(384, 421);
    let vdev = ValidatingDevice::new(NativeBackend::new());
    let bare = NativeBackend::new();
    let fac_v = factorize(&h2, &vdev);
    let fac_b = h2ulv::ulv::factorize_with_plan(&h2, &bare, fac_v.plan.clone());
    assert_eq!(fac_v.root_l.as_slice(), fac_b.root_l.as_slice());
    let bt = h2.tree.permute_vec(&rhs(384, 23));
    for mode in [SubstMode::Parallel, SubstMode::Naive] {
        let xv = fac_v.solve_tree_order(&bt, &vdev, mode);
        let xb = fac_b.solve_tree_order(&bt, &bare, mode);
        assert_eq!(xv, xb, "{mode:?}: audit wrapper must not change results");
    }
    assert!(vdev.audited() > 0, "the audit must have seen every launch");
}

#[test]
fn device_validating_wrapper_passes_fuzzed_structures() {
    // The audit holds across randomized structures (depth, leaf size,
    // ranks, admissibility), not just the fixed fixture.
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let h2 = case.h2();
        let vdev = ValidatingDevice::new(NativeBackend::new());
        let fac = factorize(&h2, &vdev);
        let bt = h2.tree.permute_vec(&case.rhs(0));
        for mode in [SubstMode::Parallel, SubstMode::Naive] {
            let x = fac.solve_tree_order(&bt, &vdev, mode);
            assert_eq!(x.len(), case.n, "solve failed for {case}");
        }
        assert!(vdev.audited() > 0, "no launches audited for {case}");
    }
}
