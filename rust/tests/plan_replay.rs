//! Plan record/replay invariants (ISSUE 2 acceptance):
//!
//! * a recorded plan replayed twice is bit-identical, and the replayed
//!   solve matches the dense oracle exactly where the eager path did;
//! * replaying a cached plan after a refactorization with perturbed kernel
//!   values matches a freshly recorded factorization;
//! * `rebind_backend(SerialReference)` matches native to 1e-12;
//! * `refactorize` (same structure), `solve_many`, and `rebind_backend`
//!   never re-plan — launch counts come from the one cached plan.

mod common;

use common::{rhs, Case};
use h2ulv::batch::native::NativeBackend;
use h2ulv::construct::H2Config;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::prelude::*;
use h2ulv::ulv::{factorize, factorize_with_plan, SubstMode};

fn cfg() -> H2Config {
    Case::fixed(0, 0).config()
}

#[test]
fn recorded_plan_replays_bit_identically_and_matches_eager_accuracy() {
    let case = Case::fixed(512, 201);
    let k = KernelFn::laplace();
    let h2 = case.h2();
    let be = NativeBackend::new();
    let fac = factorize(&h2, &be);
    let b = rhs(512, 1);
    let bt = h2.tree.permute_vec(&b);
    // Replay #1 and #2 of the same recorded substitution program are
    // bit-identical (the plan fixes launch order and batch grouping).
    for mode in [SubstMode::Parallel, SubstMode::Naive] {
        let x1 = fac.solve_tree_order(&bt, &be, mode);
        let x2 = fac.solve_tree_order(&bt, &be, mode);
        assert_eq!(x1, x2, "{mode:?}: replay must be bit-deterministic");
    }
    // A second factorization replayed from the same plan bit-matches.
    let fac2 = factorize_with_plan(&h2, &be, fac.plan.clone());
    assert_eq!(fac.root_l.as_slice(), fac2.root_l.as_slice());
    let x1 = fac.solve_tree_order(&bt, &be, SubstMode::Parallel);
    let x2 = fac2.solve_tree_order(&bt, &be, SubstMode::Parallel);
    assert_eq!(x1, x2);
    // Accuracy is unchanged from the eager implementation: the replayed
    // solve still inverts the problem to the H² approximation floor.
    let a = k.dense(&h2.tree.points);
    let want = h2ulv::linalg::lu::solve(&a, &bt).unwrap();
    let err = rel_err_vec(&x1, &want);
    assert!(err < 1e-3, "replayed solve accuracy regressed: {err}");
}

#[test]
fn replay_after_kernel_perturbation_matches_fresh_factorization() {
    // The plan is purely structural: record it from one H² matrix, then
    // replay it against a matrix with *perturbed kernel values* (same
    // geometry/config => same tree, lists, and ranks). The replayed factor
    // must match a freshly planned factorization of the perturbed matrix.
    let g = Case::fixed(384, 203).geometry();
    let be = NativeBackend::new();
    let h2_a = H2Matrix::construct(&g, &KernelFn::laplace(), &cfg());
    let fac_a = factorize(&h2_a, &be);

    let perturbed = KernelFn { diag: 1.0e3, phi: |r| 1.0002 / r, name: "laplace-pert" };
    let h2_b = H2Matrix::construct(&g, &perturbed, &cfg());
    assert!(
        fac_a.plan.compatible(&h2_b),
        "kernel-value perturbation must not change the plan structure"
    );

    let fac_replay = factorize_with_plan(&h2_b, &be, fac_a.plan.clone());
    let fac_fresh = factorize(&h2_b, &be);
    let b = rhs(384, 7);
    let bt = h2_b.tree.permute_vec(&b);
    let x_replay = fac_replay.solve_tree_order(&bt, &be, SubstMode::Parallel);
    let x_fresh = fac_fresh.solve_tree_order(&bt, &be, SubstMode::Parallel);
    let err = rel_err_vec(&x_replay, &x_fresh);
    assert!(err < 1e-12, "replayed factorization diverged from fresh: {err}");
    // And the replayed factor genuinely reflects the perturbed values.
    let x_old = fac_a.solve_tree_order(&bt, &be, SubstMode::Parallel);
    assert!(rel_err_vec(&x_replay, &x_old) > 1e-8, "replay must use the new matrix values");
}

#[test]
fn refactorize_reuses_cached_plan_and_rebind_matches_native() {
    let mut solver = Case::fixed(512, 205).solver(BackendSpec::Native);
    assert_eq!(solver.plan_recordings(), 1);
    let launches = solver.stats().schedule.factor_launches();
    assert!(launches > 0);
    let b = rhs(512, 11);
    let x_native = solver.solve(&b).expect("rhs matches").x;

    // Multi-RHS solves replay the cached substitution program.
    let reports = solver.solve_many(&[b.clone(), rhs(512, 13)]).expect("rhs match");
    assert_eq!(reports.len(), 2);
    assert_eq!(solver.plan_recordings(), 1, "solve_many must not re-plan");

    // Refactorize with the same structure: plan replayed, not re-recorded.
    solver.refactorize(cfg()).expect("refactorize");
    assert_eq!(solver.plan_recordings(), 1, "same-structure refactorize must not re-plan");
    assert_eq!(
        solver.stats().schedule.factor_launches(),
        launches,
        "launch counts must come from the one cached plan"
    );
    let x_refac = solver.solve(&b).expect("rhs matches").x;
    let err = rel_err_vec(&x_refac, &x_native);
    assert!(err < 1e-12, "same-structure refactorize changed the solution: {err}");

    // Rebind to the serial reference backend: same plan, same launches,
    // results match native to 1e-12 (bit-identical kernels).
    solver.rebind_backend(BackendSpec::SerialReference).expect("serial always available");
    assert_eq!(solver.backend_name(), "serial");
    assert_eq!(solver.plan_recordings(), 1, "rebind_backend must not re-plan");
    assert_eq!(solver.stats().schedule.factor_launches(), launches);
    assert_eq!(solver.stats().construct_time, 0.0, "rebind must not rebuild H2");
    let x_serial = solver.solve(&b).expect("rhs matches").x;
    let err = rel_err_vec(&x_serial, &x_native);
    assert!(err < 1e-12, "serial rebind diverged from native: {err}");

    // A structure-changing refactorize records a fresh plan.
    solver
        .refactorize(H2Config { leaf_size: 32, max_rank: 16, ..cfg() })
        .expect("refactorize");
    assert_eq!(solver.plan_recordings(), 2, "structure change must re-plan");
}

#[test]
fn per_call_residual_override() {
    let g = Case::fixed(256, 207).geometry();
    let solver = H2SolverBuilder::new(g, KernelFn::laplace())
        .config(H2Config { leaf_size: 32, max_rank: 24, ..Default::default() })
        .residual_samples(64)
        .build()
        .expect("well-formed problem");
    let b = rhs(256, 17);
    // Builder default: sampled residual present.
    assert!(solver.solve(&b).unwrap().residual.is_some());
    // Per-call skip.
    let rep = solver.solve_opts(&b, &SolveOptions::no_residual()).unwrap();
    assert!(rep.residual.is_none());
    // Per-call force on a sampling-disabled session.
    let g2 = Case::fixed(256, 207).geometry();
    let quiet = H2SolverBuilder::new(g2, KernelFn::laplace())
        .config(H2Config { leaf_size: 32, max_rank: 24, ..Default::default() })
        .residual_samples(0)
        .build()
        .expect("well-formed problem");
    assert!(quiet.solve(&b).unwrap().residual.is_none());
    let forced = quiet
        .solve_opts(
            &b,
            &SolveOptions { sample_residual: Some(true), ..Default::default() },
        )
        .unwrap();
    assert!(forced.residual.is_some());
}
