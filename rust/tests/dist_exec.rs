//! Integration tests for the real multi-rank SPMD runtime
//! ([`h2ulv::dist::exec`]): P-rank `solve_dist` parity with the
//! single-process facade solve, comm instructions visible in carved plans,
//! the cross-rank static audit (positive fuzz sweep plus pinned negative
//! violations), and the modeled-vs-measured communication report.

mod common;

use common::{seeds, Case};
use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::plan::verify::{verify_carved, verify_rank_set, ViolationKind};
use h2ulv::plan::{carve, record, render_comm, BufferId, Instr, PlanSig};
use h2ulv::prelude::*;
use h2ulv::util::Rng;

const N: usize = 256;

fn rhs(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

/// N=256 at leaf 48 gives a depth-3 tree (8 leaves) — deep enough to carve
/// for 4 ranks with both distributed and redundant (merged) levels.
fn build() -> H2Solver {
    let cfg = H2Config { leaf_size: 48, max_rank: 24, far_samples: 0, ..Default::default() };
    H2SolverBuilder::new(Geometry::sphere_surface(N, 977), KernelFn::laplace())
        .config(cfg)
        .build()
        .expect("well-formed problem")
}

#[test]
fn spmd_solve_matches_single_process_for_p2_and_p4() {
    let solver = build();
    let b = rhs(N, 11);
    let serial = solver.solve(&b).unwrap();
    for p in [2usize, 4] {
        let dist = solver.solve_dist(&b, p).unwrap();
        assert_eq!(dist.ranks, p);
        let err = rel_err_vec(&dist.x, &serial.x);
        assert!(err < 1e-12, "P={p}: SPMD solve diverged from single-process: {err}");
        // Real communication happened: the thread-transport measured it.
        assert!(dist.measured.factor.exchanges > 0, "P={p}: no factor collectives measured");
        assert!(dist.measured.subst.exchanges > 0, "P={p}: no subst collectives measured");
        assert!(dist.measured.factor.bytes > 0 && dist.measured.subst.bytes > 0);
    }
}

#[test]
fn repeated_spmd_solves_are_bitwise_deterministic() {
    // The carved replay is deterministic per rank and the rendezvous is a
    // full barrier, so re-running the same solve on the cached session must
    // reproduce the solution bit for bit.
    let solver = build();
    let b = rhs(N, 13);
    let first = solver.solve_dist(&b, 4).unwrap();
    let second = solver.solve_dist(&b, 4).unwrap();
    assert_eq!(first.x, second.x, "SPMD solve is not deterministic");
}

#[test]
fn modeled_and_measured_comm_are_reported_side_by_side() {
    // The α-β model stays a *prediction*; the transport reports the
    // *measurement*. Both must be populated for P > 1 — no tolerance gate
    // between them (the model is a machine abstraction, not a stopwatch).
    let solver = build();
    let b = rhs(N, 17);
    let dist = solver.solve_dist(&b, 4).unwrap();
    assert!(dist.factor_bytes > 0 && dist.subst_bytes > 0, "modeled comm volume missing");
    assert!(dist.factor_time > 0.0 && dist.subst_time > 0.0, "modeled times missing");
    let m = &dist.measured;
    assert!(m.factor.exchanges > 0 && m.factor.bytes > 0, "measured factor comm missing");
    assert!(m.subst.exchanges > 0 && m.subst.bytes > 0, "measured subst comm missing");
    assert!(m.factor.seconds >= 0.0 && m.subst.seconds >= 0.0);

    // Single rank: no communication on either side of the report.
    let single = solver.solve_dist(&b, 1).unwrap();
    assert_eq!(single.factor_bytes, 0);
    assert_eq!(single.subst_bytes, 0);
    assert_eq!(single.measured.factor.exchanges, 0);
    assert_eq!(single.measured.subst.bytes, 0);
}

#[test]
fn carved_plans_expose_comm_instructions() {
    let solver = build();
    let plan = record(solver.matrix());
    let rps = carve(&plan, 4, SubstMode::Parallel);
    assert_eq!(rps.len(), 4);
    for rp in &rps {
        let exchanges = rp
            .factor
            .prologue
            .iter()
            .chain(rp.factor.levels.iter().flat_map(|lp| lp.steps.iter()))
            .filter(|i| matches!(i, Instr::Exchange { .. }))
            .count();
        assert!(exchanges > 0, "rank {}: no Exchange instructions in carved factor", rp.rank);
    }
    let rendered = render_comm(&rps);
    assert!(rendered.contains("factor exchange"), "comm schedule not rendered:\n{rendered}");
    assert!(rendered.contains("B delivered"), "comm schedule lacks byte counts:\n{rendered}");
}

#[test]
fn rank_set_audit_passes_over_fuzzed_structures() {
    // Positive sweep: every fuzzed structure must carve into a rank set the
    // cross-rank static audit accepts, for both group sizes the CI smoke
    // job runs.
    for seed in seeds() {
        let case = Case::from_seed(seed);
        let h2 = case.h2();
        let plan = record(&h2);
        for p in [2usize, 4] {
            let report = verify_carved(&plan, p, SubstMode::Parallel)
                .unwrap_or_else(|v| panic!("{case}: P={p} rank-set audit failed: {v}"));
            if report.ranks > 1 {
                assert!(
                    report.factor_collectives > 0,
                    "{case}: P={} carved with no factor collectives",
                    report.ranks
                );
            }
        }
    }
}

#[test]
fn send_of_undefined_buffer_is_use_before_def() {
    let solver = build();
    let plan = record(solver.matrix());
    let sig = PlanSig::of(solver.matrix());
    let mut rps = carve(&plan, 2, SubstMode::Parallel);
    // Post a send of a buffer nothing has defined yet: first prologue slot,
    // before the uploads run.
    let depth = rps[0].depth;
    rps[0].factor.prologue.insert(
        0,
        Instr::Exchange { level: depth, sends: vec![BufferId(0)], recvs: Vec::new() },
    );
    let v = verify_rank_set(&rps, &sig).expect_err("undefined send must not verify");
    assert_eq!(v.kind, ViolationKind::UseBeforeDef, "got {v}");
    assert_eq!(v.opcode, "EXCHANGE");
    assert_eq!(v.buffer, Some(BufferId(0)));
}

#[test]
fn recv_without_peer_send_is_unmatched_comm() {
    let solver = build();
    let plan = record(solver.matrix());
    let sig = PlanSig::of(solver.matrix());
    let mut rps = carve(&plan, 2, SubstMode::Parallel);
    // Drop every send rank 1 posts in its first factor collective. Rank 1's
    // own dataflow stays legal (sends are reads), but its peer still
    // expects the buffers — the audit must flag the now-orphaned receive.
    let f = &mut rps[1].factor;
    let mutated = f
        .prologue
        .iter_mut()
        .chain(f.levels.iter_mut().flat_map(|lp| lp.steps.iter_mut()))
        .find_map(|i| match i {
            Instr::Exchange { sends, .. } if !sends.is_empty() => {
                sends.clear();
                Some(())
            }
            _ => None,
        });
    assert!(mutated.is_some(), "rank 1 posts no factor sends to drop");
    let v = verify_rank_set(&rps, &sig).expect_err("orphaned receive must not verify");
    assert_eq!(v.kind, ViolationKind::UnmatchedComm, "got {v}");
    assert_eq!(v.opcode, "EXCHANGE");
}

#[test]
fn duplicate_free_across_carved_stream_is_double_free() {
    let solver = build();
    let plan = record(solver.matrix());
    let sig = PlanSig::of(solver.matrix());
    let mut rps = carve(&plan, 2, SubstMode::Parallel);
    // Free the root factor twice at the end of rank 0's coarsest level: the
    // second Free must be pinned as a DoubleFree (not be reported as the
    // later residency violation the first Free also causes).
    let root = rps[0].factor.root_src;
    let last = rps[0].factor.levels.last_mut().expect("carved plan has levels");
    last.steps.push(Instr::Free { bufs: vec![root] });
    last.steps.push(Instr::Free { bufs: vec![root] });
    let v = verify_rank_set(&rps, &sig).expect_err("double free must not verify");
    assert_eq!(v.kind, ViolationKind::DoubleFree, "got {v}");
    assert_eq!(v.opcode, "FREE");
    assert_eq!(v.buffer, Some(root));
}
