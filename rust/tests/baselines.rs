//! Cross-solver integration: H²-ULV vs dense vs BLR vs HSS on the same
//! problems — the comparisons behind paper Figures 18-20.

use h2ulv::baselines::blr::{BlrConfig, BlrMatrix};
use h2ulv::baselines::dense::DenseSolver;
use h2ulv::batch::native::NativeBackend;
use h2ulv::construct::H2Config;
use h2ulv::geometry::Geometry;
use h2ulv::h2::H2Matrix;
use h2ulv::kernels::KernelFn;
use h2ulv::linalg::norms::rel_err_vec;
use h2ulv::metrics::flops;
use h2ulv::tree::ClusterTree;
use h2ulv::ulv::{factorize, SubstMode};
use h2ulv::util::Rng;

#[test]
fn all_solvers_agree_on_laplace_sphere() {
    let n = 512;
    let g = Geometry::sphere_surface(n, 601);
    let kern = KernelFn::laplace();
    let mut rng = Rng::new(1);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    // Oracle.
    let dense = DenseSolver::factorize(&g.points, &kern).unwrap();
    let x_dense = dense.solve(&b);

    // H2-ULV.
    let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 0, ..Default::default() };
    let h2 = H2Matrix::construct(&g, &kern, &cfg);
    let fac = factorize(&h2, &NativeBackend::new());
    let x_h2 = fac.solve(&b, &NativeBackend::new(), SubstMode::Parallel);
    assert!(rel_err_vec(&x_h2, &x_dense) < 2e-3);

    // BLR (needs the tree ordering; solve in tree coordinates).
    let tree = ClusterTree::build(&g, 128);
    let mut blr = BlrMatrix::build(&tree.points, &kern, &BlrConfig { rtol: 1e-9, ..Default::default() });
    blr.factorize();
    let bt = tree.permute_vec(&b);
    let xt = blr.solve(&bt);
    let x_blr = tree.unpermute_vec(&xt);
    assert!(rel_err_vec(&x_blr, &x_dense) < 1e-4);
}

#[test]
fn h2_beats_hss_in_accuracy_at_equal_rank() {
    // Paper Figure 18's claim: at equal rank the H² (strong admissibility)
    // solve is more accurate than HSS (eta = 0), because HSS is forced to
    // compress touching blocks. Our separation is a consistent 2-4x rather
    // than the paper's orders of magnitude (different ID details and
    // smaller N — see EXPERIMENTS.md fig 18); the ordering is what we
    // assert here, across two ranks.
    let n = 2048;
    let g = Geometry::sphere_surface(n, 603);
    let kern = KernelFn::laplace();
    let mut rng = Rng::new(3);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let dense = DenseSolver::factorize(&g.points, &kern).unwrap();
    let x_dense = dense.solve(&b);

    for rank in [48usize, 96] {
        let mut errs = Vec::new();
        for eta in [1.0, 0.0] {
            let cfg = H2Config {
                leaf_size: 256,
                max_rank: rank,
                far_samples: 0,
                near_samples: 0,
                eta,
                ..Default::default()
            };
            let h2 = H2Matrix::construct(&g, &kern, &cfg);
            let fac = factorize(&h2, &NativeBackend::new());
            let x = fac.solve(&b, &NativeBackend::new(), SubstMode::Parallel);
            errs.push(rel_err_vec(&x, &x_dense));
        }
        assert!(
            errs[0] < 0.8 * errs[1],
            "rank {rank}: H2 ({}) must beat HSS ({}) at equal rank",
            errs[0],
            errs[1]
        );
    }
}

#[test]
fn h2_factorization_flops_scale_better_than_blr() {
    // Paper Figure 20's complexity story: BLR is O(N²), H²-ULV is ~O(N).
    let kern = KernelFn::laplace();
    let mut h2_flops = Vec::new();
    let mut blr_flops = Vec::new();
    for n in [1024usize, 2048] {
        let g = Geometry::sphere_surface(n, 605);
        let cfg = H2Config { leaf_size: 64, max_rank: 24, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &kern, &cfg);
        let h2_scope = flops::FlopScope::new();
        let _fac = flops::scoped(&h2_scope, flops::Phase::Factor, || {
            factorize(&h2, &NativeBackend::new())
        });
        h2_flops.push(h2_scope.snapshot().factor as f64);

        let tree = ClusterTree::build(&g, 128);
        let mut blr = BlrMatrix::build(&tree.points, &kern, &BlrConfig::default());
        let blr_scope = flops::FlopScope::new();
        flops::scoped(&blr_scope, flops::Phase::Factor, || blr.factorize());
        blr_flops.push(blr_scope.snapshot().factor as f64);
    }
    let h2_ratio = h2_flops[1] / h2_flops[0];
    let blr_ratio = blr_flops[1] / blr_flops[0];
    assert!(
        h2_ratio < blr_ratio,
        "H2 growth {h2_ratio} must beat BLR growth {blr_ratio}"
    );
    assert!(h2_ratio < 3.0, "H2 should be near-linear, got {h2_ratio}");
}
