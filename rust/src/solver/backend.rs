//! Backend selection for the solver facade, plus the serial reference
//! backend.
//!
//! [`BackendSpec`] is the *description* of an execution engine; the facade
//! instantiates it once at `build()` time and owns the resulting boxed
//! [`Device`], so no concrete backend type ever crosses the facade
//! boundary.

use super::H2Error;
use crate::batch::device::{
    exec_host_launch, exec_host_solve_launch, host_arena, host_arena_ref, AsyncDevice, Device,
    DeviceArena, HostArena, HostKernels, Launch,
};
use crate::batch::native::NativeBackend;
use crate::linalg::blas::{self, Side, Uplo};
use crate::linalg::chol;
use crate::linalg::matrix::{Matrix, Trans};
use crate::metrics::flops;
use std::path::PathBuf;

/// Which execution engine runs the batched kernels.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum BackendSpec {
    /// Thread-pool native kernels (the paper's CPU path). Default.
    #[default]
    Native,
    /// AOT XLA executables through PJRT (the paper's GPU-analog path).
    /// Fails with [`H2Error::BackendUnavailable`] when the artifacts or
    /// the XLA runtime are missing.
    Pjrt {
        /// Directory holding `manifest.json` and the `.hlo.txt` artifacts.
        artifacts_dir: PathBuf,
    },
    /// Single-threaded golden-reference execution: same kernels as
    /// [`BackendSpec::Native`], no thread pool, no unsafe — bit-identical
    /// to native and useful for debugging and determinism checks.
    SerialReference,
    /// Overlapping multi-stream executor
    /// ([`crate::batch::device::AsyncDevice`]) wrapped around another
    /// backend: level *k+1*'s uploads run concurrently with level *k*'s
    /// compute under a `BufferId`-granular hazard tracker, bit-identical
    /// to the wrapped backend. Spelled `async:<inner>` on the CLI;
    /// nesting (`async:async:...`) is rejected.
    Async {
        /// The wrapped backend description (never `Async` itself).
        inner: Box<BackendSpec>,
    },
}

impl BackendSpec {
    /// PJRT with the conventional `artifacts/` directory.
    pub fn pjrt() -> BackendSpec {
        BackendSpec::Pjrt { artifacts_dir: PathBuf::from("artifacts") }
    }

    /// The overlapping executor over the native backend — the paper's
    /// "level k compute overlaps level k+1 uploads" configuration.
    pub fn async_native() -> BackendSpec {
        BackendSpec::Async { inner: Box::new(BackendSpec::Native) }
    }

    /// Parse a CLI-style backend name: `native`, `serial`, `pjrt`,
    /// `pjrt:<artifacts_dir>`, or `async:<inner>` (any non-async spec —
    /// `async:native`, `async:serial`, `async:pjrt:DIR`; bare `async`
    /// means `async:native`).
    pub fn by_name(name: &str) -> Option<BackendSpec> {
        match name {
            "native" => Some(BackendSpec::Native),
            "pjrt" => Some(BackendSpec::pjrt()),
            "serial" => Some(BackendSpec::SerialReference),
            "async" => Some(BackendSpec::async_native()),
            _ => {
                if let Some(rest) = name.strip_prefix("async:") {
                    let inner = BackendSpec::by_name(rest)?;
                    if matches!(inner, BackendSpec::Async { .. }) {
                        return None; // async backends do not nest
                    }
                    return Some(BackendSpec::Async { inner: Box::new(inner) });
                }
                let dir = name.strip_prefix("pjrt:")?;
                if dir.is_empty() {
                    return None;
                }
                Some(BackendSpec::Pjrt { artifacts_dir: PathBuf::from(dir) })
            }
        }
    }

    /// Human-readable spec name.
    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Native => "native",
            BackendSpec::Pjrt { .. } => "pjrt",
            BackendSpec::SerialReference => "serial",
            BackendSpec::Async { inner } => match inner.as_ref() {
                BackendSpec::Native => "async:native",
                BackendSpec::Pjrt { .. } => "async:pjrt",
                BackendSpec::SerialReference => "async:serial",
                BackendSpec::Async { .. } => "async",
            },
        }
    }

    /// Instantiate the described backend as an arena-native device.
    pub(crate) fn instantiate(&self) -> Result<Box<dyn Device>, H2Error> {
        match self {
            BackendSpec::Native => Ok(Box::new(NativeBackend::new())),
            BackendSpec::SerialReference => Ok(Box::new(SerialBackend)),
            BackendSpec::Pjrt { artifacts_dir } => {
                match crate::runtime::PjrtBackend::new(artifacts_dir) {
                    Ok(be) => Ok(Box::new(be)),
                    Err(e) => Err(H2Error::BackendUnavailable {
                        backend: "pjrt".to_string(),
                        reason: e.to_string(),
                    }),
                }
            }
            // Concrete per-inner wrapping keeps AsyncDevice generic (and
            // its worker threads free of double dynamic dispatch).
            BackendSpec::Async { inner } => match inner.as_ref() {
                BackendSpec::Native => Ok(Box::new(AsyncDevice::new(NativeBackend::new()))),
                BackendSpec::SerialReference => Ok(Box::new(AsyncDevice::new(SerialBackend))),
                BackendSpec::Pjrt { artifacts_dir } => {
                    match crate::runtime::PjrtBackend::new(artifacts_dir) {
                        Ok(be) => Ok(Box::new(AsyncDevice::new(be))),
                        Err(e) => Err(H2Error::BackendUnavailable {
                            backend: "async:pjrt".to_string(),
                            reason: e.to_string(),
                        }),
                    }
                }
                BackendSpec::Async { .. } => Err(H2Error::BackendUnavailable {
                    backend: "async".to_string(),
                    reason: "async backends do not nest".to_string(),
                }),
            },
        }
    }
}

/// Single-threaded reference implementation of the batched kernels.
///
/// Runs every batch item sequentially with the same `linalg` kernels the
/// native backend dispatches to the worker pool, so results are
/// bit-identical to [`NativeBackend`] while execution stays deterministic
/// and free of unsafe pointer sharing.
pub struct SerialBackend;

impl SerialBackend {
    pub fn potrf(&self, _level: usize, blocks: &mut [Matrix]) {
        for (t, blk) in blocks.iter_mut().enumerate() {
            flops::add(flops::potrf_flops(blk.rows()));
            if let Err(e) = chol::potrf(blk) {
                panic!("serial POTRF failed on block {t}: {e:?} (matrix not SPD)");
            }
        }
    }

    pub fn trsm_right_lt(&self, _level: usize, l: &[&Matrix], b: &mut [Matrix]) {
        assert_eq!(l.len(), b.len());
        for (lt, bt) in l.iter().zip(b.iter_mut()) {
            flops::add(flops::trsm_flops(lt.rows(), bt.rows()));
            blas::trsm(Side::Right, Uplo::Lower, Trans::Yes, 1.0, lt, bt);
        }
    }

    pub fn schur_self(&self, _level: usize, a: &[&Matrix], c: &mut [Matrix]) {
        assert_eq!(a.len(), c.len());
        for (at, ct) in a.iter().zip(c.iter_mut()) {
            flops::add(flops::gemm_flops(at.rows(), at.rows(), at.cols()));
            blas::gemm(-1.0, at, Trans::No, at, Trans::Yes, 1.0, ct);
        }
    }

    pub fn sparsify(&self, _level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix> {
        assert_eq!(u.len(), a.len());
        assert_eq!(v.len(), a.len());
        let mut out = Vec::with_capacity(a.len());
        for t in 0..a.len() {
            crate::batch::count_sparsify_flops(u[t], &a[t], v[t]);
            let mut ua = Matrix::zeros(u[t].cols(), a[t].cols());
            blas::gemm(1.0, u[t], Trans::Yes, &a[t], Trans::No, 0.0, &mut ua);
            let mut f = Matrix::zeros(u[t].cols(), v[t].cols());
            blas::gemm(1.0, &ua, Trans::No, v[t], Trans::No, 0.0, &mut f);
            out.push(f);
        }
        out
    }

    pub fn trsv_fwd(&self, _level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        assert_eq!(l.len(), x.len());
        for (lt, xt) in l.iter().zip(x.iter_mut()) {
            flops::add((lt.rows() * lt.rows()) as u64);
            blas::trsv(Uplo::Lower, Trans::No, lt, xt);
        }
    }

    pub fn trsv_bwd(&self, _level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        assert_eq!(l.len(), x.len());
        for (lt, xt) in l.iter().zip(x.iter_mut()) {
            flops::add((lt.rows() * lt.rows()) as u64);
            blas::trsv(Uplo::Lower, Trans::Yes, lt, xt);
        }
    }

    pub fn gemv_acc(
        &self,
        _level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    ) {
        assert_eq!(a.len(), x.len());
        assert_eq!(a.len(), y.len());
        let ta = if trans { Trans::Yes } else { Trans::No };
        for t in 0..a.len() {
            flops::add(2 * (a[t].rows() * a[t].cols()) as u64);
            blas::gemv(alpha, a[t], ta, x[t], 1.0, &mut y[t]);
        }
    }

    pub fn apply_basis(
        &self,
        _level: usize,
        u: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        assert_eq!(u.len(), x.len());
        let ta = if trans { Trans::Yes } else { Trans::No };
        let mut out = Vec::with_capacity(u.len());
        for t in 0..u.len() {
            let out_len = if trans { u[t].cols() } else { u[t].rows() };
            let mut y = vec![0.0; out_len];
            flops::add(2 * (u[t].rows() * u[t].cols()) as u64);
            blas::gemv(1.0, u[t], ta, x[t], 0.0, &mut y);
            out.push(y);
        }
        out
    }
}

impl HostKernels for SerialBackend {
    fn potrf(&self, level: usize, blocks: &mut [Matrix]) {
        SerialBackend::potrf(self, level, blocks);
    }
    fn trsm_right_lt(&self, level: usize, l: &[&Matrix], b: &mut [Matrix]) {
        SerialBackend::trsm_right_lt(self, level, l, b);
    }
    fn schur_self(&self, level: usize, a: &[&Matrix], c: &mut [Matrix]) {
        SerialBackend::schur_self(self, level, a, c);
    }
    fn sparsify(&self, level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix> {
        SerialBackend::sparsify(self, level, u, a, v)
    }
    fn trsv_fwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        SerialBackend::trsv_fwd(self, level, l, x);
    }
    fn trsv_bwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        SerialBackend::trsv_bwd(self, level, l, x);
    }
    fn gemv_acc(
        &self,
        level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    ) {
        SerialBackend::gemv_acc(self, level, alpha, a, trans, x, y);
    }
    fn apply_basis(
        &self,
        level: usize,
        u: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        SerialBackend::apply_basis(self, level, u, trans, x)
    }
}

impl Device for SerialBackend {
    fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena> {
        Box::new(HostArena::with_capacity(capacity))
    }

    fn launch(&self, arena: &mut dyn DeviceArena, launch: &Launch<'_>) {
        exec_host_launch(self, host_arena(arena), launch);
    }

    fn launch_solve(
        &self,
        factor: &dyn DeviceArena,
        ws: &mut dyn DeviceArena,
        launch: &Launch<'_>,
    ) {
        exec_host_solve_launch(self, host_arena_ref(factor), host_arena(ws), launch);
    }

    fn name(&self) -> &'static str {
        "serial"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::frob;
    use crate::util::Rng;

    #[test]
    fn spec_names_and_parsing() {
        assert_eq!(BackendSpec::default(), BackendSpec::Native);
        assert_eq!(BackendSpec::by_name("native"), Some(BackendSpec::Native));
        assert_eq!(BackendSpec::by_name("serial"), Some(BackendSpec::SerialReference));
        assert_eq!(BackendSpec::by_name("pjrt").map(|s| s.name()), Some("pjrt"));
        assert_eq!(BackendSpec::by_name("gpu"), None);
    }

    #[test]
    fn spec_parses_async_wrappers() {
        assert_eq!(BackendSpec::by_name("async"), Some(BackendSpec::async_native()));
        assert_eq!(BackendSpec::by_name("async:native"), Some(BackendSpec::async_native()));
        assert_eq!(
            BackendSpec::by_name("async:serial"),
            Some(BackendSpec::Async { inner: Box::new(BackendSpec::SerialReference) })
        );
        assert_eq!(
            BackendSpec::by_name("async:pjrt:some/dir"),
            Some(BackendSpec::Async {
                inner: Box::new(BackendSpec::Pjrt { artifacts_dir: PathBuf::from("some/dir") })
            })
        );
        assert_eq!(BackendSpec::async_native().name(), "async:native");
        assert_eq!(
            BackendSpec::by_name("async:async:native"),
            None,
            "async backends must not nest"
        );
        assert_eq!(BackendSpec::by_name("async:bogus"), None);
        // The wrapper instantiates and reports a composed name.
        let dev = BackendSpec::async_native().instantiate().expect("native always available");
        assert_eq!(dev.name(), "async:native");
    }

    #[test]
    fn spec_parses_pjrt_artifact_dir() {
        let spec = BackendSpec::by_name("pjrt:custom/artifacts").expect("valid spec");
        assert_eq!(
            spec,
            BackendSpec::Pjrt { artifacts_dir: PathBuf::from("custom/artifacts") }
        );
        assert_eq!(spec.name(), "pjrt");
        assert_eq!(BackendSpec::by_name("pjrt:"), None, "empty dir is invalid");
        assert_eq!(BackendSpec::by_name("pjrtx"), None);
    }

    #[test]
    fn serial_matches_native_kernels() {
        let mut rng = Rng::new(77);
        let mats: Vec<Matrix> = (0..4).map(|_| Matrix::rand_spd(10, &mut rng)).collect();
        let mut serial = mats.clone();
        let mut native = mats.clone();
        SerialBackend.potrf(0, &mut serial);
        NativeBackend::new().potrf(0, &mut native);
        for (s, n) in serial.iter().zip(&native) {
            let mut d = s.clone();
            d.axpy(-1.0, n);
            assert!(frob(&d) == 0.0, "serial and native POTRF must be bit-identical");
        }
    }
}
