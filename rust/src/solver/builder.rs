//! Builder for [`H2Solver`] sessions.

use super::backend::BackendSpec;
use super::session::H2Solver;
use super::H2Error;
use crate::construct::H2Config;
use crate::geometry::Geometry;
use crate::kernels::KernelFn;
use crate::ulv::SubstMode;

/// Where the ULV factor lives for the lifetime of a session.
///
/// The factor is always device-resident (solves replay against the arena);
/// the policy decides whether a *second*, host-side copy exists next to
/// it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FactorStorage {
    /// Keep a host [`crate::ulv::UlvFactor`] mirror next to the
    /// device-resident factor (2x factor memory).
    /// [`H2Solver::factor`](super::H2Solver::factor) returns `Some` and
    /// host-side research code can read blocks directly. Default.
    #[default]
    Mirrored,
    /// Device-resident only: the host mirror is never materialized, so
    /// factor memory exists exactly once. Shape queries go through
    /// [`H2Solver::factor_meta`](super::H2Solver::factor_meta); the rare
    /// paths that need values download individual blocks with
    /// [`H2Solver::download_block`](super::H2Solver::download_block).
    DeviceOnly,
}

impl FactorStorage {
    /// Parse a CLI-style mode name: `mirrored` or `device-only`
    /// (also accepts `device_only`).
    pub fn by_name(name: &str) -> Option<FactorStorage> {
        match name {
            "mirrored" => Some(FactorStorage::Mirrored),
            "device-only" | "device_only" => Some(FactorStorage::DeviceOnly),
            _ => None,
        }
    }

    /// Human-readable mode name.
    pub fn name(&self) -> &'static str {
        match self {
            FactorStorage::Mirrored => "mirrored",
            FactorStorage::DeviceOnly => "device-only",
        }
    }
}

/// Configures and builds an [`H2Solver`]: geometry + kernel are mandatory
/// (constructor arguments), everything else has sensible defaults.
///
/// ```
/// use h2ulv::prelude::*;
///
/// let solver = H2SolverBuilder::new(Geometry::sphere_surface(128, 7), KernelFn::yukawa())
///     .config(H2Config { leaf_size: 32, max_rank: 24, ..Default::default() })
///     .subst_mode(SubstMode::Parallel)
///     .factor_storage(FactorStorage::DeviceOnly)
///     .residual_samples(64)
///     .build()?;
/// assert_eq!(solver.n(), 128);
/// assert!(solver.factor().is_none(), "device-only sessions keep no host mirror");
/// # Ok::<(), h2ulv::solver::H2Error>(())
/// ```
#[derive(Clone)]
pub struct H2SolverBuilder {
    geometry: Geometry,
    kernel: KernelFn,
    config: H2Config,
    backend: BackendSpec,
    subst: SubstMode,
    residual_samples: usize,
    storage: FactorStorage,
    verify_plan: Option<bool>,
    max_solve_threads: usize,
}

impl H2SolverBuilder {
    /// Start a builder for the given problem. Defaults: [`H2Config::default`],
    /// [`BackendSpec::Native`], [`SubstMode::Parallel`], 128 residual
    /// samples, [`FactorStorage::Mirrored`].
    pub fn new(geometry: Geometry, kernel: KernelFn) -> H2SolverBuilder {
        H2SolverBuilder {
            geometry,
            kernel,
            config: H2Config::default(),
            backend: BackendSpec::Native,
            subst: SubstMode::default(),
            residual_samples: 128,
            storage: FactorStorage::default(),
            verify_plan: None,
            max_solve_threads: 0,
        }
    }

    /// Set the construction/factorization configuration.
    pub fn config(mut self, config: H2Config) -> Self {
        self.config = config;
        self
    }

    /// Select the execution backend (default [`BackendSpec::Native`]).
    pub fn backend(mut self, spec: BackendSpec) -> Self {
        self.backend = spec;
        self
    }

    /// Select the substitution algorithm (default [`SubstMode::Parallel`]).
    pub fn subst_mode(mut self, mode: SubstMode) -> Self {
        self.subst = mode;
        self
    }

    /// Number of sampled exact-kernel rows used for the per-solve residual
    /// estimate in [`super::SolveReport::residual`]; `0` disables the
    /// estimate (default 128).
    pub fn residual_samples(mut self, samples: usize) -> Self {
        self.residual_samples = samples;
        self
    }

    /// Select where the factor lives (default [`FactorStorage::Mirrored`]);
    /// [`FactorStorage::DeviceOnly`] halves factor memory by dropping the
    /// host mirror.
    pub fn factor_storage(mut self, storage: FactorStorage) -> Self {
        self.storage = storage;
        self
    }

    /// Force record-time static plan verification on or off
    /// ([`crate::plan::verify`]). Unset, the `H2_VERIFY_PLAN` environment
    /// variable decides (`0`/`false` disables, any other value enables),
    /// and absent that it defaults to on in debug builds. A violation
    /// surfaces as [`H2Error::PlanVerification`] from
    /// [`H2SolverBuilder::build`] or
    /// [`H2Solver::refactorize`](super::H2Solver::refactorize).
    pub fn verify_plan(mut self, on: bool) -> Self {
        self.verify_plan = Some(on);
        self
    }

    /// Cap the worker fan-out of
    /// [`H2Solver::solve_many`](super::H2Solver::solve_many) for the whole
    /// session: at most `n` threads replay concurrently (`0`, the default,
    /// scales to available parallelism; `1` solves sequentially in the
    /// calling thread). Results are bit-identical at every cap — the
    /// setting bounds resource use, not numerics. Per-call
    /// [`SolveOptions::max_threads`](super::SolveOptions) overrides it;
    /// the serve admission controller and the CLI `--threads` flag both
    /// build on this.
    pub fn max_solve_threads(mut self, n: usize) -> Self {
        self.max_solve_threads = n;
        self
    }

    /// Validate the problem, instantiate the backend, construct the H²
    /// matrix, and run the ULV factorization.
    ///
    /// Every failure mode returns a typed [`H2Error`] — see the taxonomy in
    /// [`crate::solver`].
    pub fn build(self) -> Result<H2Solver, H2Error> {
        validate(&self.geometry, &self.config)?;
        let backend = self.backend.instantiate()?;
        let verify_plan = self.verify_plan.unwrap_or_else(verify_plan_default);
        H2Solver::assemble(
            self.geometry,
            self.kernel,
            self.config,
            self.backend,
            backend,
            self.subst,
            self.residual_samples,
            self.storage,
            verify_plan,
            self.max_solve_threads,
        )
    }
}

/// Resolve the default for record-time plan verification: the
/// `H2_VERIFY_PLAN` environment variable wins (`0`/`false`, case
/// insensitive, disables; any other value enables), else on in debug
/// builds only.
fn verify_plan_default() -> bool {
    match std::env::var("H2_VERIFY_PLAN") {
        Ok(v) => {
            let v = v.to_lowercase();
            v != "0" && v != "false"
        }
        Err(_) => cfg!(debug_assertions),
    }
}

/// Shared problem/config validation (also used by
/// [`H2Solver::refactorize`]).
pub(crate) fn validate(geometry: &Geometry, config: &H2Config) -> Result<(), H2Error> {
    if geometry.is_empty() {
        return Err(H2Error::EmptyGeometry);
    }
    if config.leaf_size == 0 {
        return Err(H2Error::InvalidConfig("leaf_size must be >= 1".to_string()));
    }
    if config.max_rank == 0 {
        return Err(H2Error::InvalidConfig("max_rank must be >= 1".to_string()));
    }
    if !config.eta.is_finite() || config.eta < 0.0 {
        return Err(H2Error::InvalidConfig(format!(
            "eta must be a finite non-negative number, got {}",
            config.eta
        )));
    }
    if !config.rtol.is_finite() || config.rtol < 0.0 {
        return Err(H2Error::InvalidConfig(format!(
            "rtol must be a finite non-negative number, got {}",
            config.rtol
        )));
    }
    if geometry.len() < config.leaf_size {
        return Err(H2Error::ProblemTooSmall { n: geometry.len(), leaf_size: config.leaf_size });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_catches_malformed_problems() {
        let g = Geometry::uniform_cube(100, 1);
        let ok = H2Config { leaf_size: 32, ..Default::default() };
        assert!(validate(&g, &ok).is_ok());

        let empty = Geometry { points: Vec::new(), name: "empty".to_string() };
        assert_eq!(validate(&empty, &ok), Err(H2Error::EmptyGeometry));

        let zero_leaf = H2Config { leaf_size: 0, ..Default::default() };
        assert!(matches!(validate(&g, &zero_leaf), Err(H2Error::InvalidConfig(_))));

        let zero_rank = H2Config { max_rank: 0, leaf_size: 32, ..Default::default() };
        assert!(matches!(validate(&g, &zero_rank), Err(H2Error::InvalidConfig(_))));

        let nan_eta = H2Config { eta: f64::NAN, leaf_size: 32, ..Default::default() };
        assert!(matches!(validate(&g, &nan_eta), Err(H2Error::InvalidConfig(_))));

        let inf_eta = H2Config { eta: f64::INFINITY, leaf_size: 32, ..Default::default() };
        assert!(matches!(validate(&g, &inf_eta), Err(H2Error::InvalidConfig(_))));

        let inf_rtol = H2Config { rtol: f64::INFINITY, leaf_size: 32, ..Default::default() };
        assert!(matches!(validate(&g, &inf_rtol), Err(H2Error::InvalidConfig(_))));

        let big_leaf = H2Config { leaf_size: 512, ..Default::default() };
        assert_eq!(
            validate(&g, &big_leaf),
            Err(H2Error::ProblemTooSmall { n: 100, leaf_size: 512 })
        );
    }
}
