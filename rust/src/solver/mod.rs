//! The `H2Solver` facade: one coherent, `Result`-based session API over the
//! layered pipeline (geometry → construction → ULV factorization →
//! substitution).
//!
//! The layered modules ([`crate::construct`], [`crate::ulv`],
//! [`crate::batch`], [`crate::runtime`], [`crate::dist`]) stay public for
//! benchmarks and research code, but they expose three footguns the facade
//! removes:
//!
//! 1. **Permutation bookkeeping** — the cluster tree reorders points, and
//!    the low-level solve works in tree ordering. The facade accepts and
//!    returns vectors in the caller's original point ordering; every
//!    `permute_vec`/`unpermute_vec` happens inside.
//! 2. **Panics on bad input** — the layered code asserts. The facade
//!    validates inputs up front and converts any residual panic into a
//!    structured [`H2Error`] via an unwind guard.
//! 3. **Concrete backend types threaded through every call** — the facade
//!    owns a boxed [`crate::batch::device::Device`] (and its resident
//!    buffer arena) selected by [`BackendSpec`] at build time; callers
//!    never see backend types.
//!
//! Sessions are **concurrent solve servers**: the resident factor region
//! is shared read-only and every solve leases a private workspace from
//! the session's [`crate::batch::device::WorkspacePool`], so N threads
//! solve simultaneously on one `&H2Solver` with no lock held across
//! launches (see the "Concurrency model" notes on [`session`]). The
//! [`FactorStorage`] policy additionally controls whether a host factor
//! mirror exists at all ([`FactorStorage::DeviceOnly`] halves factor
//! memory).
//!
//! # Error taxonomy
//!
//! | Variant | Meaning | Typical cause |
//! |---------|---------|---------------|
//! | [`H2Error::EmptyGeometry`] | geometry has zero points | empty point cloud |
//! | [`H2Error::ProblemTooSmall`] | `N < leaf_size`, no hierarchy exists | tiny N or huge leaf — shrink `leaf_size` or use `baselines::dense` |
//! | [`H2Error::InvalidConfig`] | a config field is out of range | `leaf_size == 0`, `max_rank == 0`, negative/NaN `eta` or `rtol` |
//! | [`H2Error::DimensionMismatch`] | right-hand-side length ≠ N | wrong RHS |
//! | [`H2Error::BackendUnavailable`] | requested backend cannot start | PJRT artifacts missing, XLA runtime absent |
//! | [`H2Error::NotPositiveDefinite`] | Cholesky broke down | kernel matrix not SPD (diagonal regularization removed) |
//! | [`H2Error::ConvergenceFailure`] | iterative refinement missed its target | tolerance too tight for the factor quality |
//! | [`H2Error::PlanVerification`] | the recorded plan failed the static verifier | recorder bug — see [`crate::plan::verify`] |
//! | [`H2Error::Internal`] | a layered-code panic was caught | bug — please report |
//!
//! # Quickstart
//!
//! ```
//! use h2ulv::prelude::*;
//!
//! let geometry = Geometry::sphere_surface(96, 1);
//! let solver = H2SolverBuilder::new(geometry, KernelFn::laplace())
//!     .config(H2Config { leaf_size: 32, max_rank: 24, ..Default::default() })
//!     .backend(BackendSpec::Native)
//!     .build()?;
//! let b = vec![1.0; solver.n()];
//! let report = solver.solve(&b)?;
//! assert_eq!(report.x.len(), 96);
//! # Ok::<(), h2ulv::solver::H2Error>(())
//! ```

pub mod backend;
pub mod builder;
pub mod session;

pub use backend::BackendSpec;
pub use builder::{FactorStorage, H2SolverBuilder};
pub use session::{
    BuildStats, DistSolveReport, FactorBlock, H2Solver, SolveOptions, SolveReport,
};

use std::fmt;

/// Structured error type for the solver facade. Every fallible path in
/// construction, factorization, and substitution surfaces here instead of
/// panicking (see the module-level taxonomy table).
#[derive(Debug, Clone, PartialEq)]
pub enum H2Error {
    /// The geometry has no points.
    EmptyGeometry,
    /// `N < leaf_size`: the cluster tree would be a single box with no
    /// hierarchy to exploit. Shrink `leaf_size` or use a dense solver.
    ProblemTooSmall { n: usize, leaf_size: usize },
    /// A configuration field is out of its valid range.
    InvalidConfig(String),
    /// A supplied vector's length does not match the matrix dimension N.
    DimensionMismatch { expected: usize, got: usize },
    /// The requested execution backend could not be instantiated.
    BackendUnavailable { backend: String, reason: String },
    /// A Cholesky factorization broke down: the (regularized) kernel
    /// matrix or one of its Schur complements lost positive definiteness.
    NotPositiveDefinite { stage: String, detail: String },
    /// Iterative refinement did not reach the requested tolerance.
    ConvergenceFailure { achieved: f64, target: f64, iterations: usize },
    /// The recorded plan failed static verification
    /// ([`crate::plan::verify`]) — a recorder bug, caught before replay.
    PlanVerification(String),
    /// A panic from the layered code was caught and converted.
    Internal { stage: String, detail: String },
}

impl fmt::Display for H2Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            H2Error::EmptyGeometry => write!(f, "geometry has no points"),
            H2Error::ProblemTooSmall { n, leaf_size } => write!(
                f,
                "problem too small for a hierarchical solve: N = {n} < leaf_size = {leaf_size} \
                 (shrink leaf_size or use the dense baseline)"
            ),
            H2Error::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            H2Error::DimensionMismatch { expected, got } => {
                write!(f, "vector has length {got}, expected the matrix dimension N = {expected}")
            }
            H2Error::BackendUnavailable { backend, reason } => {
                write!(f, "backend '{backend}' unavailable: {reason}")
            }
            H2Error::NotPositiveDefinite { stage, detail } => {
                write!(f, "lost positive definiteness during {stage}: {detail}")
            }
            H2Error::ConvergenceFailure { achieved, target, iterations } => write!(
                f,
                "iterative refinement stalled at relative residual {achieved:.3e} \
                 (target {target:.3e}) after {iterations} iteration(s)"
            ),
            H2Error::PlanVerification(msg) => {
                write!(f, "plan verification failed: {msg}")
            }
            H2Error::Internal { stage, detail } => {
                write!(f, "internal failure during {stage}: {detail}")
            }
        }
    }
}

impl std::error::Error for H2Error {}

thread_local! {
    /// Set while [`guard`] is unwinding-protected on this thread, so the
    /// process-wide panic hook stays quiet for panics we convert to errors.
    static SUPPRESS_PANIC_OUTPUT: std::cell::Cell<bool> = std::cell::Cell::new(false);
}
static PANIC_HOOK_INIT: std::sync::Once = std::sync::Once::new();

/// Run `f`, converting any panic from the layered code into an [`H2Error`].
///
/// The facade validates inputs before calling into the layers, so this is
/// a safety net for genuinely exceptional states (e.g. a Schur complement
/// losing positive definiteness on an adversarial kernel). While `f` runs,
/// the default panic hook is silenced on this thread so the caller sees
/// only the returned [`H2Error`], not a spurious backtrace on stderr
/// (panics raised on pool worker threads still print before propagating).
pub(crate) fn guard<T>(stage: &str, f: impl FnOnce() -> T) -> Result<T, H2Error> {
    PANIC_HOOK_INIT.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !SUPPRESS_PANIC_OUTPUT.with(|s| s.get()) {
                prev(info);
            }
        }));
    });
    SUPPRESS_PANIC_OUTPUT.with(|s| s.set(true));
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    SUPPRESS_PANIC_OUTPUT.with(|s| s.set(false));
    result.map_err(|payload| {
        let detail = payload
            .downcast_ref::<&'static str>()
            .map(|s| (*s).to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "unknown panic payload".to_string());
        // Matches every Cholesky-breakdown panic text in the layers:
        // "NotSpd { .. }" (Debug of FactorError), "matrix not SPD",
        // "block must stay SPD", "not positive definite".
        let lower = detail.to_lowercase();
        if lower.contains("spd") || lower.contains("positive definite") {
            H2Error::NotPositiveDefinite { stage: stage.to_string(), detail }
        } else if lower.contains("hazard audit failed") || lower.contains("plan verification") {
            // The typed violation wording shared by `ValidatingDevice`,
            // the static verifier, and `AsyncDevice::launch_solve`'s
            // region-aliasing check: a launch the hazard discipline
            // rejects is a plan/dispatch bug, not an opaque internal
            // panic.
            H2Error::PlanVerification(detail)
        } else {
            H2Error::Internal { stage: stage.to_string(), detail }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_actionable() {
        let e = H2Error::DimensionMismatch { expected: 100, got: 7 };
        let s = e.to_string();
        assert!(s.contains("7") && s.contains("100"), "{s}");
        let e = H2Error::ProblemTooSmall { n: 10, leaf_size: 64 };
        assert!(e.to_string().contains("leaf_size"));
    }

    #[test]
    fn guard_converts_panics() {
        let err = guard("test", || panic!("block must stay SPD")).unwrap_err();
        assert!(matches!(err, H2Error::NotPositiveDefinite { .. }), "{err:?}");
        // The native backend's batched-POTRF assert carries the Debug form
        // of FactorError::NotSpd — it must classify the same way.
        let err = guard("test", || {
            panic!("batched POTRF failed on 1 block(s): [(0, NotSpd {{ index: 3, pivot: -1.0 }})]")
        })
        .unwrap_err();
        assert!(matches!(err, H2Error::NotPositiveDefinite { .. }), "{err:?}");
        // Typed hazard violations (ValidatingDevice, the async engine's
        // region-aliasing check) classify as plan-verification failures.
        let err = guard("test", || {
            panic!("hazard audit failed for TRSV: factor and workspace resolve to the same arena region")
        })
        .unwrap_err();
        assert!(matches!(err, H2Error::PlanVerification(_)), "{err:?}");
        let err = guard("test", || panic!("index out of bounds")).unwrap_err();
        assert!(matches!(err, H2Error::Internal { .. }), "{err:?}");
        let ok = guard("test", || 41 + 1).unwrap();
        assert_eq!(ok, 42);
    }
}
