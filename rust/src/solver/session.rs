//! The [`H2Solver`] session: owns the H² matrix, the cached execution
//! [`Plan`], the device-resident factor region, a [`WorkspacePool`] of
//! per-call vector regions, and the execution backend; every solve handles
//! tree-order permutation internally and reports through [`SolveReport`].
//!
//! The plan is recorded once per H² *structure*. Repeated solves,
//! [`H2Solver::refactorize`] with an unchanged structure, and
//! [`H2Solver::rebind_backend`] all replay the cached plan — schedule
//! discovery never runs twice ([`H2Solver::plan_recordings`] counts it).
//!
//! # Concurrency model
//!
//! After `build()` the factor arena is an **immutable factor region**:
//! substitution programs only read it, and every solve entry point
//! (`solve`, `solve_many`, `solve_refined`, `solve_dist`) leases a private
//! [`VecRegion`](crate::batch::device::VecRegion) workspace from the
//! session's pool for its vector buffers. `&self` solves therefore run
//! concurrently from any number of threads with **no lock held across
//! launches** — exclusivity is only required by the `&mut self` phases
//! (`refactorize`, `rebind_backend`), which the borrow checker enforces
//! statically.
//!
//! # Factor storage
//!
//! [`FactorStorage::Mirrored`] (default) keeps a host [`UlvFactor`] next
//! to the device-resident factor; [`FactorStorage::DeviceOnly`] drops the
//! mirror (factor memory exists exactly once), serving structural queries
//! from [`FactorMeta`] and individual values through
//! [`H2Solver::download_block`].

use super::backend::BackendSpec;
use super::builder::{validate, FactorStorage};
use super::{guard, H2Error};
use crate::batch::device::{Device, DeviceArena, WorkspacePool};
use crate::construct::H2Config;
use crate::dist::exec::DistSession;
use crate::dist::{model_report, NCCL_LIKE};
use crate::geometry::Geometry;
use crate::h2::H2Matrix;
use crate::kernels::KernelFn;
use crate::linalg::Matrix;
use crate::metrics::comm::CommMeasurement;
use crate::metrics::overlap::OverlapTrace;
use crate::metrics::run_trace::{
    overlap_metrics, LevelReport, RunReport, NO_LEVEL, RUN_REPORT_SCHEMA_VERSION,
};
use crate::metrics::{flops::FlopScope, timer::timed, RunTrace};
use crate::plan::{self, Executor, LevelScheduleStats, Plan, ScheduleStats};
use crate::ulv::{pcg_in, FactorMeta, SubstMode, UlvFactor};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Seed for the sampled residual estimator (fixed so reports are
/// reproducible across solves of the same problem).
const RESIDUAL_SEED: u64 = 0xCAFE;

/// Fallback sample count when a per-call override requests a residual but
/// the builder disabled sampling.
const DEFAULT_RESIDUAL_SAMPLES: usize = 128;

/// Timings and footprint of one `build()`/`refactorize()`/
/// `rebind_backend()`.
#[derive(Clone, Debug)]
pub struct BuildStats {
    /// Matrix dimension N.
    pub n: usize,
    /// Cluster-tree depth (leaf level index).
    pub depth: usize,
    /// H² construction wall time in seconds (0 when the H² matrix was
    /// reused, i.e. after `rebind_backend`).
    pub construct_time: f64,
    /// ULV factorization wall time in seconds (plan replay only; schedule
    /// recording is a separate structural walk, not included).
    pub factor_time: f64,
    /// FLOPs attributed to the factorization phase of *this session*
    /// (scoped — concurrent sessions do not contaminate each other).
    pub factor_flops: u64,
    /// H² storage footprint in f64 entries.
    pub h2_entries: usize,
    /// ULV factor storage footprint in f64 entries (device-resident; from
    /// [`FactorMeta::storage_entries`], so it is exact in both storage
    /// modes).
    pub factor_entries: usize,
    /// Host-mirror footprint in f64 entries: equals `factor_entries` under
    /// [`FactorStorage::Mirrored`], 0 under [`FactorStorage::DeviceOnly`]
    /// — the memory the device-only mode saves.
    pub mirror_entries: usize,
    /// Device-arena bytes live after the factorization replay (the
    /// resident factor region).
    pub arena_bytes: usize,
    /// Peak device-arena bytes during the factorization replay (factor
    /// plus transient sparsify/merge buffers).
    pub arena_peak_bytes: usize,
    /// Statically predicted peak ([`crate::plan::verify`]): equals
    /// `arena_peak_bytes` exactly on host-synchronous backends; overlapping
    /// backends may transiently exceed it (cross-stream frees retiring
    /// after later uploads).
    pub predicted_peak_bytes: usize,
    /// Schedule statistics straight from the plan IR: launch counts per
    /// level, batch sizes, useful vs constant-shape padded FLOPs.
    pub schedule: ScheduleStats,
    /// Per-stream busy intervals of the factorization replay — `Some` only
    /// on overlapping backends (`async:<inner>`), where
    /// [`OverlapTrace::overlapped_transfer_pairs`] shows which levels'
    /// uploads genuinely ran during other levels' compute.
    pub overlap: Option<OverlapTrace>,
}

impl BuildStats {
    /// Total factor bytes this session holds resident (device region plus
    /// host mirror): the number [`FactorStorage::DeviceOnly`] halves.
    pub fn factor_footprint_bytes(&self) -> usize {
        self.arena_bytes + 8 * self.mirror_entries
    }
}

/// Per-call overrides for [`H2Solver::solve_opts`].
#[derive(Clone, Debug, Default)]
pub struct SolveOptions {
    /// Substitution algorithm; `None` uses the builder's choice.
    pub subst_mode: Option<SubstMode>,
    /// Override residual sampling for this call: `Some(false)` skips the
    /// sampled-residual cost even when the builder enabled it (for solves
    /// that discard [`SolveReport::residual`]); `Some(true)` forces an
    /// estimate even when the builder disabled sampling (using the
    /// builder's sample count, or 128 if it was 0). `None` follows the
    /// builder.
    pub sample_residual: Option<bool>,
    /// Cap the [`solve_many`](H2Solver::solve_many) worker fan-out for
    /// this call: `Some(n)` uses at most `n` threads (1 solves in the
    /// calling thread), `Some(0)` and `None` fall back to the builder's
    /// [`max_solve_threads`](crate::solver::H2SolverBuilder::max_solve_threads)
    /// cap (which itself defaults to available parallelism). The serve
    /// admission controller passes its per-request worker grant here.
    pub max_threads: Option<usize>,
}

impl SolveOptions {
    /// Shorthand for "skip the residual estimate on this call".
    pub fn no_residual() -> SolveOptions {
        SolveOptions { sample_residual: Some(false), ..Default::default() }
    }
}

/// Result of one [`H2Solver::solve`] (or one right-hand side of
/// [`H2Solver::solve_many`]).
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Solution in the caller's original point ordering.
    pub x: Vec<f64>,
    /// Substitution wall time in seconds.
    pub subst_time: f64,
    /// Sampled exact-kernel relative residual `|Ax-b|/|b|`, or `None` when
    /// sampling is disabled (builder default or per-call override).
    pub residual: Option<f64>,
    /// Refinement iterations used (1 for a direct solve).
    pub iterations: usize,
    /// Substitution algorithm that produced `x`.
    pub subst_mode: SubstMode,
    /// Name of the backend that executed the batched kernels.
    pub backend: &'static str,
}

/// Result of a facade-level distributed solve ([`H2Solver::solve_dist`]):
/// the solution computed by the real multi-rank SPMD runtime
/// ([`crate::dist::exec::DistSession`]) alongside the α-β *prediction*
/// (times modeled with [`NCCL_LIKE`]; use [`crate::dist::model_report`]
/// directly for custom communication models) and the transport's
/// *measured* communication, so the two render side by side.
#[derive(Clone, Debug)]
pub struct DistSolveReport {
    /// Solution in the caller's original point ordering (matches
    /// [`solve`](H2Solver::solve) to solver accuracy for every rank
    /// count).
    pub x: Vec<f64>,
    /// Effective rank count (power of two, clamped to the leaf width).
    pub ranks: usize,
    /// Modeled factorization time (slowest rank + communication).
    pub factor_time: f64,
    /// Modeled substitution time.
    pub subst_time: f64,
    /// Modeled factorization communication volume in bytes.
    pub factor_bytes: u64,
    /// Modeled substitution communication volume in bytes.
    pub subst_bytes: u64,
    /// Measured communication from the rank transports: collective
    /// counts, bytes actually shipped, and exchange wall time on the
    /// critical path, for both phases.
    pub measured: CommMeasurement,
    /// Sampled exact-kernel relative residual (as in [`SolveReport`]).
    pub residual: Option<f64>,
}

/// One block of the device-resident factor, addressable for on-demand
/// download ([`H2Solver::download_block`]) — the escape hatch for the few
/// paths that need factor *values* from a [`FactorStorage::DeviceOnly`]
/// session. `level` indexes [`FactorMeta::levels`] (leaf level first).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FactorBlock {
    /// Diagonal Cholesky factor `L_ii` of box `box_index`.
    CholRr { level: usize, box_index: usize },
    /// Off-diagonal panel `L(r)_ji` for near pair `(j, i)`.
    Lr { level: usize, pair: (usize, usize) },
    /// Skeleton panel `L(s)_ji` for near pair `(j, i)`.
    Ls { level: usize, pair: (usize, usize) },
    /// Shared basis `U_i` of box `box_index`.
    Basis { level: usize, box_index: usize },
    /// The merged-root Cholesky factor.
    Root,
}

/// A built H² solver session: construction, plan recording, and
/// factorization are done; [`solve`](H2Solver::solve) is cheap, reusable
/// across right-hand sides, and callable from many threads at once.
pub struct H2Solver {
    geometry: Geometry,
    kernel: KernelFn,
    spec: BackendSpec,
    backend: Box<dyn Device>,
    /// The immutable factor region: holds the factor resident (outputs +
    /// bases + root) since the last factorization replay. Solves only
    /// *read* it (vector traffic goes to pooled workspaces), so `&self`
    /// methods share it lock-free; `refactorize`/`rebind_backend` replace
    /// it under `&mut self`.
    arena: Box<dyn DeviceArena>,
    /// Per-call vector regions: one leased per in-flight solve, returned
    /// (even on panic) when the solve finishes.
    pool: WorkspacePool,
    storage: FactorStorage,
    subst: SubstMode,
    residual_samples: usize,
    h2: H2Matrix,
    plan: Arc<Plan>,
    /// Host mirror of the factor — `Some` only under
    /// [`FactorStorage::Mirrored`].
    factor: Option<UlvFactor>,
    /// Shape-only factor description (always present; derived from the
    /// plan, not from the mirror).
    meta: FactorMeta,
    stats: BuildStats,
    scope: FlopScope,
    /// Session-lifetime structured span trace (`construct` → `factorize` →
    /// per-level replay spans → `substitution`), shared by clone with the
    /// executor and trace-aware backends.
    run_trace: RunTrace,
    /// Right-hand sides solved so far (all entry points) — the `rhs`
    /// column of [`RunReport`].
    solved_rhs: AtomicUsize,
    /// Solve-path overlap events drained from the backend since the last
    /// factorization replay (the factor-phase trace lives in
    /// [`BuildStats::overlap`]). Synced lazily from the backend;
    /// [`run_report`](H2Solver::run_report) snapshots it,
    /// [`take_solve_overlap`](H2Solver::take_solve_overlap) drains it.
    solve_overlap: Mutex<OverlapTrace>,
    /// Lazily built multi-rank SPMD sessions, keyed by effective rank
    /// count: each holds per-rank devices and rank-sharded factor arenas
    /// ([`crate::dist::exec::DistSession`]). Invalidated whenever the
    /// factor is replaced (`refactorize`, `rebind_backend`).
    dist_sessions: Mutex<HashMap<usize, Arc<DistSession>>>,
    /// Session-wide cap on the `solve_many` worker fan-out (0 = scale to
    /// available parallelism). Per-call [`SolveOptions::max_threads`]
    /// overrides it.
    max_solve_threads: usize,
    plan_recordings: usize,
    /// Statically verify every newly recorded plan (builder flag /
    /// `H2_VERIFY_PLAN` / debug default).
    verify_plan: bool,
}

impl H2Solver {
    /// Construct + record + factorize (called by the builder; inputs are
    /// already validated).
    pub(crate) fn assemble(
        geometry: Geometry,
        kernel: KernelFn,
        config: H2Config,
        spec: BackendSpec,
        backend: Box<dyn Device>,
        subst: SubstMode,
        residual_samples: usize,
        storage: FactorStorage,
        verify_plan: bool,
        max_solve_threads: usize,
    ) -> Result<H2Solver, H2Error> {
        let scope = FlopScope::new();
        let run_trace = RunTrace::new();
        let (h2, construct_time) = construct_timed(&geometry, &kernel, &config)?;
        run_trace.push_completed(NO_LEVEL, "construct", 0, (0, 0), construct_time);
        let plan = Arc::new(guard("planning", || plan::record(&h2))?);
        if verify_plan {
            plan::verify::verify(&plan).map_err(|v| H2Error::PlanVerification(v.to_string()))?;
        }
        let meta = plan.factor_meta();
        let (factor, arena, stats) = replay_factor(
            &plan,
            &h2,
            backend.as_ref(),
            &scope,
            &run_trace,
            construct_time,
            storage,
            &meta,
        )?;
        Ok(H2Solver {
            geometry,
            kernel,
            spec,
            backend,
            arena,
            pool: WorkspacePool::new(),
            storage,
            subst,
            residual_samples,
            h2,
            plan,
            factor,
            meta,
            stats,
            scope,
            run_trace,
            solved_rhs: AtomicUsize::new(0),
            solve_overlap: Mutex::new(OverlapTrace::default()),
            dist_sessions: Mutex::new(HashMap::new()),
            max_solve_threads,
            plan_recordings: 1,
            verify_plan,
        })
    }

    /// Matrix dimension N.
    pub fn n(&self) -> usize {
        self.h2.n()
    }

    /// Timings and footprint of the last build/refactorize/rebind.
    pub fn stats(&self) -> &BuildStats {
        &self.stats
    }

    /// Active configuration.
    pub fn config(&self) -> &H2Config {
        &self.h2.cfg
    }

    /// Name of the instantiated backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Default substitution mode for [`solve`](H2Solver::solve).
    pub fn subst_mode(&self) -> SubstMode {
        self.subst
    }

    /// The factor-storage policy this session was built with.
    pub fn factor_storage(&self) -> FactorStorage {
        self.storage
    }

    /// Low-level access to the H² matrix (benchmarks, diagnostics).
    pub fn matrix(&self) -> &H2Matrix {
        &self.h2
    }

    /// The host-side factor mirror: `Some` under
    /// [`FactorStorage::Mirrored`] (the default), `None` under
    /// [`FactorStorage::DeviceOnly`] — shape queries then go through
    /// [`factor_meta`](H2Solver::factor_meta) and values through
    /// [`download_block`](H2Solver::download_block).
    pub fn factor(&self) -> Option<&UlvFactor> {
        self.factor.as_ref()
    }

    /// Shape-only description of the factor (block dimensions, ranks,
    /// level layout). Always available — it is derived from the recorded
    /// plan, never from the mirror.
    pub fn factor_meta(&self) -> &FactorMeta {
        &self.meta
    }

    /// Download one factor block from the device-resident factor region —
    /// the on-demand value path for [`FactorStorage::DeviceOnly`]
    /// sessions (works in both modes; under `Mirrored`,
    /// [`factor`](H2Solver::factor) is the cheaper host-side read).
    pub fn download_block(&self, block: FactorBlock) -> Result<Matrix, H2Error> {
        let outputs = &self.plan.factor.outputs;
        let buf = match block {
            FactorBlock::Root => Some(self.plan.factor.root_src),
            FactorBlock::CholRr { level, box_index } => {
                outputs.get(level).and_then(|o| o.chol_rr.get(box_index)).copied()
            }
            FactorBlock::Basis { level, box_index } => {
                outputs.get(level).and_then(|o| o.basis.get(box_index)).copied()
            }
            FactorBlock::Lr { level, pair } => outputs
                .get(level)
                .and_then(|o| o.lr.iter().find(|&&(k, _)| k == pair))
                .map(|&(_, b)| b),
            FactorBlock::Ls { level, pair } => outputs
                .get(level)
                .and_then(|o| o.ls.iter().find(|&&(k, _)| k == pair))
                .map(|&(_, b)| b),
        };
        match buf {
            Some(b) => {
                self.backend.fence();
                Ok(self.arena.download(b))
            }
            None => Err(H2Error::InvalidConfig(format!(
                "no such factor block: {block:?} (levels index FactorMeta::levels, leaf first)"
            ))),
        }
    }

    /// The cached execution plan (launch schedule, FLOP/padding metadata).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// How many times this session has *recorded* a plan. Stays at 1 as
    /// long as refactorizations keep the H² structure and backends are
    /// only rebound — the assertion hook for "no re-planning occurs".
    pub fn plan_recordings(&self) -> usize {
        self.plan_recordings
    }

    /// This session's FLOP counters (scoped; see
    /// [`crate::metrics::flops::FlopScope`]).
    pub fn flop_scope(&self) -> &FlopScope {
        &self.scope
    }

    /// Live buffers in the resident factor region — constant between
    /// builds (solves never touch it), the no-leak assertion hook.
    pub fn resident_buffers(&self) -> usize {
        self.arena.live()
    }

    /// Workspace-pool counters `(created, idle)`: `created` is the number
    /// of regions the pool currently owns (tracks the high-water mark of
    /// concurrently in-flight solves until
    /// [`trim_workspaces`](H2Solver::trim_workspaces) drops some); the two
    /// are equal whenever no solve is running (leased regions always come
    /// back, even on panic).
    pub fn workspace_stats(&self) -> (usize, usize) {
        (self.pool.created(), self.pool.idle())
    }

    /// Bytes pinned by the idle workspace regions (allocator bookkeeping —
    /// idle regions carry no payload). Grows with the session's solve
    /// concurrency high-water mark; release it with
    /// [`trim_workspaces`](H2Solver::trim_workspaces).
    pub fn workspace_bytes(&self) -> usize {
        self.pool.bytes()
    }

    /// Drop idle workspace regions until at most `keep` remain, returning
    /// how many were dropped. Safe concurrently with in-flight solves
    /// (leased regions are untouched and return to the pool as usual) —
    /// the hook long-lived owners call on idle/evict paths so a burst of
    /// concurrent solves doesn't pin peak workspace memory forever.
    pub fn trim_workspaces(&self, keep: usize) -> usize {
        self.pool.shrink_to(keep)
    }

    /// Bytes held by the device-resident factor region — the session's
    /// dominant resident cost and the quantity the serve-layer cache
    /// budgets its LRU eviction on.
    pub fn resident_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Right-hand sides solved so far through any entry point.
    pub fn solved_rhs(&self) -> usize {
        self.solved_rhs.load(Ordering::Relaxed)
    }

    /// The session-wide `solve_many` worker cap (0 = scale to available
    /// parallelism), as set by
    /// [`max_solve_threads`](crate::solver::H2SolverBuilder::max_solve_threads).
    pub fn max_solve_threads(&self) -> usize {
        self.max_solve_threads
    }

    /// Solve `A x = b` with `b` in the caller's original point ordering;
    /// the returned [`SolveReport::x`] is in original ordering too. All
    /// tree-order permutation happens inside.
    ///
    /// Concurrency: solves share the session's resident factor region
    /// read-only and lease a private vector workspace from the session's
    /// pool, so **any number of threads may call `solve` on one session
    /// simultaneously** — results are bit-identical to sequential calls,
    /// and no lock is held across kernel launches.
    ///
    /// ```
    /// use h2ulv::prelude::*;
    ///
    /// let solver = H2SolverBuilder::new(Geometry::sphere_surface(96, 1), KernelFn::laplace())
    ///     .config(H2Config { leaf_size: 32, max_rank: 24, ..Default::default() })
    ///     .build()?;
    /// let b = vec![1.0; solver.n()];
    /// let report = solver.solve(&b)?;
    /// assert!(report.residual.unwrap() < 1e-2);
    ///
    /// // Malformed input is a typed error, not a panic:
    /// let err = solver.solve(&b[..10]).unwrap_err();
    /// assert!(matches!(err, H2Error::DimensionMismatch { expected: 96, got: 10 }));
    /// # Ok::<(), h2ulv::solver::H2Error>(())
    /// ```
    pub fn solve(&self, b: &[f64]) -> Result<SolveReport, H2Error> {
        self.solve_opts(b, &SolveOptions::default())
    }

    /// [`solve`](H2Solver::solve) with an explicit substitution mode
    /// (overriding the builder's choice for this call only).
    pub fn solve_with(&self, b: &[f64], mode: SubstMode) -> Result<SolveReport, H2Error> {
        self.solve_opts(b, &SolveOptions { subst_mode: Some(mode), ..Default::default() })
    }

    /// [`solve`](H2Solver::solve) with per-call overrides — e.g. skip the
    /// sampled-residual cost when the report's residual will be discarded:
    ///
    /// ```no_run
    /// # use h2ulv::prelude::*;
    /// # let solver = H2SolverBuilder::new(Geometry::sphere_surface(96, 1), KernelFn::laplace()).build()?;
    /// # let b = vec![1.0; solver.n()];
    /// let report = solver.solve_opts(&b, &SolveOptions::no_residual())?;
    /// assert!(report.residual.is_none());
    /// # Ok::<(), h2ulv::solver::H2Error>(())
    /// ```
    pub fn solve_opts(&self, b: &[f64], opts: &SolveOptions) -> Result<SolveReport, H2Error> {
        self.check_rhs(b)?;
        let mode = opts.subst_mode.unwrap_or(self.subst);
        let bt = self.h2.tree.permute_vec(b);
        // Lease a workspace; the factor region is shared read-only. The
        // lease returns to the pool when `ws` drops — panic or not.
        let mut ws = self.pool.acquire(self.backend.as_ref());
        let (res, subst_time) = timed(|| {
            guard("substitution", || {
                Executor::new(self.backend.as_ref())
                    .with_scope(&self.scope)
                    .solve_in(&self.plan, self.arena.as_ref(), ws.region(), &bt, mode)
            })
        });
        drop(ws);
        self.run_trace.push_completed(NO_LEVEL, "substitution", 1, (self.n(), 1), subst_time);
        let xt = res?;
        self.solved_rhs.fetch_add(1, Ordering::Relaxed);
        let residual = self.sample_residual_opts(&xt, &bt, opts);
        let x = self.h2.tree.unpermute_vec(&xt);
        Ok(SolveReport {
            x,
            subst_time,
            residual,
            iterations: 1,
            subst_mode: mode,
            backend: self.backend.name(),
        })
    }

    /// Solve one factorization against many right-hand sides by replaying
    /// the cached substitution program per RHS — no re-planning. Lengths
    /// are validated up front so either every RHS is solved or none is.
    ///
    /// The solves **fan out across the workspace pool**: worker threads
    /// (up to the machine's parallelism, capped by the builder's
    /// [`max_solve_threads`](crate::solver::H2SolverBuilder::max_solve_threads)
    /// or a per-call [`SolveOptions::max_threads`]) each lease their own
    /// vector region and replay concurrently against the shared factor
    /// region. Reports come back in input order and are bit-identical to
    /// sequential [`solve_opts`](H2Solver::solve_opts) calls — the thread
    /// cap changes scheduling only, never results.
    pub fn solve_many(&self, rhs: &[Vec<f64>]) -> Result<Vec<SolveReport>, H2Error> {
        self.solve_many_opts(rhs, &SolveOptions::default())
    }

    /// [`solve_many`](H2Solver::solve_many) with per-call overrides
    /// applied to every right-hand side.
    pub fn solve_many_opts(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
    ) -> Result<Vec<SolveReport>, H2Error> {
        for b in rhs {
            self.check_rhs(b)?;
        }
        // Fan-out width: available parallelism, capped by the session-wide
        // builder setting unless the call overrides it (0 = uncapped in
        // both positions).
        let cap = match opts.max_threads {
            Some(n) if n > 0 => n,
            _ => {
                if self.max_solve_threads > 0 {
                    self.max_solve_threads
                } else {
                    usize::MAX
                }
            }
        };
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(cap)
            .min(rhs.len());
        if workers <= 1 {
            return rhs.iter().map(|b| self.solve_opts(b, opts)).collect();
        }
        // Fan out: an atomic cursor hands indices to workers; each solve
        // leases its own workspace, so the replays run simultaneously.
        let results: Vec<Mutex<Option<Result<SolveReport, H2Error>>>> =
            rhs.iter().map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= rhs.len() {
                        break;
                    }
                    *results[i].lock().unwrap() = Some(self.solve_opts(&rhs[i], opts));
                });
            }
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("every index was claimed by a worker"))
            .collect()
    }

    /// Direct solve + ULV-preconditioned CG refinement until the relative
    /// residual (w.r.t. the H² operator) drops below `tol`. Recovers full
    /// accuracy from aggressively compressed factorizations at O(N) cost
    /// per iteration (paper §3.7: "direct solver or preconditioner").
    /// Like [`solve`](H2Solver::solve), safe to call from many threads at
    /// once (each refinement leases its own workspace).
    pub fn solve_refined(
        &self,
        b: &[f64],
        tol: f64,
        max_iters: usize,
    ) -> Result<SolveReport, H2Error> {
        self.check_rhs(b)?;
        if tol <= 0.0 || tol.is_nan() {
            return Err(H2Error::InvalidConfig(format!(
                "refinement tolerance must be positive, got {tol}"
            )));
        }
        let bt = self.h2.tree.permute_vec(b);
        let mut ws = self.pool.acquire(self.backend.as_ref());
        let (res, subst_time) = timed(|| {
            guard("refined substitution", || {
                pcg_in(
                    &self.h2,
                    &self.plan,
                    self.backend.as_ref(),
                    self.arena.as_ref(),
                    ws.region(),
                    &bt,
                    tol,
                    max_iters,
                )
            })
        });
        drop(ws);
        self.run_trace.push_completed(NO_LEVEL, "substitution", 1, (self.n(), 1), subst_time);
        let result = res?;
        self.solved_rhs.fetch_add(1, Ordering::Relaxed);
        if result.rel_residual > tol {
            return Err(H2Error::ConvergenceFailure {
                achieved: result.rel_residual,
                target: tol,
                iterations: result.iters,
            });
        }
        let residual = self.sample_residual(&result.x, &bt);
        let x = self.h2.tree.unpermute_vec(&result.x);
        Ok(SolveReport {
            x,
            subst_time,
            residual,
            iterations: result.iters,
            subst_mode: SubstMode::Parallel,
            backend: self.backend.name(),
        })
    }

    /// Real multi-rank SPMD solve over `ranks` ranks (paper §5): the
    /// recorded plan is carved into per-rank streams
    /// ([`crate::plan::carve`]), each rank runs on its **own** device
    /// instance against its **own** rank-sharded arena (thread-per-rank
    /// behind the [`crate::dist::exec::Transport`] seam), and ranks meet
    /// only at the plan's explicit `Exchange` instructions. The first
    /// call for a rank count runs the distributed factorization and
    /// caches the [`DistSession`]; later calls replay the carved
    /// substitution against the resident shards. The report carries both
    /// the α-β *prediction* (times modeled with [`NCCL_LIKE`]) and the
    /// transports' *measured* communication.
    pub fn solve_dist(&self, b: &[f64], ranks: usize) -> Result<DistSolveReport, H2Error> {
        self.check_rhs(b)?;
        let session = self.dist_session(ranks)?;
        let bt = self.h2.tree.permute_vec(b);
        let (res, subst_time) =
            timed(|| guard("distributed solve", || session.solve(&bt)));
        self.run_trace.push_completed(NO_LEVEL, "substitution", 1, (self.n(), 1), subst_time);
        let (xt, subst_comm) = res?;
        self.solved_rhs.fetch_add(1, Ordering::Relaxed);
        let residual = self.sample_residual(&xt, &bt);
        let x = self.h2.tree.unpermute_vec(&xt);
        let report = model_report(&self.meta, session.ranks(), Vec::new());
        Ok(DistSolveReport {
            x,
            ranks: session.ranks(),
            factor_time: report.factor_time(&NCCL_LIKE),
            subst_time: report.subst_time(&NCCL_LIKE),
            factor_bytes: report.factor_bytes,
            subst_bytes: report.subst_bytes,
            measured: CommMeasurement { factor: session.factor_comm(), subst: subst_comm },
            residual,
        })
    }

    /// The cached multi-rank session for (clamped) `ranks`, building it —
    /// per-rank devices from the session's [`BackendSpec`], distributed
    /// factorization, rank-sharded arenas — on first use. The cache lock
    /// is held across a build, so concurrent first solves at one rank
    /// count factorize once.
    fn dist_session(&self, ranks: usize) -> Result<Arc<DistSession>, H2Error> {
        let p = plan::rank::clamp_ranks(ranks, self.meta.depth);
        let mut cache = self.dist_sessions.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(s) = cache.get(&p) {
            if s.mode() == self.subst {
                return Ok(s.clone());
            }
        }
        let session = Arc::new(guard("distributed factorization", || {
            DistSession::build(&self.spec, &self.plan, &self.h2, p, self.subst)
        })??);
        cache.insert(p, session.clone());
        Ok(session)
    }

    /// Rebuild the H² matrix and the ULV factor with a new configuration
    /// (changed rank budget / tolerance / admissibility), reusing the
    /// stored geometry, kernel, backend, and storage policy. When the new
    /// configuration keeps the block structure (same tree, lists, and
    /// ranks — e.g. only kernel values changed through an identical
    /// config), the cached plan is *replayed* without re-recording;
    /// otherwise a new plan is recorded. Returns the new build stats.
    pub fn refactorize(&mut self, config: H2Config) -> Result<&BuildStats, H2Error> {
        validate(&self.geometry, &config)?;
        let (h2, construct_time) = construct_timed(&self.geometry, &self.kernel, &config)?;
        self.run_trace.push_completed(NO_LEVEL, "construct", 0, (0, 0), construct_time);
        let plan = if self.plan.compatible(&h2) {
            self.plan.clone()
        } else {
            let plan = Arc::new(guard("planning", || plan::record(&h2))?);
            if self.verify_plan {
                plan::verify::verify(&plan)
                    .map_err(|v| H2Error::PlanVerification(v.to_string()))?;
            }
            self.plan_recordings += 1;
            plan
        };
        let meta = plan.factor_meta();
        let (factor, arena, stats) = replay_factor(
            &plan,
            &h2,
            self.backend.as_ref(),
            &self.scope,
            &self.run_trace,
            construct_time,
            self.storage,
            &meta,
        )?;
        // Stale by construction: the accumulated solve-path events refer
        // to the factor that was just replaced.
        *self.solve_overlap.lock().unwrap_or_else(|p| p.into_inner()) = OverlapTrace::default();
        // Multi-rank sessions shard the factor that was just replaced.
        self.dist_sessions.get_mut().unwrap_or_else(|p| p.into_inner()).clear();
        self.h2 = h2;
        self.plan = plan;
        self.factor = factor;
        self.meta = meta;
        self.arena = arena;
        // Workspace sizes depend on the solve programs: retire the old
        // regions (they would be resized on next use anyway, but a fresh
        // pool keeps the footprint tight after a shrink).
        self.pool = WorkspacePool::new();
        self.stats = stats;
        Ok(&self.stats)
    }

    /// Re-execute the cached plan on a different backend *without*
    /// rebuilding the H² matrix or re-deriving the schedule: the same
    /// instruction stream is replayed against the new [`BackendSpec`],
    /// which re-materializes the buffer arena on the new device (the
    /// host-side H² matrix is the transport — this is how the factor
    /// "moves" across devices). Backend comparisons (native vs PJRT vs
    /// serial) share one H² construction this way. Returns the new build
    /// stats (`construct_time` is 0 — nothing was constructed).
    pub fn rebind_backend(&mut self, spec: BackendSpec) -> Result<&BuildStats, H2Error> {
        let backend = spec.instantiate()?;
        let (factor, arena, stats) = replay_factor(
            &self.plan,
            &self.h2,
            backend.as_ref(),
            &self.scope,
            &self.run_trace,
            0.0,
            self.storage,
            &self.meta,
        )?;
        // The old device's trace epoch dies with it; events from before
        // the rebind cannot be merged with the new backend's.
        *self.solve_overlap.lock().unwrap_or_else(|p| p.into_inner()) = OverlapTrace::default();
        // Multi-rank sessions were built from the old backend spec.
        self.dist_sessions.get_mut().unwrap_or_else(|p| p.into_inner()).clear();
        self.spec = spec;
        self.backend = backend;
        self.factor = factor;
        self.arena = arena;
        // Old regions belong to the old device; lease fresh ones lazily.
        self.pool = WorkspacePool::new();
        self.stats = stats;
        Ok(&self.stats)
    }

    /// The backend spec this session was built with (or last rebound to).
    pub fn backend_spec(&self) -> &BackendSpec {
        &self.spec
    }

    /// The session's structured span trace: `construct` → `factorize` →
    /// per-level replay spans → one `substitution` span per solved RHS.
    /// Clones share the buffer, so holding one across solves observes
    /// them live.
    pub fn run_trace(&self) -> &RunTrace {
        &self.run_trace
    }

    /// Fold solve-path overlap events still sitting in the backend's
    /// engine into the session-held accumulator. Draining the *backend* is
    /// safe at any time — the session trace keeps every event, so repeated
    /// report calls never lose history.
    fn sync_solve_overlap(&self) {
        if let Some(tr) = self.backend.take_overlap_trace() {
            let mut acc = self.solve_overlap.lock().unwrap_or_else(|p| p.into_inner());
            acc.events.extend(tr.events);
        }
    }

    /// Drain and return the accumulated solve-path overlap trace, leaving
    /// the session's accumulator empty — the *explicit* reset for callers
    /// that want per-interval deltas (e.g. a monitoring scrape that
    /// windows overlap per reporting period). [`run_report`]
    /// (H2Solver::run_report) itself never drains: it snapshots, so calling
    /// it twice on a live server session reports the same (monotonically
    /// growing) history both times.
    pub fn take_solve_overlap(&self) -> OverlapTrace {
        self.sync_solve_overlap();
        std::mem::take(&mut *self.solve_overlap.lock().unwrap_or_else(|p| p.into_inner()))
    }

    /// Condense the session into the serializable [`RunReport`] that
    /// benchmark trajectory files (`BENCH_*.json`) persist.
    ///
    /// Launch counts and FLOPs come from the *static* plan schedule
    /// ([`ScheduleStats`]), not measured counters — bit-deterministic for
    /// a fixed structure, which is what the trajectory comparator is
    /// strict about. Wall times come from the run trace and are noisy.
    /// Overlap metrics merge the factorization replay's trace
    /// ([`BuildStats::overlap`]) with accumulated solve-path events; all
    /// are 0 on host-synchronous backends.
    ///
    /// **Snapshot semantics**: this method synchronizes with the backend
    /// but does not reset anything — solve-overlap counters
    /// (`solve_trace_events`, `overlap_ratio`) are cumulative since the
    /// last factorization replay, so a second `run_report()` on a live
    /// server session sees everything the first one saw plus whatever
    /// happened in between. Callers that want windowed deltas drain
    /// explicitly with [`take_solve_overlap`](H2Solver::take_solve_overlap).
    pub fn run_report(&self) -> RunReport {
        // The factor-phase events were drained into `BuildStats` when the
        // replay finished; solve-path events accumulate in the session.
        self.sync_solve_overlap();
        let solve = self.solve_overlap.lock().unwrap_or_else(|p| p.into_inner()).clone();
        let combined = match &self.stats.overlap {
            Some(factor_tr) => {
                let mut all = factor_tr.clone();
                all.events.extend(solve.events.iter().cloned());
                Some(all)
            }
            None if !solve.events.is_empty() => Some(solve.clone()),
            None => None,
        };
        let (overlap_ratio, overlapped_transfer_pairs) = overlap_metrics(combined.as_ref());
        // Solve-path split: the same metrics over the substitution trace
        // alone, so the report shows whether *solves* pipelined (the
        // combined ratio is dominated by the factorization replay).
        let (solve_overlap_ratio, solve_overlapped_transfer_pairs) =
            overlap_metrics(if solve.events.is_empty() { None } else { Some(&solve) });
        let sched = &self.stats.schedule;
        RunReport {
            schema_version: RUN_REPORT_SCHEMA_VERSION,
            backend: self.backend.name().to_string(),
            n: self.stats.n,
            depth: self.stats.depth,
            rhs: self.solved_rhs.load(Ordering::Relaxed),
            construct_time: self.stats.construct_time,
            factor_time: self.stats.factor_time,
            solve_time: self.run_trace.phase_time("substitution"),
            factor_launches: sched.factor_launches(),
            factor_flops: sched.factor_flops(),
            factor_padded_flops: sched.factor_padded_flops(),
            factor_levels: level_reports(&sched.factor_levels),
            solve_levels: level_reports(&sched.solve_levels),
            overlap_ratio,
            overlapped_transfer_pairs,
            solve_trace_events: solve.events.len(),
            solve_overlap_ratio,
            solve_overlapped_transfer_pairs,
            arena_bytes: self.stats.arena_bytes as u64,
            arena_peak_bytes: self.stats.arena_peak_bytes as u64,
            predicted_peak_bytes: self.stats.predicted_peak_bytes as u64,
        }
    }

    fn check_rhs(&self, b: &[f64]) -> Result<(), H2Error> {
        if b.len() != self.n() {
            return Err(H2Error::DimensionMismatch { expected: self.n(), got: b.len() });
        }
        Ok(())
    }

    /// Sampled exact-kernel residual of a tree-ordered solution (or `None`
    /// when sampling is disabled).
    fn sample_residual(&self, xt: &[f64], bt: &[f64]) -> Option<f64> {
        if self.residual_samples == 0 {
            return None;
        }
        Some(self.h2.residual_sampled(xt, bt, self.residual_samples, RESIDUAL_SEED))
    }

    /// [`sample_residual`](H2Solver::sample_residual) with the per-call
    /// override applied.
    fn sample_residual_opts(&self, xt: &[f64], bt: &[f64], opts: &SolveOptions) -> Option<f64> {
        match opts.sample_residual {
            Some(false) => None,
            Some(true) => {
                let samples = if self.residual_samples > 0 {
                    self.residual_samples
                } else {
                    DEFAULT_RESIDUAL_SAMPLES
                };
                Some(self.h2.residual_sampled(xt, bt, samples, RESIDUAL_SEED))
            }
            None => self.sample_residual(xt, bt),
        }
    }
}

/// Serializable mirror of a level-aggregated schedule slice.
fn level_reports(levels: &[LevelScheduleStats]) -> Vec<LevelReport> {
    levels
        .iter()
        .map(|l| LevelReport {
            level: l.level,
            launches: l.launches,
            batch_items: l.batch_items,
            flops: l.flops,
            padded_flops: l.padded_flops,
        })
        .collect()
}

/// Guarded, timed H² construction.
fn construct_timed(
    geometry: &Geometry,
    kernel: &KernelFn,
    config: &H2Config,
) -> Result<(H2Matrix, f64), H2Error> {
    let (res, t) = timed(|| {
        guard("construction", || H2Matrix::construct(geometry, kernel, config))
    });
    Ok((res?, t))
}

/// Guarded plan replay shared by `build()`, `refactorize()`, and
/// `rebind_backend()`: executes the factorization program, keeps the
/// factor resident in the device arena (with or without a host mirror, per
/// the storage policy), and derives the session's [`BuildStats`] from the
/// scope, the meta, and the plan IR.
#[allow(clippy::type_complexity)]
fn replay_factor(
    plan: &Arc<Plan>,
    h2: &H2Matrix,
    backend: &dyn Device,
    scope: &FlopScope,
    trace: &RunTrace,
    construct_time: f64,
    storage: FactorStorage,
    meta: &FactorMeta,
) -> Result<(Option<UlvFactor>, Box<dyn DeviceArena>, BuildStats), H2Error> {
    let before = scope.snapshot();
    let ((factor, arena), factor_time) = {
        let (res, t) = timed(|| {
            guard("factorization", || {
                let exec = Executor::new(backend).with_scope(scope).with_trace(trace.clone());
                match storage {
                    FactorStorage::Mirrored => {
                        let (f, a) = exec.factorize_resident(plan, h2);
                        (Some(f), a)
                    }
                    FactorStorage::DeviceOnly => (None, exec.factorize_device_only(plan, h2)),
                }
            })
        });
        (res?, t)
    };
    trace.push_completed(NO_LEVEL, "factorize", 0, (0, 0), factor_time);
    let factor_flops = scope.snapshot().factor - before.factor;
    let stats = BuildStats {
        n: h2.n(),
        depth: h2.tree.depth,
        construct_time,
        factor_time,
        factor_flops,
        h2_entries: h2.storage_entries(),
        factor_entries: meta.storage_entries(),
        mirror_entries: factor.as_ref().map(|f| f.storage_entries()).unwrap_or(0),
        arena_bytes: arena.bytes(),
        arena_peak_bytes: arena.peak_bytes(),
        predicted_peak_bytes: plan::verify::predicted_peak_bytes(plan).unwrap_or(0),
        schedule: plan.schedule_stats(),
        // Drains and takes the replay's per-stream schedule on overlapping
        // backends; `None` on the synchronous ones.
        overlap: backend.take_overlap_trace(),
    };
    // The static liveness analysis is exact on host-synchronous backends
    // (overlapping executors may transiently exceed it; non-tracking
    // arenas report 0).
    debug_assert!(
        stats.overlap.is_some()
            || stats.arena_peak_bytes == 0
            || stats.arena_peak_bytes == stats.predicted_peak_bytes,
        "static peak prediction diverged from the arena: predicted {} B, measured {} B",
        stats.predicted_peak_bytes,
        stats.arena_peak_bytes
    );
    Ok((factor, arena, stats))
}
