//! The [`H2Solver`] session: owns the H² matrix, the ULV factor, and the
//! execution backend; every solve handles tree-order permutation
//! internally and reports through [`SolveReport`].

use super::backend::BackendSpec;
use super::builder::validate;
use super::{guard, H2Error};
use crate::batch::BatchExec;
use crate::construct::H2Config;
use crate::dist::{dist_solve_driver_with, NCCL_LIKE};
use crate::geometry::Geometry;
use crate::h2::H2Matrix;
use crate::kernels::KernelFn;
use crate::metrics::{flops, timer::timed};
use crate::ulv::{factorize, pcg, SubstMode, UlvFactor};

/// Seed for the sampled residual estimator (fixed so reports are
/// reproducible across solves of the same problem).
const RESIDUAL_SEED: u64 = 0xCAFE;

/// Timings and footprint of one `build()`/`refactorize()`.
#[derive(Clone, Debug)]
pub struct BuildStats {
    /// Matrix dimension N.
    pub n: usize,
    /// Cluster-tree depth (leaf level index).
    pub depth: usize,
    /// H² construction wall time in seconds.
    pub construct_time: f64,
    /// ULV factorization wall time in seconds.
    pub factor_time: f64,
    /// FLOPs attributed to the factorization phase.
    pub factor_flops: u64,
    /// H² storage footprint in f64 entries.
    pub h2_entries: usize,
    /// ULV factor storage footprint in f64 entries.
    pub factor_entries: usize,
}

/// Result of one [`H2Solver::solve`] (or one right-hand side of
/// [`H2Solver::solve_many`]).
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Solution in the caller's original point ordering.
    pub x: Vec<f64>,
    /// Substitution wall time in seconds.
    pub subst_time: f64,
    /// Sampled exact-kernel relative residual `|Ax-b|/|b|`, or `None` when
    /// the builder disabled residual sampling.
    pub residual: Option<f64>,
    /// Refinement iterations used (1 for a direct solve).
    pub iterations: usize,
    /// Substitution algorithm that produced `x`.
    pub subst_mode: SubstMode,
    /// Name of the backend that executed the batched kernels.
    pub backend: &'static str,
}

/// Result of a facade-level simulated distributed solve
/// ([`H2Solver::solve_dist`]). Times are modeled with [`NCCL_LIKE`]; use
/// [`crate::dist::dist_solve_driver`] directly for custom communication
/// models.
#[derive(Clone, Debug)]
pub struct DistSolveReport {
    /// Solution in the caller's original point ordering (identical across
    /// rank counts).
    pub x: Vec<f64>,
    /// Effective rank count (power of two, clamped to the leaf width).
    pub ranks: usize,
    /// Modeled factorization time (slowest rank + communication).
    pub factor_time: f64,
    /// Modeled substitution time.
    pub subst_time: f64,
    /// Factorization communication volume in bytes.
    pub factor_bytes: u64,
    /// Substitution communication volume in bytes.
    pub subst_bytes: u64,
    /// Sampled exact-kernel relative residual (as in [`SolveReport`]).
    pub residual: Option<f64>,
}

/// A built H² solver session: construction and factorization are done;
/// [`solve`](H2Solver::solve) is cheap and reusable across right-hand
/// sides.
pub struct H2Solver {
    geometry: Geometry,
    kernel: KernelFn,
    spec: BackendSpec,
    backend: Box<dyn BatchExec>,
    subst: SubstMode,
    residual_samples: usize,
    h2: H2Matrix,
    factor: UlvFactor,
    stats: BuildStats,
}

impl H2Solver {
    /// Construct + factorize (called by the builder; inputs are already
    /// validated).
    pub(crate) fn assemble(
        geometry: Geometry,
        kernel: KernelFn,
        config: H2Config,
        spec: BackendSpec,
        backend: Box<dyn BatchExec>,
        subst: SubstMode,
        residual_samples: usize,
    ) -> Result<H2Solver, H2Error> {
        let (h2, factor, stats) =
            build_pipeline(&geometry, &kernel, &config, backend.as_ref())?;
        Ok(H2Solver {
            geometry,
            kernel,
            spec,
            backend,
            subst,
            residual_samples,
            h2,
            factor,
            stats,
        })
    }

    /// Matrix dimension N.
    pub fn n(&self) -> usize {
        self.h2.n()
    }

    /// Timings and footprint of the last build/refactorize.
    pub fn stats(&self) -> &BuildStats {
        &self.stats
    }

    /// Active configuration.
    pub fn config(&self) -> &H2Config {
        &self.h2.cfg
    }

    /// Name of the instantiated backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Default substitution mode for [`solve`](H2Solver::solve).
    pub fn subst_mode(&self) -> SubstMode {
        self.subst
    }

    /// Low-level access to the H² matrix (benchmarks, diagnostics).
    pub fn matrix(&self) -> &H2Matrix {
        &self.h2
    }

    /// Low-level access to the ULV factor (benchmarks, diagnostics).
    pub fn factor(&self) -> &UlvFactor {
        &self.factor
    }

    /// Solve `A x = b` with `b` in the caller's original point ordering;
    /// the returned [`SolveReport::x`] is in original ordering too. All
    /// tree-order permutation happens inside.
    ///
    /// ```
    /// use h2ulv::prelude::*;
    ///
    /// let solver = H2SolverBuilder::new(Geometry::sphere_surface(96, 1), KernelFn::laplace())
    ///     .config(H2Config { leaf_size: 32, max_rank: 24, ..Default::default() })
    ///     .build()?;
    /// let b = vec![1.0; solver.n()];
    /// let report = solver.solve(&b)?;
    /// assert!(report.residual.unwrap() < 1e-2);
    ///
    /// // Malformed input is a typed error, not a panic:
    /// let err = solver.solve(&b[..10]).unwrap_err();
    /// assert!(matches!(err, H2Error::DimensionMismatch { expected: 96, got: 10 }));
    /// # Ok::<(), h2ulv::solver::H2Error>(())
    /// ```
    pub fn solve(&self, b: &[f64]) -> Result<SolveReport, H2Error> {
        self.solve_with(b, self.subst)
    }

    /// [`solve`](H2Solver::solve) with an explicit substitution mode
    /// (overriding the builder's choice for this call only).
    pub fn solve_with(&self, b: &[f64], mode: SubstMode) -> Result<SolveReport, H2Error> {
        self.check_rhs(b)?;
        let bt = self.h2.tree.permute_vec(b);
        let (xt, subst_time) = {
            let (res, t) = timed(|| {
                guard("substitution", || {
                    self.factor.solve_tree_order(&bt, self.backend.as_ref(), mode)
                })
            });
            (res?, t)
        };
        let residual = self.sample_residual(&xt, &bt);
        let x = self.h2.tree.unpermute_vec(&xt);
        Ok(SolveReport {
            x,
            subst_time,
            residual,
            iterations: 1,
            subst_mode: mode,
            backend: self.backend.name(),
        })
    }

    /// Solve one factorization against many right-hand sides. Lengths are
    /// validated up front so either every RHS is solved or none is.
    pub fn solve_many(&self, rhs: &[Vec<f64>]) -> Result<Vec<SolveReport>, H2Error> {
        for b in rhs {
            self.check_rhs(b)?;
        }
        rhs.iter().map(|b| self.solve_with(b, self.subst)).collect()
    }

    /// Direct solve + ULV-preconditioned CG refinement until the relative
    /// residual (w.r.t. the H² operator) drops below `tol`. Recovers full
    /// accuracy from aggressively compressed factorizations at O(N) cost
    /// per iteration (paper §3.7: "direct solver or preconditioner").
    pub fn solve_refined(
        &self,
        b: &[f64],
        tol: f64,
        max_iters: usize,
    ) -> Result<SolveReport, H2Error> {
        self.check_rhs(b)?;
        if tol <= 0.0 || tol.is_nan() {
            return Err(H2Error::InvalidConfig(format!(
                "refinement tolerance must be positive, got {tol}"
            )));
        }
        let bt = self.h2.tree.permute_vec(b);
        let (result, subst_time) = {
            let (res, t) = timed(|| {
                guard("refined substitution", || {
                    pcg(&self.h2, &self.factor, self.backend.as_ref(), &bt, tol, max_iters)
                })
            });
            (res?, t)
        };
        if result.rel_residual > tol {
            return Err(H2Error::ConvergenceFailure {
                achieved: result.rel_residual,
                target: tol,
                iterations: result.iters,
            });
        }
        let residual = self.sample_residual(&result.x, &bt);
        let x = self.h2.tree.unpermute_vec(&result.x);
        Ok(SolveReport {
            x,
            subst_time,
            residual,
            iterations: result.iters,
            subst_mode: SubstMode::Parallel,
            backend: self.backend.name(),
        })
    }

    /// Simulated distributed solve over `ranks` ranks (paper §5); times
    /// are modeled with [`NCCL_LIKE`]. The solution is identical to
    /// [`solve`](H2Solver::solve) for every rank count. Reuses the
    /// session's ULV factor and backend — only the substitution runs per
    /// call; the factorization cost in the report is modeled.
    pub fn solve_dist(&self, b: &[f64], ranks: usize) -> Result<DistSolveReport, H2Error> {
        self.check_rhs(b)?;
        let bt = self.h2.tree.permute_vec(b);
        let report = guard("distributed solve", || {
            dist_solve_driver_with(
                &self.h2,
                &self.factor,
                self.backend.as_ref(),
                ranks,
                &bt,
                self.subst,
            )
        })?;
        let residual = self.sample_residual(&report.x, &bt);
        let x = self.h2.tree.unpermute_vec(&report.x);
        Ok(DistSolveReport {
            x,
            ranks: report.ranks,
            factor_time: report.factor_time(&NCCL_LIKE),
            subst_time: report.subst_time(&NCCL_LIKE),
            factor_bytes: report.factor_bytes,
            subst_bytes: report.subst_bytes,
            residual,
        })
    }

    /// Rebuild the H² matrix and the ULV factor with a new configuration
    /// (changed rank budget / tolerance / admissibility), reusing the
    /// stored geometry, kernel, and backend. Returns the new build stats.
    pub fn refactorize(&mut self, config: H2Config) -> Result<&BuildStats, H2Error> {
        validate(&self.geometry, &config)?;
        let (h2, factor, stats) =
            build_pipeline(&self.geometry, &self.kernel, &config, self.backend.as_ref())?;
        self.h2 = h2;
        self.factor = factor;
        self.stats = stats;
        Ok(&self.stats)
    }

    /// The backend spec this session was built with.
    pub fn backend_spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn check_rhs(&self, b: &[f64]) -> Result<(), H2Error> {
        if b.len() != self.n() {
            return Err(H2Error::DimensionMismatch { expected: self.n(), got: b.len() });
        }
        Ok(())
    }

    /// Sampled exact-kernel residual of a tree-ordered solution (or `None`
    /// when sampling is disabled).
    fn sample_residual(&self, xt: &[f64], bt: &[f64]) -> Option<f64> {
        if self.residual_samples == 0 {
            return None;
        }
        Some(self.h2.residual_sampled(xt, bt, self.residual_samples, RESIDUAL_SEED))
    }
}

/// Guarded construct + factorize shared by `build()` and `refactorize()`.
fn build_pipeline(
    geometry: &Geometry,
    kernel: &KernelFn,
    config: &H2Config,
    backend: &dyn BatchExec,
) -> Result<(H2Matrix, UlvFactor, BuildStats), H2Error> {
    let (h2, construct_time) = {
        let (res, t) = timed(|| {
            guard("construction", || H2Matrix::construct(geometry, kernel, config))
        });
        (res?, t)
    };
    let before = flops::snapshot();
    let (factor, factor_time) = {
        let (res, t) = timed(|| guard("factorization", || factorize(&h2, backend)));
        (res?, t)
    };
    let factor_flops = flops::delta(before, flops::snapshot()).factor;
    let stats = BuildStats {
        n: h2.n(),
        depth: h2.tree.depth,
        construct_time,
        factor_time,
        factor_flops,
        h2_entries: h2.storage_entries(),
        factor_entries: factor.storage_entries(),
    };
    Ok((h2, factor, stats))
}
