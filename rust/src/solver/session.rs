//! The [`H2Solver`] session: owns the H² matrix, the ULV factor, the
//! cached execution [`Plan`], and the execution backend; every solve
//! handles tree-order permutation internally and reports through
//! [`SolveReport`].
//!
//! The plan is recorded once per H² *structure*. Repeated solves,
//! [`H2Solver::refactorize`] with an unchanged structure, and
//! [`H2Solver::rebind_backend`] all replay the cached plan — schedule
//! discovery never runs twice ([`H2Solver::plan_recordings`] counts it).

use super::backend::BackendSpec;
use super::builder::validate;
use super::{guard, H2Error};
use crate::batch::device::{Device, DeviceArena};
use crate::construct::H2Config;
use crate::dist::{dist_solve_driver_in, NCCL_LIKE};
use crate::geometry::Geometry;
use crate::h2::H2Matrix;
use crate::kernels::KernelFn;
use crate::metrics::{flops::FlopScope, timer::timed};
use crate::plan::{self, Executor, Plan, ScheduleStats};
use crate::ulv::{pcg_in, SubstMode, UlvFactor};
use std::sync::{Arc, Mutex};

/// Seed for the sampled residual estimator (fixed so reports are
/// reproducible across solves of the same problem).
const RESIDUAL_SEED: u64 = 0xCAFE;

/// Fallback sample count when a per-call override requests a residual but
/// the builder disabled sampling.
const DEFAULT_RESIDUAL_SAMPLES: usize = 128;

/// Timings and footprint of one `build()`/`refactorize()`/
/// `rebind_backend()`.
#[derive(Clone, Debug)]
pub struct BuildStats {
    /// Matrix dimension N.
    pub n: usize,
    /// Cluster-tree depth (leaf level index).
    pub depth: usize,
    /// H² construction wall time in seconds (0 when the H² matrix was
    /// reused, i.e. after `rebind_backend`).
    pub construct_time: f64,
    /// ULV factorization wall time in seconds (plan replay only; schedule
    /// recording is a separate structural walk, not included).
    pub factor_time: f64,
    /// FLOPs attributed to the factorization phase of *this session*
    /// (scoped — concurrent sessions do not contaminate each other).
    pub factor_flops: u64,
    /// H² storage footprint in f64 entries.
    pub h2_entries: usize,
    /// ULV factor storage footprint in f64 entries.
    pub factor_entries: usize,
    /// Schedule statistics straight from the plan IR: launch counts per
    /// level, batch sizes, useful vs constant-shape padded FLOPs.
    pub schedule: ScheduleStats,
}

/// Per-call overrides for [`H2Solver::solve_opts`].
#[derive(Clone, Debug, Default)]
pub struct SolveOptions {
    /// Substitution algorithm; `None` uses the builder's choice.
    pub subst_mode: Option<SubstMode>,
    /// Override residual sampling for this call: `Some(false)` skips the
    /// sampled-residual cost even when the builder enabled it (for solves
    /// that discard [`SolveReport::residual`]); `Some(true)` forces an
    /// estimate even when the builder disabled sampling (using the
    /// builder's sample count, or 128 if it was 0). `None` follows the
    /// builder.
    pub sample_residual: Option<bool>,
}

impl SolveOptions {
    /// Shorthand for "skip the residual estimate on this call".
    pub fn no_residual() -> SolveOptions {
        SolveOptions { sample_residual: Some(false), ..Default::default() }
    }
}

/// Result of one [`H2Solver::solve`] (or one right-hand side of
/// [`H2Solver::solve_many`]).
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Solution in the caller's original point ordering.
    pub x: Vec<f64>,
    /// Substitution wall time in seconds.
    pub subst_time: f64,
    /// Sampled exact-kernel relative residual `|Ax-b|/|b|`, or `None` when
    /// sampling is disabled (builder default or per-call override).
    pub residual: Option<f64>,
    /// Refinement iterations used (1 for a direct solve).
    pub iterations: usize,
    /// Substitution algorithm that produced `x`.
    pub subst_mode: SubstMode,
    /// Name of the backend that executed the batched kernels.
    pub backend: &'static str,
}

/// Result of a facade-level simulated distributed solve
/// ([`H2Solver::solve_dist`]). Times are modeled with [`NCCL_LIKE`]; use
/// [`crate::dist::dist_solve_driver`] directly for custom communication
/// models.
#[derive(Clone, Debug)]
pub struct DistSolveReport {
    /// Solution in the caller's original point ordering (identical across
    /// rank counts).
    pub x: Vec<f64>,
    /// Effective rank count (power of two, clamped to the leaf width).
    pub ranks: usize,
    /// Modeled factorization time (slowest rank + communication).
    pub factor_time: f64,
    /// Modeled substitution time.
    pub subst_time: f64,
    /// Factorization communication volume in bytes.
    pub factor_bytes: u64,
    /// Substitution communication volume in bytes.
    pub subst_bytes: u64,
    /// Sampled exact-kernel relative residual (as in [`SolveReport`]).
    pub residual: Option<f64>,
}

/// A built H² solver session: construction, plan recording, and
/// factorization are done; [`solve`](H2Solver::solve) is cheap and
/// reusable across right-hand sides.
pub struct H2Solver {
    geometry: Geometry,
    kernel: KernelFn,
    spec: BackendSpec,
    backend: Box<dyn Device>,
    /// Device arena holding the factor resident (outputs + bases + root)
    /// since the last factorization replay; every solve replays the
    /// substitution program against these buffers without re-uploading.
    arena: Mutex<Box<dyn DeviceArena>>,
    subst: SubstMode,
    residual_samples: usize,
    h2: H2Matrix,
    plan: Arc<Plan>,
    factor: UlvFactor,
    stats: BuildStats,
    scope: FlopScope,
    plan_recordings: usize,
}

impl H2Solver {
    /// Construct + record + factorize (called by the builder; inputs are
    /// already validated).
    pub(crate) fn assemble(
        geometry: Geometry,
        kernel: KernelFn,
        config: H2Config,
        spec: BackendSpec,
        backend: Box<dyn Device>,
        subst: SubstMode,
        residual_samples: usize,
    ) -> Result<H2Solver, H2Error> {
        let scope = FlopScope::new();
        let (h2, construct_time) = construct_timed(&geometry, &kernel, &config)?;
        let plan = Arc::new(guard("planning", || plan::record(&h2))?);
        let (factor, arena, stats) =
            replay_factor(&plan, &h2, backend.as_ref(), &scope, construct_time)?;
        Ok(H2Solver {
            geometry,
            kernel,
            spec,
            backend,
            arena: Mutex::new(arena),
            subst,
            residual_samples,
            h2,
            plan,
            factor,
            stats,
            scope,
            plan_recordings: 1,
        })
    }

    /// Matrix dimension N.
    pub fn n(&self) -> usize {
        self.h2.n()
    }

    /// Timings and footprint of the last build/refactorize/rebind.
    pub fn stats(&self) -> &BuildStats {
        &self.stats
    }

    /// Active configuration.
    pub fn config(&self) -> &H2Config {
        &self.h2.cfg
    }

    /// Name of the instantiated backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Default substitution mode for [`solve`](H2Solver::solve).
    pub fn subst_mode(&self) -> SubstMode {
        self.subst
    }

    /// Low-level access to the H² matrix (benchmarks, diagnostics).
    pub fn matrix(&self) -> &H2Matrix {
        &self.h2
    }

    /// Low-level access to the ULV factor (benchmarks, diagnostics).
    pub fn factor(&self) -> &UlvFactor {
        &self.factor
    }

    /// The cached execution plan (launch schedule, FLOP/padding metadata).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// How many times this session has *recorded* a plan. Stays at 1 as
    /// long as refactorizations keep the H² structure and backends are
    /// only rebound — the assertion hook for "no re-planning occurs".
    pub fn plan_recordings(&self) -> usize {
        self.plan_recordings
    }

    /// This session's FLOP counters (scoped; see
    /// [`crate::metrics::flops::FlopScope`]).
    pub fn flop_scope(&self) -> &FlopScope {
        &self.scope
    }

    /// Solve `A x = b` with `b` in the caller's original point ordering;
    /// the returned [`SolveReport::x`] is in original ordering too. All
    /// tree-order permutation happens inside.
    ///
    /// Concurrency: solves on one session replay against the session's
    /// single resident device arena and are therefore **serialized** (the
    /// arena lock is held for the whole substitution). Threads that need
    /// parallel solves against one factorization should use separate
    /// sessions, or [`crate::ulv::UlvFactor::solve_tree_order`] with
    /// per-thread arenas.
    ///
    /// ```
    /// use h2ulv::prelude::*;
    ///
    /// let solver = H2SolverBuilder::new(Geometry::sphere_surface(96, 1), KernelFn::laplace())
    ///     .config(H2Config { leaf_size: 32, max_rank: 24, ..Default::default() })
    ///     .build()?;
    /// let b = vec![1.0; solver.n()];
    /// let report = solver.solve(&b)?;
    /// assert!(report.residual.unwrap() < 1e-2);
    ///
    /// // Malformed input is a typed error, not a panic:
    /// let err = solver.solve(&b[..10]).unwrap_err();
    /// assert!(matches!(err, H2Error::DimensionMismatch { expected: 96, got: 10 }));
    /// # Ok::<(), h2ulv::solver::H2Error>(())
    /// ```
    pub fn solve(&self, b: &[f64]) -> Result<SolveReport, H2Error> {
        self.solve_opts(b, &SolveOptions::default())
    }

    /// [`solve`](H2Solver::solve) with an explicit substitution mode
    /// (overriding the builder's choice for this call only).
    pub fn solve_with(&self, b: &[f64], mode: SubstMode) -> Result<SolveReport, H2Error> {
        self.solve_opts(b, &SolveOptions { subst_mode: Some(mode), ..Default::default() })
    }

    /// [`solve`](H2Solver::solve) with per-call overrides — e.g. skip the
    /// sampled-residual cost when the report's residual will be discarded:
    ///
    /// ```no_run
    /// # use h2ulv::prelude::*;
    /// # let solver = H2SolverBuilder::new(Geometry::sphere_surface(96, 1), KernelFn::laplace()).build()?;
    /// # let b = vec![1.0; solver.n()];
    /// let report = solver.solve_opts(&b, &SolveOptions::no_residual())?;
    /// assert!(report.residual.is_none());
    /// # Ok::<(), h2ulv::solver::H2Error>(())
    /// ```
    pub fn solve_opts(&self, b: &[f64], opts: &SolveOptions) -> Result<SolveReport, H2Error> {
        self.check_rhs(b)?;
        let mode = opts.subst_mode.unwrap_or(self.subst);
        let bt = self.h2.tree.permute_vec(b);
        let (xt, subst_time) = {
            // Replay against the resident arena: the factor never leaves
            // the device between solves.
            let mut arena = self.arena.lock().unwrap();
            let (res, t) = timed(|| {
                guard("substitution", || {
                    Executor::new(self.backend.as_ref())
                        .with_scope(&self.scope)
                        .solve_in(&self.plan, arena.as_mut(), &bt, mode)
                })
            });
            (res?, t)
        };
        let residual = self.sample_residual_opts(&xt, &bt, opts);
        let x = self.h2.tree.unpermute_vec(&xt);
        Ok(SolveReport {
            x,
            subst_time,
            residual,
            iterations: 1,
            subst_mode: mode,
            backend: self.backend.name(),
        })
    }

    /// Solve one factorization against many right-hand sides by replaying
    /// the cached substitution program per RHS — no re-planning. Lengths
    /// are validated up front so either every RHS is solved or none is.
    pub fn solve_many(&self, rhs: &[Vec<f64>]) -> Result<Vec<SolveReport>, H2Error> {
        self.solve_many_opts(rhs, &SolveOptions::default())
    }

    /// [`solve_many`](H2Solver::solve_many) with per-call overrides
    /// applied to every right-hand side.
    pub fn solve_many_opts(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
    ) -> Result<Vec<SolveReport>, H2Error> {
        for b in rhs {
            self.check_rhs(b)?;
        }
        rhs.iter().map(|b| self.solve_opts(b, opts)).collect()
    }

    /// Direct solve + ULV-preconditioned CG refinement until the relative
    /// residual (w.r.t. the H² operator) drops below `tol`. Recovers full
    /// accuracy from aggressively compressed factorizations at O(N) cost
    /// per iteration (paper §3.7: "direct solver or preconditioner").
    pub fn solve_refined(
        &self,
        b: &[f64],
        tol: f64,
        max_iters: usize,
    ) -> Result<SolveReport, H2Error> {
        self.check_rhs(b)?;
        if tol <= 0.0 || tol.is_nan() {
            return Err(H2Error::InvalidConfig(format!(
                "refinement tolerance must be positive, got {tol}"
            )));
        }
        let bt = self.h2.tree.permute_vec(b);
        let (result, subst_time) = {
            let mut arena = self.arena.lock().unwrap();
            let (res, t) = timed(|| {
                guard("refined substitution", || {
                    pcg_in(
                        &self.h2,
                        &self.factor,
                        self.backend.as_ref(),
                        arena.as_mut(),
                        &bt,
                        tol,
                        max_iters,
                    )
                })
            });
            (res?, t)
        };
        if result.rel_residual > tol {
            return Err(H2Error::ConvergenceFailure {
                achieved: result.rel_residual,
                target: tol,
                iterations: result.iters,
            });
        }
        let residual = self.sample_residual(&result.x, &bt);
        let x = self.h2.tree.unpermute_vec(&result.x);
        Ok(SolveReport {
            x,
            subst_time,
            residual,
            iterations: result.iters,
            subst_mode: SubstMode::Parallel,
            backend: self.backend.name(),
        })
    }

    /// Simulated distributed solve over `ranks` ranks (paper §5); times
    /// are modeled with [`NCCL_LIKE`]. The solution is identical to
    /// [`solve`](H2Solver::solve) for every rank count. Reuses the
    /// session's ULV factor and backend — only the substitution runs per
    /// call; the factorization cost in the report is modeled.
    pub fn solve_dist(&self, b: &[f64], ranks: usize) -> Result<DistSolveReport, H2Error> {
        self.check_rhs(b)?;
        let bt = self.h2.tree.permute_vec(b);
        let report = {
            let mut arena = self.arena.lock().unwrap();
            guard("distributed solve", || {
                dist_solve_driver_in(
                    &self.h2,
                    &self.factor,
                    self.backend.as_ref(),
                    arena.as_mut(),
                    ranks,
                    &bt,
                    self.subst,
                )
            })?
        };
        let residual = self.sample_residual(&report.x, &bt);
        let x = self.h2.tree.unpermute_vec(&report.x);
        Ok(DistSolveReport {
            x,
            ranks: report.ranks,
            factor_time: report.factor_time(&NCCL_LIKE),
            subst_time: report.subst_time(&NCCL_LIKE),
            factor_bytes: report.factor_bytes,
            subst_bytes: report.subst_bytes,
            residual,
        })
    }

    /// Rebuild the H² matrix and the ULV factor with a new configuration
    /// (changed rank budget / tolerance / admissibility), reusing the
    /// stored geometry, kernel, and backend. When the new configuration
    /// keeps the block structure (same tree, lists, and ranks — e.g. only
    /// kernel values changed through an identical config), the cached plan
    /// is *replayed* without re-recording; otherwise a new plan is
    /// recorded. Returns the new build stats.
    pub fn refactorize(&mut self, config: H2Config) -> Result<&BuildStats, H2Error> {
        validate(&self.geometry, &config)?;
        let (h2, construct_time) = construct_timed(&self.geometry, &self.kernel, &config)?;
        let plan = if self.plan.compatible(&h2) {
            self.plan.clone()
        } else {
            let plan = Arc::new(guard("planning", || plan::record(&h2))?);
            self.plan_recordings += 1;
            plan
        };
        let (factor, arena, stats) =
            replay_factor(&plan, &h2, self.backend.as_ref(), &self.scope, construct_time)?;
        self.h2 = h2;
        self.plan = plan;
        self.factor = factor;
        self.arena = Mutex::new(arena);
        self.stats = stats;
        Ok(&self.stats)
    }

    /// Re-execute the cached plan on a different backend *without*
    /// rebuilding the H² matrix or re-deriving the schedule: the same
    /// instruction stream is replayed against the new [`BackendSpec`],
    /// which re-materializes the buffer arena on the new device (the
    /// host-side H² matrix is the transport — this is how the factor
    /// "moves" across devices). Backend comparisons (native vs PJRT vs
    /// serial) share one H² construction this way. Returns the new build
    /// stats (`construct_time` is 0 — nothing was constructed).
    pub fn rebind_backend(&mut self, spec: BackendSpec) -> Result<&BuildStats, H2Error> {
        let backend = spec.instantiate()?;
        let (factor, arena, stats) =
            replay_factor(&self.plan, &self.h2, backend.as_ref(), &self.scope, 0.0)?;
        self.spec = spec;
        self.backend = backend;
        self.factor = factor;
        self.arena = Mutex::new(arena);
        self.stats = stats;
        Ok(&self.stats)
    }

    /// The backend spec this session was built with (or last rebound to).
    pub fn backend_spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn check_rhs(&self, b: &[f64]) -> Result<(), H2Error> {
        if b.len() != self.n() {
            return Err(H2Error::DimensionMismatch { expected: self.n(), got: b.len() });
        }
        Ok(())
    }

    /// Sampled exact-kernel residual of a tree-ordered solution (or `None`
    /// when sampling is disabled).
    fn sample_residual(&self, xt: &[f64], bt: &[f64]) -> Option<f64> {
        if self.residual_samples == 0 {
            return None;
        }
        Some(self.h2.residual_sampled(xt, bt, self.residual_samples, RESIDUAL_SEED))
    }

    /// [`sample_residual`](H2Solver::sample_residual) with the per-call
    /// override applied.
    fn sample_residual_opts(&self, xt: &[f64], bt: &[f64], opts: &SolveOptions) -> Option<f64> {
        match opts.sample_residual {
            Some(false) => None,
            Some(true) => {
                let samples = if self.residual_samples > 0 {
                    self.residual_samples
                } else {
                    DEFAULT_RESIDUAL_SAMPLES
                };
                Some(self.h2.residual_sampled(xt, bt, samples, RESIDUAL_SEED))
            }
            None => self.sample_residual(xt, bt),
        }
    }
}

/// Guarded, timed H² construction.
fn construct_timed(
    geometry: &Geometry,
    kernel: &KernelFn,
    config: &H2Config,
) -> Result<(H2Matrix, f64), H2Error> {
    let (res, t) = timed(|| {
        guard("construction", || H2Matrix::construct(geometry, kernel, config))
    });
    Ok((res?, t))
}

/// Guarded plan replay shared by `build()`, `refactorize()`, and
/// `rebind_backend()`: executes the factorization program, keeps the
/// factor resident in the device arena, and derives the session's
/// [`BuildStats`] from the scope and the plan IR.
#[allow(clippy::type_complexity)]
fn replay_factor(
    plan: &Arc<Plan>,
    h2: &H2Matrix,
    backend: &dyn Device,
    scope: &FlopScope,
    construct_time: f64,
) -> Result<(UlvFactor, Box<dyn DeviceArena>, BuildStats), H2Error> {
    let before = scope.snapshot();
    let ((factor, arena), factor_time) = {
        let (res, t) = timed(|| {
            guard("factorization", || {
                Executor::new(backend).with_scope(scope).factorize_resident(plan, h2)
            })
        });
        (res?, t)
    };
    let factor_flops = scope.snapshot().factor - before.factor;
    let stats = BuildStats {
        n: h2.n(),
        depth: h2.tree.depth,
        construct_time,
        factor_time,
        factor_flops,
        h2_entries: h2.storage_entries(),
        factor_entries: factor.storage_entries(),
        schedule: plan.schedule_stats(),
    };
    Ok((factor, arena, stats))
}
