//! Dense O(N³) baseline solver.

use crate::kernels::KernelFn;
use crate::linalg::chol::{self, FactorError};
use crate::linalg::Matrix;
use crate::metrics::flops;

/// Dense Cholesky solve of the full kernel matrix.
pub struct DenseSolver {
    l: Matrix,
}

impl DenseSolver {
    /// Factorize the dense kernel matrix over `points`.
    pub fn factorize(points: &[crate::geometry::Point3], kernel: &KernelFn) -> Result<DenseSolver, FactorError> {
        let a = kernel.dense(points);
        let n = a.rows();
        flops::add(flops::potrf_flops(n));
        Ok(DenseSolver { l: chol::cholesky(&a)? })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        flops::add(2 * (self.l.rows() * self.l.rows()) as u64);
        chol::potrs(&self.l, &mut x);
        x
    }

    pub fn n(&self) -> usize {
        self.l.rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Geometry;
    use crate::linalg::blas;
    use crate::linalg::matrix::Trans;
    use crate::linalg::norms::rel_err_vec;
    use crate::util::Rng;

    #[test]
    fn dense_baseline_solves_exactly() {
        let g = Geometry::sphere_surface(200, 501);
        let k = KernelFn::laplace();
        let solver = DenseSolver::factorize(&g.points, &k).unwrap();
        let mut rng = Rng::new(1);
        let x0: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
        let a = k.dense(&g.points);
        let mut b = vec![0.0; 200];
        blas::gemv(1.0, &a, Trans::No, &x0, 0.0, &mut b);
        let x = solver.solve(&b);
        assert!(rel_err_vec(&x, &x0) < 1e-9);
    }
}
