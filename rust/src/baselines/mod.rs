//! Baseline solvers the paper compares against.
//!
//! * [`dense`] — plain O(N³) dense Cholesky/LU solve (correctness oracle
//!   and the "BLAS/LAPACK" reference point).
//! * [`blr`]   — Block Low-Rank tile Cholesky, our stand-in for LORAPO
//!   (paper Figure 20's comparator): O(N²) factorization with low-rank
//!   off-diagonal tiles and full trailing-update dependencies — precisely
//!   the dependency structure the H²-ULV method eliminates.
//!
//! The HSS comparator (paper Figures 18-19) is the η=0 configuration of
//! the main H² code (`H2Config::hss()`), as in the paper: "we used our
//! implementation for this comparison".

pub mod blr;
pub mod dense;
