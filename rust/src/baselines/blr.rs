//! Block Low-Rank (BLR) tile Cholesky — the LORAPO comparator
//! (paper Figure 20; Akbudak et al. 2017, Cao et al. 2020/2022).
//!
//! The matrix is partitioned into a flat `nb x nb` tile grid. Off-diagonal
//! tiles are compressed *independently* (no shared basis) to `U Vᵀ`;
//! admissible-by-distance tiles compress well, touching tiles stay dense.
//! The tile Cholesky is the classic right-looking algorithm **with full
//! trailing updates** — the top-left-to-bottom-right dependency chain the
//! paper's H²-ULV method eliminates. Fill-in recompression keeps tiles
//! low-rank but costs O(N²) total work, matching BLR's known complexity.

use crate::geometry::Point3;
use crate::kernels::KernelFn;
use crate::linalg::blas::{self, Side, Uplo};
use crate::linalg::chol;
use crate::linalg::matrix::{Matrix, Trans};
use crate::linalg::qr::{qr, row_id};
use crate::linalg::svd::svd;
use crate::metrics::flops;

/// One tile of the BLR matrix.
#[derive(Clone, Debug)]
pub enum Tile {
    Dense(Matrix),
    /// `A ≈ U Vᵀ` with `U: m x k`, `V: n x k`.
    LowRank { u: Matrix, v: Matrix },
}

impl Tile {
    /// Tile storage in f64 entries.
    pub fn entries(&self) -> usize {
        match self {
            Tile::Dense(m) => m.rows() * m.cols(),
            Tile::LowRank { u, v } => u.rows() * u.cols() + v.rows() * v.cols(),
        }
    }

    /// Materialize as dense (tests / small sizes only).
    pub fn to_dense(&self) -> Matrix {
        match self {
            Tile::Dense(m) => m.clone(),
            Tile::LowRank { u, v } => {
                let mut out = Matrix::zeros(u.rows(), v.rows());
                blas::gemm(1.0, u, Trans::No, v, Trans::Yes, 0.0, &mut out);
                out
            }
        }
    }

    /// `y += alpha * op(T) x`.
    pub fn gemv(&self, alpha: f64, trans: bool, x: &[f64], y: &mut [f64]) {
        let ta = if trans { Trans::Yes } else { Trans::No };
        match self {
            Tile::Dense(m) => {
                flops::add(2 * (m.rows() * m.cols()) as u64);
                blas::gemv(alpha, m, ta, x, 1.0, y);
            }
            Tile::LowRank { u, v } => {
                let k = u.cols();
                flops::add(2 * ((u.rows() + v.rows()) * k) as u64);
                if !trans {
                    let mut t = vec![0.0; k];
                    blas::gemv(1.0, v, Trans::Yes, x, 0.0, &mut t);
                    blas::gemv(alpha, u, Trans::No, &t, 1.0, y);
                } else {
                    let mut t = vec![0.0; k];
                    blas::gemv(1.0, u, Trans::Yes, x, 0.0, &mut t);
                    blas::gemv(alpha, v, Trans::No, &t, 1.0, y);
                }
            }
        }
    }
}

/// BLR configuration.
#[derive(Clone, Debug)]
pub struct BlrConfig {
    /// Tile size.
    pub tile: usize,
    /// Compression tolerance (relative, per tile).
    pub rtol: f64,
    /// Maximum tile rank.
    pub max_rank: usize,
    /// Distance-based admissibility: compress tiles whose point sets are
    /// separated by at least `eta * tile diameter`.
    pub eta: f64,
}

impl Default for BlrConfig {
    fn default() -> Self {
        BlrConfig { tile: 128, rtol: 1e-8, max_rank: 48, eta: 1.0 }
    }
}

/// BLR matrix: flat tile grid over (possibly reordered) points.
pub struct BlrMatrix {
    pub cfg: BlrConfig,
    /// Tile row boundaries (nb + 1 entries).
    pub offsets: Vec<usize>,
    /// Lower-triangle tiles, keyed by `(i, j)` with `i >= j`.
    pub tiles: std::collections::HashMap<(usize, usize), Tile>,
}

impl BlrMatrix {
    /// Build the BLR approximation of the kernel matrix over `points`
    /// (points should already be in a locality-preserving order; reuse the
    /// cluster-tree ordering for fairness with the H² solver).
    pub fn build(points: &[Point3], kernel: &KernelFn, cfg: &BlrConfig) -> BlrMatrix {
        let n = points.len();
        let nb = n.div_ceil(cfg.tile);
        let offsets: Vec<usize> = (0..=nb).map(|t| (t * cfg.tile).min(n)).collect();
        let mut tiles = std::collections::HashMap::new();
        let centers: Vec<Point3> = (0..nb)
            .map(|t| {
                let (b, e) = (offsets[t], offsets[t + 1]);
                let mut c = [0.0; 3];
                for p in &points[b..e] {
                    for d in 0..3 {
                        c[d] += p[d];
                    }
                }
                for x in c.iter_mut() {
                    *x /= (e - b) as f64;
                }
                c
            })
            .collect();
        let radii: Vec<f64> = (0..nb)
            .map(|t| {
                let (b, e) = (offsets[t], offsets[t + 1]);
                points[b..e]
                    .iter()
                    .map(|p| crate::geometry::dist(p, &centers[t]))
                    .fold(0.0, f64::max)
            })
            .collect();
        for i in 0..nb {
            for j in 0..=i {
                let (rb, re) = (offsets[i], offsets[i + 1]);
                let (cb, ce) = (offsets[j], offsets[j + 1]);
                let block = Matrix::from_fn(re - rb, ce - cb, |r, c| {
                    let (pi, pj) = (rb + r, cb + c);
                    if pi == pj {
                        kernel.diag
                    } else {
                        kernel.eval(&points[pi], &points[pj])
                    }
                });
                flops::add(((re - rb) * (ce - cb)) as u64);
                let admissible = i != j
                    && crate::geometry::dist(&centers[i], &centers[j])
                        >= cfg.eta * radii[i].max(radii[j]);
                let tile = if admissible {
                    compress(&block, cfg.rtol, cfg.max_rank)
                } else {
                    Tile::Dense(block)
                };
                tiles.insert((i, j), tile);
            }
        }
        BlrMatrix { cfg: cfg.clone(), offsets, tiles }
    }

    pub fn nb(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn n(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Total storage in f64 entries.
    pub fn storage_entries(&self) -> usize {
        self.tiles.values().map(|t| t.entries()).sum()
    }

    /// In-place tile Cholesky (right-looking, full trailing updates).
    pub fn factorize(&mut self) {
        let nb = self.nb();
        flops::with_phase(flops::Phase::Factor, || {
        for k in 0..nb {
            // 1. POTRF on the diagonal tile.
            let mut dkk = match self.tiles.remove(&(k, k)).unwrap() {
                Tile::Dense(m) => m,
                Tile::LowRank { .. } => unreachable!("diagonal tiles stay dense"),
            };
            flops::add(flops::potrf_flops(dkk.rows()));
            chol::potrf(&mut dkk).expect("BLR diagonal must stay SPD");
            // 2. Panel TRSM: L_ik = A_ik L_kkᵀ⁻¹.
            for i in k + 1..nb {
                let tile = self.tiles.remove(&(i, k)).unwrap();
                let solved = match tile {
                    Tile::Dense(mut m) => {
                        flops::add(flops::trsm_flops(dkk.rows(), m.rows()));
                        blas::trsm(Side::Right, Uplo::Lower, Trans::Yes, 1.0, &dkk, &mut m);
                        Tile::Dense(m)
                    }
                    Tile::LowRank { u, mut v } => {
                        // (U Vᵀ) L⁻ᵀ = U (L⁻¹ V)ᵀ.
                        flops::add(flops::trsm_flops(dkk.rows(), v.cols()));
                        blas::trsm(Side::Left, Uplo::Lower, Trans::No, 1.0, &dkk, &mut v);
                        Tile::LowRank { u, v }
                    }
                };
                self.tiles.insert((i, k), solved);
            }
            self.tiles.insert((k, k), Tile::Dense(dkk));
            // 3. Trailing updates: A_ij -= L_ik L_jkᵀ for i >= j > k.
            //    (The dependency chain BLR cannot avoid.)
            for i in k + 1..nb {
                for j in k + 1..=i {
                    let lik = self.tiles.get(&(i, k)).unwrap().clone();
                    let ljk = self.tiles.get(&(j, k)).unwrap().clone();
                    let target = self.tiles.remove(&(i, j)).unwrap();
                    let updated = apply_update(target, &lik, &ljk, self.cfg.rtol, self.cfg.max_rank);
                    self.tiles.insert((i, j), updated);
                }
            }
        }
        });
    }

    /// Solve `A x = b` after [`factorize`].
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        flops::with_phase(flops::Phase::Substitute, || {
        let nb = self.nb();
        let mut x = b.to_vec();
        // Forward: L y = b.
        for k in 0..nb {
            let (kb, ke) = (self.offsets[k], self.offsets[k + 1]);
            let dkk = match self.tiles.get(&(k, k)).unwrap() {
                Tile::Dense(m) => m,
                _ => unreachable!(),
            };
            let mut seg = x[kb..ke].to_vec();
            flops::add((seg.len() * seg.len()) as u64);
            blas::trsv(Uplo::Lower, Trans::No, dkk, &mut seg);
            x[kb..ke].copy_from_slice(&seg);
            for i in k + 1..nb {
                let (ib, ie) = (self.offsets[i], self.offsets[i + 1]);
                let tile = self.tiles.get(&(i, k)).unwrap();
                let (xk, xi) = split_ranges(&mut x, kb..ke, ib..ie);
                tile.gemv(-1.0, false, xk, xi);
            }
        }
        // Backward: Lᵀ x = y.
        for k in (0..nb).rev() {
            let (kb, ke) = (self.offsets[k], self.offsets[k + 1]);
            for i in k + 1..nb {
                let (ib, ie) = (self.offsets[i], self.offsets[i + 1]);
                let tile = self.tiles.get(&(i, k)).unwrap();
                // xk -= L_ikᵀ xi (k-range written, i-range read).
                let (xi, xk) = split_ranges(&mut x, ib..ie, kb..ke);
                tile.gemv(-1.0, true, xi, xk);
            }
            let dkk = match self.tiles.get(&(k, k)).unwrap() {
                Tile::Dense(m) => m,
                _ => unreachable!(),
            };
            let mut seg = x[kb..ke].to_vec();
            flops::add((seg.len() * seg.len()) as u64);
            blas::trsv(Uplo::Lower, Trans::Yes, dkk, &mut seg);
            x[kb..ke].copy_from_slice(&seg);
        }
        x
        })
    }
}



/// Split two disjoint ranges of a slice mutably: returns (&x[a], &mut x[b]).
fn split_ranges<'a>(
    x: &'a mut [f64],
    a: std::ops::Range<usize>,
    b: std::ops::Range<usize>,
) -> (&'a [f64], &'a mut [f64]) {
    assert!(a.end <= b.start || b.end <= a.start);
    if a.end <= b.start {
        let (lo, hi) = x.split_at_mut(b.start);
        (&lo[a.clone()], &mut hi[..b.len()])
    } else {
        let (lo, hi) = x.split_at_mut(a.start);
        (&hi[..a.len()], &mut lo[b.clone()])
    }
}

/// Independent low-rank compression of a tile (row ID + truncation).
pub fn compress(block: &Matrix, rtol: f64, max_rank: usize) -> Tile {
    let cap = max_rank.min(block.rows().min(block.cols()));
    let id = row_id(block, rtol.max(1e-14), cap);
    let k = id.skeleton.len();
    if k * (block.rows() + block.cols()) >= block.rows() * block.cols() {
        return Tile::Dense(block.clone());
    }
    flops::add(flops::gemm_flops(block.rows(), block.cols(), k));
    let u = id.t.clone();
    let v = block.select_rows(&id.skeleton).transpose();
    Tile::LowRank { u, v }
}

/// `target -= L_ik · L_jkᵀ` with recompression of low-rank targets.
fn apply_update(target: Tile, lik: &Tile, ljk: &Tile, rtol: f64, max_rank: usize) -> Tile {
    // Express the update as either dense or a low-rank pair (pu, pv):
    // update = pu · pvᵀ.
    enum Upd {
        Dense(Matrix),
        Lr(Matrix, Matrix),
    }
    let upd = match (lik, ljk) {
        (Tile::Dense(a), Tile::Dense(b)) => {
            let mut p = Matrix::zeros(a.rows(), b.rows());
            flops::add(flops::gemm_flops(a.rows(), b.rows(), a.cols()));
            blas::gemm(1.0, a, Trans::No, b, Trans::Yes, 0.0, &mut p);
            Upd::Dense(p)
        }
        (Tile::Dense(a), Tile::LowRank { u, v }) => {
            // a vᵀ... update = A (U Vᵀ)ᵀ = (A V) Uᵀ.
            let mut av = Matrix::zeros(a.rows(), v.cols());
            flops::add(flops::gemm_flops(a.rows(), v.cols(), a.cols()));
            blas::gemm(1.0, a, Trans::No, v, Trans::No, 0.0, &mut av);
            Upd::Lr(av, u.clone())
        }
        (Tile::LowRank { u, v }, Tile::Dense(b)) => {
            // (U Vᵀ) Bᵀ = U (B V)ᵀ.
            let mut bv = Matrix::zeros(b.rows(), v.cols());
            flops::add(flops::gemm_flops(b.rows(), v.cols(), b.cols()));
            blas::gemm(1.0, b, Trans::No, v, Trans::No, 0.0, &mut bv);
            Upd::Lr(u.clone(), bv)
        }
        (Tile::LowRank { u: ui, v: vi }, Tile::LowRank { u: uj, v: vj }) => {
            // U_i (V_iᵀ V_j) U_jᵀ.
            let mut core = Matrix::zeros(vi.cols(), vj.cols());
            flops::add(flops::gemm_flops(vi.cols(), vj.cols(), vi.rows()));
            blas::gemm(1.0, vi, Trans::Yes, vj, Trans::No, 0.0, &mut core);
            let mut uc = Matrix::zeros(ui.rows(), vj.cols());
            flops::add(flops::gemm_flops(ui.rows(), vj.cols(), vi.cols()));
            blas::gemm(1.0, ui, Trans::No, &core, Trans::No, 0.0, &mut uc);
            Upd::Lr(uc, uj.clone())
        }
    };
    match (target, upd) {
        (Tile::Dense(mut t), Upd::Dense(p)) => {
            t.axpy(-1.0, &p);
            Tile::Dense(t)
        }
        (Tile::Dense(mut t), Upd::Lr(pu, pv)) => {
            flops::add(flops::gemm_flops(pu.rows(), pv.rows(), pu.cols()));
            blas::gemm(-1.0, &pu, Trans::No, &pv, Trans::Yes, 1.0, &mut t);
            Tile::Dense(t)
        }
        (Tile::LowRank { u, v }, Upd::Dense(p)) => {
            // Fill-in densifies the tile, then try recompressing.
            let mut t = Matrix::zeros(u.rows(), v.rows());
            blas::gemm(1.0, &u, Trans::No, &v, Trans::Yes, 0.0, &mut t);
            t.axpy(-1.0, &p);
            compress(&t, rtol, max_rank)
        }
        (Tile::LowRank { u, v }, Upd::Lr(pu, pv)) => {
            // Concatenate factors and recompress:
            // A - P = [U | -PU] [V | PV]ᵀ.
            let mut npu = pu;
            npu.scale(-1.0);
            let cu = u.hcat(&npu);
            let cv = v.hcat(&pv);
            recompress(cu, cv, rtol, max_rank)
        }
    }
}

/// Recompress a factored pair `C_u C_vᵀ` via QR + small SVD (the classic
/// BLR recompression).
fn recompress(cu: Matrix, cv: Matrix, rtol: f64, max_rank: usize) -> Tile {
    let qu = qr(&cu, false);
    let qv = qr(&cv, false);
    // core = R_u R_vᵀ (small).
    let mut core = Matrix::zeros(qu.r.rows(), qv.r.rows());
    flops::add(flops::gemm_flops(qu.r.rows(), qv.r.rows(), qu.r.cols()));
    blas::gemm(1.0, &qu.r, Trans::No, &qv.r, Trans::Yes, 0.0, &mut core);
    let d = svd(&core);
    let smax = d.s.first().copied().unwrap_or(0.0);
    let mut k = d.s.iter().filter(|&&s| s > rtol * smax).count();
    k = k.min(max_rank).max(1);
    // u = Q_u · U_c[:, ..k] · diag(s), v = Q_v · V_c[:, ..k].
    let uc = d.u.submatrix(0, 0, d.u.rows(), k);
    let vc = d.v.submatrix(0, 0, d.v.rows(), k);
    let mut us = uc.clone();
    for j in 0..k {
        for x in us.col_mut(j) {
            *x *= d.s[j];
        }
    }
    let mut u = Matrix::zeros(cu.rows(), k);
    flops::add(flops::gemm_flops(cu.rows(), k, qu.q.cols()));
    blas::gemm(1.0, &qu.q, Trans::No, &us, Trans::No, 0.0, &mut u);
    let mut v = Matrix::zeros(cv.rows(), k);
    flops::add(flops::gemm_flops(cv.rows(), k, qv.q.cols()));
    blas::gemm(1.0, &qv.q, Trans::No, &vc, Trans::No, 0.0, &mut v);
    Tile::LowRank { u, v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Geometry;
    use crate::linalg::norms::{frob, rel_err_vec};
    use crate::tree::ClusterTree;
    use crate::util::Rng;

    #[test]
    fn compress_low_rank_tile() {
        // Distant point sets give a compressible kernel block.
        let a: Vec<Point3> = (0..30).map(|i| [i as f64 * 0.01, 0.0, 0.0]).collect();
        let b: Vec<Point3> = (0..40).map(|i| [10.0 + i as f64 * 0.01, 0.0, 0.0]).collect();
        let k = KernelFn::laplace();
        let block = k.block(&a, &b);
        let tile = compress(&block, 1e-10, 20);
        match &tile {
            Tile::LowRank { u, .. } => assert!(u.cols() < 10, "rank {}", u.cols()),
            Tile::Dense(_) => panic!("distant block must compress"),
        }
        let mut rec = tile.to_dense();
        rec.axpy(-1.0, &block);
        assert!(frob(&rec) < 1e-8 * frob(&block));
    }

    #[test]
    fn blr_storage_below_dense() {
        let g = Geometry::sphere_surface(1024, 503);
        let tree = ClusterTree::build(&g, 128);
        let k = KernelFn::laplace();
        let blr = BlrMatrix::build(&tree.points, &k, &BlrConfig::default());
        assert!(blr.storage_entries() < 1024 * 1024 * 3 / 4);
    }

    #[test]
    fn blr_solve_matches_dense() {
        let g = Geometry::sphere_surface(640, 505);
        let tree = ClusterTree::build(&g, 128);
        let k = KernelFn::laplace();
        let mut blr = BlrMatrix::build(&tree.points, &k, &BlrConfig { rtol: 1e-9, ..Default::default() });
        blr.factorize();
        let mut rng = Rng::new(1);
        let b: Vec<f64> = (0..640).map(|_| rng.normal()).collect();
        let x = blr.solve(&b);
        let a = k.dense(&tree.points);
        let want = crate::linalg::lu::solve(&a, &b).unwrap();
        let err = rel_err_vec(&x, &want);
        assert!(err < 1e-5, "BLR solve error {err}");
    }

    #[test]
    fn blr_flops_grow_quadratically() {
        // O(N²) factorization: 2x points -> ~4x flops (the paper's reason
        // LORAPO cannot reach large N in Figure 20).
        let k = KernelFn::laplace();
        let mut counts = Vec::new();
        for n in [512usize, 1024] {
            let g = Geometry::sphere_surface(n, 507);
            let tree = ClusterTree::build(&g, 128);
            let mut blr = BlrMatrix::build(&tree.points, &k, &BlrConfig::default());
            let scope = crate::metrics::flops::FlopScope::new();
            crate::metrics::flops::scoped(&scope, crate::metrics::flops::Phase::Factor, || {
                blr.factorize()
            });
            counts.push(scope.snapshot().factor as f64);
        }
        let ratio = counts[1] / counts[0];
        assert!(
            ratio > 2.2,
            "BLR factorization should scale superlinearly: ratio {ratio}"
        );
    }
}
