//! Kernel (Green's) functions that generate the dense matrix entries.
//!
//! The paper's two test kernels (eqs 35-36) plus extras used in the
//! extension studies. Every kernel carries the paper's diagonal
//! regularization `A_ii = 1e3`, which makes the matrices symmetric positive
//! definite so the Cholesky-based ULV factorization applies.

use crate::geometry::{dist, Point3};
use crate::linalg::Matrix;

/// A radial kernel function with the paper's diagonal convention.
#[derive(Clone)]
pub struct KernelFn {
    /// Value for `i == j` (paper: 1e3).
    pub diag: f64,
    /// Radial profile `phi(r)` for `r > 0`.
    pub phi: fn(f64) -> f64,
    /// Human-readable name.
    pub name: &'static str,
}

impl std::fmt::Debug for KernelFn {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KernelFn({})", self.name)
    }
}

impl KernelFn {
    /// 3-D Laplace Green's function, paper eq (35):
    /// `A_ij = 1e3 if i == j else 1/r_ij`.
    pub fn laplace() -> KernelFn {
        KernelFn { diag: 1.0e3, phi: |r| 1.0 / r, name: "laplace" }
    }

    /// Simplified Yukawa potential, paper eq (36):
    /// `A_ij = 1e3 if i == j else exp(-r_ij)/r_ij`.
    pub fn yukawa() -> KernelFn {
        KernelFn { diag: 1.0e3, phi: |r| (-r).exp() / r, name: "yukawa" }
    }

    /// Gaussian kernel (covariance-matrix workloads from the paper's intro).
    pub fn gaussian() -> KernelFn {
        KernelFn { diag: 1.0e3, phi: |r| (-r * r).exp(), name: "gaussian" }
    }

    /// Matérn 3/2 kernel (statistics workloads; HiCMA/LORAPO territory).
    pub fn matern32() -> KernelFn {
        KernelFn {
            diag: 1.0e3,
            phi: |r| {
                let s = 3.0f64.sqrt() * r;
                (1.0 + s) * (-s).exp()
            },
            name: "matern32",
        }
    }

    /// Kernel by name (CLI convenience).
    pub fn by_name(name: &str) -> Option<KernelFn> {
        match name {
            "laplace" => Some(Self::laplace()),
            "yukawa" => Some(Self::yukawa()),
            "gaussian" => Some(Self::gaussian()),
            "matern32" => Some(Self::matern32()),
            _ => None,
        }
    }

    /// Entry `G(x, y)` for two distinct points (or the diagonal value when
    /// they coincide — including the `r -> 0` singular case).
    #[inline]
    pub fn eval(&self, x: &Point3, y: &Point3) -> f64 {
        let r = dist(x, y);
        if r == 0.0 {
            self.diag
        } else {
            (self.phi)(r)
        }
    }

    /// Dense kernel block `G(rows, cols)` for two point sets.
    pub fn block(&self, rows: &[Point3], cols: &[Point3]) -> Matrix {
        Matrix::from_fn(rows.len(), cols.len(), |i, j| self.eval(&rows[i], &cols[j]))
    }

    /// Dense kernel block indexed into a shared point list.
    pub fn block_idx(&self, points: &[Point3], rows: &[usize], cols: &[usize]) -> Matrix {
        Matrix::from_fn(rows.len(), cols.len(), |i, j| {
            self.eval(&points[rows[i]], &points[cols[j]])
        })
    }

    /// Full dense matrix over a point list (verification / baselines only —
    /// O(N²) memory).
    pub fn dense(&self, points: &[Point3]) -> Matrix {
        Matrix::from_fn(points.len(), points.len(), |i, j| {
            if i == j {
                self.diag
            } else {
                self.eval(&points[i], &points[j])
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Geometry;
    use crate::linalg::chol::cholesky;

    #[test]
    fn laplace_values() {
        let k = KernelFn::laplace();
        let a = [0.0, 0.0, 0.0];
        let b = [2.0, 0.0, 0.0];
        assert_eq!(k.eval(&a, &a), 1.0e3);
        assert!((k.eval(&a, &b) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn yukawa_values() {
        let k = KernelFn::yukawa();
        let a = [0.0, 0.0, 0.0];
        let b = [1.0, 0.0, 0.0];
        assert!((k.eval(&a, &b) - (-1.0f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn dense_is_symmetric_spd() {
        // The large diagonal dominates, so kernel matrices are SPD — the
        // paper's Cholesky-based internal factorization relies on this.
        let g = Geometry::sphere_surface(64, 11);
        for k in [KernelFn::laplace(), KernelFn::yukawa(), KernelFn::gaussian(), KernelFn::matern32()] {
            let a = k.dense(&g.points);
            for i in 0..64 {
                for j in 0..64 {
                    assert_eq!(a[(i, j)], a[(j, i)]);
                }
            }
            assert!(cholesky(&a).is_ok(), "{} not SPD", k.name);
        }
    }

    #[test]
    fn block_idx_matches_block() {
        let g = Geometry::uniform_cube(20, 13);
        let k = KernelFn::laplace();
        let rows = [1usize, 5, 7];
        let cols = [0usize, 2];
        let b1 = k.block_idx(&g.points, &rows, &cols);
        let rp: Vec<_> = rows.iter().map(|&i| g.points[i]).collect();
        let cp: Vec<_> = cols.iter().map(|&i| g.points[i]).collect();
        let b2 = k.block(&rp, &cp);
        assert_eq!(b1, b2);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["laplace", "yukawa", "gaussian", "matern32"] {
            assert_eq!(KernelFn::by_name(n).unwrap().name, n);
        }
        assert!(KernelFn::by_name("nope").is_none());
    }
}
