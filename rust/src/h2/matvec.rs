//! O(N) H²-matrix-vector product (FMM-style up/interact/down passes).
//!
//! Used for fast residual checks at large N (where the dense matrix cannot
//! be materialized) and by the figure harness. Works in *interpolation*
//! coordinates: upward pass contracts `T_iᵀ`, far interactions apply the
//! raw skeleton couplings `G(SK_i, SK_j)`, downward pass expands `T_i`.

use super::H2Matrix;
use crate::linalg::blas;
use crate::linalg::matrix::Trans;

impl H2Matrix {
    /// `y = Â x` with the H² structure, `x` in tree point ordering.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(x.len(), n);
        let depth = self.tree.depth;
        let mut y = vec![0.0; n];

        // Near (dense leaf) blocks.
        for (&(i, j), blk) in &self.dense {
            let ni = self.tree.node(depth, i);
            let nj = self.tree.node(depth, j);
            let xj = &x[nj.begin..nj.end];
            let mut yi = vec![0.0; ni.len()];
            blas::gemv(1.0, blk, Trans::No, xj, 0.0, &mut yi);
            for (t, v) in yi.iter().enumerate() {
                y[ni.begin + t] += v;
            }
        }
        if depth == 0 {
            return y;
        }

        // Upward pass: x_hat[level][i] = T_iᵀ (children x_hat | leaf x).
        let mut x_hat: Vec<Vec<Vec<f64>>> = vec![Vec::new(); depth + 1];
        for l in (1..=depth).rev() {
            let width = self.tree.width(l);
            let mut level_hat = Vec::with_capacity(width);
            for i in 0..width {
                let nb = &self.bases[l][i];
                let input: Vec<f64> = if l == depth {
                    let node = self.tree.node(l, i);
                    x[node.begin..node.end].to_vec()
                } else {
                    let mut v = x_hat[l + 1][2 * i].clone();
                    v.extend_from_slice(&x_hat[l + 1][2 * i + 1]);
                    v
                };
                let mut hat = vec![0.0; nb.rank];
                blas::gemv(1.0, &nb.t, Trans::Yes, &input, 0.0, &mut hat);
                level_hat.push(hat);
            }
            x_hat[l] = level_hat;
        }

        // Far interactions: y_hat[i] += G(SK_i, SK_j) x_hat[j].
        let mut y_hat: Vec<Vec<Vec<f64>>> = (0..=depth)
            .map(|l| {
                if l == 0 {
                    Vec::new()
                } else {
                    (0..self.tree.width(l)).map(|i| vec![0.0; self.bases[l][i].rank]).collect()
                }
            })
            .collect();
        for l in 1..=depth {
            for (&(i, j), raw) in &self.coupling_raw[l] {
                let xj = &x_hat[l][j];
                let yi = &mut y_hat[l][i];
                blas::gemv(1.0, raw, Trans::No, xj, 1.0, yi);
            }
        }

        // Downward pass: expand y_hat through T and accumulate.
        for l in 1..=depth {
            let width = self.tree.width(l);
            for i in 0..width {
                let nb = &self.bases[l][i];
                if y_hat[l][i].iter().all(|&v| v == 0.0) {
                    continue;
                }
                let mut expanded = vec![0.0; nb.ndof()];
                blas::gemv(1.0, &nb.t, Trans::No, &y_hat[l][i], 0.0, &mut expanded);
                if l == depth {
                    let node = self.tree.node(l, i);
                    for (t, v) in expanded.iter().enumerate() {
                        y[node.begin + t] += v;
                    }
                } else {
                    // Push into children's y_hat.
                    let k0 = self.bases[l + 1][2 * i].rank;
                    for (t, v) in expanded.iter().enumerate() {
                        if t < k0 {
                            y_hat[l + 1][2 * i][t] += v;
                        } else {
                            y_hat[l + 1][2 * i + 1][t - k0] += v;
                        }
                    }
                }
            }
        }
        y
    }

    /// Relative residual `||Âx - b|| / ||b||` with `x`, `b` in tree order.
    pub fn residual(&self, x: &[f64], b: &[f64]) -> f64 {
        let ax = self.matvec(x);
        let mut diff = 0.0;
        let mut nb = 0.0;
        for i in 0..b.len() {
            let d = ax[i] - b[i];
            diff += d * d;
            nb += b[i] * b[i];
        }
        (diff / nb.max(1e-300)).sqrt()
    }

    /// Sampled *exact-kernel* residual: evaluates `(A x - b)` on `sample`
    /// random rows with direct kernel evaluation — O(sample · N), usable at
    /// any N. Inputs in tree order; returns relative l2 over the sample.
    pub fn residual_sampled(&self, x: &[f64], b: &[f64], sample: usize, seed: u64) -> f64 {
        let n = self.n();
        let mut rng = crate::util::Rng::new(seed);
        let rows = rng.sample_indices(n, sample.min(n));
        let mut num = 0.0;
        let mut den = 0.0;
        for &r in &rows {
            let mut ax = 0.0;
            let pr = self.tree.points[r];
            for c in 0..n {
                let g = if r == c {
                    self.kernel.diag
                } else {
                    self.kernel.eval(&pr, &self.tree.points[c])
                };
                ax += g * x[c];
            }
            let d = ax - b[r];
            num += d * d;
            den += b[r] * b[r];
        }
        (num / den.max(1e-300)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::h2::H2Matrix;
    use crate::kernels::KernelFn;
    use crate::linalg::blas;
    use crate::linalg::matrix::Trans;
    use crate::util::Rng;

    #[test]
    fn matvec_matches_reconstruction() {
        let g = Geometry::sphere_surface(512, 93);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 16, far_samples: 96, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
        let y_fast = h2.matvec(&x);
        let dense = h2.reconstruct_dense();
        let mut y_slow = vec![0.0; 512];
        blas::gemv(1.0, &dense, Trans::No, &x, 0.0, &mut y_slow);
        let err: f64 = y_fast
            .iter()
            .zip(&y_slow)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
            / y_slow.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(err < 1e-10, "matvec disagrees with reconstruction: {err}");
    }

    #[test]
    fn matvec_close_to_exact_kernel() {
        let g = Geometry::sphere_surface(400, 95);
        let k = KernelFn::yukawa();
        let cfg = H2Config { leaf_size: 50, max_rank: 20, far_samples: 0, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let mut rng = Rng::new(7);
        let x: Vec<f64> = (0..400).map(|_| rng.normal()).collect();
        let y = h2.matvec(&x);
        let exact = k.dense(&h2.tree.points);
        let mut y_ex = vec![0.0; 400];
        blas::gemv(1.0, &exact, Trans::No, &x, 0.0, &mut y_ex);
        let err: f64 = y
            .iter()
            .zip(&y_ex)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
            / y_ex.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(err < 5e-3, "H2 matvec vs exact kernel: {err}");
    }

    #[test]
    fn sampled_residual_consistent() {
        let g = Geometry::sphere_surface(300, 97);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 20, far_samples: 0, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        // b = A x for known x; residual of that x must be ~0.
        let x: Vec<f64> = (0..300).map(|i| (i as f64 * 0.1).sin()).collect();
        let exact = k.dense(&h2.tree.points);
        let mut b = vec![0.0; 300];
        blas::gemv(1.0, &exact, Trans::No, &x, 0.0, &mut b);
        let r = h2.residual_sampled(&x, &b, 50, 9);
        assert!(r < 1e-12, "sampled residual of exact solution must vanish: {r}");
    }
}
