//! The H²-matrix data structure: shared bases + dense leaf blocks +
//! per-level couplings, plus the O(N) matvec and dense reconstruction used
//! for verification.

pub mod matvec;

use crate::construct::{build_bases, H2Config, NodeBasis};
use crate::kernels::KernelFn;
use crate::linalg::blas;
use crate::linalg::matrix::{Matrix, Trans};
use crate::metrics::flops;
use crate::tree::{interaction_lists, ClusterTree, LevelLists};
use crate::util::par_map;
use std::collections::HashMap;

/// An H²-matrix approximation of a kernel matrix over a point cloud.
///
/// Block structure comes from a [`ClusterTree`] + admissibility lists; far
/// blocks are `U_i Ŝ_ij U_jᵀ` with shared bases, near blocks are dense at
/// the leaf level only.
pub struct H2Matrix {
    pub tree: ClusterTree,
    pub lists: Vec<LevelLists>,
    pub cfg: H2Config,
    pub kernel: KernelFn,
    /// `bases[level][index]`; level 0 is a full-rank placeholder.
    pub bases: Vec<Vec<NodeBasis>>,
    /// Dense near blocks at the leaf level, keyed by `(i, j)`.
    pub dense: HashMap<(usize, usize), Matrix>,
    /// Weighted couplings `Ŝ_ij = R_i G(SK_i, SK_j) R_jᵀ` per level.
    pub coupling: Vec<HashMap<(usize, usize), Matrix>>,
    /// Unweighted couplings `G(SK_i, SK_j)` per level (used by the O(N)
    /// matvec which works in interpolation coordinates).
    pub coupling_raw: Vec<HashMap<(usize, usize), Matrix>>,
}

impl H2Matrix {
    /// Construct the H² approximation (paper Algorithm 1).
    pub fn construct(geometry: &crate::geometry::Geometry, kernel: &KernelFn, cfg: &H2Config) -> H2Matrix {
        let tree = ClusterTree::build(geometry, cfg.leaf_size);
        let lists = interaction_lists(&tree, cfg.eta);
        let bases = flops::with_phase(flops::Phase::Prefactor, || {
            build_bases(&tree, &lists, kernel, cfg)
        });
        // Dense leaf blocks: A_ij = G(B_i, B_j) for leaf near pairs.
        let depth = tree.depth;
        let leaf_near = &lists[depth].near;
        let dense_blocks: Vec<((usize, usize), Matrix)> = par_map(leaf_near.len(), |t| {
            let (i, j) = leaf_near[t];
            let ni = tree.node(depth, i);
            let nj = tree.node(depth, j);
            let rows: Vec<usize> = (ni.begin..ni.end).collect();
            let cols: Vec<usize> = (nj.begin..nj.end).collect();
            flops::add((rows.len() * cols.len()) as u64);
            ((i, j), kernel.block_idx(&tree.points, &rows, &cols))
        });
        let dense: HashMap<_, _> = dense_blocks.into_iter().collect();
        // Couplings per level.
        let mut coupling: Vec<HashMap<(usize, usize), Matrix>> = vec![HashMap::new(); depth + 1];
        let mut coupling_raw: Vec<HashMap<(usize, usize), Matrix>> = vec![HashMap::new(); depth + 1];
        for l in 1..=depth {
            let far = &lists[l].far;
            let pairs: Vec<((usize, usize), (Matrix, Matrix))> = par_map(far.len(), |t| {
                let (i, j) = far[t];
                let bi = &bases[l][i];
                let bj = &bases[l][j];
                let raw = kernel.block_idx(&tree.points, &bi.skeleton, &bj.skeleton);
                // Ŝ = R_i raw R_jᵀ
                let mut tmp = Matrix::zeros(bi.rank, bj.rank);
                blas::gemm(1.0, &bi.r, Trans::No, &raw, Trans::No, 0.0, &mut tmp);
                let mut s = Matrix::zeros(bi.rank, bj.rank);
                blas::gemm(1.0, &tmp, Trans::No, &bj.r, Trans::Yes, 0.0, &mut s);
                flops::add(2 * flops::gemm_flops(bi.rank, bj.rank, bj.rank.max(bi.rank)));
                ((i, j), (s, raw))
            });
            for ((i, j), (s, raw)) in pairs {
                coupling[l].insert((i, j), s);
                coupling_raw[l].insert((i, j), raw);
            }
        }
        H2Matrix { tree, lists, cfg: cfg.clone(), kernel: kernel.clone(), bases, dense, coupling, coupling_raw }
    }

    /// Matrix dimension N.
    pub fn n(&self) -> usize {
        self.tree.points.len()
    }

    /// Total memory footprint in f64 entries (dense + couplings + bases).
    pub fn storage_entries(&self) -> usize {
        let mut total = 0;
        for m in self.dense.values() {
            total += m.rows() * m.cols();
        }
        for lvl in &self.coupling {
            for m in lvl.values() {
                total += m.rows() * m.cols();
            }
        }
        for lvl in &self.bases {
            for b in lvl {
                total += b.u.rows() * b.u.cols() + b.r.rows() * b.r.cols();
            }
        }
        total
    }

    /// Dense reconstruction of the H² approximation (verification only —
    /// O(N²) memory). Builds `Â = near-dense + Σ_levels TT_i G_sk TT_jᵀ`
    /// in the tree point ordering.
    pub fn reconstruct_dense(&self) -> Matrix {
        let n = self.n();
        let depth = self.tree.depth;
        let mut a = Matrix::zeros(n, n);
        // Leaf dense blocks.
        for (&(i, j), blk) in &self.dense {
            let ni = self.tree.node(depth, i);
            let nj = self.tree.node(depth, j);
            a.set_submatrix(ni.begin, nj.begin, blk);
        }
        // Far blocks per level, expanded through composed interpolation.
        for l in 1..=depth {
            let tt: Vec<Matrix> = (0..self.tree.width(l)).map(|i| self.composed_interp(l, i)).collect();
            for (&(i, j), raw) in &self.coupling_raw[l] {
                // block = TT_i * raw * TT_jᵀ over the nodes' point ranges.
                let ni = self.tree.node(l, i);
                let nj = self.tree.node(l, j);
                let mut tmp = Matrix::zeros(tt[i].rows(), raw.cols());
                blas::gemm(1.0, &tt[i], Trans::No, raw, Trans::No, 0.0, &mut tmp);
                let mut blk = Matrix::zeros(tt[i].rows(), tt[j].rows());
                blas::gemm(1.0, &tmp, Trans::No, &tt[j], Trans::Yes, 0.0, &mut blk);
                a.add_submatrix(ni.begin, nj.begin, 1.0, &blk);
            }
        }
        a
    }

    /// Composed interpolation `TT_i` mapping skeleton values of node
    /// `(l, i)` to all points it owns (`npoints x k_i`).
    pub fn composed_interp(&self, level: usize, i: usize) -> Matrix {
        let nb = &self.bases[level][i];
        if level == self.tree.depth {
            return nb.t.clone();
        }
        let c0 = self.composed_interp(level + 1, 2 * i);
        let c1 = self.composed_interp(level + 1, 2 * i + 1);
        // blockdiag(c0, c1) * T_i
        let rows = c0.rows() + c1.rows();
        let k = nb.rank;
        let k0 = c0.cols();
        let mut out = Matrix::zeros(rows, k);
        let t_top = nb.t.submatrix(0, 0, k0, k);
        let t_bot = nb.t.submatrix(k0, 0, nb.t.rows() - k0, k);
        let mut top = Matrix::zeros(c0.rows(), k);
        blas::gemm(1.0, &c0, Trans::No, &t_top, Trans::No, 0.0, &mut top);
        let mut bot = Matrix::zeros(c1.rows(), k);
        blas::gemm(1.0, &c1, Trans::No, &t_bot, Trans::No, 0.0, &mut bot);
        out.set_submatrix(0, 0, &top);
        out.set_submatrix(c0.rows(), 0, &bot);
        out
    }

    /// Approximation error `||Â - A||_F / ||A||_F` against the exact dense
    /// kernel matrix (verification, small N only).
    pub fn rel_error_dense(&self) -> f64 {
        let exact = self.kernel.dense(&self.tree.points);
        let mut rec = self.reconstruct_dense();
        rec.axpy(-1.0, &exact);
        crate::linalg::norms::frob(&rec) / crate::linalg::norms::frob(&exact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Geometry;

    fn build(n: usize, eta: f64, rank: usize, far_samples: usize) -> H2Matrix {
        let g = Geometry::sphere_surface(n, 91);
        let k = KernelFn::laplace();
        let cfg = H2Config {
            leaf_size: 64,
            max_rank: rank,
            eta,
            far_samples,
            near_samples: 64,
            ..Default::default()
        };
        H2Matrix::construct(&g, &k, &cfg)
    }

    #[test]
    fn reconstruction_accuracy_h2() {
        let h2 = build(512, 1.0, 24, 0);
        let rel = h2.rel_error_dense();
        // At rank 24 the blockwise SVD floor is ~8e-3; the large (1e3)
        // diagonal makes the full-matrix relative error much smaller.
        assert!(rel < 2e-3, "H2 approximation too coarse: rel={rel}");
    }

    #[test]
    fn reconstruction_accuracy_hss_worse_than_h2_at_same_rank() {
        // Paper Figure 18: at equal rank, HSS (eta=0) approximates worse
        // than H2 (strong admissibility) because near-field blocks are
        // forced to be low-rank.
        let h2 = build(512, 1.0, 12, 0);
        let hss = build(512, 0.0, 12, 0);
        let e_h2 = h2.rel_error_dense();
        let e_hss = hss.rel_error_dense();
        assert!(
            e_h2 < e_hss,
            "H2 ({e_h2}) must beat HSS ({e_hss}) at equal rank"
        );
    }

    #[test]
    fn sampling_still_accurate() {
        let full = build(512, 1.0, 20, 0);
        let sampled = build(512, 1.0, 20, 96);
        let e_full = full.rel_error_dense();
        let e_samp = sampled.rel_error_dense();
        assert!(e_samp < 50.0 * e_full.max(1e-8), "sampling degraded too much: {e_samp} vs {e_full}");
        assert!(e_samp < 5e-3);
    }

    #[test]
    fn storage_less_than_dense() {
        let h2 = build(1024, 1.0, 16, 64);
        let dense_entries = 1024 * 1024;
        assert!(
            h2.storage_entries() < dense_entries / 2,
            "H2 storage {} should be far below dense {}",
            h2.storage_entries(),
            dense_entries
        );
    }
}
