//! Recorded, replayable execution plans for the ULV factorization and
//! substitution.
//!
//! The paper's central structural claim is that the H²-ULV schedule is
//! *static*: every batched launch of every level can be enumerated before a
//! single numeric kernel runs — within a level there are no dependencies,
//! and across levels the order is fixed by the tree. This module turns that
//! claim into an explicit artifact: a [`Plan`] is a backend-neutral
//! instruction stream recorded once per H² *structure* (tree + interaction
//! lists + ranks) by the [`Recorder`](record::Recorder), and replayed any
//! number of times by the [`Executor`](exec::Executor) against any
//! [`crate::batch::BatchExec`] backend.
//!
//! Separating the task graph from its execution is the same move the
//! runtime-system literature makes (Deshmukh & Yokota's O(N) distributed
//! factorization over StarPU/PaRSEC; Ma et al.'s trailing-dependency-free
//! scheduling); here the graph degenerates into a *level-ordered list of
//! batched launches*, which is exactly why the method is GPU-friendly.
//!
//! # Instruction ↔ paper mapping
//!
//! Factorization ([`Instr`], paper Algorithms 2 and 4):
//!
//! | `Instr` | Paper step |
//! |---------|------------|
//! | [`Instr::LoadDense`] | Algorithm 2 input: leaf near blocks `A_ij` |
//! | [`Instr::Sparsify`] | Alg 2 l.6 / Alg 4 l.4: `F_ij = U_iᵀ A_ij U_j` (Figure 2 "matrix sparsification") |
//! | [`Instr::Potrf`] | Alg 2 l.8: batched Cholesky of the diagonal `F_ii^RR` blocks |
//! | [`Instr::TrsmRightLt`] | Alg 2 l.10-13 / Alg 4 l.6-8: panels `L(r)_ji = F_ji^RR L_iiᵀ⁻¹`, `L(s)_ji = F_ji^SR L_iiᵀ⁻¹` |
//! | [`Instr::SchurSelf`] | Alg 2 l.15, eq 21: the *single* trailing update `F_ii^SS -= L(s)_ii L(s)_iiᵀ` |
//! | [`Instr::Merge`] | Alg 2 l.18-20: assemble parent near blocks from children `SS` parts and couplings `Ŝ` |
//! | [`FactorProgram::root_launch`] | Alg 2 l.22: dense Cholesky of the merged root |
//!
//! Substitution ([`SolveInstr`], paper Algorithm 3 and §3.7):
//!
//! | `SolveInstr` | Paper step |
//! |--------------|------------|
//! | [`SolveInstr::ApplyBasis`] (trans) | Alg 3 l.3: `c_i = U_iᵀ b_i` |
//! | [`SolveInstr::TrsvFwd`] | Alg 3 l.5 (naive) / §3.7 eq 31 `z_i = L_ii⁻¹ b_i` (parallel, batched) |
//! | [`SolveInstr::GemvAcc`] | Alg 3 l.6-8 trailing updates / §3.7 single-hop matvec rounds |
//! | [`SolveInstr::RootSolve`] | root forward+backward solve |
//! | [`SolveInstr::TrsvBwd`] | backward variant of the above |
//! | [`SolveInstr::ApplyBasis`] (no-trans) | Alg 3 end: `x_i = U_i [x^S; x^R]` |
//!
//! Data-movement steps ([`Instr::Extract`], [`SolveInstr::Split`],
//! [`SolveInstr::Concat`], …) are bookkeeping the eager implementation did
//! inline between launches; they carry no FLOPs and are not counted as
//! launches in [`ScheduleStats`].
//!
//! # Why record?
//!
//! * **Replay** — `H2Solver::refactorize` with an unchanged structure and
//!   every additional right-hand side re-execute the cached plan; schedule
//!   discovery never runs twice ([`Plan::compatible`] guards reuse).
//! * **Backend rebinding** — `H2Solver::rebind_backend` re-executes the
//!   same plan on a different [`crate::solver::BackendSpec`] without
//!   rebuilding the H² matrix.
//! * **Introspection** — the plan carries per-launch shape/FLOP metadata,
//!   so launch counts per level and constant-shape padding waste
//!   ([`ScheduleStats`]) are reported from the IR, not measured.

pub mod exec;
pub mod record;

pub use exec::Executor;
pub use record::{record, Recorder};

use crate::batch::pad::{dim_pad, padded_batch};
use crate::h2::H2Matrix;
use crate::metrics::flops;

/// Index of a matrix block in the factorization arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BufferId(pub u32);

/// Index of a vector in the substitution arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct VecId(pub u32);

/// Reference to a shared basis `U_i` of the H² matrix, by `(level, box)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BasisRef {
    pub level: usize,
    pub index: usize,
}

/// Reference to a factor matrix resolved against a [`crate::ulv::UlvFactor`]
/// during substitution replay. `level_idx` indexes `UlvFactor::levels`
/// (0 = leaf level).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatRef {
    /// Diagonal Cholesky factor `L_ii` of box `index`.
    CholRr { level_idx: usize, index: usize },
    /// Redundant-row panel `L(r)_ji` keyed `(j, i)`.
    Lr { level_idx: usize, key: (usize, usize) },
    /// Skeleton-row panel `L(s)_ji` keyed `(j, i)`.
    Ls { level_idx: usize, key: (usize, usize) },
}

/// One batched item of [`Instr::Sparsify`]: `dst = U_uᵀ · a · U_v`.
#[derive(Clone, Debug)]
pub struct SparsifyItem {
    pub u: BasisRef,
    pub a: BufferId,
    pub v: BasisRef,
    pub dst: BufferId,
}

/// One item of [`Instr::Extract`]: `dst = src[r0.., c0..][..rows, ..cols]`.
#[derive(Clone, Debug)]
pub struct ExtractItem {
    pub src: BufferId,
    pub r0: usize,
    pub c0: usize,
    pub rows: usize,
    pub cols: usize,
    pub dst: BufferId,
}

/// One batched item of [`Instr::TrsmRightLt`]: `b <- b · L_lᵀ⁻¹`.
#[derive(Clone, Debug)]
pub struct TrsmItem {
    pub l: BufferId,
    pub b: BufferId,
}

/// One batched item of [`Instr::SchurSelf`]: `c <- c - a aᵀ`.
#[derive(Clone, Debug)]
pub struct SyrkItem {
    pub a: BufferId,
    pub c: BufferId,
}

/// Where one tile of a merged parent block comes from.
#[derive(Clone, Debug)]
pub enum MergeSrc {
    /// Leading `rows × cols` of a factorization buffer (a child's `SS`
    /// part, post-Schur for diagonal children).
    BufferSub(BufferId),
    /// A far-field coupling `Ŝ_(i,j)` of the H² matrix at `(level, key)`.
    Coupling(usize, (usize, usize)),
}

/// One tile of a [`MergeItem`].
#[derive(Clone, Debug)]
pub struct MergePart {
    pub roff: usize,
    pub coff: usize,
    pub rows: usize,
    pub cols: usize,
    pub src: MergeSrc,
}

/// One item of [`Instr::Merge`]: assemble a parent near block.
#[derive(Clone, Debug)]
pub struct MergeItem {
    pub dst: BufferId,
    pub rows: usize,
    pub cols: usize,
    pub parts: Vec<MergePart>,
}

/// One factorization instruction. Batched variants are single conceptual
/// kernel launches (the paper's batched cuBLAS/cuSOLVER calls);
/// `LoadDense`/`Extract`/`Merge`/`Free` are data movement.
#[derive(Clone, Debug)]
pub enum Instr {
    /// Gather dense leaf near blocks `A_ij` from the H² matrix.
    LoadDense { items: Vec<((usize, usize), BufferId)> },
    /// Batched two-sided basis transform (matrix sparsification).
    Sparsify { level: usize, items: Vec<SparsifyItem> },
    /// Submatrix extraction (data movement between launches).
    Extract { items: Vec<ExtractItem> },
    /// Batched in-place Cholesky of diagonal `RR` blocks.
    Potrf { level: usize, bufs: Vec<BufferId> },
    /// Batched right-side lower-transposed TRSM panel solves.
    TrsmRightLt { level: usize, items: Vec<TrsmItem> },
    /// Batched SYRK-shaped Schur update (eq 21).
    SchurSelf { level: usize, items: Vec<SyrkItem> },
    /// Assemble parent-level near blocks (`level` = child level).
    Merge { level: usize, items: Vec<MergeItem> },
    /// Release buffers that no later instruction reads.
    Free { bufs: Vec<BufferId> },
}

/// Output wiring of one factorization level: which arena buffers hold the
/// [`crate::ulv::LevelFactor`] content after replay.
#[derive(Clone, Debug)]
pub struct LevelOut {
    pub level: usize,
    /// One buffer per box (0×0 for boxes with no redundant DOFs).
    pub chol_rr: Vec<BufferId>,
    pub lr: Vec<((usize, usize), BufferId)>,
    pub ls: Vec<((usize, usize), BufferId)>,
    pub near: Vec<(usize, usize)>,
}

/// The instruction stream of one tree level: every batched launch of the
/// level plus the data movement between launches. Within a level the
/// launches have no mutual dependencies — the paper's core property — so
/// a future async executor can overlap them freely; across levels the
/// order is fixed.
#[derive(Clone, Debug)]
pub struct LevelProgram {
    pub level: usize,
    pub steps: Vec<Instr>,
    /// Per-launch metadata (see [`LaunchMeta`]), in issue order.
    pub launches: Vec<LaunchMeta>,
}

/// The complete factorization program (Algorithm 2 end to end).
#[derive(Clone, Debug)]
pub struct FactorProgram {
    /// Arena size needed to replay.
    pub buf_count: usize,
    /// Arena prologue: gather the dense leaf blocks (no launches).
    pub prologue: Vec<Instr>,
    /// Level programs, finest level first (matching `UlvFactor::levels`).
    pub levels: Vec<LevelProgram>,
    /// Output wiring, leaf level first.
    pub outputs: Vec<LevelOut>,
    /// Buffer holding the merged root block.
    pub root_src: BufferId,
    /// Root dimension.
    pub root_n: usize,
    /// The dense root Cholesky (Algorithm 2 line 22).
    pub root_launch: LaunchMeta,
    /// Total useful FLOPs of the whole program.
    pub total_flops: u64,
}

impl FactorProgram {
    /// Every launch of the program, level order then root.
    pub fn launches(&self) -> impl Iterator<Item = &LaunchMeta> {
        self.levels
            .iter()
            .flat_map(|l| l.launches.iter())
            .chain(std::iter::once(&self.root_launch))
    }
}

/// One batched item of [`SolveInstr::ApplyBasis`]: `(box, src, dst)`.
pub type BasisItem = (usize, VecId, VecId);

/// One substitution instruction. As in [`Instr`], batched variants are
/// launches; the rest is segment bookkeeping.
#[derive(Clone, Debug)]
pub enum SolveInstr {
    /// `dst = b[begin..end]` — scatter the RHS into leaf segments.
    LoadRhs { items: Vec<(usize, usize, VecId)> },
    /// Batched `dst = U_iᵀ src` (trans) or `dst = U_i src`.
    ApplyBasis { level_idx: usize, level: usize, trans: bool, items: Vec<BasisItem> },
    /// `(src, at, lo, hi)`: `lo = src[..at]`, `hi = src[at..]`.
    Split { items: Vec<(VecId, usize, VecId, VecId)> },
    /// `(dst, a, b)`: `dst = [a; b]`.
    Concat { items: Vec<(VecId, VecId, VecId)> },
    /// `(dst, src)`: `dst = src`.
    Copy { items: Vec<(VecId, VecId)> },
    /// Batched forward TRSV `x <- L⁻¹ x` in place.
    TrsvFwd { level: usize, items: Vec<(MatRef, VecId)> },
    /// Batched backward TRSV `x <- Lᵀ⁻¹ x` in place.
    TrsvBwd { level: usize, items: Vec<(MatRef, VecId)> },
    /// Batched `y += -op(A) x`; `(a, x, y)` with unique `y` per launch.
    GemvAcc { level: usize, trans: bool, items: Vec<(MatRef, VecId, VecId)> },
    /// `(dst, a, b)`: elementwise `dst = a + b`.
    Add { items: Vec<(VecId, VecId, VecId)> },
    /// Dense root solve `x <- (L Lᵀ)⁻¹ x` in place.
    RootSolve { vec: VecId },
    /// `x[begin..end] = src` — gather leaf segments into the solution.
    StoreSol { items: Vec<(usize, usize, VecId)> },
}

/// One substitution program (forward + root + backward) for a fixed
/// [`crate::ulv::SubstMode`].
#[derive(Clone, Debug)]
pub struct SolveProgram {
    /// Number of vectors in the replay arena.
    pub vec_count: usize,
    /// Length of each vector (arena slots are zero-initialized per replay).
    pub vec_lens: Vec<usize>,
    pub steps: Vec<SolveInstr>,
    pub launches: Vec<LaunchMeta>,
    pub total_flops: u64,
}

/// Static metadata of one batched launch: what the schedule looks like
/// before any numerics run.
#[derive(Clone, Copy, Debug)]
pub struct LaunchMeta {
    pub level: usize,
    pub kernel: &'static str,
    /// Number of batch items.
    pub batch: usize,
    /// Useful FLOPs (sum over the actual item shapes).
    pub flops: u64,
    /// FLOPs a constant-shape padded batch performs: every item padded to
    /// the launch maximum (dims rounded to multiples of 4, paper §4.1) and
    /// the batch rounded to the next compiled bucket.
    pub padded_flops: u64,
}

impl LaunchMeta {
    /// Build metadata from per-item `(rows, cols, flops)` triples and a
    /// padded-FLOP model for the padded `(rows, cols)` shape.
    pub(crate) fn new(
        level: usize,
        kernel: &'static str,
        shapes: &[(usize, usize, u64)],
        padded_item: impl Fn(usize, usize) -> u64,
    ) -> LaunchMeta {
        let batch = shapes.len();
        let flops: u64 = shapes.iter().map(|&(_, _, f)| f).sum();
        let max_r = shapes.iter().map(|&(r, _, _)| r).max().unwrap_or(0);
        let max_c = shapes.iter().map(|&(_, c, _)| c).max().unwrap_or(0);
        let padded = if batch == 0 {
            0
        } else {
            padded_item(dim_pad(max_r), dim_pad(max_c)) * padded_batch(batch) as u64
        };
        LaunchMeta { level, kernel, batch, flops, padded_flops: padded }
    }
}

/// Structural signature of an H² matrix: everything the recorder depends
/// on. Two matrices with equal signatures produce identical plans, so a
/// cached plan can be replayed against either ([`Plan::compatible`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanSig {
    pub depth: usize,
    pub leaf_ranges: Vec<(usize, usize)>,
    /// Near interaction pairs per level (`0..=depth`).
    pub near: Vec<Vec<(usize, usize)>>,
    /// Far interaction pairs per level.
    pub far: Vec<Vec<(usize, usize)>>,
    /// `(ndof, rank)` per box per level.
    pub shapes: Vec<Vec<(usize, usize)>>,
}

impl PlanSig {
    /// Compute the signature of an H² matrix.
    pub fn of(h2: &H2Matrix) -> PlanSig {
        let depth = h2.tree.depth;
        PlanSig {
            depth,
            leaf_ranges: h2.tree.leaves().iter().map(|n| (n.begin, n.end)).collect(),
            near: (0..=depth).map(|l| h2.lists[l].near.clone()).collect(),
            far: (0..=depth).map(|l| h2.lists[l].far.clone()).collect(),
            shapes: (0..=depth)
                .map(|l| h2.bases[l].iter().map(|b| (b.ndof(), b.rank)).collect())
                .collect(),
        }
    }
}

/// Aggregated launch statistics of one tree level.
#[derive(Clone, Copy, Debug, Default)]
pub struct LevelScheduleStats {
    pub level: usize,
    /// Batched kernel launches at this level.
    pub launches: usize,
    /// Total batch items across those launches.
    pub batch_items: usize,
    /// Useful FLOPs.
    pub flops: u64,
    /// Constant-shape padded FLOPs (see [`LaunchMeta::padded_flops`]).
    pub padded_flops: u64,
}

/// Schedule statistics computed directly from the IR — no execution needed.
#[derive(Clone, Debug, Default)]
pub struct ScheduleStats {
    /// Factorization program, aggregated by level (root = level 0).
    pub factor_levels: Vec<LevelScheduleStats>,
    /// Parallel-substitution program, aggregated by level.
    pub solve_levels: Vec<LevelScheduleStats>,
}

impl ScheduleStats {
    fn aggregate(launches: &[LaunchMeta]) -> Vec<LevelScheduleStats> {
        let max_level = launches.iter().map(|l| l.level).max().unwrap_or(0);
        let mut out: Vec<LevelScheduleStats> = (0..=max_level)
            .map(|level| LevelScheduleStats { level, ..Default::default() })
            .collect();
        for l in launches {
            let s = &mut out[l.level];
            s.launches += 1;
            s.batch_items += l.batch;
            s.flops += l.flops;
            s.padded_flops += l.padded_flops;
        }
        out
    }

    /// Total factorization launches.
    pub fn factor_launches(&self) -> usize {
        self.factor_levels.iter().map(|s| s.launches).sum()
    }

    /// Total parallel-substitution launches.
    pub fn solve_launches(&self) -> usize {
        self.solve_levels.iter().map(|s| s.launches).sum()
    }

    /// Total useful factorization FLOPs.
    pub fn factor_flops(&self) -> u64 {
        self.factor_levels.iter().map(|s| s.flops).sum()
    }

    /// Total padded factorization FLOPs.
    pub fn factor_padded_flops(&self) -> u64 {
        self.factor_levels.iter().map(|s| s.padded_flops).sum()
    }

    /// Fraction of padded factorization FLOPs that are padding waste
    /// (`1 - useful / padded`), in `[0, 1)`.
    pub fn factor_padding_waste(&self) -> f64 {
        let padded = self.factor_padded_flops();
        if padded == 0 {
            return 0.0;
        }
        1.0 - self.factor_flops() as f64 / padded as f64
    }
}

/// A recorded execution plan: the complete, backend-neutral instruction
/// stream for one H² structure. Record once, replay many times.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Matrix dimension.
    pub n: usize,
    /// Tree depth.
    pub depth: usize,
    /// Structural signature of the H² matrix this was recorded from.
    pub sig: PlanSig,
    /// Algorithm 2/4: the level-ordered factorization program.
    pub factor: FactorProgram,
    /// §3.7 parallel substitution program.
    pub solve_parallel: SolveProgram,
    /// Algorithm 3 naive substitution program (batch-of-one launches with
    /// the serial cross-box dependency order baked into the stream).
    pub solve_naive: SolveProgram,
}

impl Plan {
    /// Can this plan be replayed against `h2` (identical structure)?
    pub fn compatible(&self, h2: &H2Matrix) -> bool {
        self.sig == PlanSig::of(h2)
    }

    /// Launch/shape/FLOP statistics straight from the IR.
    pub fn schedule_stats(&self) -> ScheduleStats {
        let factor_metas: Vec<LaunchMeta> = self.factor.launches().copied().collect();
        ScheduleStats {
            factor_levels: ScheduleStats::aggregate(&factor_metas),
            solve_levels: ScheduleStats::aggregate(&self.solve_parallel.launches),
        }
    }

    /// Render a human-readable schedule dump (the CLI `plan-dump` body).
    pub fn render_schedule(&self) -> String {
        fn table(out: &mut String, header: &str, levels: &[LevelScheduleStats]) {
            out.push_str(&format!(
                "\n{header} (level, launches, batch_items, useful_gflop, padded_gflop, waste):\n"
            ));
            for s in levels.iter().rev() {
                if s.launches == 0 {
                    continue;
                }
                let waste = if s.padded_flops > 0 {
                    100.0 * (1.0 - s.flops as f64 / s.padded_flops as f64)
                } else {
                    0.0
                };
                out.push_str(&format!(
                    "  L{:<2} {:>4} {:>8} {:>12.4} {:>12.4} {:>6.1}%\n",
                    s.level,
                    s.launches,
                    s.batch_items,
                    s.flops as f64 / 1e9,
                    s.padded_flops as f64 / 1e9,
                    waste
                ));
            }
        }
        let stats = self.schedule_stats();
        let mut out = format!(
            "plan: N={}, depth={}, factor launches={}, subst launches={}\n",
            self.n,
            self.depth,
            stats.factor_launches(),
            stats.solve_launches()
        );
        table(&mut out, "factorization", &stats.factor_levels);
        table(&mut out, "parallel substitution", &stats.solve_levels);
        out.push_str(&format!(
            "\ntotal factor: {:.4} useful GFLOP, {:.4} padded GFLOP, padding waste {:.1}%\n",
            stats.factor_flops() as f64 / 1e9,
            stats.factor_padded_flops() as f64 / 1e9,
            100.0 * stats.factor_padding_waste()
        ));
        out
    }

    /// The substitution program for a mode.
    pub fn solve_program(&self, mode: crate::ulv::SubstMode) -> &SolveProgram {
        match mode {
            crate::ulv::SubstMode::Parallel => &self.solve_parallel,
            crate::ulv::SubstMode::Naive => &self.solve_naive,
        }
    }
}

/// FLOPs of a sparsification item `U_iᵀ (n_i × n_j) U_j` — two GEMMs,
/// matching [`crate::batch::count_sparsify_flops`].
pub(crate) fn sparsify_flops(ni: usize, nj: usize) -> u64 {
    flops::gemm_flops(ni, nj, ni) + flops::gemm_flops(ni, nj, nj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::kernels::KernelFn;

    fn small_h2() -> H2Matrix {
        let g = Geometry::sphere_surface(256, 31);
        let cfg = H2Config { leaf_size: 32, max_rank: 16, ..Default::default() };
        H2Matrix::construct(&g, &KernelFn::laplace(), &cfg)
    }

    #[test]
    fn signature_detects_structure_changes() {
        let h2 = small_h2();
        let sig = PlanSig::of(&h2);
        assert_eq!(sig, PlanSig::of(&h2));
        let g = Geometry::sphere_surface(256, 31);
        let cfg = H2Config { leaf_size: 64, max_rank: 16, ..Default::default() };
        let other = H2Matrix::construct(&g, &KernelFn::laplace(), &cfg);
        assert_ne!(sig, PlanSig::of(&other));
    }

    #[test]
    fn schedule_stats_nonempty_and_padded_dominates() {
        let h2 = small_h2();
        let plan = record(&h2);
        let stats = plan.schedule_stats();
        assert!(plan.factor.total_flops > 0);
        assert!(stats.factor_launches() > 0);
        assert!(stats.solve_launches() > 0);
        assert!(
            stats.factor_padded_flops() >= stats.factor_flops(),
            "padding can only add work"
        );
        let waste = stats.factor_padding_waste();
        assert!((0.0..1.0).contains(&waste), "waste {waste} out of range");
        let dump = plan.render_schedule();
        assert!(dump.contains("factor launches"));
    }
}
