//! Recorded, replayable execution plans for the ULV factorization and
//! substitution.
//!
//! The paper's central structural claim is that the H²-ULV schedule is
//! *static*: every batched launch of every level can be enumerated before a
//! single numeric kernel runs — within a level there are no dependencies,
//! and across levels the order is fixed by the tree. This module turns that
//! claim into an explicit artifact: a [`Plan`] is a backend-neutral
//! instruction stream recorded once per H² *structure* (tree + interaction
//! lists + ranks) by the [`Recorder`](record::Recorder), and replayed any
//! number of times by the [`Executor`](exec::Executor) against any
//! [`crate::batch::device::Device`] backend.
//!
//! The IR is **arena-native**: every operand of every instruction is a
//! [`BufferId`] into the device-owned buffer arena
//! ([`crate::batch::device::DeviceArena`]). Host data (dense leaf blocks,
//! far-field couplings, shared bases) enters the arena through explicit
//! [`Instr::Upload`] steps, so a backend can own residency end to end:
//! after the factorization replay the factor matrices are already
//! device-resident and the substitution programs reference them by the
//! same ids — no host marshalling happens between launches.
//!
//! Separating the task graph from its execution is the same move the
//! runtime-system literature makes (Deshmukh & Yokota's O(N) distributed
//! factorization over StarPU/PaRSEC; Ma et al.'s trailing-dependency-free
//! scheduling); here the graph degenerates into a *level-ordered list of
//! batched launches*, which is exactly why the method is GPU-friendly.
//!
//! # Instruction ↔ paper mapping
//!
//! Factorization ([`Instr`], paper Algorithms 2 and 4):
//!
//! | `Instr` | Paper step |
//! |---------|------------|
//! | [`Instr::Upload`] | host → device transfer of leaf near blocks `A_ij`, couplings `Ŝ`, and bases `U_i` |
//! | [`Instr::Sparsify`] | Alg 2 l.6 / Alg 4 l.4: `F_ij = U_iᵀ A_ij U_j` (Figure 2 "matrix sparsification") |
//! | [`Instr::Potrf`] | Alg 2 l.8: batched Cholesky of the diagonal `F_ii^RR` blocks (and, batch-of-one, the merged root — Alg 2 l.22) |
//! | [`Instr::TrsmRightLt`] | Alg 2 l.10-13 / Alg 4 l.6-8: panels `L(r)_ji = F_ji^RR L_iiᵀ⁻¹`, `L(s)_ji = F_ji^SR L_iiᵀ⁻¹` |
//! | [`Instr::SchurSelf`] | Alg 2 l.15, eq 21: the *single* trailing update `F_ii^SS -= L(s)_ii L(s)_iiᵀ` |
//! | [`Instr::Merge`] | Alg 2 l.18-20: assemble parent near blocks from children `SS` parts and couplings `Ŝ` |
//! | [`FactorProgram::root_launch`] | Alg 2 l.22: dense Cholesky of the merged root |
//!
//! Substitution ([`SolveInstr`], paper Algorithm 3 and §3.7):
//!
//! | `SolveInstr` | Paper step |
//! |--------------|------------|
//! | [`SolveInstr::ApplyBasis`] (trans) | Alg 3 l.3: `c_i = U_iᵀ b_i` |
//! | [`SolveInstr::TrsvFwd`] | Alg 3 l.5 (naive) / §3.7 eq 31 `z_i = L_ii⁻¹ b_i` (parallel, batched) |
//! | [`SolveInstr::GemvAcc`] | Alg 3 l.6-8 trailing updates / §3.7 single-hop matvec rounds |
//! | [`SolveInstr::RootSolve`] | root forward+backward solve |
//! | [`SolveInstr::TrsvBwd`] | backward variant of the above |
//! | [`SolveInstr::ApplyBasis`] (no-trans) | Alg 3 end: `x_i = U_i [x^S; x^R]` |
//!
//! Data-movement steps ([`Instr::Extract`], [`SolveInstr::Split`],
//! [`SolveInstr::Concat`], …) are device-side buffer shuffles between
//! launches; they carry no FLOPs and are not counted as launches in
//! [`ScheduleStats`].
//!
//! # Why record?
//!
//! * **Replay** — `H2Solver::refactorize` with an unchanged structure and
//!   every additional right-hand side re-execute the cached plan; schedule
//!   discovery never runs twice ([`Plan::compatible`] guards reuse).
//! * **Backend rebinding** — `H2Solver::rebind_backend` re-executes the
//!   same plan on a different [`crate::solver::BackendSpec`], which
//!   re-materializes the buffer arena on the new device without rebuilding
//!   the H² matrix.
//! * **Introspection** — the plan carries per-launch shape/FLOP metadata,
//!   so launch counts per level and constant-shape padding waste
//!   ([`ScheduleStats`]) are reported from the IR, not measured.
//!
//! The naive-substitution program (Algorithm 3) is recorded **lazily** on
//! the first `SubstMode::Naive` solve: the default mode is Parallel, so
//! eager recording would walk the tree a second time and hold a second
//! instruction stream in memory for nothing ([`Plan::solve_program`]).

pub mod exec;
pub mod rank;
pub mod record;
pub mod verify;

pub use exec::Executor;
pub use rank::{carve, render_comm, RankPlan};
pub use record::{record, Recorder};
pub use verify::{PlanReport, PlanViolation};

use crate::batch::pad::{dim_pad, padded_batch};
use crate::h2::H2Matrix;
use crate::metrics::flops;
use std::sync::OnceLock;

/// Index of a buffer (matrix block or substitution vector) in the
/// device-owned arena. Factorization buffers occupy `0..buf_count`;
/// substitution vectors start at [`SolveProgram::vec_base`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BufferId(pub u32);

/// Host-side source of an [`Instr::Upload`]: where the executor reads the
/// data that enters the arena. These are the only points where host memory
/// is touched during a factorization replay.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HostSrc {
    /// Dense leaf near block `A_ij` keyed by the leaf pair.
    Dense((usize, usize)),
    /// Far-field coupling `Ŝ_(i,j)` at `(level, key)`.
    Coupling { level: usize, key: (usize, usize) },
    /// Shared basis `U_i` of box `index` at `level`.
    Basis { level: usize, index: usize },
}

/// One batched item of [`Instr::Sparsify`]: `dst = U_uᵀ · a · U_v`. All
/// four operands are arena buffers (the bases are uploaded once per level).
#[derive(Clone, Copy, Debug)]
pub struct SparsifyItem {
    pub u: BufferId,
    pub a: BufferId,
    pub v: BufferId,
    pub dst: BufferId,
}

/// One item of [`Instr::Extract`]: `dst = src[r0.., c0..][..rows, ..cols]`.
#[derive(Clone, Copy, Debug)]
pub struct ExtractItem {
    pub src: BufferId,
    pub r0: usize,
    pub c0: usize,
    pub rows: usize,
    pub cols: usize,
    pub dst: BufferId,
}

/// One batched item of [`Instr::TrsmRightLt`]: `b <- b · L_lᵀ⁻¹`.
#[derive(Clone, Copy, Debug)]
pub struct TrsmItem {
    pub l: BufferId,
    pub b: BufferId,
}

/// One batched item of [`Instr::SchurSelf`]: `c <- c - a aᵀ`.
#[derive(Clone, Copy, Debug)]
pub struct SyrkItem {
    pub a: BufferId,
    pub c: BufferId,
}

/// One tile of a [`MergeItem`]: the leading `rows × cols` of `src` lands at
/// `(roff, coff)` of the destination. Couplings are uploaded into dedicated
/// buffers before the merge, so every tile source is an arena buffer.
#[derive(Clone, Copy, Debug)]
pub struct MergePart {
    pub roff: usize,
    pub coff: usize,
    pub rows: usize,
    pub cols: usize,
    pub src: BufferId,
}

/// One item of [`Instr::Merge`]: assemble a parent near block.
#[derive(Clone, Debug)]
pub struct MergeItem {
    pub dst: BufferId,
    pub rows: usize,
    pub cols: usize,
    pub parts: Vec<MergePart>,
}

/// One matrix buffer received by an [`Instr::Exchange`]: rank `from`
/// publishes `buf`, and the receiving rank's arena defines `buf` with the
/// annotated shape. Shapes are carried in the instruction so a rank plan
/// stays verifiable on its own (the receiver never saw the sender's
/// defining instruction).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExchangeRecv {
    /// Sending rank.
    pub from: u32,
    /// Buffer id (global id space — identical on sender and receiver).
    pub buf: BufferId,
    pub rows: u32,
    pub cols: u32,
}

/// One factorization instruction. Batched variants are single conceptual
/// kernel launches (the paper's batched cuBLAS/cuSOLVER calls);
/// `Upload`/`Extract`/`Merge`/`Free` are data movement — `Upload` is the
/// only one that reads host memory. `Exchange` appears only in carved
/// per-rank programs ([`rank::RankPlan`]); the global single-rank program
/// never communicates.
#[derive(Clone, Debug)]
pub enum Instr {
    /// Transfer host data (dense blocks, couplings, bases) into the arena.
    Upload { items: Vec<(HostSrc, BufferId)> },
    /// Batched two-sided basis transform (matrix sparsification).
    Sparsify { level: usize, items: Vec<SparsifyItem> },
    /// Device-side submatrix extraction (data movement between launches).
    Extract { items: Vec<ExtractItem> },
    /// Batched in-place Cholesky of diagonal `RR` blocks.
    Potrf { level: usize, bufs: Vec<BufferId> },
    /// Batched right-side lower-transposed TRSM panel solves.
    TrsmRightLt { level: usize, items: Vec<TrsmItem> },
    /// Batched SYRK-shaped Schur update (eq 21).
    SchurSelf { level: usize, items: Vec<SyrkItem> },
    /// Assemble parent-level near blocks (`level` = child level).
    Merge { level: usize, items: Vec<MergeItem> },
    /// Release buffers that no later instruction reads.
    Free { bufs: Vec<BufferId> },
    /// Collective rendezvous with the other ranks (SPMD programs only):
    /// every rank executes its k-th `Exchange` together — the carved
    /// analog of the paper's all-gather at the subtree-merge and
    /// root-gather boundaries. `sends` publishes local matrices (they
    /// stay live locally); each [`ExchangeRecv`] *defines* a remote
    /// buffer in the local arena. Either list may be empty — a rank with
    /// nothing to say still participates in the barrier.
    Exchange { level: usize, sends: Vec<BufferId>, recvs: Vec<ExchangeRecv> },
}

/// Output wiring of one factorization level: which arena buffers hold the
/// [`crate::ulv::LevelFactor`] content after replay. These buffers stay
/// resident in the arena at plan end, and the substitution programs
/// reference them by the same ids — residency is the backend's.
#[derive(Clone, Debug)]
pub struct LevelOut {
    pub level: usize,
    /// One buffer per box (0×0 for boxes with no redundant DOFs).
    pub chol_rr: Vec<BufferId>,
    pub lr: Vec<((usize, usize), BufferId)>,
    pub ls: Vec<((usize, usize), BufferId)>,
    pub near: Vec<(usize, usize)>,
    /// One basis buffer `U_i` per box (uploaded during the level replay,
    /// reused by the substitution's `ApplyBasis` launches).
    pub basis: Vec<BufferId>,
}

/// The instruction stream of one tree level: every batched launch of the
/// level plus the data movement between launches. Within a level the
/// launches have no mutual dependencies — the paper's core property — so
/// a multi-stream executor can overlap them freely (the
/// [`crate::batch::device::Device::stream`] hook marks the level
/// boundaries); across levels the order is fixed.
#[derive(Clone, Debug)]
pub struct LevelProgram {
    pub level: usize,
    pub steps: Vec<Instr>,
    /// Per-launch metadata (see [`LaunchMeta`]), in issue order.
    pub launches: Vec<LaunchMeta>,
}

/// The complete factorization program (Algorithm 2 end to end).
#[derive(Clone, Debug)]
pub struct FactorProgram {
    /// Number of factorization arena slots (`BufferId`s `0..buf_count`).
    pub buf_count: usize,
    /// Arena prologue: upload the dense leaf blocks (no launches).
    pub prologue: Vec<Instr>,
    /// Level programs, finest level first (matching `UlvFactor::levels`).
    pub levels: Vec<LevelProgram>,
    /// Output wiring, leaf level first.
    pub outputs: Vec<LevelOut>,
    /// Buffer holding the merged root block (the root Cholesky factor
    /// after replay — referenced by [`SolveInstr::RootSolve`]).
    pub root_src: BufferId,
    /// Root dimension.
    pub root_n: usize,
    /// The dense root Cholesky (Algorithm 2 line 22), replayed as a
    /// batch-of-one `Potrf` launch on [`FactorProgram::root_src`].
    pub root_launch: LaunchMeta,
    /// Total useful FLOPs of the whole program.
    pub total_flops: u64,
}

impl FactorProgram {
    /// Every launch of the program, level order then root.
    pub fn launches(&self) -> impl Iterator<Item = &LaunchMeta> {
        self.levels
            .iter()
            .flat_map(|l| l.launches.iter())
            .chain(std::iter::once(&self.root_launch))
    }

    /// Buffers that are live in the arena after a full factorization
    /// replay: factor outputs, bases, and the root factor. Everything else
    /// has been released by the program's `Free` steps — the invariant the
    /// arena-balance tests assert.
    pub fn resident_bufs(&self) -> Vec<BufferId> {
        let mut out = Vec::new();
        for o in &self.outputs {
            out.extend(o.chol_rr.iter().copied());
            out.extend(o.lr.iter().map(|&(_, b)| b));
            out.extend(o.ls.iter().map(|&(_, b)| b));
            out.extend(o.basis.iter().copied());
        }
        out.push(self.root_src);
        out
    }
}

/// One batched item of [`SolveInstr::ApplyBasis`]: `(u, src, dst)` — the
/// basis buffer and the source/destination vector buffers.
pub type BasisItem = (BufferId, BufferId, BufferId);

/// One substitution instruction. As in [`Instr`], batched variants are
/// launches; the rest is device-side segment bookkeeping. Matrix operands
/// (`L_ii`, `L(r)`, `L(s)`, `U_i`, the root factor) are the factorization
/// program's resident buffers; vector operands live at
/// [`SolveProgram::vec_base`] and above.
#[derive(Clone, Debug)]
pub enum SolveInstr {
    /// `dst = b[begin..end]` — upload the RHS into leaf segment buffers.
    LoadRhs { items: Vec<(usize, usize, BufferId)> },
    /// Batched `dst = U_uᵀ src` (trans) or `dst = U_u src`.
    ApplyBasis { level: usize, trans: bool, items: Vec<BasisItem> },
    /// `(src, at, lo, hi)`: `lo = src[..at]`, `hi = src[at..]`.
    Split { items: Vec<(BufferId, usize, BufferId, BufferId)> },
    /// `(dst, a, b)`: `dst = [a; b]`.
    Concat { items: Vec<(BufferId, BufferId, BufferId)> },
    /// `(dst, src)`: `dst = src`.
    Copy { items: Vec<(BufferId, BufferId)> },
    /// Batched forward TRSV `x <- L⁻¹ x` in place; items are `(l, x)`.
    TrsvFwd { level: usize, items: Vec<(BufferId, BufferId)> },
    /// Batched backward TRSV `x <- Lᵀ⁻¹ x` in place; items are `(l, x)`.
    TrsvBwd { level: usize, items: Vec<(BufferId, BufferId)> },
    /// Batched `y += -op(A) x`; `(a, x, y)` with unique `y` per launch.
    GemvAcc { level: usize, trans: bool, items: Vec<(BufferId, BufferId, BufferId)> },
    /// `(dst, a, b)`: elementwise `dst = a + b`.
    Add { items: Vec<(BufferId, BufferId, BufferId)> },
    /// Dense root solve `x <- (L Lᵀ)⁻¹ x` in place against the resident
    /// root factor `l` (= [`FactorProgram::root_src`]).
    RootSolve { l: BufferId, x: BufferId },
    /// `x[begin..end] = src` — download leaf segments into the solution.
    StoreSol { items: Vec<(usize, usize, BufferId)> },
    /// Collective segment exchange (SPMD programs only): the substitution
    /// analog of [`Instr::Exchange`] — the paper's neighbor-segment
    /// exchange and the redundant-region all-gather. `sends` publishes
    /// local vectors; each recv `(from, buf, len)` *writes* a remote
    /// rank's vector into the local workspace.
    Exchange { level: usize, sends: Vec<BufferId>, recvs: Vec<(u32, BufferId, u32)> },
}

impl SolveInstr {
    /// Tree level of a batched launch; `None` for data-movement steps and
    /// the root solve (they run on whatever stream is current). The
    /// executor uses this to emit [`crate::batch::device::Device::stream`]
    /// at the substitution program's level boundaries, mirroring the
    /// factorization replay.
    pub fn level(&self) -> Option<usize> {
        match self {
            SolveInstr::ApplyBasis { level, .. }
            | SolveInstr::TrsvFwd { level, .. }
            | SolveInstr::TrsvBwd { level, .. }
            | SolveInstr::GemvAcc { level, .. }
            | SolveInstr::Exchange { level, .. } => Some(*level),
            _ => None,
        }
    }
}

/// One substitution program (forward + root + backward) for a fixed
/// [`crate::ulv::SubstMode`].
#[derive(Clone, Debug)]
pub struct SolveProgram {
    /// First vector buffer id: vectors occupy
    /// `vec_base .. vec_base + vec_lens.len()` in the arena, above the
    /// factorization buffers.
    pub vec_base: u32,
    /// Length of each vector (slots are zero-allocated per replay).
    pub vec_lens: Vec<usize>,
    /// `(level, box)` the vector belongs to, parallel to `vec_lens`: the
    /// tree position whose segment/accumulator the vector holds. This is
    /// the recorder's ownership annotation — [`rank::carve`] maps it to a
    /// rank set (`owner(box)` at distributed levels, every rank in the
    /// redundant region), so SPMD carving needs no second structural walk.
    pub vec_home: Vec<(u32, u32)>,
    pub steps: Vec<SolveInstr>,
    pub launches: Vec<LaunchMeta>,
    pub total_flops: u64,
}

/// Static metadata of one batched launch: what the schedule looks like
/// before any numerics run.
#[derive(Clone, Copy, Debug)]
pub struct LaunchMeta {
    pub level: usize,
    pub kernel: &'static str,
    /// Number of batch items.
    pub batch: usize,
    /// Useful FLOPs (sum over the actual item shapes).
    pub flops: u64,
    /// FLOPs a constant-shape padded batch performs: every item padded to
    /// the launch maximum (dims rounded to multiples of 4, paper §4.1) and
    /// the batch rounded to the next compiled bucket.
    pub padded_flops: u64,
}

impl LaunchMeta {
    /// Build metadata from per-item `(rows, cols, flops)` triples and a
    /// padded-FLOP model for the padded `(rows, cols)` shape.
    pub(crate) fn new(
        level: usize,
        kernel: &'static str,
        shapes: &[(usize, usize, u64)],
        padded_item: impl Fn(usize, usize) -> u64,
    ) -> LaunchMeta {
        let batch = shapes.len();
        let flops: u64 = shapes.iter().map(|&(_, _, f)| f).sum();
        let max_r = shapes.iter().map(|&(r, _, _)| r).max().unwrap_or(0);
        let max_c = shapes.iter().map(|&(_, c, _)| c).max().unwrap_or(0);
        let padded = if batch == 0 {
            0
        } else {
            padded_item(dim_pad(max_r), dim_pad(max_c)) * padded_batch(batch) as u64
        };
        LaunchMeta { level, kernel, batch, flops, padded_flops: padded }
    }
}

/// Structural signature of an H² matrix: everything the recorder depends
/// on. Two matrices with equal signatures produce identical plans, so a
/// cached plan can be replayed against either ([`Plan::compatible`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanSig {
    pub depth: usize,
    pub leaf_ranges: Vec<(usize, usize)>,
    /// Near interaction pairs per level (`0..=depth`).
    pub near: Vec<Vec<(usize, usize)>>,
    /// Far interaction pairs per level.
    pub far: Vec<Vec<(usize, usize)>>,
    /// `(ndof, rank)` per box per level.
    pub shapes: Vec<Vec<(usize, usize)>>,
}

impl PlanSig {
    /// Compute the signature of an H² matrix.
    pub fn of(h2: &H2Matrix) -> PlanSig {
        let depth = h2.tree.depth;
        PlanSig {
            depth,
            leaf_ranges: h2.tree.leaves().iter().map(|n| (n.begin, n.end)).collect(),
            near: (0..=depth).map(|l| h2.lists[l].near.clone()).collect(),
            far: (0..=depth).map(|l| h2.lists[l].far.clone()).collect(),
            shapes: (0..=depth)
                .map(|l| h2.bases[l].iter().map(|b| (b.ndof(), b.rank)).collect())
                .collect(),
        }
    }
}

/// Aggregated launch statistics of one tree level.
#[derive(Clone, Copy, Debug, Default)]
pub struct LevelScheduleStats {
    pub level: usize,
    /// Batched kernel launches at this level.
    pub launches: usize,
    /// Total batch items across those launches.
    pub batch_items: usize,
    /// Useful FLOPs.
    pub flops: u64,
    /// Constant-shape padded FLOPs (see [`LaunchMeta::padded_flops`]).
    pub padded_flops: u64,
}

/// Schedule statistics computed directly from the IR — no execution needed.
#[derive(Clone, Debug, Default)]
pub struct ScheduleStats {
    /// Factorization program, aggregated by level (root = level 0).
    pub factor_levels: Vec<LevelScheduleStats>,
    /// Parallel-substitution program, aggregated by level.
    pub solve_levels: Vec<LevelScheduleStats>,
}

impl ScheduleStats {
    fn aggregate(launches: &[LaunchMeta]) -> Vec<LevelScheduleStats> {
        let max_level = launches.iter().map(|l| l.level).max().unwrap_or(0);
        let mut out: Vec<LevelScheduleStats> = (0..=max_level)
            .map(|level| LevelScheduleStats { level, ..Default::default() })
            .collect();
        for l in launches {
            let s = &mut out[l.level];
            s.launches += 1;
            s.batch_items += l.batch;
            s.flops += l.flops;
            s.padded_flops += l.padded_flops;
        }
        out
    }

    /// Total factorization launches.
    pub fn factor_launches(&self) -> usize {
        self.factor_levels.iter().map(|s| s.launches).sum()
    }

    /// Total parallel-substitution launches.
    pub fn solve_launches(&self) -> usize {
        self.solve_levels.iter().map(|s| s.launches).sum()
    }

    /// Total useful factorization FLOPs.
    pub fn factor_flops(&self) -> u64 {
        self.factor_levels.iter().map(|s| s.flops).sum()
    }

    /// Total padded factorization FLOPs.
    pub fn factor_padded_flops(&self) -> u64 {
        self.factor_levels.iter().map(|s| s.padded_flops).sum()
    }

    /// Fraction of padded factorization FLOPs that are padding waste
    /// (`1 - useful / padded`), in `[0, 1)`.
    pub fn factor_padding_waste(&self) -> f64 {
        let padded = self.factor_padded_flops();
        if padded == 0 {
            return 0.0;
        }
        1.0 - self.factor_flops() as f64 / padded as f64
    }
}

/// A recorded execution plan: the complete, backend-neutral instruction
/// stream for one H² structure. Record once, replay many times.
#[derive(Debug)]
pub struct Plan {
    /// Matrix dimension.
    pub n: usize,
    /// Tree depth.
    pub depth: usize,
    /// Structural signature of the H² matrix this was recorded from.
    pub sig: PlanSig,
    /// Algorithm 2/4: the level-ordered factorization program.
    pub factor: FactorProgram,
    /// §3.7 parallel substitution program (the default solve path).
    pub solve_parallel: SolveProgram,
    /// Algorithm 3 naive substitution program (batch-of-one launches with
    /// the serial cross-box dependency order baked into the stream).
    /// Recorded lazily on the first `SubstMode::Naive` solve — the second
    /// tree walk and its instruction memory are skipped entirely for
    /// sessions that never leave the default Parallel mode.
    solve_naive: OnceLock<SolveProgram>,
    /// Everything the lazy recording needs (level wiring, leaf ranges,
    /// root buffer) — captured once by the recorder.
    pub(crate) solve_ctx: record::SolveCtx,
}

impl Clone for Plan {
    fn clone(&self) -> Plan {
        let solve_naive = OnceLock::new();
        if let Some(p) = self.solve_naive.get() {
            let _ = solve_naive.set(p.clone());
        }
        Plan {
            n: self.n,
            depth: self.depth,
            sig: self.sig.clone(),
            factor: self.factor.clone(),
            solve_parallel: self.solve_parallel.clone(),
            solve_naive,
            solve_ctx: self.solve_ctx.clone(),
        }
    }
}

impl Plan {
    pub(crate) fn assemble(
        n: usize,
        depth: usize,
        sig: PlanSig,
        factor: FactorProgram,
        solve_parallel: SolveProgram,
        solve_ctx: record::SolveCtx,
    ) -> Plan {
        Plan { n, depth, sig, factor, solve_parallel, solve_naive: OnceLock::new(), solve_ctx }
    }

    /// Can this plan be replayed against `h2` (identical structure)?
    pub fn compatible(&self, h2: &H2Matrix) -> bool {
        self.sig == PlanSig::of(h2)
    }

    /// Launch/shape/FLOP statistics straight from the IR.
    pub fn schedule_stats(&self) -> ScheduleStats {
        let factor_metas: Vec<LaunchMeta> = self.factor.launches().copied().collect();
        ScheduleStats {
            factor_levels: ScheduleStats::aggregate(&factor_metas),
            solve_levels: ScheduleStats::aggregate(&self.solve_parallel.launches),
        }
    }

    /// Render a human-readable schedule dump (the CLI `plan-dump` body).
    pub fn render_schedule(&self) -> String {
        fn table(out: &mut String, header: &str, levels: &[LevelScheduleStats]) {
            out.push_str(&format!(
                "\n{header} (level, launches, batch_items, useful_gflop, padded_gflop, waste):\n"
            ));
            for s in levels.iter().rev() {
                if s.launches == 0 {
                    continue;
                }
                let waste = if s.padded_flops > 0 {
                    100.0 * (1.0 - s.flops as f64 / s.padded_flops as f64)
                } else {
                    0.0
                };
                out.push_str(&format!(
                    "  L{:<2} {:>4} {:>8} {:>12.4} {:>12.4} {:>6.1}%\n",
                    s.level,
                    s.launches,
                    s.batch_items,
                    s.flops as f64 / 1e9,
                    s.padded_flops as f64 / 1e9,
                    waste
                ));
            }
        }
        let stats = self.schedule_stats();
        let mut out = format!(
            "plan: N={}, depth={}, factor launches={}, subst launches={}\n",
            self.n,
            self.depth,
            stats.factor_launches(),
            stats.solve_launches()
        );
        table(&mut out, "factorization", &stats.factor_levels);
        table(&mut out, "parallel substitution", &stats.solve_levels);
        out.push_str(&format!(
            "\ntotal factor: {:.4} useful GFLOP, {:.4} padded GFLOP, padding waste {:.1}%\n",
            stats.factor_flops() as f64 / 1e9,
            stats.factor_padded_flops() as f64 / 1e9,
            100.0 * stats.factor_padding_waste()
        ));
        out
    }

    /// The substitution program for a mode. The Naive program is recorded
    /// on first use (a pure structural walk — no numerics, no backend).
    pub fn solve_program(&self, mode: crate::ulv::SubstMode) -> &SolveProgram {
        match mode {
            crate::ulv::SubstMode::Parallel => &self.solve_parallel,
            crate::ulv::SubstMode::Naive => self.solve_naive.get_or_init(|| {
                let prog = self.solve_ctx.record_solve(crate::ulv::SubstMode::Naive, &self.factor);
                verify::debug_verify_naive(&self.factor, &self.sig, self.n, &prog);
                prog
            }),
        }
    }

    /// Whether the lazily recorded naive program has materialized yet
    /// (test hook for the recording-on-demand contract).
    pub fn naive_recorded(&self) -> bool {
        self.solve_naive.get().is_some()
    }

    /// Shape-only factor description derived from the recorded structure —
    /// what `FactorStorage::DeviceOnly` sessions (and the distributed
    /// model) read instead of a host [`crate::ulv::UlvFactor`] mirror.
    pub fn factor_meta(&self) -> crate::ulv::FactorMeta {
        self.solve_ctx.factor_meta(self.depth, &self.factor)
    }
}

/// FLOPs of a sparsification item `U_iᵀ (n_i × n_j) U_j` — two GEMMs,
/// matching [`crate::batch::count_sparsify_flops`].
pub(crate) fn sparsify_flops(ni: usize, nj: usize) -> u64 {
    flops::gemm_flops(ni, nj, ni) + flops::gemm_flops(ni, nj, nj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::kernels::KernelFn;
    use crate::ulv::SubstMode;

    fn small_h2() -> H2Matrix {
        let g = Geometry::sphere_surface(256, 31);
        let cfg = H2Config { leaf_size: 32, max_rank: 16, ..Default::default() };
        H2Matrix::construct(&g, &KernelFn::laplace(), &cfg)
    }

    #[test]
    fn signature_detects_structure_changes() {
        let h2 = small_h2();
        let sig = PlanSig::of(&h2);
        assert_eq!(sig, PlanSig::of(&h2));
        let g = Geometry::sphere_surface(256, 31);
        let cfg = H2Config { leaf_size: 64, max_rank: 16, ..Default::default() };
        let other = H2Matrix::construct(&g, &KernelFn::laplace(), &cfg);
        assert_ne!(sig, PlanSig::of(&other));
    }

    #[test]
    fn schedule_stats_nonempty_and_padded_dominates() {
        let h2 = small_h2();
        let plan = record(&h2);
        let stats = plan.schedule_stats();
        assert!(plan.factor.total_flops > 0);
        assert!(stats.factor_launches() > 0);
        assert!(stats.solve_launches() > 0);
        assert!(
            stats.factor_padded_flops() >= stats.factor_flops(),
            "padding can only add work"
        );
        let waste = stats.factor_padding_waste();
        assert!((0.0..1.0).contains(&waste), "waste {waste} out of range");
        let dump = plan.render_schedule();
        assert!(dump.contains("factor launches"));
    }

    #[test]
    fn naive_program_is_recorded_lazily_and_once() {
        let h2 = small_h2();
        let plan = record(&h2);
        assert!(!plan.naive_recorded(), "naive program must not be recorded eagerly");
        let naive = plan.solve_program(SubstMode::Naive);
        assert!(plan.naive_recorded());
        assert!(naive.total_flops > 0);
        // Second access returns the same materialized program.
        let again = plan.solve_program(SubstMode::Naive) as *const SolveProgram;
        assert_eq!(naive as *const SolveProgram, again);
        // A clone carries the already-recorded program along.
        let cloned = plan.clone();
        assert!(cloned.naive_recorded());
    }

    #[test]
    fn resident_bufs_cover_outputs_and_root() {
        let h2 = small_h2();
        let plan = record(&h2);
        let resident = plan.factor.resident_bufs();
        assert!(resident.contains(&plan.factor.root_src));
        for out in &plan.factor.outputs {
            for &b in &out.chol_rr {
                assert!(resident.contains(&b));
            }
            for &b in &out.basis {
                assert!(resident.contains(&b));
            }
        }
        // No id repeats: each resident buffer is owned by exactly one role.
        let mut ids: Vec<u32> = resident.iter().map(|b| b.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), resident.len(), "resident buffer ids must be unique");
    }
}
