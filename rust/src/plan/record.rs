//! The [`Recorder`]: one structural walk of the H² tree that emits the
//! complete factorization program (paper Algorithms 2/4) and the parallel
//! substitution program (§3.7); the naive program (Algorithm 3) is
//! recorded on demand from the captured [`SolveCtx`].
//!
//! Recording touches no matrix *values* — only the tree, the interaction
//! lists, and the per-box `(ndof, rank)` shapes. That is the paper's
//! "inherently parallel" property made concrete: the entire schedule is
//! enumerable before any numeric kernel runs, and a plan recorded from one
//! H² matrix replays bit-identically against any other matrix with the same
//! structure (e.g. after a kernel-parameter change).
//!
//! Every operand the recorder emits is an arena [`BufferId`]: host data
//! (dense leaf blocks, couplings, bases) enters through explicit
//! [`Instr::Upload`] steps, and the factor outputs stay resident so the
//! substitution programs can reference them by id — the device owns
//! residency, the executor never reconstructs host slices per launch.

use super::*;
use crate::h2::H2Matrix;
use crate::metrics::flops::{gemm_flops, potrf_flops, trsm_flops};
use crate::ulv::SubstMode;
use std::collections::{HashMap, HashSet};

/// Record the full execution plan for an H² matrix.
pub fn record(h2: &H2Matrix) -> Plan {
    Recorder::new(h2).run()
}

/// Placeholder for "no buffer assigned yet" while wiring the backward pass.
const UNSET: BufferId = BufferId(u32::MAX);

/// Per-level structural info gathered while recording the factorization,
/// reused to record the substitution programs. Arena wiring (which buffer
/// holds which factor block) is *not* duplicated here — it lives once in
/// [`FactorProgram::outputs`], which `record_solve` reads.
#[derive(Clone, Debug)]
pub(crate) struct LevelInfo {
    level: usize,
    width: usize,
    ranks: Vec<usize>,
    nreds: Vec<usize>,
    near: Vec<(usize, usize)>,
    /// Sorted for deterministic launch grouping (the eager implementation
    /// iterated hash maps here — same math, arbitrary round order).
    lr_keys: Vec<(usize, usize)>,
    ls_keys: Vec<(usize, usize)>,
}

/// Everything a substitution recording needs beyond the factorization
/// program itself, captured once by the factorization walk. [`Plan`] holds
/// this so the naive program can be recorded lazily on first
/// `SubstMode::Naive` solve (against the plan's own output wiring).
#[derive(Clone, Debug)]
pub(crate) struct SolveCtx {
    infos: Vec<LevelInfo>,
    leaf_ranges: Vec<(usize, usize)>,
}

/// Walks the H² structure once and emits a [`Plan`].
pub struct Recorder<'a> {
    h2: &'a H2Matrix,
    buf_count: u32,
    steps: Vec<Instr>,
    launches: Vec<LaunchMeta>,
    infos: Vec<LevelInfo>,
}

impl<'a> Recorder<'a> {
    pub fn new(h2: &'a H2Matrix) -> Recorder<'a> {
        Recorder { h2, buf_count: 0, steps: Vec::new(), launches: Vec::new(), infos: Vec::new() }
    }

    fn buf(&mut self) -> BufferId {
        let id = BufferId(self.buf_count);
        self.buf_count += 1;
        id
    }

    /// Record a launch, skipping empty batches (no backend would issue
    /// them, so they must not inflate the schedule statistics).
    fn push_launch(&mut self, meta: LaunchMeta) {
        if meta.batch > 0 {
            self.launches.push(meta);
        }
    }

    /// Drain the step/launch buffers into a [`LevelProgram`].
    fn finish_level(&mut self, level: usize) -> LevelProgram {
        LevelProgram {
            level,
            steps: std::mem::take(&mut self.steps),
            launches: std::mem::take(&mut self.launches),
        }
    }

    /// Record everything: factorization, then the parallel substitution
    /// program. The naive program is deferred to first use.
    pub fn run(mut self) -> Plan {
        let (prologue, levels, outputs, root_src, root_n, root_launch) = self.record_factor();
        let total_flops: u64 = levels
            .iter()
            .flat_map(|l| l.launches.iter())
            .map(|l| l.flops)
            .sum::<u64>()
            + root_launch.flops;
        let factor = FactorProgram {
            buf_count: self.buf_count as usize,
            prologue,
            levels,
            outputs,
            root_src,
            root_n,
            root_launch,
            total_flops,
        };
        let ctx = SolveCtx {
            infos: std::mem::take(&mut self.infos),
            leaf_ranges: self.h2.tree.leaves().iter().map(|n| (n.begin, n.end)).collect(),
        };
        let solve_parallel = ctx.record_solve(SubstMode::Parallel, &factor);
        let plan = Plan::assemble(
            self.h2.n(),
            self.h2.tree.depth,
            PlanSig::of(self.h2),
            factor,
            solve_parallel,
            ctx,
        );
        // Debug builds statically verify every recorded plan before it
        // leaves the recorder (release sessions opt in via the builder).
        super::verify::debug_verify_recorded(&plan);
        plan
    }

    // ---------------- Factorization (Algorithms 2 and 4) ----------------

    #[allow(clippy::type_complexity)]
    fn record_factor(
        &mut self,
    ) -> (Vec<Instr>, Vec<LevelProgram>, Vec<LevelOut>, BufferId, usize, LaunchMeta) {
        let h2 = self.h2;
        let depth = h2.tree.depth;

        // Leaf near blocks enter the arena (host -> device prologue).
        let leaf_near = h2.lists[depth].near.clone();
        let mut current: HashMap<(usize, usize), BufferId> = HashMap::new();
        let mut load_items = Vec::with_capacity(leaf_near.len());
        for &key in &leaf_near {
            let b = self.buf();
            load_items.push((HostSrc::Dense(key), b));
            current.insert(key, b);
        }
        let prologue = vec![Instr::Upload { items: load_items }];

        let mut level_programs: Vec<LevelProgram> = Vec::with_capacity(depth);
        let mut outputs: Vec<LevelOut> = Vec::with_capacity(depth);
        let mut root_n = h2.n();

        for l in (1..=depth).rev() {
            let bases = &h2.bases[l];
            let near = h2.lists[l].near.clone();
            let width = h2.tree.width(l);
            let ndof = |i: usize| bases[i].ndof();
            let rank = |i: usize| bases[i].rank;
            let nred = |i: usize| bases[i].nred();

            // --- 0. Upload this level's shared bases U_i (reused by the
            //        substitution's ApplyBasis launches — never freed). ---
            let basis: Vec<BufferId> = (0..width).map(|_| self.buf()).collect();
            self.steps.push(Instr::Upload {
                items: (0..width)
                    .map(|i| (HostSrc::Basis { level: l, index: i }, basis[i]))
                    .collect(),
            });

            // --- 1. Sparsify every near block: F_ij = U_iᵀ A_ij U_j. ---
            let mut f: HashMap<(usize, usize), BufferId> = HashMap::new();
            let mut sp_items = Vec::with_capacity(near.len());
            let mut sp_shapes = Vec::with_capacity(near.len());
            let mut consumed: Vec<BufferId> = Vec::with_capacity(near.len());
            for &(i, j) in &near {
                let a = current.remove(&(i, j)).expect("missing near block");
                let dst = self.buf();
                sp_items.push(SparsifyItem { u: basis[i], a, v: basis[j], dst });
                sp_shapes.push((ndof(i), ndof(j), sparsify_flops(ndof(i), ndof(j))));
                consumed.push(a);
                f.insert((i, j), dst);
            }
            self.push_launch(LaunchMeta::new(l, "SPARSIFY", &sp_shapes, |r, c| {
                gemm_flops(r, c, r) + gemm_flops(r, c, c)
            }));
            self.steps.push(Instr::Sparsify { level: l, items: sp_items });
            // The pre-sparsification blocks are dead once F exists.
            consumed.sort_by_key(|b| b.0);
            self.steps.push(Instr::Free { bufs: consumed });

            // --- 2. Extract RR diagonal blocks; batched POTRF on non-empty. ---
            let mut rr: Vec<BufferId> = Vec::with_capacity(width);
            let mut ex_items = Vec::with_capacity(width);
            for i in 0..width {
                let dst = self.buf();
                ex_items.push(ExtractItem {
                    src: f[&(i, i)],
                    r0: rank(i),
                    c0: rank(i),
                    rows: nred(i),
                    cols: nred(i),
                    dst,
                });
                rr.push(dst);
            }
            self.steps.push(Instr::Extract { items: ex_items });
            let nonempty: Vec<usize> = (0..width).filter(|&i| nred(i) > 0).collect();
            let po_shapes: Vec<(usize, usize, u64)> =
                nonempty.iter().map(|&i| (nred(i), nred(i), potrf_flops(nred(i)))).collect();
            self.push_launch(LaunchMeta::new(l, "POTRF", &po_shapes, |r, _| potrf_flops(r)));
            if !nonempty.is_empty() {
                self.steps.push(Instr::Potrf {
                    level: l,
                    bufs: nonempty.iter().map(|&i| rr[i]).collect(),
                });
            }

            // --- 3. Extract panels; two batched TRSM launches (L(r), L(s)). ---
            let mut panel_extracts = Vec::new();
            let mut lr_items = Vec::new();
            let mut lr_shapes = Vec::new();
            let mut lr_out: Vec<((usize, usize), BufferId)> = Vec::new();
            let mut ls_items = Vec::new();
            let mut ls_shapes = Vec::new();
            let mut ls_out: Vec<((usize, usize), BufferId)> = Vec::new();
            for &(j, i) in &near {
                if nred(i) == 0 {
                    continue;
                }
                let fji = f[&(j, i)];
                if j > i && nred(j) > 0 {
                    let dst = self.buf();
                    panel_extracts.push(ExtractItem {
                        src: fji,
                        r0: rank(j),
                        c0: rank(i),
                        rows: nred(j),
                        cols: nred(i),
                        dst,
                    });
                    lr_items.push(TrsmItem { l: rr[i], b: dst });
                    lr_shapes.push((nred(j), nred(i), trsm_flops(nred(i), nred(j))));
                    lr_out.push(((j, i), dst));
                }
                if rank(j) > 0 {
                    let dst = self.buf();
                    panel_extracts.push(ExtractItem {
                        src: fji,
                        r0: 0,
                        c0: rank(i),
                        rows: rank(j),
                        cols: nred(i),
                        dst,
                    });
                    ls_items.push(TrsmItem { l: rr[i], b: dst });
                    ls_shapes.push((rank(j), nred(i), trsm_flops(nred(i), rank(j))));
                    ls_out.push(((j, i), dst));
                }
            }
            if !panel_extracts.is_empty() {
                self.steps.push(Instr::Extract { items: panel_extracts });
            }
            self.push_launch(LaunchMeta::new(l, "TRSM", &lr_shapes, |r, c| trsm_flops(c, r)));
            if !lr_items.is_empty() {
                self.steps.push(Instr::TrsmRightLt { level: l, items: lr_items });
            }
            self.push_launch(LaunchMeta::new(l, "TRSM", &ls_shapes, |r, c| trsm_flops(c, r)));
            if !ls_items.is_empty() {
                self.steps.push(Instr::TrsmRightLt { level: l, items: ls_items });
            }

            // --- 4. The single Schur update (eq 21): F_ii^SS -= L(s)_ii L(s)_iiᵀ. ---
            let ls_buf: HashMap<(usize, usize), BufferId> = ls_out.iter().copied().collect();
            let schur_idx: Vec<usize> =
                (0..width).filter(|&i| rank(i) > 0 && nred(i) > 0).collect();
            let mut ss_buf: HashMap<usize, BufferId> = HashMap::new();
            let mut ss_extracts = Vec::new();
            let mut sy_items = Vec::new();
            let mut sy_shapes = Vec::new();
            for &i in &schur_idx {
                let dst = self.buf();
                ss_extracts.push(ExtractItem {
                    src: f[&(i, i)],
                    r0: 0,
                    c0: 0,
                    rows: rank(i),
                    cols: rank(i),
                    dst,
                });
                sy_items.push(SyrkItem { a: ls_buf[&(i, i)], c: dst });
                sy_shapes.push((rank(i), nred(i), gemm_flops(rank(i), rank(i), nred(i))));
                ss_buf.insert(i, dst);
            }
            if !ss_extracts.is_empty() {
                self.steps.push(Instr::Extract { items: ss_extracts });
            }
            self.push_launch(LaunchMeta::new(l, "SYRK", &sy_shapes, |r, c| gemm_flops(r, r, c)));
            if !sy_items.is_empty() {
                self.steps.push(Instr::SchurSelf { level: l, items: sy_items });
            }

            // --- 5. Merge to the parent level. Couplings are uploaded into
            //        dedicated buffers first so every tile source is an
            //        arena buffer (no host reads inside the merge). ---
            let mut next: HashMap<(usize, usize), BufferId> = HashMap::new();
            let mut coup_uploads: Vec<(HostSrc, BufferId)> = Vec::new();
            let mut coup_bufs: Vec<BufferId> = Vec::new();
            let mut merge_items = Vec::new();
            for &(pi, pj) in &h2.lists[l - 1].near {
                let k_r0 = rank(2 * pi);
                let k_r1 = rank(2 * pi + 1);
                let k_c0 = rank(2 * pj);
                let k_c1 = rank(2 * pj + 1);
                let mut parts = Vec::with_capacity(4);
                for (ci, roff, krow) in [(2 * pi, 0usize, k_r0), (2 * pi + 1, k_r0, k_r1)] {
                    for (cj, coff, kcol) in [(2 * pj, 0usize, k_c0), (2 * pj + 1, k_c0, k_c1)] {
                        let src = if f.contains_key(&(ci, cj)) {
                            // Diagonal children read the post-Schur SS
                            // buffer; everything else the leading part of F.
                            if ci == cj && ss_buf.contains_key(&ci) {
                                ss_buf[&ci]
                            } else {
                                f[&(ci, cj)]
                            }
                        } else if self.h2.coupling[l].contains_key(&(ci, cj)) {
                            let b = self.buf();
                            coup_uploads
                                .push((HostSrc::Coupling { level: l, key: (ci, cj) }, b));
                            coup_bufs.push(b);
                            b
                        } else {
                            unreachable!("missing child block ({ci},{cj}) at level {l}")
                        };
                        parts.push(MergePart { roff, coff, rows: krow, cols: kcol, src });
                    }
                }
                let dst = self.buf();
                merge_items.push(MergeItem {
                    dst,
                    rows: k_r0 + k_r1,
                    cols: k_c0 + k_c1,
                    parts,
                });
                next.insert((pi, pj), dst);
                if (pi, pj) == (0, 0) && l == 1 {
                    root_n = k_r0 + k_r1;
                }
            }
            if !coup_uploads.is_empty() {
                self.steps.push(Instr::Upload { items: coup_uploads });
            }
            self.steps.push(Instr::Merge { level: l, items: merge_items });

            // F, SS, and coupling content is fully consumed by the merge.
            let mut free: Vec<BufferId> = f.values().copied().collect();
            free.extend(ss_buf.values().copied());
            free.extend(coup_bufs);
            free.sort_by_key(|b| b.0);
            self.steps.push(Instr::Free { bufs: free });

            let mut lr_keys: Vec<(usize, usize)> = lr_out.iter().map(|&(k, _)| k).collect();
            let mut ls_keys: Vec<(usize, usize)> = ls_out.iter().map(|&(k, _)| k).collect();
            lr_keys.sort_unstable();
            ls_keys.sort_unstable();
            self.infos.push(LevelInfo {
                level: l,
                width,
                ranks: (0..width).map(rank).collect(),
                nreds: (0..width).map(nred).collect(),
                near: near.clone(),
                lr_keys,
                ls_keys,
            });
            outputs.push(LevelOut {
                level: l,
                chol_rr: rr,
                lr: lr_out,
                ls: ls_out,
                near,
                basis,
            });
            level_programs.push(self.finish_level(l));
            current = next;
        }

        // --- Root factorization (Algorithm 2 line 22): a batch-of-one
        //     Potrf launch issued by the executor on `root_src`; the
        //     buffer then holds the root Cholesky factor for RootSolve. ---
        let root_src = *current.get(&(0, 0)).expect("root block must exist after merging");
        let root_launch = LaunchMeta::new(
            0,
            "POTRF",
            &[(root_n, root_n, potrf_flops(root_n))],
            |r, _| potrf_flops(r),
        );
        (prologue, level_programs, outputs, root_src, root_n, root_launch)
    }
}

// ---------------- Substitution (Algorithm 3 / §3.7) ----------------

impl SolveCtx {
    /// Build the shape-only factor description from the captured level
    /// structure (see [`crate::ulv::FactorMeta`]): the recorder's
    /// `(rank, nred)` tables and panel key sets are exactly the shapes the
    /// host mirror used to supply, so `FactorStorage::DeviceOnly` sessions
    /// derive them from the plan instead.
    pub(crate) fn factor_meta(
        &self,
        depth: usize,
        factor: &FactorProgram,
    ) -> crate::ulv::FactorMeta {
        crate::ulv::FactorMeta {
            levels: self
                .infos
                .iter()
                .map(|info| crate::ulv::LevelMeta {
                    level: info.level,
                    boxes: info
                        .ranks
                        .iter()
                        .zip(&info.nreds)
                        .map(|(&rank, &nred)| (rank + nred, rank))
                        .collect(),
                    near: info.near.clone(),
                    lr: info.lr_keys.clone(),
                    ls: info.ls_keys.clone(),
                })
                .collect(),
            root_n: factor.root_n,
            depth,
        }
    }

    /// Record one substitution program against the factorization program's
    /// own output wiring ([`FactorProgram::outputs`] — the single source of
    /// truth for which buffer holds which factor block). Vector buffers
    /// start right above the factorization arena.
    pub(crate) fn record_solve(&self, mode: SubstMode, factor: &FactorProgram) -> SolveProgram {
        let mut rec = SolveRecorder::new(factor.buf_count as u32);
        let leaf_ranges = &self.leaf_ranges;
        let root_n = factor.root_n;

        // ---------- Forward pass (leaves -> root). ----------
        let leaf_level = self.infos.first().map(|i| i.level).unwrap_or(0);
        let mut seg: Vec<BufferId> = leaf_ranges
            .iter()
            .enumerate()
            .map(|(i, &(s, e))| rec.vec(e - s, leaf_level, i))
            .collect();
        rec.steps.push(SolveInstr::LoadRhs {
            items: leaf_ranges
                .iter()
                .zip(&seg)
                .map(|(&(s, e), &v)| (s, e, v))
                .collect(),
        });
        let mut saved_r: Vec<Vec<BufferId>> = Vec::with_capacity(self.infos.len());

        for (li, info) in self.infos.iter().enumerate() {
            let level = info.level;
            let width = info.width;
            let (rr, lr, ls, basis) = level_wiring(&factor.outputs[li]);
            // 1. Apply Uᵀ: c_i = U_iᵀ b_i (batched).
            let c: Vec<BufferId> =
                (0..width).map(|i| rec.vec(info.ranks[i] + info.nreds[i], level, i)).collect();
            rec.apply_basis(level, true, info, basis, &seg, &c);
            // Split into skeleton (first k) and redundant (rest).
            let s_part: Vec<BufferId> =
                (0..width).map(|i| rec.vec(info.ranks[i], level, i)).collect();
            let mut r_part: Vec<BufferId> =
                (0..width).map(|i| rec.vec(info.nreds[i], level, i)).collect();
            rec.steps.push(SolveInstr::Split {
                items: (0..width)
                    .map(|i| (c[i], info.ranks[i], s_part[i], r_part[i]))
                    .collect(),
            });

            let active: Vec<usize> = (0..width).filter(|&i| info.nreds[i] > 0).collect();
            match mode {
                SubstMode::Naive => {
                    // Algorithm 3: serial over boxes, batch-of-one launches.
                    let lr_set: HashSet<(usize, usize)> =
                        info.lr_keys.iter().copied().collect();
                    let ls_set: HashSet<(usize, usize)> =
                        info.ls_keys.iter().copied().collect();
                    for &i in &active {
                        rec.trsv(level, false, &[(rr[i], r_part[i], info.nreds[i])]);
                        for &(j, i2) in &info.near {
                            if i2 != i {
                                continue;
                            }
                            if lr_set.contains(&(j, i)) {
                                rec.gemv_round(level, false, &[(
                                    lr[&(j, i)],
                                    r_part[i],
                                    r_part[j],
                                    (info.nreds[j], info.nreds[i]),
                                )]);
                            }
                            if ls_set.contains(&(j, i)) {
                                rec.gemv_round(level, false, &[(
                                    ls[&(j, i)],
                                    r_part[i],
                                    s_part[j],
                                    (info.ranks[j], info.nreds[i]),
                                )]);
                            }
                        }
                    }
                }
                SubstMode::Parallel => {
                    // §3.7: z_i = L_ii⁻¹ r_i (batched, independent).
                    let z: Vec<BufferId> =
                        active.iter().map(|&i| rec.vec(info.nreds[i], level, i)).collect();
                    rec.steps.push(SolveInstr::Copy {
                        items: active.iter().zip(&z).map(|(&i, &zi)| (zi, r_part[i])).collect(),
                    });
                    let diag_items: Vec<(BufferId, BufferId, usize)> = active
                        .iter()
                        .zip(&z)
                        .map(|(&i, &zi)| (rr[i], zi, info.nreds[i]))
                        .collect();
                    rec.trsv(level, false, &diag_items);
                    let slot_of: HashMap<usize, usize> =
                        active.iter().enumerate().map(|(s, &i)| (i, s)).collect();
                    // acc = -Σ L(r)_ij z_j in unique-target rounds.
                    let acc: Vec<BufferId> =
                        active.iter().map(|&i| rec.vec(info.nreds[i], level, i)).collect();
                    let entries: Vec<(BufferId, BufferId, BufferId, (usize, usize))> = info
                        .lr_keys
                        .iter()
                        .map(|&(row, col)| {
                            (
                                lr[&(row, col)],
                                z[slot_of[&col]],
                                acc[slot_of[&row]],
                                (info.nreds[row], info.nreds[col]),
                            )
                        })
                        .collect();
                    rec.gemv_rounds(level, false, &entries);
                    // corr = L⁻¹ acc; r = z + corr.
                    let corr_items: Vec<(BufferId, BufferId, usize)> = active
                        .iter()
                        .zip(&acc)
                        .map(|(&i, &a)| (rr[i], a, info.nreds[i]))
                        .collect();
                    rec.trsv(level, false, &corr_items);
                    let mut add_items = Vec::with_capacity(active.len());
                    for (slot, &i) in active.iter().enumerate() {
                        let r2 = rec.vec(info.nreds[i], level, i);
                        add_items.push((r2, z[slot], acc[slot]));
                        r_part[i] = r2;
                    }
                    rec.steps.push(SolveInstr::Add { items: add_items });
                    // s_j -= L(s)_ji r_i (unique-target rounds).
                    let entries: Vec<(BufferId, BufferId, BufferId, (usize, usize))> = info
                        .ls_keys
                        .iter()
                        .map(|&(j, i)| {
                            (
                                ls[&(j, i)],
                                r_part[i],
                                s_part[j],
                                (info.ranks[j], info.nreds[i]),
                            )
                        })
                        .collect();
                    rec.gemv_rounds(level, false, &entries);
                }
            }

            saved_r.push(r_part);
            // Merge skeleton parts for the parent level.
            let parent_width = width / 2;
            let mut next: Vec<BufferId> = Vec::with_capacity(parent_width);
            let mut cat = Vec::with_capacity(parent_width);
            for p in 0..parent_width {
                let v = rec.vec(info.ranks[2 * p] + info.ranks[2 * p + 1], level - 1, p);
                cat.push((v, s_part[2 * p], s_part[2 * p + 1]));
                next.push(v);
            }
            rec.steps.push(SolveInstr::Concat { items: cat });
            seg = next;
        }

        // ---------- Root solve (against the resident root factor). ----------
        rec.steps.push(SolveInstr::RootSolve { l: factor.root_src, x: seg[0] });
        rec.launches.push(LaunchMeta::new(
            0,
            "POTRS",
            &[(root_n, root_n, 2 * (root_n * root_n) as u64)],
            |r, _| 2 * (r * r) as u64,
        ));
        rec.shapes.push(vec![(root_n, root_n, 2 * (root_n * root_n) as u64)]);

        // ---------- Backward pass (root -> leaves). ----------
        let mut sol: Vec<BufferId> = vec![seg[0]];
        for (li, info) in self.infos.iter().enumerate().rev() {
            let level = info.level;
            let width = info.width;
            let (rr, lr, ls, basis) = level_wiring(&factor.outputs[li]);
            // Child skeleton solutions from the parent segments.
            let mut x_s: Vec<BufferId> = Vec::with_capacity(width);
            let mut splits = Vec::with_capacity(width / 2);
            for p in 0..width / 2 {
                let a = rec.vec(info.ranks[2 * p], level, 2 * p);
                let b = rec.vec(info.ranks[2 * p + 1], level, 2 * p + 1);
                splits.push((sol[p], info.ranks[2 * p], a, b));
                x_s.push(a);
                x_s.push(b);
            }
            rec.steps.push(SolveInstr::Split { items: splits });
            // w_i = y_i^R - Σ L(s)_jiᵀ x_j^S.
            let w: Vec<BufferId> =
                (0..width).map(|i| rec.vec(info.nreds[i], level, i)).collect();
            rec.steps.push(SolveInstr::Copy {
                items: (0..width).map(|i| (w[i], saved_r[li][i])).collect(),
            });
            let entries: Vec<(BufferId, BufferId, BufferId, (usize, usize))> = info
                .ls_keys
                .iter()
                .map(|&(j, i)| {
                    (ls[&(j, i)], x_s[j], w[i], (info.ranks[j], info.nreds[i]))
                })
                .collect();
            rec.gemv_rounds(level, true, &entries);

            let active: Vec<usize> = (0..width).filter(|&i| info.nreds[i] > 0).collect();
            let mut x_r: Vec<BufferId> = (0..width).map(|_| UNSET).collect();
            match mode {
                SubstMode::Naive => {
                    // Reverse-order serial upper solve.
                    for &i in active.iter().rev() {
                        let rhs = rec.vec(info.nreds[i], level, i);
                        rec.steps.push(SolveInstr::Copy { items: vec![(rhs, w[i])] });
                        for &(j, i2) in &info.lr_keys {
                            if i2 != i {
                                continue;
                            }
                            // j > i: already solved in reverse order.
                            rec.gemv_round(level, true, &[(
                                lr[&(j, i)],
                                x_r[j],
                                rhs,
                                (info.nreds[j], info.nreds[i]),
                            )]);
                        }
                        rec.trsv(level, true, &[(rr[i], rhs, info.nreds[i])]);
                        x_r[i] = rhs;
                    }
                }
                SubstMode::Parallel => {
                    // Single-hop: z = Lᵀ⁻¹ w; x = z + Lᵀ⁻¹(-Σ L(r)ᵀ z).
                    let z: Vec<BufferId> =
                        active.iter().map(|&i| rec.vec(info.nreds[i], level, i)).collect();
                    rec.steps.push(SolveInstr::Copy {
                        items: active.iter().zip(&z).map(|(&i, &zi)| (zi, w[i])).collect(),
                    });
                    let diag_items: Vec<(BufferId, BufferId, usize)> = active
                        .iter()
                        .zip(&z)
                        .map(|(&i, &zi)| (rr[i], zi, info.nreds[i]))
                        .collect();
                    rec.trsv(level, true, &diag_items);
                    let slot_of: HashMap<usize, usize> =
                        active.iter().enumerate().map(|(s, &i)| (i, s)).collect();
                    let acc: Vec<BufferId> =
                        active.iter().map(|&i| rec.vec(info.nreds[i], level, i)).collect();
                    let entries: Vec<(BufferId, BufferId, BufferId, (usize, usize))> = info
                        .lr_keys
                        .iter()
                        .map(|&(row, col)| {
                            (
                                lr[&(row, col)],
                                z[slot_of[&row]],
                                acc[slot_of[&col]],
                                (info.nreds[row], info.nreds[col]),
                            )
                        })
                        .collect();
                    rec.gemv_rounds(level, true, &entries);
                    let corr_items: Vec<(BufferId, BufferId, usize)> = active
                        .iter()
                        .zip(&acc)
                        .map(|(&i, &a)| (rr[i], a, info.nreds[i]))
                        .collect();
                    rec.trsv(level, true, &corr_items);
                    let mut add_items = Vec::with_capacity(active.len());
                    for (slot, &i) in active.iter().enumerate() {
                        let xi = rec.vec(info.nreds[i], level, i);
                        add_items.push((xi, z[slot], acc[slot]));
                        x_r[i] = xi;
                    }
                    rec.steps.push(SolveInstr::Add { items: add_items });
                }
            }
            for i in 0..width {
                if x_r[i] == UNSET {
                    x_r[i] = rec.vec(info.nreds[i], level, i); // nred == 0: empty
                }
            }
            // x_i = U_i [x_i^S; x_i^R] (batched).
            let stacked: Vec<BufferId> =
                (0..width).map(|i| rec.vec(info.ranks[i] + info.nreds[i], level, i)).collect();
            rec.steps.push(SolveInstr::Concat {
                items: (0..width).map(|i| (stacked[i], x_s[i], x_r[i])).collect(),
            });
            let out: Vec<BufferId> =
                (0..width).map(|i| rec.vec(info.ranks[i] + info.nreds[i], level, i)).collect();
            rec.apply_basis(level, false, info, basis, &stacked, &out);
            sol = out;
        }

        rec.steps.push(SolveInstr::StoreSol {
            items: leaf_ranges
                .iter()
                .zip(&sol)
                .map(|(&(s, e), &v)| (s, e, v))
                .collect(),
        });

        // Algorithm 3 emits batch-of-one launches along a serial chain;
        // the dependency-aware pass widens them wherever the chain's runs
        // are actually independent. The parallel program (§3.7) is already
        // maximally batched by construction and is left untouched.
        if matches!(mode, SubstMode::Naive) {
            coalesce_naive(&mut rec);
        }

        let total_flops = rec.launches.iter().map(|l| l.flops).sum();
        SolveProgram {
            vec_base: factor.buf_count as u32,
            vec_lens: rec.vec_lens,
            vec_home: rec.vec_home,
            steps: rec.steps,
            launches: rec.launches,
            total_flops,
        }
    }
}

/// Per-level arena wiring pulled from the factorization program's output
/// table (lookup maps are built transiently; recording runs at most twice
/// per plan).
#[allow(clippy::type_complexity)]
fn level_wiring(
    out: &LevelOut,
) -> (
    &[BufferId],
    HashMap<(usize, usize), BufferId>,
    HashMap<(usize, usize), BufferId>,
    &[BufferId],
) {
    (
        &out.chol_rr,
        out.lr.iter().copied().collect(),
        out.ls.iter().copied().collect(),
        &out.basis,
    )
}

/// Scratch state while recording one substitution program.
struct SolveRecorder {
    base: u32,
    vec_lens: Vec<usize>,
    vec_home: Vec<(u32, u32)>,
    steps: Vec<SolveInstr>,
    launches: Vec<LaunchMeta>,
    /// Per-launch `(rows, cols, flops)` shape lists, parallel to
    /// `launches`. [`LaunchMeta`] aggregates shapes away at construction;
    /// the coalescing pass needs them back to rebuild exact metadata for
    /// merged batches.
    shapes: Vec<Vec<(usize, usize, u64)>>,
}

impl SolveRecorder {
    fn new(base: u32) -> SolveRecorder {
        SolveRecorder {
            base,
            vec_lens: Vec::new(),
            vec_home: Vec::new(),
            steps: Vec::new(),
            launches: Vec::new(),
            shapes: Vec::new(),
        }
    }

    /// Allocate the next vector buffer (ids live above the factorization
    /// arena so matrix and vector operands share one id space). `(level,
    /// bx)` is the tree position the vector belongs to — the ownership
    /// annotation SPMD carving reads (see [`SolveProgram::vec_home`]).
    fn vec(&mut self, len: usize, level: usize, bx: usize) -> BufferId {
        let id = BufferId(self.base + self.vec_lens.len() as u32);
        self.vec_lens.push(len);
        self.vec_home.push((level as u32, bx as u32));
        id
    }

    fn apply_basis(
        &mut self,
        level: usize,
        trans: bool,
        info: &LevelInfo,
        basis: &[BufferId],
        src: &[BufferId],
        dst: &[BufferId],
    ) {
        let items: Vec<BasisItem> =
            (0..info.width).map(|i| (basis[i], src[i], dst[i])).collect();
        let shapes: Vec<(usize, usize, u64)> = (0..info.width)
            .map(|i| {
                let n = info.ranks[i] + info.nreds[i];
                (n, n, 2 * (n * n) as u64)
            })
            .collect();
        self.launches.push(LaunchMeta::new(level, "BASIS", &shapes, |r, c| 2 * (r * c) as u64));
        self.shapes.push(shapes);
        self.steps.push(SolveInstr::ApplyBasis { level, trans, items });
    }

    fn trsv(&mut self, level: usize, bwd: bool, items: &[(BufferId, BufferId, usize)]) {
        if items.is_empty() {
            return;
        }
        let shapes: Vec<(usize, usize, u64)> =
            items.iter().map(|&(_, _, n)| (n, n, (n * n) as u64)).collect();
        let kernel = if bwd { "TRSVT" } else { "TRSV" };
        self.launches.push(LaunchMeta::new(level, kernel, &shapes, |r, _| (r * r) as u64));
        self.shapes.push(shapes);
        let instr_items: Vec<(BufferId, BufferId)> =
            items.iter().map(|&(m, v, _)| (m, v)).collect();
        if bwd {
            self.steps.push(SolveInstr::TrsvBwd { level, items: instr_items });
        } else {
            self.steps.push(SolveInstr::TrsvFwd { level, items: instr_items });
        }
    }

    /// One batched `y += -op(A) x` launch; callers guarantee unique `y`.
    fn gemv_round(
        &mut self,
        level: usize,
        trans: bool,
        entries: &[(BufferId, BufferId, BufferId, (usize, usize))],
    ) {
        if entries.is_empty() {
            return;
        }
        debug_assert!({
            let ys: HashSet<BufferId> = entries.iter().map(|&(_, _, y, _)| y).collect();
            ys.len() == entries.len() && entries.iter().all(|&(_, x, _, _)| !ys.contains(&x))
        });
        let shapes: Vec<(usize, usize, u64)> = entries
            .iter()
            .map(|&(_, _, _, (r, c))| (r, c, 2 * (r * c) as u64))
            .collect();
        self.launches.push(LaunchMeta::new(level, "GEMV", &shapes, |r, c| 2 * (r * c) as u64));
        self.shapes.push(shapes);
        self.steps.push(SolveInstr::GemvAcc {
            level,
            trans,
            items: entries.iter().map(|&(m, x, y, _)| (m, x, y)).collect(),
        });
    }

    /// Split accumulations into launches with unique targets, mirroring the
    /// conflict-free batched GEMV rounds of the GPU implementation.
    fn gemv_rounds(
        &mut self,
        level: usize,
        trans: bool,
        entries: &[(BufferId, BufferId, BufferId, (usize, usize))],
    ) {
        let mut remaining: Vec<usize> = (0..entries.len()).collect();
        while !remaining.is_empty() {
            let mut used = HashSet::new();
            let mut round = Vec::new();
            let mut rest = Vec::new();
            for &t in &remaining {
                if used.insert(entries[t].2) {
                    round.push(t);
                } else {
                    rest.push(t);
                }
            }
            remaining = rest;
            let batch: Vec<(BufferId, BufferId, BufferId, (usize, usize))> =
                round.iter().map(|&t| entries[t]).collect();
            self.gemv_round(level, trans, &batch);
        }
    }
}

// ---------------- Naive-chain coalescing pass ----------------

/// Per-step hazard sets `(reads, writes)` as sorted, deduplicated raw ids
/// (matrix and vector ids share one space; read-modify-write operands
/// count as writes — the same classification the async engine's runtime
/// tracker applies at enqueue). `None` marks a scheduling barrier the pass
/// never moves a launch across (`Exchange` — the transport runs outside
/// the device's hazard discipline).
pub(crate) fn solve_step_hazards(step: &SolveInstr) -> Option<(Vec<u32>, Vec<u32>)> {
    use crate::batch::device::{launch_operands, Launch};
    let ops = match step {
        SolveInstr::LoadRhs { items } => {
            return Some((Vec::new(), items.iter().map(|&(_, _, v)| v.0).collect()));
        }
        SolveInstr::StoreSol { items } => {
            return Some((items.iter().map(|&(_, _, v)| v.0).collect(), Vec::new()));
        }
        SolveInstr::Exchange { .. } => return None,
        SolveInstr::ApplyBasis { level, trans, items } => {
            launch_operands(&Launch::ApplyBasis { level: *level, trans: *trans, items })
        }
        SolveInstr::Split { items } => launch_operands(&Launch::Split { items }),
        SolveInstr::Concat { items } => launch_operands(&Launch::Concat { items }),
        SolveInstr::Copy { items } => launch_operands(&Launch::CopyBuf { items }),
        SolveInstr::TrsvFwd { level, items } => {
            launch_operands(&Launch::TrsvFwd { level: *level, items })
        }
        SolveInstr::TrsvBwd { level, items } => {
            launch_operands(&Launch::TrsvBwd { level: *level, items })
        }
        SolveInstr::GemvAcc { level, trans, items } => launch_operands(&Launch::GemvAcc {
            level: *level,
            trans: *trans,
            alpha: -1.0,
            items,
        }),
        SolveInstr::Add { items } => launch_operands(&Launch::AddVec { items }),
        SolveInstr::RootSolve { l, x } => launch_operands(&Launch::RootSolve { l: *l, x: *x }),
    };
    let mut reads: Vec<u32> =
        ops.mat_reads.iter().chain(&ops.vec_reads).map(|b| b.0).collect();
    let mut writes: Vec<u32> = ops
        .mat_rw
        .iter()
        .chain(&ops.mat_writes)
        .chain(&ops.vec_rw)
        .chain(&ops.vec_writes)
        .map(|b| b.0)
        .collect();
    reads.sort_unstable();
    reads.dedup();
    writes.sort_unstable();
    writes.dedup();
    Some((reads, writes))
}

/// Coalescing key: two launches may merge only when they run the same
/// kernel at the same tree level (and, for GEMV, the same transpose — the
/// recorded accumulate alpha is the constant −1.0, so it never splits a
/// key).
fn merge_key(step: &SolveInstr) -> Option<(u8, usize, bool)> {
    match step {
        SolveInstr::TrsvFwd { level, .. } => Some((0, *level, false)),
        SolveInstr::TrsvBwd { level, .. } => Some((1, *level, false)),
        SolveInstr::GemvAcc { level, trans, .. } => Some((2, *level, *trans)),
        // Copies carry no launch metadata, but merging them matters: the
        // backward chain stages every box's RHS through a copy, and an
        // unmerged copy pins its TRSV (which read-write-conflicts with it)
        // at the original serial position.
        SolveInstr::Copy { .. } => Some((3, 0, false)),
        _ => None,
    }
}

fn intersects(a: &[u32], b: &[u32]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => return true,
        }
    }
    false
}

/// Rebuild exact launch metadata for a merged batch from the retained
/// shape lists (the padding model is per-kernel, so flops and padded
/// flops come out exactly as if the batch had been recorded wide).
fn rebuild_meta(instr: &SolveInstr, shapes: &[(usize, usize, u64)]) -> LaunchMeta {
    match instr {
        SolveInstr::TrsvFwd { level, .. } => {
            LaunchMeta::new(*level, "TRSV", shapes, |r, _| (r * r) as u64)
        }
        SolveInstr::TrsvBwd { level, .. } => {
            LaunchMeta::new(*level, "TRSVT", shapes, |r, _| (r * r) as u64)
        }
        SolveInstr::GemvAcc { level, .. } => {
            LaunchMeta::new(*level, "GEMV", shapes, |r, c| 2 * (r * c) as u64)
        }
        _ => unreachable!("only TRSV/TRSVT/GEMV launches are coalesced"),
    }
}

/// Dependency-aware coalescing of a recorded **naive** substitution
/// program. Algorithm 3's serial chain emits batch-of-one TRSV/GEMV
/// launches, but most of its runs are independent (different boxes touch
/// different diagonal blocks and vector segments). Each mergeable launch
/// scans *backward* over the already-emitted stream, hopping past steps it
/// shares no buffer hazard with, and merges into the nearest launch with
/// the same key ([`merge_key`]); the scan stops at the first conflicting
/// step or hard barrier, so every merge is a reordering the hazard graph
/// already permitted — dataflow, and therefore bit-exactness, is
/// preserved, and the static graph of the coalesced program is exactly
/// what the async engine's runtime tracker journals. A merged batch keeps
/// the recorder's alias discipline by construction: a duplicate write
/// target or a write aliasing another item's read *is* a hazard, so the
/// scan stops before ever proposing such a merge.
///
/// Launch metadata is rebuilt per merged batch from the retained shape
/// lists; unmerged launches keep their original metadata objects, so the
/// total-flops invariant (a shape-multiset sum) and the predicted peak
/// (a function of `vec_lens`, untouched here) stay byte-exact.
fn coalesce_naive(rec: &mut SolveRecorder) {
    struct OutStep {
        instr: SolveInstr,
        reads: Vec<u32>,
        writes: Vec<u32>,
        /// `Some((original meta index, shapes, merged))` for launch steps.
        launch: Option<(usize, Vec<(usize, usize, u64)>, bool)>,
        barrier: bool,
    }

    let steps = std::mem::take(&mut rec.steps);
    let mut metas: Vec<Option<LaunchMeta>> =
        std::mem::take(&mut rec.launches).into_iter().map(Some).collect();
    let shapes = std::mem::take(&mut rec.shapes);
    debug_assert_eq!(metas.len(), shapes.len());

    let mut out: Vec<OutStep> = Vec::with_capacity(steps.len());
    let mut next_meta = 0usize;
    for instr in steps {
        let is_launch = matches!(
            instr,
            SolveInstr::ApplyBasis { .. }
                | SolveInstr::TrsvFwd { .. }
                | SolveInstr::TrsvBwd { .. }
                | SolveInstr::GemvAcc { .. }
                | SolveInstr::RootSolve { .. }
        );
        let launch = if is_launch {
            let m = next_meta;
            next_meta += 1;
            Some((m, shapes[m].clone(), false))
        } else {
            None
        };
        let (reads, writes, barrier) = match solve_step_hazards(&instr) {
            Some((r, w)) => (r, w, false),
            None => (Vec::new(), Vec::new(), true),
        };
        if let Some(key) = merge_key(&instr) {
            let mut target = None;
            for k in (0..out.len()).rev() {
                let o = &out[k];
                if o.barrier {
                    break;
                }
                if intersects(&writes, &o.reads)
                    || intersects(&reads, &o.writes)
                    || intersects(&writes, &o.writes)
                {
                    break;
                }
                if merge_key(&o.instr) == Some(key) {
                    target = Some(k);
                    break;
                }
            }
            if let Some(k) = target {
                let o = &mut out[k];
                match (&mut o.instr, instr) {
                    (
                        SolveInstr::TrsvFwd { items: ti, .. },
                        SolveInstr::TrsvFwd { items, .. },
                    )
                    | (
                        SolveInstr::TrsvBwd { items: ti, .. },
                        SolveInstr::TrsvBwd { items, .. },
                    )
                    | (SolveInstr::Copy { items: ti }, SolveInstr::Copy { items }) => {
                        ti.extend(items)
                    }
                    (
                        SolveInstr::GemvAcc { items: ti, .. },
                        SolveInstr::GemvAcc { items, .. },
                    ) => ti.extend(items),
                    _ => unreachable!("merge key matched across launch kinds"),
                }
                // Copies carry no metadata; for real launches the merged
                // batch is re-described from the combined shape list.
                if let (Some((_, t_shapes, merged)), Some((_, s_shapes, _))) =
                    (o.launch.as_mut(), launch)
                {
                    t_shapes.extend(s_shapes);
                    *merged = true;
                }
                o.reads.extend(reads);
                o.reads.sort_unstable();
                o.reads.dedup();
                o.writes.extend(writes);
                o.writes.sort_unstable();
                o.writes.dedup();
                continue;
            }
        }
        out.push(OutStep { instr, reads, writes, launch, barrier });
    }
    debug_assert_eq!(next_meta, metas.len());

    for o in out {
        if let Some((mi, shp, merged)) = o.launch {
            let meta = if merged {
                rebuild_meta(&o.instr, &shp)
            } else {
                metas[mi].take().expect("each original meta is consumed once")
            };
            rec.launches.push(meta);
        }
        rec.steps.push(o.instr);
    }
}
