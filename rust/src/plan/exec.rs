//! The [`Executor`]: replays a recorded [`Plan`] against any
//! [`BatchExec`] backend.
//!
//! Replay is deterministic: the instruction stream fixes the launch order
//! and the grouping of every batch, so two replays of the same plan on the
//! same backend are bit-identical — the property the plan-replay tests
//! assert and the property that makes backend rebinding
//! ([`crate::solver::H2Solver::rebind_backend`]) a pure re-execution.

use super::*;
use crate::batch::BatchExec;
use crate::h2::H2Matrix;
use crate::linalg::chol;
use crate::linalg::Matrix;
use crate::metrics::flops::{self, FlopScope, Phase};
use crate::ulv::{LevelFactor, SubstMode, UlvFactor};
use std::collections::HashMap;
use std::sync::Arc;

/// Replays plans. Holds the backend and an optional per-session
/// [`FlopScope`] that the plan's static FLOP metadata is credited to.
pub struct Executor<'a> {
    exec: &'a dyn BatchExec,
    scope: Option<&'a FlopScope>,
}

impl<'a> Executor<'a> {
    pub fn new(exec: &'a dyn BatchExec) -> Executor<'a> {
        Executor { exec, scope: None }
    }

    /// Credit executed FLOPs (from the plan's metadata) to `scope` in
    /// addition to the deprecated process-global counters the backends
    /// still feed.
    pub fn with_scope(mut self, scope: &'a FlopScope) -> Executor<'a> {
        self.scope = Some(scope);
        self
    }

    // ---------------- Factorization replay ----------------

    /// Replay the factorization program against `h2`, producing a
    /// [`UlvFactor`] that shares `plan` for its substitution replays.
    ///
    /// `h2` may be any matrix structurally identical to the one the plan
    /// was recorded from ([`Plan::compatible`]).
    pub fn factorize(&self, plan: &Arc<Plan>, h2: &H2Matrix) -> UlvFactor {
        assert!(plan.compatible(h2), "plan recorded for a different H2 structure");
        let prev_phase = flops::set_phase(Phase::Factor);
        let prog = &plan.factor;
        let mut arena: Vec<Option<Matrix>> = (0..prog.buf_count).map(|_| None).collect();

        self.exec_factor_steps(&prog.prologue, &mut arena, h2);
        for lp in &prog.levels {
            self.exec_factor_steps(&lp.steps, &mut arena, h2);
        }
        self.finish_factor(plan, h2, arena, prev_phase)
    }

    /// Execute one stream of factorization instructions against the arena.
    fn exec_factor_steps(
        &self,
        steps: &[Instr],
        arena: &mut Vec<Option<Matrix>>,
        h2: &H2Matrix,
    ) {
        for step in steps {
            match step {
                Instr::LoadDense { items } => {
                    for &(key, dst) in items {
                        put(&mut arena, dst, h2.dense[&key].clone());
                    }
                }
                Instr::Sparsify { level, items } => {
                    let blocks: Vec<Matrix> =
                        items.iter().map(|it| take(&mut arena, it.a)).collect();
                    let us: Vec<&Matrix> =
                        items.iter().map(|it| &h2.bases[it.u.level][it.u.index].u).collect();
                    let vs: Vec<&Matrix> =
                        items.iter().map(|it| &h2.bases[it.v.level][it.v.index].u).collect();
                    let out = self.exec.sparsify(*level, &us, &blocks, &vs);
                    for (it, m) in items.iter().zip(out) {
                        put(&mut arena, it.dst, m);
                    }
                }
                Instr::Extract { items } => {
                    for it in items {
                        let m = get(&arena, it.src).submatrix(it.r0, it.c0, it.rows, it.cols);
                        put(&mut arena, it.dst, m);
                    }
                }
                Instr::Potrf { level, bufs } => {
                    let mut batch: Vec<Matrix> =
                        bufs.iter().map(|&b| take(&mut arena, b)).collect();
                    self.exec.potrf(*level, &mut batch);
                    for (&b, m) in bufs.iter().zip(batch) {
                        put(&mut arena, b, m);
                    }
                }
                Instr::TrsmRightLt { level, items } => {
                    let mut panels: Vec<Matrix> =
                        items.iter().map(|it| take(&mut arena, it.b)).collect();
                    {
                        let diags: Vec<&Matrix> =
                            items.iter().map(|it| get(&arena, it.l)).collect();
                        self.exec.trsm_right_lt(*level, &diags, &mut panels);
                    }
                    for (it, m) in items.iter().zip(panels) {
                        put(&mut arena, it.b, m);
                    }
                }
                Instr::SchurSelf { level, items } => {
                    let mut cs: Vec<Matrix> =
                        items.iter().map(|it| take(&mut arena, it.c)).collect();
                    {
                        let aas: Vec<&Matrix> =
                            items.iter().map(|it| get(&arena, it.a)).collect();
                        self.exec.schur_self(*level, &aas, &mut cs);
                    }
                    for (it, m) in items.iter().zip(cs) {
                        put(&mut arena, it.c, m);
                    }
                }
                Instr::Merge { level: _, items } => {
                    for item in items {
                        let mut merged = Matrix::zeros(item.rows, item.cols);
                        for part in &item.parts {
                            match &part.src {
                                MergeSrc::BufferSub(b) => {
                                    let src = get(&arena, *b);
                                    if src.rows() == part.rows && src.cols() == part.cols {
                                        merged.set_submatrix(part.roff, part.coff, src);
                                    } else {
                                        let blk = src.submatrix(0, 0, part.rows, part.cols);
                                        merged.set_submatrix(part.roff, part.coff, &blk);
                                    }
                                }
                                MergeSrc::Coupling(l, key) => {
                                    let s = h2.coupling[*l]
                                        .get(key)
                                        .expect("plan coupling ref missing in H2 matrix");
                                    merged.set_submatrix(part.roff, part.coff, s);
                                }
                            }
                        }
                        put(&mut arena, item.dst, merged);
                    }
                }
                Instr::Free { bufs } => {
                    for &b in bufs {
                        arena[b.0 as usize] = None;
                    }
                }
            }
        }
    }

    /// Assemble the [`UlvFactor`] from the output wiring and run the dense
    /// root Cholesky (Algorithm 2 line 22).
    fn finish_factor(
        &self,
        plan: &Arc<Plan>,
        h2: &H2Matrix,
        mut arena: Vec<Option<Matrix>>,
        prev_phase: Phase,
    ) -> UlvFactor {
        let prog = &plan.factor;
        // Assemble the factor from the output wiring.
        let mut levels: Vec<LevelFactor> = Vec::with_capacity(prog.outputs.len());
        for out in &prog.outputs {
            let chol_rr: Vec<Matrix> =
                out.chol_rr.iter().map(|&b| take(&mut arena, b)).collect();
            let lr: HashMap<(usize, usize), Matrix> =
                out.lr.iter().map(|&(k, b)| (k, take(&mut arena, b))).collect();
            let ls: HashMap<(usize, usize), Matrix> =
                out.ls.iter().map(|&(k, b)| (k, take(&mut arena, b))).collect();
            levels.push(LevelFactor {
                level: out.level,
                bases: h2.bases[out.level].clone(),
                chol_rr,
                lr,
                ls,
                near: out.near.clone(),
            });
        }

        // Root factorization (Algorithm 2 line 22).
        let root = take(&mut arena, prog.root_src);
        flops::add(flops::potrf_flops(root.rows()));
        let root_l = chol::cholesky(&root).expect("root block must stay SPD");
        flops::set_phase(prev_phase);
        if let Some(scope) = self.scope {
            scope.add(Phase::Factor, prog.total_flops);
        }

        UlvFactor {
            levels,
            root_l,
            depth: plan.depth,
            leaf_ranges: h2.tree.leaves().iter().map(|n| (n.begin, n.end)).collect(),
            perm: h2.tree.perm.clone(),
            plan: plan.clone(),
        }
    }

    // ---------------- Substitution replay ----------------

    /// Replay the substitution program for `mode` against a tree-ordered
    /// right-hand side; returns the tree-ordered solution.
    pub fn solve(
        &self,
        plan: &Plan,
        factor: &UlvFactor,
        b: &[f64],
        mode: SubstMode,
    ) -> Vec<f64> {
        assert_eq!(b.len(), plan.n);
        let prev_phase = flops::set_phase(Phase::Substitute);
        let prog = plan.solve_program(mode);
        let mut varena: Vec<Vec<f64>> =
            prog.vec_lens.iter().map(|&len| vec![0.0; len]).collect();
        let mut x = vec![0.0; plan.n];

        for step in &prog.steps {
            match step {
                SolveInstr::LoadRhs { items } => {
                    for &(s, e, v) in items {
                        varena[v.0 as usize].copy_from_slice(&b[s..e]);
                    }
                }
                SolveInstr::ApplyBasis { level_idx, level, trans, items } => {
                    let us: Vec<&Matrix> = items
                        .iter()
                        .map(|&(i, _, _)| &factor.levels[*level_idx].bases[i].u)
                        .collect();
                    let outs = {
                        let refs: Vec<&[f64]> = items
                            .iter()
                            .map(|&(_, s, _)| varena[s.0 as usize].as_slice())
                            .collect();
                        self.exec.apply_basis(*level, &us, *trans, &refs)
                    };
                    for (&(_, _, d), o) in items.iter().zip(outs) {
                        varena[d.0 as usize] = o;
                    }
                }
                SolveInstr::Split { items } => {
                    for &(src, at, lo, hi) in items {
                        let (a, b2) = {
                            let s = &varena[src.0 as usize];
                            (s[..at].to_vec(), s[at..].to_vec())
                        };
                        varena[lo.0 as usize] = a;
                        varena[hi.0 as usize] = b2;
                    }
                }
                SolveInstr::Concat { items } => {
                    for &(dst, a, b2) in items {
                        let mut v = varena[a.0 as usize].clone();
                        v.extend_from_slice(&varena[b2.0 as usize]);
                        varena[dst.0 as usize] = v;
                    }
                }
                SolveInstr::Copy { items } => {
                    for &(dst, src) in items {
                        varena[dst.0 as usize] = varena[src.0 as usize].clone();
                    }
                }
                SolveInstr::TrsvFwd { level, items } => {
                    let mut xs: Vec<Vec<f64>> = items
                        .iter()
                        .map(|&(_, v)| std::mem::take(&mut varena[v.0 as usize]))
                        .collect();
                    let ls: Vec<&Matrix> = items.iter().map(|(m, _)| mat(factor, m)).collect();
                    self.exec.trsv_fwd(*level, &ls, &mut xs);
                    for (&(_, v), xv) in items.iter().zip(xs) {
                        varena[v.0 as usize] = xv;
                    }
                }
                SolveInstr::TrsvBwd { level, items } => {
                    let mut xs: Vec<Vec<f64>> = items
                        .iter()
                        .map(|&(_, v)| std::mem::take(&mut varena[v.0 as usize]))
                        .collect();
                    let ls: Vec<&Matrix> = items.iter().map(|(m, _)| mat(factor, m)).collect();
                    self.exec.trsv_bwd(*level, &ls, &mut xs);
                    for (&(_, v), xv) in items.iter().zip(xs) {
                        varena[v.0 as usize] = xv;
                    }
                }
                SolveInstr::GemvAcc { level, trans, items } => {
                    let mut ys: Vec<Vec<f64>> = items
                        .iter()
                        .map(|&(_, _, y)| std::mem::take(&mut varena[y.0 as usize]))
                        .collect();
                    {
                        let mats: Vec<&Matrix> =
                            items.iter().map(|(m, _, _)| mat(factor, m)).collect();
                        let xs: Vec<&[f64]> = items
                            .iter()
                            .map(|&(_, xv, _)| varena[xv.0 as usize].as_slice())
                            .collect();
                        self.exec.gemv_acc(*level, -1.0, &mats, *trans, &xs, &mut ys);
                    }
                    for (&(_, _, y), yv) in items.iter().zip(ys) {
                        varena[y.0 as usize] = yv;
                    }
                }
                SolveInstr::Add { items } => {
                    for &(dst, a, b2) in items {
                        let v: Vec<f64> = varena[a.0 as usize]
                            .iter()
                            .zip(&varena[b2.0 as usize])
                            .map(|(&p, &q)| p + q)
                            .collect();
                        varena[dst.0 as usize] = v;
                    }
                }
                SolveInstr::RootSolve { vec } => {
                    let n = factor.root_l.rows();
                    flops::add(2 * (n * n) as u64);
                    chol::potrs(&factor.root_l, &mut varena[vec.0 as usize]);
                }
                SolveInstr::StoreSol { items } => {
                    for &(s, e, v) in items {
                        x[s..e].copy_from_slice(&varena[v.0 as usize]);
                    }
                }
            }
        }

        flops::set_phase(prev_phase);
        if let Some(scope) = self.scope {
            scope.add(Phase::Substitute, prog.total_flops);
        }
        x
    }
}

fn take(arena: &mut [Option<Matrix>], b: BufferId) -> Matrix {
    arena[b.0 as usize].take().expect("plan buffer read after free")
}

fn get<'m>(arena: &'m [Option<Matrix>], b: BufferId) -> &'m Matrix {
    arena[b.0 as usize].as_ref().expect("plan buffer read before write")
}

fn put(arena: &mut [Option<Matrix>], b: BufferId, m: Matrix) {
    arena[b.0 as usize] = Some(m);
}

fn mat<'f>(factor: &'f UlvFactor, m: &MatRef) -> &'f Matrix {
    match *m {
        MatRef::CholRr { level_idx, index } => &factor.levels[level_idx].chol_rr[index],
        MatRef::Lr { level_idx, key } => &factor.levels[level_idx].lr[&key],
        MatRef::Ls { level_idx, key } => &factor.levels[level_idx].ls[&key],
    }
}
