//! The [`Executor`]: replays a recorded [`Plan`] against any
//! [`Device`] backend.
//!
//! The executor performs **zero per-launch host-slice marshalling**: every
//! factorization and substitution instruction maps 1:1 onto a
//! [`Launch`] whose operands are the plan's own `BufferId` lists, issued
//! against a device-owned arena. Host memory is touched only at the
//! explicit transfer points — `Instr::Upload` (H² data in), `LoadRhs`
//! (right-hand side in), `StoreSol` and the factor download (results out).
//!
//! Replay is deterministic: the instruction stream fixes the launch order
//! and the grouping of every batch, so two replays of the same plan on the
//! same backend are bit-identical — the property the plan-replay tests
//! assert and the property that makes backend rebinding
//! ([`crate::solver::H2Solver::rebind_backend`]) a pure re-execution.
//!
//! After [`Executor::factorize_resident`] the factor matrices (and bases
//! and root factor) are still live in the arena; substitution programs
//! reference them by the same `BufferId`s, so a session can replay solves
//! against the resident arena without re-uploading the factor
//! ([`Executor::solve_in`]). [`Executor::upload_factor`] rebuilds such an
//! arena from a host-side [`UlvFactor`] for standalone solves;
//! [`Executor::factorize_device_only`] skips the host mirror entirely
//! (`FactorStorage::DeviceOnly`).
//!
//! Substitution replays are **concurrent**: [`Executor::solve_in`] takes
//! the factor arena by shared reference (solve programs only *read* the
//! factor) and a private [`VecRegion`] workspace for its vector buffers,
//! so any number of threads can replay solves against one resident factor
//! simultaneously — no lock is held across launches.

use super::*;
use crate::batch::device::{Device, DeviceArena, Launch, VecRegion};
use crate::dist::exec::{CommPayload, ExchangeMsg, Transport};
use crate::h2::H2Matrix;
use crate::linalg::Matrix;
use crate::metrics::flops::{FlopScope, Phase};
use crate::metrics::RunTrace;
use crate::ulv::{LevelFactor, SubstMode, UlvFactor};
use std::collections::HashMap;
use std::sync::Arc;

/// Replays plans. Holds the device, an optional per-session
/// [`FlopScope`] that the plan's static FLOP metadata is credited to, and
/// an optional [`RunTrace`] recording replay-level spans.
pub struct Executor<'a> {
    device: &'a dyn Device,
    scope: Option<&'a FlopScope>,
    trace: Option<RunTrace>,
    /// Rank-boundary endpoint for `Exchange` instructions — `Some` only
    /// when replaying a carved [`RankPlan`]; a global plan contains no
    /// comm instructions and never consults it.
    comm: Option<&'a dyn Transport>,
}

/// What happens to the factor when a factorization replay finishes.
enum Mirror {
    /// Move the factor out of the (about-to-drop) arena.
    Move,
    /// Download a host mirror, keeping the arena resident.
    Download,
    /// Keep only the resident arena (`FactorStorage::DeviceOnly`).
    Skip,
}

impl<'a> Executor<'a> {
    pub fn new(device: &'a dyn Device) -> Executor<'a> {
        Executor { device, scope: None, trace: None, comm: None }
    }

    /// Attach the rank-boundary [`Transport`] endpoint that `Exchange`
    /// instructions execute through (SPMD replay of a carved
    /// [`RankPlan`]). Replaying a stream that contains comm instructions
    /// without an endpoint panics at the first exchange.
    pub fn with_comm(mut self, comm: &'a dyn Transport) -> Executor<'a> {
        self.comm = Some(comm);
        self
    }

    /// Credit executed FLOPs (from the plan's statically-known metadata)
    /// to `scope`. Kernel-level counting stays off during replay: the
    /// executor binds no ambient scope, so backend `flops::add` calls are
    /// no-ops and nothing double-counts.
    pub fn with_scope(mut self, scope: &'a FlopScope) -> Executor<'a> {
        self.scope = Some(scope);
        self
    }

    /// Record one span per replayed level (`factor-level`, `factor-root`,
    /// `solve-replay`) into `trace` — the executor's slice of the
    /// session-wide structured run trace. Issue-side wall time: on an
    /// overlapping device a level span covers journaling, not kernel
    /// completion (that is the overlap trace's job).
    pub fn with_trace(mut self, trace: RunTrace) -> Executor<'a> {
        self.trace = Some(trace);
        self
    }

    fn traced<T>(
        &self,
        level: usize,
        name: &'static str,
        batch: usize,
        f: impl FnOnce() -> T,
    ) -> T {
        match &self.trace {
            Some(tr) => tr.record(level, name, batch, (0, 0), f),
            None => f(),
        }
    }

    // ---------------- Factorization replay ----------------

    /// Replay the factorization program against `h2`, producing a
    /// [`UlvFactor`] that shares `plan` for its substitution replays. The
    /// device arena is dropped — the factor is *moved* out of it
    /// (copy-free on host-memory arenas); use
    /// [`Executor::factorize_resident`] to keep the factor device-resident
    /// for subsequent solves instead.
    ///
    /// `h2` may be any matrix structurally identical to the one the plan
    /// was recorded from ([`Plan::compatible`]).
    pub fn factorize(&self, plan: &Arc<Plan>, h2: &H2Matrix) -> UlvFactor {
        self.factorize_inner(plan, h2, Mirror::Move).0.expect("Mirror::Move builds a factor")
    }

    /// [`factorize`](Executor::factorize), additionally returning the
    /// arena with the factor still resident (outputs + bases + root — see
    /// [`FactorProgram::resident_bufs`]); the returned [`UlvFactor`] is a
    /// downloaded host mirror. The session facade holds the arena so
    /// every solve replays against device-resident factors.
    pub fn factorize_resident(
        &self,
        plan: &Arc<Plan>,
        h2: &H2Matrix,
    ) -> (UlvFactor, Box<dyn DeviceArena>) {
        let (factor, arena) = self.factorize_inner(plan, h2, Mirror::Download);
        (factor.expect("Mirror::Download builds a factor"), arena)
    }

    /// Factorize keeping the factor device-resident **without**
    /// materializing a host [`UlvFactor`] mirror — the
    /// `FactorStorage::DeviceOnly` path: factor memory exists exactly once
    /// (in the arena). Shape queries go through
    /// [`Plan::factor_meta`]; individual blocks can still be downloaded on
    /// demand straight from the returned arena.
    pub fn factorize_device_only(&self, plan: &Arc<Plan>, h2: &H2Matrix) -> Box<dyn DeviceArena> {
        self.factorize_inner(plan, h2, Mirror::Skip).1
    }

    fn factorize_inner(
        &self,
        plan: &Arc<Plan>,
        h2: &H2Matrix,
        mirror: Mirror,
    ) -> (Option<UlvFactor>, Box<dyn DeviceArena>) {
        assert!(plan.compatible(h2), "plan recorded for a different H2 structure");
        let prog = &plan.factor;
        let mut arena = self.device.new_arena(prog.buf_count);

        self.run_factor_steps(&prog.prologue, arena.as_mut(), h2);
        for lp in &prog.levels {
            self.device.stream(lp.level);
            self.traced(lp.level, "factor-level", lp.steps.len(), || {
                self.run_factor_steps(&lp.steps, arena.as_mut(), h2);
            });
        }
        // Root factorization (Algorithm 2 line 22): batch-of-one POTRF on
        // the merged root buffer, which then holds L for RootSolve.
        self.device.stream(0);
        let root = [prog.root_src];
        self.traced(0, "factor-root", 1, || {
            self.device.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &root });
            self.device.fence();
        });

        let factor = {
            let a = arena.as_mut();
            match mirror {
                // Keep the arena intact: the factor is a downloaded mirror.
                Mirror::Download => Some(self.assemble_factor(plan, h2, &mut |b| a.download(b))),
                // The arena is about to be dropped: move the factor out
                // (pointer moves, no data copies, on host-memory arenas).
                Mirror::Move => Some(self.assemble_factor(plan, h2, &mut |b| a.take(b))),
                // Device-only: the arena is the factor.
                Mirror::Skip => None,
            }
        };
        if let Some(scope) = self.scope {
            scope.add(Phase::Factor, prog.total_flops);
        }
        (factor, arena)
    }

    /// Issue one stream of factorization instructions. `Upload` and `Free`
    /// are arena transfers; everything else is a device launch with the
    /// instruction's own operand lists.
    fn run_factor_steps(&self, steps: &[Instr], arena: &mut dyn DeviceArena, h2: &H2Matrix) {
        for step in steps {
            match step {
                Instr::Upload { items } => {
                    for &(src, dst) in items {
                        arena.upload(dst, host_src(h2, src));
                    }
                }
                Instr::Free { bufs } => {
                    for &b in bufs {
                        arena.free(b);
                    }
                }
                Instr::Sparsify { level, items } => {
                    self.device.launch(arena, &Launch::Sparsify { level: *level, items });
                }
                Instr::Extract { items } => {
                    self.device.launch(arena, &Launch::Extract { items });
                }
                Instr::Potrf { level, bufs } => {
                    self.device.launch(arena, &Launch::Potrf { level: *level, bufs });
                }
                Instr::TrsmRightLt { level, items } => {
                    self.device.launch(arena, &Launch::TrsmRightLt { level: *level, items });
                }
                Instr::SchurSelf { level, items } => {
                    self.device.launch(arena, &Launch::SchurSelf { level: *level, items });
                }
                Instr::Merge { level: _, items } => {
                    self.device.launch(arena, &Launch::Merge { items });
                }
                Instr::Exchange { level: _, sends, recvs } => {
                    let comm = self
                        .comm
                        .expect("factor stream contains Exchange but no transport is attached");
                    // The send payloads must reflect every launch issued so
                    // far; comm is a synchronization point for this rank.
                    self.device.fence();
                    let msgs: Vec<ExchangeMsg> = sends
                        .iter()
                        .map(|&b| ExchangeMsg {
                            buf: b,
                            payload: CommPayload::Mat(arena.download(b)),
                        })
                        .collect();
                    let want: Vec<(usize, BufferId)> =
                        recvs.iter().map(|r| (r.from as usize, r.buf)).collect();
                    let payloads = comm.exchange(msgs, &want);
                    for (r, p) in recvs.iter().zip(payloads) {
                        match p {
                            CommPayload::Mat(m) => arena.upload(r.buf, &m),
                            CommPayload::Vector(_) => {
                                panic!("matrix exchange received a vector payload")
                            }
                        }
                    }
                }
            }
        }
    }

    /// Replay one rank's carved factorization program, leaving that rank's
    /// shard of the factor resident in the returned arena. `Exchange`
    /// steps route through the attached [`Transport`] endpoint
    /// ([`Executor::with_comm`] is mandatory for multi-rank plans). The
    /// root factor is computed redundantly on every rank (paper §5), so
    /// each arena can serve its own substitution replays.
    pub fn factorize_rank(&self, rp: &RankPlan, h2: &H2Matrix) -> Box<dyn DeviceArena> {
        let prog = &rp.factor;
        let mut arena = self.device.new_arena(prog.buf_count);
        self.run_factor_steps(&prog.prologue, arena.as_mut(), h2);
        for lp in &prog.levels {
            self.device.stream(lp.level);
            self.traced(lp.level, "factor-level", lp.steps.len(), || {
                self.run_factor_steps(&lp.steps, arena.as_mut(), h2);
            });
        }
        self.device.stream(0);
        let root = [prog.root_src];
        self.traced(0, "factor-root", 1, || {
            self.device.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &root });
            self.device.fence();
        });
        if let Some(scope) = self.scope {
            scope.add(Phase::Factor, prog.total_flops);
        }
        arena
    }

    /// Build the factor's host form from the output wiring; `fetch`
    /// decides whether buffers are downloaded (resident arena) or moved
    /// out (transient arena).
    fn assemble_factor(
        &self,
        plan: &Arc<Plan>,
        h2: &H2Matrix,
        fetch: &mut dyn FnMut(BufferId) -> Matrix,
    ) -> UlvFactor {
        let prog = &plan.factor;
        let mut levels: Vec<LevelFactor> = Vec::with_capacity(prog.outputs.len());
        for out in &prog.outputs {
            let chol_rr: Vec<Matrix> = out.chol_rr.iter().map(|&b| fetch(b)).collect();
            let lr: HashMap<(usize, usize), Matrix> =
                out.lr.iter().map(|&(k, b)| (k, fetch(b))).collect();
            let ls: HashMap<(usize, usize), Matrix> =
                out.ls.iter().map(|&(k, b)| (k, fetch(b))).collect();
            levels.push(LevelFactor {
                level: out.level,
                bases: h2.bases[out.level].clone(),
                chol_rr,
                lr,
                ls,
                near: out.near.clone(),
            });
        }
        UlvFactor {
            levels,
            root_l: fetch(prog.root_src),
            depth: plan.depth,
            leaf_ranges: h2.tree.leaves().iter().map(|n| (n.begin, n.end)).collect(),
            perm: h2.tree.perm.clone(),
            plan: plan.clone(),
        }
    }

    // ---------------- Substitution replay ----------------

    /// Build an arena with the factor resident at the plan's output
    /// wiring — the standalone-solve path (a session reuses the arena
    /// kept by [`Executor::factorize_resident`] instead).
    pub fn upload_factor(&self, factor: &UlvFactor) -> Box<dyn DeviceArena> {
        let prog = &factor.plan.factor;
        let mut arena = self.device.new_arena(prog.buf_count);
        for (li, out) in prog.outputs.iter().enumerate() {
            let lf = &factor.levels[li];
            for (i, &b) in out.chol_rr.iter().enumerate() {
                arena.upload(b, &lf.chol_rr[i]);
            }
            for &(k, b) in &out.lr {
                arena.upload(b, &lf.lr[&k]);
            }
            for &(k, b) in &out.ls {
                arena.upload(b, &lf.ls[&k]);
            }
            for (i, &b) in out.basis.iter().enumerate() {
                arena.upload(b, &lf.bases[i].u);
            }
        }
        arena.upload(prog.root_src, &factor.root_l);
        arena
    }

    /// Replay the substitution program for `mode` against a tree-ordered
    /// right-hand side, uploading the factor into a transient arena (and
    /// carving a one-shot workspace) first; returns the tree-ordered
    /// solution.
    pub fn solve(
        &self,
        plan: &Plan,
        factor: &UlvFactor,
        b: &[f64],
        mode: SubstMode,
    ) -> Vec<f64> {
        let arena = self.upload_factor(factor);
        let mut ws = VecRegion::new(self.device, 0);
        self.solve_in(plan, arena.as_ref(), &mut ws, b, mode)
    }

    /// Replay the substitution program for `mode` against a factor region
    /// that already holds the factor resident (from
    /// [`Executor::factorize_resident`],
    /// [`Executor::factorize_device_only`], or
    /// [`Executor::upload_factor`]).
    ///
    /// The factor region is taken by **shared** reference — substitution
    /// programs only read it — and all vector traffic goes to the caller's
    /// private `ws` region, so concurrent callers with distinct workspaces
    /// replay simultaneously with no lock held across launches. The
    /// workspace is emptied before returning (its live count drops back to
    /// 0 — the balance invariant the device tests assert), even when a
    /// launch panics: the region is *reset*, not dropped, so it returns to
    /// its pool at full capacity.
    pub fn solve_in(
        &self,
        plan: &Plan,
        factor: &dyn DeviceArena,
        ws: &mut VecRegion,
        b: &[f64],
        mode: SubstMode,
    ) -> Vec<f64> {
        assert_eq!(b.len(), plan.n);
        let prog = plan.solve_program(mode);
        self.solve_program_in(prog, plan.n, factor, ws, b)
    }

    /// Replay an explicit substitution program (the body of
    /// [`Executor::solve_in`], also the entry point for carved
    /// [`RankPlan`] solve streams, whose `StoreSol` items cover only the
    /// rank-owned leaf segments — the rest of the returned vector stays
    /// zero for the caller to merge).
    pub(crate) fn solve_program_in(
        &self,
        prog: &SolveProgram,
        n: usize,
        factor: &dyn DeviceArena,
        ws: &mut VecRegion,
        b: &[f64],
    ) -> Vec<f64> {
        let base = prog.vec_base;
        let mut x = vec![0.0; n];

        // Allocate and run under one unwind guard: a panic anywhere (a
        // non-SPD diagonal mid-launch, an allocation failure) must leave
        // the workspace empty and intact, never shrink its pool, and never
        // touch the shared factor region.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for (k, &len) in prog.vec_lens.iter().enumerate() {
                ws.arena().alloc_vec(BufferId(base + k as u32), len);
            }
            self.run_solve_steps(prog, factor, ws, b, &mut x)
        }));
        // Tolerant region reset: mid-launch panics leave half-moved slots.
        ws.reset(BufferId(base));
        match run {
            Ok(()) => {}
            Err(payload) => std::panic::resume_unwind(payload),
        }
        if let Some(scope) = self.scope {
            scope.add(Phase::Substitute, prog.total_flops);
        }
        x
    }

    /// Issue the substitution instruction stream (the body of
    /// [`Executor::solve_in`], separated so the caller can guard it).
    ///
    /// Like the factorization replay, the stream marks tree-level
    /// boundaries via [`Device::stream`] (from [`SolveInstr::level`]) so
    /// an overlapping device can route adjacent levels to different
    /// queues; correctness never depends on the hints (device.rs rule 3).
    fn run_solve_steps(
        &self,
        prog: &SolveProgram,
        factor: &dyn DeviceArena,
        ws: &mut VecRegion,
        b: &[f64],
        x: &mut [f64],
    ) {
        let mut cur_level = usize::MAX;
        for step in &prog.steps {
            if let Some(level) = step.level() {
                if level != cur_level {
                    cur_level = level;
                    self.device.stream(level);
                }
            }
            match step {
                SolveInstr::LoadRhs { items } => {
                    for &(s, e, v) in items {
                        ws.arena().upload_vec(v, &b[s..e]);
                    }
                }
                SolveInstr::StoreSol { items } => {
                    // No device-wide fence here: `download_vec` itself
                    // observes this workspace's completed state and
                    // re-raises its recorded failures (device.rs rule 4's
                    // arena-scoped form). A global fence would needlessly
                    // quiesce *other* solves pipelining through the same
                    // engine.
                    for &(s, e, v) in items {
                        x[s..e].copy_from_slice(&ws.arena_ref().download_vec(v));
                    }
                }
                SolveInstr::ApplyBasis { level, trans, items } => {
                    self.device.launch_solve(
                        factor,
                        ws.arena(),
                        &Launch::ApplyBasis { level: *level, trans: *trans, items },
                    );
                }
                SolveInstr::Split { items } => {
                    self.device.launch_solve(factor, ws.arena(), &Launch::Split { items });
                }
                SolveInstr::Concat { items } => {
                    self.device.launch_solve(factor, ws.arena(), &Launch::Concat { items });
                }
                SolveInstr::Copy { items } => {
                    self.device.launch_solve(factor, ws.arena(), &Launch::CopyBuf { items });
                }
                SolveInstr::TrsvFwd { level, items } => {
                    self.device.launch_solve(
                        factor,
                        ws.arena(),
                        &Launch::TrsvFwd { level: *level, items },
                    );
                }
                SolveInstr::TrsvBwd { level, items } => {
                    self.device.launch_solve(
                        factor,
                        ws.arena(),
                        &Launch::TrsvBwd { level: *level, items },
                    );
                }
                SolveInstr::GemvAcc { level, trans, items } => {
                    self.device.launch_solve(
                        factor,
                        ws.arena(),
                        &Launch::GemvAcc { level: *level, trans: *trans, alpha: -1.0, items },
                    );
                }
                SolveInstr::Add { items } => {
                    self.device.launch_solve(factor, ws.arena(), &Launch::AddVec { items });
                }
                SolveInstr::RootSolve { l, x } => {
                    self.device.launch_solve(
                        factor,
                        ws.arena(),
                        &Launch::RootSolve { l: *l, x: *x },
                    );
                }
                SolveInstr::Exchange { level: _, sends, recvs } => {
                    let comm = self
                        .comm
                        .expect("solve stream contains Exchange but no transport is attached");
                    self.device.fence();
                    let msgs: Vec<ExchangeMsg> = sends
                        .iter()
                        .map(|&v| ExchangeMsg {
                            buf: v,
                            payload: CommPayload::Vector(ws.arena_ref().download_vec(v)),
                        })
                        .collect();
                    let want: Vec<(usize, BufferId)> =
                        recvs.iter().map(|&(from, v, _)| (from as usize, v)).collect();
                    let payloads = comm.exchange(msgs, &want);
                    for (&(_, v, len), p) in recvs.iter().zip(payloads) {
                        match p {
                            CommPayload::Vector(seg) => {
                                assert_eq!(seg.len(), len as usize, "exchanged vector length");
                                ws.arena().upload_vec(v, &seg);
                            }
                            CommPayload::Mat(_) => {
                                panic!("vector exchange received a matrix payload")
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Resolve an upload source against the H² matrix (the only host reads of
/// a factorization replay).
fn host_src<'m>(h2: &'m H2Matrix, src: HostSrc) -> &'m Matrix {
    match src {
        HostSrc::Dense(key) => &h2.dense[&key],
        HostSrc::Coupling { level, key } => h2.coupling[level]
            .get(&key)
            .expect("plan coupling ref missing in H2 matrix"),
        HostSrc::Basis { level, index } => &h2.bases[level][index].u,
    }
}
