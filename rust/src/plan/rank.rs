//! SPMD carving: split one recorded [`Plan`] into `p` per-rank programs
//! ([`RankPlan`]) with explicit [`Instr::Exchange`] / [`SolveInstr::Exchange`]
//! collectives at the ownership boundaries (paper §5).
//!
//! # Ownership
//!
//! Rank `r` owns a contiguous run of leaf subtrees: box `i` at a level of
//! width `w ≥ p` belongs to rank `i·p/w`. Levels with fewer than `p` boxes
//! (the top `log2 p` levels) are *redundant*: every rank executes them on
//! replicated data, which is exactly the paper's scheme — comm volume
//! stays independent of `N` because only skeleton-sized blocks cross the
//! boundary, once, at the widest redundant level.
//!
//! # Carving
//!
//! Three passes over the already-recorded instruction streams — carving
//! never re-walks the H² tree:
//!
//! 1. **Substitution needs.** The solve program annotates every vector
//!    with its tree position ([`SolveProgram::vec_home`]); a walk of the
//!    solve steps collects, per factor-output matrix, the union of ranks
//!    that will read it during substitution (`L(r)` panels on the row
//!    owner, `L(s)` panels on the skeleton-target owner, bases on the box
//!    owner).
//! 2. **Factor executors.** Every batched item executes where its primary
//!    operand was defined: a sparsification runs on the rank holding the
//!    near block, a panel TRSM on the rank holding the panel, a merge on
//!    the owner of the parent box (all four child tiles share it while the
//!    parent level is distributed — the property that makes distributed
//!    merges comm-free). Upload-defined buffers are seeded structurally
//!    (dense/coupling blocks by column owner, bases by box owner).
//! 3. **Emission.** One forward walk re-plays the global stream into `p`
//!    filtered streams while tracking, per buffer, the set of ranks
//!    holding its *current* value. A read whose executor set is not
//!    covered inserts an `Exchange` immediately before the instruction —
//!    on **every** rank's stream at the same position (possibly with empty
//!    send/recv lists), so the k-th collective of every rank belongs to
//!    the same rendezvous. Host uploads replicate to all eventual readers
//!    for free (host memory is shared); factor outputs that substitution
//!    will read elsewhere are haloed once at the end of their level.
//!
//! The global plan is never mutated: comm instructions exist only in the
//! carved programs, and `carve(plan, 1, mode)` degenerates to the global
//! program with zero exchanges. Each carved program is self-contained —
//! [`super::verify::verify_factor`] accepts it unchanged, and
//! [`super::verify::verify_rank_set`] additionally audits the cross-rank
//! send/recv matching.

use super::{
    BufferId, ExchangeRecv, FactorProgram, HostSrc, Instr, LaunchMeta, LevelOut, LevelProgram,
    MergeItem, Plan, PlanSig, SolveInstr, SolveProgram,
};
use crate::metrics::flops::{gemm_flops, potrf_flops, trsm_flops};
use crate::ulv::SubstMode;
use std::collections::HashMap;

/// One rank's share of a carved plan: a complete, independently verifiable
/// factorization + substitution program pair whose `Exchange` steps line
/// up with every peer's (same collective count, matching send/recv pairs).
#[derive(Clone, Debug)]
pub struct RankPlan {
    /// Group size the plan was carved for.
    pub ranks: usize,
    /// This plan's rank (0-based).
    pub rank: usize,
    /// Global problem size (every rank sees the full RHS).
    pub n: usize,
    pub depth: usize,
    pub factor: FactorProgram,
    pub solve: SolveProgram,
    /// Solution index ranges this rank's `StoreSol` steps produce; their
    /// union over the group is `0..n` and they are pairwise disjoint.
    pub store_ranges: Vec<(usize, usize)>,
}

/// Bitmask over ranks (carving caps the group at 64).
type RankSet = u64;

/// Largest usable power-of-two group size: bounded by the request, by the
/// leaf width (one subtree per rank minimum), and by the `u64` rank mask.
pub fn clamp_ranks(requested: usize, depth: usize) -> usize {
    let cap = 1usize << depth.min(6);
    let want = requested.clamp(1, cap);
    let mut p = 1;
    while p * 2 <= want {
        p *= 2;
    }
    p
}

fn bits(mut mask: RankSet) -> impl Iterator<Item = usize> {
    std::iter::from_fn(move || {
        if mask == 0 {
            None
        } else {
            let r = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            Some(r)
        }
    })
}

/// Carve `plan` into per-rank SPMD programs for (up to) `ranks` ranks.
/// The returned vector's length is the clamped group size; element `r` is
/// rank `r`'s program. In debug builds the carved set is statically
/// verified (per-rank dataflow plus cross-rank comm matching) before it is
/// returned.
pub fn carve(plan: &Plan, ranks: usize, mode: SubstMode) -> Vec<RankPlan> {
    let p = clamp_ranks(ranks, plan.depth);
    let solve = plan.solve_program(mode);
    let mut cv = Carver::new(plan, solve, p);
    cv.solve_needs();
    cv.factor_defs();
    let rps = cv.emit(plan);
    #[cfg(debug_assertions)]
    if let Err(v) = super::verify::verify_rank_set(&rps, &plan.sig) {
        panic!("carved rank plans failed verification: {v:?}");
    }
    rps
}

/// One rank's in-construction factor stream.
#[derive(Default)]
struct Stream {
    steps: Vec<Instr>,
    launches: Vec<LaunchMeta>,
}

/// One rank's in-construction substitution stream.
#[derive(Default)]
struct SolveStream {
    steps: Vec<SolveInstr>,
    launches: Vec<LaunchMeta>,
    store: Vec<(usize, usize)>,
}

struct Carver<'p> {
    p: usize,
    /// `log2(p)`: levels at or below depth `k` are distributed.
    k: u32,
    all: RankSet,
    sig: &'p PlanSig,
    prog: &'p FactorProgram,
    solve: &'p SolveProgram,
    /// Executor/defining rank set per matrix buffer (structural; never
    /// widened by exchanges — both passes must compute identical sets).
    def: Vec<RankSet>,
    /// Ranks reading each matrix buffer during factorization.
    readers: Vec<RankSet>,
    /// Ranks reading each matrix buffer during substitution.
    needs: Vec<RankSet>,
    /// Ranks currently holding each matrix buffer's value (emission).
    avail: Vec<RankSet>,
    shape: Vec<(usize, usize)>,
    /// Ranks holding each vector's *current* value. Vectors are
    /// zero-allocated on every rank, so the initial state is "all"; every
    /// write narrows it to the writing executor set.
    vec_avail: Vec<RankSet>,
}

impl<'p> Carver<'p> {
    fn new(plan: &'p Plan, solve: &'p SolveProgram, p: usize) -> Carver<'p> {
        let all = if p >= 64 { u64::MAX } else { (1u64 << p) - 1 };
        let bufs = plan.factor.buf_count;
        Carver {
            p,
            k: p.trailing_zeros(),
            all,
            sig: &plan.sig,
            prog: &plan.factor,
            solve,
            def: vec![0; bufs],
            readers: vec![0; bufs],
            needs: vec![0; bufs],
            avail: vec![0; bufs],
            shape: vec![(0, 0); bufs],
            vec_avail: vec![all; solve.vec_lens.len()],
        }
    }

    /// Owner mask of box `bx` at `level`: a singleton at distributed
    /// levels (width `2^level ≥ p`), every rank in the redundant region.
    fn owner_mask(&self, bx: usize, level: usize) -> RankSet {
        if level as u32 >= self.k {
            1u64 << ((bx * self.p) >> level)
        } else {
            self.all
        }
    }

    /// Structural home of an upload-defined buffer: dense and coupling
    /// blocks live with their column owner (the rank that eliminates that
    /// column's redundant DOFs), bases with their box owner.
    fn home(&self, src: &HostSrc) -> RankSet {
        match src {
            HostSrc::Dense((_, j)) => self.owner_mask(*j, self.sig.depth),
            HostSrc::Basis { level, index } => self.owner_mask(*index, *level),
            HostSrc::Coupling { level, key } => self.owner_mask(key.1, *level),
        }
    }

    fn host_shape(&self, src: &HostSrc) -> (usize, usize) {
        match src {
            HostSrc::Dense((i, j)) => {
                let d = self.sig.depth;
                (self.sig.shapes[d][*i].0, self.sig.shapes[d][*j].0)
            }
            HostSrc::Basis { level, index } => {
                let n = self.sig.shapes[*level][*index].0;
                (n, n)
            }
            HostSrc::Coupling { level, key } => {
                (self.sig.shapes[*level][key.0].1, self.sig.shapes[*level][key.1].1)
            }
        }
    }

    /// Rank set a solve vector belongs to, from the recorder's `(level,
    /// box)` home annotation.
    fn ann(&self, v: BufferId) -> RankSet {
        let (level, bx) = self.solve.vec_home[self.vslot(v)];
        self.owner_mask(bx as usize, level as usize)
    }

    fn vslot(&self, v: BufferId) -> usize {
        debug_assert!(v.0 >= self.solve.vec_base, "B{} is not a vector buffer", v.0);
        (v.0 - self.solve.vec_base) as usize
    }

    // ------------------- Pass 1: substitution needs -------------------

    fn solve_needs(&mut self) {
        for step in &self.solve.steps {
            match step {
                SolveInstr::ApplyBasis { items, .. } => {
                    for &(u, _, dst) in items {
                        self.needs[u.0 as usize] |= self.ann(dst);
                    }
                }
                SolveInstr::TrsvFwd { items, .. } | SolveInstr::TrsvBwd { items, .. } => {
                    for &(m, v) in items {
                        self.needs[m.0 as usize] |= self.ann(v);
                    }
                }
                SolveInstr::GemvAcc { items, .. } => {
                    for &(m, _, y) in items {
                        self.needs[m.0 as usize] |= self.ann(y);
                    }
                }
                SolveInstr::RootSolve { l, .. } => {
                    self.needs[l.0 as usize] |= self.all;
                }
                _ => {}
            }
        }
    }

    // ------------------- Pass 2: factor executors -------------------

    fn factor_defs(&mut self) {
        let prog = self.prog;
        for instr in prog.prologue.iter().chain(prog.levels.iter().flat_map(|l| l.steps.iter()))
        {
            self.def_instr(instr);
        }
        // The root Cholesky runs redundantly on every rank.
        self.readers[prog.root_src.0 as usize] |= self.all;
    }

    fn def_instr(&mut self, instr: &Instr) {
        match instr {
            Instr::Upload { items } => {
                for (src, b) in items {
                    self.def[b.0 as usize] = self.home(src);
                }
            }
            Instr::Sparsify { items, .. } => {
                for it in items {
                    let ex = self.def[it.a.0 as usize];
                    self.readers[it.u.0 as usize] |= ex;
                    self.readers[it.a.0 as usize] |= ex;
                    self.readers[it.v.0 as usize] |= ex;
                    self.def[it.dst.0 as usize] = ex;
                }
            }
            Instr::Extract { items } => {
                for it in items {
                    let ex = self.def[it.src.0 as usize];
                    self.readers[it.src.0 as usize] |= ex;
                    self.def[it.dst.0 as usize] = ex;
                }
            }
            Instr::Potrf { bufs, .. } => {
                for b in bufs {
                    self.readers[b.0 as usize] |= self.def[b.0 as usize];
                }
            }
            Instr::TrsmRightLt { items, .. } => {
                for it in items {
                    let ex = self.def[it.b.0 as usize];
                    self.readers[it.l.0 as usize] |= ex;
                    self.readers[it.b.0 as usize] |= ex;
                }
            }
            Instr::SchurSelf { items, .. } => {
                for it in items {
                    let ex = self.def[it.c.0 as usize];
                    self.readers[it.a.0 as usize] |= ex;
                    self.readers[it.c.0 as usize] |= ex;
                }
            }
            Instr::Merge { level, items } => {
                for it in items {
                    let ex = self.merge_exec(*level, it);
                    for pt in &it.parts {
                        self.readers[pt.src.0 as usize] |= ex;
                    }
                    self.def[it.dst.0 as usize] = ex;
                }
            }
            Instr::Free { .. } => {}
            Instr::Exchange { .. } => unreachable!("global plans carry no comm"),
        }
    }

    /// Executor of one merge item (`level` is the child level). While the
    /// parent level is still distributed, all four child tiles share the
    /// parent owner (children of one box never straddle a rank boundary);
    /// below that the merge replicates onto every rank.
    fn merge_exec(&self, level: usize, it: &MergeItem) -> RankSet {
        if (level - 1) as u32 >= self.k {
            let ex = self.def[it.parts[0].src.0 as usize];
            debug_assert!(
                it.parts.iter().all(|pt| self.def[pt.src.0 as usize] == ex),
                "distributed merge tiles must share one owner"
            );
            ex
        } else {
            self.all
        }
    }

    // ------------------- Pass 3: emission -------------------

    fn emit(&mut self, plan: &Plan) -> Vec<RankPlan> {
        let p = self.p;
        let prog = self.prog;
        let solve = self.solve;

        let mut prologues: Vec<Vec<Instr>> = (0..p).map(|_| Vec::new()).collect();
        for instr in &prog.prologue {
            match instr {
                Instr::Upload { items } => self.emit_upload(items, &mut prologues),
                _ => unreachable!("the factorization prologue holds only uploads"),
            }
        }

        let mut levels: Vec<Vec<LevelProgram>> = (0..p).map(|_| Vec::new()).collect();
        for lp in &prog.levels {
            let mut st: Vec<Stream> = (0..p).map(|_| Stream::default()).collect();
            let mut defined: Vec<BufferId> = Vec::new();
            for instr in &lp.steps {
                self.emit_factor_instr(instr, &mut st, &mut defined);
            }
            // Halo: factor outputs of this level that substitution reads
            // on ranks that do not hold them (boundary L(r)/L(s) panels)
            // ship once, now, while every peer is at the same position.
            let halo: Vec<(BufferId, RankSet)> = defined
                .iter()
                .filter(|b| {
                    let i = b.0 as usize;
                    self.avail[i] != 0 && self.needs[i] & !self.avail[i] != 0
                })
                .map(|&b| (b, self.needs[b.0 as usize]))
                .collect();
            self.settle_mats(lp.level, &halo, &mut st);
            for (r, s) in st.into_iter().enumerate() {
                levels[r].push(LevelProgram {
                    level: lp.level,
                    steps: s.steps,
                    launches: s.launches,
                });
            }
        }
        debug_assert_eq!(
            self.avail[prog.root_src.0 as usize],
            self.all,
            "the merged root block must be replicated on every rank"
        );

        let (solve_streams, store) = self.emit_solve();

        let mut out = Vec::with_capacity(p);
        let mut levels = levels.into_iter();
        let mut prologues = prologues.into_iter();
        let mut solve_streams = solve_streams.into_iter();
        let mut store = store.into_iter();
        for r in 0..p {
            let rank_levels = levels.next().unwrap();
            let bit = 1u64 << r;
            let outputs: Vec<LevelOut> = prog
                .outputs
                .iter()
                .map(|o| LevelOut {
                    level: o.level,
                    chol_rr: o
                        .chol_rr
                        .iter()
                        .copied()
                        .filter(|b| self.avail[b.0 as usize] & bit != 0)
                        .collect(),
                    lr: o
                        .lr
                        .iter()
                        .copied()
                        .filter(|&(_, b)| self.avail[b.0 as usize] & bit != 0)
                        .collect(),
                    ls: o
                        .ls
                        .iter()
                        .copied()
                        .filter(|&(_, b)| self.avail[b.0 as usize] & bit != 0)
                        .collect(),
                    near: o.near.clone(),
                    basis: o
                        .basis
                        .iter()
                        .copied()
                        .filter(|b| self.avail[b.0 as usize] & bit != 0)
                        .collect(),
                })
                .collect();
            let total_flops: u64 = rank_levels
                .iter()
                .flat_map(|l| l.launches.iter())
                .map(|l| l.flops)
                .sum::<u64>()
                + prog.root_launch.flops;
            let factor = FactorProgram {
                buf_count: prog.buf_count,
                prologue: prologues.next().unwrap(),
                levels: rank_levels,
                outputs,
                root_src: prog.root_src,
                root_n: prog.root_n,
                root_launch: prog.root_launch,
                total_flops,
            };
            let ss = solve_streams.next().unwrap();
            let solve_flops: u64 = ss.launches.iter().map(|l| l.flops).sum();
            let rank_solve = SolveProgram {
                vec_base: solve.vec_base,
                vec_lens: solve.vec_lens.clone(),
                vec_home: solve.vec_home.clone(),
                steps: ss.steps,
                launches: ss.launches,
                total_flops: solve_flops,
            };
            out.push(RankPlan {
                ranks: p,
                rank: r,
                n: plan.n,
                depth: plan.depth,
                factor,
                solve: rank_solve,
                store_ranges: store.next().unwrap(),
            });
        }
        out
    }

    /// Emit one upload, replicated onto every rank that ever reads the
    /// buffer (host memory is shared — replication costs no comm).
    fn emit_upload(&mut self, items: &[(HostSrc, BufferId)], outs: &mut [Vec<Instr>]) {
        let mut per: Vec<Vec<(HostSrc, BufferId)>> = (0..self.p).map(|_| Vec::new()).collect();
        for &(src, b) in items {
            let i = b.0 as usize;
            let want = self.readers[i] | self.needs[i] | self.def[i];
            debug_assert_eq!(self.avail[i], 0, "SSA: B{} uploaded twice", b.0);
            self.avail[i] = want;
            self.shape[i] = self.host_shape(&src);
            for r in bits(want) {
                per[r].push((src, b));
            }
        }
        for (r, items) in per.into_iter().enumerate() {
            if !items.is_empty() {
                outs[r].push(Instr::Upload { items });
            }
        }
    }

    fn define(&mut self, b: BufferId, ex: RankSet, shape: (usize, usize)) {
        let i = b.0 as usize;
        debug_assert_eq!(self.avail[i], 0, "SSA: B{} defined twice", b.0);
        debug_assert_eq!(self.def[i], ex, "executor passes disagree on B{}", b.0);
        self.avail[i] = ex;
        self.shape[i] = shape;
    }

    /// Cover a set of matrix reads: for every `(buffer, executor)` pair
    /// whose executor set is not fully held, insert one `Exchange` on
    /// *every* rank's stream (the sender is the lowest holding rank) and
    /// widen availability. No-op when everything is already covered.
    fn settle_mats(&mut self, level: usize, reads: &[(BufferId, RankSet)], st: &mut [Stream]) {
        let mut order: Vec<u32> = Vec::new();
        let mut need: HashMap<u32, RankSet> = HashMap::new();
        for &(b, ex) in reads {
            let have = self.avail[b.0 as usize];
            assert!(have != 0, "B{} is read before any rank holds it", b.0);
            let miss = ex & !have;
            if miss != 0 {
                *need.entry(b.0).or_insert_with(|| {
                    order.push(b.0);
                    0
                }) |= miss;
            }
        }
        if order.is_empty() {
            return;
        }
        let mut sends: Vec<Vec<BufferId>> = (0..self.p).map(|_| Vec::new()).collect();
        let mut recvs: Vec<Vec<ExchangeRecv>> = (0..self.p).map(|_| Vec::new()).collect();
        for &id in &order {
            let i = id as usize;
            let miss = need[&id] & !self.avail[i];
            if miss == 0 {
                continue;
            }
            let from = self.avail[i].trailing_zeros();
            let (rows, cols) = self.shape[i];
            for r in bits(miss) {
                recvs[r].push(ExchangeRecv {
                    from,
                    buf: BufferId(id),
                    rows: rows as u32,
                    cols: cols as u32,
                });
            }
            sends[from as usize].push(BufferId(id));
            self.avail[i] |= miss;
        }
        let mut sends = sends.into_iter();
        let mut recvs = recvs.into_iter();
        for s in st.iter_mut() {
            s.steps.push(Instr::Exchange {
                level,
                sends: sends.next().unwrap(),
                recvs: recvs.next().unwrap(),
            });
        }
    }

    fn emit_factor_instr(&mut self, instr: &Instr, st: &mut [Stream], defined: &mut Vec<BufferId>) {
        let p = self.p;
        match instr {
            Instr::Upload { items } => {
                let mut per: Vec<Vec<(HostSrc, BufferId)>> =
                    (0..p).map(|_| Vec::new()).collect();
                for &(src, b) in items {
                    let i = b.0 as usize;
                    let want = self.readers[i] | self.needs[i] | self.def[i];
                    debug_assert_eq!(self.avail[i], 0, "SSA: B{} uploaded twice", b.0);
                    self.avail[i] = want;
                    self.shape[i] = self.host_shape(&src);
                    defined.push(b);
                    for r in bits(want) {
                        per[r].push((src, b));
                    }
                }
                for (r, items) in per.into_iter().enumerate() {
                    if !items.is_empty() {
                        st[r].steps.push(Instr::Upload { items });
                    }
                }
            }
            Instr::Sparsify { level, items } => {
                let exs: Vec<RankSet> =
                    items.iter().map(|it| self.def[it.a.0 as usize]).collect();
                let mut reads = Vec::with_capacity(3 * items.len());
                for (it, &ex) in items.iter().zip(&exs) {
                    reads.push((it.u, ex));
                    reads.push((it.a, ex));
                    reads.push((it.v, ex));
                }
                self.settle_mats(*level, &reads, st);
                for (rk, s) in st.iter_mut().enumerate() {
                    let bit = 1u64 << rk;
                    let mut sel = Vec::new();
                    let mut shapes = Vec::new();
                    for (it, &ex) in items.iter().zip(&exs) {
                        if ex & bit != 0 {
                            let (rr, cc) = self.shape[it.a.0 as usize];
                            shapes.push((rr, cc, super::sparsify_flops(rr, cc)));
                            sel.push(*it);
                        }
                    }
                    if sel.is_empty() {
                        continue;
                    }
                    s.launches.push(LaunchMeta::new(*level, "SPARSIFY", &shapes, |r, c| {
                        gemm_flops(r, c, r) + gemm_flops(r, c, c)
                    }));
                    s.steps.push(Instr::Sparsify { level: *level, items: sel });
                }
                for (it, &ex) in items.iter().zip(&exs) {
                    let shape = self.shape[it.a.0 as usize];
                    self.define(it.dst, ex, shape);
                    defined.push(it.dst);
                }
            }
            Instr::Extract { items } => {
                for (rk, s) in st.iter_mut().enumerate() {
                    let bit = 1u64 << rk;
                    let sel: Vec<_> = items
                        .iter()
                        .filter(|it| self.def[it.src.0 as usize] & bit != 0)
                        .copied()
                        .collect();
                    if !sel.is_empty() {
                        s.steps.push(Instr::Extract { items: sel });
                    }
                }
                for it in items {
                    let ex = self.def[it.src.0 as usize];
                    debug_assert!(
                        self.avail[it.src.0 as usize] & ex == ex,
                        "extract source B{} not resident on its executor",
                        it.src.0
                    );
                    self.define(it.dst, ex, (it.rows, it.cols));
                    defined.push(it.dst);
                }
            }
            Instr::Potrf { level, bufs } => {
                for (rk, s) in st.iter_mut().enumerate() {
                    let bit = 1u64 << rk;
                    let mut sel = Vec::new();
                    let mut shapes = Vec::new();
                    for &b in bufs {
                        if self.def[b.0 as usize] & bit != 0 {
                            let n = self.shape[b.0 as usize].0;
                            shapes.push((n, n, potrf_flops(n)));
                            sel.push(b);
                        }
                    }
                    if sel.is_empty() {
                        continue;
                    }
                    s.launches.push(LaunchMeta::new(*level, "POTRF", &shapes, |r, _| {
                        potrf_flops(r)
                    }));
                    s.steps.push(Instr::Potrf { level: *level, bufs: sel });
                }
            }
            Instr::TrsmRightLt { level, items } => {
                let reads: Vec<(BufferId, RankSet)> = items
                    .iter()
                    .map(|it| (it.l, self.def[it.b.0 as usize]))
                    .collect();
                self.settle_mats(*level, &reads, st);
                for (rk, s) in st.iter_mut().enumerate() {
                    let bit = 1u64 << rk;
                    let mut sel = Vec::new();
                    let mut shapes = Vec::new();
                    for it in items {
                        if self.def[it.b.0 as usize] & bit != 0 {
                            let (rows, cols) = self.shape[it.b.0 as usize];
                            shapes.push((rows, cols, trsm_flops(cols, rows)));
                            sel.push(*it);
                        }
                    }
                    if sel.is_empty() {
                        continue;
                    }
                    s.launches
                        .push(LaunchMeta::new(*level, "TRSM", &shapes, |r, c| trsm_flops(c, r)));
                    s.steps.push(Instr::TrsmRightLt { level: *level, items: sel });
                }
            }
            Instr::SchurSelf { level, items } => {
                let reads: Vec<(BufferId, RankSet)> = items
                    .iter()
                    .map(|it| (it.a, self.def[it.c.0 as usize]))
                    .collect();
                self.settle_mats(*level, &reads, st);
                for (rk, s) in st.iter_mut().enumerate() {
                    let bit = 1u64 << rk;
                    let mut sel = Vec::new();
                    let mut shapes = Vec::new();
                    for it in items {
                        if self.def[it.c.0 as usize] & bit != 0 {
                            let (rows, cols) = self.shape[it.a.0 as usize];
                            shapes.push((rows, cols, gemm_flops(rows, rows, cols)));
                            sel.push(*it);
                        }
                    }
                    if sel.is_empty() {
                        continue;
                    }
                    s.launches.push(LaunchMeta::new(*level, "SYRK", &shapes, |r, c| {
                        gemm_flops(r, r, c)
                    }));
                    s.steps.push(Instr::SchurSelf { level: *level, items: sel });
                }
            }
            Instr::Merge { level, items } => {
                let exs: Vec<RankSet> =
                    items.iter().map(|it| self.merge_exec(*level, it)).collect();
                let mut reads = Vec::new();
                for (it, &ex) in items.iter().zip(&exs) {
                    for pt in &it.parts {
                        reads.push((pt.src, ex));
                    }
                }
                self.settle_mats(*level, &reads, st);
                for (rk, s) in st.iter_mut().enumerate() {
                    let bit = 1u64 << rk;
                    let sel: Vec<MergeItem> = items
                        .iter()
                        .zip(&exs)
                        .filter(|(_, &ex)| ex & bit != 0)
                        .map(|(it, _)| it.clone())
                        .collect();
                    if !sel.is_empty() {
                        s.steps.push(Instr::Merge { level: *level, items: sel });
                    }
                }
                for (it, &ex) in items.iter().zip(&exs) {
                    self.define(it.dst, ex, (it.rows, it.cols));
                    defined.push(it.dst);
                }
            }
            Instr::Free { bufs } => {
                for (rk, s) in st.iter_mut().enumerate() {
                    let bit = 1u64 << rk;
                    let sel: Vec<BufferId> = bufs
                        .iter()
                        .copied()
                        .filter(|b| self.avail[b.0 as usize] & bit != 0)
                        .collect();
                    if !sel.is_empty() {
                        s.steps.push(Instr::Free { bufs: sel });
                    }
                }
                for b in bufs {
                    self.avail[b.0 as usize] = 0;
                }
            }
            Instr::Exchange { .. } => unreachable!("global plans carry no comm"),
        }
    }

    // ------------------- Pass 3b: substitution emission -------------------

    /// Assert a substitution matrix operand is resident wherever the step
    /// executes (the factor carving's upload replication + halos must have
    /// covered it — a failure here is a carving bug, not a user error).
    fn mat_check(&self, m: BufferId, ex: RankSet) {
        assert!(
            self.avail[m.0 as usize] & ex == ex,
            "substitution reads matrix B{} on a rank that does not hold it",
            m.0
        );
    }

    /// Record an in-place vector write: the executor must hold the current
    /// value, and afterwards only the executor does.
    fn vrw(&mut self, v: BufferId, ex: RankSet) {
        let s = self.vslot(v);
        assert!(
            self.vec_avail[s] & ex == ex,
            "vector B{} updated in place on a rank that does not hold it",
            v.0
        );
        self.vec_avail[s] = ex;
    }

    fn vdefine(&mut self, v: BufferId, ex: RankSet) {
        let s = self.vslot(v);
        self.vec_avail[s] = ex;
    }

    /// Vector analog of [`Carver::settle_mats`]. Zero-length vectors are
    /// marked available without comm (every rank's zero allocation already
    /// equals the value).
    fn settle_vecs(&mut self, reads: &[(BufferId, RankSet)], st: &mut [SolveStream]) {
        let mut order: Vec<u32> = Vec::new();
        let mut need: HashMap<u32, RankSet> = HashMap::new();
        for &(v, ex) in reads {
            let s = self.vslot(v);
            let miss = ex & !self.vec_avail[s];
            if miss != 0 {
                if self.solve.vec_lens[s] == 0 {
                    self.vec_avail[s] |= miss;
                    continue;
                }
                *need.entry(v.0).or_insert_with(|| {
                    order.push(v.0);
                    0
                }) |= miss;
            }
        }
        if order.is_empty() {
            return;
        }
        let level = {
            let (l, _) = self.solve.vec_home[(order[0] - self.solve.vec_base) as usize];
            l as usize
        };
        let mut sends: Vec<Vec<BufferId>> = (0..self.p).map(|_| Vec::new()).collect();
        let mut recvs: Vec<Vec<(u32, BufferId, u32)>> =
            (0..self.p).map(|_| Vec::new()).collect();
        for &id in &order {
            let s = (id - self.solve.vec_base) as usize;
            let miss = need[&id] & !self.vec_avail[s];
            if miss == 0 {
                continue;
            }
            let from = self.vec_avail[s].trailing_zeros();
            let len = self.solve.vec_lens[s] as u32;
            for r in bits(miss) {
                recvs[r].push((from, BufferId(id), len));
            }
            sends[from as usize].push(BufferId(id));
            self.vec_avail[s] |= miss;
        }
        let mut sends = sends.into_iter();
        let mut recvs = recvs.into_iter();
        for s in st.iter_mut() {
            s.steps.push(SolveInstr::Exchange {
                level,
                sends: sends.next().unwrap(),
                recvs: recvs.next().unwrap(),
            });
        }
    }

    #[allow(clippy::type_complexity)]
    fn emit_solve(&mut self) -> (Vec<SolveStream>, Vec<Vec<(usize, usize)>>) {
        let p = self.p;
        let solve = self.solve;
        let mut st: Vec<SolveStream> = (0..p).map(|_| SolveStream::default()).collect();
        for step in &solve.steps {
            match step {
                SolveInstr::LoadRhs { items } => {
                    let mut per: Vec<Vec<(usize, usize, BufferId)>> =
                        (0..p).map(|_| Vec::new()).collect();
                    for &(b0, b1, v) in items {
                        let ex = self.ann(v);
                        self.vdefine(v, ex);
                        for r in bits(ex) {
                            per[r].push((b0, b1, v));
                        }
                    }
                    for (r, items) in per.into_iter().enumerate() {
                        if !items.is_empty() {
                            st[r].steps.push(SolveInstr::LoadRhs { items });
                        }
                    }
                }
                SolveInstr::ApplyBasis { level, trans, items } => {
                    let mut reads = Vec::with_capacity(items.len());
                    for &(u, src, dst) in items {
                        let ex = self.ann(dst);
                        self.mat_check(u, ex);
                        reads.push((src, ex));
                    }
                    self.settle_vecs(&reads, &mut st);
                    for (rk, s) in st.iter_mut().enumerate() {
                        let bit = 1u64 << rk;
                        let mut sel = Vec::new();
                        let mut shapes = Vec::new();
                        for &(u, src, dst) in items {
                            if self.ann(dst) & bit != 0 {
                                let n = solve.vec_lens[self.vslot(dst)];
                                shapes.push((n, n, 2 * (n * n) as u64));
                                sel.push((u, src, dst));
                            }
                        }
                        if sel.is_empty() {
                            continue;
                        }
                        s.launches.push(LaunchMeta::new(*level, "BASIS", &shapes, |r, c| {
                            2 * (r * c) as u64
                        }));
                        s.steps.push(SolveInstr::ApplyBasis {
                            level: *level,
                            trans: *trans,
                            items: sel,
                        });
                    }
                    for &(_, _, dst) in items {
                        let ex = self.ann(dst);
                        self.vdefine(dst, ex);
                    }
                }
                SolveInstr::Split { items } => {
                    let mut reads = Vec::with_capacity(items.len());
                    for &(src, _, lo, hi) in items {
                        reads.push((src, self.ann(lo) | self.ann(hi)));
                    }
                    self.settle_vecs(&reads, &mut st);
                    for (rk, s) in st.iter_mut().enumerate() {
                        let bit = 1u64 << rk;
                        let sel: Vec<_> = items
                            .iter()
                            .copied()
                            .filter(|&(_, _, lo, hi)| (self.ann(lo) | self.ann(hi)) & bit != 0)
                            .collect();
                        if !sel.is_empty() {
                            s.steps.push(SolveInstr::Split { items: sel });
                        }
                    }
                    for &(_, _, lo, hi) in items {
                        let ex = self.ann(lo) | self.ann(hi);
                        self.vdefine(lo, ex);
                        self.vdefine(hi, ex);
                    }
                }
                SolveInstr::Concat { items } => {
                    let mut reads = Vec::with_capacity(2 * items.len());
                    for &(dst, a, b) in items {
                        let ex = self.ann(dst);
                        reads.push((a, ex));
                        reads.push((b, ex));
                    }
                    self.settle_vecs(&reads, &mut st);
                    for (rk, s) in st.iter_mut().enumerate() {
                        let bit = 1u64 << rk;
                        let sel: Vec<_> = items
                            .iter()
                            .copied()
                            .filter(|&(dst, _, _)| self.ann(dst) & bit != 0)
                            .collect();
                        if !sel.is_empty() {
                            s.steps.push(SolveInstr::Concat { items: sel });
                        }
                    }
                    for &(dst, _, _) in items {
                        let ex = self.ann(dst);
                        self.vdefine(dst, ex);
                    }
                }
                SolveInstr::Copy { items } => {
                    let mut reads = Vec::with_capacity(items.len());
                    for &(dst, src) in items {
                        reads.push((src, self.ann(dst)));
                    }
                    self.settle_vecs(&reads, &mut st);
                    for (rk, s) in st.iter_mut().enumerate() {
                        let bit = 1u64 << rk;
                        let sel: Vec<_> = items
                            .iter()
                            .copied()
                            .filter(|&(dst, _)| self.ann(dst) & bit != 0)
                            .collect();
                        if !sel.is_empty() {
                            s.steps.push(SolveInstr::Copy { items: sel });
                        }
                    }
                    for &(dst, _) in items {
                        let ex = self.ann(dst);
                        self.vdefine(dst, ex);
                    }
                }
                SolveInstr::TrsvFwd { level, items } | SolveInstr::TrsvBwd { level, items } => {
                    let bwd = matches!(step, SolveInstr::TrsvBwd { .. });
                    for &(m, v) in items {
                        self.mat_check(m, self.ann(v));
                    }
                    for (rk, s) in st.iter_mut().enumerate() {
                        let bit = 1u64 << rk;
                        let mut sel = Vec::new();
                        let mut shapes = Vec::new();
                        for &(m, v) in items {
                            if self.ann(v) & bit != 0 {
                                let n = solve.vec_lens[self.vslot(v)];
                                shapes.push((n, n, (n * n) as u64));
                                sel.push((m, v));
                            }
                        }
                        if sel.is_empty() {
                            continue;
                        }
                        let kernel = if bwd { "TRSVT" } else { "TRSV" };
                        s.launches.push(LaunchMeta::new(*level, kernel, &shapes, |r, _| {
                            (r * r) as u64
                        }));
                        s.steps.push(if bwd {
                            SolveInstr::TrsvBwd { level: *level, items: sel }
                        } else {
                            SolveInstr::TrsvFwd { level: *level, items: sel }
                        });
                    }
                    for &(_, v) in items {
                        let ex = self.ann(v);
                        self.vrw(v, ex);
                    }
                }
                SolveInstr::GemvAcc { level, trans, items } => {
                    let mut reads = Vec::with_capacity(items.len());
                    for &(m, x, y) in items {
                        let ex = self.ann(y);
                        self.mat_check(m, ex);
                        reads.push((x, ex));
                    }
                    self.settle_vecs(&reads, &mut st);
                    for (rk, s) in st.iter_mut().enumerate() {
                        let bit = 1u64 << rk;
                        let mut sel = Vec::new();
                        let mut shapes = Vec::new();
                        for &(m, x, y) in items {
                            if self.ann(y) & bit != 0 {
                                let (rows, cols) = self.shape[m.0 as usize];
                                shapes.push((rows, cols, 2 * (rows * cols) as u64));
                                sel.push((m, x, y));
                            }
                        }
                        if sel.is_empty() {
                            continue;
                        }
                        s.launches.push(LaunchMeta::new(*level, "GEMV", &shapes, |r, c| {
                            2 * (r * c) as u64
                        }));
                        s.steps.push(SolveInstr::GemvAcc {
                            level: *level,
                            trans: *trans,
                            items: sel,
                        });
                    }
                    for &(_, _, y) in items {
                        let ex = self.ann(y);
                        self.vrw(y, ex);
                    }
                }
                SolveInstr::Add { items } => {
                    let mut reads = Vec::with_capacity(2 * items.len());
                    for &(dst, a, b) in items {
                        let ex = self.ann(dst);
                        reads.push((a, ex));
                        reads.push((b, ex));
                    }
                    self.settle_vecs(&reads, &mut st);
                    for (rk, s) in st.iter_mut().enumerate() {
                        let bit = 1u64 << rk;
                        let sel: Vec<_> = items
                            .iter()
                            .copied()
                            .filter(|&(dst, _, _)| self.ann(dst) & bit != 0)
                            .collect();
                        if !sel.is_empty() {
                            s.steps.push(SolveInstr::Add { items: sel });
                        }
                    }
                    for &(dst, _, _) in items {
                        let ex = self.ann(dst);
                        self.vdefine(dst, ex);
                    }
                }
                SolveInstr::RootSolve { l, x } => {
                    self.mat_check(*l, self.all);
                    self.vrw(*x, self.all);
                    let root_n = self.prog.root_n;
                    for s in st.iter_mut() {
                        s.launches.push(LaunchMeta::new(
                            0,
                            "POTRS",
                            &[(root_n, root_n, 2 * (root_n * root_n) as u64)],
                            |r, _| 2 * (r * r) as u64,
                        ));
                        s.steps.push(SolveInstr::RootSolve { l: *l, x: *x });
                    }
                }
                SolveInstr::StoreSol { items } => {
                    let mut reads = Vec::with_capacity(items.len());
                    for &(_, _, v) in items {
                        reads.push((v, self.ann(v)));
                    }
                    self.settle_vecs(&reads, &mut st);
                    let mut per: Vec<Vec<(usize, usize, BufferId)>> =
                        (0..p).map(|_| Vec::new()).collect();
                    for &(b0, b1, v) in items {
                        for r in bits(self.ann(v)) {
                            per[r].push((b0, b1, v));
                            st[r].store.push((b0, b1));
                        }
                    }
                    for (r, items) in per.into_iter().enumerate() {
                        if !items.is_empty() {
                            st[r].steps.push(SolveInstr::StoreSol { items });
                        }
                    }
                }
                SolveInstr::Exchange { .. } => unreachable!("global plans carry no comm"),
            }
        }
        let store: Vec<Vec<(usize, usize)>> =
            st.iter_mut().map(|s| std::mem::take(&mut s.store)).collect();
        (st, store)
    }
}

/// Render the carved set's communication schedule: one line per
/// collective — factor phase first, then substitution — with the tree
/// level it belongs to, the total buffers posted, and the bytes delivered
/// across the group. Comm instructions are ordinary plan IR, so the whole
/// schedule is visible here before anything executes (the
/// `plan-dump --ranks` view).
pub fn render_comm(rps: &[RankPlan]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "carved comm schedule: P={}", rps.len());
    let factor: Vec<Vec<&Instr>> = rps
        .iter()
        .map(|rp| {
            rp.factor
                .prologue
                .iter()
                .chain(rp.factor.levels.iter().flat_map(|l| l.steps.iter()))
                .filter(|i| matches!(i, Instr::Exchange { .. }))
                .collect()
        })
        .collect();
    for k in 0..factor[0].len() {
        let Instr::Exchange { level, .. } = factor[0][k] else { unreachable!() };
        let mut sends = 0usize;
        let mut bytes = 0u64;
        for stream in &factor {
            let Instr::Exchange { sends: s, recvs, .. } = stream[k] else { unreachable!() };
            sends += s.len();
            bytes += recvs.iter().map(|r| r.rows as u64 * r.cols as u64 * 8).sum::<u64>();
        }
        let _ = writeln!(
            out,
            "  factor exchange #{k} (level {level}): {sends} buffer(s) posted, {bytes} B delivered"
        );
    }
    let solve: Vec<Vec<&SolveInstr>> = rps
        .iter()
        .map(|rp| {
            rp.solve
                .steps
                .iter()
                .filter(|i| matches!(i, SolveInstr::Exchange { .. }))
                .collect()
        })
        .collect();
    for k in 0..solve[0].len() {
        let SolveInstr::Exchange { level, .. } = solve[0][k] else { unreachable!() };
        let mut sends = 0usize;
        let mut bytes = 0u64;
        for stream in &solve {
            let SolveInstr::Exchange { sends: s, recvs, .. } = stream[k] else { unreachable!() };
            sends += s.len();
            bytes += recvs.iter().map(|&(_, _, len)| len as u64 * 8).sum::<u64>();
        }
        let _ = writeln!(
            out,
            "  solve exchange #{k} (level {level}): {sends} buffer(s) posted, {bytes} B delivered"
        );
    }
    if factor[0].is_empty() && solve[0].is_empty() {
        let _ = writeln!(out, "  (no cross-rank communication — single rank)");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_is_a_power_of_two_within_bounds() {
        assert_eq!(clamp_ranks(1, 4), 1);
        assert_eq!(clamp_ranks(3, 4), 2);
        assert_eq!(clamp_ranks(4, 4), 4);
        assert_eq!(clamp_ranks(7, 2), 4); // leaf width caps at 2^2
        assert_eq!(clamp_ranks(1000, 10), 64); // rank-mask cap
        assert_eq!(clamp_ranks(0, 3), 1);
    }

    /// Children of one box never straddle a rank boundary while the parent
    /// level is distributed — the property that makes distributed-level
    /// merges and segment concats comm-free.
    #[test]
    fn children_share_the_parent_owner_at_distributed_levels() {
        for k in 0..4u32 {
            let p = 1usize << k;
            for level in (k as usize + 1)..8 {
                let parent_level = level - 1;
                let owner = |bx: usize, l: usize| (bx * p) >> l;
                for pj in 0..(1usize << parent_level) {
                    let po = owner(pj, parent_level);
                    assert_eq!(owner(2 * pj, level), po);
                    assert_eq!(owner(2 * pj + 1, level), po);
                    assert!(po < p);
                }
            }
        }
    }
}
