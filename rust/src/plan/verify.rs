//! Static plan verification: a pure, numerics-free analysis pass over a
//! recorded [`Plan`].
//!
//! The paper's structural claim — the H²-ULV schedule is *static* (every
//! buffer, launch, and dependency is fixed by the tree before numerics
//! run) — means plan legality is decidable at record time, once per
//! structure, instead of per execution. This module is that decision
//! procedure: [`verify`] walks the factorization and substitution
//! instruction streams with an abstract arena (states and shapes, no
//! values) and either returns a [`PlanReport`] or the first
//! [`PlanViolation`] with the offending instruction index.
//!
//! # Violation classes and the paper invariants they guard
//!
//! | [`ViolationKind`] | Invariant |
//! |-------------------|-----------|
//! | [`UseBeforeDef`](ViolationKind::UseBeforeDef), [`UseAfterFree`](ViolationKind::UseAfterFree), [`FreeBeforeDef`](ViolationKind::FreeBeforeDef), [`DoubleFree`](ViolationKind::DoubleFree) | Algorithm 2/4 level ordering: sparsify → factor → merge consumes each block exactly once, finest level first |
//! | [`Redefinition`](ViolationKind::Redefinition) | single-assignment IR: every buffer is produced by exactly one instruction, so replay is order-deterministic |
//! | [`DuplicateWrite`](ViolationKind::DuplicateWrite), [`ReadWriteAlias`](ViolationKind::ReadWriteAlias) | §3.7 level independence: batch items of one launch execute concurrently, so intra-launch aliasing is a data race |
//! | [`FactorRegionWrite`](ViolationKind::FactorRegionWrite) | Algorithm 3/§3.7 substitution reads the factor read-only — the property that makes concurrent solve sessions sound |
//! | [`Leak`](ViolationKind::Leak), [`MissingResident`](ViolationKind::MissingResident) | arena balance: after replay exactly the factor outputs, bases, and root stay resident ([`FactorProgram::resident_bufs`]) |
//! | [`ShapeMismatch`](ViolationKind::ShapeMismatch) | eq 21 / Figure 2 block conformality: `U_iᵀ A_ij U_j`, panel TRSMs, and merges must agree on `(ndof, rank)` per box |
//! | [`UnsetOperand`](ViolationKind::UnsetOperand), [`OutOfRange`](ViolationKind::OutOfRange) | recorder wiring: no `BufferId(u32::MAX)` placeholder or out-of-arena id survives recording |
//!
//! # Liveness → exact peak prediction
//!
//! The walk folds per-instruction live-buffer byte totals into a predicted
//! peak footprint. On host-synchronous backends this is **exact** (the
//! arena's byte count only dips *within* a launch — kernels move operands
//! out and back — and uploads grow it monotonically inside an
//! instruction), so `BuildStats::predicted_peak_bytes` equals the runtime
//! [`crate::batch::device::DeviceArena::peak_bytes`] bit-for-bit.
//! Overlapping executors ([`crate::batch::device::AsyncDevice`]) may
//! transiently exceed the prediction when a cross-stream `Free` retires
//! after a later `Upload`.
//!
//! # Static hazard graph
//!
//! [`hazard_graph`] enumerates the exact operation sequence an
//! [`crate::batch::device::AsyncDevice`] executor issues (per-item
//! uploads, per-buffer frees, one op per launch) and derives last-toucher
//! dependency edges per [`BufferId`] from the same
//! `device::launch_operands` classifier the runtime tracker uses — one
//! source of operand roles for both. The differential audit test replays a
//! factorization with the runtime hazard log enabled and asserts the two
//! edge sets are identical, op for op.

use super::{
    ExchangeRecv, FactorProgram, HostSrc, Instr, Plan, PlanSig, RankPlan, SolveInstr, SolveProgram,
};
use crate::batch::device::{launch_operands, Launch, LaunchOperands};
use crate::plan::BufferId;
use std::collections::HashMap;
use std::fmt;

// ---------------------------------------------------------------------
// Shared launch-legality primitives (used statically here, dynamically by
// `batch::device::validate::ValidatingDevice`).
// ---------------------------------------------------------------------

/// Is `id` the recorder's "unset" placeholder (`BufferId(u32::MAX)`)?
pub(crate) fn is_unset(id: BufferId) -> bool {
    id.0 == u32::MAX
}

/// An intra-launch write hazard (see [`write_alias_hazard`]).
pub(crate) enum LaunchHazard {
    /// Two batch items write the same buffer.
    DuplicateWrite(BufferId),
    /// One batch item reads a buffer another item writes.
    ReadWriteAlias(BufferId),
}

/// Decide whether one launch's operand lists contain an intra-launch write
/// hazard: batch items execute concurrently on real backends, so no two
/// items may write the same buffer and no item may write a buffer another
/// item reads (in-place updates are the defined exception for their *own*
/// operand). Returns the first hazard in the deterministic order the
/// runtime auditor reports (duplicate writes first, then read/write
/// aliases in read order).
pub(crate) fn write_alias_hazard(
    reads: &[BufferId],
    rw: &[BufferId],
    writes: &[BufferId],
) -> Option<LaunchHazard> {
    let mut all_writes: Vec<u32> = rw.iter().chain(writes).map(|b| b.0).collect();
    all_writes.sort_unstable();
    for pair in all_writes.windows(2) {
        if pair[0] == pair[1] {
            return Some(LaunchHazard::DuplicateWrite(BufferId(pair[0])));
        }
    }
    for r in reads {
        if all_writes.binary_search(&r.0).is_ok() {
            return Some(LaunchHazard::ReadWriteAlias(*r));
        }
    }
    None
}

/// Does a substitution launch write any matrix buffer? The factor region
/// is read-only during solves.
pub(crate) fn solve_writes_matrices(ops: &LaunchOperands) -> bool {
    !ops.mat_rw.is_empty() || !ops.mat_writes.is_empty()
}

// ---------------------------------------------------------------------
// Violations and reports.
// ---------------------------------------------------------------------

/// Which instruction stream a violation was found in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProgramKind {
    Factor,
    SolveParallel,
    SolveNaive,
}

impl fmt::Display for ProgramKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ProgramKind::Factor => "factorization",
            ProgramKind::SolveParallel => "parallel substitution",
            ProgramKind::SolveNaive => "naive substitution",
        })
    }
}

/// The class of a [`PlanViolation`] (see the module docs for the paper
/// invariant each class guards).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ViolationKind {
    /// An operand is the recorder's unset placeholder `BufferId(u32::MAX)`.
    UnsetOperand,
    /// An operand id lies outside the program's arena range.
    OutOfRange,
    /// A buffer is read before any instruction defines it.
    UseBeforeDef,
    /// A buffer is read after a `Free` released it.
    UseAfterFree,
    /// A buffer is written by more than one instruction.
    Redefinition,
    /// A `Free` targets a buffer that was never defined.
    FreeBeforeDef,
    /// A `Free` targets an already-freed buffer.
    DoubleFree,
    /// A buffer is still live at program end without being a declared
    /// resident output.
    Leak,
    /// A declared resident output is not live at program end.
    MissingResident,
    /// Two batch items of one launch write the same buffer.
    DuplicateWrite,
    /// One batch item reads a buffer another item of the same launch
    /// writes.
    ReadWriteAlias,
    /// A substitution instruction writes into the read-only factor region.
    FactorRegionWrite,
    /// Operand shapes/lengths do not conform.
    ShapeMismatch,
    /// A cross-rank exchange is unbalanced: a posted send no peer
    /// receives, a receive no peer sends, or collective counts that differ
    /// across the rank streams.
    UnmatchedComm,
}

impl fmt::Display for ViolationKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ViolationKind::UnsetOperand => "unset operand",
            ViolationKind::OutOfRange => "operand out of range",
            ViolationKind::UseBeforeDef => "use before definition",
            ViolationKind::UseAfterFree => "use after free",
            ViolationKind::Redefinition => "buffer redefinition",
            ViolationKind::FreeBeforeDef => "free before definition",
            ViolationKind::DoubleFree => "double free",
            ViolationKind::Leak => "buffer leak at program end",
            ViolationKind::MissingResident => "missing resident output",
            ViolationKind::DuplicateWrite => "duplicate intra-launch write",
            ViolationKind::ReadWriteAlias => "intra-launch read/write alias",
            ViolationKind::FactorRegionWrite => "write into read-only factor region",
            ViolationKind::ShapeMismatch => "shape mismatch",
            ViolationKind::UnmatchedComm => "unmatched cross-rank communication",
        })
    }
}

/// One verification failure, pinned to the offending instruction.
#[derive(Clone, Debug)]
pub struct PlanViolation {
    /// Which program the violation is in.
    pub program: ProgramKind,
    /// Flattened instruction index within that program (prologue first for
    /// the factorization; the end-of-program residency audit reports one
    /// past the last instruction).
    pub index: usize,
    /// Opcode of the offending instruction (`"UPLOAD"`, `"FREE"`,
    /// `"LOADRHS"`, `"STORESOL"`, `"END"`, or a launch opcode).
    pub opcode: &'static str,
    /// The buffer involved, when one is identifiable.
    pub buffer: Option<BufferId>,
    pub kind: ViolationKind,
    /// Human-readable specifics.
    pub detail: String,
}

impl fmt::Display for PlanViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} program, instruction {}: [{}] {} — {}",
            self.program, self.index, self.opcode, self.kind, self.detail
        )
    }
}

impl std::error::Error for PlanViolation {}

/// Static analysis of one substitution program.
#[derive(Clone, Debug)]
pub struct SolveProgramReport {
    /// Instruction count.
    pub instrs: usize,
    /// Batched launch count (from the recorded metadata).
    pub launches: usize,
    /// Workspace bytes a solve replay allocates (8 bytes per f64 entry of
    /// every vector buffer).
    pub workspace_bytes: usize,
}

/// One node of the static hazard graph: an operation the async executor
/// would enqueue (an upload, a free, or a batched launch).
#[derive(Clone, Debug)]
pub struct HazardOp {
    /// Issue-order sequence number.
    pub seq: usize,
    pub opcode: &'static str,
    /// Stream the op is enqueued on (`level % streams`).
    pub stream: usize,
    /// Tree level (`usize::MAX` for the prologue).
    pub level: usize,
    /// Touched buffers, sorted and deduplicated — the async engine's
    /// operand set.
    pub operands: Vec<u32>,
    /// Sequence numbers of the ops this one must wait for (last toucher
    /// per operand), sorted and deduplicated.
    pub deps: Vec<usize>,
}

/// Per-level aggregation of the hazard graph.
#[derive(Clone, Copy, Debug)]
pub struct LevelHazard {
    /// Tree level (`usize::MAX` for the prologue, rendered as "pre").
    pub level: usize,
    /// Operations at this level.
    pub ops: usize,
    /// Longest chain of intra-level dependencies (in ops).
    pub critical_path: usize,
    /// Available parallelism: `ops / critical_path`.
    pub parallelism: f64,
}

/// The static RAW/WAW dependency graph of a factorization replay.
#[derive(Clone, Debug)]
pub struct HazardGraph {
    /// Stream count the graph was built for.
    pub streams: usize,
    /// Operations in issue order.
    pub ops: Vec<HazardOp>,
    /// Per-level aggregation, in first-occurrence order.
    pub levels: Vec<LevelHazard>,
    /// Longest dependency chain across the whole program (in ops).
    pub critical_path: usize,
    /// Total dependency edges.
    pub edges: usize,
}

/// The verifier's positive result: everything the static analysis knows
/// about a plan.
#[derive(Clone, Debug)]
pub struct PlanReport {
    pub n: usize,
    pub depth: usize,
    /// Factorization instruction count (including the root Cholesky).
    pub factor_instrs: usize,
    /// Exact predicted arena peak (see the module docs).
    pub predicted_peak_bytes: usize,
    /// Bytes resident after the factorization replay (factor outputs,
    /// bases, root).
    pub resident_bytes: usize,
    /// Resident buffer count.
    pub resident_buffers: usize,
    /// Static hazard graph (built for the async executor's default stream
    /// count).
    pub hazard: HazardGraph,
    pub solve_parallel: SolveProgramReport,
    /// `Some` only if the naive program was already materialized
    /// ([`Plan::solve_program`] records it lazily).
    pub solve_naive: Option<SolveProgramReport>,
}

impl PlanReport {
    /// Human-readable report (the CLI `plan-lint` body).
    pub fn render(&self) -> String {
        let mut out = format!(
            "plan ok: N={}, depth={}, factor instrs={}, predicted peak {} B, \
             resident {} B in {} buffers\n",
            self.n,
            self.depth,
            self.factor_instrs,
            self.predicted_peak_bytes,
            self.resident_bytes,
            self.resident_buffers,
        );
        out.push_str(&format!(
            "hazard graph ({} streams): {} ops, {} edges, critical path {} \
             (available parallelism {:.1})\n",
            self.hazard.streams,
            self.hazard.ops.len(),
            self.hazard.edges,
            self.hazard.critical_path,
            if self.hazard.critical_path > 0 {
                self.hazard.ops.len() as f64 / self.hazard.critical_path as f64
            } else {
                0.0
            },
        ));
        out.push_str("  level   ops   crit   parallelism\n");
        for lh in &self.hazard.levels {
            let name = if lh.level == usize::MAX {
                "pre".to_string()
            } else {
                format!("L{}", lh.level)
            };
            out.push_str(&format!(
                "  {:<5} {:>5} {:>6} {:>12.1}\n",
                name, lh.ops, lh.critical_path, lh.parallelism
            ));
        }
        let solve = |name: &str, r: &SolveProgramReport| {
            format!(
                "{name}: {} instrs, {} launches, workspace {} B\n",
                r.instrs, r.launches, r.workspace_bytes
            )
        };
        out.push_str(&solve("parallel substitution", &self.solve_parallel));
        if let Some(naive) = &self.solve_naive {
            out.push_str(&solve("naive substitution", naive));
        }
        out
    }
}

// ---------------------------------------------------------------------
// Factorization walk.
// ---------------------------------------------------------------------

/// Abstract state of one arena slot during the walk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BufState {
    Never,
    Live,
    Freed,
}

/// Result of a passing factorization walk: final buffer states and shapes
/// (the substitution walk resolves its matrix operands against these) plus
/// the liveness-derived footprint numbers.
pub(crate) struct FactorAnalysis {
    pub peak_bytes: usize,
    pub resident_bytes: usize,
    pub resident_buffers: usize,
    state: Vec<BufState>,
    shape: Vec<(usize, usize)>,
    /// Instruction count including the root Cholesky.
    pub instrs: usize,
}

/// The walking abstract arena.
struct Walk<'p> {
    program: ProgramKind,
    count: usize,
    state: Vec<BufState>,
    shape: Vec<(usize, usize)>,
    live_bytes: usize,
    peak_bytes: usize,
    index: usize,
    sig: &'p PlanSig,
}

impl<'p> Walk<'p> {
    fn new(count: usize, sig: &'p PlanSig) -> Walk<'p> {
        Walk {
            program: ProgramKind::Factor,
            count,
            state: vec![BufState::Never; count],
            shape: vec![(0, 0); count],
            live_bytes: 0,
            peak_bytes: 0,
            index: 0,
            sig,
        }
    }

    fn violation(
        &self,
        opcode: &'static str,
        kind: ViolationKind,
        buffer: Option<BufferId>,
        detail: String,
    ) -> PlanViolation {
        PlanViolation { program: self.program, index: self.index, opcode, kind, buffer, detail }
    }

    /// Operand id sanity: not the unset placeholder, inside the arena.
    fn check_id(&self, opcode: &'static str, id: BufferId, role: &str) -> Result<(), PlanViolation> {
        if is_unset(id) {
            return Err(self.violation(
                opcode,
                ViolationKind::UnsetOperand,
                Some(id),
                format!("{role} operand is the unset placeholder B{}", id.0),
            ));
        }
        if id.0 as usize >= self.count {
            return Err(self.violation(
                opcode,
                ViolationKind::OutOfRange,
                Some(id),
                format!("{role} operand B{} is outside the arena (0..{})", id.0, self.count),
            ));
        }
        Ok(())
    }

    /// A read (or in-place) operand must be live.
    fn check_read(
        &self,
        opcode: &'static str,
        id: BufferId,
        role: &str,
    ) -> Result<(usize, usize), PlanViolation> {
        self.check_id(opcode, id, role)?;
        match self.state[id.0 as usize] {
            BufState::Live => Ok(self.shape[id.0 as usize]),
            BufState::Never => Err(self.violation(
                opcode,
                ViolationKind::UseBeforeDef,
                Some(id),
                format!("{role} operand B{} is read before any instruction defines it", id.0),
            )),
            BufState::Freed => Err(self.violation(
                opcode,
                ViolationKind::UseAfterFree,
                Some(id),
                format!("{role} operand B{} was already freed", id.0),
            )),
        }
    }

    /// A write target must be untouched (single-assignment IR).
    fn check_write(
        &self,
        opcode: &'static str,
        id: BufferId,
        role: &str,
    ) -> Result<(), PlanViolation> {
        self.check_id(opcode, id, role)?;
        match self.state[id.0 as usize] {
            BufState::Never => Ok(()),
            BufState::Live => Err(self.violation(
                opcode,
                ViolationKind::Redefinition,
                Some(id),
                format!("{role} target B{} is already live (defined twice)", id.0),
            )),
            BufState::Freed => Err(self.violation(
                opcode,
                ViolationKind::Redefinition,
                Some(id),
                format!("{role} target B{} is redefined after being freed", id.0),
            )),
        }
    }

    /// Commit a definition: slot becomes live with `shape`.
    fn define(&mut self, id: BufferId, shape: (usize, usize)) {
        let idx = id.0 as usize;
        self.state[idx] = BufState::Live;
        self.shape[idx] = shape;
        self.live_bytes += 8 * shape.0 * shape.1;
    }

    fn free(&mut self, opcode: &'static str, id: BufferId) -> Result<(), PlanViolation> {
        self.check_id(opcode, id, "freed")?;
        let idx = id.0 as usize;
        match self.state[idx] {
            BufState::Live => {
                self.state[idx] = BufState::Freed;
                self.live_bytes -= 8 * self.shape[idx].0 * self.shape[idx].1;
                Ok(())
            }
            BufState::Never => Err(self.violation(
                opcode,
                ViolationKind::FreeBeforeDef,
                Some(id),
                format!("B{} is freed but was never defined", id.0),
            )),
            BufState::Freed => Err(self.violation(
                opcode,
                ViolationKind::DoubleFree,
                Some(id),
                format!("B{} is freed twice", id.0),
            )),
        }
    }

    fn shape_err(
        &self,
        opcode: &'static str,
        buffer: Option<BufferId>,
        detail: String,
    ) -> PlanViolation {
        self.violation(opcode, ViolationKind::ShapeMismatch, buffer, detail)
    }

    /// Close out one instruction: advance the index, fold the live-byte
    /// total into the peak (byte counts only grow monotonically *within*
    /// an instruction, so the post-instruction total is the instruction's
    /// maximum — see the module docs).
    fn step(&mut self) {
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
        self.index += 1;
    }

    /// Shape of an uploaded host source, derived from the structural
    /// signature: dense leaf blocks are `ndof_i × ndof_j`, bases are
    /// square `ndof × ndof` transforms, couplings are `rank_i × rank_j`.
    fn host_shape(&self, src: HostSrc) -> (usize, usize) {
        let shapes = &self.sig.shapes;
        match src {
            HostSrc::Dense((i, j)) => (shapes[self.sig.depth][i].0, shapes[self.sig.depth][j].0),
            HostSrc::Basis { level, index } => {
                let n = shapes[level][index].0;
                (n, n)
            }
            HostSrc::Coupling { level, key: (i, j) } => (shapes[level][i].1, shapes[level][j].1),
        }
    }

    /// Verify one factorization instruction.
    fn factor_instr(&mut self, instr: &Instr) -> Result<(), PlanViolation> {
        match instr {
            Instr::Upload { items } => {
                for &(src, dst) in items {
                    self.check_write("UPLOAD", dst, "upload")?;
                    self.define(dst, self.host_shape(src));
                }
            }
            Instr::Free { bufs } => {
                for &b in bufs {
                    self.free("FREE", b)?;
                }
            }
            _ => {
                let launch = factor_launch(instr);
                self.factor_launch_instr(&launch)?;
            }
        }
        self.step();
        Ok(())
    }

    /// Verify one factorization launch: operand legality, intra-launch
    /// aliasing, per-opcode shape conformality, then commit the writes.
    fn factor_launch_instr(&mut self, launch: &Launch<'_>) -> Result<(), PlanViolation> {
        let opcode = launch.opcode();
        let ops = launch_operands(launch);
        for &id in &ops.mat_reads {
            self.check_read(opcode, id, "read")?;
        }
        for &id in &ops.mat_rw {
            self.check_read(opcode, id, "in-place")?;
        }
        for &id in &ops.mat_writes {
            self.check_write(opcode, id, "output")?;
        }
        if let Some(hazard) = write_alias_hazard(&ops.mat_reads, &ops.mat_rw, &ops.mat_writes) {
            return Err(self.alias_violation(opcode, hazard, "matrix"));
        }

        // Per-opcode shape rules; `define` the outputs as we go (write
        // targets are disjoint from every other operand after the checks
        // above, so the order within the launch does not matter).
        match launch {
            Launch::Sparsify { items, .. } => {
                for it in items.iter() {
                    let u = self.shape[it.u.0 as usize];
                    let a = self.shape[it.a.0 as usize];
                    let v = self.shape[it.v.0 as usize];
                    if u.0 != u.1 || u.0 != a.0 {
                        return Err(self.shape_err(
                            opcode,
                            Some(it.u),
                            format!("basis U is {}x{} but block rows are {}", u.0, u.1, a.0),
                        ));
                    }
                    if v.0 != v.1 || v.0 != a.1 {
                        return Err(self.shape_err(
                            opcode,
                            Some(it.v),
                            format!("basis V is {}x{} but block cols are {}", v.0, v.1, a.1),
                        ));
                    }
                    self.define(it.dst, a);
                }
            }
            Launch::Extract { items } => {
                for it in items.iter() {
                    let src = self.shape[it.src.0 as usize];
                    if it.r0 + it.rows > src.0 || it.c0 + it.cols > src.1 {
                        return Err(self.shape_err(
                            opcode,
                            Some(it.src),
                            format!(
                                "extract window ({},{})+({}x{}) exceeds source {}x{}",
                                it.r0, it.c0, it.rows, it.cols, src.0, src.1
                            ),
                        ));
                    }
                    self.define(it.dst, (it.rows, it.cols));
                }
            }
            Launch::Potrf { bufs, .. } => {
                for &b in bufs.iter() {
                    let s = self.shape[b.0 as usize];
                    if s.0 != s.1 {
                        return Err(self.shape_err(
                            opcode,
                            Some(b),
                            format!("Cholesky block B{} is {}x{}, not square", b.0, s.0, s.1),
                        ));
                    }
                }
            }
            Launch::TrsmRightLt { items, .. } => {
                for it in items.iter() {
                    let l = self.shape[it.l.0 as usize];
                    let b = self.shape[it.b.0 as usize];
                    if l.0 != l.1 || b.1 != l.0 {
                        return Err(self.shape_err(
                            opcode,
                            Some(it.b),
                            format!(
                                "panel {}x{} does not conform with triangle {}x{}",
                                b.0, b.1, l.0, l.1
                            ),
                        ));
                    }
                }
            }
            Launch::SchurSelf { items, .. } => {
                for it in items.iter() {
                    let a = self.shape[it.a.0 as usize];
                    let c = self.shape[it.c.0 as usize];
                    if c.0 != c.1 || a.0 != c.0 {
                        return Err(self.shape_err(
                            opcode,
                            Some(it.c),
                            format!(
                                "Schur update a={}x{} into c={}x{} does not conform",
                                a.0, a.1, c.0, c.1
                            ),
                        ));
                    }
                }
            }
            Launch::Merge { items } => {
                for it in items.iter() {
                    for p in &it.parts {
                        let src = self.shape[p.src.0 as usize];
                        if p.roff + p.rows > it.rows
                            || p.coff + p.cols > it.cols
                            || p.rows > src.0
                            || p.cols > src.1
                        {
                            return Err(self.shape_err(
                                opcode,
                                Some(p.src),
                                format!(
                                    "merge tile ({},{})+({}x{}) from {}x{} source exceeds \
                                     {}x{} destination",
                                    p.roff, p.coff, p.rows, p.cols, src.0, src.1, it.rows,
                                    it.cols
                                ),
                            ));
                        }
                    }
                    self.define(it.dst, (it.rows, it.cols));
                }
            }
            Launch::Exchange { recvs, .. } => {
                // The generic operand checks above already enforced the
                // comm discipline (sends Live, receive targets Never);
                // receiving defines each target at its wire shape.
                for r in recvs.iter() {
                    self.define(r.buf, (r.rows as usize, r.cols as usize));
                }
            }
            _ => unreachable!("substitution opcode in factorization stream"),
        }
        Ok(())
    }

    fn alias_violation(
        &self,
        opcode: &'static str,
        hazard: LaunchHazard,
        space: &str,
    ) -> PlanViolation {
        match hazard {
            LaunchHazard::DuplicateWrite(b) => self.violation(
                opcode,
                ViolationKind::DuplicateWrite,
                Some(b),
                format!("two batch items write the same {space} buffer B{}", b.0),
            ),
            LaunchHazard::ReadWriteAlias(b) => self.violation(
                opcode,
                ViolationKind::ReadWriteAlias,
                Some(b),
                format!(
                    "{space} buffer B{} is read by one batch item and written by another",
                    b.0
                ),
            ),
        }
    }
}

/// Build the [`Launch`] a factorization instruction maps onto (mirrors
/// `exec::Executor::run_factor_steps` — `Upload`/`Free` never reach here).
fn factor_launch(instr: &Instr) -> Launch<'_> {
    match instr {
        Instr::Sparsify { level, items } => Launch::Sparsify { level: *level, items },
        Instr::Extract { items } => Launch::Extract { items },
        Instr::Potrf { level, bufs } => Launch::Potrf { level: *level, bufs },
        Instr::TrsmRightLt { level, items } => Launch::TrsmRightLt { level: *level, items },
        Instr::SchurSelf { level, items } => Launch::SchurSelf { level: *level, items },
        Instr::Merge { level: _, items } => Launch::Merge { items },
        Instr::Exchange { level, sends, recvs } => {
            Launch::Exchange { level: *level, sends, recvs }
        }
        Instr::Upload { .. } | Instr::Free { .. } => {
            unreachable!("Upload/Free are arena transfers, not launches")
        }
    }
}

/// Walk the factorization program. On success the returned analysis holds
/// the exact predicted peak and the final (resident) buffer states the
/// substitution walks resolve their matrix operands against.
pub(crate) fn verify_factor(
    factor: &FactorProgram,
    sig: &PlanSig,
) -> Result<FactorAnalysis, PlanViolation> {
    let mut walk = Walk::new(factor.buf_count, sig);
    for instr in &factor.prologue {
        walk.factor_instr(instr)?;
    }
    for lp in &factor.levels {
        for instr in &lp.steps {
            walk.factor_instr(instr)?;
        }
    }

    // The root Cholesky (Algorithm 2 line 22) is issued by the executor,
    // not recorded as a step — verify it as a virtual final instruction.
    let root = [factor.root_src];
    let root_launch = Launch::Potrf { level: 0, bufs: &root };
    walk.factor_launch_instr(&root_launch)?;
    let root_shape = walk.check_read("POTRF", factor.root_src, "root")?;
    if root_shape != (factor.root_n, factor.root_n) {
        return Err(walk.shape_err(
            "POTRF",
            Some(factor.root_src),
            format!(
                "root buffer is {}x{} but root_n is {}",
                root_shape.0, root_shape.1, factor.root_n
            ),
        ));
    }
    walk.step();

    // End-of-program residency audit: the live set must be exactly the
    // declared resident outputs (factor blocks, bases, root).
    let resident = factor.resident_bufs();
    let mut is_resident = vec![false; factor.buf_count];
    for &b in &resident {
        walk.check_id("END", b, "resident")?;
        is_resident[b.0 as usize] = true;
    }
    let mut resident_bytes = 0;
    for idx in 0..factor.buf_count {
        let live = walk.state[idx] == BufState::Live;
        if live && !is_resident[idx] {
            return Err(walk.violation(
                "END",
                ViolationKind::Leak,
                Some(BufferId(idx as u32)),
                format!("B{idx} is still live at program end but is not a resident output"),
            ));
        }
        if !live && is_resident[idx] {
            return Err(walk.violation(
                "END",
                ViolationKind::MissingResident,
                Some(BufferId(idx as u32)),
                format!("resident output B{idx} is not live at program end"),
            ));
        }
        if live {
            resident_bytes += 8 * walk.shape[idx].0 * walk.shape[idx].1;
        }
    }

    Ok(FactorAnalysis {
        peak_bytes: walk.peak_bytes,
        resident_bytes,
        resident_buffers: resident.len(),
        state: walk.state,
        shape: walk.shape,
        instrs: walk.index,
    })
}

// ---------------------------------------------------------------------
// Substitution walk.
// ---------------------------------------------------------------------

/// The substitution walk's view of the arena: the factorization's final
/// (resident) matrix states plus the program's pre-allocated vector region
/// (`Executor::solve_in` zero-allocates every vector up front, so vectors
/// have no def-before-use discipline — only range, length, aliasing, and
/// factor-region rules).
struct SolveWalk<'a> {
    kind: ProgramKind,
    fa: &'a FactorAnalysis,
    base: usize,
    lens: &'a [usize],
    n: usize,
    index: usize,
}

impl SolveWalk<'_> {
    fn violation(
        &self,
        opcode: &'static str,
        kind: ViolationKind,
        buffer: Option<BufferId>,
        detail: String,
    ) -> PlanViolation {
        PlanViolation { program: self.kind, index: self.index, opcode, kind, buffer, detail }
    }

    /// Resolve a matrix operand against the resident factor region.
    fn check_mat(
        &self,
        opcode: &'static str,
        id: BufferId,
        role: &str,
    ) -> Result<(usize, usize), PlanViolation> {
        if is_unset(id) {
            return Err(self.violation(
                opcode,
                ViolationKind::UnsetOperand,
                Some(id),
                format!("{role} operand is the unset placeholder B{}", id.0),
            ));
        }
        let idx = id.0 as usize;
        if idx >= self.fa.state.len() {
            return Err(self.violation(
                opcode,
                ViolationKind::OutOfRange,
                Some(id),
                format!(
                    "{role} operand B{} is outside the factor region (0..{})",
                    id.0,
                    self.fa.state.len()
                ),
            ));
        }
        match self.fa.state[idx] {
            BufState::Live => Ok(self.fa.shape[idx]),
            BufState::Never => Err(self.violation(
                opcode,
                ViolationKind::UseBeforeDef,
                Some(id),
                format!("{role} operand B{} is never defined by the factorization", id.0),
            )),
            BufState::Freed => Err(self.violation(
                opcode,
                ViolationKind::UseAfterFree,
                Some(id),
                format!("{role} operand B{} is freed before the factorization ends", id.0),
            )),
        }
    }

    /// Resolve a vector operand: must lie in the program's vector region.
    /// `write` distinguishes the factor-region-write violation from a
    /// plain out-of-range read.
    fn check_vec(
        &self,
        opcode: &'static str,
        id: BufferId,
        role: &str,
        write: bool,
    ) -> Result<usize, PlanViolation> {
        if is_unset(id) {
            return Err(self.violation(
                opcode,
                ViolationKind::UnsetOperand,
                Some(id),
                format!("{role} operand is the unset placeholder B{}", id.0),
            ));
        }
        let idx = id.0 as usize;
        if idx < self.base {
            if write {
                return Err(self.violation(
                    opcode,
                    ViolationKind::FactorRegionWrite,
                    Some(id),
                    format!(
                        "{role} target B{} lies in the read-only factor region (vectors \
                         start at B{})",
                        id.0, self.base
                    ),
                ));
            }
            return Err(self.violation(
                opcode,
                ViolationKind::OutOfRange,
                Some(id),
                format!(
                    "{role} operand B{} lies below the vector region (vectors start at B{})",
                    id.0, self.base
                ),
            ));
        }
        if idx >= self.base + self.lens.len() {
            return Err(self.violation(
                opcode,
                ViolationKind::OutOfRange,
                Some(id),
                format!(
                    "{role} operand B{} is outside the vector region ({}..{})",
                    id.0,
                    self.base,
                    self.base + self.lens.len()
                ),
            ));
        }
        Ok(self.lens[idx - self.base])
    }

    fn len_err(
        &self,
        opcode: &'static str,
        buffer: Option<BufferId>,
        detail: String,
    ) -> PlanViolation {
        self.violation(opcode, ViolationKind::ShapeMismatch, buffer, detail)
    }

    /// Verify one RHS/solution transfer step (`LoadRhs`/`StoreSol`).
    fn check_segments(
        &self,
        opcode: &'static str,
        items: &[(usize, usize, BufferId)],
        write: bool,
    ) -> Result<(), PlanViolation> {
        for &(s, e, v) in items {
            if s > e || e > self.n {
                return Err(self.len_err(
                    opcode,
                    Some(v),
                    format!("segment {s}..{e} is outside the vector 0..{}", self.n),
                ));
            }
            let len = self.check_vec(opcode, v, "segment", write)?;
            if len != e - s {
                return Err(self.len_err(
                    opcode,
                    Some(v),
                    format!("segment {s}..{e} has {} elements but B{} holds {len}", e - s, v.0),
                ));
            }
        }
        if write {
            let bufs: Vec<BufferId> = items.iter().map(|&(_, _, v)| v).collect();
            if let Some(hazard) = write_alias_hazard(&[], &[], &bufs) {
                return Err(match hazard {
                    LaunchHazard::DuplicateWrite(b) => self.violation(
                        opcode,
                        ViolationKind::DuplicateWrite,
                        Some(b),
                        format!("two segments load into the same buffer B{}", b.0),
                    ),
                    LaunchHazard::ReadWriteAlias(_) => unreachable!("no reads supplied"),
                });
            }
        }
        Ok(())
    }

    /// Verify one launch-like substitution step.
    fn check_launch(&self, launch: &Launch<'_>) -> Result<(), PlanViolation> {
        let opcode = launch.opcode();
        let ops = launch_operands(launch);
        if solve_writes_matrices(&ops) {
            let b = ops.mat_rw.first().or(ops.mat_writes.first()).copied();
            return Err(self.violation(
                opcode,
                ViolationKind::FactorRegionWrite,
                b,
                "substitution launches must not write matrix buffers (the factor region is \
                 read-only)"
                    .to_string(),
            ));
        }
        for &id in &ops.mat_reads {
            self.check_mat(opcode, id, "factor-region read")?;
        }
        for &id in &ops.vec_reads {
            self.check_vec(opcode, id, "workspace read", false)?;
        }
        for &id in &ops.vec_rw {
            self.check_vec(opcode, id, "workspace in-place", true)?;
        }
        for &id in &ops.vec_writes {
            self.check_vec(opcode, id, "workspace output", true)?;
        }
        if let Some(hazard) = write_alias_hazard(&ops.vec_reads, &ops.vec_rw, &ops.vec_writes) {
            return Err(match hazard {
                LaunchHazard::DuplicateWrite(b) => self.violation(
                    opcode,
                    ViolationKind::DuplicateWrite,
                    Some(b),
                    format!("two batch items write the same vector buffer B{}", b.0),
                ),
                LaunchHazard::ReadWriteAlias(b) => self.violation(
                    opcode,
                    ViolationKind::ReadWriteAlias,
                    Some(b),
                    format!(
                        "vector buffer B{} is read by one batch item and written by another",
                        b.0
                    ),
                ),
            });
        }

        // Length conformality per opcode.
        let vlen = |id: BufferId| self.lens[id.0 as usize - self.base];
        let mshape = |id: BufferId| self.fa.shape[id.0 as usize];
        match launch {
            Launch::ApplyBasis { items, .. } => {
                for &(u, src, dst) in items.iter() {
                    let us = mshape(u);
                    if us.0 != us.1 || vlen(src) != us.0 || vlen(dst) != us.0 {
                        return Err(self.len_err(
                            opcode,
                            Some(u),
                            format!(
                                "basis {}x{} applied to vectors of length {} -> {}",
                                us.0,
                                us.1,
                                vlen(src),
                                vlen(dst)
                            ),
                        ));
                    }
                }
            }
            Launch::Split { items } => {
                for &(src, at, lo, hi) in items.iter() {
                    if at > vlen(src) || vlen(lo) != at || vlen(hi) != vlen(src) - at {
                        return Err(self.len_err(
                            opcode,
                            Some(src),
                            format!(
                                "split of length-{} vector at {} into {} + {}",
                                vlen(src),
                                at,
                                vlen(lo),
                                vlen(hi)
                            ),
                        ));
                    }
                }
            }
            Launch::Concat { items } => {
                for &(dst, a, b) in items.iter() {
                    if vlen(dst) != vlen(a) + vlen(b) {
                        return Err(self.len_err(
                            opcode,
                            Some(dst),
                            format!(
                                "concat of lengths {} + {} into length {}",
                                vlen(a),
                                vlen(b),
                                vlen(dst)
                            ),
                        ));
                    }
                }
            }
            Launch::CopyBuf { items } => {
                for &(dst, src) in items.iter() {
                    if vlen(dst) != vlen(src) {
                        return Err(self.len_err(
                            opcode,
                            Some(dst),
                            format!("copy of length {} into length {}", vlen(src), vlen(dst)),
                        ));
                    }
                }
            }
            Launch::AddVec { items } => {
                for &(dst, a, b) in items.iter() {
                    if vlen(dst) != vlen(a) || vlen(dst) != vlen(b) {
                        return Err(self.len_err(
                            opcode,
                            Some(dst),
                            format!(
                                "add of lengths {} + {} into length {}",
                                vlen(a),
                                vlen(b),
                                vlen(dst)
                            ),
                        ));
                    }
                }
            }
            Launch::TrsvFwd { items, .. } | Launch::TrsvBwd { items, .. } => {
                for &(l, x) in items.iter() {
                    let ls = mshape(l);
                    if ls.0 != ls.1 || vlen(x) != ls.0 {
                        return Err(self.len_err(
                            opcode,
                            Some(l),
                            format!(
                                "triangular solve {}x{} against length-{} vector",
                                ls.0,
                                ls.1,
                                vlen(x)
                            ),
                        ));
                    }
                }
            }
            Launch::GemvAcc { trans, items, .. } => {
                for &(a, x, y) in items.iter() {
                    let s = mshape(a);
                    let (rows, cols) = if *trans { (s.1, s.0) } else { (s.0, s.1) };
                    if vlen(y) != rows || vlen(x) != cols {
                        return Err(self.len_err(
                            opcode,
                            Some(a),
                            format!(
                                "GEMV op(A)={rows}x{cols} against x of length {} into y of \
                                 length {}",
                                vlen(x),
                                vlen(y)
                            ),
                        ));
                    }
                }
            }
            Launch::RootSolve { l, x } => {
                let ls = mshape(*l);
                if ls.0 != ls.1 || vlen(*x) != ls.0 {
                    return Err(self.len_err(
                        opcode,
                        Some(*l),
                        format!(
                            "root solve {}x{} against length-{} vector",
                            ls.0,
                            ls.1,
                            vlen(*x)
                        ),
                    ));
                }
            }
            Launch::ExchangeVec { recvs, .. } => {
                for &(_, v, len) in recvs.iter() {
                    if vlen(v) != len as usize {
                        return Err(self.len_err(
                            opcode,
                            Some(v),
                            format!(
                                "exchange delivers {len} elements into length-{} buffer B{}",
                                vlen(v),
                                v.0
                            ),
                        ));
                    }
                }
            }
            _ => unreachable!("factorization opcode in substitution stream"),
        }
        Ok(())
    }
}

/// Walk one substitution program against a passing factorization analysis.
fn verify_solve_inner(
    fa: &FactorAnalysis,
    n: usize,
    prog: &SolveProgram,
    kind: ProgramKind,
) -> Result<SolveProgramReport, PlanViolation> {
    let mut walk = SolveWalk {
        kind,
        fa,
        base: prog.vec_base as usize,
        lens: &prog.vec_lens,
        n,
        index: 0,
    };
    for step in &prog.steps {
        match step {
            SolveInstr::LoadRhs { items } => walk.check_segments("LOADRHS", items, true)?,
            SolveInstr::StoreSol { items } => walk.check_segments("STORESOL", items, false)?,
            SolveInstr::ApplyBasis { level, trans, items } => walk.check_launch(
                &Launch::ApplyBasis { level: *level, trans: *trans, items },
            )?,
            SolveInstr::Split { items } => walk.check_launch(&Launch::Split { items })?,
            SolveInstr::Concat { items } => walk.check_launch(&Launch::Concat { items })?,
            SolveInstr::Copy { items } => walk.check_launch(&Launch::CopyBuf { items })?,
            SolveInstr::TrsvFwd { level, items } => {
                walk.check_launch(&Launch::TrsvFwd { level: *level, items })?
            }
            SolveInstr::TrsvBwd { level, items } => {
                walk.check_launch(&Launch::TrsvBwd { level: *level, items })?
            }
            SolveInstr::GemvAcc { level, trans, items } => walk.check_launch(&Launch::GemvAcc {
                level: *level,
                trans: *trans,
                alpha: -1.0,
                items,
            })?,
            SolveInstr::Add { items } => walk.check_launch(&Launch::AddVec { items })?,
            SolveInstr::RootSolve { l, x } => {
                walk.check_launch(&Launch::RootSolve { l: *l, x: *x })?
            }
            SolveInstr::Exchange { level, sends, recvs } => walk.check_launch(
                &Launch::ExchangeVec { level: *level, sends, recvs },
            )?,
        }
        walk.index += 1;
    }
    Ok(SolveProgramReport {
        instrs: prog.steps.len(),
        launches: prog.launches.len(),
        workspace_bytes: 8 * prog.vec_lens.iter().sum::<usize>(),
    })
}

/// Verify one substitution program standalone (runs the factorization walk
/// internally to resolve matrix operands). [`Plan::solve_program`] uses
/// this to debug-verify the lazily recorded naive program.
pub fn verify_solve(
    factor: &FactorProgram,
    sig: &PlanSig,
    n: usize,
    prog: &SolveProgram,
    kind: ProgramKind,
) -> Result<SolveProgramReport, PlanViolation> {
    let fa = verify_factor(factor, sig)?;
    verify_solve_inner(&fa, n, prog, kind)
}

// ---------------------------------------------------------------------
// Entry points.
// ---------------------------------------------------------------------

/// Verify a whole plan: the factorization program, the parallel
/// substitution program, and — if it has already materialized — the lazy
/// naive program. Returns the first violation found, or the full static
/// report.
pub fn verify(plan: &Plan) -> Result<PlanReport, PlanViolation> {
    let fa = verify_factor(&plan.factor, &plan.sig)?;
    let solve_parallel =
        verify_solve_inner(&fa, plan.n, &plan.solve_parallel, ProgramKind::SolveParallel)?;
    // Respect the lazy-recording contract: never force the naive program.
    let solve_naive = if plan.naive_recorded() {
        let prog = plan.solve_program(crate::ulv::SubstMode::Naive);
        Some(verify_solve_inner(&fa, plan.n, prog, ProgramKind::SolveNaive)?)
    } else {
        None
    };
    Ok(PlanReport {
        n: plan.n,
        depth: plan.depth,
        factor_instrs: fa.instrs,
        predicted_peak_bytes: fa.peak_bytes,
        resident_bytes: fa.resident_bytes,
        resident_buffers: fa.resident_buffers,
        hazard: hazard_graph(plan, crate::batch::device::r#async::DEFAULT_STREAMS),
        solve_parallel,
        solve_naive,
    })
}

/// The exact arena peak a factorization replay reaches on a
/// host-synchronous backend, or `None` if the program does not verify.
pub fn predicted_peak_bytes(plan: &Plan) -> Option<usize> {
    verify_factor(&plan.factor, &plan.sig).ok().map(|fa| fa.peak_bytes)
}

/// The positive result of [`verify_rank_set`]: aggregate communication
/// structure of a carved rank-plan set.
#[derive(Clone, Copy, Debug)]
pub struct RankSetReport {
    /// Rank count.
    pub ranks: usize,
    /// Factor-phase collectives per rank stream (equal across ranks).
    pub factor_collectives: usize,
    /// Substitution collectives per rank stream.
    pub solve_collectives: usize,
    /// Factor-phase bytes delivered (summed over every receive).
    pub factor_comm_bytes: u64,
    /// Substitution bytes delivered.
    pub solve_comm_bytes: u64,
}

/// Carve `plan` for `ranks` ranks and run the full cross-rank static
/// audit ([`verify_rank_set`]) on the result — the `plan-lint --ranks`
/// entry point. The plan's structural signature is crate-private, so
/// out-of-crate callers come through here rather than carving and
/// auditing separately.
pub fn verify_carved(
    plan: &super::Plan,
    ranks: usize,
    mode: crate::ulv::SubstMode,
) -> Result<RankSetReport, PlanViolation> {
    let rps = super::rank::carve(plan, ranks, mode);
    verify_rank_set(&rps, &plan.sig)
}

/// Cross-rank static audit of a carved rank-plan set
/// ([`crate::plan::carve`]). Every rank's factorization and substitution
/// stream must verify on its own (the per-rank walk treats `Exchange` like
/// any other launch: sends must be live, receive targets untouched), the
/// ranks must agree on the number of collectives in each phase (the k-th
/// `Exchange` on every rank is one rendezvous), every receive must name a
/// buffer its peer actually sends in that collective — at a conforming
/// shape — and every posted send must have at least one receiver.
pub fn verify_rank_set(rps: &[RankPlan], sig: &PlanSig) -> Result<RankSetReport, PlanViolation> {
    assert!(!rps.is_empty(), "verify_rank_set needs at least one rank plan");
    let mut fas = Vec::with_capacity(rps.len());
    for rp in rps {
        let fa = verify_factor(&rp.factor, sig)?;
        verify_solve_inner(&fa, rp.n, &rp.solve, ProgramKind::SolveParallel)?;
        fas.push(fa);
    }
    let unmatched = |program: ProgramKind,
                     index: usize,
                     opcode: &'static str,
                     buffer: Option<BufferId>,
                     detail: String| PlanViolation {
        program,
        index,
        opcode,
        buffer,
        kind: ViolationKind::UnmatchedComm,
        detail,
    };

    // ---- Factor-phase collectives --------------------------------------
    let factor_seqs: Vec<Vec<(&[BufferId], &[ExchangeRecv])>> = rps
        .iter()
        .map(|rp| {
            rp.factor
                .prologue
                .iter()
                .chain(rp.factor.levels.iter().flat_map(|lp| lp.steps.iter()))
                .filter_map(|i| match i {
                    Instr::Exchange { sends, recvs, .. } => {
                        Some((sends.as_slice(), recvs.as_slice()))
                    }
                    _ => None,
                })
                .collect()
        })
        .collect();
    let factor_epochs = factor_seqs[0].len();
    for (r, seq) in factor_seqs.iter().enumerate() {
        if seq.len() != factor_epochs {
            return Err(unmatched(
                ProgramKind::Factor,
                seq.len().min(factor_epochs),
                "EXCHANGE",
                None,
                format!(
                    "rank {r} records {} factor collectives but rank 0 records {factor_epochs} \
                     — the rendezvous would deadlock",
                    seq.len()
                ),
            ));
        }
    }
    let mut factor_comm_bytes = 0u64;
    for k in 0..factor_epochs {
        // (sender, buffer) -> (shape, received-by-someone).
        let mut posted: HashMap<(usize, u32), ((usize, usize), bool)> = HashMap::new();
        for (r, seq) in factor_seqs.iter().enumerate() {
            for &b in seq[k].0 {
                posted.insert((r, b.0), (fas[r].shape[b.0 as usize], false));
            }
        }
        for (r, seq) in factor_seqs.iter().enumerate() {
            for rv in seq[k].1 {
                match posted.get_mut(&(rv.from as usize, rv.buf.0)) {
                    None => {
                        return Err(unmatched(
                            ProgramKind::Factor,
                            k,
                            "EXCHANGE",
                            Some(rv.buf),
                            format!(
                                "rank {r} expects B{} from rank {} in factor collective {k}, \
                                 but rank {} never sends it",
                                rv.buf.0, rv.from, rv.from
                            ),
                        ))
                    }
                    Some((shape, received)) => {
                        if *shape != (rv.rows as usize, rv.cols as usize) {
                            return Err(PlanViolation {
                                program: ProgramKind::Factor,
                                index: k,
                                opcode: "EXCHANGE",
                                buffer: Some(rv.buf),
                                kind: ViolationKind::ShapeMismatch,
                                detail: format!(
                                    "rank {r} receives B{} as {}x{} but rank {} holds {}x{}",
                                    rv.buf.0, rv.rows, rv.cols, rv.from, shape.0, shape.1
                                ),
                            });
                        }
                        *received = true;
                        factor_comm_bytes += 8 * rv.rows as u64 * rv.cols as u64;
                    }
                }
            }
        }
        let mut orphans: Vec<(usize, u32)> =
            posted.iter().filter(|(_, &(_, rx))| !rx).map(|(&key, _)| key).collect();
        orphans.sort_unstable();
        if let Some(&(r, b)) = orphans.first() {
            return Err(unmatched(
                ProgramKind::Factor,
                k,
                "EXCHANGE",
                Some(BufferId(b)),
                format!("rank {r} sends B{b} in factor collective {k} but no rank receives it"),
            ));
        }
    }

    // ---- Substitution collectives --------------------------------------
    let solve_seqs: Vec<Vec<(&[BufferId], &[(u32, BufferId, u32)])>> = rps
        .iter()
        .map(|rp| {
            rp.solve
                .steps
                .iter()
                .filter_map(|s| match s {
                    SolveInstr::Exchange { sends, recvs, .. } => {
                        Some((sends.as_slice(), recvs.as_slice()))
                    }
                    _ => None,
                })
                .collect()
        })
        .collect();
    let solve_epochs = solve_seqs[0].len();
    for (r, seq) in solve_seqs.iter().enumerate() {
        if seq.len() != solve_epochs {
            return Err(unmatched(
                ProgramKind::SolveParallel,
                seq.len().min(solve_epochs),
                "EXCHANGEV",
                None,
                format!(
                    "rank {r} records {} substitution collectives but rank 0 records \
                     {solve_epochs} — the rendezvous would deadlock",
                    seq.len()
                ),
            ));
        }
    }
    let mut solve_comm_bytes = 0u64;
    for k in 0..solve_epochs {
        let mut posted: HashMap<(usize, u32), (usize, bool)> = HashMap::new();
        for (r, seq) in solve_seqs.iter().enumerate() {
            for &v in seq[k].0 {
                let len = rps[r].solve.vec_lens[v.0 as usize - rps[r].solve.vec_base as usize];
                posted.insert((r, v.0), (len, false));
            }
        }
        for (r, seq) in solve_seqs.iter().enumerate() {
            for &(from, v, len) in seq[k].1 {
                match posted.get_mut(&(from as usize, v.0)) {
                    None => {
                        return Err(unmatched(
                            ProgramKind::SolveParallel,
                            k,
                            "EXCHANGEV",
                            Some(v),
                            format!(
                                "rank {r} expects B{} from rank {from} in substitution \
                                 collective {k}, but rank {from} never sends it",
                                v.0
                            ),
                        ))
                    }
                    Some((sent_len, received)) => {
                        if *sent_len != len as usize {
                            return Err(PlanViolation {
                                program: ProgramKind::SolveParallel,
                                index: k,
                                opcode: "EXCHANGEV",
                                buffer: Some(v),
                                kind: ViolationKind::ShapeMismatch,
                                detail: format!(
                                    "rank {r} receives B{} at length {len} but rank {from} \
                                     sends length {sent_len}",
                                    v.0
                                ),
                            });
                        }
                        *received = true;
                        solve_comm_bytes += 8 * len as u64;
                    }
                }
            }
        }
        let mut orphans: Vec<(usize, u32)> =
            posted.iter().filter(|(_, &(_, rx))| !rx).map(|(&key, _)| key).collect();
        orphans.sort_unstable();
        if let Some(&(r, b)) = orphans.first() {
            return Err(unmatched(
                ProgramKind::SolveParallel,
                k,
                "EXCHANGEV",
                Some(BufferId(b)),
                format!(
                    "rank {r} sends B{b} in substitution collective {k} but no rank receives it"
                ),
            ));
        }
    }

    Ok(RankSetReport {
        ranks: rps.len(),
        factor_collectives: factor_epochs,
        solve_collectives: solve_epochs,
        factor_comm_bytes,
        solve_comm_bytes,
    })
}

// ---------------------------------------------------------------------
// Static hazard graph.
// ---------------------------------------------------------------------

/// Last-toucher chain builder: each op depends on the most recent prior op
/// that touched any of its operands (the async engine's exact rule —
/// every toucher updates the chain, reads included, so the graph is a
/// conservative RAW/WAW/WAR order identical to the runtime tracker's).
struct GraphBuilder {
    ops: Vec<HazardOp>,
    last: HashMap<u32, usize>,
    edges: usize,
}

impl GraphBuilder {
    fn push(&mut self, opcode: &'static str, stream: usize, level: usize, operands: Vec<u32>) {
        let mut deps: Vec<usize> =
            operands.iter().filter_map(|b| self.last.get(b).copied()).collect();
        deps.sort_unstable();
        deps.dedup();
        let seq = self.ops.len();
        for &b in &operands {
            self.last.insert(b, seq);
        }
        self.edges += deps.len();
        self.ops.push(HazardOp { seq, opcode, stream, level, operands, deps });
    }

    /// The async engine's operand set for a launch: every touched buffer,
    /// sorted and deduplicated.
    fn operand_set(launch: &Launch<'_>) -> Vec<u32> {
        let ops = launch_operands(launch);
        let mut set: Vec<u32> = ops
            .mat_reads
            .iter()
            .chain(&ops.mat_rw)
            .chain(&ops.mat_writes)
            .map(|b| b.0)
            .collect();
        set.sort_unstable();
        set.dedup();
        set
    }

    fn instr(&mut self, instr: &Instr, stream: usize, level: usize) {
        match instr {
            Instr::Upload { items } => {
                for &(_, dst) in items {
                    self.push("UPLOAD", stream, level, vec![dst.0]);
                }
            }
            Instr::Free { bufs } => {
                for &b in bufs {
                    self.push("FREE", stream, level, vec![b.0]);
                }
            }
            _ => {
                let launch = factor_launch(instr);
                self.push(launch.opcode(), stream, level, Self::operand_set(&launch));
            }
        }
    }
}

/// Build the static hazard graph of a factorization replay on an async
/// executor with `streams` queues: the exact op sequence
/// (`Executor::factorize_*` issue order — per-item uploads, per-buffer
/// frees, one op per launch) with last-toucher dependency edges and the
/// engine's stream assignment (`level % streams`; the prologue runs on the
/// initial stream 0).
pub fn hazard_graph(plan: &Plan, streams: usize) -> HazardGraph {
    let streams = streams.max(1);
    let mut b = GraphBuilder { ops: Vec::new(), last: HashMap::new(), edges: 0 };

    // Prologue: issued before any stream hint — the engine's initial state
    // is stream 0, level unset.
    for instr in &plan.factor.prologue {
        b.instr(instr, 0, usize::MAX);
    }
    for lp in &plan.factor.levels {
        let stream = lp.level % streams;
        for instr in &lp.steps {
            b.instr(instr, stream, lp.level);
        }
    }
    // Root Cholesky: the executor switches to stream(0) first.
    b.push("POTRF", 0, 0, vec![plan.factor.root_src.0]);

    assemble_graph(streams, b.ops, b.edges)
}

/// Finish a hazard graph from its op list: longest dependency chain
/// (critical path, in ops) plus per-level aggregation (intra-level chains
/// only) in first-occurrence order. Shared by the factorization and
/// substitution builders so the two reports stay comparable.
fn assemble_graph(streams: usize, ops: Vec<HazardOp>, edges: usize) -> HazardGraph {
    let mut depth = vec![0usize; ops.len()];
    let mut critical_path = 0;
    for op in &ops {
        let d = 1 + op.deps.iter().map(|&p| depth[p]).max().unwrap_or(0);
        depth[op.seq] = d;
        critical_path = critical_path.max(d);
    }

    let mut level_order: Vec<usize> = Vec::new();
    let mut level_idx: HashMap<usize, usize> = HashMap::new();
    for op in &ops {
        level_idx.entry(op.level).or_insert_with(|| {
            level_order.push(op.level);
            level_order.len() - 1
        });
    }
    let mut level_ops = vec![0usize; level_order.len()];
    let mut level_crit = vec![0usize; level_order.len()];
    let mut intra = vec![0usize; ops.len()];
    for op in &ops {
        let li = level_idx[&op.level];
        level_ops[li] += 1;
        let d = 1 + op
            .deps
            .iter()
            .filter(|&&p| ops[p].level == op.level)
            .map(|&p| intra[p])
            .max()
            .unwrap_or(0);
        intra[op.seq] = d;
        level_crit[li] = level_crit[li].max(d);
    }
    let levels = level_order
        .iter()
        .enumerate()
        .map(|(li, &level)| LevelHazard {
            level,
            ops: level_ops[li],
            critical_path: level_crit[li],
            parallelism: if level_crit[li] > 0 {
                level_ops[li] as f64 / level_crit[li] as f64
            } else {
                0.0
            },
        })
        .collect();

    HazardGraph { streams, ops, levels, critical_path, edges }
}

/// Shared-reader chain builder for the substitution stream: the exact dep
/// rule of the async engine's hazard table (`Engine::enqueue`). A read
/// depends on the last writer of its buffer only — concurrent readers
/// never order against each other, which is what lets every box of a level
/// read the same factor block at once — while a write depends on the last
/// writer *and* every reader journaled since, then becomes the new writer.
#[derive(Default)]
struct SolveGraphBuilder {
    ops: Vec<HazardOp>,
    /// Per-buffer `(last writer, readers since)`. One u32 namespace is
    /// exact: factor matrices live below `vec_base` and workspace vectors
    /// at `vec_base..`, mirroring the runtime's disjoint (arena, buffer)
    /// keys.
    access: HashMap<u32, (Option<usize>, Vec<usize>)>,
    edges: usize,
}

impl SolveGraphBuilder {
    fn push(
        &mut self,
        opcode: &'static str,
        stream: usize,
        level: usize,
        reads: &[u32],
        writes: &[u32],
    ) {
        let mut deps: Vec<usize> = Vec::new();
        for b in reads {
            if let Some((Some(w), _)) = self.access.get(b) {
                deps.push(*w);
            }
        }
        for b in writes {
            if let Some((w, rs)) = self.access.get(b) {
                if let Some(w) = w {
                    deps.push(*w);
                }
                deps.extend(rs.iter().copied());
            }
        }
        deps.sort_unstable();
        deps.dedup();
        let seq = self.ops.len();
        for &b in reads {
            self.access.entry(b).or_default().1.push(seq);
        }
        for &b in writes {
            let entry = self.access.entry(b).or_default();
            entry.0 = Some(seq);
            entry.1.clear();
        }
        let mut operands: Vec<u32> = reads.iter().chain(writes).copied().collect();
        operands.sort_unstable();
        operands.dedup();
        self.edges += deps.len();
        self.ops.push(HazardOp { seq, opcode, stream, level, operands, deps });
    }
}

/// Build the static hazard graph of one substitution replay on an async
/// executor with `streams` queues — the journal [`AsyncDevice`] produces
/// when `Executor::run_solve_steps` issues `prog` (one solve at a time):
///
/// * `LoadRhs` journals one `UPLOADV` transfer per segment (a workspace
///   write); received `Exchange` segments do the same after the
///   collective's full fence.
/// * every launch step journals one op whose operand roles come from
///   [`launch_operands`], split exactly like the engine's `solve_roles`:
///   factor-matrix and vector reads are *shared reads*, updated or
///   written vectors are writes.
/// * `StoreSol` journals nothing — `download_vec` is a synchronous,
///   arena-scoped drain that leaves the (single-solve) engine quiescent,
///   so the hazard table resets there, as it does at an `Exchange` fence.
///
/// Stream assignment mirrors the replay's level hints (`level % streams`
/// at each level boundary). The graph models a solve issued right after a
/// completed factorization replay, which parks the engine on stream 0 /
/// level 0 (the root Cholesky's hint) — the session's steady state.
pub fn solve_hazard_graph(prog: &SolveProgram, streams: usize) -> HazardGraph {
    let streams = streams.max(1);
    let mut b = SolveGraphBuilder::default();
    let mut stream = 0usize;
    let mut level = 0usize;
    let mut cur_level = usize::MAX;
    for step in &prog.steps {
        if let Some(l) = step.level() {
            if l != cur_level {
                cur_level = l;
                stream = l % streams;
                level = l;
            }
        }
        match step {
            SolveInstr::LoadRhs { items } => {
                for &(_, _, v) in items {
                    b.push("UPLOADV", stream, level, &[], &[v.0]);
                }
            }
            SolveInstr::StoreSol { .. } => {
                b.access.clear();
            }
            SolveInstr::Exchange { recvs, .. } => {
                // `device.fence()` before the collective quiesces the
                // engine; the received segments then re-enter as journaled
                // uploads.
                b.access.clear();
                for &(_, v, _) in recvs {
                    b.push("UPLOADV", stream, level, &[], &[v.0]);
                }
            }
            _ => {
                let launch = solve_step_launch(step)
                    .expect("transfer steps are handled above");
                let ops = launch_operands(&launch);
                let mut reads: Vec<u32> =
                    ops.mat_reads.iter().chain(&ops.vec_reads).map(|b| b.0).collect();
                let mut writes: Vec<u32> = ops
                    .mat_rw
                    .iter()
                    .chain(&ops.mat_writes)
                    .chain(&ops.vec_rw)
                    .chain(&ops.vec_writes)
                    .map(|b| b.0)
                    .collect();
                reads.sort_unstable();
                reads.dedup();
                writes.sort_unstable();
                writes.dedup();
                b.push(launch.opcode(), stream, level, &reads, &writes);
            }
        }
    }
    assemble_graph(streams, b.ops, b.edges)
}

/// View a launch-like substitution step as the [`Launch`] the replay
/// issues for it (`None` for the transfer/collective steps `LoadRhs`,
/// `StoreSol`, and `Exchange`, which never reach `launch_solve`).
fn solve_step_launch<'a>(step: &'a SolveInstr) -> Option<Launch<'a>> {
    Some(match step {
        SolveInstr::ApplyBasis { level, trans, items } => {
            Launch::ApplyBasis { level: *level, trans: *trans, items }
        }
        SolveInstr::Split { items } => Launch::Split { items },
        SolveInstr::Concat { items } => Launch::Concat { items },
        SolveInstr::Copy { items } => Launch::CopyBuf { items },
        SolveInstr::TrsvFwd { level, items } => Launch::TrsvFwd { level: *level, items },
        SolveInstr::TrsvBwd { level, items } => Launch::TrsvBwd { level: *level, items },
        SolveInstr::GemvAcc { level, trans, items } => {
            Launch::GemvAcc { level: *level, trans: *trans, alpha: -1.0, items }
        }
        SolveInstr::Add { items } => Launch::AddVec { items },
        SolveInstr::RootSolve { l, x } => Launch::RootSolve { l: *l, x: *x },
        SolveInstr::LoadRhs { .. } | SolveInstr::StoreSol { .. } | SolveInstr::Exchange { .. } => {
            return None
        }
    })
}

// Re-exported for the record-time hook (`Recorder::run` debug-verifies its
// own output before handing the plan out).
pub(crate) fn debug_verify_recorded(plan: &Plan) {
    if cfg!(debug_assertions) {
        if let Err(v) = verify(plan) {
            panic!("recorder produced an invalid plan: {v}");
        }
    }
}

pub(crate) fn debug_verify_naive(
    factor: &FactorProgram,
    sig: &PlanSig,
    n: usize,
    prog: &SolveProgram,
) {
    if cfg!(debug_assertions) {
        if let Err(v) = verify_solve(factor, sig, n, prog, ProgramKind::SolveNaive) {
            panic!("recorder produced an invalid naive substitution program: {v}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_placeholder_is_detected() {
        assert!(is_unset(BufferId(u32::MAX)));
        assert!(!is_unset(BufferId(0)));
    }

    #[test]
    fn alias_hazard_reports_duplicates_before_aliases() {
        // Two items write B3; B3 is also read — the duplicate wins, in the
        // same order the runtime auditor reports.
        let reads = [BufferId(3)];
        let writes = [BufferId(3), BufferId(3)];
        match write_alias_hazard(&reads, &[], &writes) {
            Some(LaunchHazard::DuplicateWrite(b)) => assert_eq!(b, BufferId(3)),
            _ => panic!("expected a duplicate-write hazard"),
        }
        // Clean write sets pass.
        let writes = [BufferId(4), BufferId(5)];
        assert!(write_alias_hazard(&[BufferId(1)], &[], &writes).is_none());
        // A read aliasing a write is the second class.
        match write_alias_hazard(&[BufferId(4)], &[], &writes) {
            Some(LaunchHazard::ReadWriteAlias(b)) => assert_eq!(b, BufferId(4)),
            _ => panic!("expected a read/write alias hazard"),
        }
        // In-place operands count as writes.
        match write_alias_hazard(&[], &[BufferId(7), BufferId(7)], &[]) {
            Some(LaunchHazard::DuplicateWrite(b)) => assert_eq!(b, BufferId(7)),
            _ => panic!("expected a duplicate-write hazard from rw operands"),
        }
    }

    #[test]
    fn solve_matrix_write_detection() {
        let mut ops = LaunchOperands::default();
        assert!(!solve_writes_matrices(&ops));
        ops.mat_rw.push(BufferId(0));
        assert!(solve_writes_matrices(&ops));
        let mut ops = LaunchOperands::default();
        ops.mat_writes.push(BufferId(1));
        assert!(solve_writes_matrices(&ops));
    }
}
