//! Householder QR, column-pivoted QR (CPQR), and the interpolative
//! decomposition (ID) built on top of it.
//!
//! The construction phase (paper Algorithm 1) computes
//! `(U_i, SK_i) <- ID([A_Far, A_Close])`: a *row* ID selecting skeleton
//! points of a box plus an interpolation operator. We realize the ID with
//! CPQR, then orthogonalize the interpolation operator with plain QR to get
//! the square orthogonal basis `U_i = [U^S | U^R]` that the ULV
//! factorization applies from both sides (paper eq 6).

use super::blas::{self, Side, Uplo};
use super::matrix::{Matrix, Trans};

/// Result of a (thin or full) Householder QR.
pub struct QrFactor {
    /// Orthogonal factor. `rows x rows` when full, `rows x min(rows,cols)` thin.
    pub q: Matrix,
    /// Upper-triangular/trapezoidal factor matching `q`.
    pub r: Matrix,
}

/// Householder QR of `a`. When `full` is true, `q` is square `m x m`
/// (its trailing columns complete the range of `a` to an orthonormal basis
/// of R^m — this is how `U^R` is obtained from `U^S`).
pub fn qr(a: &Matrix, full: bool) -> QrFactor {
    let m = a.rows();
    let n = a.cols();
    let kmax = m.min(n);
    let mut r = a.clone();
    // Householder vectors stored per reflection.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(kmax);
    for k in 0..kmax {
        // Build reflector for column k below diagonal.
        let mut v = vec![0.0; m - k];
        for i in k..m {
            v[i - k] = r[(i, k)];
        }
        let alpha = -v[0].signum() * blas::dot(&v, &v).sqrt();
        if alpha == 0.0 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        v[0] -= alpha;
        let vnorm2 = blas::dot(&v, &v);
        if vnorm2 == 0.0 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // Apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..] — slice-based dot +
        // AXPY per column (perf pass: removes per-element index math).
        for j in k..n {
            let col = &mut r.col_mut(j)[k..];
            let w = 2.0 * blas::dot(&v, col) / vnorm2;
            for (ci, vi) in col.iter_mut().zip(&v) {
                *ci -= w * vi;
            }
        }
        vs.push(v);
    }
    // Zero sub-diagonal noise.
    for j in 0..n {
        for i in (j + 1)..m {
            r[(i, j)] = 0.0;
        }
    }
    // Accumulate Q by applying reflectors to identity columns.
    let qcols = if full { m } else { kmax };
    let mut q = Matrix::zeros(m, qcols);
    for j in 0..qcols {
        q[(j, j)] = 1.0;
    }
    for k in (0..vs.len()).rev() {
        let v = &vs[k];
        let vnorm2 = blas::dot(v, v);
        if vnorm2 == 0.0 {
            continue;
        }
        for j in 0..qcols {
            let col = &mut q.col_mut(j)[k..];
            let w = 2.0 * blas::dot(v, col) / vnorm2;
            for (ci, vi) in col.iter_mut().zip(v) {
                *ci -= w * vi;
            }
        }
    }
    let r_out = if full {
        r
    } else {
        r.submatrix(0, 0, kmax, n)
    };
    QrFactor { q, r: r_out }
}

/// Column-pivoted QR: `A P = Q R` with pivots chosen greedily by remaining
/// column norm. Stops at `max_rank` columns or when the pivot norm falls
/// below `rtol * |first pivot|`.
pub struct Cpqr {
    /// Pivot order: `jpvt[t]` is the original column index chosen at step t.
    pub jpvt: Vec<usize>,
    /// Numerical rank k detected.
    pub rank: usize,
    /// `R` factor, `k x n`, columns in *pivoted* order.
    pub r: Matrix,
}

/// Column-pivoted Householder QR (LAPACK `geqp3`-style, unblocked).
pub fn cpqr(a: &Matrix, rtol: f64, max_rank: usize) -> Cpqr {
    let m = a.rows();
    let n = a.cols();
    let kmax = m.min(n).min(max_rank.max(1));
    let mut r = a.clone();
    let mut jpvt: Vec<usize> = (0..n).collect();
    // Running squared column norms of the trailing block.
    let mut cnorm: Vec<f64> = (0..n).map(|j| blas::dot(r.col(j), r.col(j))).collect();
    let mut cnorm0 = cnorm.clone();
    let mut first_pivot = 0.0;
    let mut rank = 0;
    for k in 0..kmax {
        // Select pivot column with max remaining norm.
        let (pj, &pn) = cnorm[k..]
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .map(|(i, v)| (i + k, v))
            .unwrap();
        if k == 0 {
            first_pivot = pn.sqrt();
        }
        if pn.sqrt() <= rtol * first_pivot || pn == 0.0 {
            break;
        }
        if pj != k {
            // Swap columns k and pj in R, cnorm, jpvt.
            for i in 0..m {
                let t = r[(i, k)];
                r[(i, k)] = r[(i, pj)];
                r[(i, pj)] = t;
            }
            cnorm.swap(k, pj);
            cnorm0.swap(k, pj);
            jpvt.swap(k, pj);
        }
        // Householder on column k.
        let mut v = vec![0.0; m - k];
        for i in k..m {
            v[i - k] = r[(i, k)];
        }
        let alpha = -v[0].signum() * blas::dot(&v, &v).sqrt();
        if alpha != 0.0 {
            v[0] -= alpha;
            let vnorm2 = blas::dot(&v, &v);
            if vnorm2 > 0.0 {
                // Column k is known analytically: (alpha, 0, ..., 0).
                {
                    let col = &mut r.col_mut(k)[k..];
                    col.fill(0.0);
                    col[0] = alpha;
                }
                for j in k + 1..n {
                    let col = &mut r.col_mut(j)[k..];
                    let w = 2.0 * blas::dot(&v, col) / vnorm2;
                    for (ci, vi) in col.iter_mut().zip(&v) {
                        *ci -= w * vi;
                    }
                }
            }
        }
        // Downdate trailing column norms; recompute exactly when the
        // downdate cancels badly (LAPACK geqp3-style safeguard).
        for j in k + 1..n {
            let rkj = r[(k, j)];
            let down = cnorm[j] - rkj * rkj;
            if down <= 1e-8 * cnorm0[j] {
                let mut s = 0.0;
                for i in k + 1..m {
                    let v = r[(i, j)];
                    s += v * v;
                }
                cnorm[j] = s;
                cnorm0[j] = s;
            } else {
                cnorm[j] = down;
            }
        }
        rank = k + 1;
    }
    let mut r_out = Matrix::zeros(rank, n);
    for j in 0..n {
        for i in 0..rank.min(j + 1) {
            r_out[(i, j)] = r[(i, j)];
        }
    }
    Cpqr { jpvt, rank, r: r_out }
}

/// Row interpolative decomposition: `M ≈ T * M[sk, :]` where `sk` are
/// `rank` selected row indices and `T` is `m x rank` with `T[sk, :] = I`.
///
/// Implemented as a column ID of `Mᵀ` via CPQR: `MᵀP = QR`,
/// `X = R11⁻¹ R12` interpolates non-skeleton rows from skeleton rows.
pub struct RowId {
    /// Selected (skeleton) row indices, in pivot order.
    pub skeleton: Vec<usize>,
    /// Interpolation operator `m x rank`.
    pub t: Matrix,
}

/// Compute a row ID with rank bounded by `max_rank` and relative tolerance
/// `rtol` (pass `rtol = 0.0` for fixed-rank truncation).
pub fn row_id(m: &Matrix, rtol: f64, max_rank: usize) -> RowId {
    let mt = m.transpose();
    let f = cpqr(&mt, rtol, max_rank);
    let k = f.rank;
    let rows = m.rows();
    if k == 0 {
        // Degenerate: all rows ~ zero. Keep one skeleton row to stay well-formed.
        let mut t = Matrix::zeros(rows, 1.min(rows));
        if rows > 0 {
            t[(0, 0)] = 1.0;
        }
        return RowId { skeleton: if rows > 0 { vec![0] } else { vec![] }, t };
    }
    // Solve R11 X = R12  (R11 k x k upper-triangular).
    let r11 = f.r.submatrix(0, 0, k, k);
    let ncols = f.r.cols();
    let mut x = f.r.submatrix(0, k, k, ncols - k);
    if !x.is_empty() {
        blas::trsm(Side::Left, Uplo::Upper, Trans::No, 1.0, &r11, &mut x);
    }
    // Assemble T in original row order: T[jpvt[t], t] = I for t < k,
    // T[jpvt[k + j], :] = X[:, j]ᵀ for the rest.
    let mut t = Matrix::zeros(rows, k);
    for (tcol, &orig) in f.jpvt.iter().take(k).enumerate() {
        t[(orig, tcol)] = 1.0;
    }
    for j in 0..(rows - k) {
        let orig = f.jpvt[k + j];
        for i in 0..k {
            t[(orig, i)] = x[(i, j)];
        }
    }
    RowId { skeleton: f.jpvt[..k].to_vec(), t }
}

/// Square orthogonal basis from an interpolation operator.
///
/// Given `T` (n x k, full column rank), returns `(U, R)` with
/// `U = [U^S | U^R]` square orthogonal (n x n), `U^S = Q` from `T = Q R`,
/// and `R` (k x k upper). The ULV transform applies `Uᵀ` from the left /
/// `U` from the right; couplings are weighted by `R` (DESIGN.md §4).
pub fn orthogonalize_basis(t: &Matrix) -> (Matrix, Matrix) {
    let f = qr(t, true);
    let k = t.cols();
    let r = f.r.submatrix(0, 0, k, k);
    (f.q, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::frob;
    use crate::util::Rng;

    #[test]
    fn qr_thin_reconstructs() {
        let mut rng = Rng::new(41);
        for &(m, n) in &[(6, 4), (4, 6), (5, 5), (10, 1)] {
            let a = Matrix::randn(m, n, &mut rng);
            let f = qr(&a, false);
            let mut rec = Matrix::zeros(m, n);
            blas::gemm(1.0, &f.q, Trans::No, &f.r, Trans::No, 0.0, &mut rec);
            rec.axpy(-1.0, &a);
            assert!(frob(&rec) < 1e-12 * (1.0 + frob(&a)), "({m},{n})");
        }
    }

    #[test]
    fn qr_full_orthogonal() {
        let mut rng = Rng::new(43);
        let a = Matrix::randn(7, 3, &mut rng);
        let f = qr(&a, true);
        assert_eq!((f.q.rows(), f.q.cols()), (7, 7));
        let mut qtq = Matrix::zeros(7, 7);
        blas::gemm(1.0, &f.q, Trans::Yes, &f.q, Trans::No, 0.0, &mut qtq);
        qtq.axpy(-1.0, &Matrix::eye(7));
        assert!(frob(&qtq) < 1e-12);
        // Reconstruction via full factors.
        let mut rec = Matrix::zeros(7, 3);
        blas::gemm(1.0, &f.q, Trans::No, &f.r, Trans::No, 0.0, &mut rec);
        rec.axpy(-1.0, &a);
        assert!(frob(&rec) < 1e-12);
    }

    #[test]
    fn cpqr_finds_rank() {
        let mut rng = Rng::new(45);
        // Rank-3 matrix 10x8.
        let b = Matrix::randn(10, 3, &mut rng);
        let c = Matrix::randn(3, 8, &mut rng);
        let mut a = Matrix::zeros(10, 8);
        blas::gemm(1.0, &b, Trans::No, &c, Trans::No, 0.0, &mut a);
        let f = cpqr(&a, 1e-10, 8);
        assert_eq!(f.rank, 3);
    }

    #[test]
    fn cpqr_respects_max_rank() {
        let mut rng = Rng::new(47);
        let a = Matrix::randn(10, 10, &mut rng);
        let f = cpqr(&a, 0.0, 4);
        assert_eq!(f.rank, 4);
        assert_eq!(f.r.rows(), 4);
    }

    #[test]
    fn row_id_exact_for_low_rank() {
        let mut rng = Rng::new(49);
        let b = Matrix::randn(12, 4, &mut rng);
        let c = Matrix::randn(4, 20, &mut rng);
        let mut m = Matrix::zeros(12, 20);
        blas::gemm(1.0, &b, Trans::No, &c, Trans::No, 0.0, &mut m);
        let id = row_id(&m, 1e-12, 12);
        assert_eq!(id.skeleton.len(), 4);
        // T * M[sk,:] == M
        let msk = m.select_rows(&id.skeleton);
        let mut rec = Matrix::zeros(12, 20);
        blas::gemm(1.0, &id.t, Trans::No, &msk, Trans::No, 0.0, &mut rec);
        rec.axpy(-1.0, &m);
        assert!(frob(&rec) < 1e-9 * frob(&m));
        // Identity rows at skeleton positions.
        for (t, &s) in id.skeleton.iter().enumerate() {
            for j in 0..id.skeleton.len() {
                let want = if j == t { 1.0 } else { 0.0 };
                assert!((id.t[(s, j)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn row_id_fixed_rank_quality() {
        // Smooth (Hilbert-like) kernel rows compress well at fixed rank.
        let m = Matrix::from_fn(30, 40, |i, j| 1.0 / (1.0 + i as f64 + j as f64));
        let id = row_id(&m, 0.0, 8);
        assert_eq!(id.skeleton.len(), 8);
        let msk = m.select_rows(&id.skeleton);
        let mut rec = Matrix::zeros(30, 40);
        blas::gemm(1.0, &id.t, Trans::No, &msk, Trans::No, 0.0, &mut rec);
        rec.axpy(-1.0, &m);
        assert!(frob(&rec) < 0.1 * frob(&m));
    }

    #[test]
    fn orthogonalize_basis_splits() {
        let mut rng = Rng::new(51);
        let t = Matrix::randn(9, 3, &mut rng);
        let (u, r) = orthogonalize_basis(&t);
        assert_eq!((u.rows(), u.cols()), (9, 9));
        assert_eq!((r.rows(), r.cols()), (3, 3));
        // U orthogonal.
        let mut utu = Matrix::zeros(9, 9);
        blas::gemm(1.0, &u, Trans::Yes, &u, Trans::No, 0.0, &mut utu);
        utu.axpy(-1.0, &Matrix::eye(9));
        assert!(frob(&utu) < 1e-12);
        // First 3 columns * R == T.
        let us = u.submatrix(0, 0, 9, 3);
        let mut rec = Matrix::zeros(9, 3);
        blas::gemm(1.0, &us, Trans::No, &r, Trans::No, 0.0, &mut rec);
        rec.axpy(-1.0, &t);
        assert!(frob(&rec) < 1e-12 * (1.0 + frob(&t)));
    }
}
