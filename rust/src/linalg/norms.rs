//! Matrix and vector norms.

use super::blas;
use super::matrix::{Matrix, Trans};

/// Frobenius norm.
pub fn frob(a: &Matrix) -> f64 {
    a.as_slice().iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Euclidean norm of a vector.
pub fn norm2_vec(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Relative error `||x - y|| / ||y||` of two vectors.
pub fn rel_err_vec(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let d: f64 = x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let n = norm2_vec(y);
    if n == 0.0 {
        d
    } else {
        d / n
    }
}

/// Spectral norm estimate via power iteration on `AᵀA`.
pub fn norm2_est(a: &Matrix, iters: usize) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let n = a.cols();
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.37).sin()).collect();
    let mut av = vec![0.0; a.rows()];
    let mut s = 0.0;
    for _ in 0..iters.max(2) {
        let nv = norm2_vec(&v);
        if nv == 0.0 {
            return 0.0;
        }
        for x in v.iter_mut() {
            *x /= nv;
        }
        blas::gemv(1.0, a, Trans::No, &v, 0.0, &mut av);
        blas::gemv(1.0, a, Trans::Yes, &av, 0.0, &mut v);
        s = norm2_vec(&av);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn frob_eye() {
        assert!((frob(&Matrix::eye(9)) - 3.0).abs() < 1e-14);
    }

    #[test]
    fn norm2_diag() {
        let mut a = Matrix::zeros(4, 4);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -7.0;
        a[(2, 2)] = 2.0;
        let est = norm2_est(&a, 50);
        assert!((est - 7.0).abs() < 1e-6, "est={est}");
    }

    #[test]
    fn rel_err_zero_for_equal() {
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        assert_eq!(rel_err_vec(&x, &x), 0.0);
    }
}
