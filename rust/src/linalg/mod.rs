//! From-scratch dense linear-algebra substrate.
//!
//! The paper's smallest unit of computation is a single dense matrix block
//! operated on by BLAS/LAPACK routines (paper §4). Since no external BLAS
//! is available offline, this module implements the needed subset:
//!
//! * [`Matrix`] — column-major `f64` matrix (LAPACK convention).
//! * [`blas`]   — GEMM / SYRK / TRSM / TRSV / GEMV and friends.
//! * [`chol`]   — Cholesky factorization (POTRF) + solves.
//! * [`lu`]     — partially pivoted LU (GETRF/GETRS), used by baselines.
//! * [`qr`]     — Householder QR and column-pivoted QR (basis of the
//!                interpolative decomposition in the construction phase).
//! * [`svd`]    — one-sided Jacobi SVD for rank/accuracy studies.
//! * [`norms`]  — Frobenius / 2-norm estimation / vector norms.

pub mod blas;
pub mod chol;
pub mod lu;
pub mod matrix;
pub mod norms;
pub mod qr;
pub mod svd;

pub use matrix::Matrix;
