//! BLAS-level routines (levels 1-3) over [`Matrix`].
//!
//! GEMM is the FLOP hot path for the whole stack (sparsification, Schur
//! updates, TRSM right-hand sides), so it gets a blocked micro-kernel
//! implementation; everything else is written for clarity.

use super::matrix::{Matrix, Trans};

/// Which triangle of a matrix a routine reads/writes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Uplo {
    Lower,
    Upper,
}

/// Side of multiplication for TRSM.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Side {
    Left,
    Right,
}

#[inline]
fn dims(a: &Matrix, ta: Trans) -> (usize, usize) {
    match ta {
        Trans::No => (a.rows(), a.cols()),
        Trans::Yes => (a.cols(), a.rows()),
    }
}

/// `C = alpha * op(A) * op(B) + beta * C`.
///
/// Dispatches to a packed, register-blocked kernel for the dominant
/// NoTrans x NoTrans case; transposed operands go through explicit
/// transposition (cheap relative to the O(mnk) multiply).
pub fn gemm(alpha: f64, a: &Matrix, ta: Trans, b: &Matrix, tb: Trans, beta: f64, c: &mut Matrix) {
    let (m, ka) = dims(a, ta);
    let (kb, n) = dims(b, tb);
    assert_eq!(ka, kb, "gemm inner dim mismatch: {ka} vs {kb}");
    assert_eq!((c.rows(), c.cols()), (m, n), "gemm output shape mismatch");
    let k = ka;

    if beta != 1.0 {
        if beta == 0.0 {
            c.as_mut_slice().fill(0.0);
        } else {
            c.scale(beta);
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }

    // Hot path: plain column-major multiply, no transposes needed.
    match (ta, tb) {
        (Trans::No, Trans::No) => gemm_nn(alpha, a, b, c),
        (Trans::Yes, Trans::No) => {
            let at = a.transpose();
            gemm_nn(alpha, &at, b, c);
        }
        (Trans::No, Trans::Yes) => {
            let bt = b.transpose();
            gemm_nn(alpha, a, &bt, c);
        }
        (Trans::Yes, Trans::Yes) => {
            let at = a.transpose();
            let bt = b.transpose();
            gemm_nn(alpha, &at, &bt, c);
        }
    }
}

/// Blocked column-major `C += alpha * A * B` (all NoTrans).
///
/// Loop order j-k-i makes the inner loop a contiguous AXPY over a column of
/// C with a column of A — auto-vectorizes well and is cache-friendly for
/// column-major data. K-blocking keeps the working set of A in L2.
fn gemm_nn(alpha: f64, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let m = a.rows();
    let k = a.cols();
    let n = b.cols();
    const KC: usize = 256;
    let a_data = a.as_slice();
    for j in 0..n {
        let bcol = b.col(j);
        let ccol = c.col_mut(j);
        let mut p0 = 0;
        while p0 < k {
            let pend = (p0 + KC).min(k);
            let mut p = p0;
            // 4-way k-unrolling: one pass over the C column consumes four
            // A columns, quartering the C-column traffic. The perf pass
            // measured ~1.45x over the single-AXPY loop; 8-way regressed
            // (register pressure) — see EXPERIMENTS.md §Perf.
            while p + 4 <= pend {
                let w0 = alpha * bcol[p];
                let w1 = alpha * bcol[p + 1];
                let w2 = alpha * bcol[p + 2];
                let w3 = alpha * bcol[p + 3];
                let a0 = &a_data[p * m..(p + 1) * m];
                let a1 = &a_data[(p + 1) * m..(p + 2) * m];
                let a2 = &a_data[(p + 2) * m..(p + 3) * m];
                let a3 = &a_data[(p + 3) * m..(p + 4) * m];
                for i in 0..m {
                    ccol[i] += w0 * a0[i] + w1 * a1[i] + w2 * a2[i] + w3 * a3[i];
                }
                p += 4;
            }
            while p < pend {
                let w = alpha * bcol[p];
                if w != 0.0 {
                    let acol = &a_data[p * m..(p + 1) * m];
                    for i in 0..m {
                        ccol[i] += w * acol[i];
                    }
                }
                p += 1;
            }
            p0 = pend;
        }
    }
}

/// Symmetric rank-k update: `C = alpha * op(A) * op(A)ᵀ + beta * C`,
/// writing only the `uplo` triangle (the other triangle is mirrored so C
/// stays a full symmetric matrix, which downstream code expects).
pub fn syrk(uplo: Uplo, alpha: f64, a: &Matrix, ta: Trans, beta: f64, c: &mut Matrix) {
    let (n, _k) = dims(a, ta);
    assert_eq!((c.rows(), c.cols()), (n, n));
    // Compute the full product (simple, correct); then symmetrize from the
    // requested triangle to keep exact symmetry.
    let mut full = Matrix::zeros(n, n);
    match ta {
        Trans::No => gemm(alpha, a, Trans::No, a, Trans::Yes, 0.0, &mut full),
        Trans::Yes => gemm(alpha, a, Trans::Yes, a, Trans::No, 0.0, &mut full),
    }
    if beta == 0.0 {
        c.as_mut_slice().fill(0.0);
    } else if beta != 1.0 {
        c.scale(beta);
    }
    match uplo {
        Uplo::Lower => {
            for j in 0..n {
                for i in j..n {
                    let v = c[(i, j)] + full[(i, j)];
                    c[(i, j)] = v;
                    c[(j, i)] = v;
                }
            }
        }
        Uplo::Upper => {
            for j in 0..n {
                for i in 0..=j {
                    let v = c[(i, j)] + full[(i, j)];
                    c[(i, j)] = v;
                    c[(j, i)] = v;
                }
            }
        }
    }
}

/// `y = alpha * op(A) * x + beta * y`.
pub fn gemv(alpha: f64, a: &Matrix, ta: Trans, x: &[f64], beta: f64, y: &mut [f64]) {
    let (m, k) = dims(a, ta);
    assert_eq!(x.len(), k, "gemv x len");
    assert_eq!(y.len(), m, "gemv y len");
    if beta == 0.0 {
        y.fill(0.0);
    } else if beta != 1.0 {
        for v in y.iter_mut() {
            *v *= beta;
        }
    }
    match ta {
        Trans::No => {
            for p in 0..k {
                let w = alpha * x[p];
                if w == 0.0 {
                    continue;
                }
                let acol = a.col(p);
                for i in 0..m {
                    y[i] += w * acol[i];
                }
            }
        }
        Trans::Yes => {
            for i in 0..m {
                // row i of Aᵀ = column i of A
                let acol = a.col(i);
                let mut dot = 0.0;
                for p in 0..k {
                    dot += acol[p] * x[p];
                }
                y[i] += alpha * dot;
            }
        }
    }
}

/// Triangular solve with multiple right-hand sides:
/// `Side::Left`:  solve `op(A) X = alpha B` (X overwrites B),
/// `Side::Right`: solve `X op(A) = alpha B`.
///
/// `A` is triangular per `uplo`; unit diagonal is not supported (the ULV
/// factorization always produces non-unit Cholesky factors).
pub fn trsm(side: Side, uplo: Uplo, ta: Trans, alpha: f64, a: &Matrix, b: &mut Matrix) {
    assert_eq!(a.rows(), a.cols(), "trsm: A must be square");
    let n = a.rows();
    match side {
        Side::Left => assert_eq!(b.rows(), n, "trsm left dim"),
        Side::Right => assert_eq!(b.cols(), n, "trsm right dim"),
    }
    if alpha != 1.0 {
        b.scale(alpha);
    }
    // Effective triangle after transpose.
    let eff_lower = match (uplo, ta) {
        (Uplo::Lower, Trans::No) | (Uplo::Upper, Trans::Yes) => true,
        (Uplo::Upper, Trans::No) | (Uplo::Lower, Trans::Yes) => false,
    };
    let at = |i: usize, j: usize| -> f64 {
        match ta {
            Trans::No => a[(i, j)],
            Trans::Yes => a[(j, i)],
        }
    };
    match side {
        Side::Left => {
            // Solve T X = B column by column.
            for jcol in 0..b.cols() {
                if eff_lower {
                    for i in 0..n {
                        let mut s = b[(i, jcol)];
                        for p in 0..i {
                            s -= at(i, p) * b[(p, jcol)];
                        }
                        b[(i, jcol)] = s / at(i, i);
                    }
                } else {
                    for i in (0..n).rev() {
                        let mut s = b[(i, jcol)];
                        for p in i + 1..n {
                            s -= at(i, p) * b[(p, jcol)];
                        }
                        b[(i, jcol)] = s / at(i, i);
                    }
                }
            }
        }
        Side::Right => {
            // Solve X T = B row by row: X[:, j] determined column-wise.
            // X T = B  =>  for lower T: process columns left..right?
            // X[:,j] * T[j,j] + sum_{p!=j} X[:,p] T[p,j] = B[:,j].
            // For lower-triangular T (T[p,j] != 0 for p >= j): column j of B
            // depends on X columns p >= j → iterate j from n-1 down to 0.
            let m = b.rows();
            if eff_lower {
                for j in (0..n).rev() {
                    let d = at(j, j);
                    for r in 0..m {
                        b[(r, j)] /= d;
                    }
                    for p in 0..j {
                        let w = at(j, p);
                        if w == 0.0 {
                            continue;
                        }
                        for r in 0..m {
                            let xj = b[(r, j)];
                            b[(r, p)] -= xj * w;
                        }
                    }
                }
            } else {
                for j in 0..n {
                    let d = at(j, j);
                    for r in 0..m {
                        b[(r, j)] /= d;
                    }
                    for p in j + 1..n {
                        let w = at(j, p);
                        if w == 0.0 {
                            continue;
                        }
                        for r in 0..m {
                            let xj = b[(r, j)];
                            b[(r, p)] -= xj * w;
                        }
                    }
                }
            }
        }
    }
}

/// Triangular solve with a single vector: `op(A) x = b` in place.
pub fn trsv(uplo: Uplo, ta: Trans, a: &Matrix, x: &mut [f64]) {
    let n = a.rows();
    assert_eq!(x.len(), n);
    let mut b = Matrix::from_col_major(n, 1, x.to_vec());
    trsm(Side::Left, uplo, ta, 1.0, a, &mut b);
    x.copy_from_slice(b.col(0));
}

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// `y += alpha * x` on raw vectors.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::frob;
    use crate::util::Rng;

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_all_transposes() {
        let mut rng = Rng::new(42);
        for &(m, n, k) in &[(3, 4, 5), (8, 8, 8), (17, 3, 29), (1, 7, 1)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let want = naive_gemm(&a, &b);

            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &a, Trans::No, &b, Trans::No, 0.0, &mut c);
            assert!(frob(&c.clone().transpose()) > 0.0);
            c.axpy(-1.0, &want);
            assert!(frob(&c) < 1e-12 * (1.0 + frob(&want)));

            let at = a.transpose();
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &at, Trans::Yes, &b, Trans::No, 0.0, &mut c);
            c.axpy(-1.0, &want);
            assert!(frob(&c) < 1e-12 * (1.0 + frob(&want)));

            let bt = b.transpose();
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &a, Trans::No, &bt, Trans::Yes, 0.0, &mut c);
            c.axpy(-1.0, &want);
            assert!(frob(&c) < 1e-12 * (1.0 + frob(&want)));

            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &at, Trans::Yes, &bt, Trans::Yes, 0.0, &mut c);
            c.axpy(-1.0, &want);
            assert!(frob(&c) < 1e-12 * (1.0 + frob(&want)));
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(4, 4, &mut rng);
        let b = Matrix::randn(4, 4, &mut rng);
        let c0 = Matrix::randn(4, 4, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, Trans::No, &b, Trans::No, 3.0, &mut c);
        let want = {
            let mut w = naive_gemm(&a, &b);
            w.scale(2.0);
            w.axpy(3.0, &c0);
            w
        };
        let mut d = c;
        d.axpy(-1.0, &want);
        assert!(frob(&d) < 1e-12 * frob(&want));
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut rng = Rng::new(9);
        let a = Matrix::randn(6, 4, &mut rng);
        let mut c = Matrix::zeros(6, 6);
        syrk(Uplo::Lower, 1.0, &a, Trans::No, 0.0, &mut c);
        let want = naive_gemm(&a, &a.transpose());
        let mut d = c;
        d.axpy(-1.0, &want);
        assert!(frob(&d) < 1e-12 * frob(&want));
    }

    #[test]
    fn gemv_both_transposes() {
        let mut rng = Rng::new(11);
        let a = Matrix::randn(5, 3, &mut rng);
        let x = vec![1.0, -2.0, 0.5];
        let mut y = vec![0.0; 5];
        gemv(1.0, &a, Trans::No, &x, 0.0, &mut y);
        for i in 0..5 {
            let want: f64 = (0..3).map(|p| a[(i, p)] * x[p]).sum();
            assert!((y[i] - want).abs() < 1e-13);
        }
        let x2 = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut y2 = vec![0.0; 3];
        gemv(1.0, &a, Trans::Yes, &x2, 0.0, &mut y2);
        for j in 0..3 {
            let want: f64 = (0..5).map(|i| a[(i, j)] * x2[i]).sum();
            assert!((y2[j] - want).abs() < 1e-13);
        }
    }

    /// Build a well-conditioned lower-triangular matrix.
    fn rand_lower(n: usize, rng: &mut Rng) -> Matrix {
        let mut l = Matrix::randn(n, n, rng);
        for j in 0..n {
            for i in 0..j {
                l[(i, j)] = 0.0;
            }
            l[(j, j)] = 2.0 + l[(j, j)].abs();
        }
        l
    }

    #[test]
    fn trsm_left_lower_roundtrip() {
        let mut rng = Rng::new(13);
        let l = rand_lower(6, &mut rng);
        let x0 = Matrix::randn(6, 3, &mut rng);
        let mut b = Matrix::zeros(6, 3);
        gemm(1.0, &l, Trans::No, &x0, Trans::No, 0.0, &mut b);
        trsm(Side::Left, Uplo::Lower, Trans::No, 1.0, &l, &mut b);
        b.axpy(-1.0, &x0);
        assert!(frob(&b) < 1e-10);
    }

    #[test]
    fn trsm_left_lower_trans_roundtrip() {
        let mut rng = Rng::new(14);
        let l = rand_lower(6, &mut rng);
        let x0 = Matrix::randn(6, 3, &mut rng);
        let mut b = Matrix::zeros(6, 3);
        gemm(1.0, &l, Trans::Yes, &x0, Trans::No, 0.0, &mut b);
        trsm(Side::Left, Uplo::Lower, Trans::Yes, 1.0, &l, &mut b);
        b.axpy(-1.0, &x0);
        assert!(frob(&b) < 1e-10);
    }

    #[test]
    fn trsm_right_lower_trans_roundtrip() {
        // The ULV factorization's main TRSM: L_ij = A_ij * L_jj^{-T}
        // i.e. solve X * L^T = A  (right side, lower, transposed).
        let mut rng = Rng::new(15);
        let l = rand_lower(5, &mut rng);
        let x0 = Matrix::randn(7, 5, &mut rng);
        let mut b = Matrix::zeros(7, 5);
        gemm(1.0, &x0, Trans::No, &l, Trans::Yes, 0.0, &mut b);
        trsm(Side::Right, Uplo::Lower, Trans::Yes, 1.0, &l, &mut b);
        b.axpy(-1.0, &x0);
        assert!(frob(&b) < 1e-10);
    }

    #[test]
    fn trsm_right_upper_roundtrip() {
        let mut rng = Rng::new(16);
        let u = rand_lower(5, &mut rng).transpose();
        let x0 = Matrix::randn(4, 5, &mut rng);
        let mut b = Matrix::zeros(4, 5);
        gemm(1.0, &x0, Trans::No, &u, Trans::No, 0.0, &mut b);
        trsm(Side::Right, Uplo::Upper, Trans::No, 1.0, &u, &mut b);
        b.axpy(-1.0, &x0);
        assert!(frob(&b) < 1e-10);
    }

    #[test]
    fn trsv_matches_trsm() {
        let mut rng = Rng::new(17);
        let l = rand_lower(8, &mut rng);
        let x0: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; 8];
        gemv(1.0, &l, Trans::No, &x0, 0.0, &mut b);
        trsv(Uplo::Lower, Trans::No, &l, &mut b);
        for i in 0..8 {
            assert!((b[i] - x0[i]).abs() < 1e-10);
        }
    }
}
