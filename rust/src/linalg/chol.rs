//! Cholesky factorization (POTRF) and solves.
//!
//! The paper's ULV factorization uses an internal block-Cholesky
//! (Algorithm 2 line 9: `L(r)_ii, L(r)_iiᵀ ← cholesky(A_ii^RR)`), assuming
//! the kernel matrix is SPD thanks to the large diagonal (eqs 35-36).

use super::blas::{self, Side, Uplo};
use super::matrix::{Matrix, Trans};

/// Error type for factorization failures.
#[derive(Debug, Clone, PartialEq)]
pub enum FactorError {
    /// Pivot at index `i` was non-positive (matrix not SPD).
    NotSpd { index: usize, pivot: f64 },
    /// Zero pivot encountered in LU.
    Singular { index: usize },
}

impl std::fmt::Display for FactorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FactorError::NotSpd { index, pivot } => {
                write!(f, "matrix not SPD: pivot {pivot:.3e} at index {index}")
            }
            FactorError::Singular { index } => write!(f, "singular matrix at index {index}"),
        }
    }
}

impl std::error::Error for FactorError {}

/// In-place lower Cholesky: overwrite the lower triangle of `a` with L such
/// that `A = L Lᵀ`; the strict upper triangle is zeroed.
///
/// Blocked right-looking variant: factor a diagonal panel, TRSM the panel
/// below it, SYRK-update the trailing block. Block size 64 keeps panels in
/// cache and routes most FLOPs through `gemm`.
pub fn potrf(a: &mut Matrix) -> Result<(), FactorError> {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    const NB: usize = 64;
    let mut k = 0;
    while k < n {
        let nb = NB.min(n - k);
        // Factor diagonal block A[k..k+nb, k..k+nb] unblocked.
        for j in k..k + nb {
            let mut d = a[(j, j)];
            for p in k..j {
                let v = a[(j, p)];
                d -= v * v;
            }
            if d <= 0.0 {
                return Err(FactorError::NotSpd { index: j, pivot: d });
            }
            let dj = d.sqrt();
            a[(j, j)] = dj;
            for i in j + 1..k + nb {
                let mut s = a[(i, j)];
                for p in k..j {
                    s -= a[(i, p)] * a[(j, p)];
                }
                a[(i, j)] = s / dj;
            }
        }
        let rest = n - k - nb;
        if rest > 0 {
            // Panel solve: A[k+nb.., k..k+nb] = A21 * L11^{-T}
            let l11 = a.submatrix(k, k, nb, nb);
            let mut a21 = a.submatrix(k + nb, k, rest, nb);
            blas::trsm(Side::Right, Uplo::Lower, Trans::Yes, 1.0, &l11, &mut a21);
            a.set_submatrix(k + nb, k, &a21);
            // Trailing update: A22 -= A21 * A21ᵀ.
            let mut a22 = a.submatrix(k + nb, k + nb, rest, rest);
            blas::gemm(-1.0, &a21, Trans::No, &a21, Trans::Yes, 1.0, &mut a22);
            a.set_submatrix(k + nb, k + nb, &a22);
        }
        k += nb;
    }
    // Zero strict upper triangle so the result is exactly L.
    for j in 0..n {
        for i in 0..j {
            a[(i, j)] = 0.0;
        }
    }
    Ok(())
}

/// Convenience: return L with `A = L Lᵀ` (A unchanged).
pub fn cholesky(a: &Matrix) -> Result<Matrix, FactorError> {
    let mut l = a.clone();
    potrf(&mut l)?;
    Ok(l)
}

/// Solve `A x = b` given the Cholesky factor L (`A = L Lᵀ`), in place.
pub fn potrs(l: &Matrix, b: &mut [f64]) {
    blas::trsv(Uplo::Lower, Trans::No, l, b);
    blas::trsv(Uplo::Lower, Trans::Yes, l, b);
}

/// Solve `A X = B` for a matrix RHS given the Cholesky factor L.
pub fn potrs_mat(l: &Matrix, b: &mut Matrix) {
    blas::trsm(Side::Left, Uplo::Lower, Trans::No, 1.0, l, b);
    blas::trsm(Side::Left, Uplo::Lower, Trans::Yes, 1.0, l, b);
}

/// Explicit SPD inverse via Cholesky (used in construction where A_cc⁻¹ is
/// applied to sampled near-field blocks; sizes are O(leaf), so this is fine).
pub fn spd_inverse(a: &Matrix) -> Result<Matrix, FactorError> {
    let l = cholesky(a)?;
    let mut inv = Matrix::eye(a.rows());
    potrs_mat(&l, &mut inv);
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::frob;
    use crate::util::Rng;

    #[test]
    fn potrf_reconstructs() {
        let mut rng = Rng::new(21);
        for &n in &[1usize, 2, 5, 16, 64, 100, 130] {
            let a = Matrix::rand_spd(n, &mut rng);
            let l = cholesky(&a).unwrap();
            // strict upper must be zero
            for j in 0..n {
                for i in 0..j {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
            let mut rec = Matrix::zeros(n, n);
            blas::gemm(1.0, &l, Trans::No, &l, Trans::Yes, 0.0, &mut rec);
            rec.axpy(-1.0, &a);
            assert!(
                frob(&rec) < 1e-10 * frob(&a),
                "n={n} err={}",
                frob(&rec) / frob(&a)
            );
        }
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(matches!(cholesky(&a), Err(FactorError::NotSpd { .. })));
    }

    #[test]
    fn potrs_solves() {
        let mut rng = Rng::new(23);
        let n = 40;
        let a = Matrix::rand_spd(n, &mut rng);
        let l = cholesky(&a).unwrap();
        let x0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; n];
        blas::gemv(1.0, &a, Trans::No, &x0, 0.0, &mut b);
        potrs(&l, &mut b);
        let err: f64 = b.iter().zip(&x0).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(err < 1e-8, "err={err}");
    }

    #[test]
    fn spd_inverse_identity() {
        let mut rng = Rng::new(25);
        let n = 24;
        let a = Matrix::rand_spd(n, &mut rng);
        let inv = spd_inverse(&a).unwrap();
        let mut prod = Matrix::zeros(n, n);
        blas::gemm(1.0, &a, Trans::No, &inv, Trans::No, 0.0, &mut prod);
        prod.axpy(-1.0, &Matrix::eye(n));
        assert!(frob(&prod) < 1e-9);
    }
}
