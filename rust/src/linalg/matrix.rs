//! Column-major dense matrix.

use crate::util::Rng;
use std::fmt;

/// Dense `f64` matrix, column-major storage (LAPACK convention):
/// element `(i, j)` lives at `data[i + j * rows]`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for j in 0..cols {
            for i in 0..rows {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Build from a column-major buffer.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Build from row-major data (convenience for literals in tests).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        Matrix::from_fn(r, c, |i, j| rows[i][j])
    }

    /// Standard-normal random matrix.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    /// Random symmetric positive-definite matrix `A = G Gᵀ + n·I`.
    pub fn rand_spd(n: usize, rng: &mut Rng) -> Self {
        let g = Matrix::randn(n, n, rng);
        let mut a = Matrix::zeros(n, n);
        crate::linalg::blas::gemm(1.0, &g, Trans::No, &g, Trans::Yes, 0.0, &mut a);
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0 || self.cols == 0
    }

    /// Borrow raw column-major storage.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw column-major storage.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Column `j` as a slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Extract sub-matrix `rows x cols` starting at `(r0, c0)`.
    pub fn submatrix(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Matrix {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols);
        Matrix::from_fn(rows, cols, |i, j| self[(r0 + i, c0 + j)])
    }

    /// Write `block` into `self` at offset `(r0, c0)`.
    pub fn set_submatrix(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for j in 0..block.cols {
            for i in 0..block.rows {
                self[(r0 + i, c0 + j)] = block[(i, j)];
            }
        }
    }

    /// Add `alpha * block` into `self` at offset `(r0, c0)`.
    pub fn add_submatrix(&mut self, r0: usize, c0: usize, alpha: f64, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for j in 0..block.cols {
            for i in 0..block.rows {
                self[(r0 + i, c0 + j)] += alpha * block[(i, j)];
            }
        }
    }

    /// Gather selected rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        Matrix::from_fn(idx.len(), self.cols, |i, j| self[(idx[i], j)])
    }

    /// Gather selected columns into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        Matrix::from_fn(self.rows, idx.len(), |i, j| self[(i, idx[j])])
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "hcat row mismatch");
        let mut data = Vec::with_capacity(self.data.len() + other.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows, cols: self.cols + other.cols, data }
    }

    /// Vertical concatenation `[self; other]`.
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "vcat col mismatch");
        Matrix::from_fn(self.rows + other.rows, self.cols, |i, j| {
            if i < self.rows {
                self[(i, j)]
            } else {
                other[(i - self.rows, j)]
            }
        })
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f64) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    /// `self += alpha * other` (same shape).
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(other.data.iter()) {
            *x += alpha * y;
        }
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }

    /// Zero-pad (or truncate) to shape `(rows, cols)`, keeping the top-left.
    pub fn resized(&self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |i, j| {
            if i < self.rows && j < self.cols {
                self[(i, j)]
            } else {
                0.0
            }
        })
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {}x{}", self.rows, self.cols);
        &self.data[i + j * self.rows]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i + j * self.rows]
    }
}

/// Transpose flag for BLAS-style calls.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Trans {
    No,
    Yes,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(8);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>11.4e} ", self[(i, j)])?;
            }
            if cmax < self.cols {
                write!(f, "...")?;
            }
            writeln!(f)?;
        }
        if rmax < self.rows {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

pub use Trans::{No as NoTrans, Yes as DoTrans};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_col_major() {
        let m = Matrix::from_col_major(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.);
        assert_eq!(m[(1, 0)], 2.);
        assert_eq!(m[(0, 1)], 3.);
        assert_eq!(m[(1, 2)], 6.);
    }

    #[test]
    fn from_rows_matches() {
        let m = Matrix::from_rows(&[&[1., 2.], &[3., 4.]]);
        assert_eq!(m[(0, 1)], 2.);
        assert_eq!(m[(1, 0)], 3.);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 3, &mut rng);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        for i in 0..5 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], t[(j, i)]);
            }
        }
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn submatrix_and_set() {
        let m = Matrix::from_fn(6, 6, |i, j| (10 * i + j) as f64);
        let s = m.submatrix(1, 2, 3, 2);
        assert_eq!(s[(0, 0)], 12.);
        assert_eq!(s[(2, 1)], 33.);
        let mut z = Matrix::zeros(6, 6);
        z.set_submatrix(1, 2, &s);
        assert_eq!(z[(1, 2)], 12.);
        assert_eq!(z[(3, 3)], 33.);
        assert_eq!(z[(0, 0)], 0.);
    }

    #[test]
    fn cat_shapes() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::eye(2);
        let h = a.hcat(&b);
        assert_eq!((h.rows(), h.cols()), (2, 5));
        assert_eq!(h[(1, 4)], 1.0);
        let c = Matrix::zeros(4, 3);
        let v = a.vcat(&c);
        assert_eq!((v.rows(), v.cols()), (6, 3));
    }

    #[test]
    fn select_rows_cols() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let r = m.select_rows(&[3, 0]);
        assert_eq!(r[(0, 0)], 12.);
        assert_eq!(r[(1, 3)], 3.);
        let c = m.select_cols(&[2]);
        assert_eq!(c[(1, 0)], 6.);
    }

    #[test]
    fn resized_pads_with_zeros() {
        let m = Matrix::eye(2);
        let p = m.resized(3, 4);
        assert_eq!(p[(0, 0)], 1.);
        assert_eq!(p[(2, 3)], 0.);
        let t = p.resized(1, 1);
        assert_eq!(t[(0, 0)], 1.);
    }

    #[test]
    fn spd_is_symmetric() {
        let mut rng = Rng::new(2);
        let a = Matrix::rand_spd(8, &mut rng);
        for i in 0..8 {
            for j in 0..8 {
                assert!((a[(i, j)] - a[(j, i)]).abs() < 1e-12);
            }
        }
    }
}
