//! One-sided Jacobi SVD.
//!
//! Used by the rank/accuracy studies (paper Figure 18) to measure the true
//! numerical rank of Schur-complement updates, and as an alternative
//! truncation for the low-rank basis. Sizes are O(leaf) so the O(n³) Jacobi
//! sweep cost is acceptable and its accuracy is excellent.

use super::blas;
use super::matrix::Matrix;

/// Result of an SVD: `A = U diag(s) Vᵀ`.
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f64>,
    pub v: Matrix,
}

/// One-sided Jacobi SVD (on columns). Converges when all column pairs are
/// numerically orthogonal.
pub fn svd(a: &Matrix) -> Svd {
    let m = a.rows();
    let n = a.cols();
    if m < n {
        // Work on the transpose and swap U/V.
        let t = svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let mut u = a.clone();
    let mut v = Matrix::eye(n);
    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let up = u.col(p);
                let uq = u.col(q);
                let alpha = blas::dot(up, up);
                let beta = blas::dot(uq, uq);
                let gamma = blas::dot(up, uq);
                if alpha * beta > 0.0 {
                    off = off.max(gamma.abs() / (alpha * beta).sqrt());
                }
                if gamma.abs() <= eps * (alpha * beta).sqrt() || gamma == 0.0 {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) entry of AᵀA.
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let a_ip = u[(i, p)];
                    let a_iq = u[(i, q)];
                    u[(i, p)] = c * a_ip - s * a_iq;
                    u[(i, q)] = s * a_ip + c * a_iq;
                }
                for i in 0..n {
                    let v_ip = v[(i, p)];
                    let v_iq = v[(i, q)];
                    v[(i, p)] = c * v_ip - s * v_iq;
                    v[(i, q)] = s * v_ip + c * v_iq;
                }
            }
        }
        if off < 1e-13 {
            break;
        }
    }
    // Extract singular values and normalize U columns.
    let mut s: Vec<f64> = (0..n).map(|j| blas::dot(u.col(j), u.col(j)).sqrt()).collect();
    for j in 0..n {
        if s[j] > 0.0 {
            let inv = 1.0 / s[j];
            for x in u.col_mut(j) {
                *x *= inv;
            }
        }
    }
    // Sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| s[j].partial_cmp(&s[i]).unwrap());
    let u_sorted = u.select_cols(&order);
    let v_sorted = v.select_cols(&order);
    s = order.iter().map(|&i| s[i]).collect();
    Svd { u: u_sorted, s, v: v_sorted }
}

/// Numerical rank at relative tolerance `rtol` (w.r.t. the largest singular
/// value).
pub fn numerical_rank(a: &Matrix, rtol: f64) -> usize {
    let d = svd(a);
    if d.s.is_empty() || d.s[0] == 0.0 {
        return 0;
    }
    d.s.iter().filter(|&&x| x > rtol * d.s[0]).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Trans;
    use crate::linalg::norms::frob;
    use crate::util::Rng;

    fn reconstruct(d: &Svd) -> Matrix {
        let m = d.u.rows();
        let n = d.v.rows();
        let k = d.s.len();
        let mut us = d.u.clone();
        for j in 0..k {
            for x in us.col_mut(j) {
                *x *= d.s[j];
            }
        }
        let mut rec = Matrix::zeros(m, n);
        blas::gemm(1.0, &us, Trans::No, &d.v, Trans::Yes, 0.0, &mut rec);
        rec
    }

    #[test]
    fn svd_reconstructs_tall_and_wide() {
        let mut rng = Rng::new(61);
        for &(m, n) in &[(8, 5), (5, 8), (6, 6)] {
            let a = Matrix::randn(m, n, &mut rng);
            let d = svd(&a);
            let mut rec = reconstruct(&d);
            rec.axpy(-1.0, &a);
            assert!(frob(&rec) < 1e-11 * frob(&a), "({m},{n}) err={}", frob(&rec));
            // Singular values sorted descending and non-negative.
            for w in d.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            assert!(d.s.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn svd_known_values() {
        // diag(3, 2) embedded.
        let a = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, -2.0], &[0.0, 0.0]]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-12);
        assert!((d.s[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn numerical_rank_detects() {
        let mut rng = Rng::new(63);
        let b = Matrix::randn(10, 3, &mut rng);
        let c = Matrix::randn(3, 10, &mut rng);
        let mut a = Matrix::zeros(10, 10);
        blas::gemm(1.0, &b, Trans::No, &c, Trans::No, 0.0, &mut a);
        assert_eq!(numerical_rank(&a, 1e-10), 3);
    }
}
