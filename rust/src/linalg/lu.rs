//! Partially pivoted LU (GETRF/GETRS). Used by the dense baseline solver
//! and by general (non-SPD) verification paths.

use super::chol::FactorError;
use super::matrix::Matrix;

/// LU factorization with partial pivoting: `P A = L U`, packed in place
/// (unit lower L below the diagonal, U on/above it).
pub struct LuFactor {
    /// Packed L\U factors.
    pub lu: Matrix,
    /// Pivot row swapped with row `i` at step `i`.
    pub piv: Vec<usize>,
}

/// Factor `a` (copied) with partial pivoting.
pub fn getrf(a: &Matrix) -> Result<LuFactor, FactorError> {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut lu = a.clone();
    let mut piv = vec![0usize; n];
    for k in 0..n {
        // Pivot search in column k.
        let mut p = k;
        let mut best = lu[(k, k)].abs();
        for i in k + 1..n {
            let v = lu[(i, k)].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        piv[k] = p;
        if best == 0.0 {
            return Err(FactorError::Singular { index: k });
        }
        if p != k {
            for j in 0..n {
                let t = lu[(k, j)];
                lu[(k, j)] = lu[(p, j)];
                lu[(p, j)] = t;
            }
        }
        let dk = lu[(k, k)];
        for i in k + 1..n {
            lu[(i, k)] /= dk;
        }
        // Rank-1 trailing update, column-wise for cache friendliness.
        for j in k + 1..n {
            let ukj = lu[(k, j)];
            if ukj == 0.0 {
                continue;
            }
            for i in k + 1..n {
                let lik = lu[(i, k)];
                lu[(i, j)] -= lik * ukj;
            }
        }
    }
    Ok(LuFactor { lu, piv })
}

/// Solve `A x = b` using factors from [`getrf`], in place.
pub fn getrs(f: &LuFactor, b: &mut [f64]) {
    let n = f.lu.rows();
    assert_eq!(b.len(), n);
    // Apply permutation.
    for k in 0..n {
        let p = f.piv[k];
        if p != k {
            b.swap(k, p);
        }
    }
    // Forward: L y = Pb (unit diagonal).
    for i in 0..n {
        let mut s = b[i];
        for p in 0..i {
            s -= f.lu[(i, p)] * b[p];
        }
        b[i] = s;
    }
    // Backward: U x = y.
    for i in (0..n).rev() {
        let mut s = b[i];
        for p in i + 1..n {
            s -= f.lu[(i, p)] * b[p];
        }
        b[i] = s / f.lu[(i, i)];
    }
}

/// One-shot dense solve (baseline path).
pub fn solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>, FactorError> {
    let f = getrf(a)?;
    let mut x = b.to_vec();
    getrs(&f, &mut x);
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::matrix::Trans;
    use crate::util::Rng;

    #[test]
    fn lu_solve_random() {
        let mut rng = Rng::new(31);
        for &n in &[1usize, 3, 10, 50] {
            let mut a = Matrix::randn(n, n, &mut rng);
            for i in 0..n {
                a[(i, i)] += 4.0; // keep well-conditioned
            }
            let x0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut b = vec![0.0; n];
            blas::gemv(1.0, &a, Trans::No, &x0, 0.0, &mut b);
            let x = solve(&a, &b).unwrap();
            let err = x.iter().zip(&x0).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
            assert!(err < 1e-9, "n={n} err={err}");
        }
    }

    #[test]
    fn lu_needs_pivoting() {
        // Zero leading pivot forces a swap.
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = solve(&a, &[2.0, 3.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-14);
        assert!((x[1] - 2.0).abs() < 1e-14);
    }

    #[test]
    fn lu_detects_singular() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(solve(&a, &[1.0, 1.0]).is_err());
    }
}
