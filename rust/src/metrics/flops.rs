//! FLOP counters with named phases: per-session [`FlopScope`] handles plus
//! deprecated process-global totals.
//!
//! The paper reports FLOP *counts* (Fig 15), FLOP *rates* (Fig 14) and the
//! pre-factorization vs factorization *split* (Fig 17). Counters are
//! thread-safe atomics so batched parallel kernels can report from any
//! worker.
//!
//! **Scoping.** The free functions ([`add`], [`snapshot`], …) feed
//! process-global statics — concurrent solver sessions cross-contaminate
//! them, so they are kept only as a deprecated process-wide sum for
//! single-session harnesses (the figure scripts). Session-accurate
//! accounting uses a [`FlopScope`]: the plan executor credits each
//! program's statically-known FLOP total to the scope threaded through it,
//! so `BuildStats::factor_flops` is correct even with concurrent sessions.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static TOTAL: AtomicU64 = AtomicU64::new(0);

// Named phase counters (paper phases).
static CONSTRUCT: AtomicU64 = AtomicU64::new(0);
static PREFACTOR: AtomicU64 = AtomicU64::new(0);
static FACTOR: AtomicU64 = AtomicU64::new(0);
static SUBSTITUTE: AtomicU64 = AtomicU64::new(0);

/// Which phase subsequent [`add`] calls are attributed to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Phase {
    Construct,
    /// Pre-factorization: the `A_Close · A_cc⁻¹` work (paper §3.5, Fig 17).
    Prefactor,
    Factor,
    Substitute,
}

// Global (not thread-local): batched kernels run on pool workers that must
// inherit the coordinator's phase attribution. Within one single-threaded
// harness phases never overlap in time, so a relaxed global is correct for
// that (deprecated) accounting. Concurrent solves on one session — or
// concurrent sessions — DO overlap: their set/restore pairs interleave, so
// the global phase *split* is unreliable exactly where the global *totals*
// already were. This is accepted: the globals exist only for the
// single-session figure scripts; session-accurate numbers come from
// [`FlopScope`], which has no phase global at all.
static CURRENT_PHASE: AtomicU64 = AtomicU64::new(0);

fn phase_to_u64(p: Phase) -> u64 {
    match p {
        Phase::Construct => 0,
        Phase::Prefactor => 1,
        Phase::Factor => 2,
        Phase::Substitute => 3,
    }
}

fn phase_from_u64(v: u64) -> Phase {
    match v {
        1 => Phase::Prefactor,
        2 => Phase::Factor,
        3 => Phase::Substitute,
        _ => Phase::Construct,
    }
}

/// Set the global phase; returns the previous phase.
pub fn set_phase(p: Phase) -> Phase {
    phase_from_u64(CURRENT_PHASE.swap(phase_to_u64(p), Ordering::Relaxed))
}

/// Run `f` with the given phase attribution.
pub fn with_phase<T>(p: Phase, f: impl FnOnce() -> T) -> T {
    let old = set_phase(p);
    let out = f();
    set_phase(old);
    out
}

/// Record `n` floating-point operations in the current phase.
#[inline]
pub fn add(n: u64) {
    TOTAL.fetch_add(n, Ordering::Relaxed);
    let phase = phase_from_u64(CURRENT_PHASE.load(Ordering::Relaxed));
    match phase {
        Phase::Construct => CONSTRUCT.fetch_add(n, Ordering::Relaxed),
        Phase::Prefactor => PREFACTOR.fetch_add(n, Ordering::Relaxed),
        Phase::Factor => FACTOR.fetch_add(n, Ordering::Relaxed),
        Phase::Substitute => SUBSTITUTE.fetch_add(n, Ordering::Relaxed),
    };
}

/// FLOPs for a GEMM of shape m x n x k.
#[inline]
pub fn gemm_flops(m: usize, n: usize, k: usize) -> u64 {
    2 * m as u64 * n as u64 * k as u64
}

/// FLOPs for a Cholesky of size n.
#[inline]
pub fn potrf_flops(n: usize) -> u64 {
    (n as u64 * n as u64 * n as u64) / 3
}

/// FLOPs for a TRSM with triangle n and rhs m columns (right side: m rows).
#[inline]
pub fn trsm_flops(n: usize, m: usize) -> u64 {
    n as u64 * n as u64 * m as u64
}

/// Snapshot of all counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    pub total: u64,
    pub construct: u64,
    pub prefactor: u64,
    pub factor: u64,
    pub substitute: u64,
}

/// Read the counters.
pub fn snapshot() -> Counts {
    Counts {
        total: TOTAL.load(Ordering::Relaxed),
        construct: CONSTRUCT.load(Ordering::Relaxed),
        prefactor: PREFACTOR.load(Ordering::Relaxed),
        factor: FACTOR.load(Ordering::Relaxed),
        substitute: SUBSTITUTE.load(Ordering::Relaxed),
    }
}

/// Per-session FLOP counters.
///
/// Cheap to clone (shared atomics); thread the same scope through every
/// executor of one session. Unlike the process-global statics, scopes from
/// different sessions never see each other's work.
#[derive(Clone, Debug, Default)]
pub struct FlopScope {
    inner: Arc<ScopeCounters>,
}

#[derive(Debug, Default)]
struct ScopeCounters {
    construct: AtomicU64,
    prefactor: AtomicU64,
    factor: AtomicU64,
    substitute: AtomicU64,
}

impl FlopScope {
    pub fn new() -> FlopScope {
        FlopScope::default()
    }

    /// Record `n` FLOPs against `phase` in this scope only.
    pub fn add(&self, phase: Phase, n: u64) {
        let c = match phase {
            Phase::Construct => &self.inner.construct,
            Phase::Prefactor => &self.inner.prefactor,
            Phase::Factor => &self.inner.factor,
            Phase::Substitute => &self.inner.substitute,
        };
        c.fetch_add(n, Ordering::Relaxed);
    }

    /// Read this scope's counters.
    pub fn snapshot(&self) -> Counts {
        let construct = self.inner.construct.load(Ordering::Relaxed);
        let prefactor = self.inner.prefactor.load(Ordering::Relaxed);
        let factor = self.inner.factor.load(Ordering::Relaxed);
        let substitute = self.inner.substitute.load(Ordering::Relaxed);
        Counts {
            total: construct + prefactor + factor + substitute,
            construct,
            prefactor,
            factor,
            substitute,
        }
    }
}

/// Difference of two snapshots (b - a).
pub fn delta(a: Counts, b: Counts) -> Counts {
    Counts {
        total: b.total - a.total,
        construct: b.construct - a.construct,
        prefactor: b.prefactor - a.prefactor,
        factor: b.factor - a.factor,
        substitute: b.substitute - a.substitute,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_attribute() {
        let before = snapshot();
        with_phase(Phase::Factor, || add(100));
        with_phase(Phase::Prefactor, || add(40));
        let after = snapshot();
        let d = delta(before, after);
        assert!(d.factor >= 100);
        assert!(d.prefactor >= 40);
        assert!(d.total >= 140);
    }

    #[test]
    fn scopes_are_isolated() {
        let a = FlopScope::new();
        let b = FlopScope::new();
        a.add(Phase::Factor, 100);
        b.add(Phase::Substitute, 7);
        assert_eq!(a.snapshot().factor, 100);
        assert_eq!(a.snapshot().substitute, 0);
        assert_eq!(b.snapshot().substitute, 7);
        assert_eq!(b.snapshot().factor, 0);
        assert_eq!(a.snapshot().total, 100);
        // Clones share counters (one scope per session, threaded around).
        let a2 = a.clone();
        a2.add(Phase::Factor, 1);
        assert_eq!(a.snapshot().factor, 101);
    }

    #[test]
    fn helpers() {
        assert_eq!(gemm_flops(2, 3, 4), 48);
        assert_eq!(potrf_flops(6), 72);
        assert_eq!(trsm_flops(4, 3), 48);
    }
}
