//! FLOP counters with named phases: per-session [`FlopScope`] handles plus
//! an ambient thread-local binding for kernel call sites.
//!
//! The paper reports FLOP *counts* (Fig 15), FLOP *rates* (Fig 14) and the
//! pre-factorization vs factorization *split* (Fig 17). Counters are
//! thread-safe atomics so batched parallel kernels can report from any
//! worker.
//!
//! **Scoping.** All accounting is per-[`FlopScope`]: scopes from different
//! sessions never see each other's work, so `BuildStats::factor_flops` is
//! correct even with concurrent sessions. Kernel call sites stay
//! one-liners ([`add`]) by crediting the thread's *ambient* scope — bound
//! with [`scoped`] around a pipeline stage, propagated to pool workers by
//! [`crate::util::pool::par_for`], and simply a no-op when nothing is
//! bound (the plan executor credits statically-known program totals
//! directly via [`FlopScope::add`] instead). [`with_phase`] re-attributes
//! the ambient scope to a different phase for a sub-stage (e.g. the
//! `A_Close · A_cc⁻¹` pre-factorization work inside construction); without
//! an ambient scope it is a transparent passthrough. There is no
//! process-global counter: unscoped work is intentionally uncounted.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Which phase FLOPs are attributed to (the paper's pipeline stages).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Phase {
    Construct,
    /// Pre-factorization: the `A_Close · A_cc⁻¹` work (paper §3.5, Fig 17).
    Prefactor,
    Factor,
    Substitute,
}

thread_local! {
    /// The scope+phase that [`add`] credits on this thread, if any.
    static AMBIENT: RefCell<Option<(FlopScope, Phase)>> = const { RefCell::new(None) };
}

/// RAII guard restoring the previous ambient binding on drop, so nested
/// [`scoped`]/[`with_phase`] regions and pool workers unwind cleanly.
pub(crate) struct AmbientGuard {
    prev: Option<(FlopScope, Phase)>,
}

impl Drop for AmbientGuard {
    fn drop(&mut self) {
        AMBIENT.with(|a| *a.borrow_mut() = self.prev.take());
    }
}

/// Bind (or clear, with `None`) this thread's ambient scope until the
/// returned guard drops. Used by the pool to mirror the coordinator's
/// binding onto worker threads.
pub(crate) fn bind_ambient(val: Option<(FlopScope, Phase)>) -> AmbientGuard {
    let prev = AMBIENT.with(|a| std::mem::replace(&mut *a.borrow_mut(), val));
    AmbientGuard { prev }
}

/// This thread's current ambient binding (cheap clone: scopes share
/// atomics).
pub(crate) fn ambient() -> Option<(FlopScope, Phase)> {
    AMBIENT.with(|a| a.borrow().clone())
}

/// Run `f` with kernel-level [`add`] calls on this thread (and on pool
/// workers it fans out to) credited to `scope` under `phase`.
pub fn scoped<T>(scope: &FlopScope, phase: Phase, f: impl FnOnce() -> T) -> T {
    let _guard = bind_ambient(Some((scope.clone(), phase)));
    f()
}

/// Re-attribute the ambient scope to `phase` for the duration of `f`.
/// Without an ambient binding this is a transparent passthrough: the work
/// still runs, its FLOPs are simply uncounted.
pub fn with_phase<T>(phase: Phase, f: impl FnOnce() -> T) -> T {
    match ambient() {
        Some((scope, _)) => scoped(&scope, phase, f),
        None => f(),
    }
}

/// Record `n` floating-point operations against the ambient scope, if one
/// is bound; a no-op otherwise. Kernels call this unconditionally — the
/// binding decides whether anyone is listening.
#[inline]
pub fn add(n: u64) {
    AMBIENT.with(|a| {
        if let Some((scope, phase)) = a.borrow().as_ref() {
            scope.add(*phase, n);
        }
    });
}

/// FLOPs for a GEMM of shape m x n x k.
#[inline]
pub fn gemm_flops(m: usize, n: usize, k: usize) -> u64 {
    2 * m as u64 * n as u64 * k as u64
}

/// FLOPs for a Cholesky of size n.
#[inline]
pub fn potrf_flops(n: usize) -> u64 {
    (n as u64 * n as u64 * n as u64) / 3
}

/// FLOPs for a TRSM with triangle n and rhs m columns (right side: m rows).
#[inline]
pub fn trsm_flops(n: usize, m: usize) -> u64 {
    n as u64 * n as u64 * m as u64
}

/// Snapshot of one scope's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    pub total: u64,
    pub construct: u64,
    pub prefactor: u64,
    pub factor: u64,
    pub substitute: u64,
}

/// Per-session FLOP counters.
///
/// Cheap to clone (shared atomics); thread the same scope through every
/// executor of one session. Scopes from different sessions never see each
/// other's work.
#[derive(Clone, Debug, Default)]
pub struct FlopScope {
    inner: Arc<ScopeCounters>,
}

#[derive(Debug, Default)]
struct ScopeCounters {
    construct: AtomicU64,
    prefactor: AtomicU64,
    factor: AtomicU64,
    substitute: AtomicU64,
}

impl FlopScope {
    pub fn new() -> FlopScope {
        FlopScope::default()
    }

    /// Record `n` FLOPs against `phase` in this scope only.
    pub fn add(&self, phase: Phase, n: u64) {
        let c = match phase {
            Phase::Construct => &self.inner.construct,
            Phase::Prefactor => &self.inner.prefactor,
            Phase::Factor => &self.inner.factor,
            Phase::Substitute => &self.inner.substitute,
        };
        c.fetch_add(n, Ordering::Relaxed);
    }

    /// Read this scope's counters.
    pub fn snapshot(&self) -> Counts {
        let construct = self.inner.construct.load(Ordering::Relaxed);
        let prefactor = self.inner.prefactor.load(Ordering::Relaxed);
        let factor = self.inner.factor.load(Ordering::Relaxed);
        let substitute = self.inner.substitute.load(Ordering::Relaxed);
        Counts {
            total: construct + prefactor + factor + substitute,
            construct,
            prefactor,
            factor,
            substitute,
        }
    }
}

/// Difference of two snapshots (b - a).
pub fn delta(a: Counts, b: Counts) -> Counts {
    Counts {
        total: b.total - a.total,
        construct: b.construct - a.construct,
        prefactor: b.prefactor - a.prefactor,
        factor: b.factor - a.factor,
        substitute: b.substitute - a.substitute,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_attribute() {
        let scope = FlopScope::new();
        scoped(&scope, Phase::Construct, || {
            add(5);
            with_phase(Phase::Factor, || add(100));
            with_phase(Phase::Prefactor, || add(40));
            // with_phase restores the outer attribution on exit.
            add(2);
        });
        let c = scope.snapshot();
        assert_eq!(c.construct, 7);
        assert_eq!(c.factor, 100);
        assert_eq!(c.prefactor, 40);
        assert_eq!(c.total, 147);
    }

    #[test]
    fn unbound_adds_are_dropped() {
        // No ambient scope on this thread: add() is a no-op, with_phase a
        // passthrough, and nothing panics.
        add(1_000_000);
        let out = with_phase(Phase::Factor, || {
            add(9);
            7
        });
        assert_eq!(out, 7);
        let scope = FlopScope::new();
        assert_eq!(scope.snapshot().total, 0);
    }

    #[test]
    fn scopes_are_isolated() {
        let a = FlopScope::new();
        let b = FlopScope::new();
        a.add(Phase::Factor, 100);
        b.add(Phase::Substitute, 7);
        assert_eq!(a.snapshot().factor, 100);
        assert_eq!(a.snapshot().substitute, 0);
        assert_eq!(b.snapshot().substitute, 7);
        assert_eq!(b.snapshot().factor, 0);
        assert_eq!(a.snapshot().total, 100);
        // Clones share counters (one scope per session, threaded around).
        let a2 = a.clone();
        a2.add(Phase::Factor, 1);
        assert_eq!(a.snapshot().factor, 101);
    }

    #[test]
    fn ambient_binding_nests_and_restores() {
        let outer = FlopScope::new();
        let inner = FlopScope::new();
        scoped(&outer, Phase::Factor, || {
            add(1);
            scoped(&inner, Phase::Substitute, || add(10));
            add(1);
        });
        assert_eq!(outer.snapshot().factor, 2);
        assert_eq!(inner.snapshot().substitute, 10);
        assert!(ambient().is_none(), "guard must clear the binding");
    }

    #[test]
    fn helpers() {
        assert_eq!(gemm_flops(2, 3, 4), 48);
        assert_eq!(potrf_flops(6), 72);
        assert_eq!(trsm_flops(4, 3), 48);
    }
}
