//! Per-stream busy intervals of an overlapping executor — the evidence
//! that asynchronous scheduling actually happened.
//!
//! The paper's central scheduling claim is that H²-ULV "removes the
//! dependency on trailing sub-matrices", so level *k*'s batched compute
//! can overlap level *k+1*'s uploads. A host-synchronous backend can only
//! *assert* this; an overlapping one must *show* it. Every operation an
//! [`crate::batch::device::AsyncDevice`] worker executes is recorded as an
//! [`OverlapEvent`] (stream, level, kind, wall-clock interval), and the
//! resulting [`OverlapTrace`] answers the two questions the test harness
//! and `BuildStats` care about:
//!
//! * did a host→device transfer genuinely run while another stream was
//!   computing ([`OverlapTrace::overlapped_transfer_pairs`])?
//! * how busy was each stream ([`OverlapTrace::stream_busy`])?
//!
//! Events carry *wall-clock* intervals measured on the worker threads, not
//! issue-order bookkeeping — an empty overlap list on an async device means
//! the schedule degenerated to serial execution, whatever the stream tags
//! claim.

/// What kind of work an overlap event represents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapKind {
    /// Host → device transfer (an `Instr::Upload` item).
    Transfer,
    /// A batched kernel launch (POTRF / TRSM / SYRK / SPARSIFY / ...).
    Compute,
    /// Arena bookkeeping with no data payload (`Free`).
    Housekeeping,
}

/// One executed operation on one stream: `[start, end)` in seconds since
/// the trace epoch (the device's creation instant).
#[derive(Clone, Debug)]
pub struct OverlapEvent {
    /// Stream (worker queue) the operation executed on.
    pub stream: usize,
    /// Tree level active when the operation was issued (`usize::MAX` when
    /// issued before the first `stream(level)` call).
    pub level: usize,
    pub kind: OverlapKind,
    /// Opcode name (`UPLOAD`, `POTRF`, `TRSM`, ...).
    pub opcode: &'static str,
    /// Start offset in seconds since the trace epoch.
    pub start: f64,
    /// End offset in seconds since the trace epoch.
    pub end: f64,
}

impl OverlapEvent {
    /// Wall-clock overlap in seconds between two events (0 if disjoint).
    pub fn overlap_with(&self, other: &OverlapEvent) -> f64 {
        (self.end.min(other.end) - self.start.max(other.start)).max(0.0)
    }
}

/// The recorded per-stream schedule of one (or more) replays on an
/// overlapping device. Drained from the device via
/// [`crate::batch::device::Device::take_overlap_trace`]; carried in
/// [`crate::solver::BuildStats::overlap`] for facade builds.
#[derive(Clone, Debug, Default)]
pub struct OverlapTrace {
    /// Executed operations in completion order.
    pub events: Vec<OverlapEvent>,
}

impl OverlapTrace {
    /// Number of distinct streams that executed at least one operation.
    pub fn streams(&self) -> usize {
        self.events.iter().map(|e| e.stream + 1).max().unwrap_or(0)
    }

    /// Total busy seconds of one stream.
    pub fn stream_busy(&self, stream: usize) -> f64 {
        self.events
            .iter()
            .filter(|e| e.stream == stream)
            .map(|e| e.end - e.start)
            .sum()
    }

    /// `(transfer_level, compute_level)` pairs where a [`Transfer`]
    /// event's wall-clock interval genuinely intersected a [`Compute`]
    /// event running on a *different* stream — the paper's "level k+1
    /// uploads while level k computes", observed rather than asserted.
    /// Pairs are deduplicated; an empty result on an async device means no
    /// overlap occurred.
    ///
    /// [`Transfer`]: OverlapKind::Transfer
    /// [`Compute`]: OverlapKind::Compute
    pub fn overlapped_transfer_pairs(&self) -> Vec<(usize, usize)> {
        let mut pairs = Vec::new();
        for t in self.events.iter().filter(|e| e.kind == OverlapKind::Transfer) {
            for c in self.events.iter().filter(|e| e.kind == OverlapKind::Compute) {
                if t.stream != c.stream && t.overlap_with(c) > 0.0 {
                    let pair = (t.level, c.level);
                    if !pairs.contains(&pair) {
                        pairs.push(pair);
                    }
                }
            }
        }
        pairs
    }

    /// Whether any upload ran concurrently with compute on another stream.
    pub fn has_transfer_compute_overlap(&self) -> bool {
        !self.overlapped_transfer_pairs().is_empty()
    }

    /// `(level_a, level_b)` pairs (`a ≤ b`) where two [`Compute`] events
    /// on *different* streams genuinely intersected in wall-clock time —
    /// the substitution-path evidence: two runs of the serial solve chain
    /// (or two RHS workspaces) computing at once. Deduplicated, like
    /// [`OverlapTrace::overlapped_transfer_pairs`].
    ///
    /// [`Compute`]: OverlapKind::Compute
    pub fn overlapped_compute_pairs(&self) -> Vec<(usize, usize)> {
        let mut pairs = Vec::new();
        let computes: Vec<&OverlapEvent> =
            self.events.iter().filter(|e| e.kind == OverlapKind::Compute).collect();
        for (i, a) in computes.iter().enumerate() {
            for b in &computes[i + 1..] {
                if a.stream != b.stream && a.overlap_with(b) > 0.0 {
                    let pair = (a.level.min(b.level), a.level.max(b.level));
                    if !pairs.contains(&pair) {
                        pairs.push(pair);
                    }
                }
            }
        }
        pairs
    }

    /// Total seconds during which ≥2 streams were simultaneously busy
    /// (any kinds), from an event-boundary sweep.
    pub fn concurrent_busy(&self) -> f64 {
        let mut edges: Vec<(f64, i32)> = Vec::with_capacity(2 * self.events.len());
        for e in &self.events {
            edges.push((e.start, 1));
            edges.push((e.end, -1));
        }
        edges.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut depth = 0;
        let mut last = 0.0;
        let mut out = 0.0;
        for (t, d) in edges {
            if depth >= 2 {
                out += t - last;
            }
            depth += d;
            last = t;
        }
        out
    }

    /// Human-readable per-stream summary plus the observed overlap pairs
    /// (the `plan-dump --exec` / CLI `solve` report body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("overlap trace:\n");
        for s in 0..self.streams() {
            let n = self.events.iter().filter(|e| e.stream == s).count();
            out.push_str(&format!(
                "  stream {s}: {n} ops, busy {:.3} ms\n",
                1e3 * self.stream_busy(s)
            ));
        }
        out.push_str(&format!(
            "  concurrent (≥2 streams busy): {:.3} ms\n",
            1e3 * self.concurrent_busy()
        ));
        let pairs = self.overlapped_transfer_pairs();
        if pairs.is_empty() {
            out.push_str("  no upload/compute overlap observed\n");
        } else {
            for (tl, cl) in pairs {
                let t = if tl == usize::MAX { "-".to_string() } else { format!("L{tl}") };
                out.push_str(&format!(
                    "  uploads at {t} overlapped compute at L{cl}\n"
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(stream: usize, level: usize, kind: OverlapKind, start: f64, end: f64) -> OverlapEvent {
        OverlapEvent { stream, level, kind, opcode: "TEST", start, end }
    }

    #[test]
    fn overlap_pairs_require_distinct_streams_and_intersection() {
        let tr = OverlapTrace {
            events: vec![
                ev(0, 3, OverlapKind::Compute, 0.0, 1.0),
                ev(1, 2, OverlapKind::Transfer, 0.5, 0.6),
                ev(1, 1, OverlapKind::Transfer, 2.0, 2.1), // disjoint in time
                ev(0, 3, OverlapKind::Transfer, 0.1, 0.2), // same stream
            ],
        };
        assert_eq!(tr.overlapped_transfer_pairs(), vec![(2, 3)]);
        assert!(tr.has_transfer_compute_overlap());
        assert!(tr.overlapped_compute_pairs().is_empty());
        assert_eq!(tr.streams(), 2);
        assert!((tr.stream_busy(0) - 1.1).abs() < 1e-12);
        let rendered = tr.render();
        assert!(rendered.contains("uploads at L2 overlapped compute at L3"), "{rendered}");
    }

    #[test]
    fn serial_trace_reports_no_overlap() {
        let tr = OverlapTrace {
            events: vec![
                ev(0, 3, OverlapKind::Compute, 0.0, 1.0),
                ev(0, 2, OverlapKind::Transfer, 1.0, 1.5),
            ],
        };
        assert!(!tr.has_transfer_compute_overlap());
        assert_eq!(tr.concurrent_busy(), 0.0);
        assert!(tr.render().contains("no upload/compute overlap"));
    }

    #[test]
    fn concurrent_busy_sweeps_event_boundaries() {
        let tr = OverlapTrace {
            events: vec![
                ev(0, 0, OverlapKind::Compute, 0.0, 2.0),
                ev(1, 0, OverlapKind::Compute, 1.0, 3.0),
            ],
        };
        assert!((tr.concurrent_busy() - 1.0).abs() < 1e-12);
        assert_eq!(tr.overlapped_compute_pairs(), vec![(0, 0)]);
    }

    #[test]
    fn compute_pairs_require_distinct_streams() {
        let tr = OverlapTrace {
            events: vec![
                ev(0, 2, OverlapKind::Compute, 0.0, 2.0),
                ev(0, 1, OverlapKind::Compute, 1.0, 3.0), // same stream
                ev(1, 1, OverlapKind::Transfer, 1.0, 3.0), // not compute
            ],
        };
        assert!(tr.overlapped_compute_pairs().is_empty());
    }
}
