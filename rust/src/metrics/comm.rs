//! Measured cross-rank communication totals.
//!
//! The modeled α-β numbers in [`crate::dist`] predict what the plan's
//! `Exchange` instructions *should* cost; these types carry what the
//! transport actually observed when the carved rank plans ran. The
//! distributed solve report keeps both so prediction and measurement can
//! be rendered side by side (paper Figure 23's compute/comm split).

/// Measured totals for one phase (factorization or substitution) of a
/// multi-rank run, aggregated across every rank's transport endpoint.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommTotals {
    /// Collective exchanges per rank (every rank participates in the same
    /// sequence of collectives, so this is the per-endpoint count).
    pub exchanges: u64,
    /// Total payload bytes sent, summed over all ranks.
    pub bytes: u64,
    /// Wall time inside `exchange()` on the critical path: the maximum
    /// over ranks of per-endpoint cumulative exchange time, in seconds.
    pub seconds: f64,
}

/// Measured communication for a full distributed factorize + solve.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommMeasurement {
    /// Factorization-phase exchanges (`Instr::Exchange`).
    pub factor: CommTotals,
    /// Substitution-phase exchanges (`SolveInstr::Exchange`).
    pub subst: CommTotals,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_zero() {
        let m = CommMeasurement::default();
        assert_eq!(m.factor.exchanges, 0);
        assert_eq!(m.subst.bytes, 0);
        assert_eq!(m.factor.seconds, 0.0);
    }
}
