//! Batched-execution trace — the repo's analog of the paper's Nsight
//! profiler screenshot (Figure 12).
//!
//! Every batched kernel launch records (level, kernel name, batch size,
//! matrix shape, duration). The figure harness renders per-level occupancy
//! summaries and a text timeline from these events.

use std::sync::Mutex;
use std::time::Instant;

/// One batched-kernel launch.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Tree level the launch belongs to (usize::MAX = outside level loop).
    pub level: usize,
    /// Kernel name (POTRF / TRSM / GEMM / ...).
    pub kernel: &'static str,
    /// Number of matrices in the batch.
    pub batch: usize,
    /// Representative shape (m, n) of a batch element.
    pub shape: (usize, usize),
    /// Start offset in seconds from tracer creation.
    pub t_start: f64,
    /// Duration in seconds.
    pub dt: f64,
}

/// Collects [`TraceEvent`]s.
pub struct Tracer {
    origin: Instant,
    events: Mutex<Vec<TraceEvent>>,
    enabled: bool,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new(true)
    }
}

impl Tracer {
    pub fn new(enabled: bool) -> Self {
        Tracer { origin: Instant::now(), events: Mutex::new(Vec::new()), enabled }
    }

    /// Record a launch that ran `f`.
    pub fn record<T>(
        &self,
        level: usize,
        kernel: &'static str,
        batch: usize,
        shape: (usize, usize),
        f: impl FnOnce() -> T,
    ) -> T {
        if !self.enabled {
            return f();
        }
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        let t_start = t0.duration_since(self.origin).as_secs_f64();
        self.events.lock().unwrap().push(TraceEvent { level, kernel, batch, shape, t_start, dt });
        out
    }

    /// Snapshot of all events.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Text rendering of the trace, grouped by level (Fig 12 analog).
    pub fn render(&self) -> String {
        let events = self.events();
        let mut out = String::new();
        out.push_str("level  kernel   batch  shape        start[ms]  dur[ms]\n");
        for e in &events {
            let lvl = if e.level == usize::MAX { "-".to_string() } else { e.level.to_string() };
            out.push_str(&format!(
                "{:>5}  {:<8} {:>5}  {:>5}x{:<5}  {:>9.3}  {:>7.3}\n",
                lvl,
                e.kernel,
                e.batch,
                e.shape.0,
                e.shape.1,
                e.t_start * 1e3,
                e.dt * 1e3
            ));
        }
        out
    }

    /// Mean batch size per kernel — a proxy for GPU "occupancy": large
    /// batches saturate batched BLAS the way the paper's Figure 12 shows.
    pub fn mean_batch(&self) -> f64 {
        let ev = self.events();
        if ev.is_empty() {
            return 0.0;
        }
        ev.iter().map(|e| e.batch as f64).sum::<f64>() / ev.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_events() {
        let tr = Tracer::new(true);
        let v = tr.record(3, "POTRF", 16, (8, 8), || 5);
        assert_eq!(v, 5);
        let ev = tr.events();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].kernel, "POTRF");
        assert_eq!(ev[0].batch, 16);
        assert!(tr.render().contains("POTRF"));
        assert_eq!(tr.mean_batch(), 16.0);
    }

    #[test]
    fn disabled_tracer_skips() {
        let tr = Tracer::new(false);
        tr.record(0, "GEMM", 4, (2, 2), || ());
        assert!(tr.events().is_empty());
    }
}
