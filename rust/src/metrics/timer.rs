//! Simple wall-clock stopwatch with named laps.

use std::time::Instant;

/// Accumulating stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, f64)>,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, laps: Vec::new(), last: now }
    }

    /// Record the time since the previous lap under `name`.
    pub fn lap(&mut self, name: &str) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.laps.push((name.to_string(), dt));
        self.last = now;
        dt
    }

    /// Total elapsed seconds since creation.
    pub fn total(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// All recorded laps.
    pub fn laps(&self) -> &[(String, f64)] {
        &self.laps
    }

    /// Seconds recorded for a named lap (summed over repeats).
    pub fn named(&self, name: &str) -> f64 {
        self.laps.iter().filter(|(n, _)| n == name).map(|(_, t)| t).sum()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let l1 = sw.lap("a");
        assert!(l1 >= 0.004);
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert!(sw.named("a") >= 0.004);
        assert!(sw.total() >= l1);
    }

    #[test]
    fn timed_returns_value() {
        let (v, t) = timed(|| 7 * 6);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
