//! Simple wall-clock timing helper.
//!
//! Named-lap accumulation lives in [`crate::metrics::run_trace::RunTrace`]
//! (`phase` / `phase_time`), which subsumed the old `Stopwatch`.

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, t) = timed(|| 7 * 6);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
