//! FLOP accounting, wall-clock timing, and structured run tracing.
//!
//! These power the paper's Figures 12 (profiler view), 14 (TFLOP/s),
//! 15 (FLOP count), 17 (FLOP split), and 23 (compute/comm breakdown),
//! plus the `BENCH_*.json` benchmark trajectory files.

pub mod comm;
pub mod flops;
pub mod overlap;
pub mod run_trace;
pub mod timer;

pub use comm::{CommMeasurement, CommTotals};
pub use overlap::{OverlapEvent, OverlapKind, OverlapTrace};
pub use run_trace::{RunReport, RunTrace, Span};
