//! FLOP accounting, wall-clock timing, and the batched-execution trace.
//!
//! These power the paper's Figures 12 (profiler view), 14 (TFLOP/s),
//! 15 (FLOP count), 17 (FLOP split), and 23 (compute/comm breakdown).

pub mod flops;
pub mod overlap;
pub mod timer;
pub mod trace;

pub use overlap::{OverlapEvent, OverlapKind, OverlapTrace};
pub use timer::Stopwatch;
pub use trace::{TraceEvent, Tracer};
