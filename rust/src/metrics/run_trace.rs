//! Span-based structured run tracing + the serializable [`RunReport`].
//!
//! One [`RunTrace`] follows a solver session end to end: the facade
//! records the top-level phases (`construct` → `factorize` →
//! `substitution`), [`crate::plan::Executor`] records one span per
//! replayed level, and a backend built `with_trace` (native / PJRT)
//! records every batched kernel launch — the repo's analog of the paper's
//! Nsight profiler view (Figure 12), replacing the old Mutex-global
//! `Tracer`. Cloning is cheap (`Arc`-shared, like
//! [`crate::metrics::flops::FlopScope`]), so one trace threads through
//! backends, executors, and worker threads without lifetime plumbing.
//!
//! [`RunReport`] condenses one run into the schema the benchmark
//! trajectory files (`BENCH_*.json`) persist: per-phase wall times,
//! per-level launch counts and padded-vs-useful FLOPs (from
//! [`crate::plan::LaunchMeta`] via `ScheduleStats`), overlap metrics from
//! [`crate::metrics::overlap::OverlapTrace`], and arena byte counters.
//! It serializes through [`crate::util::json::Json`]; parse →
//! re-serialize is byte-stable (pinned by tests).

use crate::metrics::overlap::OverlapTrace;
use crate::util::json::{Json, JsonError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Sentinel level for spans recorded outside any per-level loop.
pub const NO_LEVEL: usize = usize::MAX;

/// One traced interval: a top-level phase, a replayed level, or a single
/// batched kernel launch.
#[derive(Clone, Debug)]
pub struct Span {
    /// Phase / kernel name (`construct`, `factor-level`, `POTRF`, ...).
    pub name: &'static str,
    /// Tree level ([`NO_LEVEL`] = outside the level loop).
    pub level: usize,
    /// Batch items covered by the span (0 for pure phase spans).
    pub batch: usize,
    /// Representative shape (m, n) of a batch element ((0, 0) for phases).
    pub shape: (usize, usize),
    /// Start offset in seconds since trace creation.
    pub t_start: f64,
    /// Duration in seconds.
    pub dt: f64,
}

struct Inner {
    origin: Instant,
    spans: Mutex<Vec<Span>>,
    enabled: bool,
}

/// Cheap-to-clone span collector; all clones append to one buffer.
#[derive(Clone)]
pub struct RunTrace {
    inner: Arc<Inner>,
}

impl Default for RunTrace {
    fn default() -> Self {
        Self::new()
    }
}

impl RunTrace {
    /// An enabled trace with its epoch at the call instant.
    pub fn new() -> Self {
        RunTrace {
            inner: Arc::new(Inner {
                origin: Instant::now(),
                spans: Mutex::new(Vec::new()),
                enabled: true,
            }),
        }
    }

    /// A no-op trace: `record`/`phase` run the closure untimed.
    pub fn disabled() -> Self {
        RunTrace {
            inner: Arc::new(Inner {
                origin: Instant::now(),
                spans: Mutex::new(Vec::new()),
                enabled: false,
            }),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.enabled
    }

    /// Record a span around `f` (kernel-launch granularity).
    pub fn record<T>(
        &self,
        level: usize,
        name: &'static str,
        batch: usize,
        shape: (usize, usize),
        f: impl FnOnce() -> T,
    ) -> T {
        if !self.inner.enabled {
            return f();
        }
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        let t_start = t0.duration_since(self.inner.origin).as_secs_f64();
        self.push(Span { name, level, batch, shape, t_start, dt });
        out
    }

    /// Record a top-level phase span around `f`.
    pub fn phase<T>(&self, name: &'static str, f: impl FnOnce() -> T) -> T {
        self.record(NO_LEVEL, name, 0, (0, 0), f)
    }

    /// Append a span for work that already ran for `dt` seconds ending
    /// now — used when the caller timed the interval itself.
    pub fn push_completed(
        &self,
        level: usize,
        name: &'static str,
        batch: usize,
        shape: (usize, usize),
        dt: f64,
    ) {
        if !self.inner.enabled {
            return;
        }
        let end = self.inner.origin.elapsed().as_secs_f64();
        let t_start = (end - dt).max(0.0);
        self.push(Span { name, level, batch, shape, t_start, dt });
    }

    fn push(&self, span: Span) {
        // Recover from poisoning: a panicking solve must not take the
        // session's trace down with it (mirrors the async arena cells).
        let mut g = self.inner.spans.lock().unwrap_or_else(|p| p.into_inner());
        g.push(span);
    }

    /// Snapshot of all spans recorded so far.
    pub fn spans(&self) -> Vec<Span> {
        self.inner.spans.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Summed duration of all spans named `name`.
    pub fn phase_time(&self, name: &str) -> f64 {
        self.spans().iter().filter(|s| s.name == name).map(|s| s.dt).sum()
    }

    /// Mean batch size over launch spans (batch > 0) — the Figure 12
    /// occupancy proxy (large batches saturate batched BLAS).
    pub fn mean_batch(&self) -> f64 {
        let spans = self.spans();
        let launches: Vec<&Span> = spans.iter().filter(|s| s.batch > 0).collect();
        if launches.is_empty() {
            return 0.0;
        }
        launches.iter().map(|s| s.batch as f64).sum::<f64>() / launches.len() as f64
    }

    /// Text rendering, one line per span (Fig 12 analog).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("level  span          batch  shape        start[ms]  dur[ms]\n");
        for s in self.spans() {
            let lvl = if s.level == NO_LEVEL { "-".to_string() } else { s.level.to_string() };
            out.push_str(&format!(
                "{:>5}  {:<13} {:>5}  {:>5}x{:<5}  {:>9.3}  {:>7.3}\n",
                lvl,
                s.name,
                s.batch,
                s.shape.0,
                s.shape.1,
                s.t_start * 1e3,
                s.dt * 1e3
            ));
        }
        out
    }
}

/// Current `RunReport` / `BENCH_*.json` schema version.
///
/// v2 added the solve-path overlap split (`solve_overlap_ratio`,
/// `solve_overlapped_transfer_pairs`) when substitution started pipelining
/// through the async engine.
pub const RUN_REPORT_SCHEMA_VERSION: u64 = 2;

/// Per-level launch statistics inside a [`RunReport`] (a serializable
/// mirror of [`crate::plan::LevelScheduleStats`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LevelReport {
    pub level: usize,
    pub launches: usize,
    pub batch_items: usize,
    pub flops: u64,
    pub padded_flops: u64,
}

/// The condensed, serializable record of one solver run.
///
/// Wall times are measured and therefore noisy; everything else (launch
/// counts, FLOPs, byte counters) is computed from the plan IR / arena and
/// is bit-deterministic for a fixed structure — the comparator is strict
/// on counters and tolerant on times for exactly this reason.
#[derive(Clone, Debug, PartialEq)]
pub struct RunReport {
    pub schema_version: u64,
    pub backend: String,
    /// Problem size (matrix dimension).
    pub n: usize,
    /// Cluster-tree depth.
    pub depth: usize,
    /// RHS columns covered by `solve_time` (0 = no solve ran).
    pub rhs: usize,
    pub construct_time: f64,
    pub factor_time: f64,
    pub solve_time: f64,
    pub factor_launches: usize,
    pub factor_flops: u64,
    pub factor_padded_flops: u64,
    pub factor_levels: Vec<LevelReport>,
    pub solve_levels: Vec<LevelReport>,
    /// Fraction of the traced wall interval during which ≥2 streams were
    /// simultaneously busy (0 on host-synchronous backends).
    pub overlap_ratio: f64,
    /// Distinct (transfer level, compute level) overlap pairs observed.
    pub overlapped_transfer_pairs: usize,
    /// Solve-path operations recorded in the overlap trace (0 until a
    /// solve runs on an overlapping device).
    pub solve_trace_events: usize,
    /// [`overlap_ratio`](RunReport::overlap_ratio) restricted to the
    /// substitution trace: the fraction of the solve wall interval with
    /// ≥2 streams busy (0 until solves pipeline through the async engine).
    pub solve_overlap_ratio: f64,
    /// Distinct overlap pairs observed on the solve path alone: RHS
    /// uploads overlapping substitution compute on another stream.
    pub solve_overlapped_transfer_pairs: usize,
    pub arena_bytes: u64,
    pub arena_peak_bytes: u64,
    pub predicted_peak_bytes: u64,
}

/// `(overlap_ratio, overlapped_transfer_pairs)` from an optional trace.
pub fn overlap_metrics(overlap: Option<&OverlapTrace>) -> (f64, usize) {
    let Some(tr) = overlap else {
        return (0.0, 0);
    };
    if tr.events.is_empty() {
        return (0.0, 0);
    }
    let start = tr.events.iter().map(|e| e.start).fold(f64::INFINITY, f64::min);
    let end = tr.events.iter().map(|e| e.end).fold(0.0, f64::max);
    let wall = (end - start).max(0.0);
    let ratio = if wall > 0.0 { tr.concurrent_busy() / wall } else { 0.0 };
    (ratio, tr.overlapped_transfer_pairs().len())
}

fn levels_json(levels: &[LevelReport]) -> Json {
    Json::Arr(
        levels
            .iter()
            .map(|l| {
                Json::Obj(vec![
                    ("level".into(), Json::Num(l.level as f64)),
                    ("launches".into(), Json::Num(l.launches as f64)),
                    ("batch_items".into(), Json::Num(l.batch_items as f64)),
                    ("flops".into(), Json::Num(l.flops as f64)),
                    ("padded_flops".into(), Json::Num(l.padded_flops as f64)),
                ])
            })
            .collect(),
    )
}

fn levels_from_json(v: &Json, what: &'static str) -> Result<Vec<LevelReport>, JsonError> {
    let miss = |_| JsonError { pos: 0, msg: what };
    v.as_arr()
        .ok_or(JsonError { pos: 0, msg: what })?
        .iter()
        .map(|l| {
            Ok(LevelReport {
                level: l.get("level").and_then(Json::as_usize).ok_or(()).map_err(miss)?,
                launches: l.get("launches").and_then(Json::as_usize).ok_or(()).map_err(miss)?,
                batch_items: l
                    .get("batch_items")
                    .and_then(Json::as_usize)
                    .ok_or(())
                    .map_err(miss)?,
                flops: l.get("flops").and_then(Json::as_u64).ok_or(()).map_err(miss)?,
                padded_flops: l
                    .get("padded_flops")
                    .and_then(Json::as_u64)
                    .ok_or(())
                    .map_err(miss)?,
            })
        })
        .collect()
}

impl RunReport {
    /// The report as a [`Json`] tree (field order fixed by the schema).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("schema_version".into(), Json::Num(self.schema_version as f64)),
            ("backend".into(), Json::Str(self.backend.clone())),
            ("n".into(), Json::Num(self.n as f64)),
            ("depth".into(), Json::Num(self.depth as f64)),
            ("rhs".into(), Json::Num(self.rhs as f64)),
            ("construct_time".into(), Json::Num(self.construct_time)),
            ("factor_time".into(), Json::Num(self.factor_time)),
            ("solve_time".into(), Json::Num(self.solve_time)),
            ("factor_launches".into(), Json::Num(self.factor_launches as f64)),
            ("factor_flops".into(), Json::Num(self.factor_flops as f64)),
            ("factor_padded_flops".into(), Json::Num(self.factor_padded_flops as f64)),
            ("factor_levels".into(), levels_json(&self.factor_levels)),
            ("solve_levels".into(), levels_json(&self.solve_levels)),
            ("overlap_ratio".into(), Json::Num(self.overlap_ratio)),
            (
                "overlapped_transfer_pairs".into(),
                Json::Num(self.overlapped_transfer_pairs as f64),
            ),
            ("solve_trace_events".into(), Json::Num(self.solve_trace_events as f64)),
            ("solve_overlap_ratio".into(), Json::Num(self.solve_overlap_ratio)),
            (
                "solve_overlapped_transfer_pairs".into(),
                Json::Num(self.solve_overlapped_transfer_pairs as f64),
            ),
            ("arena_bytes".into(), Json::Num(self.arena_bytes as f64)),
            ("arena_peak_bytes".into(), Json::Num(self.arena_peak_bytes as f64)),
            ("predicted_peak_bytes".into(), Json::Num(self.predicted_peak_bytes as f64)),
        ])
    }

    /// Compact JSON text of the report.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_compact()
    }

    /// Rebuild a report from a parsed [`Json`] tree.
    pub fn from_json(v: &Json) -> Result<RunReport, JsonError> {
        fn num(v: &Json, key: &'static str) -> Result<f64, JsonError> {
            v.get(key).and_then(Json::as_f64).ok_or(JsonError { pos: 0, msg: key })
        }
        fn count(v: &Json, key: &'static str) -> Result<usize, JsonError> {
            v.get(key).and_then(Json::as_usize).ok_or(JsonError { pos: 0, msg: key })
        }
        fn counter(v: &Json, key: &'static str) -> Result<u64, JsonError> {
            v.get(key).and_then(Json::as_u64).ok_or(JsonError { pos: 0, msg: key })
        }
        Ok(RunReport {
            schema_version: counter(v, "schema_version")?,
            backend: v
                .get("backend")
                .and_then(Json::as_str)
                .ok_or(JsonError { pos: 0, msg: "backend" })?
                .to_string(),
            n: count(v, "n")?,
            depth: count(v, "depth")?,
            rhs: count(v, "rhs")?,
            construct_time: num(v, "construct_time")?,
            factor_time: num(v, "factor_time")?,
            solve_time: num(v, "solve_time")?,
            factor_launches: count(v, "factor_launches")?,
            factor_flops: counter(v, "factor_flops")?,
            factor_padded_flops: counter(v, "factor_padded_flops")?,
            factor_levels: levels_from_json(
                v.get("factor_levels").unwrap_or(&Json::Null),
                "factor_levels",
            )?,
            solve_levels: levels_from_json(
                v.get("solve_levels").unwrap_or(&Json::Null),
                "solve_levels",
            )?,
            overlap_ratio: num(v, "overlap_ratio")?,
            overlapped_transfer_pairs: count(v, "overlapped_transfer_pairs")?,
            solve_trace_events: count(v, "solve_trace_events")?,
            solve_overlap_ratio: num(v, "solve_overlap_ratio")?,
            solve_overlapped_transfer_pairs: count(v, "solve_overlapped_transfer_pairs")?,
            arena_bytes: counter(v, "arena_bytes")?,
            arena_peak_bytes: counter(v, "arena_peak_bytes")?,
            predicted_peak_bytes: counter(v, "predicted_peak_bytes")?,
        })
    }

    /// Parse a report from JSON text.
    pub fn from_json_str(src: &str) -> Result<RunReport, JsonError> {
        Self::from_json(&Json::parse(src)?)
    }

    /// Padding waste: padded FLOPs the factorization performed beyond the
    /// useful ones, as a fraction of useful (0 = no padding).
    pub fn factor_padding_waste(&self) -> f64 {
        if self.factor_flops == 0 {
            return 0.0;
        }
        (self.factor_padded_flops.saturating_sub(self.factor_flops)) as f64
            / self.factor_flops as f64
    }

    /// Human-readable one-run summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "run report (schema v{}): backend {}, n {}, depth {}, rhs {}\n",
            self.schema_version, self.backend, self.n, self.depth, self.rhs
        ));
        out.push_str(&format!(
            "  construct {:.3} ms | factor {:.3} ms | solve {:.3} ms\n",
            1e3 * self.construct_time,
            1e3 * self.factor_time,
            1e3 * self.solve_time
        ));
        out.push_str(&format!(
            "  {} factor launches, {:.3e} useful / {:.3e} padded FLOPs ({:.1}% waste)\n",
            self.factor_launches,
            self.factor_flops as f64,
            self.factor_padded_flops as f64,
            1e2 * self.factor_padding_waste()
        ));
        out.push_str(&format!(
            "  overlap ratio {:.3}, {} transfer/compute pairs, {} solve trace events\n",
            self.overlap_ratio, self.overlapped_transfer_pairs, self.solve_trace_events
        ));
        out.push_str(&format!(
            "  solve-path overlap ratio {:.3}, {} transfer/compute pairs\n",
            self.solve_overlap_ratio, self.solve_overlapped_transfer_pairs
        ));
        out.push_str(&format!(
            "  arena {} B (peak {} B, predicted {} B)\n",
            self.arena_bytes, self.arena_peak_bytes, self.predicted_peak_bytes
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::overlap::{OverlapEvent, OverlapKind};

    #[test]
    fn records_spans_and_phases() {
        let tr = RunTrace::new();
        let v = tr.record(3, "POTRF", 16, (8, 8), || 5);
        assert_eq!(v, 5);
        let w = tr.phase("construct", || 7);
        assert_eq!(w, 7);
        let spans = tr.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "POTRF");
        assert_eq!(spans[0].batch, 16);
        assert_eq!(spans[1].level, NO_LEVEL);
        // Phase spans (batch 0) stay out of the occupancy proxy.
        assert_eq!(tr.mean_batch(), 16.0);
        assert!(tr.render().contains("POTRF"));
        assert!(tr.phase_time("construct") >= 0.0);
    }

    #[test]
    fn disabled_trace_skips() {
        let tr = RunTrace::disabled();
        tr.record(0, "GEMM", 4, (2, 2), || ());
        tr.push_completed(0, "factor-level", 1, (0, 0), 0.5);
        assert!(tr.spans().is_empty());
        assert!(!tr.is_enabled());
    }

    #[test]
    fn clones_share_one_buffer() {
        let tr = RunTrace::new();
        let clone = tr.clone();
        clone.record(1, "TRSM", 2, (4, 4), || ());
        assert_eq!(tr.spans().len(), 1);
    }

    #[test]
    fn push_completed_backdates_start() {
        let tr = RunTrace::new();
        tr.push_completed(2, "factor-level", 3, (0, 0), 0.25);
        let spans = tr.spans();
        assert_eq!(spans.len(), 1);
        assert!((spans[0].dt - 0.25).abs() < 1e-12);
        assert!(spans[0].t_start >= 0.0);
    }

    fn sample_report() -> RunReport {
        RunReport {
            schema_version: RUN_REPORT_SCHEMA_VERSION,
            backend: "native".to_string(),
            n: 256,
            depth: 2,
            rhs: 4,
            construct_time: 0.0125,
            factor_time: 0.5,
            solve_time: 0.03125,
            factor_launches: 12,
            factor_flops: 1_000_000,
            factor_padded_flops: 1_250_000,
            factor_levels: vec![LevelReport {
                level: 2,
                launches: 12,
                batch_items: 48,
                flops: 1_000_000,
                padded_flops: 1_250_000,
            }],
            solve_levels: vec![LevelReport {
                level: 2,
                launches: 6,
                batch_items: 24,
                flops: 10_000,
                padded_flops: 12_000,
            }],
            overlap_ratio: 0.25,
            overlapped_transfer_pairs: 3,
            solve_trace_events: 7,
            solve_overlap_ratio: 0.125,
            solve_overlapped_transfer_pairs: 2,
            arena_bytes: 4096,
            arena_peak_bytes: 8192,
            predicted_peak_bytes: 8192,
        }
    }

    #[test]
    fn report_round_trips_byte_stable() {
        let r = sample_report();
        let once = r.to_json_string();
        let parsed = RunReport::from_json_str(&once).unwrap();
        assert_eq!(parsed, r);
        assert_eq!(parsed.to_json_string(), once);
    }

    #[test]
    fn report_parse_rejects_missing_fields() {
        let mut j = sample_report().to_json();
        if let Json::Obj(fields) = &mut j {
            fields.retain(|(k, _)| k != "factor_flops");
        }
        assert!(RunReport::from_json(&j).is_err());
    }

    #[test]
    fn padding_waste_math() {
        let r = sample_report();
        assert!((r.factor_padding_waste() - 0.25).abs() < 1e-12);
        assert!(r.render().contains("25.0% waste"));
    }

    #[test]
    fn overlap_metrics_from_trace() {
        let tr = OverlapTrace {
            events: vec![
                OverlapEvent {
                    stream: 0,
                    level: 2,
                    kind: OverlapKind::Compute,
                    opcode: "POTRF",
                    start: 0.0,
                    end: 1.0,
                },
                OverlapEvent {
                    stream: 1,
                    level: 1,
                    kind: OverlapKind::Transfer,
                    opcode: "UPLOAD",
                    start: 0.5,
                    end: 1.0,
                },
            ],
        };
        let (ratio, pairs) = overlap_metrics(Some(&tr));
        assert!((ratio - 0.5).abs() < 1e-12);
        assert_eq!(pairs, 1);
        assert_eq!(overlap_metrics(None), (0.0, 0));
    }
}
