//! ULV factorization driver (paper Algorithms 2 and 4), implemented as
//! record-then-execute over the [`crate::plan`] IR.
//!
//! [`factorize`] records the complete level-ordered launch schedule once
//! (a structural walk — no numerics) and immediately replays it on the
//! given [`Device`]; [`factorize_with_plan`] replays an existing plan
//! against a structurally identical H² matrix, which is how
//! `H2Solver::refactorize` and `H2Solver::rebind_backend` skip schedule
//! re-derivation entirely.

use super::UlvFactor;
use crate::batch::device::Device;
use crate::h2::H2Matrix;
use crate::plan::{self, Executor, Plan};
use std::sync::Arc;

/// Factorize an H²-matrix with the inherently parallel ULV scheme.
///
/// `device` supplies the batched kernels (native thread pool or PJRT/XLA
/// artifacts) and owns the buffer arena the replay runs in. All
/// within-level launches are dependency-free; only the level loop and the
/// merge are synchronization points — exactly the paper's structure. The
/// schedule is recorded as a [`Plan`] before any kernel runs and is kept
/// on the returned factor for replay.
pub fn factorize(h2: &H2Matrix, device: &dyn Device) -> UlvFactor {
    let plan = Arc::new(plan::record(h2));
    factorize_with_plan(h2, device, plan)
}

/// Replay an existing plan against `h2` (which must be structurally
/// identical to the matrix the plan was recorded from — see
/// [`Plan::compatible`]). No schedule discovery runs.
pub fn factorize_with_plan(h2: &H2Matrix, device: &dyn Device, plan: Arc<Plan>) -> UlvFactor {
    Executor::new(device).factorize(&plan, h2)
}

#[cfg(test)]
mod tests {
    use crate::batch::native::NativeBackend;
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::h2::H2Matrix;
    use crate::kernels::KernelFn;

    #[test]
    fn factorize_produces_all_levels() {
        let g = Geometry::sphere_surface(512, 111);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 16, far_samples: 96, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = super::factorize(&h2, &NativeBackend::new());
        assert_eq!(fac.depth, h2.tree.depth);
        assert_eq!(fac.levels.len(), h2.tree.depth);
        assert_eq!(fac.n(), 512);
        // Diagonal Cholesky factors exist for every box of every level.
        for lf in &fac.levels {
            assert_eq!(lf.chol_rr.len(), 1 << lf.level);
            for (i, l) in lf.chol_rr.iter().enumerate() {
                let nred = lf.bases[i].nred();
                assert_eq!(l.rows(), nred);
                // Lower-triangular with positive diagonal.
                for d in 0..nred {
                    assert!(l[(d, d)] > 0.0);
                }
            }
        }
        assert!(fac.root_l.rows() > 0);
        assert!(fac.storage_entries() > 0);
        // The factor carries its replayable schedule.
        assert!(fac.plan.compatible(&h2));
        assert!(fac.plan.schedule_stats().factor_launches() > 0);
    }

    #[test]
    fn factorize_single_leaf() {
        let g = Geometry::uniform_cube(40, 113);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = super::factorize(&h2, &NativeBackend::new());
        assert_eq!(fac.depth, 0);
        assert_eq!(fac.levels.len(), 0);
        assert_eq!(fac.root_l.rows(), 40);
    }

    #[test]
    fn replay_is_bit_identical() {
        let g = Geometry::sphere_surface(384, 115);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 16, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let be = NativeBackend::new();
        let fac1 = super::factorize(&h2, &be);
        let fac2 = super::factorize_with_plan(&h2, &be, fac1.plan.clone());
        assert_eq!(fac1.root_l.as_slice(), fac2.root_l.as_slice());
        for (a, b) in fac1.levels.iter().zip(&fac2.levels) {
            for (ca, cb) in a.chol_rr.iter().zip(&b.chol_rr) {
                assert_eq!(ca.as_slice(), cb.as_slice());
            }
            for (k, m) in &a.lr {
                assert_eq!(m.as_slice(), b.lr[k].as_slice());
            }
            for (k, m) in &a.ls {
                assert_eq!(m.as_slice(), b.ls[k].as_slice());
            }
        }
    }
}
