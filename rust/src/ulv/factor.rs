//! ULV factorization driver (paper Algorithms 2 and 4).

use super::{LevelFactor, UlvFactor};
use crate::batch::BatchExec;
use crate::h2::H2Matrix;
use crate::linalg::chol;
use crate::linalg::Matrix;
use crate::metrics::flops;
use std::collections::HashMap;

/// Factorize an H²-matrix with the inherently parallel ULV scheme.
///
/// `exec` supplies the batched kernels (native thread pool or PJRT/XLA
/// artifacts). All within-level launches are dependency-free; only the
/// level loop and the merge are synchronization points — exactly the
/// paper's structure.
pub fn factorize(h2: &H2Matrix, exec: &dyn BatchExec) -> UlvFactor {
    let prev_phase = flops::set_phase(flops::Phase::Factor);
    let depth = h2.tree.depth;
    let leaf_ranges: Vec<(usize, usize)> =
        h2.tree.leaves().iter().map(|n| (n.begin, n.end)).collect();

    // Current working content: near blocks at the active level, in the
    // coordinates produced by all finer-level transforms.
    let mut current: HashMap<(usize, usize), Matrix> = h2.dense.clone();
    let mut levels: Vec<LevelFactor> = Vec::with_capacity(depth);

    for l in (1..=depth).rev() {
        let bases = &h2.bases[l];
        let near = h2.lists[l].near.clone();

        // --- 1. Sparsify every near block: F_ij = U_iᵀ A_ij U_j. ---
        // (Algorithm 4 computes V_j = U_j L(r)ᵀ⁻¹ to fuse this TRSM with the
        // basis application; we keep the two launches separate — the fusion
        // is an optimization toggle benchmarked in benches/ablation.)
        let pairs: Vec<(usize, usize)> = near.clone();
        let us: Vec<&Matrix> = pairs.iter().map(|&(i, _)| &bases[i].u).collect();
        let vs: Vec<&Matrix> = pairs.iter().map(|&(_, j)| &bases[j].u).collect();
        let blocks: Vec<Matrix> = pairs
            .iter()
            .map(|p| current.remove(p).expect("missing near block"))
            .collect();
        let transformed = exec.sparsify(l, &us, &blocks, &vs);
        let mut f: HashMap<(usize, usize), Matrix> =
            pairs.into_iter().zip(transformed).collect();

        // --- 2. Batched POTRF on diagonal RR blocks. ---
        let width = h2.tree.width(l);
        let mut rr: Vec<Matrix> = (0..width)
            .map(|i| {
                let nb = &bases[i];
                let fii = &f[&(i, i)];
                fii.submatrix(nb.rank, nb.rank, nb.nred(), nb.nred())
            })
            .collect();
        // Skip genuinely empty blocks but keep indices aligned by batching
        // only the non-empty ones.
        let nonempty: Vec<usize> = (0..width).filter(|&i| bases[i].nred() > 0).collect();
        let mut rr_batch: Vec<Matrix> = nonempty.iter().map(|&i| rr[i].clone()).collect();
        exec.potrf(l, &mut rr_batch);
        for (slot, &i) in nonempty.iter().enumerate() {
            rr[i] = rr_batch[slot].clone();
        }
        let chol_rr = rr;

        // --- 3. Batched TRSM panels. ---
        // L(r)_ji = F_ji^RR · L_iiᵀ⁻¹  for near (j,i), j > i;
        // L(s)_ji = F_ji^SR · L_iiᵀ⁻¹  for all near (j,i).
        let mut lr_keys: Vec<(usize, usize)> = Vec::new();
        let mut lr_blocks: Vec<Matrix> = Vec::new();
        let mut lr_diag: Vec<&Matrix> = Vec::new();
        let mut ls_keys: Vec<(usize, usize)> = Vec::new();
        let mut ls_blocks: Vec<Matrix> = Vec::new();
        let mut ls_diag: Vec<&Matrix> = Vec::new();
        for &(j, i) in &near {
            let nbi = &bases[i];
            let nbj = &bases[j];
            if nbi.nred() == 0 {
                continue;
            }
            let fji = &f[&(j, i)];
            if j > i && nbj.nred() > 0 {
                lr_keys.push((j, i));
                lr_blocks.push(fji.submatrix(nbj.rank, nbi.rank, nbj.nred(), nbi.nred()));
                lr_diag.push(&chol_rr[i]);
            }
            if nbj.rank > 0 {
                ls_keys.push((j, i));
                ls_blocks.push(fji.submatrix(0, nbi.rank, nbj.rank, nbi.nred()));
                ls_diag.push(&chol_rr[i]);
            }
        }
        exec.trsm_right_lt(l, &lr_diag, &mut lr_blocks);
        exec.trsm_right_lt(l, &ls_diag, &mut ls_blocks);
        let lr: HashMap<(usize, usize), Matrix> = lr_keys.into_iter().zip(lr_blocks).collect();
        let ls: HashMap<(usize, usize), Matrix> = ls_keys.iter().copied().zip(ls_blocks).collect();

        // --- 4. The single Schur update (eq 21): F_ii^SS -= L(s)_ii L(s)_iiᵀ. ---
        let schur_idx: Vec<usize> = (0..width)
            .filter(|&i| bases[i].rank > 0 && bases[i].nred() > 0)
            .collect();
        let schur_a: Vec<&Matrix> = schur_idx.iter().map(|&i| &ls[&(i, i)]).collect();
        let mut schur_c: Vec<Matrix> = schur_idx
            .iter()
            .map(|&i| f[&(i, i)].submatrix(0, 0, bases[i].rank, bases[i].rank))
            .collect();
        exec.schur_self(l, &schur_a, &mut schur_c);
        // Write the updated SS parts back into the F map.
        for (slot, &i) in schur_idx.iter().enumerate() {
            let fii = f.get_mut(&(i, i)).unwrap();
            fii.set_submatrix(0, 0, &schur_c[slot]);
        }

        // --- 5. Merge to the parent level. ---
        // Parent near block (I, J) = 2x2 assembly of children SS content:
        // near child pair -> SS part of F; far child pair -> coupling Ŝ.
        let mut next: HashMap<(usize, usize), Matrix> = HashMap::new();
        for &(pi, pj) in &h2.lists[l - 1].near {
            let k_r0 = bases[2 * pi].rank;
            let k_r1 = bases[2 * pi + 1].rank;
            let k_c0 = bases[2 * pj].rank;
            let k_c1 = bases[2 * pj + 1].rank;
            let mut merged = Matrix::zeros(k_r0 + k_r1, k_c0 + k_c1);
            for (ci, roff, krow) in [(2 * pi, 0usize, k_r0), (2 * pi + 1, k_r0, k_r1)] {
                for (cj, coff, kcol) in [(2 * pj, 0usize, k_c0), (2 * pj + 1, k_c0, k_c1)] {
                    let blk: Matrix = if let Some(fij) = f.get(&(ci, cj)) {
                        fij.submatrix(0, 0, krow, kcol)
                    } else if let Some(s) = h2.coupling[l].get(&(ci, cj)) {
                        s.clone()
                    } else {
                        // Parent near but child pair absent: structurally
                        // impossible (lists are complete) — keep zero.
                        unreachable!("missing child block ({ci},{cj}) at level {l}")
                    };
                    merged.set_submatrix(roff, coff, &blk);
                }
            }
            next.insert((pi, pj), merged);
        }

        levels.push(LevelFactor {
            level: l,
            bases: bases.clone(),
            chol_rr,
            lr,
            ls,
            near,
        });
        current = next;
    }

    // --- Root factorization (Algorithm 2 line 22). ---
    let root = current
        .remove(&(0, 0))
        .expect("root block must exist after merging");
    flops::add(flops::potrf_flops(root.rows()));
    let root_l = chol::cholesky(&root).expect("root block must stay SPD");
    flops::set_phase(prev_phase);

    UlvFactor { levels, root_l, depth, leaf_ranges, perm: h2.tree.perm.clone() }
}

#[cfg(test)]
mod tests {
    use crate::batch::native::NativeBackend;
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::h2::H2Matrix;
    use crate::kernels::KernelFn;

    #[test]
    fn factorize_produces_all_levels() {
        let g = Geometry::sphere_surface(512, 111);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 16, far_samples: 96, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = super::factorize(&h2, &NativeBackend::new());
        assert_eq!(fac.depth, h2.tree.depth);
        assert_eq!(fac.levels.len(), h2.tree.depth);
        assert_eq!(fac.n(), 512);
        // Diagonal Cholesky factors exist for every box of every level.
        for lf in &fac.levels {
            assert_eq!(lf.chol_rr.len(), 1 << lf.level);
            for (i, l) in lf.chol_rr.iter().enumerate() {
                let nred = lf.bases[i].nred();
                assert_eq!(l.rows(), nred);
                // Lower-triangular with positive diagonal.
                for d in 0..nred {
                    assert!(l[(d, d)] > 0.0);
                }
            }
        }
        assert!(fac.root_l.rows() > 0);
        assert!(fac.storage_entries() > 0);
    }

    #[test]
    fn factorize_single_leaf() {
        let g = Geometry::uniform_cube(40, 113);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = super::factorize(&h2, &NativeBackend::new());
        assert_eq!(fac.depth, 0);
        assert_eq!(fac.levels.len(), 0);
        assert_eq!(fac.root_l.rows(), 40);
    }
}
