//! ULV-preconditioned conjugate gradients.
//!
//! The paper positions the factorization as "an essential part of the
//! direct solver **or preconditioner**" (§3.7). At aggressive (low-rank /
//! heavily sampled) configurations the ULV solve is cheap but only
//! approximate; wrapping it as a CG preconditioner recovers full accuracy
//! in a handful of iterations while keeping the O(N) per-iteration cost
//! (H² matvec + ULV substitution).

use super::{SubstMode, UlvFactor};
use crate::batch::device::{Device, DeviceArena, VecRegion};
use crate::h2::H2Matrix;
use crate::plan::{Executor, Plan};

/// Outcome of a preconditioned-CG solve.
#[derive(Debug, Clone)]
pub struct PcgResult {
    /// Solution in tree ordering.
    pub x: Vec<f64>,
    /// Iterations used.
    pub iters: usize,
    /// Final relative residual (w.r.t. the H² operator).
    pub rel_residual: f64,
}

/// Solve `Â x = b` (tree ordering) by CG on the H² operator, preconditioned
/// with the ULV factorization. `tol` is the relative residual target.
///
/// The factor is uploaded into a device arena once and every CG iteration
/// replays the substitution program against the resident buffers; use
/// [`pcg_in`] directly when a resident factor region (and a leased
/// workspace) already exists — the session facade's case.
pub fn pcg(
    h2: &H2Matrix,
    fac: &UlvFactor,
    device: &dyn Device,
    b: &[f64],
    tol: f64,
    max_iters: usize,
) -> PcgResult {
    let arena = Executor::new(device).upload_factor(fac);
    let mut ws = VecRegion::new(device, 0);
    pcg_in(h2, &fac.plan, device, arena.as_ref(), &mut ws, b, tol, max_iters)
}

/// [`pcg`] against a factor region that already holds the factor resident.
/// The region is only read (every iteration's preconditioner apply writes
/// to `ws`), so concurrent refinement solves on one session each bring
/// their own workspace.
pub fn pcg_in(
    h2: &H2Matrix,
    plan: &Plan,
    device: &dyn Device,
    factor: &dyn DeviceArena,
    ws: &mut VecRegion,
    b: &[f64],
    tol: f64,
    max_iters: usize,
) -> PcgResult {
    let exec = Executor::new(device);
    let n = b.len();
    let norm_b = b.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z = exec.solve_in(plan, factor, ws, &r, SubstMode::Parallel);
    let mut p = z.clone();
    let mut rz: f64 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
    let mut iters = 0;
    let mut rel = 1.0;
    for it in 0..max_iters {
        let ap = h2.matvec(&p);
        let pap: f64 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        if pap.abs() < 1e-300 {
            break;
        }
        let alpha = rz / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        rel = r.iter().map(|v| v * v).sum::<f64>().sqrt() / norm_b;
        iters = it + 1;
        if rel < tol {
            break;
        }
        z = exec.solve_in(plan, factor, ws, &r, SubstMode::Parallel);
        let rz_new: f64 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    PcgResult { x, iters, rel_residual: rel }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::native::NativeBackend;
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::kernels::KernelFn;
    use crate::linalg::norms::rel_err_vec;
    use crate::ulv::factorize;
    use crate::util::Rng;

    #[test]
    fn pcg_converges_fast_with_ulv_preconditioner() {
        // Aggressively sampled, low-rank construction: direct ULV solve is
        // only ~1e-2 accurate; PCG polishes it to 1e-8 in a few iterations.
        let n = 1024;
        let g = Geometry::sphere_surface(n, 801);
        let kern = KernelFn::laplace();
        let cfg = H2Config {
            leaf_size: 64,
            max_rank: 16,
            far_samples: 64,
            near_samples: 48,
            ..Default::default()
        };
        let h2 = crate::h2::H2Matrix::construct(&g, &kern, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let mut rng = Rng::new(1);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let bt = h2.tree.permute_vec(&b);
        let res = pcg(&h2, &fac, &NativeBackend::new(), &bt, 1e-8, 30);
        assert!(res.rel_residual < 1e-8, "PCG residual {}", res.rel_residual);
        assert!(res.iters <= 15, "preconditioner too weak: {} iters", res.iters);
        // And the polished solution really solves the H² system better
        // than the direct ULV solve.
        let direct = fac.solve_tree_order(&bt, &NativeBackend::new(), crate::ulv::SubstMode::Parallel);
        let r_direct = h2.residual(&direct, &bt);
        let r_pcg = h2.residual(&res.x, &bt);
        assert!(r_pcg < 0.1 * r_direct, "pcg {r_pcg} vs direct {r_direct}");
    }

    #[test]
    fn pcg_exact_rhs_zero_iterations_tolerance() {
        let n = 256;
        let g = Geometry::sphere_surface(n, 803);
        let kern = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 0, ..Default::default() };
        let h2 = crate::h2::H2Matrix::construct(&g, &kern, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        // b = Â x_true: PCG must recover x_true.
        let x_true: Vec<f64> = (0..n).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
        let b = h2.matvec(&x_true);
        let res = pcg(&h2, &fac, &NativeBackend::new(), &b, 1e-10, 50);
        assert!(rel_err_vec(&res.x, &x_true) < 1e-8);
    }
}
