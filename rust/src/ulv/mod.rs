//! The inherently parallel H²-ULV factorization (paper Algorithms 2 & 4)
//! and forward/backward substitution (Algorithm 3 + the paper's novel
//! parallel variant, §3.7).
//!
//! Factorization processes the tree level by level (leaves → root). Within
//! a level every operation is a *batched* kernel launch with no
//! dependencies between blocks:
//!
//! 1. **Sparsify** — `F_ij = U_iᵀ A_ij U_j` for every near pair (Figure 2);
//! 2. **POTRF** — Cholesky of every diagonal redundant block `F_ii^RR`;
//! 3. **TRSM** — panel solves `L(r)_ji = F_ji^RR L_iiᵀ⁻¹` and
//!    `L(s)_ji = F_ji^SR L_iiᵀ⁻¹`;
//! 4. **Schur** — the *single* trailing update `F_ii^SS -= L(s)_ii L(s)_iiᵀ`
//!    (eq 21 proves every other trailing update vanishes under the
//!    factorization basis — this is what removes the dependencies);
//! 5. **Merge** — assemble parent-level near blocks from children `SS`
//!    parts and far couplings `Ŝ`.
//!
//! The root block is factorized densely (Algorithm 2 line 22).

//! Both phases run exclusively through the recorded execution-plan IR
//! ([`crate::plan`]) driven against an arena-native
//! [`crate::batch::device::Device`]: `factorize` records the instruction
//! stream once per H² structure and replays it, leaving the factor
//! resident in the device arena; every solve replays the recorded
//! substitution program against those resident buffers. The factor keeps
//! its plan so refactorization and backend rebinding replay without
//! re-planning.

pub mod factor;
pub mod precond;
pub mod solve;

use crate::construct::NodeBasis;
use crate::linalg::Matrix;
use crate::plan::Plan;
use std::collections::HashMap;
use std::sync::Arc;

pub use factor::{factorize, factorize_with_plan};
pub use precond::{pcg, pcg_in};

/// Which substitution algorithm to run (paper §3.7).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SubstMode {
    /// Naive block-TRSV (Algorithm 3) — serial dependencies across boxes,
    /// the paper's CPU substitution path.
    Naive,
    /// The paper's inherently parallel substitution: triangular solves
    /// become matvecs through the single-hop structure of `L⁻¹` (eq 31).
    #[default]
    Parallel,
}

/// Factor data for one tree level.
pub struct LevelFactor {
    pub level: usize,
    /// Shared bases of this level (clone of the H² bases).
    pub bases: Vec<NodeBasis>,
    /// `L(r)_ii`: Cholesky factors of the diagonal `RR` blocks.
    pub chol_rr: Vec<Matrix>,
    /// `L(r)_ji` for near pairs with `j > i` (lower panel, redundant rows).
    pub lr: HashMap<(usize, usize), Matrix>,
    /// `L(s)_ji` for *all* near pairs (skeleton rows are eliminated at the
    /// next level, so they sit below every redundant row of this level).
    pub ls: HashMap<(usize, usize), Matrix>,
    /// Near pairs at this level.
    pub near: Vec<(usize, usize)>,
}

/// The complete ULV factorization: per-level factors + the dense root
/// factor. Self-contained (owns copies of the tree metadata needed by the
/// solve).
pub struct UlvFactor {
    /// Levels in factorization order: `levels[0]` is the leaf level.
    pub levels: Vec<LevelFactor>,
    /// Cholesky factor of the merged root block.
    pub root_l: Matrix,
    /// Tree depth.
    pub depth: usize,
    /// `(begin, end)` point ranges of the leaf boxes.
    pub leaf_ranges: Vec<(usize, usize)>,
    /// Tree permutation (`perm[p]` = original index of tree point p).
    pub perm: Vec<usize>,
    /// The execution plan this factor was produced by; substitution
    /// replays its recorded programs, and the same plan can re-factorize
    /// a structurally identical H² matrix on any backend.
    pub plan: Arc<Plan>,
}

impl UlvFactor {
    /// Leaf-level width.
    pub fn leaf_width(&self) -> usize {
        self.leaf_ranges.len()
    }

    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.perm.len()
    }

    /// Total stored factor entries (memory footprint diagnostics).
    pub fn storage_entries(&self) -> usize {
        let mut total = self.root_l.rows() * self.root_l.cols();
        for lf in &self.levels {
            for m in &lf.chol_rr {
                total += m.rows() * m.cols();
            }
            for m in lf.lr.values() {
                total += m.rows() * m.cols();
            }
            for m in lf.ls.values() {
                total += m.rows() * m.cols();
            }
            for b in &lf.bases {
                total += b.u.rows() * b.u.cols();
            }
        }
        total
    }

    /// Shape-only description of this factor (see [`FactorMeta`]).
    pub fn meta(&self) -> FactorMeta {
        self.plan.factor_meta()
    }
}

/// Shape-only description of a ULV factor: block dimensions, ranks, and
/// level layout — everything the distributed model ([`crate::dist`]) and
/// the figure harnesses need without touching factor *values*. Derived
/// from the recorded [`Plan`] structure alone, so it exists even when no
/// host [`UlvFactor`] mirror does: sessions built with
/// `FactorStorage::DeviceOnly` answer every structural query from this
/// meta and fetch values (rarely) with `H2Solver::download_block`.
#[derive(Clone, Debug)]
pub struct FactorMeta {
    /// Per-level shape tables, leaf level first (the order of
    /// [`UlvFactor::levels`]).
    pub levels: Vec<LevelMeta>,
    /// Merged-root dimension.
    pub root_n: usize,
    /// Tree depth.
    pub depth: usize,
}

/// Shapes of one factor level.
#[derive(Clone, Debug)]
pub struct LevelMeta {
    /// Tree level this table describes.
    pub level: usize,
    /// `(ndof, rank)` per box; the redundant dimension is `ndof - rank`.
    pub boxes: Vec<(usize, usize)>,
    /// Near interaction pairs at this level.
    pub near: Vec<(usize, usize)>,
    /// Keys `(j, i)` holding an `L(r)` panel, of shape
    /// `(nred(j), nred(i))`.
    pub lr: Vec<(usize, usize)>,
    /// Keys `(j, i)` holding an `L(s)` panel, of shape
    /// `(rank(j), nred(i))`.
    pub ls: Vec<(usize, usize)>,
}

impl LevelMeta {
    /// Boxes at this level.
    pub fn width(&self) -> usize {
        self.boxes.len()
    }

    /// DOFs box `i` exposes to this level (`n_i`).
    pub fn ndof(&self, i: usize) -> usize {
        self.boxes[i].0
    }

    /// Skeleton rank `k_i`.
    pub fn rank(&self, i: usize) -> usize {
        self.boxes[i].1
    }

    /// Redundant dimension `n_i - k_i`.
    pub fn nred(&self, i: usize) -> usize {
        self.boxes[i].0 - self.boxes[i].1
    }
}

impl FactorMeta {
    /// Total factor entries (diagonal factors + panels + bases + root) —
    /// equals [`UlvFactor::storage_entries`] of the mirrored factor, but
    /// computed from shapes alone.
    pub fn storage_entries(&self) -> usize {
        let mut total = self.root_n * self.root_n;
        for lm in &self.levels {
            for i in 0..lm.width() {
                total += lm.nred(i) * lm.nred(i); // chol_rr
                total += lm.ndof(i) * lm.ndof(i); // square basis U_i
            }
            for &(j, i) in &lm.lr {
                total += lm.nred(j) * lm.nred(i);
            }
            for &(j, i) in &lm.ls {
                total += lm.rank(j) * lm.nred(i);
            }
        }
        total
    }
}
