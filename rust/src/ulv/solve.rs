//! Forward and backward substitution (paper Algorithm 3 and §3.7).
//!
//! Two variants share the factor data:
//!
//! * [`SubstMode::Naive`] — block-TRSV with serial cross-box dependencies
//!   (Algorithm 3): `b_j^R -= L(r)_ji b_i^R` must wait for `b_i^R`.
//! * [`SubstMode::Parallel`] — the paper's contribution: because the
//!   factorization basis zeroes every second-order fill-in (eq 21), `L⁻¹`
//!   has *single-hop* block structure (eq 31), so the triangular solve
//!   becomes independent TRSVs plus one round of batched matvecs:
//!
//!   ```text
//!   z_i = L_ii⁻¹ b_i                      (batched, independent)
//!   b_i = z_i - L_ii⁻¹ Σ_{j<i} L_ij z_j   (batched matvec + TRSV)
//!   ```
//!
//! Both produce the same solution up to the basis truncation error; the
//! equivalence is asserted in tests.

use super::{SubstMode, UlvFactor};
use crate::batch::BatchExec;
use crate::linalg::blas;
use crate::linalg::chol;
use crate::linalg::matrix::Trans;
use crate::metrics::flops;

impl UlvFactor {
    /// Solve `A x = b` with `b` in *original* point ordering; returns `x`
    /// in original ordering. Convenience wrapper over [`solve_tree_order`].
    pub fn solve(&self, b: &[f64], exec: &dyn BatchExec, mode: SubstMode) -> Vec<f64> {
        assert_eq!(b.len(), self.n());
        // Permute into tree order.
        let bt: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        let xt = self.solve_tree_order(&bt, exec, mode);
        // Back to original ordering.
        let mut x = vec![0.0; b.len()];
        for (t, &orig) in self.perm.iter().enumerate() {
            x[orig] = xt[t];
        }
        x
    }

    /// Solve with `b` already in tree ordering.
    pub fn solve_tree_order(&self, b: &[f64], exec: &dyn BatchExec, mode: SubstMode) -> Vec<f64> {
        let prev_phase = flops::set_phase(flops::Phase::Substitute);
        let x = self.solve_inner(b, exec, mode);
        flops::set_phase(prev_phase);
        x
    }

    fn solve_inner(&self, b: &[f64], exec: &dyn BatchExec, mode: SubstMode) -> Vec<f64> {
        // ---------- Forward pass (leaves -> root). ----------
        // Per level, keep the solved redundant parts for the backward pass.
        let mut saved_r: Vec<Vec<Vec<f64>>> = Vec::with_capacity(self.levels.len());
        // Current segments: one vector per box at the active level.
        let mut seg: Vec<Vec<f64>> = self
            .leaf_ranges
            .iter()
            .map(|&(s, e)| b[s..e].to_vec())
            .collect();

        for lf in &self.levels {
            let level = lf.level;
            let width = lf.bases.len();
            // 1. Apply Uᵀ: c_i = U_iᵀ b_i (batched).
            let us: Vec<&crate::linalg::Matrix> = lf.bases.iter().map(|nb| &nb.u).collect();
            let refs: Vec<&[f64]> = seg.iter().map(|v| v.as_slice()).collect();
            let c = exec.apply_basis(level, &us, true, &refs);
            // Split into skeleton (first k) and redundant (rest).
            let mut s_part: Vec<Vec<f64>> = Vec::with_capacity(width);
            let mut r_part: Vec<Vec<f64>> = Vec::with_capacity(width);
            for (i, ci) in c.into_iter().enumerate() {
                let k = lf.bases[i].rank;
                s_part.push(ci[..k].to_vec());
                r_part.push(ci[k..].to_vec());
            }

            match mode {
                SubstMode::Naive => {
                    // Algorithm 3: serial over boxes.
                    for i in 0..width {
                        if lf.bases[i].nred() == 0 {
                            continue;
                        }
                        blas::trsv(
                            crate::linalg::blas::Uplo::Lower,
                            Trans::No,
                            &lf.chol_rr[i],
                            &mut r_part[i],
                        );
                        flops::add((lf.bases[i].nred() * lf.bases[i].nred()) as u64);
                        // Trailing updates (read-after-write dependency).
                        for &(j, i2) in &lf.near {
                            if i2 != i {
                                continue;
                            }
                            if let Some(lrm) = lf.lr.get(&(j, i)) {
                                let (ri, rj) = split_two(&mut r_part, i, j);
                                blas::gemv(-1.0, lrm, Trans::No, ri, 1.0, rj);
                                flops::add(2 * (lrm.rows() * lrm.cols()) as u64);
                            }
                            if let Some(lsm) = lf.ls.get(&(j, i)) {
                                blas::gemv(-1.0, lsm, Trans::No, &r_part[i].clone(), 1.0, &mut s_part[j]);
                                flops::add(2 * (lsm.rows() * lsm.cols()) as u64);
                            }
                        }
                    }
                }
                SubstMode::Parallel => {
                    // Paper §3.7: single-hop inverse.
                    // z_i = L_ii⁻¹ r_i (batched TRSV, independent).
                    let active: Vec<usize> =
                        (0..width).filter(|&i| lf.bases[i].nred() > 0).collect();
                    let diag: Vec<&crate::linalg::Matrix> =
                        active.iter().map(|&i| &lf.chol_rr[i]).collect();
                    let mut z: Vec<Vec<f64>> = active.iter().map(|&i| r_part[i].clone()).collect();
                    exec.trsv_fwd(level, &diag, &mut z);
                    let z_of: std::collections::HashMap<usize, usize> =
                        active.iter().enumerate().map(|(slot, &i)| (i, slot)).collect();
                    // acc_i = Σ_{j<i near} L(r)_ij z_j  — batched matvecs.
                    // L(r) keys are (row j, col i) with j > i; for target row
                    // i we need L(r)_{i,j} with j < i, stored at key (i, j).
                    let mut acc: Vec<Vec<f64>> =
                        active.iter().map(|&i| vec![0.0; lf.bases[i].nred()]).collect();
                    let mut mats = Vec::new();
                    let mut xs: Vec<&[f64]> = Vec::new();
                    let mut targets = Vec::new();
                    for (&(row, col), m) in &lf.lr {
                        // row > col; contributes to acc[row] from z[col].
                        if let (Some(&tr), Some(&sc)) = (z_of.get(&row), z_of.get(&col)) {
                            mats.push(m);
                            xs.push(z[sc].as_slice());
                            targets.push(tr);
                        }
                    }
                    // Group-by-target accumulation (disjoint writes per launch
                    // round: simple sequential rounds over duplicate targets).
                    accumulate_rounds(exec, level, &mats, &xs, &targets, &mut acc);
                    // r_i = z_i - L_ii⁻¹ Σ L_ij z_j. The batched GEMV runs
                    // with the artifact-fixed alpha = -1, so `acc` already
                    // holds -Σ L_ij z_j; after the TRSV we *add* it.
                    let mut corr = acc;
                    exec.trsv_fwd(level, &diag, &mut corr);
                    for (slot, &i) in active.iter().enumerate() {
                        for t in 0..r_part[i].len() {
                            r_part[i][t] = z[slot][t] + corr[slot][t];
                        }
                    }
                    // s_j -= L(s)_ji r_i (batched, independent of each other).
                    let mut mats = Vec::new();
                    let mut xs: Vec<&[f64]> = Vec::new();
                    let mut targets = Vec::new();
                    for (&(j, i), m) in &lf.ls {
                        if lf.bases[i].nred() == 0 || lf.bases[j].rank == 0 {
                            continue;
                        }
                        mats.push(m);
                        xs.push(r_part[i].as_slice());
                        targets.push(j);
                    }
                    accumulate_rounds(exec, level, &mats, &xs, &targets, &mut s_part);
                }
            }

            saved_r.push(r_part);
            // Merge skeleton parts for the parent level.
            let parent_width = width / 2;
            let mut next: Vec<Vec<f64>> = Vec::with_capacity(parent_width);
            for p in 0..parent_width {
                let mut v = s_part[2 * p].clone();
                v.extend_from_slice(&s_part[2 * p + 1]);
                next.push(v);
            }
            seg = next;
        }

        // ---------- Root solve. ----------
        let mut root = std::mem::take(&mut seg[0]);
        flops::add(2 * (self.root_l.rows() * self.root_l.rows()) as u64);
        chol::potrs(&self.root_l, &mut root);

        // ---------- Backward pass (root -> leaves). ----------
        // `sol` holds the full solution segment per box at the active level.
        let mut sol: Vec<Vec<f64>> = vec![root];
        for (li, lf) in self.levels.iter().enumerate().rev() {
            let level = lf.level;
            let width = lf.bases.len();
            let y_r = &saved_r[li];
            // Child skeleton solutions from the parent segments.
            let mut x_s: Vec<Vec<f64>> = Vec::with_capacity(width);
            for p in 0..width / 2 {
                let k0 = lf.bases[2 * p].rank;
                let parent = &sol[p];
                x_s.push(parent[..k0].to_vec());
                x_s.push(parent[k0..].to_vec());
            }
            // w_i = y_i^R - Σ_{near (j,i)} L(s)_jiᵀ x_j^S.
            let mut w: Vec<Vec<f64>> = y_r.clone();
            {
                let mut mats = Vec::new();
                let mut xs: Vec<&[f64]> = Vec::new();
                let mut targets = Vec::new();
                for (&(j, i), m) in &lf.ls {
                    if lf.bases[i].nred() == 0 || lf.bases[j].rank == 0 {
                        continue;
                    }
                    mats.push(m);
                    xs.push(x_s[j].as_slice());
                    targets.push(i);
                }
                accumulate_rounds_trans(exec, level, &mats, &xs, &targets, &mut w);
            }
            // Solve L_RRᵀ x^R = w.
            let active: Vec<usize> = (0..width).filter(|&i| lf.bases[i].nred() > 0).collect();
            let diag: Vec<&crate::linalg::Matrix> =
                active.iter().map(|&i| &lf.chol_rr[i]).collect();
            let mut x_r: Vec<Vec<f64>> = vec![Vec::new(); width];
            match mode {
                SubstMode::Naive => {
                    // Reverse order serial upper solve.
                    for &i in active.iter().rev() {
                        let mut rhs = w[i].clone();
                        for (&(j, i2), m) in &lf.lr {
                            if i2 == i && !x_r[j].is_empty() {
                                blas::gemv(-1.0, m, Trans::Yes, &x_r[j], 1.0, &mut rhs);
                                flops::add(2 * (m.rows() * m.cols()) as u64);
                            }
                        }
                        blas::trsv(crate::linalg::blas::Uplo::Lower, Trans::Yes, &lf.chol_rr[i], &mut rhs);
                        flops::add((lf.bases[i].nred() * lf.bases[i].nred()) as u64);
                        x_r[i] = rhs;
                    }
                }
                SubstMode::Parallel => {
                    // Single-hop: z_i = L_iiᵀ⁻¹ w_i;
                    // x_i = z_i - L_iiᵀ⁻¹ Σ_{j>i} L(r)_jiᵀ z_j.
                    let mut z: Vec<Vec<f64>> = active.iter().map(|&i| w[i].clone()).collect();
                    exec.trsv_bwd(level, &diag, &mut z);
                    let z_of: std::collections::HashMap<usize, usize> =
                        active.iter().enumerate().map(|(slot, &i)| (i, slot)).collect();
                    let mut acc: Vec<Vec<f64>> =
                        active.iter().map(|&i| vec![0.0; lf.bases[i].nred()]).collect();
                    let mut mats = Vec::new();
                    let mut xs: Vec<&[f64]> = Vec::new();
                    let mut targets = Vec::new();
                    for (&(row, col), m) in &lf.lr {
                        // (row > col): L(r)_jiᵀ contributes to target col from z[row].
                        if let (Some(&tc), Some(&sr)) = (z_of.get(&col), z_of.get(&row)) {
                            mats.push(m);
                            xs.push(z[sr].as_slice());
                            targets.push(tc);
                        }
                    }
                    accumulate_rounds_trans_slots(exec, level, &mats, &xs, &targets, &mut acc);
                    // As in the forward pass: acc = -Σ L(r)_jiᵀ z_j, so add.
                    let mut corr = acc;
                    exec.trsv_bwd(level, &diag, &mut corr);
                    for (slot, &i) in active.iter().enumerate() {
                        let mut v = vec![0.0; lf.bases[i].nred()];
                        for t in 0..v.len() {
                            v[t] = z[slot][t] + corr[slot][t];
                        }
                        x_r[i] = v;
                    }
                }
            }
            for i in 0..width {
                if x_r[i].is_empty() {
                    x_r[i] = vec![0.0; lf.bases[i].nred()];
                }
            }
            // x_i = U_i [x_i^S; x_i^R] (batched).
            let us: Vec<&crate::linalg::Matrix> = lf.bases.iter().map(|nb| &nb.u).collect();
            let stacked: Vec<Vec<f64>> = (0..width)
                .map(|i| {
                    let mut v = x_s[i].clone();
                    v.extend_from_slice(&x_r[i]);
                    v
                })
                .collect();
            let refs: Vec<&[f64]> = stacked.iter().map(|v| v.as_slice()).collect();
            sol = exec.apply_basis(level, &us, false, &refs);
        }

        // Flatten leaf segments into the tree-ordered solution.
        let mut x = vec![0.0; self.n()];
        for (i, &(s, e)) in self.leaf_ranges.iter().enumerate() {
            x[s..e].copy_from_slice(&sol[i]);
        }
        x
    }
}

/// Split two distinct mutable elements out of a slice.
fn split_two<'a, T>(v: &'a mut [T], i: usize, j: usize) -> (&'a T, &'a mut T) {
    assert_ne!(i, j);
    if i < j {
        let (a, b) = v.split_at_mut(j);
        (&a[i], &mut b[0])
    } else {
        let (a, b) = v.split_at_mut(i);
        (&b[0], &mut a[j])
    }
}

/// Launch batched `y[target] += -1 * A x` accumulations, splitting into
/// rounds so that within one launch every target is unique (batched calls
/// must not alias outputs — mirrors how the GPU implementation issues
/// conflict-free batched GEMV rounds).
fn accumulate_rounds(
    exec: &dyn BatchExec,
    level: usize,
    mats: &[&crate::linalg::Matrix],
    xs: &[&[f64]],
    targets: &[usize],
    out: &mut [Vec<f64>],
) {
    accumulate_impl(exec, level, mats, xs, targets, out, false);
}

fn accumulate_rounds_trans(
    exec: &dyn BatchExec,
    level: usize,
    mats: &[&crate::linalg::Matrix],
    xs: &[&[f64]],
    targets: &[usize],
    out: &mut [Vec<f64>],
) {
    accumulate_impl(exec, level, mats, xs, targets, out, true);
}

/// Variant where `targets` index into `out` directly (already slot-mapped).
fn accumulate_rounds_trans_slots(
    exec: &dyn BatchExec,
    level: usize,
    mats: &[&crate::linalg::Matrix],
    xs: &[&[f64]],
    targets: &[usize],
    out: &mut [Vec<f64>],
) {
    accumulate_impl(exec, level, mats, xs, targets, out, true);
}

fn accumulate_impl(
    exec: &dyn BatchExec,
    level: usize,
    mats: &[&crate::linalg::Matrix],
    xs: &[&[f64]],
    targets: &[usize],
    out: &mut [Vec<f64>],
    trans: bool,
) {
    let mut remaining: Vec<usize> = (0..mats.len()).collect();
    while !remaining.is_empty() {
        let mut used = std::collections::HashSet::new();
        let mut round = Vec::new();
        let mut rest = Vec::new();
        for &t in &remaining {
            if used.insert(targets[t]) {
                round.push(t);
            } else {
                rest.push(t);
            }
        }
        remaining = rest;
        // Gather round inputs; outputs are unique targets so we can split
        // borrow via a temporary take.
        let rmats: Vec<&crate::linalg::Matrix> = round.iter().map(|&t| mats[t]).collect();
        let rxs: Vec<&[f64]> = round.iter().map(|&t| xs[t]).collect();
        let mut rys: Vec<Vec<f64>> = round.iter().map(|&t| std::mem::take(&mut out[targets[t]])).collect();
        exec.gemv_acc(level, -1.0, &rmats, trans, &rxs, &mut rys);
        for (slot, &t) in round.iter().enumerate() {
            out[targets[t]] = std::mem::take(&mut rys[slot]);
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::batch::native::NativeBackend;
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::h2::H2Matrix;
    use crate::kernels::KernelFn;
    use crate::linalg::blas;
    use crate::linalg::matrix::Trans;
    use crate::linalg::norms::rel_err_vec;
    use crate::ulv::{factorize, SubstMode};
    use crate::util::Rng;

    fn dense_solution(h2: &H2Matrix, b_tree: &[f64]) -> Vec<f64> {
        let a = h2.kernel.dense(&h2.tree.points);
        crate::linalg::lu::solve(&a, b_tree).unwrap()
    }

    fn rhs(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn solve_matches_dense_h2() {
        let g = Geometry::sphere_surface(512, 121);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 0, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(512, 1);
        let bt = h2.tree.permute_vec(&b);
        let want = dense_solution(&h2, &bt);
        for mode in [SubstMode::Parallel, SubstMode::Naive] {
            let xt = fac.solve_tree_order(&bt, &NativeBackend::new(), mode);
            let err = rel_err_vec(&xt, &want);
            // Solution error tracks the H2 approximation error (~3e-4 at
            // rank 32 for this geometry; see EXPERIMENTS.md rank study).
            assert!(err < 1e-3, "{mode:?}: solution error vs dense {err}");
        }
    }

    #[test]
    fn solve_hss_exact_wrt_reconstruction() {
        // With eta=0 there are no off-diagonal near blocks, so no trailing
        // update is ever skipped: the ULV solve must invert the H²
        // reconstruction to near machine precision.
        let g = Geometry::sphere_surface(256, 123);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 32, max_rank: 16, far_samples: 0, eta: 0.0, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(256, 3);
        let xt = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Parallel);
        // Residual against the reconstructed H2 operator.
        let rec = h2.reconstruct_dense();
        let mut ax = vec![0.0; 256];
        blas::gemv(1.0, &rec, Trans::No, &xt, 0.0, &mut ax);
        let err = rel_err_vec(&ax, &b);
        assert!(err < 1e-10, "HSS ULV must be exact wrt reconstruction: {err}");
    }

    #[test]
    fn parallel_and_naive_agree() {
        let g = Geometry::sphere_surface(640, 125);
        let k = KernelFn::yukawa();
        let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 96, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(640, 5);
        let xp = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Parallel);
        let xn = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Naive);
        // They differ only by second-order skipped terms ~ O(truncation²).
        let err = rel_err_vec(&xp, &xn);
        assert!(err < 1e-3, "substitution variants diverged: {err}");
    }

    #[test]
    fn solve_inverts_reconstruction_to_high_accuracy() {
        // The key correctness invariant: the ULV solve inverts the H2
        // *reconstruction* Â almost exactly — the only gap is the skipped
        // second-order trailing terms (eq 21), which the factorization
        // basis makes tiny. (Accuracy vs the true kernel matrix is then
        // governed purely by the construction-phase approximation.)
        let g = Geometry::sphere_surface(512, 131);
        let k = KernelFn::laplace();
        let cfg = H2Config {
            leaf_size: 128,
            max_rank: 64,
            far_samples: 0,
            near_samples: 0, // full near field -> complete factorization basis
            ..Default::default()
        };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(512, 11);
        let rec = h2.reconstruct_dense();
        // Naive substitution inverts the computed factor L̂ exactly, so the
        // only gap is the factorization's skipped second-order terms.
        let xn = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Naive);
        let mut ax = vec![0.0; 512];
        blas::gemv(1.0, &rec, Trans::No, &xn, 0.0, &mut ax);
        let err_naive = rel_err_vec(&ax, &b);
        assert!(err_naive < 1e-6, "naive ULV must invert the reconstruction: {err_naive}");
        // The parallel substitution adds its own single-hop truncation of
        // L̂⁻¹ (eq 31) — also second-order small.
        let xp = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Parallel);
        blas::gemv(1.0, &rec, Trans::No, &xp, 0.0, &mut ax);
        let err_par = rel_err_vec(&ax, &b);
        assert!(err_par < 1e-4, "parallel ULV must invert the reconstruction: {err_par}");
    }

    #[test]
    fn solve_original_ordering_roundtrip() {
        let g = Geometry::uniform_cube(300, 127);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 24, far_samples: 0, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(300, 7);
        let x = fac.solve(&b, &NativeBackend::new(), SubstMode::Parallel);
        // Verify in original ordering against dense solve.
        let a = k.dense(&g.points);
        let want = crate::linalg::lu::solve(&a, &b).unwrap();
        let err = rel_err_vec(&x, &want);
        assert!(err < 1e-3, "original-order solve error {err}");
    }

    #[test]
    fn single_leaf_solve_is_exact() {
        let g = Geometry::uniform_cube(48, 129);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(48, 9);
        let x = fac.solve(&b, &NativeBackend::new(), SubstMode::Parallel);
        let a = k.dense(&g.points);
        let want = crate::linalg::lu::solve(&a, &b).unwrap();
        let err = rel_err_vec(&x, &want);
        assert!(err < 1e-9, "single-leaf must be a plain dense solve: {err}");
    }
}
