//! Forward and backward substitution (paper Algorithm 3 and §3.7),
//! executed by replaying the recorded substitution programs of the
//! factor's [`crate::plan::Plan`].
//!
//! Two variants share the factor data:
//!
//! * [`SubstMode::Naive`] — block-TRSV with serial cross-box dependencies
//!   (Algorithm 3): `b_j^R -= L(r)_ji b_i^R` must wait for `b_i^R`. The
//!   recorded program bakes that dependency order into a stream of
//!   batch-of-one launches.
//! * [`SubstMode::Parallel`] — the paper's contribution: because the
//!   factorization basis zeroes every second-order fill-in (eq 21), `L⁻¹`
//!   has *single-hop* block structure (eq 31), so the triangular solve
//!   becomes independent TRSVs plus one round of batched matvecs:
//!
//!   ```text
//!   z_i = L_ii⁻¹ b_i                      (batched, independent)
//!   b_i = z_i - L_ii⁻¹ Σ_{j<i} L_ij z_j   (batched matvec + TRSV)
//!   ```
//!
//! Both produce the same solution up to the basis truncation error; the
//! equivalence is asserted in tests. Because the programs are recorded,
//! every solve of the same factor issues the identical launch sequence —
//! replay is bit-deterministic per backend.

use super::{SubstMode, UlvFactor};
use crate::batch::device::Device;
use crate::metrics::flops::FlopScope;
use crate::plan::Executor;

impl UlvFactor {
    /// Solve `A x = b` with `b` in *original* point ordering; returns `x`
    /// in original ordering. Convenience wrapper over [`solve_tree_order`].
    ///
    /// [`solve_tree_order`]: UlvFactor::solve_tree_order
    pub fn solve(&self, b: &[f64], device: &dyn Device, mode: SubstMode) -> Vec<f64> {
        assert_eq!(b.len(), self.n());
        // Permute into tree order.
        let bt: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        let xt = self.solve_tree_order(&bt, device, mode);
        // Back to original ordering.
        let mut x = vec![0.0; b.len()];
        for (t, &orig) in self.perm.iter().enumerate() {
            x[orig] = xt[t];
        }
        x
    }

    /// Solve with `b` already in tree ordering: replays the recorded
    /// substitution program for `mode`. The factor is uploaded into a
    /// transient device arena for this call; sessions that solve
    /// repeatedly keep a resident arena instead
    /// ([`Executor::factorize_resident`] / [`Executor::solve_in`]).
    pub fn solve_tree_order(&self, b: &[f64], device: &dyn Device, mode: SubstMode) -> Vec<f64> {
        Executor::new(device).solve(&self.plan, self, b, mode)
    }

    /// [`solve_tree_order`](UlvFactor::solve_tree_order) with per-session
    /// FLOP attribution (used by the solver facade).
    pub fn solve_tree_order_scoped(
        &self,
        b: &[f64],
        device: &dyn Device,
        mode: SubstMode,
        scope: &FlopScope,
    ) -> Vec<f64> {
        Executor::new(device).with_scope(scope).solve(&self.plan, self, b, mode)
    }
}

#[cfg(test)]
mod tests {
    use crate::batch::native::NativeBackend;
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::h2::H2Matrix;
    use crate::kernels::KernelFn;
    use crate::linalg::blas;
    use crate::linalg::matrix::Trans;
    use crate::linalg::norms::rel_err_vec;
    use crate::ulv::{factorize, SubstMode};
    use crate::util::Rng;

    fn dense_solution(h2: &H2Matrix, b_tree: &[f64]) -> Vec<f64> {
        let a = h2.kernel.dense(&h2.tree.points);
        crate::linalg::lu::solve(&a, b_tree).unwrap()
    }

    fn rhs(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn solve_matches_dense_h2() {
        let g = Geometry::sphere_surface(512, 121);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 0, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(512, 1);
        let bt = h2.tree.permute_vec(&b);
        let want = dense_solution(&h2, &bt);
        for mode in [SubstMode::Parallel, SubstMode::Naive] {
            let xt = fac.solve_tree_order(&bt, &NativeBackend::new(), mode);
            let err = rel_err_vec(&xt, &want);
            // Solution error tracks the H2 approximation error (~3e-4 at
            // rank 32 for this geometry; see EXPERIMENTS.md rank study).
            assert!(err < 1e-3, "{mode:?}: solution error vs dense {err}");
        }
    }

    #[test]
    fn solve_hss_exact_wrt_reconstruction() {
        // With eta=0 there are no off-diagonal near blocks, so no trailing
        // update is ever skipped: the ULV solve must invert the H²
        // reconstruction to near machine precision.
        let g = Geometry::sphere_surface(256, 123);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 32, max_rank: 16, far_samples: 0, eta: 0.0, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(256, 3);
        let xt = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Parallel);
        // Residual against the reconstructed H2 operator.
        let rec = h2.reconstruct_dense();
        let mut ax = vec![0.0; 256];
        blas::gemv(1.0, &rec, Trans::No, &xt, 0.0, &mut ax);
        let err = rel_err_vec(&ax, &b);
        assert!(err < 1e-10, "HSS ULV must be exact wrt reconstruction: {err}");
    }

    #[test]
    fn parallel_and_naive_agree() {
        let g = Geometry::sphere_surface(640, 125);
        let k = KernelFn::yukawa();
        let cfg = H2Config { leaf_size: 64, max_rank: 32, far_samples: 96, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(640, 5);
        let xp = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Parallel);
        let xn = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Naive);
        // They differ only by second-order skipped terms ~ O(truncation²).
        let err = rel_err_vec(&xp, &xn);
        assert!(err < 1e-3, "substitution variants diverged: {err}");
    }

    #[test]
    fn solve_inverts_reconstruction_to_high_accuracy() {
        // The key correctness invariant: the ULV solve inverts the H2
        // *reconstruction* Â almost exactly — the only gap is the skipped
        // second-order trailing terms (eq 21), which the factorization
        // basis makes tiny. (Accuracy vs the true kernel matrix is then
        // governed purely by the construction-phase approximation.)
        let g = Geometry::sphere_surface(512, 131);
        let k = KernelFn::laplace();
        let cfg = H2Config {
            leaf_size: 128,
            max_rank: 64,
            far_samples: 0,
            near_samples: 0, // full near field -> complete factorization basis
            ..Default::default()
        };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(512, 11);
        let rec = h2.reconstruct_dense();
        // Naive substitution inverts the computed factor L̂ exactly, so the
        // only gap is the factorization's skipped second-order terms.
        let xn = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Naive);
        let mut ax = vec![0.0; 512];
        blas::gemv(1.0, &rec, Trans::No, &xn, 0.0, &mut ax);
        let err_naive = rel_err_vec(&ax, &b);
        assert!(err_naive < 1e-6, "naive ULV must invert the reconstruction: {err_naive}");
        // The parallel substitution adds its own single-hop truncation of
        // L̂⁻¹ (eq 31) — also second-order small.
        let xp = fac.solve_tree_order(&b, &NativeBackend::new(), SubstMode::Parallel);
        blas::gemv(1.0, &rec, Trans::No, &xp, 0.0, &mut ax);
        let err_par = rel_err_vec(&ax, &b);
        assert!(err_par < 1e-4, "parallel ULV must invert the reconstruction: {err_par}");
    }

    #[test]
    fn solve_original_ordering_roundtrip() {
        let g = Geometry::uniform_cube(300, 127);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 24, far_samples: 0, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(300, 7);
        let x = fac.solve(&b, &NativeBackend::new(), SubstMode::Parallel);
        // Verify in original ordering against dense solve.
        let a = k.dense(&g.points);
        let want = crate::linalg::lu::solve(&a, &b).unwrap();
        let err = rel_err_vec(&x, &want);
        assert!(err < 1e-3, "original-order solve error {err}");
    }

    #[test]
    fn single_leaf_solve_is_exact() {
        let g = Geometry::uniform_cube(48, 129);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(48, 9);
        let x = fac.solve(&b, &NativeBackend::new(), SubstMode::Parallel);
        let a = k.dense(&g.points);
        let want = crate::linalg::lu::solve(&a, &b).unwrap();
        let err = rel_err_vec(&x, &want);
        assert!(err < 1e-9, "single-leaf must be a plain dense solve: {err}");
    }

    #[test]
    fn replayed_solves_are_bit_identical() {
        let g = Geometry::sphere_surface(384, 133);
        let k = KernelFn::laplace();
        let cfg = H2Config { leaf_size: 64, max_rank: 24, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &k, &cfg);
        let fac = factorize(&h2, &NativeBackend::new());
        let b = rhs(384, 13);
        for mode in [SubstMode::Parallel, SubstMode::Naive] {
            let x1 = fac.solve_tree_order(&b, &NativeBackend::new(), mode);
            let x2 = fac.solve_tree_order(&b, &NativeBackend::new(), mode);
            assert_eq!(x1, x2, "{mode:?}: replay must be deterministic");
        }
    }
}
