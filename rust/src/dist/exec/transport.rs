//! The rank boundary: a [`Transport`] endpoint per rank, carrying the
//! payloads of the plan's `Exchange` instructions.
//!
//! The executor replays a [`crate::plan::RankPlan`] exactly like a global
//! plan, except that `Exchange` steps are routed here instead of to a
//! device kernel: the sending side downloads the named buffers from its
//! arena, the transport rendezvouses with every peer's matching exchange,
//! and the receiving side uploads the incoming payloads into its own
//! arena. Because every rank's carved stream contains the *same* sequence
//! of `Exchange` steps (possibly with empty send/recv lists), the k-th
//! `exchange()` call on every endpoint belongs to the same collective —
//! no tags are needed; the epoch counter is the tag.
//!
//! [`ThreadTransport`] is the in-process implementation (thread-per-rank
//! over a shared mailbox). The trait is deliberately narrow — `ranks`,
//! `rank`, one collective `exchange`, and counters — so a process or
//! socket transport can slot in behind the same seam.

use crate::linalg::Matrix;
use crate::plan::BufferId;
use std::cell::Cell;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One buffer's worth of exchanged data. Matrix payloads carry
/// factorization blocks (`Instr::Exchange`); vector payloads carry
/// substitution segments (`SolveInstr::Exchange`).
#[derive(Clone, Debug)]
pub enum CommPayload {
    /// A factor-phase matrix block.
    Mat(Matrix),
    /// A substitution-phase vector segment.
    Vector(Vec<f64>),
}

impl CommPayload {
    /// Payload size in bytes (f64 entries × 8).
    pub fn bytes(&self) -> u64 {
        match self {
            CommPayload::Mat(m) => (m.rows() * m.cols() * 8) as u64,
            CommPayload::Vector(v) => (v.len() * 8) as u64,
        }
    }
}

/// One outgoing buffer in an exchange: the plan-global [`BufferId`] is the
/// address — receivers ask for `(sender rank, BufferId)` pairs.
#[derive(Clone, Debug)]
pub struct ExchangeMsg {
    pub buf: BufferId,
    pub payload: CommPayload,
}

/// Per-endpoint communication counters, accumulated across every
/// `exchange()` on this endpoint.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransportStats {
    /// Collective exchanges this endpoint participated in.
    pub exchanges: u64,
    /// Bytes this endpoint sent (payload only).
    pub bytes_sent: u64,
    /// Wall time spent inside `exchange()` (serialization + rendezvous
    /// wait), in seconds.
    pub seconds: f64,
}

/// The rank boundary. One endpoint per rank; endpoints are `Send` so a
/// rank thread can own its endpoint, and all methods take `&self` (state
/// lives behind interior mutability) so the endpoint can sit next to the
/// executor's other shared references.
pub trait Transport: Send {
    /// Number of ranks in the group.
    fn ranks(&self) -> usize;
    /// This endpoint's rank (0-based).
    fn rank(&self) -> usize;
    /// One collective exchange: post `sends`, rendezvous with every peer's
    /// matching call, and return the payloads for `recvs` (as
    /// `(sender rank, buffer)` pairs), in order. Every rank must call
    /// `exchange` the same number of times — the call index is the
    /// collective's identity.
    fn exchange(&self, sends: Vec<ExchangeMsg>, recvs: &[(usize, BufferId)]) -> Vec<CommPayload>;
    /// Counters accumulated on this endpoint so far.
    fn stats(&self) -> TransportStats;
}

/// In-flight state of one collective: how many ranks have posted, how many
/// have finished collecting, and the posted payloads keyed by
/// `(sender rank, buffer)`.
#[derive(Default)]
struct EpochState {
    posted: usize,
    done: usize,
    inbox: HashMap<(u32, u32), Arc<CommPayload>>,
}

/// Mailbox shared by every endpoint of one [`ThreadTransport::group`].
struct Shared {
    ranks: usize,
    state: Mutex<HashMap<u64, EpochState>>,
    cv: Condvar,
}

/// Thread-per-rank transport over a shared in-process mailbox. Epochs key
/// the mailbox, so a fast rank may begin collective `e+1` while a slow
/// rank is still collecting `e` — no barrier beyond the rendezvous itself.
pub struct ThreadTransport {
    shared: Arc<Shared>,
    rank: usize,
    epoch: Cell<u64>,
    exchanges: Cell<u64>,
    bytes_sent: Cell<u64>,
    seconds: Cell<f64>,
}

impl ThreadTransport {
    /// Create the endpoints of a `p`-rank group. Endpoint `i` is rank `i`.
    pub fn group(p: usize) -> Vec<ThreadTransport> {
        assert!(p >= 1, "a transport group needs at least one rank");
        let shared = Arc::new(Shared {
            ranks: p,
            state: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
        });
        (0..p)
            .map(|rank| ThreadTransport {
                shared: shared.clone(),
                rank,
                epoch: Cell::new(0),
                exchanges: Cell::new(0),
                bytes_sent: Cell::new(0),
                seconds: Cell::new(0.0),
            })
            .collect()
    }
}

impl Transport for ThreadTransport {
    fn ranks(&self) -> usize {
        self.shared.ranks
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn exchange(&self, sends: Vec<ExchangeMsg>, recvs: &[(usize, BufferId)]) -> Vec<CommPayload> {
        let start = Instant::now();
        let e = self.epoch.get();
        let sent_bytes: u64 = sends.iter().map(|m| m.payload.bytes()).sum();
        let mut state = self.shared.state.lock().unwrap_or_else(|p| p.into_inner());
        {
            let ep = state.entry(e).or_default();
            for msg in sends {
                let prev = ep.inbox.insert((self.rank as u32, msg.buf.0), Arc::new(msg.payload));
                assert!(prev.is_none(), "rank {} re-sent buffer {} in one exchange", self.rank, msg.buf.0);
            }
            ep.posted += 1;
        }
        self.shared.cv.notify_all();
        // Rendezvous: wait until every rank has posted this epoch's sends.
        while state.get(&e).map(|ep| ep.posted).unwrap_or(0) < self.shared.ranks {
            state = self.shared.cv.wait(state).unwrap_or_else(|p| p.into_inner());
        }
        let out: Vec<CommPayload> = {
            let ep = state.get(&e).expect("epoch present until every rank is done");
            recvs
                .iter()
                .map(|&(from, buf)| {
                    let payload = ep.inbox.get(&(from as u32, buf.0)).unwrap_or_else(|| {
                        panic!(
                            "rank {} expected buffer {} from rank {} in exchange {}, \
                             but it was never sent",
                            self.rank, buf.0, from, e
                        )
                    });
                    (**payload).clone()
                })
                .collect()
        };
        {
            let ep = state.get_mut(&e).expect("epoch present until every rank is done");
            ep.done += 1;
            if ep.done == self.shared.ranks {
                state.remove(&e);
            }
        }
        drop(state);
        self.shared.cv.notify_all();
        self.epoch.set(e + 1);
        self.exchanges.set(self.exchanges.get() + 1);
        self.bytes_sent.set(self.bytes_sent.get() + sent_bytes);
        self.seconds.set(self.seconds.get() + start.elapsed().as_secs_f64());
        out
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            exchanges: self.exchanges.get(),
            bytes_sent: self.bytes_sent.get(),
            seconds: self.seconds.get(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_rank_exchange_delivers_both_ways() {
        let group = ThreadTransport::group(2);
        let (t0, t1) = {
            let mut it = group.into_iter();
            (it.next().unwrap(), it.next().unwrap())
        };
        let out = std::thread::scope(|s| {
            let h0 = s.spawn(move || {
                let sends = vec![ExchangeMsg {
                    buf: BufferId(7),
                    payload: CommPayload::Vector(vec![1.0, 2.0]),
                }];
                let got = t0.exchange(sends, &[(1, BufferId(9))]);
                (got, t0.stats())
            });
            let h1 = s.spawn(move || {
                let sends = vec![ExchangeMsg {
                    buf: BufferId(9),
                    payload: CommPayload::Vector(vec![3.0]),
                }];
                let got = t1.exchange(sends, &[(0, BufferId(7))]);
                (got, t1.stats())
            });
            (h0.join().unwrap(), h1.join().unwrap())
        });
        let ((got0, st0), (got1, st1)) = out;
        match &got0[0] {
            CommPayload::Vector(v) => assert_eq!(v, &vec![3.0]),
            _ => panic!("expected vector payload"),
        }
        match &got1[0] {
            CommPayload::Vector(v) => assert_eq!(v, &vec![1.0, 2.0]),
            _ => panic!("expected vector payload"),
        }
        assert_eq!(st0.exchanges, 1);
        assert_eq!(st0.bytes_sent, 16);
        assert_eq!(st1.bytes_sent, 8);
    }

    #[test]
    fn empty_exchanges_still_rendezvous() {
        let group = ThreadTransport::group(3);
        std::thread::scope(|s| {
            for t in group {
                s.spawn(move || {
                    for _ in 0..4 {
                        let got = t.exchange(Vec::new(), &[]);
                        assert!(got.is_empty());
                    }
                    assert_eq!(t.stats().exchanges, 4);
                });
            }
        });
    }
}
