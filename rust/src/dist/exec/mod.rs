//! Real multi-rank SPMD execution of carved rank plans.
//!
//! This is the runtime behind `H2Solver::solve_dist`: the global recorded
//! plan is carved into per-rank [`RankPlan`]s
//! ([`crate::plan::carve`]), each rank gets its **own** device instance
//! and device arena, and the ranks execute their streams concurrently —
//! one OS thread per rank — meeting only at the plan's explicit
//! `Exchange` instructions, which a [`Transport`] endpoint per rank
//! carries across the rank boundary.
//!
//! The division of labor:
//!
//! * [`crate::plan::rank`] decides *what* each rank runs (instruction
//!   filtering, comm placement);
//! * [`crate::plan::exec::Executor`] replays a rank's stream unchanged,
//!   routing `Exchange` steps to the attached transport;
//! * this module owns the *processes*: per-rank devices, per-rank arenas,
//!   the thread-per-rank harness, and aggregation of measured
//!   communication ([`crate::metrics::comm::CommTotals`]).
//!
//! [`ThreadTransport`] is the in-process transport; the [`Transport`]
//! trait is the seam where an inter-process or NCCL-style backend would
//! plug in. Because every rank replays the same collective sequence
//! (statically checked by [`crate::plan::verify::verify_rank_set`]), the
//! rendezvous needs no tags. A rank panic inside a collective would
//! strand its peers, so the carved plans are verified before any thread
//! is spawned (debug builds verify inside [`crate::plan::carve`] too).
//!
//! The modeled α-β driver in [`crate::dist`] is retained as the
//! *prediction* — `DistReport` carries both the model and, when a run
//! came through here, the measured totals, so the two render side by
//! side.

pub mod transport;

pub use transport::{CommPayload, ExchangeMsg, ThreadTransport, Transport, TransportStats};

use crate::batch::device::{Device, DeviceArena, VecRegion};
use crate::h2::H2Matrix;
use crate::metrics::comm::CommTotals;
use crate::plan::{carve, Executor, Plan, RankPlan};
use crate::solver::{BackendSpec, H2Error};
use crate::ulv::SubstMode;

/// Aggregate per-endpoint counters into phase totals: the collective
/// count is per-rank (identical on every endpoint of a verified rank
/// set), bytes sum over ranks, and seconds take the slowest endpoint
/// (the critical path).
fn aggregate(stats: &[TransportStats]) -> CommTotals {
    let exchanges = stats.first().map(|s| s.exchanges).unwrap_or(0);
    debug_assert!(
        stats.iter().all(|s| s.exchanges == exchanges),
        "ranks disagree on collective count: {stats:?}"
    );
    CommTotals {
        exchanges,
        bytes: stats.iter().map(|s| s.bytes_sent).sum(),
        seconds: stats.iter().map(|s| s.seconds).fold(0.0, f64::max),
    }
}

/// A factorized multi-rank session: `P` carved rank plans, `P` device
/// instances, and `P` rank-sharded arenas holding the distributed ULV
/// factor. Building the session runs the factorization once (SPMD,
/// thread-per-rank); [`DistSession::solve`] then replays the carved
/// substitution any number of times against the resident shards.
///
/// Solves take `&self` — each call gets fresh transport endpoints and
/// per-thread workspaces, and the factor shards are only read — so a
/// session can serve concurrent distributed solves.
pub struct DistSession {
    plans: Vec<RankPlan>,
    devices: Vec<Box<dyn Device>>,
    arenas: Vec<Box<dyn DeviceArena>>,
    factor_comm: CommTotals,
    mode: SubstMode,
    n: usize,
}

impl DistSession {
    /// Carve `plan` for (up to) `ranks` ranks and run the distributed
    /// factorization: one device instantiated from `spec` per rank, one
    /// thread per rank, arenas kept resident for later solves.
    ///
    /// The effective rank count is `ranks` rounded down to a power of two
    /// and clamped to the leaf width ([`crate::plan::rank::clamp_ranks`]);
    /// read it back with [`DistSession::ranks`]. Fails with
    /// [`H2Error::BackendUnavailable`] when `spec` cannot instantiate.
    pub fn build(
        spec: &BackendSpec,
        plan: &Plan,
        h2: &H2Matrix,
        ranks: usize,
        mode: SubstMode,
    ) -> Result<DistSession, H2Error> {
        let plans = carve(plan, ranks, mode);
        let p = plans.len();
        let devices = (0..p)
            .map(|_| spec.instantiate())
            .collect::<Result<Vec<Box<dyn Device>>, H2Error>>()?;

        let group = ThreadTransport::group(p);
        let built: Vec<(Box<dyn DeviceArena>, TransportStats)> = std::thread::scope(|s| {
            let handles: Vec<_> = group
                .into_iter()
                .enumerate()
                .map(|(r, t)| {
                    let dev: &dyn Device = devices[r].as_ref();
                    let rp = &plans[r];
                    s.spawn(move || {
                        let arena = Executor::new(dev).with_comm(&t).factorize_rank(rp, h2);
                        (arena, t.stats())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread panicked during distributed factorization"))
                .collect()
        });

        let stats: Vec<TransportStats> = built.iter().map(|(_, st)| *st).collect();
        let arenas = built.into_iter().map(|(a, _)| a).collect();
        Ok(DistSession {
            n: plans[0].n,
            plans,
            devices,
            arenas,
            factor_comm: aggregate(&stats),
            mode,
        })
    }

    /// Effective rank count (power of two, clamped to the leaf width).
    pub fn ranks(&self) -> usize {
        self.plans.len()
    }

    /// The substitution mode the rank plans were carved for.
    pub fn mode(&self) -> SubstMode {
        self.mode
    }

    /// The carved per-rank plans (for inspection / plan dumps).
    pub fn rank_plans(&self) -> &[RankPlan] {
        &self.plans
    }

    /// Measured factorization-phase communication.
    pub fn factor_comm(&self) -> CommTotals {
        self.factor_comm
    }

    /// Run the carved substitution: `b` and the returned solution are in
    /// tree ordering (the solver facade handles the permutation). Each
    /// rank solves its stream against its resident factor shard; the
    /// global solution is stitched from the per-rank owned leaf ranges,
    /// which partition `0..n`. Also returns the measured
    /// substitution-phase communication.
    pub fn solve(&self, b: &[f64]) -> (Vec<f64>, CommTotals) {
        assert_eq!(b.len(), self.n, "right-hand side length must match the plan");
        let p = self.ranks();
        let group = ThreadTransport::group(p);
        let results: Vec<(Vec<f64>, TransportStats)> = std::thread::scope(|s| {
            let handles: Vec<_> = group
                .into_iter()
                .enumerate()
                .map(|(r, t)| {
                    let dev: &dyn Device = self.devices[r].as_ref();
                    let rp = &self.plans[r];
                    let arena = self.arenas[r].as_ref();
                    s.spawn(move || {
                        let mut ws = VecRegion::new(dev, 0);
                        let x = Executor::new(dev)
                            .with_comm(&t)
                            .solve_program_in(&rp.solve, rp.n, arena, &mut ws, b);
                        (x, t.stats())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread panicked during distributed substitution"))
                .collect()
        });

        let mut x = vec![0.0; self.n];
        for (r, (xr, _)) in results.iter().enumerate() {
            for &(s0, e) in &self.plans[r].store_ranges {
                x[s0..e].copy_from_slice(&xr[s0..e]);
            }
        }
        let stats: Vec<TransportStats> = results.iter().map(|(_, st)| *st).collect();
        (x, aggregate(&stats))
    }
}
