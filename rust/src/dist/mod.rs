//! Distributed-memory runtime: real SPMD execution plus an α-β
//! prediction model (paper §5).
//!
//! Multi-rank execution is *real*: [`exec::DistSession`] carves the
//! recorded plan into per-rank streams ([`crate::plan::carve`]), gives
//! each rank its own device instance and rank-sharded arena, and runs
//! the ranks concurrently — thread-per-rank behind the
//! [`exec::Transport`] seam — meeting only at the plan's explicit
//! `Exchange` instructions. The sharding follows the paper:
//!
//! * every rank owns a contiguous range of leaf subtrees — the 1-D
//!   distribution enabled by the tree-ordered points (paper §5);
//! * within a *distributed* level (width ≥ P) the inherently parallel
//!   factorization has no cross-box dependencies, so it needs **no**
//!   communication there at all;
//! * the top `log2 P` levels are computed redundantly on every rank after
//!   an allgather whose message sizes depend only on leaf size and rank —
//!   *not* on N (the paper's §5.1 claim: "both the number of collective
//!   communication function calls and the message sizes are independent of
//!   the problem size N");
//! * substitution additionally exchanges neighbor segments at distributed
//!   levels — the O(P) neighbor-communication regime of Figure 22.
//!
//! This module keeps the *prediction* side: communication volume and the
//! per-rank FLOP split are modeled from the H² structure, and modeled
//! wall times combine that split with an α-β (latency/bandwidth)
//! collective cost model ([`CommModel`], [`NCCL_LIKE`]). When a solve
//! runs through the real path, [`DistReport::measured`] carries the
//! transport's observed totals so prediction and measurement render side
//! by side.

pub mod exec;

use crate::batch::device::{Device, DeviceArena, VecRegion};
use crate::batch::native::NativeBackend;
use crate::h2::H2Matrix;
use crate::metrics::comm::CommMeasurement;
use crate::metrics::flops;
use crate::plan::Plan;
use crate::ulv::{FactorMeta, SubstMode, UlvFactor};
use std::collections::HashSet;

/// α-β (latency/bandwidth) communication cost model plus a modeled
/// per-rank dense compute rate for converting FLOP splits into times.
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    /// Seconds per communication call (α).
    pub latency_s: f64,
    /// Link bandwidth in GB/s (1/β).
    pub gb_per_s: f64,
    /// Modeled per-rank compute rate in FLOP/s.
    pub flop_per_s: f64,
}

impl CommModel {
    /// Modeled wall time of `ops` communication calls moving `bytes` bytes.
    pub fn cost(&self, ops: u64, bytes: u64) -> f64 {
        ops as f64 * self.latency_s + bytes as f64 / (self.gb_per_s * 1e9)
    }
}

/// NCCL-over-NVLink-like constants (the paper's A100 platform class).
pub const NCCL_LIKE: CommModel =
    CommModel { latency_s: 12e-6, gb_per_s: 80.0, flop_per_s: 2.0e12 };

/// Result of a distributed factorize + solve: the solution, the modeled
/// (predicted) communication volumes, and — when the run came through
/// the real SPMD path ([`exec::DistSession`]) — the measured totals.
pub struct DistReport {
    /// Solution in tree ordering (same ordering as the input right-hand
    /// side), identical across rank counts.
    pub x: Vec<f64>,
    /// Effective rank count used (power of two, clamped to the leaf width).
    pub ranks: usize,
    /// Modeled factorization communication volume in bytes.
    pub factor_bytes: u64,
    /// Modeled factorization collective-call count.
    pub factor_ops: u64,
    /// Modeled substitution communication volume in bytes.
    pub subst_bytes: u64,
    /// Modeled substitution communication-call count.
    pub subst_ops: u64,
    /// Per-rank `(factorization, substitution)` FLOPs.
    pub rank_flops: Vec<(u64, u64)>,
    /// Measured communication from the real multi-rank run, `None` when
    /// the report came from the modeled driver alone.
    pub measured: Option<CommMeasurement>,
}

impl DistReport {
    /// Modeled factorization time: slowest rank's compute + communication.
    pub fn factor_time(&self, model: &CommModel) -> f64 {
        let peak = self.rank_flops.iter().map(|&(f, _)| f).max().unwrap_or(0);
        peak as f64 / model.flop_per_s + model.cost(self.factor_ops, self.factor_bytes)
    }

    /// Modeled substitution time: slowest rank's compute + communication.
    pub fn subst_time(&self, model: &CommModel) -> f64 {
        let peak = self.rank_flops.iter().map(|&(_, s)| s).max().unwrap_or(0);
        peak as f64 / model.flop_per_s + model.cost(self.subst_ops, self.subst_bytes)
    }
}

/// Owner rank of box `i` at a level of `width` boxes (`width >= p`,
/// contiguous subtree distribution).
#[inline]
fn owner(i: usize, width: usize, p: usize) -> usize {
    i * p / width
}

/// Run the simulated P-rank SPMD factorize + solve.
///
/// `b` is the right-hand side in **tree** ordering; the returned solution
/// is in tree ordering too (the [`crate::solver::H2Solver`] facade handles
/// the permutation for callers working in original point order). `ranks`
/// is rounded down to a power of two and clamped to one rank per leaf.
///
/// Factorizes `h2` on a fresh native backend (keeping the factor resident
/// in the device arena, with no host mirror, for the substitution);
/// callers that already hold a ULV factor (notably
/// [`crate::solver::H2Solver::solve_dist`]) should use
/// [`dist_solve_driver_in`] to avoid the redundant factorization.
pub fn dist_solve_driver(
    h2: &H2Matrix,
    ranks: usize,
    b: &[f64],
    mode: SubstMode,
) -> DistReport {
    let exec = NativeBackend::new();
    let plan = std::sync::Arc::new(crate::plan::record(h2));
    let arena = crate::plan::Executor::new(&exec).factorize_device_only(&plan, h2);
    let meta = plan.factor_meta();
    let mut ws = VecRegion::new(&exec, 0);
    dist_solve_driver_in(&plan, &meta, &exec, arena.as_ref(), &mut ws, ranks, b, mode)
}

/// [`dist_solve_driver`] over an existing ULV factor and backend: only the
/// substitution runs numerically; factorization cost is *modeled* from the
/// factor's block shapes. Uploads the factor into a transient device arena;
/// callers that already hold a resident factor region (the session facade)
/// use [`dist_solve_driver_in`].
pub fn dist_solve_driver_with(
    fac: &UlvFactor,
    exec: &dyn Device,
    ranks: usize,
    b: &[f64],
    mode: SubstMode,
) -> DistReport {
    let arena = crate::plan::Executor::new(exec).upload_factor(fac);
    let meta = fac.meta();
    let mut ws = VecRegion::new(exec, 0);
    dist_solve_driver_in(&fac.plan, &meta, exec, arena.as_ref(), &mut ws, ranks, b, mode)
}

/// [`dist_solve_driver_with`] against a factor region that already holds
/// the factor resident — no per-call factor upload, no host mirror: every
/// block shape the model needs comes from [`FactorMeta`]. The factor
/// region is only read and the substitution writes to the caller's
/// workspace, so concurrent distributed solves on one session coexist
/// with plain solves.
pub fn dist_solve_driver_in(
    plan: &Plan,
    meta: &FactorMeta,
    exec: &dyn Device,
    factor: &dyn DeviceArena,
    ws: &mut VecRegion,
    ranks: usize,
    b: &[f64],
    mode: SubstMode,
) -> DistReport {
    let leaf_width = 1usize << meta.depth;
    let mut p = 1usize;
    while p * 2 <= ranks.max(1) && p * 2 <= leaf_width {
        p *= 2;
    }

    // The numerical pipeline: identical math for every rank count.
    let x = crate::plan::Executor::new(exec).solve_in(plan, factor, ws, b, mode);
    model_report(meta, p, x)
}

/// The α-β *prediction* alone: modeled communication volumes and per-rank
/// FLOP splits for an (already clamped, power-of-two) rank count `p`,
/// derived entirely from the factor's block shapes. `x` is wrapped into
/// the report unchanged — pass the solution computed elsewhere (the real
/// SPMD path computes it through [`exec::DistSession::solve`]).
pub fn model_report(meta: &FactorMeta, p: usize, x: Vec<f64>) -> DistReport {
    let mut rank_flops = vec![(0u64, 0u64); p];
    let mut factor_bytes = 0u64;
    let mut factor_ops = 0u64;
    let mut subst_bytes = 0u64;
    let mut subst_ops = 0u64;

    for lm in &meta.levels {
        let width = lm.width();
        let distributed = width >= p;

        // Per-box compute estimates from the factor's block shapes (all in
        // the meta — the values themselves are never touched).
        let mut box_factor = vec![0u64; width];
        let mut box_subst = vec![0u64; width];
        for i in 0..width {
            let (ndof, rank, nred) = (lm.ndof(i), lm.rank(i), lm.nred(i));
            box_factor[i] += flops::potrf_flops(nred);
            if rank > 0 && nred > 0 {
                box_factor[i] += flops::gemm_flops(rank, rank, nred);
            }
            // Basis applied twice (forward + backward) plus the two
            // diagonal TRSVs.
            box_subst[i] += 4 * (ndof * ndof) as u64 + 4 * (nred * nred) as u64;
        }
        let lr_keys: HashSet<(usize, usize)> = lm.lr.iter().copied().collect();
        let ls_keys: HashSet<(usize, usize)> = lm.ls.iter().copied().collect();
        for &(j, i) in &lm.near {
            let ni = lm.ndof(i);
            let nj = lm.ndof(j);
            // Sparsify F_ji = U_jᵀ A_ji U_i, charged to the column owner.
            box_factor[i] += flops::gemm_flops(nj, ni, nj) + flops::gemm_flops(nj, ni, ni);
            if lr_keys.contains(&(j, i)) {
                // L(r)_ji panel: (nred_j, nred_i).
                box_factor[i] += flops::trsm_flops(lm.nred(i), lm.nred(j));
                box_subst[i] += 4 * (lm.nred(j) * lm.nred(i)) as u64;
            }
            if ls_keys.contains(&(j, i)) {
                // L(s)_ji panel: (rank_j, nred_i).
                box_factor[i] += flops::trsm_flops(lm.nred(i), lm.rank(j));
                box_subst[i] += 4 * (lm.rank(j) * lm.nred(i)) as u64;
            }
        }

        if distributed {
            for i in 0..width {
                let o = owner(i, width, p);
                rank_flops[o].0 += box_factor[i];
                rank_flops[o].1 += box_subst[i];
            }
            // Substitution-only neighbor exchange: near pairs straddling a
            // rank boundary ship the source box's solved segments.
            let mut links: HashSet<(usize, usize)> = HashSet::new();
            for &(j, i) in &lm.near {
                let oi = owner(i, width, p);
                let oj = owner(j, width, p);
                if oi != oj {
                    subst_bytes += 8 * lm.ndof(i) as u64;
                    links.insert((oi.min(oj), oi.max(oj)));
                }
            }
            subst_ops += links.len() as u64;
        } else {
            // Redundant top levels: every rank computes every box after an
            // allgather of the level's sparsified near blocks (factor) and
            // solved segments (substitution). Block shapes here are bounded
            // by the rank budget — independent of N.
            let bf: u64 = box_factor.iter().sum();
            let bs: u64 = box_subst.iter().sum();
            for r in rank_flops.iter_mut() {
                r.0 += bf;
                r.1 += bs;
            }
            for &(j, i) in &lm.near {
                factor_bytes += 8 * (lm.ndof(j) * lm.ndof(i)) as u64;
            }
            factor_ops += 1;
            let seg: usize = (0..width).map(|i| lm.ndof(i)).sum();
            subst_bytes += 8 * seg as u64;
            subst_ops += 1;
        }
    }

    // Root factorization + solve: redundant on every rank (Algorithm 2
    // line 22); the merged root block is allgathered first when P > 1.
    let root_n = meta.root_n;
    for r in rank_flops.iter_mut() {
        r.0 += flops::potrf_flops(root_n);
        r.1 += 2 * (root_n * root_n) as u64;
    }
    if p > 1 {
        factor_bytes += 8 * (root_n * root_n) as u64;
        factor_ops += 1;
        subst_bytes += 8 * root_n as u64;
        subst_ops += 1;
    }

    DistReport {
        x,
        ranks: p,
        factor_bytes,
        factor_ops,
        subst_bytes,
        subst_ops,
        rank_flops,
        measured: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::construct::H2Config;
    use crate::geometry::Geometry;
    use crate::kernels::KernelFn;
    use crate::util::Rng;

    #[test]
    fn rank_count_is_clamped_to_leaf_width() {
        let g = Geometry::sphere_surface(256, 51);
        let cfg = H2Config { leaf_size: 64, max_rank: 16, far_samples: 64, ..Default::default() };
        let h2 = H2Matrix::construct(&g, &KernelFn::laplace(), &cfg);
        let mut rng = Rng::new(1);
        let b: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        // 256 points / leaf 64 -> 4 leaves; asking for 64 ranks clamps to 4.
        let report = dist_solve_driver(&h2, 64, &b, SubstMode::Parallel);
        assert_eq!(report.ranks, 4);
        assert_eq!(report.rank_flops.len(), 4);
        // Non-power-of-two requests round down.
        let report3 = dist_solve_driver(&h2, 3, &b, SubstMode::Parallel);
        assert_eq!(report3.ranks, 2);
    }

    #[test]
    fn comm_model_cost_is_linear() {
        let m = CommModel { latency_s: 1e-6, gb_per_s: 100.0, flop_per_s: 1e12 };
        let c1 = m.cost(1, 0);
        let c2 = m.cost(2, 0);
        assert!((c2 - 2.0 * c1).abs() < 1e-18);
        assert!(m.cost(0, 1_000_000_000) > 0.0);
    }
}
