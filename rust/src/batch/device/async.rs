//! [`AsyncDevice`]: an overlapping multi-stream executor wrapped around
//! any host-synchronous [`Device`].
//!
//! The paper's schedule property — level *k*'s batched TRSM/Schur work has
//! no dependency on level *k+1*'s sparsify uploads, and the substitution
//! chain decomposes into dependency-free runs — only pays off if an
//! executor actually runs them concurrently. `AsyncDevice` does exactly
//! that for the factorization replay **and** the solve path:
//!
//! * **Journaled arena traffic.** Arenas created by an `AsyncDevice` are
//!   [`AsyncArena`]s: matrix `upload`s, vector `upload_vec`s, `free`s,
//!   every factorization [`Launch`], and every substitution launch
//!   ([`Device::launch_solve`]) are *journaled* as asynchronous operations
//!   instead of executing on the issuing thread. `stream(level)` routes
//!   subsequent operations to the queue `level % streams` (two queues by
//!   default — the paper's double-buffer), each drained in FIFO order by
//!   its own worker thread.
//! * **A `BufferId`-granular hazard tracker with a shared-reader role.**
//!   At enqueue time every operation declares per-`(arena, buffer)` read
//!   and write sets (from the launch operand lists via
//!   [`super::launch_operands`], or the touched id for uploads/frees).
//!   Factorization launches declare all operands as *writes* — their
//!   staging strategy physically moves buffers, so per-buffer ordering is
//!   a single last-toucher chain (see `OwnedLaunch::operand_set` for why
//!   no recorded plan loses overlap to this). Substitution launches use
//!   the role split for real: factor matrices are **shared reads**
//!   (readers only order against the previous writer, never against each
//!   other), so concurrent solves reading the same Cholesky panel do not
//!   serialize; vector operands are writes in the owning workspace. A
//!   write depends on the previous writer *and* every reader since — the
//!   full RAW/WAR/WAW order. A worker only starts an operation once all
//!   its edges have completed. Issue order is the semantic order
//!   (device.rs "Streams, fences, and hazards"), so results are
//!   **bit-identical** to the wrapped device — overlap reorders *when*
//!   kernels run, never their operands.
//! * **Zero-copy staging for factor launches; lock-shared execution for
//!   solve launches.** A factorization worker executes a launch by
//!   *moving* its operand buffers from the shared arena into a private
//!   arena (pointer moves via the `HostArena` fast path of
//!   [`super::put_owned`]), running the wrapped device's kernel outside
//!   any lock, and moving the results back. A substitution worker instead
//!   takes the factor arena's **read** lock (many solve workers share it
//!   simultaneously — the refcounted-reader analog of copy-on-read) and
//!   the workspace's write lock, then runs the wrapped
//!   `launch_solve` in place: the factor is never moved or copied.
//! * **Per-arena scoped drains.** Synchronous arena traffic (allocs,
//!   downloads, balance queries) waits only for *this arena's* in-flight
//!   operations, so independent RHS batches pipelining through distinct
//!   workspaces never quiesce each other. Result reads (`download`,
//!   `download_vec`, `take`) additionally re-raise a panic recorded
//!   against their arena — the per-arena form of the fence contract.
//!   [`Device::fence`] still drains *everything* and re-raises the first
//!   recorded panic on the issuing thread.
//! * **Observable overlap.** Every executed operation is recorded as an
//!   [`OverlapEvent`] (stream, level, wall-clock interval); solve
//!   launches and RHS uploads are first-class events, so
//!   [`Device::take_overlap_trace`] — and the `RunReport` built from it —
//!   shows solve-path transfer/compute overlap, not just the
//!   factorization replay.
//!
//! The transfer clone in [`AsyncArena::upload`] / `upload_vec` is this
//! emulation's analog of staging into pinned host memory: the borrowed
//! source cannot outlive the call, so the owned copy is taken at issue
//! time and the device-side insertion (a pointer move on host arenas)
//! happens on the worker — genuinely concurrent with other streams'
//! compute.

use super::{launch_operands, put_owned, Device, DeviceArena, Launch};
use crate::linalg::Matrix;
use crate::metrics::overlap::{OverlapEvent, OverlapKind, OverlapTrace};
use crate::plan::{
    BasisItem, BufferId, ExtractItem, MergeItem, SparsifyItem, SyrkItem, TrsmItem,
};
use std::any::Any;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};
use std::time::Instant;

/// Default number of stream queues: two adjacent tree levels in flight —
/// the paper's double-buffering.
pub const DEFAULT_STREAMS: usize = 2;

/// One journaled operation as the runtime hazard tracker saw it at
/// enqueue time (recorded while [`AsyncDevice::enable_hazard_log`] is on):
/// sequence number, placement, operand set, and the full dependency edges
/// *before* completed-op pruning — directly comparable, op for op, to the
/// static graphs from [`crate::plan::verify::hazard_graph`] (factor) and
/// [`crate::plan::verify::solve_hazard_graph`] (substitution).
#[derive(Clone, Debug)]
pub struct HazardRecord {
    pub seq: u64,
    pub opcode: &'static str,
    pub stream: usize,
    pub level: usize,
    pub operands: Vec<u32>,
    pub deps: Vec<u64>,
}

// ---------------------------------------------------------------------
// Owned launches (journal entries cannot borrow the plan).
// ---------------------------------------------------------------------

/// An owned factorization launch: the journal's copy of a [`Launch`] whose
/// operand lists are borrowed from the plan. Substitution opcodes take the
/// [`OwnedSolveLaunch`] route instead.
#[derive(Clone, Debug)]
enum OwnedLaunch {
    Potrf { level: usize, bufs: Vec<BufferId> },
    TrsmRightLt { level: usize, items: Vec<TrsmItem> },
    SchurSelf { level: usize, items: Vec<SyrkItem> },
    Sparsify { level: usize, items: Vec<SparsifyItem> },
    Extract { items: Vec<ExtractItem> },
    Merge { items: Vec<MergeItem> },
}

impl OwnedLaunch {
    /// Copy a factorization-phase launch; `None` for substitution opcodes.
    fn from_launch(launch: &Launch<'_>) -> Option<OwnedLaunch> {
        Some(match launch {
            Launch::Potrf { level, bufs } => {
                OwnedLaunch::Potrf { level: *level, bufs: bufs.to_vec() }
            }
            Launch::TrsmRightLt { level, items } => {
                OwnedLaunch::TrsmRightLt { level: *level, items: items.to_vec() }
            }
            Launch::SchurSelf { level, items } => {
                OwnedLaunch::SchurSelf { level: *level, items: items.to_vec() }
            }
            Launch::Sparsify { level, items } => {
                OwnedLaunch::Sparsify { level: *level, items: items.to_vec() }
            }
            Launch::Extract { items } => OwnedLaunch::Extract { items: items.to_vec() },
            Launch::Merge { items } => OwnedLaunch::Merge { items: items.to_vec() },
            _ => return None,
        })
    }

    /// Re-borrow as the trait-level launch type.
    fn as_launch(&self) -> Launch<'_> {
        match self {
            OwnedLaunch::Potrf { level, bufs } => Launch::Potrf { level: *level, bufs },
            OwnedLaunch::TrsmRightLt { level, items } => {
                Launch::TrsmRightLt { level: *level, items }
            }
            OwnedLaunch::SchurSelf { level, items } => {
                Launch::SchurSelf { level: *level, items }
            }
            OwnedLaunch::Sparsify { level, items } => {
                Launch::Sparsify { level: *level, items }
            }
            OwnedLaunch::Extract { items } => Launch::Extract { items },
            OwnedLaunch::Merge { items } => Launch::Merge { items },
        }
    }

    /// Every operand id, deduplicated, declared as an *exclusive* hazard
    /// set. The contract (device.rs rule 2) permits concurrent readers,
    /// but the factor-launch staging strategy physically *moves* operands
    /// into a launch's private arena, so it conservatively serializes
    /// read-read pairs too. No recorded plan loses overlap to this:
    /// same-level launches are already FIFO on one stream, and every
    /// cross-level pair is either buffer-disjoint (uploads vs prior
    /// compute — the overlap that matters) or genuinely ordered (merge →
    /// next-level sparsify). Substitution launches, which *do* share the
    /// factor across concurrent solves, use the shared-reader role
    /// instead (see `solve_roles`).
    fn operand_set(&self) -> Vec<BufferId> {
        let ops = launch_operands(&self.as_launch());
        let mut set = ops.mat_reads;
        set.extend(ops.mat_rw);
        set.extend(ops.mat_writes);
        set.sort_unstable_by_key(|b| b.0);
        set.dedup();
        set
    }

    /// Rewrite every operand id through `map` (shared-arena id → private
    /// execution-arena id).
    fn remap(&mut self, map: &HashMap<u32, BufferId>) {
        fn r(map: &HashMap<u32, BufferId>, b: &mut BufferId) {
            *b = map[&b.0];
        }
        match self {
            OwnedLaunch::Potrf { bufs, .. } => {
                for b in bufs {
                    r(map, b);
                }
            }
            OwnedLaunch::TrsmRightLt { items, .. } => {
                for it in items {
                    r(map, &mut it.l);
                    r(map, &mut it.b);
                }
            }
            OwnedLaunch::SchurSelf { items, .. } => {
                for it in items {
                    r(map, &mut it.a);
                    r(map, &mut it.c);
                }
            }
            OwnedLaunch::Sparsify { items, .. } => {
                for it in items {
                    r(map, &mut it.u);
                    r(map, &mut it.a);
                    r(map, &mut it.v);
                    r(map, &mut it.dst);
                }
            }
            OwnedLaunch::Extract { items } => {
                for it in items {
                    r(map, &mut it.src);
                    r(map, &mut it.dst);
                }
            }
            OwnedLaunch::Merge { items } => {
                for it in items {
                    r(map, &mut it.dst);
                    for p in &mut it.parts {
                        r(map, &mut p.src);
                    }
                }
            }
        }
    }
}

/// An owned substitution launch: the journal's copy of a solve-phase
/// [`Launch`]. `Exchange`/`ExchangeVec` never enter the journal (the
/// executor routes them through the transport around an explicit fence).
#[derive(Clone, Debug)]
enum OwnedSolveLaunch {
    ApplyBasis { level: usize, trans: bool, items: Vec<BasisItem> },
    TrsvFwd { level: usize, items: Vec<(BufferId, BufferId)> },
    TrsvBwd { level: usize, items: Vec<(BufferId, BufferId)> },
    GemvAcc {
        level: usize,
        trans: bool,
        alpha: f64,
        items: Vec<(BufferId, BufferId, BufferId)>,
    },
    Split { items: Vec<(BufferId, usize, BufferId, BufferId)> },
    Concat { items: Vec<(BufferId, BufferId, BufferId)> },
    CopyBuf { items: Vec<(BufferId, BufferId)> },
    AddVec { items: Vec<(BufferId, BufferId, BufferId)> },
    RootSolve { l: BufferId, x: BufferId },
}

impl OwnedSolveLaunch {
    /// Copy a substitution-phase launch; `None` for factorization opcodes
    /// and the transport-routed exchanges.
    fn from_launch(launch: &Launch<'_>) -> Option<OwnedSolveLaunch> {
        Some(match launch {
            Launch::ApplyBasis { level, trans, items } => OwnedSolveLaunch::ApplyBasis {
                level: *level,
                trans: *trans,
                items: items.to_vec(),
            },
            Launch::TrsvFwd { level, items } => {
                OwnedSolveLaunch::TrsvFwd { level: *level, items: items.to_vec() }
            }
            Launch::TrsvBwd { level, items } => {
                OwnedSolveLaunch::TrsvBwd { level: *level, items: items.to_vec() }
            }
            Launch::GemvAcc { level, trans, alpha, items } => OwnedSolveLaunch::GemvAcc {
                level: *level,
                trans: *trans,
                alpha: *alpha,
                items: items.to_vec(),
            },
            Launch::Split { items } => OwnedSolveLaunch::Split { items: items.to_vec() },
            Launch::Concat { items } => OwnedSolveLaunch::Concat { items: items.to_vec() },
            Launch::CopyBuf { items } => {
                OwnedSolveLaunch::CopyBuf { items: items.to_vec() }
            }
            Launch::AddVec { items } => OwnedSolveLaunch::AddVec { items: items.to_vec() },
            Launch::RootSolve { l, x } => OwnedSolveLaunch::RootSolve { l: *l, x: *x },
            _ => return None,
        })
    }

    /// Re-borrow as the trait-level launch type.
    fn as_launch(&self) -> Launch<'_> {
        match self {
            OwnedSolveLaunch::ApplyBasis { level, trans, items } => {
                Launch::ApplyBasis { level: *level, trans: *trans, items }
            }
            OwnedSolveLaunch::TrsvFwd { level, items } => {
                Launch::TrsvFwd { level: *level, items }
            }
            OwnedSolveLaunch::TrsvBwd { level, items } => {
                Launch::TrsvBwd { level: *level, items }
            }
            OwnedSolveLaunch::GemvAcc { level, trans, alpha, items } => Launch::GemvAcc {
                level: *level,
                trans: *trans,
                alpha: *alpha,
                items,
            },
            OwnedSolveLaunch::Split { items } => Launch::Split { items },
            OwnedSolveLaunch::Concat { items } => Launch::Concat { items },
            OwnedSolveLaunch::CopyBuf { items } => Launch::CopyBuf { items },
            OwnedSolveLaunch::AddVec { items } => Launch::AddVec { items },
            OwnedSolveLaunch::RootSolve { l, x } => Launch::RootSolve { l: *l, x: *x },
        }
    }
}

/// Classify a substitution launch's operands into the hazard tracker's
/// shared-reader roles, keyed by arena: factor matrices are shared reads
/// in the factor arena, vector reads are reads in the workspace, and
/// updated/written vectors are workspace writes. Roles come from the one
/// shared classifier ([`super::launch_operands`]) so this split, the
/// synchronous backends, and the static solve hazard graph cannot drift.
fn solve_roles(
    launch: &Launch<'_>,
    factor_id: u64,
    ws_id: u64,
) -> (Vec<(u64, BufferId)>, Vec<(u64, BufferId)>) {
    let ops = launch_operands(launch);
    let mut reads: Vec<(u64, BufferId)> =
        ops.mat_reads.iter().map(|&b| (factor_id, b)).collect();
    reads.extend(ops.vec_reads.iter().map(|&b| (ws_id, b)));
    // Substitution launches never write factor matrices (the verifier's
    // read-only-factor rule); mat_rw/mat_writes are mapped defensively.
    let mut writes: Vec<(u64, BufferId)> =
        ops.mat_rw.iter().chain(&ops.mat_writes).map(|&b| (factor_id, b)).collect();
    writes.extend(ops.vec_rw.iter().chain(&ops.vec_writes).map(|&b| (ws_id, b)));
    reads.sort_unstable();
    reads.dedup();
    writes.sort_unstable();
    writes.dedup();
    (reads, writes)
}

// ---------------------------------------------------------------------
// The stream engine.
// ---------------------------------------------------------------------

/// The shared inner arena of one [`AsyncArena`]: the wrapped device's own
/// arena behind a lock that workers (briefly for pointer-move staging,
/// shared for the whole kernel on solve launches) and synchronous readers
/// share.
struct InnerArena {
    id: u64,
    cell: RwLock<Box<dyn DeviceArena>>,
}

/// Lock an arena cell for writing, recovering from poisoning. A panic
/// while the guard is held (a kernel breakdown, a take of a dead buffer)
/// is already recorded by the engine and re-raised at the next `fence` (or
/// the owning arena's next result read); the arena contents are then
/// exactly as unspecified as on a synchronous device after the same panic
/// — but the lock itself must stay usable so the PR-4 unwind guards
/// (workspace reset, pool return) and post-repair traffic keep working.
fn write_cell(cell: &RwLock<Box<dyn DeviceArena>>) -> RwLockWriteGuard<'_, Box<dyn DeviceArena>> {
    cell.write().unwrap_or_else(|e| e.into_inner())
}

/// Shared-lock counterpart of [`write_cell`] (same poisoning rationale).
fn read_cell(cell: &RwLock<Box<dyn DeviceArena>>) -> RwLockReadGuard<'_, Box<dyn DeviceArena>> {
    cell.read().unwrap_or_else(|e| e.into_inner())
}

/// One journaled operation's payload.
enum OpAction {
    /// Insert a staged matrix (the "device-side" half of an upload).
    Upload { arena: Arc<InnerArena>, id: BufferId, mat: Matrix },
    /// Insert a staged vector (an RHS segment upload).
    UploadVec { arena: Arc<InnerArena>, id: BufferId, v: Vec<f64> },
    /// Release buffers (a plan `Free` step).
    Free { arena: Arc<InnerArena>, bufs: Vec<BufferId> },
    /// Execute a batched factorization launch (move-staged).
    Launch { arena: Arc<InnerArena>, launch: OwnedLaunch },
    /// Execute a batched substitution launch: factor read-locked (shared
    /// across concurrent solve workers), workspace write-locked.
    SolveLaunch {
        factor: Arc<InnerArena>,
        ws: Arc<InnerArena>,
        launch: OwnedSolveLaunch,
    },
}

/// One journal entry: payload plus the hazard edges it must wait on.
struct Op {
    seq: u64,
    /// Seqs of still-pending conflicting operations (strictly earlier).
    deps: Vec<u64>,
    /// Arena this operation is accounted against (scoped drains, panic
    /// attribution): the touched arena, or the *workspace* for solve
    /// launches (the factor is only read).
    home: u64,
    level: usize,
    kind: OverlapKind,
    opcode: &'static str,
    action: OpAction,
}

/// Hazard-table entry for one `(arena, buffer)` pair: the last writer plus
/// every shared reader journaled since. A read depends on the writer only
/// (readers never order against each other); a write depends on the writer
/// *and* all readers, then becomes the new writer. Factorization traffic
/// declares writes exclusively, which degenerates to the old single
/// last-toucher chain.
#[derive(Default)]
struct Access {
    writer: Option<u64>,
    readers: Vec<u64>,
}

struct EngineState {
    queues: Vec<VecDeque<Op>>,
    next_seq: u64,
    /// Completed op seqs (cleared whenever the engine goes quiescent).
    done: HashSet<u64>,
    /// Hazard table: last writer + readers per (arena, buffer).
    access: HashMap<(u64, u32), Access>,
    /// Queued + executing operations.
    inflight: usize,
    /// Queued + executing operations per home arena (scoped drains).
    arena_inflight: HashMap<u64, usize>,
    current_stream: usize,
    current_level: usize,
    trace: Vec<OverlapEvent>,
    /// Differential-audit log: `Some` while hazard recording is enabled.
    hazard_log: Option<Vec<HazardRecord>>,
    /// First worker panic per home arena, in recording order. Re-raised by
    /// the owning arena's next result read or the next `fence`.
    panics: Vec<(u64, Box<dyn Any + Send>)>,
    shutdown: bool,
}

/// The multi-stream scheduler shared by an [`AsyncDevice`] and every
/// [`AsyncArena`] it creates.
struct Engine {
    device: Arc<dyn Device + Send + Sync>,
    state: Mutex<EngineState>,
    cv: Condvar,
    origin: Instant,
    streams: usize,
    /// Mirror of `EngineState::inflight` for the lock-free drain fast
    /// path (data visibility itself comes from the arena locks).
    pending: AtomicUsize,
    /// Mirror of `EngineState::panics.len()` for the lock-free no-panic
    /// fast path of result reads.
    panic_count: AtomicUsize,
    next_arena: AtomicU64,
}

impl Engine {
    fn new(device: Arc<dyn Device + Send + Sync>, streams: usize) -> Engine {
        Engine {
            device,
            state: Mutex::new(EngineState {
                queues: (0..streams).map(|_| VecDeque::new()).collect(),
                next_seq: 0,
                done: HashSet::new(),
                access: HashMap::new(),
                inflight: 0,
                arena_inflight: HashMap::new(),
                current_stream: 0,
                current_level: usize::MAX,
                trace: Vec::new(),
                hazard_log: None,
                panics: Vec::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            origin: Instant::now(),
            streams,
            pending: AtomicUsize::new(0),
            panic_count: AtomicUsize::new(0),
            next_arena: AtomicU64::new(0),
        }
    }

    /// Lock the engine state, recovering from poisoning: a thread that
    /// panicked while holding the lock (a poisoned `cv.wait`, an unwinding
    /// issuer) must not turn every later `fence()` into a `PoisonError`
    /// panic — the recorded worker payload is the error that matters, and
    /// it is re-raised through the normal panic slots below.
    fn lock_state(&self) -> MutexGuard<'_, EngineState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Condvar wait with the same poison recovery as [`Engine::lock_state`].
    fn wait_state<'a>(
        &'a self,
        guard: MutexGuard<'a, EngineState>,
    ) -> MutexGuard<'a, EngineState> {
        self.cv.wait(guard).unwrap_or_else(|e| e.into_inner())
    }

    /// Journal one operation: compute its hazard edges against the pending
    /// set (reads order after the last writer; writes order after the
    /// writer and every reader), append it to the current stream's queue,
    /// and return without executing. `home` is the arena the operation is
    /// accounted against for scoped drains and panic attribution. After
    /// device shutdown (late arena traffic) the operation degrades to
    /// synchronous execution on the caller thread.
    fn enqueue(
        &self,
        home: u64,
        reads: &[(u64, BufferId)],
        writes: &[(u64, BufferId)],
        kind: OverlapKind,
        opcode: &'static str,
        action: OpAction,
    ) {
        let mut guard = self.lock_state();
        if guard.shutdown {
            drop(guard);
            exec_op(self.device.as_ref(), action);
            return;
        }
        let seq = guard.next_seq;
        guard.next_seq += 1;
        // Full dependency edges first (the semantic set the static hazard
        // graphs predict), then prune already-completed ops for the
        // scheduler's working set.
        let mut full: Vec<u64> = Vec::new();
        for &(aid, b) in reads {
            if let Some(acc) = guard.access.get(&(aid, b.0)) {
                if let Some(prev) = acc.writer {
                    full.push(prev);
                }
            }
        }
        for &(aid, b) in writes {
            if let Some(acc) = guard.access.get(&(aid, b.0)) {
                if let Some(prev) = acc.writer {
                    full.push(prev);
                }
                full.extend(acc.readers.iter().copied());
            }
        }
        full.sort_unstable();
        full.dedup();
        let deps: Vec<u64> = full.iter().copied().filter(|d| !guard.done.contains(d)).collect();
        if let Some(log) = guard.hazard_log.as_mut() {
            let mut operands: Vec<u32> =
                reads.iter().chain(writes).map(|&(_, b)| b.0).collect();
            operands.sort_unstable();
            operands.dedup();
            log.push(HazardRecord {
                seq,
                opcode,
                stream: guard.current_stream,
                level: guard.current_level,
                operands,
                deps: full,
            });
        }
        for &(aid, b) in reads {
            guard.access.entry((aid, b.0)).or_default().readers.push(seq);
        }
        for &(aid, b) in writes {
            let acc = guard.access.entry((aid, b.0)).or_default();
            acc.writer = Some(seq);
            acc.readers.clear();
        }
        let stream = guard.current_stream;
        let level = guard.current_level;
        guard.inflight += 1;
        *guard.arena_inflight.entry(home).or_insert(0) += 1;
        self.pending.fetch_add(1, Ordering::SeqCst);
        guard.queues[stream].push_back(Op { seq, deps, home, level, kind, opcode, action });
        drop(guard);
        self.cv.notify_all();
    }

    /// Wait until every journaled operation has completed. Lock-free when
    /// the engine is already quiescent.
    fn drain(&self) {
        if self.pending.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut st = self.lock_state();
        while st.inflight > 0 {
            st = self.wait_state(st);
        }
        // Quiescent: nothing references the bookkeeping any more.
        st.done.clear();
        st.access.clear();
        st.arena_inflight.clear();
    }

    /// Wait until every operation accounted against `home` has completed
    /// (operations of *other* arenas keep flowing — this is what lets
    /// independent RHS workspaces pipeline instead of quiescing each
    /// other). With `raise`, additionally re-raise a panic recorded
    /// against `home` — the per-arena half of the fence contract, used by
    /// result reads. Never raises while the current thread is already
    /// unwinding (the executor's tolerant reset path).
    fn drain_arena(&self, home: u64, raise: bool) {
        if self.pending.load(Ordering::SeqCst) != 0 {
            let mut st = self.lock_state();
            while st.arena_inflight.get(&home).is_some_and(|c| *c > 0) {
                st = self.wait_state(st);
            }
            if st.inflight == 0 {
                st.done.clear();
                st.access.clear();
                st.arena_inflight.clear();
            }
        }
        if raise && self.panic_count.load(Ordering::SeqCst) != 0 && !std::thread::panicking() {
            let payload = {
                let mut st = self.lock_state();
                st.panics.iter().position(|(h, _)| *h == home).map(|i| {
                    self.panic_count.fetch_sub(1, Ordering::SeqCst);
                    st.panics.remove(i).1
                })
            };
            if let Some(payload) = payload {
                std::panic::resume_unwind(payload);
            }
        }
    }

    /// [`drain`](Engine::drain), then re-raise the first recorded worker
    /// panic on this thread (the `Device::fence` contract).
    fn fence(&self) {
        self.drain();
        if self.panic_count.load(Ordering::SeqCst) == 0 {
            return;
        }
        let payload = {
            let mut st = self.lock_state();
            if st.panics.is_empty() {
                None
            } else {
                self.panic_count.fetch_sub(1, Ordering::SeqCst);
                Some(st.panics.remove(0).1)
            }
        };
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }

    fn set_stream(&self, level: usize) {
        let mut st = self.lock_state();
        st.current_stream = level % self.streams;
        st.current_level = level;
    }

    fn take_trace(&self) -> OverlapTrace {
        let mut st = self.lock_state();
        OverlapTrace { events: std::mem::take(&mut st.trace) }
    }
}

/// Execute one journaled operation against the wrapped device.
fn exec_op(device: &dyn Device, action: OpAction) {
    match action {
        OpAction::Upload { arena, id, mat } => {
            let mut shared = write_cell(&arena.cell);
            put_owned(&mut **shared, id, mat);
        }
        OpAction::UploadVec { arena, id, v } => {
            let mut shared = write_cell(&arena.cell);
            shared.upload_vec(id, &v);
        }
        OpAction::Free { arena, bufs } => {
            let mut shared = write_cell(&arena.cell);
            for b in bufs {
                shared.free(b);
            }
        }
        OpAction::Launch { arena, launch } => exec_async_launch(device, &arena, launch),
        OpAction::SolveLaunch { factor, ws, launch } => {
            // Lock order is factor-then-workspace everywhere, so solve
            // workers cannot deadlock against each other or against
            // factor staging. The factor read lock is *shared*: any
            // number of concurrent solve launches read the same panels
            // simultaneously — nothing is moved or copied.
            let f = read_cell(&factor.cell);
            let mut w = write_cell(&ws.cell);
            device.launch_solve(&**f, &mut **w, &launch.as_launch());
        }
    }
}

/// Execute one batched launch: move its operands from the shared arena
/// into a dense-id private arena (pointer moves on host arenas), run the
/// wrapped device's kernel with **no lock held**, and move every operand
/// and output back. The hazard tracker guarantees no other in-flight
/// operation touches these buffers, so the round-trip is invisible.
fn exec_async_launch(device: &dyn Device, arena: &InnerArena, mut launch: OwnedLaunch) {
    let ops = launch_operands(&launch.as_launch());
    let mut uniq: Vec<BufferId> = Vec::new();
    let mut map: HashMap<u32, BufferId> = HashMap::new();
    for &id in ops.mat_reads.iter().chain(&ops.mat_rw).chain(&ops.mat_writes) {
        if let std::collections::hash_map::Entry::Vacant(e) = map.entry(id.0) {
            e.insert(BufferId(uniq.len() as u32));
            uniq.push(id);
        }
    }
    // Pure outputs are created by the kernel; everything else moves in.
    let gathered: HashSet<u32> =
        ops.mat_reads.iter().chain(&ops.mat_rw).map(|b| b.0).collect();
    let mut private = device.new_arena(uniq.len());
    {
        let mut shared = write_cell(&arena.cell);
        for &id in &uniq {
            if gathered.contains(&id.0) {
                let m = shared.take(id);
                put_owned(private.as_mut(), map[&id.0], m);
            }
        }
    }
    launch.remap(&map);
    device.launch(private.as_mut(), &launch.as_launch());
    device.fence();
    {
        let mut shared = write_cell(&arena.cell);
        for &id in &uniq {
            let m = private.take(map[&id.0]);
            put_owned(&mut **shared, id, m);
        }
    }
}

/// Per-stream worker: pops the front of its queue once all hazard edges
/// are done, executes it, and publishes completion. FIFO per queue plus
/// strictly-earlier dependency seqs make the schedule deadlock-free (the
/// minimal-seq unfinished operation is always runnable).
fn worker_loop(engine: Arc<Engine>, stream: usize) {
    loop {
        let op = {
            let mut st = engine.lock_state();
            loop {
                // Honor shutdown only once this queue is empty: an op that
                // raced past the enqueue-side shutdown check (journaled
                // between Drop's drain and the flag flip) must still
                // execute, or a surviving arena's next drain would hang on
                // `inflight` forever.
                if st.shutdown && st.queues[stream].is_empty() {
                    return;
                }
                let ready = st.queues[stream]
                    .front()
                    .map(|op| op.deps.iter().all(|d| st.done.contains(d)))
                    .unwrap_or(false);
                if ready {
                    break st.queues[stream].pop_front().unwrap();
                }
                st = engine.wait_state(st);
            }
        };
        let Op { seq, home, level, kind, opcode, action, .. } = op;
        let start = engine.origin.elapsed().as_secs_f64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec_op(engine.device.as_ref(), action)
        }));
        let end = engine.origin.elapsed().as_secs_f64();
        let mut st = engine.lock_state();
        st.done.insert(seq);
        st.inflight -= 1;
        if let Some(c) = st.arena_inflight.get_mut(&home) {
            *c -= 1;
            if *c == 0 {
                st.arena_inflight.remove(&home);
            }
        }
        engine.pending.fetch_sub(1, Ordering::SeqCst);
        st.trace.push(OverlapEvent { stream, level, kind, opcode, start, end });
        if let Err(payload) = result {
            // First failure per arena wins; dependents still run (and may
            // fail on the inconsistent state — also recorded) so the
            // queues always drain and `fence` / result reads can re-raise
            // deterministically.
            if !st.panics.iter().any(|(h, _)| *h == home) {
                st.panics.push((home, payload));
                engine.panic_count.fetch_add(1, Ordering::SeqCst);
            }
        }
        drop(st);
        engine.cv.notify_all();
    }
}

// ---------------------------------------------------------------------
// The journaling arena.
// ---------------------------------------------------------------------

/// The arena type an [`AsyncDevice`] hands out: journals matrix and vector
/// uploads, frees, and (through [`Device::launch_solve`]) substitution
/// launches onto the stream queues. Everything else — allocs, downloads,
/// balance queries — executes synchronously after a *scoped* drain of this
/// arena's own in-flight operations, so independent workspaces never wait
/// on each other. Result reads (`download`/`download_vec`/`take`) observe
/// post-drain state and re-raise a panic recorded against this arena; the
/// live/bytes invariants the device tests assert hold exactly as on the
/// wrapped arena.
pub struct AsyncArena {
    handle: Arc<InnerArena>,
    engine: Arc<Engine>,
}

impl AsyncArena {
    /// Synchronous shared access after a scoped drain; `raise` re-raises
    /// this arena's recorded panic (result reads only).
    fn sync<T>(&self, raise: bool, f: impl FnOnce(&dyn DeviceArena) -> T) -> T {
        self.engine.drain_arena(self.handle.id, raise);
        let shared = read_cell(&self.handle.cell);
        f(&**shared)
    }

    fn sync_mut<T>(&mut self, raise: bool, f: impl FnOnce(&mut dyn DeviceArena) -> T) -> T {
        self.engine.drain_arena(self.handle.id, raise);
        let mut shared = write_cell(&self.handle.cell);
        f(&mut **shared)
    }
}

impl DeviceArena for AsyncArena {
    fn upload(&mut self, id: BufferId, m: &Matrix) {
        // The staging copy (pinned-memory analog) happens here; the
        // device-side insertion runs on a stream worker.
        self.engine.enqueue(
            self.handle.id,
            &[],
            &[(self.handle.id, id)],
            OverlapKind::Transfer,
            "UPLOAD",
            OpAction::Upload { arena: self.handle.clone(), id, mat: m.clone() },
        );
    }

    fn upload_vec(&mut self, id: BufferId, v: &[f64]) {
        // RHS segment uploads are journaled like matrix uploads, so one
        // solve's transfers overlap another solve's (or the same solve's
        // independent) compute — the solve-path transfer half of the
        // overlap trace.
        self.engine.enqueue(
            self.handle.id,
            &[],
            &[(self.handle.id, id)],
            OverlapKind::Transfer,
            "UPLOADV",
            OpAction::UploadVec { arena: self.handle.clone(), id, v: v.to_vec() },
        );
    }

    fn alloc(&mut self, id: BufferId, rows: usize, cols: usize) {
        self.sync_mut(false, |a| a.alloc(id, rows, cols));
    }

    fn alloc_vec(&mut self, id: BufferId, len: usize) {
        self.sync_mut(false, |a| a.alloc_vec(id, len));
    }

    fn download(&self, id: BufferId) -> Matrix {
        self.sync(true, |a| a.download(id))
    }

    fn take(&mut self, id: BufferId) -> Matrix {
        self.sync_mut(true, |a| a.take(id))
    }

    fn download_vec(&self, id: BufferId) -> Vec<f64> {
        self.sync(true, |a| a.download_vec(id))
    }

    fn free(&mut self, id: BufferId) {
        self.engine.enqueue(
            self.handle.id,
            &[],
            &[(self.handle.id, id)],
            OverlapKind::Housekeeping,
            "FREE",
            OpAction::Free { arena: self.handle.clone(), bufs: vec![id] },
        );
    }

    fn free_region(&mut self, from: BufferId) {
        self.sync_mut(false, |a| a.free_region(from));
    }

    fn live(&self) -> usize {
        self.sync(false, |a| a.live())
    }

    fn is_live(&self, id: BufferId) -> bool {
        self.sync(false, |a| a.is_live(id))
    }

    fn bytes(&self) -> usize {
        self.sync(false, |a| a.bytes())
    }

    fn peak_bytes(&self) -> usize {
        self.sync(false, |a| a.peak_bytes())
    }

    fn footprint_bytes(&self) -> usize {
        self.sync(false, |a| a.footprint_bytes())
    }

    fn as_any(&self) -> &dyn Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

// ---------------------------------------------------------------------
// The device wrapper.
// ---------------------------------------------------------------------

/// Overlapping multi-stream executor around any host-synchronous
/// [`Device`] (see the module docs for the execution model). Construct
/// with [`AsyncDevice::new`] (two streams) or
/// [`AsyncDevice::with_streams`]; the facade spells it `async:<inner>`
/// ([`crate::solver::BackendSpec`]).
pub struct AsyncDevice<D: Device + Send + Sync + 'static> {
    inner: Arc<D>,
    engine: Arc<Engine>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<D: Device + Send + Sync + 'static> AsyncDevice<D> {
    /// Wrap `inner` with the default double-buffered stream pair.
    pub fn new(inner: D) -> AsyncDevice<D> {
        AsyncDevice::with_streams(inner, DEFAULT_STREAMS)
    }

    /// Wrap `inner` with an explicit stream count (clamped to ≥ 1). One
    /// worker thread per stream; `stream(level)` routes to
    /// `level % streams`.
    pub fn with_streams(inner: D, streams: usize) -> AsyncDevice<D> {
        let streams = streams.max(1);
        let inner = Arc::new(inner);
        let device: Arc<dyn Device + Send + Sync> = inner.clone();
        let engine = Arc::new(Engine::new(device, streams));
        let workers = (0..streams)
            .map(|s| {
                let engine = engine.clone();
                std::thread::Builder::new()
                    .name(format!("h2ulv-stream-{s}"))
                    .spawn(move || worker_loop(engine, s))
                    .expect("failed to spawn stream worker")
            })
            .collect();
        AsyncDevice { inner, engine, workers }
    }

    /// The wrapped device.
    pub fn inner(&self) -> &D {
        &self.inner
    }

    /// Number of stream queues.
    pub fn streams(&self) -> usize {
        self.engine.streams
    }

    /// Start recording every enqueue decision of the runtime hazard
    /// tracker (sequence, stream, operand set, full dependency edges) for
    /// differential comparison against the static graphs from
    /// [`crate::plan::verify::hazard_graph`] and
    /// [`crate::plan::verify::solve_hazard_graph`].
    pub fn enable_hazard_log(&self) {
        self.engine.lock_state().hazard_log = Some(Vec::new());
    }

    /// Drain the engine and take the recorded hazard log (empty if
    /// recording was never enabled). Recording stops until re-enabled.
    pub fn take_hazard_log(&self) -> Vec<HazardRecord> {
        self.engine.drain();
        self.engine.lock_state().hazard_log.take().unwrap_or_default()
    }
}

impl<D: Device + Send + Sync + 'static> Drop for AsyncDevice<D> {
    fn drop(&mut self) {
        // Drain first: surviving arenas must never wait on ops that no
        // worker will run.
        self.engine.drain();
        self.engine.lock_state().shutdown = true;
        self.engine.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl<D: Device + Send + Sync + 'static> Device for AsyncDevice<D> {
    fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena> {
        Box::new(AsyncArena {
            handle: Arc::new(InnerArena {
                id: self.engine.next_arena.fetch_add(1, Ordering::Relaxed),
                cell: RwLock::new(self.inner.new_arena(capacity)),
            }),
            engine: self.engine.clone(),
        })
    }

    fn launch(&self, arena: &mut dyn DeviceArena, launch: &Launch<'_>) {
        let owned = OwnedLaunch::from_launch(launch).unwrap_or_else(|| {
            panic!(
                "{} is a substitution-phase launch; AsyncDevice executes it \
                 through launch_solve",
                launch.opcode()
            )
        });
        match arena.as_any_mut().downcast_mut::<AsyncArena>() {
            Some(aa) => {
                let writes: Vec<(u64, BufferId)> =
                    owned.operand_set().into_iter().map(|b| (aa.handle.id, b)).collect();
                let opcode = launch.opcode();
                let handle = aa.handle.clone();
                self.engine.enqueue(
                    handle.id,
                    &[],
                    &writes,
                    OverlapKind::Compute,
                    opcode,
                    OpAction::Launch { arena: handle, launch: owned },
                );
            }
            // A foreign arena (e.g. the wrapped device's own): execute
            // synchronously — correct, just without overlap.
            None => self.inner.launch(arena, launch),
        }
    }

    fn launch_solve(
        &self,
        factor: &dyn DeviceArena,
        ws: &mut dyn DeviceArena,
        launch: &Launch<'_>,
    ) {
        let f_handle = factor.as_any().downcast_ref::<AsyncArena>().map(|a| a.handle.clone());
        if let (Some(f), Some(w)) =
            (&f_handle, ws.as_any().downcast_ref::<AsyncArena>().map(|a| a.handle.id))
        {
            if f.id == w {
                // The typed violation path (same wording family as
                // `ValidatingDevice`): the facade's substitution guard
                // classifies "hazard audit failed" panics as
                // `H2Error::PlanVerification` instead of letting a bare
                // assert unwind as an opaque internal error.
                panic!(
                    "hazard audit failed for {}: factor and workspace resolve to the same \
                     arena region (solve launches require the immutable-factor / private-\
                     workspace split)\noffending instruction: {launch:?}",
                    launch.opcode()
                );
            }
        }
        // Journaled path: both regions belong to this engine and the
        // launch is an ordinary substitution opcode. The op is accounted
        // against the *workspace* (scoped drains, panic attribution);
        // factor matrices enter the hazard table as shared reads.
        if let (Some(f), Some(owned)) = (&f_handle, OwnedSolveLaunch::from_launch(launch)) {
            if let Some(wa) = ws.as_any_mut().downcast_mut::<AsyncArena>() {
                let (reads, writes) = solve_roles(launch, f.id, wa.handle.id);
                self.engine.enqueue(
                    wa.handle.id,
                    &reads,
                    &writes,
                    OverlapKind::Compute,
                    launch.opcode(),
                    OpAction::SolveLaunch {
                        factor: f.clone(),
                        ws: wa.handle.clone(),
                        launch: owned,
                    },
                );
                return;
            }
        }
        // Fallback (a foreign region on either side): quiesce the journal,
        // then delegate on the calling thread — correct, just without
        // solve-path overlap. Still timed against the engine epoch so the
        // overlap trace covers it.
        self.engine.drain();
        let f_guard = f_handle.as_ref().map(|h| read_cell(&h.cell));
        let factor_ref: &dyn DeviceArena = match &f_guard {
            Some(g) => &***g,
            None => factor,
        };
        let t_start = self.engine.origin.elapsed().as_secs_f64();
        match ws.as_any_mut().downcast_mut::<AsyncArena>() {
            Some(wa) => {
                let mut g = write_cell(&wa.handle.cell);
                self.inner.launch_solve(factor_ref, &mut **g, launch);
            }
            None => self.inner.launch_solve(factor_ref, ws, launch),
        }
        let t_end = self.engine.origin.elapsed().as_secs_f64();
        let mut st = self.engine.lock_state();
        let (stream, level) = (st.current_stream, st.current_level);
        st.trace.push(OverlapEvent {
            stream,
            level,
            kind: OverlapKind::Compute,
            opcode: launch.opcode(),
            start: t_start,
            end: t_end,
        });
    }

    fn stream(&self, level: usize) {
        self.engine.set_stream(level);
    }

    fn fence(&self) {
        self.engine.fence();
    }

    fn take_overlap_trace(&self) -> Option<OverlapTrace> {
        self.engine.drain();
        Some(self.engine.take_trace())
    }

    fn name(&self) -> &'static str {
        match self.inner.name() {
            "native" => "async:native",
            "serial" => "async:serial",
            "pjrt" => "async:pjrt",
            _ => "async",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol;
    use crate::solver::backend::SerialBackend;
    use crate::util::Rng;

    #[test]
    fn async_device_replays_launches_bit_identically() {
        let mut rng = Rng::new(42);
        let mats: Vec<Matrix> = (0..3).map(|_| Matrix::rand_spd(10, &mut rng)).collect();
        let dev = AsyncDevice::new(SerialBackend);
        let mut arena = dev.new_arena(4);
        let ids: Vec<BufferId> = (0..3u32).map(BufferId).collect();
        dev.stream(2);
        for (&id, m) in ids.iter().zip(&mats) {
            arena.upload(id, m);
        }
        dev.launch(arena.as_mut(), &Launch::Potrf { level: 2, bufs: &ids });
        // Cross-stream RAW hazard: the extract on the other queue reads a
        // POTRF output and must wait for it.
        dev.stream(1);
        let ex = [ExtractItem { src: ids[0], r0: 0, c0: 0, rows: 4, cols: 4, dst: BufferId(3) }];
        dev.launch(arena.as_mut(), &Launch::Extract { items: &ex });
        dev.fence();
        for (&id, m) in ids.iter().zip(&mats) {
            let want = chol::cholesky(m).unwrap();
            assert_eq!(arena.download(id).as_slice(), want.as_slice());
        }
        let want_block = chol::cholesky(&mats[0]).unwrap().submatrix(0, 0, 4, 4);
        assert_eq!(arena.download(BufferId(3)).as_slice(), want_block.as_slice());
        assert_eq!(arena.live(), 4);
        let trace = dev.take_overlap_trace().expect("async devices trace");
        assert_eq!(trace.events.len(), 5, "3 uploads + 2 launches");
        assert!(trace.streams() >= 1);
    }

    #[test]
    fn async_device_journals_frees_in_hazard_order() {
        let mut rng = Rng::new(43);
        let m = Matrix::rand_spd(8, &mut rng);
        let dev = AsyncDevice::new(SerialBackend);
        let mut arena = dev.new_arena(2);
        dev.stream(0);
        arena.upload(BufferId(0), &m);
        let ex = [ExtractItem { src: BufferId(0), r0: 0, c0: 0, rows: 8, cols: 8, dst: BufferId(1) }];
        dev.launch(arena.as_mut(), &Launch::Extract { items: &ex });
        // The free on the other stream must wait for the extract's read.
        dev.stream(1);
        arena.free(BufferId(0));
        dev.fence();
        assert_eq!(arena.live(), 1);
        assert_eq!(arena.download(BufferId(1)).as_slice(), m.as_slice());
        assert!(!arena.is_live(BufferId(0)));
    }

    #[test]
    fn async_fence_reraises_worker_panics() {
        let dev = AsyncDevice::new(SerialBackend);
        let mut arena = dev.new_arena(1);
        // POTRF of a buffer that was never uploaded: the worker panics,
        // fence re-raises on this thread.
        let bufs = [BufferId(0)];
        dev.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &bufs });
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| dev.fence()));
        assert!(err.is_err(), "fence must re-raise the worker panic");
        // The engine stays usable afterwards.
        arena.upload(BufferId(0), &Matrix::eye(2));
        dev.fence();
        assert_eq!(arena.live(), 1);
    }

    #[test]
    fn journaled_solve_launches_replay_in_hazard_order() {
        // A substitution chain issued through launch_solve runs on the
        // stream workers yet produces the synchronous result bit-for-bit:
        // upload_vec → TRSV(fwd) → TRSV(bwd) with RAW edges on the vector.
        let mut rng = Rng::new(44);
        let spd = Matrix::rand_spd(6, &mut rng);
        let l = chol::cholesky(&spd).unwrap();
        let b: Vec<f64> = (0..6).map(|i| (i as f64) - 2.5).collect();

        // Synchronous reference on the wrapped device.
        let sync_dev = SerialBackend;
        let mut f_ref = sync_dev.new_arena(1);
        f_ref.upload(BufferId(0), &l);
        let mut w_ref = sync_dev.new_arena(1);
        w_ref.upload_vec(BufferId(1), &b);
        let items = [(BufferId(0), BufferId(1))];
        sync_dev.launch_solve(f_ref.as_ref(), w_ref.as_mut(), &Launch::TrsvFwd {
            level: 1,
            items: &items,
        });
        sync_dev.launch_solve(f_ref.as_ref(), w_ref.as_mut(), &Launch::TrsvBwd {
            level: 1,
            items: &items,
        });
        let want = w_ref.download_vec(BufferId(1));

        let dev = AsyncDevice::new(SerialBackend);
        let mut factor = dev.new_arena(1);
        factor.upload(BufferId(0), &l);
        dev.fence();
        let mut ws = dev.new_arena(1);
        dev.stream(1);
        ws.upload_vec(BufferId(1), &b);
        dev.launch_solve(factor.as_ref(), ws.as_mut(), &Launch::TrsvFwd {
            level: 1,
            items: &items,
        });
        dev.launch_solve(factor.as_ref(), ws.as_mut(), &Launch::TrsvBwd {
            level: 1,
            items: &items,
        });
        // No fence: download_vec scope-drains the workspace arena itself.
        assert_eq!(ws.download_vec(BufferId(1)), want, "journaled solve diverged");
        let trace = dev.take_overlap_trace().expect("async devices trace");
        let solves: Vec<_> =
            trace.events.iter().filter(|e| e.kind == OverlapKind::Compute).collect();
        assert_eq!(solves.len(), 2, "both solve launches must be traced as compute");
        assert!(
            trace.events.iter().any(|e| e.opcode == "UPLOADV"),
            "the RHS upload must be traced as a transfer"
        );
    }

    #[test]
    fn journaled_solve_panic_surfaces_its_own_message_through_fence() {
        // Satellite (panic/poison): a panicking journaled launch must
        // surface its *own* payload at the next fence — never a
        // `PoisonError` from a lock the dying worker left behind.
        let dev = AsyncDevice::new(SerialBackend);
        let factor = dev.new_arena(1);
        let mut ws = dev.new_arena(1);
        // TRSV against buffers that were never written: the worker panics
        // with the arena's "read before upload" message.
        let items = [(BufferId(0), BufferId(1))];
        dev.launch_solve(factor.as_ref(), ws.as_mut(), &Launch::TrsvFwd {
            level: 0,
            items: &items,
        });
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| dev.fence()))
            .expect_err("fence must re-raise the solve worker panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&'static str>().map(|s| s.to_string()))
            .expect("panic payload must be a message");
        assert!(
            msg.contains("read before upload"),
            "fence re-raised the wrong payload: {msg:?}"
        );
        // The engine (and its state lock) stays usable afterwards.
        ws.upload_vec(BufferId(1), &[1.0, 2.0]);
        dev.fence();
        assert_eq!(ws.download_vec(BufferId(1)), vec![1.0, 2.0]);
    }

    #[test]
    fn same_region_solve_launch_is_a_typed_violation() {
        // Same region on both sides of launch_solve → the typed
        // "hazard audit failed" violation, not a bare assert string. Two
        // AsyncArena handles sharing one inner arena resolve to the same
        // engine region id, which is exactly the aliasing the check
        // rejects.
        let dev = AsyncDevice::new(SerialBackend);
        let arena = dev.new_arena(1);
        let aa = arena.as_any().downcast_ref::<AsyncArena>().unwrap();
        let factor =
            AsyncArena { handle: aa.handle.clone(), engine: aa.engine.clone() };
        let mut ws =
            AsyncArena { handle: aa.handle.clone(), engine: aa.engine.clone() };
        let items = [(BufferId(0), BufferId(1))];
        let launch = Launch::TrsvFwd { level: 0, items: &items };
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            dev.launch_solve(&factor, &mut ws, &launch);
        }))
        .expect_err("same-region launch_solve must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .expect("violation panics carry a formatted message");
        assert!(
            msg.contains("hazard audit failed"),
            "violation must use the typed hazard-audit wording: {msg}"
        );
    }
}
