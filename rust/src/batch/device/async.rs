//! [`AsyncDevice`]: an overlapping multi-stream executor wrapped around
//! any host-synchronous [`Device`].
//!
//! The paper's schedule property — level *k*'s batched TRSM/Schur work has
//! no dependency on level *k+1*'s sparsify uploads — only pays off if an
//! executor actually runs them concurrently. `AsyncDevice` does exactly
//! that for the factorization replay:
//!
//! * **Journaled arena traffic.** Arenas created by an `AsyncDevice` are
//!   [`AsyncArena`]s: matrix `upload`s, `free`s, and every factorization
//!   [`Launch`] are *journaled* as asynchronous operations instead of
//!   executing on the issuing thread. `stream(level)` routes subsequent
//!   operations to the queue `level % streams` (two queues by default —
//!   the paper's double-buffer), each drained in FIFO order by its own
//!   worker thread.
//! * **A `BufferId`-granular hazard tracker.** At enqueue time every
//!   operation declares its operand set (from the launch operand lists
//!   via [`super::launch_operands`], or the touched id for
//!   uploads/frees), held *exclusively*: because the staging strategy
//!   below moves buffers instead of sharing them, per-buffer ordering is
//!   a single last-toucher chain whose transitive closure yields every
//!   RAW/WAR/WAW edge — read-read pairs serialize too; see
//!   `OwnedLaunch::operand_set` for why no recorded plan loses overlap to
//!   this. A worker only starts an operation once all its edges have
//!   completed. Issue order is the semantic order (device.rs "Streams,
//!   fences, and hazards"), so replay results are **bit-identical** to
//!   the wrapped device — overlap reorders *when* kernels run, never
//!   their operands.
//! * **Zero-copy staging on host arenas.** A worker executes a launch by
//!   *moving* its operand buffers from the shared arena into a private
//!   arena (pointer moves via the `HostArena` fast path of
//!   [`super::put_owned`]), running the wrapped device's kernel outside
//!   any lock, and moving the results back. The shared-arena lock is held
//!   only during the two pointer-move phases, which is what lets an
//!   upload on one stream proceed while another stream computes.
//! * **[`Device::fence`] drains.** It blocks until every journaled
//!   operation has completed and re-raises the first worker panic (so a
//!   non-SPD breakdown surfaces on the issuing thread exactly as on a
//!   synchronous device). The executor already fences before every
//!   download.
//! * **Observable overlap.** Every executed operation is recorded as an
//!   [`OverlapEvent`] (stream, level, wall-clock interval);
//!   [`Device::take_overlap_trace`] drains the [`OverlapTrace`] that the
//!   test harness and `BuildStats` interrogate.
//!
//! Substitution launches ([`Device::launch_solve`]) stay synchronous on
//! the calling thread: their concurrency comes from the session's
//! workspace pool (many threads, one read-only factor region), and their
//! vector operands live in caller-borrowed regions that cannot outlive a
//! journal entry. The wrapper resolves both regions to the wrapped
//! device's arenas and delegates, so an `AsyncDevice` session keeps the
//! lock-free concurrent-solve property of PR 4. Each delegated solve
//! launch is still *timed* against the engine epoch and recorded as a
//! [`OverlapKind::Compute`] event, so the overlap trace — and the
//! `RunReport` built from it — covers the solve path too: concurrent
//! solve threads show up as overlapping per-stream busy intervals.
//!
//! The transfer clone in [`AsyncArena::upload`] is this emulation's analog
//! of staging into pinned host memory: the borrowed source matrix cannot
//! outlive the `upload` call, so the owned copy is taken at issue time and
//! the device-side insertion (a pointer move on host arenas) happens on
//! the worker — genuinely concurrent with other streams' compute.

use super::{launch_operands, put_owned, Device, DeviceArena, Launch};
use crate::linalg::Matrix;
use crate::metrics::overlap::{OverlapEvent, OverlapKind, OverlapTrace};
use crate::plan::{BufferId, ExtractItem, MergeItem, SparsifyItem, SyrkItem, TrsmItem};
use std::any::Any;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Instant;

/// Default number of stream queues: two adjacent tree levels in flight —
/// the paper's double-buffering.
pub const DEFAULT_STREAMS: usize = 2;

/// One journaled operation as the runtime hazard tracker saw it at
/// enqueue time (recorded while [`AsyncDevice::enable_hazard_log`] is on):
/// sequence number, placement, operand set, and the full last-toucher
/// dependency edges *before* completed-op pruning — directly comparable,
/// op for op, to the static graph from
/// [`crate::plan::verify::hazard_graph`].
#[derive(Clone, Debug)]
pub struct HazardRecord {
    pub seq: u64,
    pub opcode: &'static str,
    pub stream: usize,
    pub level: usize,
    pub operands: Vec<u32>,
    pub deps: Vec<u64>,
}

// ---------------------------------------------------------------------
// Owned launches (journal entries cannot borrow the plan).
// ---------------------------------------------------------------------

/// An owned factorization launch: the journal's copy of a [`Launch`] whose
/// operand lists are borrowed from the plan. Substitution opcodes never
/// enter the journal (they execute synchronously through `launch_solve`).
#[derive(Clone, Debug)]
enum OwnedLaunch {
    Potrf { level: usize, bufs: Vec<BufferId> },
    TrsmRightLt { level: usize, items: Vec<TrsmItem> },
    SchurSelf { level: usize, items: Vec<SyrkItem> },
    Sparsify { level: usize, items: Vec<SparsifyItem> },
    Extract { items: Vec<ExtractItem> },
    Merge { items: Vec<MergeItem> },
}

impl OwnedLaunch {
    /// Copy a factorization-phase launch; `None` for substitution opcodes.
    fn from_launch(launch: &Launch<'_>) -> Option<OwnedLaunch> {
        Some(match launch {
            Launch::Potrf { level, bufs } => {
                OwnedLaunch::Potrf { level: *level, bufs: bufs.to_vec() }
            }
            Launch::TrsmRightLt { level, items } => {
                OwnedLaunch::TrsmRightLt { level: *level, items: items.to_vec() }
            }
            Launch::SchurSelf { level, items } => {
                OwnedLaunch::SchurSelf { level: *level, items: items.to_vec() }
            }
            Launch::Sparsify { level, items } => {
                OwnedLaunch::Sparsify { level: *level, items: items.to_vec() }
            }
            Launch::Extract { items } => OwnedLaunch::Extract { items: items.to_vec() },
            Launch::Merge { items } => OwnedLaunch::Merge { items: items.to_vec() },
            _ => return None,
        })
    }

    /// Re-borrow as the trait-level launch type.
    fn as_launch(&self) -> Launch<'_> {
        match self {
            OwnedLaunch::Potrf { level, bufs } => Launch::Potrf { level: *level, bufs },
            OwnedLaunch::TrsmRightLt { level, items } => {
                Launch::TrsmRightLt { level: *level, items }
            }
            OwnedLaunch::SchurSelf { level, items } => {
                Launch::SchurSelf { level: *level, items }
            }
            OwnedLaunch::Sparsify { level, items } => {
                Launch::Sparsify { level: *level, items }
            }
            OwnedLaunch::Extract { items } => Launch::Extract { items },
            OwnedLaunch::Merge { items } => Launch::Merge { items },
        }
    }

    /// Every operand id, deduplicated, declared as an *exclusive* hazard
    /// set. The contract (device.rs rule 2) permits concurrent readers,
    /// but this executor's staging strategy physically *moves* operands
    /// into a launch's private arena, so it conservatively serializes
    /// read-read pairs too. No recorded plan loses overlap to this:
    /// same-level launches are already FIFO on one stream, and every
    /// cross-level pair is either buffer-disjoint (uploads vs prior
    /// compute — the overlap that matters) or genuinely ordered (merge →
    /// next-level sparsify).
    fn operand_set(&self) -> Vec<BufferId> {
        let ops = launch_operands(&self.as_launch());
        let mut set = ops.mat_reads;
        set.extend(ops.mat_rw);
        set.extend(ops.mat_writes);
        set.sort_unstable_by_key(|b| b.0);
        set.dedup();
        set
    }

    /// Rewrite every operand id through `map` (shared-arena id → private
    /// execution-arena id).
    fn remap(&mut self, map: &HashMap<u32, BufferId>) {
        fn r(map: &HashMap<u32, BufferId>, b: &mut BufferId) {
            *b = map[&b.0];
        }
        match self {
            OwnedLaunch::Potrf { bufs, .. } => {
                for b in bufs {
                    r(map, b);
                }
            }
            OwnedLaunch::TrsmRightLt { items, .. } => {
                for it in items {
                    r(map, &mut it.l);
                    r(map, &mut it.b);
                }
            }
            OwnedLaunch::SchurSelf { items, .. } => {
                for it in items {
                    r(map, &mut it.a);
                    r(map, &mut it.c);
                }
            }
            OwnedLaunch::Sparsify { items, .. } => {
                for it in items {
                    r(map, &mut it.u);
                    r(map, &mut it.a);
                    r(map, &mut it.v);
                    r(map, &mut it.dst);
                }
            }
            OwnedLaunch::Extract { items } => {
                for it in items {
                    r(map, &mut it.src);
                    r(map, &mut it.dst);
                }
            }
            OwnedLaunch::Merge { items } => {
                for it in items {
                    r(map, &mut it.dst);
                    for p in &mut it.parts {
                        r(map, &mut p.src);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// The stream engine.
// ---------------------------------------------------------------------

/// The shared inner arena of one [`AsyncArena`]: the wrapped device's own
/// arena behind a lock that workers (briefly, for pointer-move staging)
/// and synchronous readers share.
struct InnerArena {
    id: u64,
    cell: RwLock<Box<dyn DeviceArena>>,
}

/// Lock an arena cell for writing, recovering from poisoning. A panic
/// while the guard is held (a kernel breakdown, a take of a dead buffer)
/// is already recorded by the engine and re-raised at the next `fence`;
/// the arena contents are then exactly as unspecified as on a synchronous
/// device after the same panic — but the lock itself must stay usable so
/// the PR-4 unwind guards (workspace reset, pool return) and post-repair
/// traffic keep working.
fn write_cell(cell: &RwLock<Box<dyn DeviceArena>>) -> RwLockWriteGuard<'_, Box<dyn DeviceArena>> {
    cell.write().unwrap_or_else(|e| e.into_inner())
}

/// Shared-lock counterpart of [`write_cell`] (same poisoning rationale).
fn read_cell(cell: &RwLock<Box<dyn DeviceArena>>) -> RwLockReadGuard<'_, Box<dyn DeviceArena>> {
    cell.read().unwrap_or_else(|e| e.into_inner())
}

/// One journaled operation's payload.
enum OpAction {
    /// Insert a staged matrix (the "device-side" half of an upload).
    Upload { arena: Arc<InnerArena>, id: BufferId, mat: Matrix },
    /// Release buffers (a plan `Free` step).
    Free { arena: Arc<InnerArena>, bufs: Vec<BufferId> },
    /// Execute a batched factorization launch.
    Launch { arena: Arc<InnerArena>, launch: OwnedLaunch },
}

/// One journal entry: payload plus the hazard edges it must wait on.
struct Op {
    seq: u64,
    /// Seqs of still-pending conflicting operations (strictly earlier).
    deps: Vec<u64>,
    level: usize,
    kind: OverlapKind,
    opcode: &'static str,
    action: OpAction,
}

/// Last operation touching one `(arena, buffer)` pair. Every journaled
/// operation declares its operands exclusively (see
/// `OwnedLaunch::operand_set`), so per-buffer ordering is a single
/// last-writer chain: each new op depends on the previous toucher, and
/// transitivity gives the full RAW/WAR/WAW order.
#[derive(Default)]
struct Access {
    writer: Option<u64>,
}

struct EngineState {
    queues: Vec<VecDeque<Op>>,
    next_seq: u64,
    /// Completed op seqs (cleared whenever the engine goes quiescent).
    done: HashSet<u64>,
    /// Hazard table: last toucher per (arena, buffer).
    access: HashMap<(u64, u32), Access>,
    /// Queued + executing operations.
    inflight: usize,
    current_stream: usize,
    current_level: usize,
    trace: Vec<OverlapEvent>,
    /// Differential-audit log: `Some` while hazard recording is enabled.
    hazard_log: Option<Vec<HazardRecord>>,
    /// First worker panic, re-raised by the next `fence`.
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

/// The multi-stream scheduler shared by an [`AsyncDevice`] and every
/// [`AsyncArena`] it creates.
struct Engine {
    device: Arc<dyn Device + Send + Sync>,
    state: Mutex<EngineState>,
    cv: Condvar,
    origin: Instant,
    streams: usize,
    /// Mirror of `EngineState::inflight` for the lock-free drain fast
    /// path (data visibility itself comes from the arena locks).
    pending: AtomicUsize,
    next_arena: AtomicU64,
}

impl Engine {
    fn new(device: Arc<dyn Device + Send + Sync>, streams: usize) -> Engine {
        Engine {
            device,
            state: Mutex::new(EngineState {
                queues: (0..streams).map(|_| VecDeque::new()).collect(),
                next_seq: 0,
                done: HashSet::new(),
                access: HashMap::new(),
                inflight: 0,
                current_stream: 0,
                current_level: usize::MAX,
                trace: Vec::new(),
                hazard_log: None,
                panic: None,
                shutdown: false,
            }),
            cv: Condvar::new(),
            origin: Instant::now(),
            streams,
            pending: AtomicUsize::new(0),
            next_arena: AtomicU64::new(0),
        }
    }

    /// Journal one operation touching `operands` (exclusively): compute
    /// its hazard edges against the pending set, append it to the current
    /// stream's queue, and return without executing. After device
    /// shutdown (late arena traffic) the operation degrades to
    /// synchronous execution on the caller thread.
    fn enqueue(
        &self,
        arena_id: u64,
        operands: &[BufferId],
        kind: OverlapKind,
        opcode: &'static str,
        action: OpAction,
    ) {
        let mut guard = self.state.lock().unwrap();
        if guard.shutdown {
            drop(guard);
            exec_op(self.device.as_ref(), action);
            return;
        }
        let seq = guard.next_seq;
        guard.next_seq += 1;
        // Full last-toucher edges first (the semantic dependency set the
        // static hazard graph predicts), then prune already-completed ops
        // for the scheduler's working set.
        let mut full: Vec<u64> = Vec::new();
        for &b in operands {
            if let Some(acc) = guard.access.get(&(arena_id, b.0)) {
                if let Some(prev) = acc.writer {
                    full.push(prev);
                }
            }
        }
        full.sort_unstable();
        full.dedup();
        let deps: Vec<u64> = full.iter().copied().filter(|d| !guard.done.contains(d)).collect();
        if let Some(log) = guard.hazard_log.as_mut() {
            log.push(HazardRecord {
                seq,
                opcode,
                stream: guard.current_stream,
                level: guard.current_level,
                operands: operands.iter().map(|b| b.0).collect(),
                deps: full,
            });
        }
        for &b in operands {
            guard.access.entry((arena_id, b.0)).or_default().writer = Some(seq);
        }
        let stream = guard.current_stream;
        let level = guard.current_level;
        guard.inflight += 1;
        self.pending.fetch_add(1, Ordering::SeqCst);
        guard.queues[stream].push_back(Op { seq, deps, level, kind, opcode, action });
        drop(guard);
        self.cv.notify_all();
    }

    /// Wait until every journaled operation has completed. Lock-free when
    /// the engine is already quiescent — the per-solve-launch fast path.
    fn drain(&self) {
        if self.pending.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        while st.inflight > 0 {
            st = self.cv.wait(st).unwrap();
        }
        // Quiescent: nothing references the bookkeeping any more.
        st.done.clear();
        st.access.clear();
    }

    /// [`drain`](Engine::drain), then re-raise the first worker panic on
    /// this thread (the `Device::fence` contract).
    fn fence(&self) {
        self.drain();
        let payload = self.state.lock().unwrap().panic.take();
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }

    fn set_stream(&self, level: usize) {
        let mut st = self.state.lock().unwrap();
        st.current_stream = level % self.streams;
        st.current_level = level;
    }

    fn take_trace(&self) -> OverlapTrace {
        let mut st = self.state.lock().unwrap();
        OverlapTrace { events: std::mem::take(&mut st.trace) }
    }
}

/// Execute one journaled operation against the wrapped device.
fn exec_op(device: &dyn Device, action: OpAction) {
    match action {
        OpAction::Upload { arena, id, mat } => {
            let mut shared = write_cell(&arena.cell);
            put_owned(&mut **shared, id, mat);
        }
        OpAction::Free { arena, bufs } => {
            let mut shared = write_cell(&arena.cell);
            for b in bufs {
                shared.free(b);
            }
        }
        OpAction::Launch { arena, launch } => exec_async_launch(device, &arena, launch),
    }
}

/// Execute one batched launch: move its operands from the shared arena
/// into a dense-id private arena (pointer moves on host arenas), run the
/// wrapped device's kernel with **no lock held**, and move every operand
/// and output back. The hazard tracker guarantees no other in-flight
/// operation touches these buffers, so the round-trip is invisible.
fn exec_async_launch(device: &dyn Device, arena: &InnerArena, mut launch: OwnedLaunch) {
    let ops = launch_operands(&launch.as_launch());
    let mut uniq: Vec<BufferId> = Vec::new();
    let mut map: HashMap<u32, BufferId> = HashMap::new();
    for &id in ops.mat_reads.iter().chain(&ops.mat_rw).chain(&ops.mat_writes) {
        if let std::collections::hash_map::Entry::Vacant(e) = map.entry(id.0) {
            e.insert(BufferId(uniq.len() as u32));
            uniq.push(id);
        }
    }
    // Pure outputs are created by the kernel; everything else moves in.
    let gathered: HashSet<u32> =
        ops.mat_reads.iter().chain(&ops.mat_rw).map(|b| b.0).collect();
    let mut private = device.new_arena(uniq.len());
    {
        let mut shared = write_cell(&arena.cell);
        for &id in &uniq {
            if gathered.contains(&id.0) {
                let m = shared.take(id);
                put_owned(private.as_mut(), map[&id.0], m);
            }
        }
    }
    launch.remap(&map);
    device.launch(private.as_mut(), &launch.as_launch());
    device.fence();
    {
        let mut shared = write_cell(&arena.cell);
        for &id in &uniq {
            let m = private.take(map[&id.0]);
            put_owned(&mut **shared, id, m);
        }
    }
}

/// Per-stream worker: pops the front of its queue once all hazard edges
/// are done, executes it, and publishes completion. FIFO per queue plus
/// strictly-earlier dependency seqs make the schedule deadlock-free (the
/// minimal-seq unfinished operation is always runnable).
fn worker_loop(engine: Arc<Engine>, stream: usize) {
    loop {
        let op = {
            let mut st = engine.state.lock().unwrap();
            loop {
                // Honor shutdown only once this queue is empty: an op that
                // raced past the enqueue-side shutdown check (journaled
                // between Drop's drain and the flag flip) must still
                // execute, or a surviving arena's next drain would hang on
                // `inflight` forever.
                if st.shutdown && st.queues[stream].is_empty() {
                    return;
                }
                let ready = st.queues[stream]
                    .front()
                    .map(|op| op.deps.iter().all(|d| st.done.contains(d)))
                    .unwrap_or(false);
                if ready {
                    break st.queues[stream].pop_front().unwrap();
                }
                st = engine.cv.wait(st).unwrap();
            }
        };
        let Op { seq, level, kind, opcode, action, .. } = op;
        let start = engine.origin.elapsed().as_secs_f64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec_op(engine.device.as_ref(), action)
        }));
        let end = engine.origin.elapsed().as_secs_f64();
        let mut st = engine.state.lock().unwrap();
        st.done.insert(seq);
        st.inflight -= 1;
        engine.pending.fetch_sub(1, Ordering::SeqCst);
        st.trace.push(OverlapEvent { stream, level, kind, opcode, start, end });
        if let Err(payload) = result {
            // First failure wins; dependents still run (and may fail on
            // the inconsistent state — also recorded) so the queues always
            // drain and `fence` can re-raise deterministically.
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        drop(st);
        engine.cv.notify_all();
    }
}

// ---------------------------------------------------------------------
// The journaling arena.
// ---------------------------------------------------------------------

/// The arena type an [`AsyncDevice`] hands out: journals matrix uploads
/// and frees (the factorization-replay traffic) onto the stream queues,
/// and serves everything synchronous — vector traffic, downloads, balance
/// queries — by draining first. Downloads therefore always observe
/// post-fence state, and the live/bytes invariants the device tests assert
/// hold exactly as on the wrapped arena.
pub struct AsyncArena {
    handle: Arc<InnerArena>,
    engine: Arc<Engine>,
}

impl AsyncArena {
    /// Synchronous access after a drain (reads and solve-phase traffic).
    fn sync<T>(&self, f: impl FnOnce(&dyn DeviceArena) -> T) -> T {
        self.engine.drain();
        let shared = read_cell(&self.handle.cell);
        f(&**shared)
    }

    fn sync_mut<T>(&mut self, f: impl FnOnce(&mut dyn DeviceArena) -> T) -> T {
        self.engine.drain();
        let mut shared = write_cell(&self.handle.cell);
        f(&mut **shared)
    }
}

impl DeviceArena for AsyncArena {
    fn upload(&mut self, id: BufferId, m: &Matrix) {
        // The staging copy (pinned-memory analog) happens here; the
        // device-side insertion runs on a stream worker.
        self.engine.enqueue(
            self.handle.id,
            &[id],
            OverlapKind::Transfer,
            "UPLOAD",
            OpAction::Upload { arena: self.handle.clone(), id, mat: m.clone() },
        );
    }

    fn upload_vec(&mut self, id: BufferId, v: &[f64]) {
        self.sync_mut(|a| a.upload_vec(id, v));
    }

    fn alloc(&mut self, id: BufferId, rows: usize, cols: usize) {
        self.sync_mut(|a| a.alloc(id, rows, cols));
    }

    fn alloc_vec(&mut self, id: BufferId, len: usize) {
        self.sync_mut(|a| a.alloc_vec(id, len));
    }

    fn download(&self, id: BufferId) -> Matrix {
        self.sync(|a| a.download(id))
    }

    fn take(&mut self, id: BufferId) -> Matrix {
        self.sync_mut(|a| a.take(id))
    }

    fn download_vec(&self, id: BufferId) -> Vec<f64> {
        self.sync(|a| a.download_vec(id))
    }

    fn free(&mut self, id: BufferId) {
        self.engine.enqueue(
            self.handle.id,
            &[id],
            OverlapKind::Housekeeping,
            "FREE",
            OpAction::Free { arena: self.handle.clone(), bufs: vec![id] },
        );
    }

    fn free_region(&mut self, from: BufferId) {
        self.sync_mut(|a| a.free_region(from));
    }

    fn live(&self) -> usize {
        self.sync(|a| a.live())
    }

    fn is_live(&self, id: BufferId) -> bool {
        self.sync(|a| a.is_live(id))
    }

    fn bytes(&self) -> usize {
        self.sync(|a| a.bytes())
    }

    fn peak_bytes(&self) -> usize {
        self.sync(|a| a.peak_bytes())
    }

    fn footprint_bytes(&self) -> usize {
        self.sync(|a| a.footprint_bytes())
    }

    fn as_any(&self) -> &dyn Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

// ---------------------------------------------------------------------
// The device wrapper.
// ---------------------------------------------------------------------

/// Overlapping multi-stream executor around any host-synchronous
/// [`Device`] (see the module docs for the execution model). Construct
/// with [`AsyncDevice::new`] (two streams) or
/// [`AsyncDevice::with_streams`]; the facade spells it `async:<inner>`
/// ([`crate::solver::BackendSpec`]).
pub struct AsyncDevice<D: Device + Send + Sync + 'static> {
    inner: Arc<D>,
    engine: Arc<Engine>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<D: Device + Send + Sync + 'static> AsyncDevice<D> {
    /// Wrap `inner` with the default double-buffered stream pair.
    pub fn new(inner: D) -> AsyncDevice<D> {
        AsyncDevice::with_streams(inner, DEFAULT_STREAMS)
    }

    /// Wrap `inner` with an explicit stream count (clamped to ≥ 1). One
    /// worker thread per stream; `stream(level)` routes to
    /// `level % streams`.
    pub fn with_streams(inner: D, streams: usize) -> AsyncDevice<D> {
        let streams = streams.max(1);
        let inner = Arc::new(inner);
        let device: Arc<dyn Device + Send + Sync> = inner.clone();
        let engine = Arc::new(Engine::new(device, streams));
        let workers = (0..streams)
            .map(|s| {
                let engine = engine.clone();
                std::thread::Builder::new()
                    .name(format!("h2ulv-stream-{s}"))
                    .spawn(move || worker_loop(engine, s))
                    .expect("failed to spawn stream worker")
            })
            .collect();
        AsyncDevice { inner, engine, workers }
    }

    /// The wrapped device.
    pub fn inner(&self) -> &D {
        &self.inner
    }

    /// Number of stream queues.
    pub fn streams(&self) -> usize {
        self.engine.streams
    }

    /// Start recording every enqueue decision of the runtime hazard
    /// tracker (sequence, stream, operand set, full last-toucher edges)
    /// for differential comparison against the static graph from
    /// [`crate::plan::verify::hazard_graph`].
    pub fn enable_hazard_log(&self) {
        self.engine.state.lock().unwrap().hazard_log = Some(Vec::new());
    }

    /// Drain the engine and take the recorded hazard log (empty if
    /// recording was never enabled). Recording stops until re-enabled.
    pub fn take_hazard_log(&self) -> Vec<HazardRecord> {
        self.engine.drain();
        self.engine.state.lock().unwrap().hazard_log.take().unwrap_or_default()
    }
}

impl<D: Device + Send + Sync + 'static> Drop for AsyncDevice<D> {
    fn drop(&mut self) {
        // Drain first: surviving arenas must never wait on ops that no
        // worker will run.
        self.engine.drain();
        self.engine.state.lock().unwrap().shutdown = true;
        self.engine.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl<D: Device + Send + Sync + 'static> Device for AsyncDevice<D> {
    fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena> {
        Box::new(AsyncArena {
            handle: Arc::new(InnerArena {
                id: self.engine.next_arena.fetch_add(1, Ordering::Relaxed),
                cell: RwLock::new(self.inner.new_arena(capacity)),
            }),
            engine: self.engine.clone(),
        })
    }

    fn launch(&self, arena: &mut dyn DeviceArena, launch: &Launch<'_>) {
        let owned = OwnedLaunch::from_launch(launch).unwrap_or_else(|| {
            panic!(
                "{} is a substitution-phase launch; AsyncDevice executes it \
                 synchronously through launch_solve",
                launch.opcode()
            )
        });
        match arena.as_any_mut().downcast_mut::<AsyncArena>() {
            Some(aa) => {
                let operands = owned.operand_set();
                let opcode = launch.opcode();
                let handle = aa.handle.clone();
                self.engine.enqueue(
                    handle.id,
                    &operands,
                    OverlapKind::Compute,
                    opcode,
                    OpAction::Launch { arena: handle, launch: owned },
                );
            }
            // A foreign arena (e.g. the wrapped device's own): execute
            // synchronously — correct, just without overlap.
            None => self.inner.launch(arena, launch),
        }
    }

    fn launch_solve(
        &self,
        factor: &dyn DeviceArena,
        ws: &mut dyn DeviceArena,
        launch: &Launch<'_>,
    ) {
        // Quiesce journaled factor traffic (lock-free once the factor is
        // resident), then delegate on the calling thread: solve
        // concurrency is the workspace pool's job, not the journal's.
        self.engine.drain();
        {
            let f_id = factor.as_any().downcast_ref::<AsyncArena>().map(|a| a.handle.id);
            let w_id = ws.as_any().downcast_ref::<AsyncArena>().map(|a| a.handle.id);
            if let (Some(f), Some(w)) = (f_id, w_id) {
                assert_ne!(
                    f, w,
                    "launch_solve requires distinct factor and workspace regions"
                );
            }
        }
        let f_guard = factor
            .as_any()
            .downcast_ref::<AsyncArena>()
            .map(|a| read_cell(&a.handle.cell));
        let factor_ref: &dyn DeviceArena = match &f_guard {
            Some(g) => &***g,
            None => factor,
        };
        // Time the delegated call against the engine epoch so the solve
        // path shows up in the overlap trace alongside the factorization
        // workers' events (per-stream busy intervals, RunReport's
        // `solve_trace_events`). Substitution runs on the calling thread;
        // concurrent solve threads therefore appear as overlapping
        // intervals tagged with the current stream/level.
        let t_start = self.engine.origin.elapsed().as_secs_f64();
        match ws.as_any_mut().downcast_mut::<AsyncArena>() {
            Some(wa) => {
                // write_cell recovers a workspace lock poisoned by an
                // earlier panicking launch, so the executor's unwind
                // guard can still reset the region and return it to its
                // pool (the PR-4 contract).
                let mut g = write_cell(&wa.handle.cell);
                self.inner.launch_solve(factor_ref, &mut **g, launch);
            }
            None => self.inner.launch_solve(factor_ref, ws, launch),
        }
        let t_end = self.engine.origin.elapsed().as_secs_f64();
        let mut st = self.engine.state.lock().unwrap();
        let (stream, level) = (st.current_stream, st.current_level);
        st.trace.push(OverlapEvent {
            stream,
            level,
            kind: OverlapKind::Compute,
            opcode: launch.opcode(),
            start: t_start,
            end: t_end,
        });
    }

    fn stream(&self, level: usize) {
        self.engine.set_stream(level);
    }

    fn fence(&self) {
        self.engine.fence();
    }

    fn take_overlap_trace(&self) -> Option<OverlapTrace> {
        self.engine.drain();
        Some(self.engine.take_trace())
    }

    fn name(&self) -> &'static str {
        match self.inner.name() {
            "native" => "async:native",
            "serial" => "async:serial",
            "pjrt" => "async:pjrt",
            _ => "async",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol;
    use crate::solver::backend::SerialBackend;
    use crate::util::Rng;

    #[test]
    fn async_device_replays_launches_bit_identically() {
        let mut rng = Rng::new(42);
        let mats: Vec<Matrix> = (0..3).map(|_| Matrix::rand_spd(10, &mut rng)).collect();
        let dev = AsyncDevice::new(SerialBackend);
        let mut arena = dev.new_arena(4);
        let ids: Vec<BufferId> = (0..3u32).map(BufferId).collect();
        dev.stream(2);
        for (&id, m) in ids.iter().zip(&mats) {
            arena.upload(id, m);
        }
        dev.launch(arena.as_mut(), &Launch::Potrf { level: 2, bufs: &ids });
        // Cross-stream RAW hazard: the extract on the other queue reads a
        // POTRF output and must wait for it.
        dev.stream(1);
        let ex = [ExtractItem { src: ids[0], r0: 0, c0: 0, rows: 4, cols: 4, dst: BufferId(3) }];
        dev.launch(arena.as_mut(), &Launch::Extract { items: &ex });
        dev.fence();
        for (&id, m) in ids.iter().zip(&mats) {
            let want = chol::cholesky(m).unwrap();
            assert_eq!(arena.download(id).as_slice(), want.as_slice());
        }
        let want_block = chol::cholesky(&mats[0]).unwrap().submatrix(0, 0, 4, 4);
        assert_eq!(arena.download(BufferId(3)).as_slice(), want_block.as_slice());
        assert_eq!(arena.live(), 4);
        let trace = dev.take_overlap_trace().expect("async devices trace");
        assert_eq!(trace.events.len(), 5, "3 uploads + 2 launches");
        assert!(trace.streams() >= 1);
    }

    #[test]
    fn async_device_journals_frees_in_hazard_order() {
        let mut rng = Rng::new(43);
        let m = Matrix::rand_spd(8, &mut rng);
        let dev = AsyncDevice::new(SerialBackend);
        let mut arena = dev.new_arena(2);
        dev.stream(0);
        arena.upload(BufferId(0), &m);
        let ex = [ExtractItem { src: BufferId(0), r0: 0, c0: 0, rows: 8, cols: 8, dst: BufferId(1) }];
        dev.launch(arena.as_mut(), &Launch::Extract { items: &ex });
        // The free on the other stream must wait for the extract's read.
        dev.stream(1);
        arena.free(BufferId(0));
        dev.fence();
        assert_eq!(arena.live(), 1);
        assert_eq!(arena.download(BufferId(1)).as_slice(), m.as_slice());
        assert!(!arena.is_live(BufferId(0)));
    }

    #[test]
    fn async_fence_reraises_worker_panics() {
        let dev = AsyncDevice::new(SerialBackend);
        let mut arena = dev.new_arena(1);
        // POTRF of a buffer that was never uploaded: the worker panics,
        // fence re-raises on this thread.
        let bufs = [BufferId(0)];
        dev.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &bufs });
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| dev.fence()));
        assert!(err.is_err(), "fence must re-raise the worker panic");
        // The engine stays usable afterwards.
        arena.upload(BufferId(0), &Matrix::eye(2));
        dev.fence();
        assert_eq!(arena.live(), 1);
    }
}
