//! [`ValidatingDevice`]: a debug wrapper that audits every [`Launch`]
//! against arena state before executing it.
//!
//! The hazard rules of the device contract (device.rs "Streams, fences,
//! and hazards") are only trustworthy if something checks them. This
//! wrapper enforces, per launch, the invariants every recorded plan must
//! satisfy — and panics with the offending instruction when one is
//! violated, so a recorder bug surfaces at the launch that exposes it
//! rather than as a wrong number three levels later:
//!
//! 1. **Liveness** — every operand that is read (or updated in place) must
//!    be live in its arena: matrices in the factorization arena (the
//!    factor region for substitution launches), vectors in the workspace.
//!    A dead or never-written operand is a use-after-free or a wiring bug.
//! 2. **No out-of-range ids** — `BufferId(u32::MAX)` is the recorder's
//!    "unset" placeholder; reaching a backend means the backward-pass
//!    wiring left a hole.
//! 3. **No write aliasing within one launch** — batch items execute
//!    concurrently on real backends, so (a) no two items may write the
//!    same buffer, and (b) no item may write a buffer another item reads.
//!    In-place updates (POTRF blocks, TRSM panels, TRSV/GEMV vectors) are
//!    the defined exception for their *own* operand, never across items.
//!
//! The wrapper is execution-transparent: it delegates to the wrapped
//! device after the audit, so results are bit-identical and it composes
//! with any backend (`ValidatingDevice<NativeBackend>` in the test suite;
//! wrap it *inside* an [`super::AsyncDevice`] to audit at execution time
//! with the journal's private arenas).
//!
//! Launch legality itself (unset ids, intra-launch write aliasing, the
//! read-only factor region) has exactly one implementation — the static
//! primitives in [`crate::plan::verify`] — applied here per launch against
//! real arena state, and there per program at record time. Only the
//! genuinely runtime-only check (is the operand actually live in *this*
//! arena) stays local.

use super::{launch_operands, Device, DeviceArena, Launch};
use crate::metrics::overlap::OverlapTrace;
use crate::plan::verify::{is_unset, solve_writes_matrices, write_alias_hazard, LaunchHazard};
use crate::plan::BufferId;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Debug wrapper auditing every launch (see the module docs). Panics on
/// the first violated invariant; [`ValidatingDevice::audited`] counts the
/// launches that passed.
pub struct ValidatingDevice<D: Device> {
    inner: D,
    audited: AtomicUsize,
}

impl<D: Device> ValidatingDevice<D> {
    pub fn new(inner: D) -> ValidatingDevice<D> {
        ValidatingDevice { inner, audited: AtomicUsize::new(0) }
    }

    /// Number of launches audited (and passed) so far.
    pub fn audited(&self) -> usize {
        self.audited.load(Ordering::Relaxed)
    }

    /// The wrapped device.
    pub fn inner(&self) -> &D {
        &self.inner
    }
}

/// Panic with the audit reason and the offending instruction.
fn violation(launch: &Launch<'_>, reason: String) -> ! {
    panic!(
        "hazard audit failed for {}: {reason}\noffending instruction: {launch:?}",
        launch.opcode()
    )
}

fn check_id(launch: &Launch<'_>, id: BufferId, role: &str) {
    if is_unset(id) {
        violation(launch, format!("{role} operand is the unset placeholder B{} (out of range)", id.0));
    }
}

fn check_live(arena: &dyn DeviceArena, launch: &Launch<'_>, id: BufferId, role: &str) {
    check_id(launch, id, role);
    if !arena.is_live(id) {
        violation(
            launch,
            format!("{role} operand B{} is not live (never written, freed, or out of range)", id.0),
        );
    }
}

/// Shared write-set audit: no duplicate write targets, no write target
/// aliasing a read operand of another item (the decision lives in
/// [`write_alias_hazard`]; this wrapper just renders it as a panic).
fn check_write_aliasing(
    launch: &Launch<'_>,
    reads: &[BufferId],
    rw: &[BufferId],
    writes: &[BufferId],
    space: &str,
) {
    match write_alias_hazard(reads, rw, writes) {
        None => {}
        Some(LaunchHazard::DuplicateWrite(b)) => violation(
            launch,
            format!("two batch items write the same {space} buffer B{}", b.0),
        ),
        Some(LaunchHazard::ReadWriteAlias(b)) => violation(
            launch,
            format!(
                "{space} buffer B{} is read by one batch item and written by another \
                 (intra-launch aliasing)",
                b.0
            ),
        ),
    }
}

/// Audit a factorization-phase launch against its arena.
fn audit_factor(arena: &dyn DeviceArena, launch: &Launch<'_>) {
    let ops = launch_operands(launch);
    for &id in &ops.mat_reads {
        check_live(arena, launch, id, "read");
    }
    for &id in &ops.mat_rw {
        check_live(arena, launch, id, "in-place");
    }
    for &id in &ops.mat_writes {
        check_id(launch, id, "output");
    }
    check_write_aliasing(launch, &ops.mat_reads, &ops.mat_rw, &ops.mat_writes, "matrix");
}

/// Audit a substitution-phase launch: matrices resolve read-only in the
/// factor region, vectors in the workspace.
fn audit_solve(factor: &dyn DeviceArena, ws: &dyn DeviceArena, launch: &Launch<'_>) {
    let ops = launch_operands(launch);
    if solve_writes_matrices(&ops) {
        violation(
            launch,
            "substitution launches must not write matrix buffers (the factor region is \
             read-only)"
                .to_string(),
        );
    }
    for &id in &ops.mat_reads {
        check_live(factor, launch, id, "factor-region read");
    }
    for &id in &ops.vec_reads {
        check_live(ws, launch, id, "workspace read");
    }
    for &id in &ops.vec_rw {
        check_live(ws, launch, id, "workspace in-place");
    }
    for &id in &ops.vec_writes {
        check_id(launch, id, "workspace output");
    }
    check_write_aliasing(launch, &ops.vec_reads, &ops.vec_rw, &ops.vec_writes, "vector");
}

impl<D: Device> Device for ValidatingDevice<D> {
    fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena> {
        self.inner.new_arena(capacity)
    }

    fn launch(&self, arena: &mut dyn DeviceArena, launch: &Launch<'_>) {
        audit_factor(arena, launch);
        self.audited.fetch_add(1, Ordering::Relaxed);
        self.inner.launch(arena, launch);
    }

    fn launch_solve(
        &self,
        factor: &dyn DeviceArena,
        ws: &mut dyn DeviceArena,
        launch: &Launch<'_>,
    ) {
        audit_solve(factor, ws, launch);
        self.audited.fetch_add(1, Ordering::Relaxed);
        self.inner.launch_solve(factor, ws, launch);
    }

    fn stream(&self, level: usize) {
        self.inner.stream(level);
    }

    fn fence(&self) {
        self.inner.fence();
    }

    fn take_overlap_trace(&self) -> Option<OverlapTrace> {
        self.inner.take_overlap_trace()
    }

    // Transparent: audits never change results, so reports keep the
    // wrapped backend's name.
    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::plan::ExtractItem;
    use crate::solver::backend::SerialBackend;
    use crate::util::Rng;

    fn dev() -> ValidatingDevice<SerialBackend> {
        ValidatingDevice::new(SerialBackend)
    }

    #[test]
    fn audit_passes_well_formed_launches() {
        let mut rng = Rng::new(7);
        let d = dev();
        let mut arena = d.new_arena(2);
        arena.upload(BufferId(0), &Matrix::rand_spd(6, &mut rng));
        let bufs = [BufferId(0)];
        d.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &bufs });
        let ex = [ExtractItem { src: BufferId(0), r0: 0, c0: 0, rows: 2, cols: 2, dst: BufferId(1) }];
        d.launch(arena.as_mut(), &Launch::Extract { items: &ex });
        assert_eq!(d.audited(), 2);
        assert_eq!(arena.live(), 2);
    }

    #[test]
    #[should_panic(expected = "hazard audit failed for POTRF")]
    fn audit_rejects_dead_operand() {
        let d = dev();
        let mut arena = d.new_arena(1);
        let bufs = [BufferId(0)];
        d.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &bufs });
    }

    #[test]
    #[should_panic(expected = "two batch items write the same matrix buffer")]
    fn audit_rejects_duplicate_write_targets() {
        let mut rng = Rng::new(9);
        let d = dev();
        let mut arena = d.new_arena(1);
        arena.upload(BufferId(0), &Matrix::rand_spd(4, &mut rng));
        let bufs = [BufferId(0), BufferId(0)];
        d.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &bufs });
    }

    #[test]
    #[should_panic(expected = "intra-launch aliasing")]
    fn audit_rejects_write_read_aliasing() {
        let mut rng = Rng::new(11);
        let d = dev();
        let mut arena = d.new_arena(2);
        arena.upload(BufferId(0), &Matrix::randn(4, 4, &mut rng));
        arena.upload(BufferId(1), &Matrix::randn(4, 4, &mut rng));
        // Item 1 reads B0; item 2 writes B0 while reading B1.
        let ex = [
            ExtractItem { src: BufferId(0), r0: 0, c0: 0, rows: 2, cols: 2, dst: BufferId(2) },
            ExtractItem { src: BufferId(1), r0: 0, c0: 0, rows: 2, cols: 2, dst: BufferId(0) },
        ];
        d.launch(arena.as_mut(), &Launch::Extract { items: &ex });
    }

    #[test]
    #[should_panic(expected = "unset placeholder")]
    fn audit_rejects_out_of_range_ids() {
        let d = dev();
        let mut arena = d.new_arena(1);
        let ex = [ExtractItem {
            src: BufferId(u32::MAX),
            r0: 0,
            c0: 0,
            rows: 1,
            cols: 1,
            dst: BufferId(0),
        }];
        d.launch(arena.as_mut(), &Launch::Extract { items: &ex });
    }

    #[test]
    #[should_panic(expected = "factor region is read-only")]
    fn audit_rejects_matrix_writes_in_solve_launches() {
        let d = dev();
        let factor = d.new_arena(1);
        let mut ws = d.new_arena(1);
        let bufs = [BufferId(0)];
        // A factorization opcode routed through launch_solve.
        d.launch_solve(factor.as_ref(), ws.as_mut(), &Launch::Potrf { level: 0, bufs: &bufs });
    }
}
