//! Zero-padding and shape-bucketing utilities for constant-size batched
//! execution (paper §4.1 "Variable-size batch versus constant-size batch").
//!
//! The paper found variable-size batched kernels ~50% slower than
//! constant-size ones and chose zero-padding to the level maximum, with
//! dimensions rounded to multiples of 4 and a diagonal fill so padded
//! Cholesky stays non-singular. These helpers implement exactly that
//! policy for the PJRT backend.

use crate::linalg::Matrix;
use crate::util::{next_pow2, round_up};

/// Batch-size buckets compiled as AOT artifacts.
pub const BATCH_BUCKETS: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Round a batch size up to the next compiled bucket (saturating at the
/// largest bucket — callers then split the batch).
pub fn batch_bucket(n: usize) -> usize {
    let b = next_pow2(n.max(1));
    *BATCH_BUCKETS
        .iter()
        .find(|&&x| x >= b)
        .unwrap_or(BATCH_BUCKETS.last().unwrap())
}

/// Pad a matrix dimension to a multiple of 4 (cuBLAS/cuSOLVER alignment
/// guidance quoted by the paper).
pub fn dim_pad(d: usize) -> usize {
    round_up(d.max(1), 4)
}

/// Number of batch slots a constant-size batched launch executes for `n`
/// useful items: the next compiled bucket, or whole 256-slot splits past
/// the largest bucket. Used by the plan IR to report padding waste.
pub fn padded_batch(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let top = *BATCH_BUCKETS.last().unwrap();
    if n <= top {
        batch_bucket(n)
    } else {
        n.div_ceil(top) * top
    }
}

/// Pad `m` into shape `(rows, cols)`, writing `diag_fill` on padded diagonal
/// entries (the paper's AXPY-diagonal trick: keeps padded POTRF/TRSM
/// non-singular, zero elsewhere so GEMM results are unaffected).
pub fn pad_matrix(m: &Matrix, rows: usize, cols: usize, diag_fill: f64) -> Matrix {
    assert!(rows >= m.rows() && cols >= m.cols());
    let mut out = m.resized(rows, cols);
    if diag_fill != 0.0 {
        let start = m.rows().min(m.cols());
        for d in start..rows.min(cols) {
            out[(d, d)] = diag_fill;
        }
    }
    out
}

/// Flatten a padded batch into one contiguous row-major `[batch, rows, cols]`
/// buffer (the layout the XLA artifacts take).
pub fn batch_to_buffer(mats: &[Matrix], rows: usize, cols: usize, diag_fill: f64) -> Vec<f32> {
    let mut buf = vec![0.0f32; mats.len() * rows * cols];
    for (t, m) in mats.iter().enumerate() {
        let base = t * rows * cols;
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                buf[base + i * cols + j] = m[(i, j)] as f32;
            }
        }
        if diag_fill != 0.0 {
            let start = m.rows().min(m.cols());
            for d in start..rows.min(cols) {
                buf[base + d * cols + d] = diag_fill as f32;
            }
        }
    }
    buf
}

/// Extract the leading `(rows_t, cols_t)` of each batch element from a
/// row-major `[batch, rows, cols]` buffer.
pub fn buffer_to_batch(
    buf: &[f32],
    rows: usize,
    cols: usize,
    shapes: &[(usize, usize)],
) -> Vec<Matrix> {
    let mut out = Vec::with_capacity(shapes.len());
    for (t, &(r, c)) in shapes.iter().enumerate() {
        let base = t * rows * cols;
        out.push(Matrix::from_fn(r, c, |i, j| buf[base + i * cols + j] as f64));
    }
    out
}

/// Double-precision variants (the f64 artifacts). Shares the padding and
/// diag-fill rules with [`refs_to_buffer_f64`] so the AXPY-diagonal
/// semantics live in one place.
pub fn batch_to_buffer_f64(mats: &[Matrix], rows: usize, cols: usize, diag_fill: f64) -> Vec<f64> {
    let refs: Vec<&Matrix> = mats.iter().collect();
    refs_to_buffer_f64(&refs, mats.len(), rows, cols, diag_fill)
}

/// First-class padded upload: write a batch of matrix *references* straight
/// into a constant-shape row-major `[slots, rows, cols]` buffer, including
/// the padding slots past `mats.len()` (their diagonals get `diag_fill`, so
/// a padded POTRF/TRSM sees identity blocks — the paper's batched-AXPY
/// diagonal trick). Replaces the clone-resize-flatten round trip the PJRT
/// backend used to perform per op.
pub fn refs_to_buffer_f64(
    mats: &[&Matrix],
    slots: usize,
    rows: usize,
    cols: usize,
    diag_fill: f64,
) -> Vec<f64> {
    assert!(slots >= mats.len());
    let mut buf = vec![0.0f64; slots * rows * cols];
    for (t, m) in mats.iter().enumerate() {
        let base = t * rows * cols;
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                buf[base + i * cols + j] = m[(i, j)];
            }
        }
        if diag_fill != 0.0 {
            let start = m.rows().min(m.cols());
            for d in start..rows.min(cols) {
                buf[base + d * cols + d] = diag_fill;
            }
        }
    }
    if diag_fill != 0.0 {
        for t in mats.len()..slots {
            let base = t * rows * cols;
            for d in 0..rows.min(cols) {
                buf[base + d * cols + d] = diag_fill;
            }
        }
    }
    buf
}

/// Padded upload of vector references into a `[slots, rows, 1]` buffer
/// (segment vectors for the batched TRSV/GEMV/BASIS artifacts).
pub fn vecs_to_buffer_f64(xs: &[&[f64]], slots: usize, rows: usize) -> Vec<f64> {
    assert!(slots >= xs.len());
    let mut buf = vec![0.0f64; slots * rows];
    for (t, x) in xs.iter().enumerate() {
        buf[t * rows..t * rows + x.len()].copy_from_slice(x);
    }
    buf
}

pub fn buffer_to_batch_f64(
    buf: &[f64],
    rows: usize,
    cols: usize,
    shapes: &[(usize, usize)],
) -> Vec<Matrix> {
    let mut out = Vec::with_capacity(shapes.len());
    for (t, &(r, c)) in shapes.iter().enumerate() {
        let base = t * rows * cols;
        out.push(Matrix::from_fn(r, c, |i, j| buf[base + i * cols + j]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::Rng;

    #[test]
    fn buckets_round_up() {
        assert_eq!(batch_bucket(1), 1);
        assert_eq!(batch_bucket(3), 4);
        assert_eq!(batch_bucket(64), 64);
        assert_eq!(batch_bucket(65), 128);
        assert_eq!(batch_bucket(1000), 256); // saturates, caller splits
    }

    #[test]
    fn padded_batch_buckets_and_splits() {
        assert_eq!(padded_batch(0), 0);
        assert_eq!(padded_batch(3), 4);
        assert_eq!(padded_batch(256), 256);
        assert_eq!(padded_batch(257), 512); // two 256-slot launches
        assert_eq!(padded_batch(1000), 1024);
    }

    #[test]
    fn dim_pad_multiple_of_4() {
        assert_eq!(dim_pad(1), 4);
        assert_eq!(dim_pad(4), 4);
        assert_eq!(dim_pad(13), 16);
    }

    #[test]
    fn pad_matrix_diag_fill() {
        let m = Matrix::eye(2);
        let p = pad_matrix(&m, 4, 4, 1.0);
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(2, 2)], 1.0);
        assert_eq!(p[(3, 3)], 1.0);
        assert_eq!(p[(2, 0)], 0.0);
        // Padded Cholesky must succeed and reproduce the original corner.
        let l = crate::linalg::chol::cholesky(&p).unwrap();
        assert!((l[(0, 0)] - 1.0).abs() < 1e-14);
        assert!((l[(3, 3)] - 1.0).abs() < 1e-14);
    }

    #[test]
    fn refs_buffer_matches_clone_resize_path() {
        let mut rng = Rng::new(0xBEEF);
        let mats: Vec<Matrix> = (0..3).map(|_| Matrix::randn(5, 7, &mut rng)).collect();
        let refs: Vec<&Matrix> = mats.iter().collect();
        let (pr, pc, slots) = (dim_pad(5), dim_pad(7), 4);
        for diag in [0.0, 1.0] {
            // Old path: clone, resize with eye/zeros, flatten.
            let mut padded = mats.clone();
            let filler = if diag != 0.0 {
                Matrix::eye(pr.min(pc))
            } else {
                Matrix::zeros(pr, pc)
            };
            padded.resize(slots, filler);
            let want = batch_to_buffer_f64(&padded, pr, pc, diag);
            // New path: straight from refs.
            let got = refs_to_buffer_f64(&refs, slots, pr, pc, diag);
            assert_eq!(got, want, "diag_fill={diag}");
        }
        // Vector variant.
        let xs: Vec<Vec<f64>> = (0..2).map(|i| vec![i as f64 + 1.0; 3]).collect();
        let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        let buf = vecs_to_buffer_f64(&xrefs, 4, 6);
        assert_eq!(buf.len(), 24);
        assert_eq!(&buf[0..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&buf[6..9], &[2.0, 2.0, 2.0]);
        assert_eq!(&buf[12..], &[0.0; 12]);
    }

    #[test]
    fn prop_buffer_roundtrip() {
        check(
            &PropConfig { cases: 24, seed: 0xFADE },
            |rng| {
                let b = 1 + rng.below(6);
                let r = 1 + rng.below(9);
                let c = 1 + rng.below(9);
                let seed = rng.next_u64();
                (b, r, c, seed)
            },
            |&(b, r, c, seed)| {
                let mut rng = Rng::new(seed);
                let mats: Vec<Matrix> = (0..b).map(|_| Matrix::randn(r, c, &mut rng)).collect();
                let pr = dim_pad(r);
                let pc = dim_pad(c);
                let buf = batch_to_buffer_f64(&mats, pr, pc, 0.0);
                let shapes: Vec<(usize, usize)> = mats.iter().map(|m| (m.rows(), m.cols())).collect();
                let back = buffer_to_batch_f64(&buf, pr, pc, &shapes);
                for (a, bm) in mats.iter().zip(&back) {
                    if a != bm {
                        return Err("roundtrip mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }
}
