//! Native batched backend: each batch item runs on the worker pool with the
//! from-scratch dense kernels. This is the paper's CPU execution path
//! ("for the CPU, we utilize the multiple cores", §6.2).
//!
//! [`NativeBackend`] implements the arena-native
//! [`Device`](super::device::Device) trait: launches arrive with `BufferId`
//! operands, the shared [`HostArena`](super::device::HostArena) supplies
//! the blocks by pointer move, and the batched math below runs each item
//! on the thread pool. The kernels are also exposed as inherent methods
//! for micro-benchmarks.

use super::device::{
    exec_host_launch, exec_host_solve_launch, host_arena, host_arena_ref, Device, DeviceArena,
    HostArena, HostKernels, Launch,
};
use crate::linalg::blas::{self, Side, Uplo};
use crate::linalg::chol;
use crate::linalg::matrix::{Matrix, Trans};
use crate::metrics::flops;
use crate::metrics::RunTrace;
use crate::util::par_for;
use std::sync::Mutex;

/// Thread-pool batched backend.
#[derive(Default)]
pub struct NativeBackend {
    /// Optional span trace recording every batched launch (Fig 12 analog).
    pub trace: Option<RunTrace>,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record every batched kernel launch into `trace` (a clone of the
    /// caller's session-wide [`RunTrace`]).
    pub fn with_trace(trace: RunTrace) -> Self {
        NativeBackend { trace: Some(trace) }
    }

    fn trace<T>(
        &self,
        level: usize,
        kernel: &'static str,
        batch: usize,
        shape: (usize, usize),
        f: impl FnOnce() -> T,
    ) -> T {
        match &self.trace {
            Some(tr) => tr.record(level, kernel, batch, shape, f),
            None => f(),
        }
    }

    /// In-place lower Cholesky of each block.
    pub fn potrf(&self, level: usize, blocks: &mut [Matrix]) {
        let shape = blocks.first().map(|b| (b.rows(), b.cols())).unwrap_or((0, 0));
        let n = blocks.len();
        self.trace(level, "POTRF", n, shape, || {
            let failed = Mutex::new(Vec::new());
            {
                let failed_ref = &failed;
                let blocks_ptr = SendPtr(blocks.as_mut_ptr());
                let pr = &blocks_ptr;
                par_for(n, move |t| {
                    // SAFETY: disjoint indices (par_for visits each once).
                    let blk = unsafe { &mut *pr.0.add(t) };
                    flops::add(flops::potrf_flops(blk.rows()));
                    if let Err(e) = chol::potrf(blk) {
                        failed_ref.lock().unwrap().push((t, e));
                    }
                });
            }
            let failed = failed.into_inner().unwrap();
            assert!(
                failed.is_empty(),
                "batched POTRF failed on {} block(s): {:?}",
                failed.len(),
                &failed[..failed.len().min(3)]
            );
        });
    }

    /// `B_t <- B_t · L_tᵀ⁻¹` for each t.
    pub fn trsm_right_lt(&self, level: usize, l: &[&Matrix], b: &mut [Matrix]) {
        assert_eq!(l.len(), b.len());
        let shape = b.first().map(|m| (m.rows(), m.cols())).unwrap_or((0, 0));
        let n = b.len();
        self.trace(level, "TRSM", n, shape, || {
            let b_ptr = SendPtr(b.as_mut_ptr());
            let pr = &b_ptr;
            par_for(n, move |t| {
                let bt = unsafe { &mut *pr.0.add(t) };
                flops::add(flops::trsm_flops(l[t].rows(), bt.rows()));
                blas::trsm(Side::Right, Uplo::Lower, Trans::Yes, 1.0, l[t], bt);
            });
        });
    }

    /// `C_t <- C_t - A_t A_tᵀ` (SYRK-shaped Schur update).
    pub fn schur_self(&self, level: usize, a: &[&Matrix], c: &mut [Matrix]) {
        assert_eq!(a.len(), c.len());
        let shape = c.first().map(|m| (m.rows(), m.cols())).unwrap_or((0, 0));
        let n = c.len();
        self.trace(level, "SYRK", n, shape, || {
            let c_ptr = SendPtr(c.as_mut_ptr());
            let pr = &c_ptr;
            par_for(n, move |t| {
                let ct = unsafe { &mut *pr.0.add(t) };
                flops::add(flops::gemm_flops(a[t].rows(), a[t].rows(), a[t].cols()));
                blas::gemm(-1.0, a[t], Trans::No, a[t], Trans::Yes, 1.0, ct);
            });
        });
    }

    /// Two-sided basis transform `F_t = U_tᵀ A_t V_t`.
    pub fn sparsify(&self, level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix> {
        assert_eq!(u.len(), a.len());
        assert_eq!(v.len(), a.len());
        let shape = a.first().map(|m| (m.rows(), m.cols())).unwrap_or((0, 0));
        self.trace(level, "GEMM2", a.len(), shape, || {
            crate::util::par_map(a.len(), |t| {
                super::count_sparsify_flops(u[t], &a[t], v[t]);
                // F = Uᵀ A V
                let mut ua = Matrix::zeros(u[t].cols(), a[t].cols());
                blas::gemm(1.0, u[t], Trans::Yes, &a[t], Trans::No, 0.0, &mut ua);
                let mut f = Matrix::zeros(u[t].cols(), v[t].cols());
                blas::gemm(1.0, &ua, Trans::No, v[t], Trans::No, 0.0, &mut f);
                f
            })
        })
    }

    /// Batched forward TRSV.
    pub fn trsv_fwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        assert_eq!(l.len(), x.len());
        let n = x.len();
        let shape = l.first().map(|m| (m.rows(), 1)).unwrap_or((0, 0));
        self.trace(level, "TRSV", n, shape, || {
            let x_ptr = SendPtr(x.as_mut_ptr());
            let pr = &x_ptr;
            par_for(n, move |t| {
                let xt = unsafe { &mut *pr.0.add(t) };
                flops::add((l[t].rows() * l[t].rows()) as u64);
                blas::trsv(Uplo::Lower, Trans::No, l[t], xt);
            });
        });
    }

    /// Batched backward TRSV.
    pub fn trsv_bwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        assert_eq!(l.len(), x.len());
        let n = x.len();
        let shape = l.first().map(|m| (m.rows(), 1)).unwrap_or((0, 0));
        self.trace(level, "TRSVT", n, shape, || {
            let x_ptr = SendPtr(x.as_mut_ptr());
            let pr = &x_ptr;
            par_for(n, move |t| {
                let xt = unsafe { &mut *pr.0.add(t) };
                flops::add((l[t].rows() * l[t].rows()) as u64);
                blas::trsv(Uplo::Lower, Trans::Yes, l[t], xt);
            });
        });
    }

    /// Batched GEMV accumulate `y_t += alpha · op(A_t) x_t`.
    pub fn gemv_acc(
        &self,
        level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    ) {
        assert_eq!(a.len(), x.len());
        assert_eq!(a.len(), y.len());
        let n = a.len();
        let shape = a.first().map(|m| (m.rows(), m.cols())).unwrap_or((0, 0));
        self.trace(level, "GEMV", n, shape, || {
            let y_ptr = SendPtr(y.as_mut_ptr());
            let pr = &y_ptr;
            let ta = if trans { Trans::Yes } else { Trans::No };
            par_for(n, move |t| {
                let yt = unsafe { &mut *pr.0.add(t) };
                flops::add(2 * (a[t].rows() * a[t].cols()) as u64);
                blas::gemv(alpha, a[t], ta, x[t], 1.0, yt);
            });
        });
    }

    /// Batched `y_t = op(U_t) x_t` (basis applied to segment vectors).
    pub fn apply_basis(
        &self,
        level: usize,
        u: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        assert_eq!(u.len(), x.len());
        let shape = u.first().map(|m| (m.rows(), m.cols())).unwrap_or((0, 0));
        self.trace(level, "BASIS", u.len(), shape, || {
            let ta = if trans { Trans::Yes } else { Trans::No };
            crate::util::par_map(u.len(), |t| {
                let out_len = if trans { u[t].cols() } else { u[t].rows() };
                let mut y = vec![0.0; out_len];
                flops::add(2 * (u[t].rows() * u[t].cols()) as u64);
                blas::gemv(1.0, u[t], ta, x[t], 0.0, &mut y);
                y
            })
        })
    }
}

impl HostKernels for NativeBackend {
    fn potrf(&self, level: usize, blocks: &mut [Matrix]) {
        NativeBackend::potrf(self, level, blocks);
    }
    fn trsm_right_lt(&self, level: usize, l: &[&Matrix], b: &mut [Matrix]) {
        NativeBackend::trsm_right_lt(self, level, l, b);
    }
    fn schur_self(&self, level: usize, a: &[&Matrix], c: &mut [Matrix]) {
        NativeBackend::schur_self(self, level, a, c);
    }
    fn sparsify(&self, level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix> {
        NativeBackend::sparsify(self, level, u, a, v)
    }
    fn trsv_fwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        NativeBackend::trsv_fwd(self, level, l, x);
    }
    fn trsv_bwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]) {
        NativeBackend::trsv_bwd(self, level, l, x);
    }
    fn gemv_acc(
        &self,
        level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    ) {
        NativeBackend::gemv_acc(self, level, alpha, a, trans, x, y);
    }
    fn apply_basis(
        &self,
        level: usize,
        u: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
    ) -> Vec<Vec<f64>> {
        NativeBackend::apply_basis(self, level, u, trans, x)
    }
}

impl Device for NativeBackend {
    fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena> {
        Box::new(HostArena::with_capacity(capacity))
    }

    fn launch(&self, arena: &mut dyn DeviceArena, launch: &Launch<'_>) {
        exec_host_launch(self, host_arena(arena), launch);
    }

    fn launch_solve(
        &self,
        factor: &dyn DeviceArena,
        ws: &mut dyn DeviceArena,
        launch: &Launch<'_>,
    ) {
        exec_host_solve_launch(self, host_arena_ref(factor), host_arena(ws), launch);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Raw-pointer wrapper for disjoint-index parallel writes.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::frob;
    use crate::util::Rng;

    #[test]
    fn batched_potrf_matches_serial() {
        let mut rng = Rng::new(101);
        let mats: Vec<Matrix> = (0..9).map(|_| Matrix::rand_spd(12, &mut rng)).collect();
        let mut batch = mats.clone();
        NativeBackend::new().potrf(0, &mut batch);
        for (orig, l) in mats.iter().zip(&batch) {
            let want = chol::cholesky(orig).unwrap();
            let mut d = l.clone();
            d.axpy(-1.0, &want);
            assert!(frob(&d) < 1e-12 * frob(&want));
        }
    }

    #[test]
    fn batched_trsm_matches_serial() {
        let mut rng = Rng::new(103);
        let ls: Vec<Matrix> = (0..5)
            .map(|_| chol::cholesky(&Matrix::rand_spd(8, &mut rng)).unwrap())
            .collect();
        let bs: Vec<Matrix> = (0..5).map(|_| Matrix::randn(6, 8, &mut rng)).collect();
        let mut batch = bs.clone();
        let lrefs: Vec<&Matrix> = ls.iter().collect();
        NativeBackend::new().trsm_right_lt(0, &lrefs, &mut batch);
        for t in 0..5 {
            let mut want = bs[t].clone();
            blas::trsm(Side::Right, Uplo::Lower, Trans::Yes, 1.0, &ls[t], &mut want);
            let mut d = batch[t].clone();
            d.axpy(-1.0, &want);
            assert!(frob(&d) < 1e-13);
        }
    }

    #[test]
    fn sparsify_is_two_sided_product() {
        let mut rng = Rng::new(105);
        let u = Matrix::randn(6, 6, &mut rng);
        let v = Matrix::randn(5, 5, &mut rng);
        let a = Matrix::randn(6, 5, &mut rng);
        let f = NativeBackend::new().sparsify(0, &[&u], vec![a.clone()].as_slice(), &[&v]);
        let mut ua = Matrix::zeros(6, 5);
        blas::gemm(1.0, &u, Trans::Yes, &a, Trans::No, 0.0, &mut ua);
        let mut want = Matrix::zeros(6, 5);
        blas::gemm(1.0, &ua, Trans::No, &v, Trans::No, 0.0, &mut want);
        let mut d = f[0].clone();
        d.axpy(-1.0, &want);
        assert!(frob(&d) < 1e-13);
    }

    #[test]
    fn gemv_acc_accumulates() {
        let mut rng = Rng::new(107);
        let a = Matrix::randn(4, 3, &mut rng);
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![vec![1.0; 4]];
        NativeBackend::new().gemv_acc(0, -1.0, &[&a], false, &[&x], &mut y);
        for i in 0..4 {
            let want = 1.0 - (a[(i, 0)] + 2.0 * a[(i, 1)] + 3.0 * a[(i, 2)]);
            assert!((y[0][i] - want).abs() < 1e-13);
        }
    }

    #[test]
    fn run_trace_collects_launches() {
        let mut rng = Rng::new(109);
        let tr = RunTrace::new();
        let be = NativeBackend::with_trace(tr.clone());
        let mut blocks: Vec<Matrix> = (0..4).map(|_| Matrix::rand_spd(6, &mut rng)).collect();
        be.potrf(2, &mut blocks);
        let spans = tr.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].level, 2);
        assert_eq!(spans[0].batch, 4);
        assert_eq!(spans[0].name, "POTRF");
    }

    #[test]
    fn device_launch_runs_in_arena() {
        // The same POTRF issued through the arena-native Device interface.
        let mut rng = Rng::new(111);
        let mats: Vec<Matrix> = (0..3).map(|_| Matrix::rand_spd(10, &mut rng)).collect();
        let be = NativeBackend::new();
        let mut arena = be.new_arena(3);
        let ids: Vec<crate::plan::BufferId> =
            (0..3u32).map(crate::plan::BufferId).collect();
        for (&id, m) in ids.iter().zip(&mats) {
            arena.upload(id, m);
        }
        be.launch(arena.as_mut(), &Launch::Potrf { level: 0, bufs: &ids });
        be.fence();
        for (&id, orig) in ids.iter().zip(&mats) {
            let got = arena.download(id);
            let want = chol::cholesky(orig).unwrap();
            assert_eq!(got.as_slice(), want.as_slice(), "device POTRF must be bit-identical");
        }
        assert_eq!(arena.live(), 3);
    }
}
