//! The device-resident launch API: the backend contract of the execution
//! engine, designed around the plan IR's own vocabulary (paper §4 "Design
//! considerations for GPUs").
//!
//! A [`Device`] executes [`Launch`]es — opcode + [`BufferId`] operand lists
//! — against a device-owned [`DeviceArena`]. Host data crosses the boundary
//! only through the arena's explicit `upload`/`download` calls (issued by
//! the plan [`crate::plan::Executor`] for `Instr::Upload`, `LoadRhs`, and
//! `StoreSol`); every launch in between references device-resident buffers
//! by id. This is the shape of the paper's GPU implementation: the H²
//! matrix is copied to the device once, the factor stays resident, and the
//! batched cuBLAS/cuSOLVER calls consume device pointer arrays — never
//! host slices.
//!
//! # Launch opcode ↔ paper batched call (§4)
//!
//! | [`Launch`] opcode | Paper batched call |
//! |-------------------|--------------------|
//! | `Potrf` | `cusolverDnDpotrfBatched` on the diagonal `F_ii^RR` blocks (Alg 2 l.8; batch-of-one for the merged root, Alg 2 l.22) |
//! | `TrsmRightLt` | `cublasDtrsmBatched` (right, lower, transposed) panel solves (Alg 2 l.10-13) |
//! | `SchurSelf` | SYRK-shaped `cublasDgemmBatched`, the *single* trailing update of eq 21 |
//! | `Sparsify` | two chained `cublasDgemmBatched` calls, `F = Uᵀ A V` (Figure 2) |
//! | `TrsvFwd` / `TrsvBwd` | `trsmBatched` with one right-hand column (§3.7 eq 31) |
//! | `GemvAcc` | `cublasDgemvBatched` / the paper's "batched AXPY via a degenerate GEMM" (§4.1) |
//! | `ApplyBasis` | `gemvBatched` applying `U_i` / `U_iᵀ` to segment vectors (Alg 3 l.3 and final line) |
//! | `RootSolve` | dense `potrs` at the root — the one serialization point |
//! | `Extract` / `Merge` / `Split` / `Concat` / `CopyBuf` / `AddVec` | device-side batched copies (no FLOPs, no host round-trip) |
//!
//! # Streams, fences, and hazards (normative)
//!
//! These rules are the contract between the plan executor and every
//! overlapping [`Device`] implementation (the in-tree one is
//! [`AsyncDevice`](r#async::AsyncDevice); the three base backends are
//! host-synchronous and satisfy the contract trivially):
//!
//! 1. **Program order is the semantic order.** The executor issues
//!    launches and arena transfers in the recorded plan order; an
//!    implementation may *execute* them in any order that preserves the
//!    per-buffer data dependencies below. The result must be bit-identical
//!    to in-order execution — overlap may only change *when* kernels run,
//!    never their operands or arithmetic.
//! 2. **Hazards are per `BufferId`, with shared readers.** Two operations
//!    conflict iff they touch the same buffer of the same arena and at
//!    least one writes it (write = `upload`/`alloc`/`free` of the id, or a
//!    launch operand in a written role — POTRF blocks, TRSM panels,
//!    SYRK/Sparsify/Extract/Merge destinations, updated/written solve
//!    vectors; the shared role classification is [`launch_operands`]).
//!    Conflicting operations must execute in issue order (RAW, WAR, and
//!    WAW edges all hold); non-conflicting operations may overlap
//!    arbitrarily, and in particular *reads of one buffer never order
//!    against each other* — any number of in-flight operations (and
//!    concurrent solve workspaces) may read the same factor matrix at
//!    once. The plan guarantees launches *within* a level are mutually
//!    independent, and level *k+1*'s uploads are independent of level
//!    *k*'s compute — exactly the overlap the paper's schedule exposes.
//! 3. **[`Device::stream`] is a placement hint, never a synchronization
//!    point.** It marks tree-level boundaries (the executor emits it in
//!    both the factorization and substitution replays); an implementation
//!    may route subsequent work to a different queue, but correctness must
//!    come from rule 2 alone — a device that needs `stream` calls to be
//!    correct is broken.
//! 4. **[`Device::fence`] drains; result reads observe *their arena's*
//!    completed state.** After `fence` returns, every previously issued
//!    operation has completed and its effects are visible to
//!    `download`/`take`. Additionally, a result read
//!    (`download`/`download_vec`/`take`) on any arena must itself observe
//!    the completed state of every operation previously issued *against
//!    that arena* — the arena-scoped half of the fence contract, which is
//!    what lets [`SolveInstr::StoreSol`](crate::plan::SolveInstr) read a
//!    workspace back without quiescing unrelated solves pipelining through
//!    the same device. Arena reads outside those two forms observe
//!    unspecified intermediate state. A panic raised by an asynchronous
//!    operation is re-raised on the issuing side: by the next `fence`, or
//!    by the next result read of the arena the failed operation targeted.
//! 5. **[`Device::launch_solve`] is concurrent and may be asynchronous.**
//!    It may be called from many threads against one shared factor region
//!    with distinct workspaces; implementations must not require the
//!    caller to fence between solve launches of one workspace (their
//!    program order on the calling thread is the dependency order, per
//!    rule 2 — an overlapping device journals them like any other
//!    operation, with the factor matrices as shared reads and the
//!    workspace vectors as writes). Factor and workspace must resolve to
//!    *different* regions; an implementation that detects aliasing rejects
//!    the launch through the typed hazard-violation path (a panic whose
//!    message carries `hazard audit failed`, surfaced by the facade as
//!    [`H2Error::PlanVerification`](crate::solver::H2Error)).
//!
//! # Factor region vs. vector regions (concurrent solves)
//!
//! Factorization owns its arena exclusively (`&mut` through
//! [`Device::launch`]). Once the factor is resident, the arena becomes an
//! **immutable factor region**: substitution programs only *read* the
//! factor matrices (diagonal Cholesky blocks, panels, bases, root) and
//! write exclusively to vector buffers at ids ≥
//! [`SolveProgram::vec_base`](crate::plan::SolveProgram::vec_base). That
//! split is what makes the solve phase inherently concurrent — the
//! paper's throughput-serving scenario of many right-hand sides against
//! one resident factor:
//!
//! * a [`VecRegion`] is one solve's private vector region, carved above
//!   the factor region in the buffer-id space;
//! * a [`WorkspacePool`] leases regions to callers ([`Workspace`] returns
//!   the region on drop — even on panic, so a failed launch can never
//!   shrink pool capacity);
//! * [`Device::launch_solve`] executes a substitution launch with matrix
//!   operands resolved in the shared read-only factor region and vector
//!   operands in the caller's exclusive workspace.
//!
//! Any number of threads may run [`Device::launch_solve`] against the same
//! factor region with distinct workspaces; no lock is held across
//! launches.

pub mod r#async;
pub mod validate;

pub use r#async::{AsyncDevice, HazardRecord};
pub use validate::ValidatingDevice;

use crate::linalg::{chol, Matrix};
use crate::metrics::flops;
use crate::metrics::overlap::OverlapTrace;
use crate::plan::{
    BasisItem, BufferId, ExchangeRecv, ExtractItem, MergeItem, SparsifyItem, SyrkItem, TrsmItem,
};
use std::any::Any;

/// One batched launch: an opcode plus `BufferId` operand lists borrowed
/// straight from the plan IR — the executor never rebuilds host slices.
#[derive(Clone, Copy, Debug)]
pub enum Launch<'p> {
    /// Batched in-place Cholesky of the listed buffers.
    Potrf { level: usize, bufs: &'p [BufferId] },
    /// Batched `b <- b · L_lᵀ⁻¹` panel solves.
    TrsmRightLt { level: usize, items: &'p [TrsmItem] },
    /// Batched `c <- c - a aᵀ` Schur updates.
    SchurSelf { level: usize, items: &'p [SyrkItem] },
    /// Batched two-sided basis transforms `dst = uᵀ · a · v`.
    Sparsify { level: usize, items: &'p [SparsifyItem] },
    /// Device-side submatrix extraction.
    Extract { items: &'p [ExtractItem] },
    /// Device-side parent-block assembly.
    Merge { items: &'p [MergeItem] },
    /// Batched `u`/`uᵀ` applied to vectors: items are `(u, src, dst)`.
    ApplyBasis { level: usize, trans: bool, items: &'p [BasisItem] },
    /// Batched in-place forward TRSV; items are `(l, x)`.
    TrsvFwd { level: usize, items: &'p [(BufferId, BufferId)] },
    /// Batched in-place backward TRSV; items are `(l, x)`.
    TrsvBwd { level: usize, items: &'p [(BufferId, BufferId)] },
    /// Batched `y += alpha · op(a) x`; items are `(a, x, y)`.
    GemvAcc {
        level: usize,
        trans: bool,
        alpha: f64,
        items: &'p [(BufferId, BufferId, BufferId)],
    },
    /// Vector splits `(src, at, lo, hi)`.
    Split { items: &'p [(BufferId, usize, BufferId, BufferId)] },
    /// Vector concatenations `(dst, a, b)`.
    Concat { items: &'p [(BufferId, BufferId, BufferId)] },
    /// Buffer copies `(dst, src)`.
    CopyBuf { items: &'p [(BufferId, BufferId)] },
    /// Elementwise vector adds `(dst, a, b)`.
    AddVec { items: &'p [(BufferId, BufferId, BufferId)] },
    /// Dense root solve `x <- (L Lᵀ)⁻¹ x` against the resident root factor.
    RootSolve { l: BufferId, x: BufferId },
    /// Cross-rank matrix rendezvous (SPMD rank plans only): `sends` leave
    /// this rank (staying live locally), `recvs` arrive and define their
    /// buffers. Routed through the executor's [`Transport`] endpoint —
    /// never dispatched to a device kernel.
    ///
    /// [`Transport`]: crate::dist::exec::Transport
    Exchange { level: usize, sends: &'p [BufferId], recvs: &'p [ExchangeRecv] },
    /// Cross-rank vector rendezvous (solve phase); recvs are
    /// `(from, buf, len)`. Same executor-side routing as [`Launch::Exchange`].
    ExchangeVec {
        level: usize,
        sends: &'p [BufferId],
        recvs: &'p [(u32, BufferId, u32)],
    },
}

impl Launch<'_> {
    /// Short opcode name (diagnostics / traces).
    pub fn opcode(&self) -> &'static str {
        match self {
            Launch::Potrf { .. } => "POTRF",
            Launch::TrsmRightLt { .. } => "TRSM",
            Launch::SchurSelf { .. } => "SYRK",
            Launch::Sparsify { .. } => "SPARSIFY",
            Launch::Extract { .. } => "EXTRACT",
            Launch::Merge { .. } => "MERGE",
            Launch::ApplyBasis { .. } => "BASIS",
            Launch::TrsvFwd { .. } => "TRSV",
            Launch::TrsvBwd { .. } => "TRSVT",
            Launch::GemvAcc { .. } => "GEMV",
            Launch::Split { .. } => "SPLIT",
            Launch::Concat { .. } => "CONCAT",
            Launch::CopyBuf { .. } => "COPY",
            Launch::AddVec { .. } => "ADD",
            Launch::RootSolve { .. } => "POTRS",
            Launch::Exchange { .. } => "EXCHANGE",
            Launch::ExchangeVec { .. } => "EXCHANGEV",
        }
    }
}

/// A device-owned buffer arena: the residency boundary of the execution
/// engine. Buffers are matrices or vectors addressed by [`BufferId`];
/// `upload`/`download` are the only host↔device transfers, `alloc`/`free`
/// manage device-side lifetime. Implementations grow on demand, so the
/// construction capacity is a hint.
///
/// Arenas are `Send + Sync`: after factorization a session shares its
/// factor arena read-only across concurrently solving threads (all `&self`
/// methods); mutation still requires `&mut self`, so exclusive phases
/// (factorization, refactorization) are enforced by the borrow checker
/// rather than a runtime lock.
pub trait DeviceArena: Send + Sync {
    /// Host → device: copy a matrix into slot `id` (overwrites).
    fn upload(&mut self, id: BufferId, m: &Matrix);
    /// Host → device: copy a vector into slot `id` (overwrites).
    fn upload_vec(&mut self, id: BufferId, v: &[f64]);
    /// Allocate a zero matrix at `id` (overwrites any previous content).
    fn alloc(&mut self, id: BufferId, rows: usize, cols: usize);
    /// Allocate a zero vector at `id` (overwrites any previous content).
    fn alloc_vec(&mut self, id: BufferId, len: usize);
    /// Device → host: copy the matrix at `id` out. Callers must
    /// [`Device::fence`] first if launches may still be in flight.
    fn download(&self, id: BufferId) -> Matrix;
    /// Device → host, destructive: move the matrix at `id` out and free
    /// the slot. Host-memory arenas override the default download+free
    /// with a true move (no copy) — the transient-factorize fast path.
    fn take(&mut self, id: BufferId) -> Matrix {
        let m = self.download(id);
        self.free(id);
        m
    }
    /// Device → host: copy the vector at `id` out.
    fn download_vec(&self, id: BufferId) -> Vec<f64>;
    /// Release slot `id`. Panics on double-free — the plan's `Free` steps
    /// are exact, so a double-free is a recorder bug.
    fn free(&mut self, id: BufferId);
    /// Release every live buffer with id ≥ `from`. Tolerant of
    /// already-empty slots: the executor uses this to release a solve's
    /// vector region even when a mid-launch panic left slots half-moved,
    /// so the resident factor region below `from` keeps its balance.
    fn free_region(&mut self, from: BufferId);
    /// Number of live (allocated) buffers — the leak-check hook.
    fn live(&self) -> usize;
    /// Whether slot `id` currently holds a buffer. `false` for ids that
    /// were never written, already freed, or out of the arena's range —
    /// the [`validate::ValidatingDevice`] liveness-audit hook.
    fn is_live(&self, id: BufferId) -> bool;
    /// Payload bytes of the live buffers (8 bytes per f64 entry), or 0 if
    /// the implementation does not track footprint.
    fn bytes(&self) -> usize {
        0
    }
    /// High-water mark of [`bytes`](DeviceArena::bytes) over this arena's
    /// lifetime — the peak-footprint hook for `BuildStats`.
    fn peak_bytes(&self) -> usize {
        0
    }
    /// Total bytes this arena pins, including allocator bookkeeping (slot
    /// tables etc.) on top of the live payload — always ≥
    /// [`bytes`](DeviceArena::bytes). This is what an *empty* arena still
    /// costs: a workspace region whose vectors were all freed reports
    /// payload 0 here but keeps its slot table, which is exactly the
    /// memory [`WorkspacePool::shrink_to`] releases. Default: payload
    /// only.
    fn footprint_bytes(&self) -> usize {
        self.bytes()
    }
    /// Downcast support for concrete-device launch implementations.
    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// The backend contract: create arenas, execute launches against them.
/// This is the narrowest, hottest interface in the codebase — everything
/// the ULV factorization and substitution do numerically flows through
/// [`Device::launch`] with arena operands.
pub trait Device: Send + Sync {
    /// Create an arena sized for `capacity` buffers (a hint; arenas grow).
    fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena>;
    /// Execute one batched *factorization-phase* launch against `arena`
    /// (`Potrf`, `TrsmRightLt`, `SchurSelf`, `Sparsify`, `Extract`,
    /// `Merge`). May be asynchronous; ordering with other launches on the
    /// same arena follows program order unless the implementation can
    /// prove independence. Substitution opcodes go through
    /// [`Device::launch_solve`] instead (and panic here).
    fn launch(&self, arena: &mut dyn DeviceArena, launch: &Launch<'_>);
    /// Execute one *substitution-phase* launch: matrix operands (diagonal
    /// Cholesky blocks, `L(r)`/`L(s)` panels, bases, the root factor) are
    /// **read** from the immutable `factor` region; vector operands live in
    /// the caller's exclusive `ws` region. This is the concurrent-solve
    /// entry point — any number of threads may call it simultaneously with
    /// the same factor region and distinct workspaces; implementations must
    /// not require external synchronization beyond that split. Panics on
    /// factorization-only opcodes (`Potrf`, `TrsmRightLt`, `SchurSelf`,
    /// `Sparsify`, `Extract`, `Merge`).
    fn launch_solve(
        &self,
        factor: &dyn DeviceArena,
        ws: &mut dyn DeviceArena,
        launch: &Launch<'_>,
    );
    /// Hint: subsequent launches belong to tree level `level`. A
    /// multi-stream implementation may use this to double-buffer adjacent
    /// levels; host-synchronous backends ignore it.
    fn stream(&self, _level: usize) {}
    /// Drain all outstanding asynchronous work. Must be called before any
    /// `download` observes launch results; no-op for synchronous backends.
    fn fence(&self) {}
    /// Drain and hand back the per-stream busy intervals recorded since
    /// the last call — `Some` only on overlapping devices
    /// ([`r#async::AsyncDevice`]); synchronous backends return `None`.
    /// The session facade stores the factorization's trace in
    /// [`crate::solver::BuildStats::overlap`].
    fn take_overlap_trace(&self) -> Option<OverlapTrace> {
        None
    }
    /// Human-readable backend name (diagnostics / reports).
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------
// Host-memory arena shared by the in-tree backends.
// ---------------------------------------------------------------------

/// One arena slot: empty, a matrix block, or a substitution vector.
enum Slot {
    Empty,
    Mat(Matrix),
    Vec(Vec<f64>),
}

impl Slot {
    fn is_empty(&self) -> bool {
        matches!(self, Slot::Empty)
    }

    /// Payload bytes of this slot (8 bytes per f64 entry).
    fn bytes(&self) -> usize {
        8 * match self {
            Slot::Empty => 0,
            Slot::Mat(m) => m.rows() * m.cols(),
            Slot::Vec(v) => v.len(),
        }
    }
}

/// Host-memory [`DeviceArena`] used by the native, serial, and PJRT
/// backends (for PJRT the "device" stages in host memory and ships padded
/// buffers to the XLA executables per launch; a real GPU PJRT arena would
/// hold device literals instead).
pub struct HostArena {
    slots: Vec<Slot>,
    live: usize,
    bytes: usize,
    peak_bytes: usize,
}

impl HostArena {
    pub fn with_capacity(capacity: usize) -> HostArena {
        let mut slots = Vec::new();
        slots.resize_with(capacity, || Slot::Empty);
        HostArena { slots, live: 0, bytes: 0, peak_bytes: 0 }
    }

    fn ensure(&mut self, id: BufferId) {
        let idx = id.0 as usize;
        if idx >= self.slots.len() {
            self.slots.resize_with(idx + 1, || Slot::Empty);
        }
    }

    fn put_slot(&mut self, id: BufferId, slot: Slot) {
        self.ensure(id);
        let idx = id.0 as usize;
        if self.slots[idx].is_empty() && !slot.is_empty() {
            self.live += 1;
        }
        // Subtract the overwritten slot before adding, so overwriting a
        // live buffer never transiently inflates the peak.
        self.bytes -= self.slots[idx].bytes();
        self.bytes += slot.bytes();
        self.peak_bytes = self.peak_bytes.max(self.bytes);
        self.slots[idx] = slot;
    }

    /// Move a matrix out of the arena (cheap: a `Vec` pointer move).
    pub(crate) fn take_mat(&mut self, id: BufferId) -> Matrix {
        let idx = id.0 as usize;
        match std::mem::replace(
            self.slots.get_mut(idx).expect("buffer id out of arena range"),
            Slot::Empty,
        ) {
            Slot::Mat(m) => {
                self.live -= 1;
                self.bytes -= 8 * m.rows() * m.cols();
                m
            }
            Slot::Vec(_) => panic!("buffer B{idx} holds a vector, matrix expected"),
            Slot::Empty => panic!("buffer B{idx} read before upload (or after free)"),
        }
    }

    pub(crate) fn put_mat(&mut self, id: BufferId, m: Matrix) {
        self.put_slot(id, Slot::Mat(m));
    }

    pub(crate) fn get_mat(&self, id: BufferId) -> &Matrix {
        let idx = id.0 as usize;
        match self.slots.get(idx).expect("buffer id out of arena range") {
            Slot::Mat(m) => m,
            Slot::Vec(_) => panic!("buffer B{idx} holds a vector, matrix expected"),
            Slot::Empty => panic!("buffer B{idx} read before upload (or after free)"),
        }
    }

    pub(crate) fn take_vec(&mut self, id: BufferId) -> Vec<f64> {
        let idx = id.0 as usize;
        match std::mem::replace(
            self.slots.get_mut(idx).expect("buffer id out of arena range"),
            Slot::Empty,
        ) {
            Slot::Vec(v) => {
                self.live -= 1;
                self.bytes -= 8 * v.len();
                v
            }
            Slot::Mat(_) => panic!("buffer B{idx} holds a matrix, vector expected"),
            Slot::Empty => panic!("buffer B{idx} read before upload (or after free)"),
        }
    }

    pub(crate) fn put_vec(&mut self, id: BufferId, v: Vec<f64>) {
        self.put_slot(id, Slot::Vec(v));
    }

    pub(crate) fn get_vec(&self, id: BufferId) -> &Vec<f64> {
        let idx = id.0 as usize;
        match self.slots.get(idx).expect("buffer id out of arena range") {
            Slot::Vec(v) => v,
            Slot::Mat(_) => panic!("buffer B{idx} holds a matrix, vector expected"),
            Slot::Empty => panic!("buffer B{idx} read before upload (or after free)"),
        }
    }
}

impl DeviceArena for HostArena {
    fn upload(&mut self, id: BufferId, m: &Matrix) {
        self.put_mat(id, m.clone());
    }

    fn upload_vec(&mut self, id: BufferId, v: &[f64]) {
        self.put_vec(id, v.to_vec());
    }

    fn alloc(&mut self, id: BufferId, rows: usize, cols: usize) {
        self.put_mat(id, Matrix::zeros(rows, cols));
    }

    fn alloc_vec(&mut self, id: BufferId, len: usize) {
        self.put_vec(id, vec![0.0; len]);
    }

    fn download(&self, id: BufferId) -> Matrix {
        self.get_mat(id).clone()
    }

    fn take(&mut self, id: BufferId) -> Matrix {
        self.take_mat(id)
    }

    fn download_vec(&self, id: BufferId) -> Vec<f64> {
        self.get_vec(id).clone()
    }

    fn free(&mut self, id: BufferId) {
        let idx = id.0 as usize;
        let slot = self.slots.get_mut(idx).expect("buffer id out of arena range");
        assert!(!slot.is_empty(), "double free of buffer B{idx}");
        let freed = std::mem::replace(slot, Slot::Empty);
        self.bytes -= freed.bytes();
        self.live -= 1;
    }

    fn free_region(&mut self, from: BufferId) {
        for idx in (from.0 as usize)..self.slots.len() {
            if !self.slots[idx].is_empty() {
                let freed = std::mem::replace(&mut self.slots[idx], Slot::Empty);
                self.bytes -= freed.bytes();
                self.live -= 1;
            }
        }
    }

    fn live(&self) -> usize {
        self.live
    }

    fn is_live(&self, id: BufferId) -> bool {
        self.slots.get(id.0 as usize).map(|s| !s.is_empty()).unwrap_or(false)
    }

    fn bytes(&self) -> usize {
        self.bytes
    }

    fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    fn footprint_bytes(&self) -> usize {
        // The slot table never shrinks (ids are stable addresses), so an
        // emptied workspace region still pins capacity × slot size.
        self.bytes + self.slots.capacity() * std::mem::size_of::<Slot>()
    }

    fn as_any(&self) -> &dyn Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

// ---------------------------------------------------------------------
// Shared launch execution for host-memory backends.
// ---------------------------------------------------------------------

/// The batched math kernels a host-memory backend supplies; the shared
/// [`exec_host_launch`] handles arena operand gathering and all
/// data-movement opcodes, so each backend only implements the math.
/// Signatures mirror the batched cuBLAS/cuSOLVER calls of paper §4.
pub(crate) trait HostKernels {
    fn potrf(&self, level: usize, blocks: &mut [Matrix]);
    fn trsm_right_lt(&self, level: usize, l: &[&Matrix], b: &mut [Matrix]);
    fn schur_self(&self, level: usize, a: &[&Matrix], c: &mut [Matrix]);
    fn sparsify(&self, level: usize, u: &[&Matrix], a: &[Matrix], v: &[&Matrix]) -> Vec<Matrix>;
    fn trsv_fwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]);
    fn trsv_bwd(&self, level: usize, l: &[&Matrix], x: &mut [Vec<f64>]);
    fn gemv_acc(
        &self,
        level: usize,
        alpha: f64,
        a: &[&Matrix],
        trans: bool,
        x: &[&[f64]],
        y: &mut [Vec<f64>],
    );
    fn apply_basis(&self, level: usize, u: &[&Matrix], trans: bool, x: &[&[f64]])
        -> Vec<Vec<f64>>;
}

/// Downcast a trait-object arena to the host arena the in-tree backends
/// share.
pub(crate) fn host_arena(arena: &mut dyn DeviceArena) -> &mut HostArena {
    arena
        .as_any_mut()
        .downcast_mut::<HostArena>()
        .expect("host-memory backend requires a HostArena (arena from another device?)")
}

/// Shared read-only downcast (the factor region of a solve launch).
pub(crate) fn host_arena_ref(arena: &dyn DeviceArena) -> &HostArena {
    arena
        .as_any()
        .downcast_ref::<HostArena>()
        .expect("host-memory backend requires a HostArena (arena from another device?)")
}

/// Insert an *owned* matrix into `arena` at `id`: a pointer move on the
/// shared [`HostArena`] (all three in-tree backends), an `upload` copy on
/// anything else. This is how the async executor moves buffers between the
/// shared arena and a launch's private arena without per-launch host
/// marshalling.
pub(crate) fn put_owned(arena: &mut dyn DeviceArena, id: BufferId, m: Matrix) {
    match arena.as_any_mut().downcast_mut::<HostArena>() {
        Some(host) => host.put_mat(id, m),
        None => arena.upload(id, &m),
    }
}

/// A [`Launch`]'s operands classified by role — the single source of truth
/// for hazard edges ([`r#async::AsyncDevice`]) and the hazard audit
/// ([`validate::ValidatingDevice`]). Lists are *not* deduplicated: repeats
/// (e.g. one diagonal block shared by many TRSM panels) are preserved so
/// the audit can see per-item aliasing.
///
/// Matrix operands live in the factorization arena (the factor region for
/// substitution launches); vector operands live in the solve workspace.
/// `*_rw` buffers are read *and* written in place by the kernel; `*_writes`
/// are created/overwritten outputs.
#[derive(Clone, Debug, Default)]
pub(crate) struct LaunchOperands {
    pub mat_reads: Vec<BufferId>,
    pub mat_rw: Vec<BufferId>,
    pub mat_writes: Vec<BufferId>,
    pub vec_reads: Vec<BufferId>,
    pub vec_rw: Vec<BufferId>,
    pub vec_writes: Vec<BufferId>,
}

/// Classify every operand of a launch by role (see [`LaunchOperands`]).
pub(crate) fn launch_operands(launch: &Launch<'_>) -> LaunchOperands {
    let mut ops = LaunchOperands::default();
    match launch {
        Launch::Potrf { bufs, .. } => {
            ops.mat_rw.extend_from_slice(bufs);
        }
        Launch::TrsmRightLt { items, .. } => {
            for it in items.iter() {
                ops.mat_reads.push(it.l);
                ops.mat_rw.push(it.b);
            }
        }
        Launch::SchurSelf { items, .. } => {
            for it in items.iter() {
                ops.mat_reads.push(it.a);
                ops.mat_rw.push(it.c);
            }
        }
        Launch::Sparsify { items, .. } => {
            for it in items.iter() {
                ops.mat_reads.push(it.u);
                ops.mat_reads.push(it.a);
                ops.mat_reads.push(it.v);
                ops.mat_writes.push(it.dst);
            }
        }
        Launch::Extract { items } => {
            for it in items.iter() {
                ops.mat_reads.push(it.src);
                ops.mat_writes.push(it.dst);
            }
        }
        Launch::Merge { items } => {
            for it in items.iter() {
                for p in &it.parts {
                    ops.mat_reads.push(p.src);
                }
                ops.mat_writes.push(it.dst);
            }
        }
        Launch::ApplyBasis { items, .. } => {
            for &(u, src, dst) in items.iter() {
                ops.mat_reads.push(u);
                ops.vec_reads.push(src);
                ops.vec_writes.push(dst);
            }
        }
        Launch::TrsvFwd { items, .. } | Launch::TrsvBwd { items, .. } => {
            for &(l, x) in items.iter() {
                ops.mat_reads.push(l);
                ops.vec_rw.push(x);
            }
        }
        Launch::GemvAcc { items, .. } => {
            for &(a, x, y) in items.iter() {
                ops.mat_reads.push(a);
                ops.vec_reads.push(x);
                ops.vec_rw.push(y);
            }
        }
        Launch::Split { items } => {
            for &(src, _, lo, hi) in items.iter() {
                ops.vec_reads.push(src);
                ops.vec_writes.push(lo);
                ops.vec_writes.push(hi);
            }
        }
        Launch::Concat { items } | Launch::AddVec { items } => {
            for &(dst, a, b) in items.iter() {
                ops.vec_reads.push(a);
                ops.vec_reads.push(b);
                ops.vec_writes.push(dst);
            }
        }
        Launch::CopyBuf { items } => {
            for &(dst, src) in items.iter() {
                ops.vec_reads.push(src);
                ops.vec_writes.push(dst);
            }
        }
        Launch::RootSolve { l, x } => {
            ops.mat_reads.push(*l);
            ops.vec_rw.push(*x);
        }
        Launch::Exchange { sends, recvs, .. } => {
            ops.mat_reads.extend_from_slice(sends);
            for r in recvs.iter() {
                ops.mat_writes.push(r.buf);
            }
        }
        Launch::ExchangeVec { sends, recvs, .. } => {
            ops.vec_reads.extend_from_slice(sends);
            for &(_, buf, _) in recvs.iter() {
                ops.vec_writes.push(buf);
            }
        }
    }
    ops
}

/// Execute one *factorization-phase* launch against a [`HostArena`] using
/// `kern`'s batched math. Matrix operands are *moved* out of the arena for
/// in-place kernels and moved back afterwards — pointer moves, no data
/// copies — which is this backend family's analog of building device
/// pointer arrays for the batched cuBLAS calls. Substitution opcodes have
/// exactly one executor, [`exec_host_solve_launch`] (the factor/workspace
/// split) — this function panics on them so the two launch paths can never
/// silently diverge.
pub(crate) fn exec_host_launch(kern: &dyn HostKernels, arena: &mut HostArena, launch: &Launch) {
    match launch {
        Launch::Potrf { level, bufs } => {
            let mut blocks: Vec<Matrix> = bufs.iter().map(|&b| arena.take_mat(b)).collect();
            kern.potrf(*level, &mut blocks);
            for (&b, m) in bufs.iter().zip(blocks) {
                arena.put_mat(b, m);
            }
        }
        Launch::TrsmRightLt { level, items } => {
            let mut panels: Vec<Matrix> = items.iter().map(|it| arena.take_mat(it.b)).collect();
            {
                let diags: Vec<&Matrix> = items.iter().map(|it| arena.get_mat(it.l)).collect();
                kern.trsm_right_lt(*level, &diags, &mut panels);
            }
            for (it, m) in items.iter().zip(panels) {
                arena.put_mat(it.b, m);
            }
        }
        Launch::SchurSelf { level, items } => {
            let mut cs: Vec<Matrix> = items.iter().map(|it| arena.take_mat(it.c)).collect();
            {
                let aas: Vec<&Matrix> = items.iter().map(|it| arena.get_mat(it.a)).collect();
                kern.schur_self(*level, &aas, &mut cs);
            }
            for (it, m) in items.iter().zip(cs) {
                arena.put_mat(it.c, m);
            }
        }
        Launch::Sparsify { level, items } => {
            let a_mats: Vec<Matrix> = items.iter().map(|it| arena.take_mat(it.a)).collect();
            let out = {
                let us: Vec<&Matrix> = items.iter().map(|it| arena.get_mat(it.u)).collect();
                let vs: Vec<&Matrix> = items.iter().map(|it| arena.get_mat(it.v)).collect();
                kern.sparsify(*level, &us, &a_mats, &vs)
            };
            for (it, m) in items.iter().zip(a_mats) {
                arena.put_mat(it.a, m);
            }
            for (it, m) in items.iter().zip(out) {
                arena.put_mat(it.dst, m);
            }
        }
        Launch::Extract { items } => {
            for it in items.iter() {
                let m = arena.get_mat(it.src).submatrix(it.r0, it.c0, it.rows, it.cols);
                arena.put_mat(it.dst, m);
            }
        }
        Launch::Merge { items } => {
            for item in items.iter() {
                let mut merged = Matrix::zeros(item.rows, item.cols);
                for part in &item.parts {
                    let src = arena.get_mat(part.src);
                    if src.rows() == part.rows && src.cols() == part.cols {
                        merged.set_submatrix(part.roff, part.coff, src);
                    } else {
                        let blk = src.submatrix(0, 0, part.rows, part.cols);
                        merged.set_submatrix(part.roff, part.coff, &blk);
                    }
                }
                arena.put_mat(item.dst, merged);
            }
        }
        Launch::Exchange { .. } | Launch::ExchangeVec { .. } => panic!(
            "{} is a comm launch; it executes through the executor's transport \
             endpoint, never through a device",
            launch.opcode()
        ),
        other => panic!(
            "{} is a substitution-phase launch; it executes through launch_solve \
             (exec_host_solve_launch), never through the factorization launch path",
            other.opcode()
        ),
    }
}

/// Execute one substitution-phase launch for a host-memory backend: matrix
/// operands resolve read-only in `factor` (the session's resident factor
/// region — shared by every concurrently solving thread), vector operands
/// resolve in the caller's exclusive `ws` region. The split is total: the
/// substitution programs never write a matrix and never read a vector
/// outside their own region, which is exactly why no lock is needed.
pub(crate) fn exec_host_solve_launch(
    kern: &dyn HostKernels,
    factor: &HostArena,
    ws: &mut HostArena,
    launch: &Launch,
) {
    match launch {
        Launch::ApplyBasis { level, trans, items } => {
            let outs = {
                let us: Vec<&Matrix> = items.iter().map(|&(u, _, _)| factor.get_mat(u)).collect();
                let xs: Vec<&[f64]> =
                    items.iter().map(|&(_, s, _)| ws.get_vec(s).as_slice()).collect();
                kern.apply_basis(*level, &us, *trans, &xs)
            };
            for (&(_, _, d), o) in items.iter().zip(outs) {
                ws.put_vec(d, o);
            }
        }
        Launch::TrsvFwd { level, items } => {
            let mut xs: Vec<Vec<f64>> = items.iter().map(|&(_, v)| ws.take_vec(v)).collect();
            {
                let ls: Vec<&Matrix> = items.iter().map(|&(l, _)| factor.get_mat(l)).collect();
                kern.trsv_fwd(*level, &ls, &mut xs);
            }
            for (&(_, v), xv) in items.iter().zip(xs) {
                ws.put_vec(v, xv);
            }
        }
        Launch::TrsvBwd { level, items } => {
            let mut xs: Vec<Vec<f64>> = items.iter().map(|&(_, v)| ws.take_vec(v)).collect();
            {
                let ls: Vec<&Matrix> = items.iter().map(|&(l, _)| factor.get_mat(l)).collect();
                kern.trsv_bwd(*level, &ls, &mut xs);
            }
            for (&(_, v), xv) in items.iter().zip(xs) {
                ws.put_vec(v, xv);
            }
        }
        Launch::GemvAcc { level, trans, alpha, items } => {
            let mut ys: Vec<Vec<f64>> = items.iter().map(|&(_, _, y)| ws.take_vec(y)).collect();
            {
                let mats: Vec<&Matrix> =
                    items.iter().map(|&(a, _, _)| factor.get_mat(a)).collect();
                let xs: Vec<&[f64]> =
                    items.iter().map(|&(_, x, _)| ws.get_vec(x).as_slice()).collect();
                kern.gemv_acc(*level, *alpha, &mats, *trans, &xs, &mut ys);
            }
            for (&(_, _, y), yv) in items.iter().zip(ys) {
                ws.put_vec(y, yv);
            }
        }
        Launch::Split { items } => {
            for &(src, at, lo, hi) in items.iter() {
                let (a, b) = {
                    let s = ws.get_vec(src);
                    (s[..at].to_vec(), s[at..].to_vec())
                };
                ws.put_vec(lo, a);
                ws.put_vec(hi, b);
            }
        }
        Launch::Concat { items } => {
            for &(dst, a, b) in items.iter() {
                let mut v = ws.get_vec(a).clone();
                v.extend_from_slice(ws.get_vec(b));
                ws.put_vec(dst, v);
            }
        }
        Launch::CopyBuf { items } => {
            for &(dst, src) in items.iter() {
                let v = ws.get_vec(src).clone();
                ws.put_vec(dst, v);
            }
        }
        Launch::AddVec { items } => {
            for &(dst, a, b) in items.iter() {
                let v: Vec<f64> = ws
                    .get_vec(a)
                    .iter()
                    .zip(ws.get_vec(b))
                    .map(|(&p, &q)| p + q)
                    .collect();
                ws.put_vec(dst, v);
            }
        }
        Launch::RootSolve { l, x } => {
            let mut xv = ws.take_vec(*x);
            {
                let lm = factor.get_mat(*l);
                flops::add(2 * (lm.rows() * lm.rows()) as u64);
                chol::potrs(lm, &mut xv);
            }
            ws.put_vec(*x, xv);
        }
        Launch::Exchange { .. } | Launch::ExchangeVec { .. } => panic!(
            "{} is a comm launch; it executes through the executor's transport \
             endpoint, never through a device",
            launch.opcode()
        ),
        other => panic!(
            "{} is a factorization-phase launch; launch_solve only executes substitution opcodes",
            other.opcode()
        ),
    }
}

// ---------------------------------------------------------------------
// Pooled per-solve vector regions.
// ---------------------------------------------------------------------

/// One solve call's private vector region, carved above the resident
/// factor region in the buffer-id space: every program id at or above
/// [`SolveProgram::vec_base`](crate::plan::SolveProgram::vec_base) resolves
/// in this region's backing slots, while matrix ids below it resolve in
/// the shared read-only factor region. Distinct regions back disjoint
/// storage, so concurrent solves never observe each other — the trait-
/// object analog of carving per-call allocations at distinct offsets above
/// the factor in one device heap.
///
/// Regions come from a [`WorkspacePool`] in session use (so a solve
/// re-leases warm storage instead of allocating), or from
/// [`VecRegion::new`] for standalone one-shot solves.
pub struct VecRegion {
    arena: Box<dyn DeviceArena>,
    index: usize,
}

impl VecRegion {
    /// Carve a fresh region on `device`. `index` identifies the region
    /// (pool slot for pooled regions, 0 for standalone ones).
    pub fn new(device: &dyn Device, index: usize) -> VecRegion {
        VecRegion { arena: device.new_arena(0), index }
    }

    /// This region's slot index in its pool (diagnostics).
    pub fn index(&self) -> usize {
        self.index
    }

    /// Mutable access to the backing slots (vector uploads/allocs and the
    /// workspace side of [`Device::launch_solve`]).
    pub fn arena(&mut self) -> &mut dyn DeviceArena {
        self.arena.as_mut()
    }

    /// Shared access to the backing slots (downloads).
    pub fn arena_ref(&self) -> &dyn DeviceArena {
        self.arena.as_ref()
    }

    /// Release every slot at or above `from` — tolerant of half-moved
    /// slots after a mid-launch panic (built on
    /// [`DeviceArena::free_region`]). The region itself stays usable and
    /// returns to its pool, so a panicking launch can never shrink pool
    /// capacity.
    pub fn reset(&mut self, from: BufferId) {
        self.arena.free_region(from);
    }

    /// Live vector buffers in this region (0 between solves — the balance
    /// invariant the guard tests assert).
    pub fn live(&self) -> usize {
        self.arena.live()
    }

    /// Bytes this region pins on the device, including allocator
    /// bookkeeping ([`DeviceArena::footprint_bytes`]). Idle regions hold
    /// no payload (they are reset on release) but still pin their slot
    /// tables — the memory [`WorkspacePool::shrink_to`] releases.
    pub fn footprint_bytes(&self) -> usize {
        self.arena.footprint_bytes()
    }
}

/// A pool of [`VecRegion`]s shared by every solve entry point of one
/// session: concurrent callers lease distinct regions and solve
/// simultaneously against the session's shared factor region; sequential
/// callers keep re-leasing the same warm region. The pool grows on demand
/// (one region per concurrently in-flight solve) and never shrinks on its
/// own — a leased region always comes back, even when the solve panics
/// ([`Workspace`] returns it on drop). Long-lived owners (the serve-layer
/// session cache) call [`shrink_to`](WorkspacePool::shrink_to) on idle/
/// evict paths to release post-burst capacity.
#[derive(Default)]
pub struct WorkspacePool {
    idle: std::sync::Mutex<Vec<VecRegion>>,
    created: std::sync::atomic::AtomicUsize,
}

impl WorkspacePool {
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// Lease a region: pops an idle one, or carves a new region on
    /// `device` when every existing region is in flight.
    pub fn acquire(&self, device: &dyn Device) -> Workspace<'_> {
        // Drop the pool lock before carving: a cold-start burst of N
        // concurrent solves must create its N regions in parallel, not
        // serialize arena construction behind the idle-list mutex.
        let popped = self.idle.lock().unwrap().pop();
        let region = popped.unwrap_or_else(|| {
            let index = self.created.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            VecRegion::new(device, index)
        });
        Workspace { region: Some(region), pool: self }
    }

    /// Regions currently idle in the pool (equals
    /// [`created`](WorkspacePool::created) when no solve is in flight —
    /// the no-leaked-regions invariant).
    pub fn idle(&self) -> usize {
        self.idle.lock().unwrap().len()
    }

    /// Regions the pool currently owns (leased + idle). Tracks the
    /// high-water mark of solve concurrency until a
    /// [`shrink_to`](WorkspacePool::shrink_to) drops idle regions.
    pub fn created(&self) -> usize {
        self.created.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Bytes currently pinned by the *idle* regions (leased regions are
    /// accounted by their in-flight solves). Because idle regions are
    /// payload-free, this is pure bookkeeping overhead — exactly what
    /// [`shrink_to`](WorkspacePool::shrink_to) reclaims.
    pub fn bytes(&self) -> usize {
        self.idle.lock().unwrap().iter().map(VecRegion::footprint_bytes).sum()
    }

    /// Drop idle regions until at most `keep` remain idle, returning how
    /// many were dropped. In-flight regions are untouched (they return to
    /// the pool as usual), so this is safe to call concurrently with
    /// solves: a post-burst server session calls `shrink_to(1)` to stop
    /// pinning peak-concurrency workspace memory while staying warm for
    /// the steady-state request rate.
    pub fn shrink_to(&self, keep: usize) -> usize {
        let mut idle = self.idle.lock().unwrap();
        let dropped = idle.len().saturating_sub(keep);
        idle.truncate(keep);
        self.created.fetch_sub(dropped, std::sync::atomic::Ordering::Relaxed);
        dropped
    }

    fn release(&self, mut region: VecRegion) {
        if region.live() != 0 {
            // A panic before the executor's own region reset (e.g. during
            // vector allocation) can leave slots live; clear them so the
            // region re-enters the pool empty.
            region.reset(BufferId(0));
        }
        self.idle.lock().unwrap().push(region);
    }
}

/// RAII lease of a [`VecRegion`]: returns the region to its pool on drop —
/// including drops during unwinding, so a panicking solve can't shrink the
/// pool.
pub struct Workspace<'p> {
    region: Option<VecRegion>,
    pool: &'p WorkspacePool,
}

impl Workspace<'_> {
    /// The leased region.
    pub fn region(&mut self) -> &mut VecRegion {
        self.region.as_mut().expect("workspace region already returned")
    }
}

impl Drop for Workspace<'_> {
    fn drop(&mut self) {
        if let Some(region) = self.region.take() {
            self.pool.release(region);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_arena_tracks_live_buffers() {
        let mut arena = HostArena::with_capacity(4);
        assert_eq!(arena.live(), 0);
        arena.upload(BufferId(0), &Matrix::eye(3));
        arena.upload_vec(BufferId(1), &[1.0, 2.0]);
        assert_eq!(arena.live(), 2);
        // Overwrite keeps the count.
        arena.alloc(BufferId(0), 2, 2);
        assert_eq!(arena.live(), 2);
        assert_eq!(arena.download(BufferId(0)).rows(), 2);
        assert_eq!(arena.download_vec(BufferId(1)), vec![1.0, 2.0]);
        arena.free(BufferId(0));
        arena.free(BufferId(1));
        assert_eq!(arena.live(), 0);
        // Growth on demand past the construction capacity.
        arena.alloc_vec(BufferId(17), 5);
        assert_eq!(arena.live(), 1);
        assert_eq!(arena.download_vec(BufferId(17)).len(), 5);
        // Region free is tolerant of gaps and empty slots (the executor's
        // vector-region cleanup after a mid-launch panic).
        arena.alloc(BufferId(2), 1, 1);
        arena.alloc_vec(BufferId(20), 3);
        assert_eq!(arena.live(), 3);
        arena.free_region(BufferId(10)); // frees 17 and 20, keeps 2
        assert_eq!(arena.live(), 1);
        assert_eq!(arena.download(BufferId(2)).rows(), 1);
        arena.free_region(BufferId(10)); // idempotent on empty region
        assert_eq!(arena.live(), 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn device_arena_rejects_double_free() {
        let mut arena = HostArena::with_capacity(1);
        arena.alloc(BufferId(0), 1, 1);
        arena.free(BufferId(0));
        arena.free(BufferId(0));
    }

    #[test]
    #[should_panic(expected = "read before upload")]
    fn device_arena_rejects_use_after_free() {
        let mut arena = HostArena::with_capacity(1);
        arena.alloc(BufferId(0), 1, 1);
        arena.free(BufferId(0));
        let _ = arena.download(BufferId(0));
    }

    #[test]
    fn device_launch_opcodes_are_named() {
        let l = Launch::Potrf { level: 2, bufs: &[] };
        assert_eq!(l.opcode(), "POTRF");
        let l = Launch::RootSolve { l: BufferId(0), x: BufferId(1) };
        assert_eq!(l.opcode(), "POTRS");
    }

    #[test]
    fn device_arena_tracks_bytes_and_peak() {
        let mut arena = HostArena::with_capacity(4);
        assert_eq!(arena.bytes(), 0);
        arena.upload(BufferId(0), &Matrix::eye(4)); // 16 entries
        arena.upload_vec(BufferId(1), &[1.0, 2.0]); // 2 entries
        assert_eq!(arena.bytes(), 8 * 18);
        assert_eq!(arena.peak_bytes(), 8 * 18);
        // Overwrite with a smaller block shrinks bytes, keeps the peak.
        arena.alloc(BufferId(0), 2, 2);
        assert_eq!(arena.bytes(), 8 * 6);
        assert_eq!(arena.peak_bytes(), 8 * 18);
        // take/free return their bytes.
        let _ = arena.take(BufferId(0));
        arena.free(BufferId(1));
        assert_eq!(arena.bytes(), 0);
        assert_eq!(arena.peak_bytes(), 8 * 18);
        // Region free subtracts too.
        arena.alloc_vec(BufferId(7), 5);
        arena.free_region(BufferId(0));
        assert_eq!(arena.bytes(), 0);
        assert_eq!(arena.live(), 0);
    }

    #[test]
    fn workspace_pool_leases_and_recycles_regions() {
        // SerialBackend lives in solver::backend; use a tiny local device
        // to keep this test self-contained.
        struct Dev;
        impl Device for Dev {
            fn new_arena(&self, capacity: usize) -> Box<dyn DeviceArena> {
                Box::new(HostArena::with_capacity(capacity))
            }
            fn launch(&self, _arena: &mut dyn DeviceArena, _launch: &Launch<'_>) {
                unreachable!("pool test issues no launches")
            }
            fn launch_solve(
                &self,
                _factor: &dyn DeviceArena,
                _ws: &mut dyn DeviceArena,
                _launch: &Launch<'_>,
            ) {
                unreachable!("pool test issues no launches")
            }
            fn name(&self) -> &'static str {
                "test"
            }
        }
        let dev = Dev;
        let pool = WorkspacePool::new();
        assert_eq!((pool.created(), pool.idle()), (0, 0));
        {
            let mut a = pool.acquire(&dev);
            let mut b = pool.acquire(&dev);
            assert_eq!(pool.created(), 2, "two concurrent leases carve two regions");
            assert_ne!(a.region().index(), b.region().index());
            a.region().arena().alloc_vec(BufferId(10), 3);
            assert_eq!(a.region().live(), 1);
            // Dropping a lease with live slots resets the region first.
        }
        assert_eq!(pool.idle(), 2, "both regions returned on drop");
        let mut c = pool.acquire(&dev);
        assert_eq!(pool.created(), 2, "sequential reuse never grows the pool");
        assert_eq!(c.region().live(), 0, "recycled regions come back empty");
    }
}
