//! Batched execution engine (paper §4 "Design considerations for GPUs").
//!
//! The inherently parallel ULV factorization issues its per-level work as
//! *batched* kernel launches — the paper's cuBLAS/cuSOLVER batched calls.
//! The backend contract is the arena-native [`device::Device`] trait: a
//! backend executes [`device::Launch`]es (opcode + `BufferId` operand
//! lists, the plan IR's own vocabulary) against a device-owned
//! [`device::DeviceArena`], so residency, streams, and fences belong to
//! the backend. In-tree implementations:
//!
//! * [`native::NativeBackend`] — thread-pool execution of each batch item
//!   with the from-scratch [`crate::linalg`] kernels (the paper's CPU path);
//! * [`crate::solver::backend::SerialBackend`] — single-threaded golden
//!   reference, bit-identical to native;
//! * [`crate::runtime::PjrtBackend`] — constant-shape, zero-padded batches
//!   executed by AOT-compiled XLA executables (the paper's GPU path; see
//!   `python/compile/` for the JAX/Pallas kernels).
//!
//! Two composable wrappers turn any of the above into richer executors:
//! [`device::AsyncDevice`] overlaps adjacent tree levels on multiple
//! stream queues with a `BufferId`-granular hazard tracker (the spec name
//! is `async:<inner>`), and [`device::ValidatingDevice`] audits every
//! launch against arena state (liveness, out-of-range ids, intra-launch
//! write aliasing) before executing it.
//!
//! Padding follows the paper: batch elements are padded to the level
//! maximum (multiples of 4), and POTRF padding writes unit diagonals so the
//! Cholesky never divides by zero (the paper's "batched AXPY ... via a
//! degenerate GEMM" trick).

pub mod device;
pub mod native;
pub mod pad;

pub use device::{
    AsyncDevice, Device, DeviceArena, HostArena, Launch, ValidatingDevice, VecRegion, Workspace,
    WorkspacePool,
};

use crate::linalg::Matrix;

/// FLOP-count helpers shared by backends.
pub(crate) fn count_sparsify_flops(u: &Matrix, a: &Matrix, v: &Matrix) {
    use crate::metrics::flops;
    flops::add(flops::gemm_flops(u.cols(), a.cols(), u.rows()));
    flops::add(flops::gemm_flops(u.cols(), v.cols(), a.cols()));
    let _ = v;
}
